"""Shared fixtures/helpers for L2 tests: tiny random graphs, full-batch
reference computation, and step-input assembly mirroring the Rust sampler."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from compile.archs import make_arch
from compile.step import StepSpec, build_step, masked_ce


def tiny_graph(n=24, dx=6, c=3, p=0.15, seed=0):
    """Random undirected graph with GCN-normalized adjacency (self-loops)."""
    rng = np.random.default_rng(seed)
    A = (rng.uniform(size=(n, n)) < p).astype(np.float32)
    A = np.maximum(A, A.T)
    np.fill_diagonal(A, 1.0)
    deg = A.sum(1)
    Ahat = (A / np.sqrt(deg[:, None] * deg[None, :])).astype(np.float32)
    X = rng.normal(size=(n, dx)).astype(np.float32)
    y = rng.integers(0, c, size=n).astype(np.int32)
    mask = (rng.uniform(size=n) < 0.6).astype(np.float32)
    return Ahat, X, y, mask


def full_loss_fn(arch, Ahat, X, y, mask):
    nl = float(mask.sum())

    def full_loss(p):
        h = arch.embed0(p, jnp.asarray(X))
        h0 = h
        for l in range(1, arch.L + 1):
            h = arch.layer(p, l, jnp.asarray(Ahat) @ h, h, h0)
        return masked_ce(arch.logits(p, h), jnp.asarray(y), jnp.asarray(mask)) / nl

    return full_loss


def full_forward_all_layers(arch, params, Ahat, X):
    """Exact H^l for l=0..L and exact V^l for l=1..L (via autodiff)."""
    hs = [np.asarray(arch.embed0(params, jnp.asarray(X)))]
    h0 = jnp.asarray(hs[0])
    h = h0
    for l in range(1, arch.L + 1):
        h = arch.layer(params, l, jnp.asarray(Ahat) @ h, h, h0)
        hs.append(np.asarray(h))
    return hs


def full_aux_vars(arch, params, Ahat, X, y, mask):
    """Exact auxiliary variables V^l = dL/dH^l, l = 1..L (full loss)."""
    nl = float(mask.sum())
    L = arch.L
    vs = {}
    for l in range(1, L + 1):
        def from_l(hl, _l=l):
            h = hl
            h0 = arch.embed0(params, jnp.asarray(X))
            for k in range(_l + 1, L + 1):
                h = arch.layer(params, k, jnp.asarray(Ahat) @ h, h, h0)
            return masked_ce(arch.logits(params, h), jnp.asarray(y), jnp.asarray(mask)) / nl

        hs = full_forward_all_layers(arch, params, Ahat, X)
        vs[l] = np.asarray(jax.grad(from_l)(jnp.asarray(hs[l])))
    return vs


def make_step_inputs(arch, params, Ahat, X, y, mask, batch_idx, H_pad,
                     histH, histV, beta_val, bwd_scale, vscale, grad_scale,
                     B_pad=None):
    """Assemble positional train_step inputs the way the Rust sampler does.

    batch_idx: the in-batch nodes; halo = all neighbors outside the batch.
    histH/histV: dicts layer -> full [n, d] arrays to gather halo rows from.
    """
    n = Ahat.shape[0]
    batch = np.asarray(batch_idx)
    in_batch = np.zeros(n, bool)
    in_batch[batch] = True
    nbr = (Ahat[batch] != 0).any(axis=0)
    halo = np.where(nbr & ~in_batch)[0]
    B = B_pad or len(batch)
    assert len(batch) <= B and len(halo) <= H_pad
    L = arch.L

    def pad2(a, r, c):
        out = np.zeros((r, c), np.float32)
        out[: a.shape[0], : a.shape[1]] = a
        return out

    A_bb = pad2(Ahat[np.ix_(batch, batch)], B, B)
    A_bh = pad2(Ahat[np.ix_(batch, halo)], B, H_pad)
    A_hh = pad2(Ahat[np.ix_(halo, halo)], H_pad, H_pad)
    X_b = pad2(X[batch], B, X.shape[1])
    X_h = pad2(X[halo], H_pad, X.shape[1])
    y_b = np.zeros(B, np.int32)
    y_b[: len(batch)] = y[batch]
    m_b = np.zeros(B, np.float32)
    m_b[: len(batch)] = mask[batch]
    y_h = np.zeros(H_pad, np.int32)
    y_h[: len(halo)] = y[halo]
    m_h = np.zeros(H_pad, np.float32)
    m_h[: len(halo)] = mask[halo]
    beta = np.zeros(H_pad, np.float32)
    beta[: len(halo)] = beta_val

    args = [params[nm] for nm in arch.param_names()]
    args += [jnp.asarray(X_b), jnp.asarray(X_h), jnp.asarray(A_bb), jnp.asarray(A_bh), jnp.asarray(A_hh)]
    for l in range(1, L):
        args.append(jnp.asarray(pad2(histH[l][halo], H_pad, arch.dims[l])))
    for l in range(1, L):
        args.append(jnp.asarray(pad2(histV[l][halo], H_pad, arch.dims[l])))
    args += [jnp.asarray(y_b), jnp.asarray(m_b), jnp.asarray(y_h), jnp.asarray(m_h), jnp.asarray(beta),
             jnp.float32(bwd_scale), jnp.float32(vscale), jnp.float32(grad_scale)]
    return args, batch, halo


def run_step(arch, B, H, args):
    step, ins, outs = build_step(StepSpec(arch=arch, B=B, H=H))
    res = step(*args)
    names = [o[0] for o in outs]
    return {nm: res[i] for i, nm in enumerate(names)}
