"""L2 train_step correctness.

Key oracles:
  1. Degenerate full-batch (whole graph in batch, empty halo): the step's
     backward-SGD gradients must equal ``jax.grad`` of the full loss exactly
     (paper Theorem 1 with V_B = V).
  2. Exact histories: with beta=0, bwd_scale=1 and histories set to the exact
     H/V values, LMC's gradients approach backward SGD's; the LMC gradient
     error w.r.t. the full-batch gradient must not exceed GAS's under stale
     histories (paper Theorem 2 / Fig. 3 mechanism).
  3. Padding rows are inert: growing the pad changes nothing.
  4. Method modes (GAS/CLUSTER) are exact specializations of the program.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.archs import make_arch
from gnn_util import (
    full_aux_vars,
    full_forward_all_layers,
    full_loss_fn,
    make_step_inputs,
    run_step,
    tiny_graph,
)

ARCHS = ["gcn", "gcnii"]


def _setup(arch_name, seed=0, n=24, dx=6, c=3):
    Ahat, X, y, mask = tiny_graph(n=n, dx=dx, c=c, seed=seed)
    arch = make_arch(arch_name, L=3, d_x=dx, hidden=8, n_class=c)
    params = arch.init_params(jax.random.PRNGKey(seed + 1))
    return arch, params, Ahat, X, y, mask


@pytest.mark.parametrize("arch_name", ARCHS)
def test_fullbatch_step_equals_autodiff(arch_name):
    arch, params, Ahat, X, y, mask = _setup(arch_name)
    n = Ahat.shape[0]
    nl = float(mask.sum())
    ref_grads = jax.grad(full_loss_fn(arch, Ahat, X, y, mask))(params)
    zeroH = {l: np.zeros((n, arch.dims[l]), np.float32) for l in range(1, arch.L)}
    args, _, halo = make_step_inputs(
        arch, params, Ahat, X, y, mask, np.arange(n), H_pad=4,
        histH=zeroH, histV=zeroH, beta_val=0.0, bwd_scale=1.0,
        vscale=1.0 / nl, grad_scale=1.0,
    )
    assert len(halo) == 0
    out = run_step(arch, n, 4, args)
    for nm in arch.param_names():
        np.testing.assert_allclose(
            out[f"g_{nm}"], ref_grads[nm], rtol=3e-4, atol=3e-5, err_msg=f"g_{nm}"
        )
    # reported loss matches
    np.testing.assert_allclose(float(out["loss_sum"]) / nl, float(full_loss_fn(arch, Ahat, X, y, mask)(params)), rtol=1e-5)


@pytest.mark.parametrize("arch_name", ARCHS)
def test_lmc_beats_gas_under_stale_histories(arch_name):
    """With stale histories, LMC's minibatch gradient is closer to the
    full-batch gradient than GAS's (averaged over batches) — the Fig. 3
    mechanism, and the reason LMC converges faster."""
    arch, params, Ahat, X, y, mask = _setup(arch_name, seed=2, n=40)
    n = Ahat.shape[0]
    nl = float(mask.sum())
    ref_grads = jax.grad(full_loss_fn(arch, Ahat, X, y, mask))(params)
    hs = full_forward_all_layers(arch, params, Ahat, X)
    vs = full_aux_vars(arch, params, Ahat, X, y, mask)
    rng = np.random.default_rng(7)
    # stale histories: exact values plus noise (simulating previous-iterate values)
    histH = {l: hs[l] + 0.3 * rng.normal(size=hs[l].shape).astype(np.float32) for l in range(1, arch.L)}
    histV = {l: vs[l] + 0.3 * np.abs(vs[l]).mean() * rng.normal(size=vs[l].shape).astype(np.float32) for l in range(1, arch.L)}

    def err(bwd_scale, beta_val):
        errs = []
        for start in range(0, n, 10):
            batch = np.arange(start, min(start + 10, n))
            labeled = mask[batch].sum()
            if labeled == 0:
                continue
            # grad_scale: 4 equal parts, 1 sampled -> b/c = 4 per Eq. 15
            args, _, halo = make_step_inputs(
                arch, params, Ahat, X, y, mask, batch, H_pad=40,
                histH=histH, histV=histV, beta_val=beta_val,
                bwd_scale=bwd_scale, vscale=1.0 / nl, grad_scale=4.0,
            )
            out = run_step(arch, 10, 40, args)
            e = 0.0
            r = 0.0
            for nm in arch.param_names():
                e += float(np.sum((np.asarray(out[f"g_{nm}"]) - np.asarray(ref_grads[nm])) ** 2))
                r += float(np.sum(np.asarray(ref_grads[nm]) ** 2))
            errs.append(np.sqrt(e / r))
        return float(np.mean(errs))

    err_gas = err(bwd_scale=0.0, beta_val=0.0)
    err_lmc = err(bwd_scale=1.0, beta_val=0.5)
    assert err_lmc < err_gas, f"LMC err {err_lmc} !< GAS err {err_gas}"


@pytest.mark.parametrize("arch_name", ARCHS)
def test_exact_histories_near_zero_bias(arch_name):
    """With exact histories and the compensations on, the averaged (over a
    uniform partition) LMC gradient is close to the full-batch gradient —
    the bias term of Theorem 2 with zero staleness."""
    arch, params, Ahat, X, y, mask = _setup(arch_name, seed=3, n=40)
    n = Ahat.shape[0]
    nl = float(mask.sum())
    ref_grads = jax.grad(full_loss_fn(arch, Ahat, X, y, mask))(params)
    hs = full_forward_all_layers(arch, params, Ahat, X)
    vs = full_aux_vars(arch, params, Ahat, X, y, mask)
    histH = {l: hs[l] for l in range(1, arch.L)}
    histV = {l: vs[l] for l in range(1, arch.L)}
    acc = {nm: 0.0 for nm in arch.param_names()}
    nb = 0
    for start in range(0, n, 10):
        batch = np.arange(start, min(start + 10, n))
        args, _, _ = make_step_inputs(
            arch, params, Ahat, X, y, mask, batch, H_pad=40,
            histH=histH, histV=histV, beta_val=0.0, bwd_scale=1.0,
            vscale=1.0 / nl, grad_scale=1.0,
        )
        out = run_step(arch, 10, 40, args)
        for nm in arch.param_names():
            acc[nm] = acc[nm] + np.asarray(out[f"g_{nm}"])
        nb += 1
    # Sum over a full partition of backward-SGD gradients = full gradient
    # (Theorem 1); with exact histories the compensated values equal the
    # exact ones for in-batch nodes' updates, so the sum is near-exact.
    for nm in arch.param_names():
        denom = np.linalg.norm(np.asarray(ref_grads[nm]).ravel()) + 1e-8
        rel = np.linalg.norm((acc[nm] - np.asarray(ref_grads[nm])).ravel()) / denom
        assert rel < 0.08, f"{nm}: rel bias {rel}"


@pytest.mark.parametrize("arch_name", ARCHS)
def test_padding_inert(arch_name):
    """Doubling the pad must not change any real output (bit-for-bit-ish)."""
    arch, params, Ahat, X, y, mask = _setup(arch_name, seed=4, n=30)
    n = Ahat.shape[0]
    nl = float(mask.sum())
    hs = full_forward_all_layers(arch, params, Ahat, X)
    histH = {l: hs[l] for l in range(1, arch.L)}
    batch = np.arange(0, 12)
    outs = []
    for B_pad, H_pad in [(16, 32), (24, 64)]:
        args, b, halo = make_step_inputs(
            arch, params, Ahat, X, y, mask, batch, H_pad=H_pad,
            histH=histH, histV=histH, beta_val=0.4, bwd_scale=1.0,
            vscale=1.0 / nl, grad_scale=1.0, B_pad=B_pad,
        )
        outs.append((run_step(arch, B_pad, H_pad, args), len(halo)))
    (o1, nh), (o2, _) = outs
    np.testing.assert_allclose(float(o1["loss_sum"]), float(o2["loss_sum"]), rtol=1e-6)
    for nm in arch.param_names():
        np.testing.assert_allclose(o1[f"g_{nm}"], o2[f"g_{nm}"], rtol=2e-5, atol=1e-6)
    for l in range(1, arch.L):
        np.testing.assert_allclose(
            np.asarray(o1[f"newH{l}"])[:12], np.asarray(o2[f"newH{l}"])[:12], rtol=2e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(o1[f"hhat{l}"])[:nh], np.asarray(o2[f"hhat{l}"])[:nh], rtol=2e-5, atol=1e-6
        )


def test_cluster_mode_matches_isolated_subgraph():
    """CLUSTER mode (no halo inputs) equals running the GNN on the isolated
    re-normalized subgraph — the program specializes exactly."""
    arch, params, Ahat, X, y, mask = _setup("gcn", seed=5, n=30)
    n = 30
    batch = np.arange(0, 12)
    # re-normalized adjacency of the induced subgraph, as CLUSTER-GCN does
    A = (Ahat[np.ix_(batch, batch)] != 0).astype(np.float32)
    deg = A.sum(1)
    A_local = (A / np.sqrt(deg[:, None] * deg[None, :])).astype(np.float32)
    nl = float(mask[batch].sum())

    def sub_loss(p):
        h = jnp.asarray(X[batch])
        h0 = h
        for l in range(1, arch.L + 1):
            h = arch.layer(p, l, jnp.asarray(A_local) @ h, h, h0)
        from compile.step import masked_ce
        return masked_ce(arch.logits(p, h), jnp.asarray(y[batch]), jnp.asarray(mask[batch])) / nl

    ref_grads = jax.grad(sub_loss)(params)

    B, H = 12, 24
    zero = {l: np.zeros((n, arch.dims[l]), np.float32) for l in range(1, arch.L)}
    args, _, _ = make_step_inputs(
        arch, params, Ahat, X, y, mask, batch, H_pad=H,
        histH=zero, histV=zero, beta_val=0.0, bwd_scale=0.0,
        vscale=1.0 / nl, grad_scale=1.0,
    )
    # overwrite adjacency blocks with the CLUSTER policy: local renorm, no halo
    pn = len(arch.param_names())
    args[pn + 2] = jnp.asarray(A_local)            # A_bb
    args[pn + 3] = jnp.zeros((B, H), jnp.float32)  # A_bh
    args[pn + 4] = jnp.zeros((H, H), jnp.float32)  # A_hh
    out = run_step(arch, B, H, args)
    for nm in arch.param_names():
        np.testing.assert_allclose(out[f"g_{nm}"], ref_grads[nm], rtol=3e-4, atol=3e-5, err_msg=nm)
