"""AOT driver: HLO-text emission, manifest structure, fingerprint cache."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import aot
from compile.spec import PROFILES
from compile.step import StepSpec, build_step


def test_lower_emits_parseable_hlo_text():
    arch = PROFILES["planetoid"].arch("gcn")
    fn, ins, outs = build_step(StepSpec(arch=arch, B=16, H=32))
    text = aot.lower_program(fn, ins)
    # HLO text, not proto bytes: must start with the module header
    assert text.lstrip().startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # keep_unused: every input must appear as a parameter
    assert text.count("parameter(") >= len(ins)


def test_emitter_manifest_and_cache(tmp_path):
    out = str(tmp_path)
    aot.main(["--out", out, "--profile", "planetoid", "--arch", "gcn"])
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["version"] == 1
    names = {p["name"] for p in man["programs"]}
    prof = PROFILES["planetoid"]
    for b, h in prof.step_buckets:
        assert f"planetoid_train_step_gcn_b{b}_h{h}" in names
    for l in (1, 2, 3):
        assert f"planetoid_fwd_gcn_l{l}" in names
        assert f"planetoid_bwd_gcn_l{l}" in names
    assert "planetoid_loss_gcn" in names
    # arch metadata records the canonical param order
    arch_info = man["archs"]["planetoid/gcn"]
    assert [p["name"] for p in arch_info["params"]][:2] == ["W1", "b1"]
    # every referenced file exists and is HLO text
    for p in man["programs"]:
        path = tmp_path / p["file"]
        assert path.exists(), p["file"]
        assert path.read_text().lstrip().startswith("HloModule")
    # second run: everything cached (no re-lowering -> fast, same manifest)
    aot.main(["--out", out, "--profile", "planetoid", "--arch", "gcn"])
    man2 = json.loads((tmp_path / "manifest.json").read_text())
    assert {p["name"]: p["fingerprint"] for p in man["programs"]} == {
        p["name"]: p["fingerprint"] for p in man2["programs"]
    }


def test_fingerprint_includes_kernel_source():
    # the fingerprint must change if kernel/model source changes — guards the
    # stale-artifact failure mode we hit during development
    fp1 = aot._fingerprint("k", [("x", (1,), "f32")], [("y", (1,), "f32")], "e")
    aot._SRC_HASH = "deadbeef"
    fp2 = aot._fingerprint("k", [("x", (1,), "f32")], [("y", (1,), "f32")], "e")
    aot._SRC_HASH = None
    assert fp1 != fp2
