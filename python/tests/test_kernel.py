"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

Sweeps shapes (including non-multiples of the block sizes) and dtypes with
hypothesis, and checks the custom-vjp backward path (which itself routes
through the Pallas kernel).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import agg, combine, pallas_matmul, ref


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (7, 13, 5), (128, 128, 128), (129, 130, 131), (64, 257, 40), (300, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matmul_matches_ref(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a, b = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    got = pallas_matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("b,h,d", [(4, 4, 4), (13, 29, 8), (128, 512, 64), (100, 300, 17)])
def test_agg_matches_ref(b, h, d):
    rng = np.random.default_rng(b + h + d)
    abb = _rand(rng, (b, b), jnp.float32)
    abh = _rand(rng, (b, h), jnp.float32)
    hb = _rand(rng, (b, d), jnp.float32)
    hh = _rand(rng, (h, d), jnp.float32)
    np.testing.assert_allclose(
        agg(abb, abh, hb, hh), ref.agg_ref(abb, abh, hb, hh), rtol=2e-5, atol=2e-5
    )


def test_agg_vjp_matches_ref_vjp():
    rng = np.random.default_rng(0)
    b, h, d = 24, 40, 16
    abb = _rand(rng, (b, b), jnp.float32)
    abh = _rand(rng, (b, h), jnp.float32)
    hb = _rand(rng, (b, d), jnp.float32)
    hh = _rand(rng, (h, d), jnp.float32)

    f = lambda x, y: jnp.sum(jnp.sin(agg(abb, abh, x, y)))
    fr = lambda x, y: jnp.sum(jnp.sin(ref.agg_ref(abb, abh, x, y)))
    g = jax.grad(f, argnums=(0, 1))(hb, hh)
    gr = jax.grad(fr, argnums=(0, 1))(hb, hh)
    np.testing.assert_allclose(g[0], gr[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g[1], gr[1], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,d", [(1, 1), (5, 3), (256, 64), (257, 63), (1000, 8)])
def test_combine_matches_ref(n, d):
    rng = np.random.default_rng(n * 7 + d)
    beta = jnp.asarray(rng.uniform(size=n).astype(np.float32))
    hist = _rand(rng, (n, d), jnp.float32)
    fresh = _rand(rng, (n, d), jnp.float32)
    np.testing.assert_allclose(
        combine(beta, hist, fresh), ref.combine_ref(beta, hist, fresh), rtol=1e-6, atol=1e-6
    )


def test_combine_endpoints():
    """beta=0 returns history exactly (GAS mode); beta=1 returns fresh."""
    rng = np.random.default_rng(3)
    hist = _rand(rng, (33, 9), jnp.float32)
    fresh = _rand(rng, (33, 9), jnp.float32)
    np.testing.assert_array_equal(combine(jnp.zeros(33), hist, fresh), hist)
    np.testing.assert_array_equal(combine(jnp.ones(33), hist, fresh), fresh)


def test_matmul_zero_padding_exact():
    """Padding rows/cols are exactly zero-preserving (sampler relies on it)."""
    rng = np.random.default_rng(4)
    a = np.zeros((70, 90), np.float32)
    b = np.zeros((90, 30), np.float32)
    a[:50, :60] = rng.normal(size=(50, 60))
    b[:60, :20] = rng.normal(size=(60, 20))
    out = np.asarray(pallas_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.all(out[50:] == 0) and np.all(out[:, 20:] == 0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_matmul(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), jnp.float32)
    b = _rand(rng, (k, n), jnp.float32)
    np.testing.assert_allclose(
        pallas_matmul(a, b), ref.matmul_ref(a, b), rtol=3e-5, atol=3e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_combine(n, d, seed):
    rng = np.random.default_rng(seed)
    beta = jnp.asarray(rng.uniform(size=n).astype(np.float32))
    hist = _rand(rng, (n, d), jnp.float32)
    fresh = _rand(rng, (n, d), jnp.float32)
    np.testing.assert_allclose(
        combine(beta, hist, fresh), ref.combine_ref(beta, hist, fresh), rtol=1e-6, atol=1e-6
    )
