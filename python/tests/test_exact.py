"""Exact tile programs vs full-graph autodiff.

The tile-wise layer programs (compile.exact) drive the Rust evaluator, the GD
baseline, and the Fig. 3 gradient-error oracle; summed over a tiling of the
graph they must reproduce the full forward pass and the full-batch gradient
exactly (paper Theorem 1 with V_B = V).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import exact as ex
from compile.archs import make_arch
from gnn_util import full_forward_all_layers, full_loss_fn, tiny_graph

ARCHS = ["gcn", "gcnii"]


def _tile_exact_grads(arch, params, Ahat, X, y, mask, tile_size=8):
    n = Ahat.shape[0]
    nl = float(mask.sum())
    L = arch.L
    pnames = arch.param_names()
    pspecs = dict(arch.param_specs())
    tiles = [np.arange(s, min(s + tile_size, n)) for s in range(0, n, tile_size)]
    Bt, Ht = tile_size, n

    def blocks(t):
        halo = np.setdiff1d(np.arange(n), t)
        A_bb = Ahat[np.ix_(t, t)]
        A_bh = Ahat[np.ix_(t, halo)]
        return halo, A_bb, A_bh

    def pad_rows(a, r):
        out = np.zeros((r,) + a.shape[1:], np.float32)
        out[: a.shape[0]] = a
        return out

    # exact forward, tile by tile
    H0 = np.asarray(arch.embed0(params, jnp.asarray(X)))
    Hcur = H0.copy()
    Hs = [Hcur]
    for l in range(1, L + 1):
        fwd, _, _ = ex.build_fwd_layer(arch, l, Bt, Ht)
        Hn = np.zeros((n, arch.dims[l]), np.float32)
        for t in tiles:
            halo, A_bb, A_bh = blocks(t)
            pv = [params[nm] for nm in ex.layer_param_names(arch, l)]
            out = fwd(
                jnp.asarray(np.pad(A_bb, ((0, Bt - len(t)), (0, Bt - len(t))))),
                jnp.asarray(np.pad(A_bh, ((0, Bt - len(t)), (0, Ht - len(halo))))),
                jnp.asarray(pad_rows(Hcur[t], Bt)),
                jnp.asarray(pad_rows(Hcur[halo], Ht)),
                jnp.asarray(pad_rows(H0[t], Bt)),
                *pv,
            )
            Hn[t] = np.asarray(out[0])[: len(t)]
        Hcur = Hn
        Hs.append(Hcur)

    # loss grads per tile
    lg, _, _ = ex.build_loss_grad(arch, Bt)
    head = arch.head_param_names()
    V = np.zeros((n, arch.dims[L]), np.float32)
    g = {nm: np.zeros(pspecs[nm], np.float32) for nm in pnames}
    loss_total, correct_total = 0.0, 0.0
    for t in tiles:
        hv = [params[nm] for nm in head]
        out = lg(
            jnp.asarray(pad_rows(Hs[L][t], Bt)),
            jnp.asarray(np.pad(y[t], (0, Bt - len(t)))),
            jnp.asarray(np.pad(mask[t], (0, Bt - len(t)))),
            jnp.float32(1.0 / nl),
            *hv,
        )
        loss_total += float(out[0])
        correct_total += float(out[1])
        V[t] = np.asarray(out[2])[: len(t)]
        for i, nm in enumerate(head):
            g[nm] += np.asarray(out[4 + i])

    # backward per layer, accumulating scattered contributions
    C0 = np.zeros((n, arch.dims[0]), np.float32)
    for l in range(L, 0, -1):
        bwd, _, _ = ex.build_bwd_layer(arch, l, Bt, Ht)
        lp = ex.layer_param_names(arch, l)
        Vprev = np.zeros((n, arch.dims[l - 1]), np.float32)
        for t in tiles:
            halo, A_bb, A_bh = blocks(t)
            pv = [params[nm] for nm in lp]
            out = bwd(
                jnp.asarray(np.pad(A_bb, ((0, Bt - len(t)), (0, Bt - len(t))))),
                jnp.asarray(np.pad(A_bh, ((0, Bt - len(t)), (0, Ht - len(halo))))),
                jnp.asarray(pad_rows(Hs[l - 1][t], Bt)),
                jnp.asarray(pad_rows(Hs[l - 1][halo], Ht)),
                jnp.asarray(pad_rows(H0[t], Bt)),
                jnp.asarray(pad_rows(V[t], Bt)),
                *pv,
            )
            k = len(lp)
            for i, nm in enumerate(lp):
                g[nm] += np.asarray(out[i])
            Vprev[t] += np.asarray(out[k])[: len(t)]
            Vprev[halo] += np.asarray(out[k + 1])[: len(halo)]
            C0[t] += np.asarray(out[k + 2])[: len(t)]
        V = Vprev
    C0 += V
    if head:
        eb, _, _ = ex.build_embed0_bwd(arch, Bt)
        for t in tiles:
            gw0, gb0 = eb(
                jnp.asarray(pad_rows(X[t], Bt)),
                jnp.asarray(pad_rows(C0[t], Bt)),
                params["W0"],
                params["b0"],
            )
            g["W0"] += np.asarray(gw0)
            g["b0"] += np.asarray(gb0)
    return Hs, g, loss_total, correct_total


@pytest.mark.parametrize("arch_name", ARCHS)
def test_tile_exact_matches_autodiff(arch_name):
    Ahat, X, y, mask = tiny_graph(n=26, dx=6, c=3, seed=11)
    arch = make_arch(arch_name, L=3, d_x=6, hidden=8, n_class=3)
    params = arch.init_params(jax.random.PRNGKey(1))
    Hs, g, loss, _ = _tile_exact_grads(arch, params, Ahat, X, y, mask)
    ref = jax.grad(full_loss_fn(arch, Ahat, X, y, mask))(params)
    Hfull = full_forward_all_layers(arch, params, Ahat, X)
    np.testing.assert_allclose(Hs[-1], Hfull[-1], rtol=2e-4, atol=2e-5)
    for nm in arch.param_names():
        np.testing.assert_allclose(g[nm], ref[nm], rtol=5e-4, atol=5e-5, err_msg=nm)
    nl = float(mask.sum())
    np.testing.assert_allclose(
        loss / nl, float(full_loss_fn(arch, Ahat, X, y, mask)(params)), rtol=1e-5
    )


@pytest.mark.parametrize("arch_name", ARCHS)
def test_tile_size_invariance(arch_name):
    """The exact path is invariant to the tiling (4 vs 13 rows per tile)."""
    Ahat, X, y, mask = tiny_graph(n=26, dx=6, c=3, seed=12)
    arch = make_arch(arch_name, L=3, d_x=6, hidden=8, n_class=3)
    params = arch.init_params(jax.random.PRNGKey(2))
    _, g1, l1, c1 = _tile_exact_grads(arch, params, Ahat, X, y, mask, tile_size=4)
    _, g2, l2, c2 = _tile_exact_grads(arch, params, Ahat, X, y, mask, tile_size=13)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    assert c1 == c2
    for nm in arch.param_names():
        np.testing.assert_allclose(g1[nm], g2[nm], rtol=5e-4, atol=5e-5, err_msg=nm)
