import sys
from pathlib import Path

# Make `compile.*` and the shared test helpers importable from anywhere.
root = Path(__file__).resolve().parent
for p in (root, root / "tests"):
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))
