"""L2: the fused LMC train-step program (forward + backward compensation).

One compiled ``train_step`` covers LMC / GAS / FM / CLUSTER-GCN via runtime
scalars (DESIGN.md §1):

  - ``beta``       [H]  per-halo-node convex combination coefficient (Eq. 9/12);
                        0 => pure historical values (GAS/FM/CLUSTER).
  - ``bwd_scale``  []   1 => backward compensation C_b on (Eqs. 11-13, LMC);
                        0 => halo auxiliary variables discarded (GAS/CLUSTER).
  - ``vscale``     []   1/|V_L| — folds the full-loss normalization into V^L.
  - ``grad_scale`` []   b/c — the cluster-sampling reweighting (Eqs. 14-15).

Faithfulness to the paper:

  * Forward: Eq. (8) for in-batch nodes, Eq. (10) for the *incomplete
    up-to-date* halo values (only edges inside N(V_B) are present in A_hh),
    Eq. (9) via the Pallas ``combine`` kernel.
  * Backward: auxiliary variables V are propagated by ``jax.vjp`` of the
    *local* per-layer map F_l : (hbar_b^{l-1}, hhat_h^{l-1}) -> (hbar_b^l,
    htilde_h^l) with cotangents (Vbar_b^l, Vhat_h^l) — term-by-term identical
    to Eqs. (11) and (13). Halo cotangents at layer l<L are compensated via
    Eq. (12); at layer L they are the local loss gradients (Algorithm 1 line
    11 initializes Vhat^L = grad_{H^L} L).
  * Parameter gradients: Eq. (7) sums over in-batch nodes only, so g_theta^l
    is the vjp w.r.t. params with cotangent (Vbar_b^l, 0) — a separate
    cotangent evaluation from the propagation one (vjp residuals are shared).
  * Mini-batch gradients for the output head ``w`` follow Eq. (6)/(14).

Outputs include the updated in-batch histories (Hbar, Vbar per layer) and the
halo temporary/incomplete values (Hhat, Htilde per layer) so the Rust
coordinator can implement each method's write-back policy (LMC/GAS write
in-batch only; FM additionally pushes a momentum update of Htilde to halo
histories; CLUSTER writes nothing).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .archs import Arch
from .kernels import agg as k_agg
from .kernels import combine as k_combine
from .kernels import ref as k_ref

Spec = Tuple[str, Tuple[int, ...], str]  # (name, shape, dtype)


def masked_ce(logits: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Sum of masked cross-entropy losses (numerically stable)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    return jnp.sum(ce * mask)


def masked_correct(logits: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32) * mask)


@dataclass(frozen=True)
class StepSpec:
    arch: Arch
    B: int  # padded in-batch size
    H: int  # padded halo size
    use_pallas: bool = True

    @property
    def name(self) -> str:
        return f"train_step_{self.arch.name}_b{self.B}_h{self.H}"


def _kernels(use_pallas: bool):
    if use_pallas:
        from .kernels.agg import agg2

        return agg2, k_combine
    return k_ref.agg2_ref, k_ref.combine_ref


def build_step(spec: StepSpec) -> Tuple[Callable, List[Spec], List[Spec]]:
    """Build the step function plus positional input/output specs."""
    arch, B, H = spec.arch, spec.B, spec.H
    L, dims, d_x = arch.L, arch.dims, arch.d_x
    agg2_fn, combine_fn = _kernels(spec.use_pallas)
    pnames = arch.param_names()
    pspecs = dict(arch.param_specs())

    in_specs: List[Spec] = [(n, tuple(pspecs[n]), "f32") for n in pnames]
    in_specs += [
        ("X_b", (B, d_x), "f32"),
        ("X_h", (H, d_x), "f32"),
        ("A_bb", (B, B), "f32"),
        ("A_bh", (B, H), "f32"),
        ("A_hh", (H, H), "f32"),
    ]
    for l in range(1, L):
        in_specs.append((f"histH{l}", (H, dims[l]), "f32"))
    for l in range(1, L):
        in_specs.append((f"histV{l}", (H, dims[l]), "f32"))
    in_specs += [
        ("y_b", (B,), "i32"),
        ("mask_b", (B,), "f32"),
        ("y_h", (H,), "i32"),
        ("mask_h", (H,), "f32"),
        ("beta", (H,), "f32"),
        ("bwd_scale", (), "f32"),
        ("vscale", (), "f32"),
        ("grad_scale", (), "f32"),
    ]

    out_specs: List[Spec] = [
        ("loss_sum", (), "f32"),
        ("correct", (), "f32"),
        ("logits_b", (B, arch.n_class), "f32"),
    ]
    out_specs += [(f"g_{n}", tuple(pspecs[n]), "f32") for n in pnames]
    for l in range(1, L):
        out_specs.append((f"newH{l}", (B, dims[l]), "f32"))
    for l in range(1, L):
        out_specs.append((f"newV{l}", (B, dims[l]), "f32"))
    for l in range(1, L):
        out_specs.append((f"hhat{l}", (H, dims[l]), "f32"))
    for l in range(1, L):
        out_specs.append((f"htilde{l}", (H, dims[l]), "f32"))

    n_params = len(pnames)

    def step(*args):
        params: Dict[str, jax.Array] = {n: a for n, a in zip(pnames, args[:n_params])}
        rest = list(args[n_params:])
        X_b, X_h, A_bb, A_bh, A_hh = rest[:5]
        idx = 5
        histH = rest[idx: idx + (L - 1)]
        idx += L - 1
        histV = rest[idx: idx + (L - 1)]
        idx += L - 1
        y_b, mask_b, y_h, mask_h, beta, bwd_scale, vscale, grad_scale = rest[idx: idx + 8]

        # PERF (EXPERIMENTS.md §Perf, L2): the per-layer batch/halo updates
        # are computed over the *stacked* node space [batch; halo] with one
        # block adjacency — a single Pallas aggregation per layer direction
        # instead of four, which matters under interpret-mode per-call cost.
        # Row semantics are unchanged: rows :B aggregate Eq. (8)'s message,
        # rows B: aggregate Eq. (10)'s incomplete message.
        A_full = jnp.concatenate(
            [
                jnp.concatenate([A_bb, A_bh], axis=1),
                jnp.concatenate([A_bh.T, A_hh], axis=1),
            ],
            axis=0,
        )
        def agg_full(x_full):
            return agg2_fn(A_full, x_full)

        h0_full = arch.embed0(params, jnp.concatenate([X_b, X_h], axis=0))

        # ------------------------------ forward ---------------------------
        h = h0_full                     # rows :B = hbar_b, rows B: = hhat_h
        layer_inputs: List[jax.Array] = []
        newH: List[jax.Array] = []      # Hbar_b^l, l = 1..L-1
        hhat_out: List[jax.Array] = []
        htilde_out: List[jax.Array] = []
        for l in range(1, L + 1):
            layer_inputs.append(h)
            out = arch.layer(params, l, agg_full(h), h, h0_full)
            hb_new, ht = out[:B], out[B:]
            if l < L:
                hh_new = combine_fn(beta, histH[l - 1], ht)  # Eq. (9)
                newH.append(hb_new)
                hhat_out.append(hh_new)
                htilde_out.append(ht)
            else:
                hh_new = ht  # htilde^L: only used for the halo loss gradient
            h = jnp.concatenate([hb_new, hh_new], axis=0)
        hb, hh = h[:B], h[B:]

        # ------------------------------ loss -------------------------------
        def head_loss(p, hbv):
            return masked_ce(arch.logits(p, hbv), y_b, mask_b)

        loss_sum, head_vjp = jax.vjp(head_loss, params, hb)
        g_head, VbL_raw = head_vjp(jnp.float32(1.0))
        Vb = vscale * VbL_raw                                # Vbar_b^L
        correct = masked_correct(arch.logits(params, hb), y_b, mask_b)

        def halo_loss(hv):
            return masked_ce(arch.logits(params, hv), y_h, mask_h)

        VhL_raw = jax.grad(halo_loss)(hh)
        Vh = bwd_scale * vscale * VhL_raw                    # Vhat_h^L (local init)

        # ------------------------------ backward ---------------------------
        grads = jax.tree_util.tree_map(lambda g: grad_scale * vscale * g, g_head)
        newV: List[jax.Array] = [None] * (L - 1)             # Vbar_b^l, l = 1..L-1
        acc_h0 = jnp.zeros_like(h0_full[:B])                 # cotangent into embed0 (GCNII)

        for l in range(L, 0, -1):
            h_prev = layer_inputs[l - 1]

            def F(p, x_full, h0f, _l=l):
                return arch.layer(p, _l, agg_full(x_full), x_full, h0f)

            _, f_vjp = jax.vjp(F, params, h_prev, h0_full)
            # Eq. (7): parameter gradients from in-batch cotangents only.
            cot_b = jnp.concatenate([Vb, jnp.zeros((H, dims[l]), jnp.float32)], axis=0)
            gp, _, ch0_p = f_vjp(cot_b)
            grads = jax.tree_util.tree_map(lambda a, b: a + grad_scale * b, grads, gp)
            acc_h0 = acc_h0 + ch0_p[:B]
            # Eqs. (11) & (13): propagate with full (batch, halo) cotangents.
            cot_full = jnp.concatenate([Vb, Vh], axis=0)
            _, v_full, _ = f_vjp(cot_full)
            if l > 1:
                newV[l - 2] = v_full[:B]
                # Eq. (12): compensate halo auxiliary variables with history.
                Vh = bwd_scale * combine_fn(beta, histV[l - 2], v_full[B:])
                Vb = v_full[:B]
            else:
                # Layer 1: V^0_b (the cotangent w.r.t. h0_b) feeds embed0's
                # params, via the *compensated* propagation (Eq. 11) —
                # batch-only misses out-of-batch neighbor terms and biases
                # W0 even with exact histories.
                acc_h0 = acc_h0 + v_full[:B]

        # embed0 parameter gradients (GCNII's W0/b0; zero-paths DCE for GCN).
        def E(p):
            return arch.embed0(p, X_b)

        _, e_vjp = jax.vjp(E, params)
        (g_embed,) = e_vjp(acc_h0)
        grads = jax.tree_util.tree_map(lambda a, b: a + grad_scale * b, grads, g_embed)

        outs: List[jax.Array] = [loss_sum, correct, arch.logits(params, hb)]
        outs += [grads[n] for n in pnames]
        outs += newH
        outs += list(newV)
        outs += hhat_out
        outs += htilde_out
        return tuple(outs)

    return step, in_specs, out_specs
