"""L1 Pallas kernels: blocked halo aggregation and compensation combine.

The paper's compute hot-spot is sparse neighborhood aggregation (PyG scatter on
CUDA). Per DESIGN.md §6 we rethink it for TPU: the sampler densifies each
mini-batch subgraph into normalized adjacency blocks, so aggregation becomes a
blocked matmul feeding the MXU. `BlockSpec` expresses the HBM->VMEM schedule
that the paper expressed with threadblocks.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU the same BlockSpecs drive the VMEM tiling.

Kernels:
  - :func:`pallas_matmul` — tiled ``A @ H`` with output-block accumulation over
    the K grid axis (f32 accumulate via ``preferred_element_type``).
  - :func:`agg` — ``A_bb @ H_b + A_bh @ H_h`` as one fused blocked matmul over
    the concatenated K dimension, wrapped in a ``custom_vjp`` whose backward is
    itself the Pallas kernel (``A^T @ g``), so both forward and backward
    message passing (paper Eqs. 2 and 5) route through the kernel.
  - :func:`combine` — the convex-combination compensation, paper Eqs. (9)/(12):
    ``(1-beta) * hist + beta * fresh`` fused elementwise in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes: large M/N panels, K unblocked.
#
# PERF (EXPERIMENTS.md §Perf, L1): the interpret-mode grid is lowered to an
# XLA scan whose per-step dynamic slice/update costs ~100-200ms on CPU; a
# 3-D (i, j, k) grid of 128^3 tiles made one train_step ~67x slower than the
# jnp reference. With full-K panels and large M/N blocks the grid collapses
# to a handful of steps and the overhead disappears, while the BlockSpec
# still expresses the HBM->VMEM M/N panel schedule. Interpret-mode profiling
# (EXPERIMENTS.md §Perf) measured ~25-30ms of fixed cost *per grid step* on
# this CPU substrate, so the defaults below cover every shipped shape bucket
# with a single-step grid. A real-TPU build would set bm=bn=128 with a
# bk=512 K axis + VMEM accumulator (the schedule DESIGN.md §6 costs out);
# both are the same kernel under different block constants.
DEFAULT_BM = 4096
DEFAULT_BN = 4096


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _mm_kernel(a_ref, b_ref, o_ref):
    """One (i, j) grid step: an (bm, K) @ (K, bn) panel product (f32 acc)."""
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def pallas_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """Blocked ``a @ b`` via Pallas. Pads M/N up to the block grid."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"pallas_matmul shape mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, 0))) if mp != m else a
    b_p = jnp.pad(b, ((0, 0), (0, np_ - n))) if np_ != n else b
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


@jax.custom_vjp
def _agg_cv(a: jax.Array, h: jax.Array) -> jax.Array:
    return pallas_matmul(a, h)


def _agg_fwd(a, h):
    return pallas_matmul(a, h), a


def _agg_bwd(a, g):
    # Adjacency blocks are data, not parameters: their cotangent is never
    # consumed by the step program (vjp closes over A), so return a symbolic
    # zero that XLA DCEs. The embedding cotangent is the paper's backward
    # message passing (Eq. 5): A^T @ g — again through the Pallas kernel.
    return jnp.zeros_like(a), pallas_matmul(a.T, g)


_agg_cv.defvjp(_agg_fwd, _agg_bwd)


def agg2(a: jax.Array, h: jax.Array) -> jax.Array:
    """Single-block aggregation ``a @ h`` through the Pallas kernel with the
    message-passing custom VJP (used by the stacked-space train step)."""
    return _agg_cv(a, h)


def agg(a_self: jax.Array, a_halo: jax.Array, h_self: jax.Array, h_halo: jax.Array) -> jax.Array:
    """Halo aggregation ``a_self @ h_self + a_halo @ h_halo`` (paper Eq. 8/10).

    The two blocks are concatenated along K so the whole aggregation is one
    blocked-matmul sweep (one HBM->VMEM pass over the adjacency row panel).
    """
    a = jnp.concatenate([a_self, a_halo], axis=1)
    h = jnp.concatenate([h_self, h_halo], axis=0)
    return _agg_cv(a, h)


def _combine_kernel(beta_ref, hist_ref, fresh_ref, o_ref):
    b = beta_ref[...]  # (bm, 1) broadcast over the feature axis
    o_ref[...] = (1.0 - b) * hist_ref[...] + b * fresh_ref[...]


def combine(beta: jax.Array, hist: jax.Array, fresh: jax.Array, *, bm: int = 4096) -> jax.Array:
    """Per-node convex combination, paper Eqs. (9) and (12).

    ``beta`` is a per-node coefficient vector [n]; hist/fresh are [n, d].
    Fused elementwise in VMEM so history fetch -> compensation costs a single
    HBM round trip.
    """
    if hist.shape != fresh.shape:
        raise ValueError(f"combine shape mismatch: {hist.shape} vs {fresh.shape}")
    n, d = hist.shape
    if beta.shape != (n,):
        raise ValueError(f"combine beta shape {beta.shape} != ({n},)")
    bm = min(bm, _ceil_to(max(n, 1), 8))
    npad = _ceil_to(max(n, 1), bm)
    b2 = beta.astype(hist.dtype).reshape(n, 1)
    if npad != n:
        b2 = jnp.pad(b2, ((0, npad - n), (0, 0)))
        hist = jnp.pad(hist, ((0, npad - n), (0, 0)))
        fresh = jnp.pad(fresh, ((0, npad - n), (0, 0)))
    out = pl.pallas_call(
        _combine_kernel,
        grid=(npad // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, d), hist.dtype),
        interpret=True,
    )(b2, hist, fresh)
    return out[:n]
