"""L1: Pallas kernels for the paper compute hot-spot (halo aggregation +
compensation combine), with pure-jnp oracles in :mod:`.ref`."""

from . import ref  # noqa: F401
from .agg import agg, combine, pallas_matmul  # noqa: F401
