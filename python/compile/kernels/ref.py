"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground-truth definitions the kernels are tested against
(python/tests/test_kernel.py) and double as the ``use_pallas=False`` lowering
path used when debugging HLO output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def agg2_ref(a: jax.Array, h: jax.Array) -> jax.Array:
    """Reference single-block aggregation."""
    return matmul_ref(a, h)


def agg_ref(a_self: jax.Array, a_halo: jax.Array, h_self: jax.Array, h_halo: jax.Array) -> jax.Array:
    """Reference halo aggregation: ``A_bb @ H_b + A_bh @ H_h`` (paper Eq. 8)."""
    return matmul_ref(a_self, h_self) + matmul_ref(a_halo, h_halo)


def combine_ref(beta: jax.Array, hist: jax.Array, fresh: jax.Array) -> jax.Array:
    """Reference convex combination (paper Eqs. 9/12)."""
    b = beta.astype(hist.dtype)[:, None]
    return (1.0 - b) * hist + b * fresh
