"""L2: exact layer-wise tile programs (no histories, no compensation).

These implement full-graph computation tile-by-tile with *exact* halo values,
which is simultaneously:

  - the exact inference path used for evaluation (test/val accuracy),
  - the full-batch gradient oracle (backward SGD over *all* tiles sums to the
    full-batch gradient — paper Theorem 1 with V_B = V), used by the GD
    baseline and by the gradient-error experiment (paper Fig. 3),
  - the exact auxiliary-variable oracle (V^l for every node).

Programs (per arch, per layer where applicable), all over a (B, H) tile
bucket where B indexes tile rows and H their exact 1-hop halo:

  embed0       (GCNII only)  X_t -> h0_t
  fwd_layer_l  A_bb, A_bh, Hprev_t, Hprev_h, H0_t, params_l -> H_t
  loss_grad    HL_t, y, mask, vscale, head_params
                 -> loss_sum, correct, V_t [, g_head...]
  bwd_layer_l  A_bb, A_bh, Hprev_t, Hprev_h, H0_t, V_t, params_l
                 -> g_params_l..., Vprev_t, Vprev_h, Ch0_t
               (Vprev_h and the per-tile grads are *contributions*; the Rust
               coordinator scatter-adds them across tiles — each node's update
               appears in exactly one tile, so the sums are exact.)
  embed0_bwd   (GCNII only)  X_t, C_t -> gW0, gb0 contributions
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .archs import Arch, GCNII
from .kernels import agg as k_agg
from .kernels import ref as k_ref
from .step import Spec, masked_ce, masked_correct


def _agg_fn(use_pallas: bool):
    return k_agg if use_pallas else k_ref.agg_ref


def layer_param_names(arch: Arch, l: int) -> List[str]:
    """Parameters used by MP layer ``l`` (the paper's theta^l)."""
    if arch.name == "gcn":
        return [f"W{l}", f"b{l}"]
    if arch.name == "gcnii":
        return [f"W{l}"]
    raise ValueError(arch.name)


def build_embed0(arch: Arch, B: int) -> Tuple[Callable, List[Spec], List[Spec]]:
    assert isinstance(arch, GCNII)
    in_specs: List[Spec] = [("X_t", (B, arch.d_x), "f32"), ("W0", (arch.d_x, arch.dims[0]), "f32"), ("b0", (arch.dims[0],), "f32")]
    out_specs: List[Spec] = [("h0_t", (B, arch.dims[0]), "f32")]

    def fn(X_t, W0, b0):
        return (arch.embed0({"W0": W0, "b0": b0}, X_t),)

    return fn, in_specs, out_specs


def build_embed0_bwd(arch: Arch, B: int) -> Tuple[Callable, List[Spec], List[Spec]]:
    assert isinstance(arch, GCNII)
    d0 = arch.dims[0]
    in_specs: List[Spec] = [
        ("X_t", (B, arch.d_x), "f32"),
        ("C_t", (B, d0), "f32"),
        ("W0", (arch.d_x, d0), "f32"),
        ("b0", (d0,), "f32"),
    ]
    out_specs: List[Spec] = [("gW0", (arch.d_x, d0), "f32"), ("gb0", (d0,), "f32")]

    def fn(X_t, C_t, W0, b0):
        def E(w0, b0_):
            return arch.embed0({"W0": w0, "b0": b0_}, X_t)

        _, e_vjp = jax.vjp(E, W0, b0)
        gw0, gb0 = e_vjp(C_t)
        return gw0, gb0

    return fn, in_specs, out_specs


def build_fwd_layer(arch: Arch, l: int, B: int, H: int, use_pallas: bool = True) -> Tuple[Callable, List[Spec], List[Spec]]:
    agg_fn = _agg_fn(use_pallas)
    d_prev, d_l, d0 = arch.dims[l - 1], arch.dims[l], arch.dims[0]
    pnames = layer_param_names(arch, l)
    pspecs = dict(arch.param_specs())
    in_specs: List[Spec] = [
        ("A_bb", (B, B), "f32"),
        ("A_bh", (B, H), "f32"),
        ("Hprev_t", (B, d_prev), "f32"),
        ("Hprev_h", (H, d_prev), "f32"),
        ("H0_t", (B, d0), "f32"),
    ] + [(n, tuple(pspecs[n]), "f32") for n in pnames]
    out_specs: List[Spec] = [("H_t", (B, d_l), "f32")]

    def fn(A_bb, A_bh, Hprev_t, Hprev_h, H0_t, *pvals):
        params = dict(zip(pnames, pvals))
        a = agg_fn(A_bb, A_bh, Hprev_t, Hprev_h)
        return (arch.layer(params, l, a, Hprev_t, H0_t),)

    return fn, in_specs, out_specs


def build_loss_grad(arch: Arch, B: int) -> Tuple[Callable, List[Spec], List[Spec]]:
    dL = arch.dims[arch.L]
    head = arch.head_param_names()
    pspecs = dict(arch.param_specs())
    in_specs: List[Spec] = [
        ("HL_t", (B, dL), "f32"),
        ("y_t", (B,), "i32"),
        ("mask_t", (B,), "f32"),
        ("vscale", (), "f32"),
    ] + [(n, tuple(pspecs[n]), "f32") for n in head]
    out_specs: List[Spec] = [
        ("loss_sum", (), "f32"),
        ("correct", (), "f32"),
        ("V_t", (B, dL), "f32"),
        ("logits_t", (B, arch.n_class), "f32"),
    ] + [(f"g_{n}", tuple(pspecs[n]), "f32") for n in head]

    def fn(HL_t, y_t, mask_t, vscale, *head_vals):
        params = dict(zip(head, head_vals))

        def f(p, h):
            return masked_ce(arch.logits(p, h), y_t, mask_t)

        loss_sum, f_vjp = jax.vjp(f, params, HL_t)
        g_head, V_raw = f_vjp(jnp.float32(1.0))
        logits = arch.logits(params, HL_t)
        outs = [loss_sum, masked_correct(logits, y_t, mask_t), vscale * V_raw, logits]
        outs += [vscale * g_head[n] for n in head]
        return tuple(outs)

    return fn, in_specs, out_specs


def build_bwd_layer(arch: Arch, l: int, B: int, H: int, use_pallas: bool = True) -> Tuple[Callable, List[Spec], List[Spec]]:
    agg_fn = _agg_fn(use_pallas)
    d_prev, d_l, d0 = arch.dims[l - 1], arch.dims[l], arch.dims[0]
    pnames = layer_param_names(arch, l)
    pspecs = dict(arch.param_specs())
    in_specs: List[Spec] = [
        ("A_bb", (B, B), "f32"),
        ("A_bh", (B, H), "f32"),
        ("Hprev_t", (B, d_prev), "f32"),
        ("Hprev_h", (H, d_prev), "f32"),
        ("H0_t", (B, d0), "f32"),
        ("V_t", (B, d_l), "f32"),
    ] + [(n, tuple(pspecs[n]), "f32") for n in pnames]
    out_specs: List[Spec] = [(f"g_{n}", tuple(pspecs[n]), "f32") for n in pnames] + [
        ("Vprev_t", (B, d_prev), "f32"),
        ("Vprev_h", (H, d_prev), "f32"),
        ("Ch0_t", (B, d0), "f32"),
    ]

    def fn(A_bb, A_bh, Hprev_t, Hprev_h, H0_t, V_t, *pvals):
        params = dict(zip(pnames, pvals))

        def F(p, xt, xh, h0t):
            a = agg_fn(A_bb, A_bh, xt, xh)
            return arch.layer(p, l, a, xt, h0t)

        _, f_vjp = jax.vjp(F, params, Hprev_t, Hprev_h, H0_t)
        gp, vt, vh, ch0 = f_vjp(V_t)
        outs = [gp[n] for n in pnames] + [vt, vh, ch0]
        return tuple(outs)

    return fn, in_specs, out_specs
