"""Artifact specification: dataset profiles, shape buckets, arch hyperparams.

A *profile* fixes the tensor dimensions every compiled program for a dataset
family shares (feature dim, class count, hidden width, depth). The Rust side
maps each dataset to a profile (rust/src/graph/datasets.rs must agree with
this file; the manifest is the source of truth at runtime).

Buckets are (B, H) padded shapes: B = in-batch rows, H = halo rows. The
sampler picks the smallest bucket that fits and pads with zero rows/cols
(zero adjacency columns, beta = 0, mask = 0 — padded entries are exactly
inert, see python/tests/test_step.py::test_padding_inert).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .archs import Arch, make_arch


@dataclass(frozen=True)
class Profile:
    name: str
    d_x: int
    n_class: int
    hidden: int
    gcn_layers: int
    gcnii_layers: int
    step_buckets: Tuple[Tuple[int, int], ...]
    exact_bucket: Tuple[int, int]
    gcnii_alpha: float = 0.1
    gcnii_lam: float = 0.5

    def arch(self, name: str) -> Arch:
        if name == "gcn":
            return make_arch("gcn", L=self.gcn_layers, d_x=self.d_x,
                             hidden=self.hidden, n_class=self.n_class)
        if name == "gcnii":
            return make_arch("gcnii", L=self.gcnii_layers, d_x=self.d_x,
                             hidden=self.hidden, n_class=self.n_class,
                             alpha=self.gcnii_alpha, lam=self.gcnii_lam)
        raise ValueError(name)


PROFILES: Dict[str, Profile] = {
    # arxiv-sim & reddit-sim (16 classes, 64-dim features)
    "std16": Profile(
        name="std16", d_x=64, n_class=16, hidden=64,
        gcn_layers=3, gcnii_layers=4,
        step_buckets=((192, 1024), (320, 1536), (768, 1792), (1408, 1792)),
        exact_bucket=(256, 1792),
    ),
    # flickr-sim (7 classes)
    "flickr": Profile(
        name="flickr", d_x=64, n_class=7, hidden=64,
        gcn_layers=3, gcnii_layers=4,
        step_buckets=((160, 768), (320, 1024)),
        exact_bucket=(256, 1024),
    ),
    # ppi-sim (12 classes, 48-dim features, multi-graph inductive)
    "ppi": Profile(
        name="ppi", d_x=48, n_class=12, hidden=64,
        gcn_layers=3, gcnii_layers=4,
        step_buckets=((160, 640), (320, 896)),
        exact_bucket=(160, 640),
    ),
    # cora/citeseer/pubmed-sim (7 classes, 48-dim features)
    "planetoid": Profile(
        name="planetoid", d_x=48, n_class=7, hidden=64,
        gcn_layers=3, gcnii_layers=4,
        step_buckets=((256, 768), (640, 1024)),
        exact_bucket=(256, 1024),
    ),
}

ARCH_NAMES = ("gcn", "gcnii")
