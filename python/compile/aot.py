"""AOT lowering: every L2 program -> HLO *text* + artifacts/manifest.json.

HLO text (NOT ``lowered.compiler_ir("hlo")``-proto serialization) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids, which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/gen_hlo.py.

Usage (normally via ``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts [--profile std16]
        [--arch gcn] [--no-pallas] [--force]

Lowering is incremental: a program is re-lowered only if its spec fingerprint
changed or the HLO file is missing. The manifest records, per program, the
positional input/output signatures the Rust runtime binds to.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional

import jax
from jax._src.lib import xla_client as xc

from . import exact
from .archs import Arch
from .spec import ARCH_NAMES, PROFILES, Profile
from .step import Spec, StepSpec, build_step

_DTYPES = {"f32": "float32", "i32": "int32"}


def _shape_structs(specs: List[Spec]):
    import jax.numpy as jnp

    out = []
    for _, shape, dt in specs:
        out.append(jax.ShapeDtypeStruct(shape, getattr(jnp, _DTYPES[dt])))
    return out


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(fn: Callable, in_specs: List[Spec]) -> str:
    # keep_unused: the manifest promises a positional signature; without it
    # XLA prunes inputs a given arch ignores (e.g. GCN's H0_t) and the Rust
    # runtime's buffer count no longer matches.
    lowered = jax.jit(fn, keep_unused=True).lower(*_shape_structs(in_specs))
    return to_hlo_text(lowered)


_SRC_HASH: Optional[str] = None


def _source_hash() -> str:
    """Hash of every module that shapes lowered HLO — kernels included, so a
    kernel change invalidates *all* cached programs (not just ones whose
    shapes moved)."""
    global _SRC_HASH
    if _SRC_HASH is None:
        h = hashlib.sha256()
        base = os.path.dirname(os.path.abspath(__file__))
        for rel in ["archs.py", "step.py", "exact.py", "spec.py",
                    "kernels/agg.py", "kernels/ref.py"]:
            with open(os.path.join(base, rel), "rb") as f:
                h.update(f.read())
        _SRC_HASH = h.hexdigest()[:16]
    return _SRC_HASH


def _fingerprint(kind: str, in_specs: List[Spec], out_specs: List[Spec], extra: str) -> str:
    blob = json.dumps(
        [kind, in_specs, out_specs, extra, jax.__version__, _source_hash()]
    ).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


class Emitter:
    def __init__(self, out_dir: str, force: bool, use_pallas: bool):
        self.out_dir = out_dir
        self.force = force
        self.use_pallas = use_pallas
        self.programs: List[dict] = []
        self.old: Dict[str, dict] = {}
        os.makedirs(out_dir, exist_ok=True)
        mpath = os.path.join(out_dir, "manifest.json")
        if os.path.exists(mpath) and not force:
            try:
                with open(mpath) as f:
                    for p in json.load(f).get("programs", []):
                        self.old[p["name"]] = p
            except (json.JSONDecodeError, KeyError):
                pass

    def emit(self, name: str, kind: str, meta: dict,
             fn: Callable, in_specs: List[Spec], out_specs: List[Spec]) -> None:
        fname = f"{name}.hlo.txt"
        fpath = os.path.join(self.out_dir, fname)
        fp = _fingerprint(kind, in_specs, out_specs, json.dumps(meta, sort_keys=True) + str(self.use_pallas))
        prev = self.old.get(name)
        entry = {
            "name": name,
            "file": fname,
            "kind": kind,
            "fingerprint": fp,
            "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in in_specs],
            "outputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in out_specs],
            **meta,
        }
        if prev is not None and prev.get("fingerprint") == fp and os.path.exists(fpath):
            self.programs.append(entry)
            print(f"  [cached] {name}")
            return
        t0 = time.time()
        text = lower_program(fn, in_specs)
        with open(fpath, "w") as f:
            f.write(text)
        self.programs.append(entry)
        print(f"  [lower ] {name}  ({time.time() - t0:.1f}s, {len(text)//1024} KiB)")

    def write_manifest(self, profiles: Dict[str, Profile]) -> None:
        manifest = {
            "version": 1,
            "use_pallas": self.use_pallas,
            "profiles": {
                p.name: {
                    "d_x": p.d_x, "n_class": p.n_class, "hidden": p.hidden,
                    "gcn_layers": p.gcn_layers, "gcnii_layers": p.gcnii_layers,
                    "step_buckets": [list(b) for b in p.step_buckets],
                    "exact_bucket": list(p.exact_bucket),
                }
                for p in profiles.values()
            },
            "archs": {},
            "programs": self.programs,
        }
        # Record canonical parameter orderings per (profile, arch).
        for p in profiles.values():
            for an in ARCH_NAMES:
                arch = p.arch(an)
                manifest["archs"][f"{p.name}/{an}"] = {
                    "L": arch.L,
                    "dims": arch.dims,
                    "params": [{"name": n, "shape": list(s)} for n, s in arch.param_specs()],
                    "head_params": arch.head_param_names(),
                    "layer_params": {str(l): exact.layer_param_names(arch, l) for l in range(1, arch.L + 1)},
                }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {len(self.programs)} programs")


def emit_profile(em: Emitter, profile: Profile, arch_names) -> None:
    for an in arch_names:
        arch: Arch = profile.arch(an)
        base = {"profile": profile.name, "arch": an}
        # --- train steps, one per bucket --------------------------------
        for (B, H) in profile.step_buckets:
            sspec = StepSpec(arch=arch, B=B, H=H, use_pallas=em.use_pallas)
            fn, ins, outs = build_step(sspec)
            em.emit(f"{profile.name}_{sspec.name}", "train_step",
                    {**base, "B": B, "H": H}, fn, ins, outs)
        # --- exact tile programs ----------------------------------------
        Bt, Ht = profile.exact_bucket
        for l in range(1, arch.L + 1):
            fn, ins, outs = exact.build_fwd_layer(arch, l, Bt, Ht, em.use_pallas)
            em.emit(f"{profile.name}_fwd_{an}_l{l}", "fwd_layer",
                    {**base, "layer": l, "B": Bt, "H": Ht}, fn, ins, outs)
            fn, ins, outs = exact.build_bwd_layer(arch, l, Bt, Ht, em.use_pallas)
            em.emit(f"{profile.name}_bwd_{an}_l{l}", "bwd_layer",
                    {**base, "layer": l, "B": Bt, "H": Ht}, fn, ins, outs)
        fn, ins, outs = exact.build_loss_grad(arch, Bt)
        em.emit(f"{profile.name}_loss_{an}", "loss_grad",
                {**base, "B": Bt}, fn, ins, outs)
        if an == "gcnii":
            fn, ins, outs = exact.build_embed0(arch, Bt)
            em.emit(f"{profile.name}_embed0_{an}", "embed0",
                    {**base, "B": Bt}, fn, ins, outs)
            fn, ins, outs = exact.build_embed0_bwd(arch, Bt)
            em.emit(f"{profile.name}_embed0bwd_{an}", "embed0_bwd",
                    {**base, "B": Bt}, fn, ins, outs)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profile", action="append", default=None,
                    help="limit to profile(s); default all")
    ap.add_argument("--arch", action="append", default=None,
                    help="limit to arch(es); default all")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the jnp reference kernels (debug only)")
    ap.add_argument("--force", action="store_true", help="ignore fingerprint cache")
    args = ap.parse_args(argv)

    profiles = {k: v for k, v in PROFILES.items()
                if args.profile is None or k in args.profile}
    arch_names = args.arch or list(ARCH_NAMES)
    em = Emitter(args.out, force=args.force, use_pallas=not args.no_pallas)
    t0 = time.time()
    for p in profiles.values():
        print(f"profile {p.name}:")
        emit_profile(em, p, arch_names)
    em.write_manifest(PROFILES)
    print(f"done in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
