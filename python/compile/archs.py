"""L2 architectures: GCN (Kipf & Welling 2017) and GCNII (Chen et al. 2020).

Both are expressed in the paper's aggregate-and-update form (Eq. 2) so the LMC
step builder (:mod:`.step`) can drive forward compensation (Eqs. 8-10) and the
backward message-passing compensation (Eqs. 11-13) generically:

  - ``embed0(params, X)``   — the per-node, neighbor-free layer-0 embedding
    (identity for GCN; ``relu(X @ W0 + b0)`` for GCNII). Exact for halo nodes.
  - ``layer(params, l, agg, h_prev, h0)`` — the update function
    ``u_theta(h_prev, m, x)`` where ``agg`` is the GCN-normalized message
    (self-loop folded into the adjacency diagonal).
  - ``logits(params, h)``   — the output head ``ell_w`` (identity for GCN, an
    affine classifier for GCNII; its params are the paper's ``w``).

Parameters are a flat ``{name: array}`` dict with a canonical ordering
(:meth:`Arch.param_names`) that the AOT manifest records so the Rust runtime
can build inputs positionally.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[1]
    scale = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)


@dataclass(frozen=True)
class Arch:
    """Static description + callables for one GNN architecture."""

    name: str
    L: int                       # number of message passing layers
    dims: List[int]              # layer output dims, index 0 = embed0 output
    d_x: int                     # raw feature dim
    n_class: int
    hyper: Dict[str, float] = field(default_factory=dict)

    # --- canonical parameter ordering -------------------------------------
    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        raise NotImplementedError

    def param_names(self) -> List[str]:
        return [n for n, _ in self.param_specs()]

    def init_params(self, key) -> Params:
        raise NotImplementedError

    # --- model pieces ------------------------------------------------------
    def embed0(self, params: Params, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def layer(self, params: Params, l: int, agg: jax.Array, h_prev: jax.Array, h0: jax.Array) -> jax.Array:
        raise NotImplementedError

    def logits(self, params: Params, h: jax.Array) -> jax.Array:
        raise NotImplementedError

    def head_param_names(self) -> List[str]:
        """Names of the output-head parameters (the paper's ``w``)."""
        return []


class GCN(Arch):
    """Plain GCN: ``H^l = relu(Ahat H^{l-1} W^l + b^l)``, last layer linear.

    ``dims`` = [d_x, hidden, ..., n_class]; embed0 is the identity.
    """

    def __init__(self, L: int, d_x: int, hidden: int, n_class: int):
        dims = [d_x] + [hidden] * (L - 1) + [n_class]
        super().__init__(name="gcn", L=L, dims=dims, d_x=d_x, n_class=n_class)

    def param_specs(self):
        specs = []
        for l in range(1, self.L + 1):
            specs.append((f"W{l}", (self.dims[l - 1], self.dims[l])))
            specs.append((f"b{l}", (self.dims[l],)))
        return specs

    def init_params(self, key) -> Params:
        params: Params = {}
        keys = jax.random.split(key, self.L)
        for l in range(1, self.L + 1):
            params[f"W{l}"] = _glorot(keys[l - 1], (self.dims[l - 1], self.dims[l]))
            params[f"b{l}"] = jnp.zeros((self.dims[l],), jnp.float32)
        return params

    def embed0(self, params, x):
        return x

    def layer(self, params, l, agg, h_prev, h0):
        z = agg @ params[f"W{l}"] + params[f"b{l}"]
        return z if l == self.L else jax.nn.relu(z)

    def logits(self, params, h):
        return h


class GCNII(Arch):
    """GCNII: initial residual + identity mapping (Chen et al. 2020).

    ``h0 = relu(X @ W0 + b0)``;
    ``s  = (1-alpha) * Ahat H^{l-1} + alpha * h0``;
    ``H^l = relu((1-gamma_l) * s + gamma_l * s @ W^l)``, gamma_l = log(lam/l+1);
    logits = ``H^L @ Wc + bc`` (the paper's output params ``w``).
    """

    def __init__(self, L: int, d_x: int, hidden: int, n_class: int,
                 alpha: float = 0.1, lam: float = 0.5):
        dims = [hidden] * (L + 1)
        super().__init__(name="gcnii", L=L, dims=dims, d_x=d_x, n_class=n_class,
                         hyper={"alpha": alpha, "lam": lam})

    def param_specs(self):
        d = self.dims[0]
        specs = [("W0", (self.d_x, d)), ("b0", (d,))]
        for l in range(1, self.L + 1):
            specs.append((f"W{l}", (d, d)))
        specs += [("Wc", (d, self.n_class)), ("bc", (self.n_class,))]
        return specs

    def head_param_names(self):
        return ["Wc", "bc"]

    def init_params(self, key) -> Params:
        d = self.dims[0]
        keys = jax.random.split(key, self.L + 2)
        params: Params = {
            "W0": _glorot(keys[0], (self.d_x, d)),
            "b0": jnp.zeros((d,), jnp.float32),
        }
        for l in range(1, self.L + 1):
            params[f"W{l}"] = _glorot(keys[l], (d, d))
        params["Wc"] = _glorot(keys[-1], (d, self.n_class))
        params["bc"] = jnp.zeros((self.n_class,), jnp.float32)
        return params

    def embed0(self, params, x):
        return jax.nn.relu(x @ params["W0"] + params["b0"])

    def gamma(self, l: int) -> float:
        return math.log(self.hyper["lam"] / l + 1.0)

    def layer(self, params, l, agg, h_prev, h0):
        alpha = self.hyper["alpha"]
        s = (1.0 - alpha) * agg + alpha * h0
        g = self.gamma(l)
        z = (1.0 - g) * s + g * (s @ params[f"W{l}"])
        return jax.nn.relu(z)

    def logits(self, params, h):
        return h @ params["Wc"] + params["bc"]


def make_arch(name: str, L: int, d_x: int, hidden: int, n_class: int, **hyper) -> Arch:
    if name == "gcn":
        return GCN(L=L, d_x=d_x, hidden=hidden, n_class=n_class)
    if name == "gcnii":
        return GCNII(L=L, d_x=d_x, hidden=hidden, n_class=n_class, **hyper)
    raise ValueError(f"unknown arch {name!r}")
