"""L2 model package entry point.

The actual model code lives in:
  - :mod:`compile.archs`  — GCN / GCNII in aggregate-and-update form,
  - :mod:`compile.step`   — the fused LMC train-step (fwd+bwd compensation),
  - :mod:`compile.exact`  — exact layer-wise tile programs (eval / GD oracle).

This module re-exports the builders so ``compile.model`` is the one import
surface for tests and :mod:`compile.aot`.
"""

from .archs import GCN, GCNII, Arch, make_arch  # noqa: F401
from .exact import (  # noqa: F401
    build_bwd_layer,
    build_embed0,
    build_embed0_bwd,
    build_fwd_layer,
    build_loss_grad,
    layer_param_names,
)
from .step import StepSpec, build_step, masked_ce, masked_correct  # noqa: F401
