//! Batch-size robustness demo (Table 3 shape): GAS vs LMC on arxiv-sim at
//! batch sizes of 1 and 5 clusters. LMC's backward compensation matters most
//! at small batches, where more messages are discarded.
//!
//! ```bash
//! cargo run --release --example batch_size_sweep
//! ```

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;

fn main() -> anyhow::Result<()> {
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new());
    println!("{:<12} {:>10} {:>10}", "batch_size", "GAS", "LMC");
    for bs in [1usize, 5] {
        let mut row = format!("{bs:<12}");
        for method in [Method::Gas, Method::Lmc] {
            let cfg = RunConfig {
                dataset: DatasetId::ArxivSim,
                arch: "gcn".into(),
                method,
                clusters_per_batch: bs,
                lr: if bs == 1 { 5e-3 } else { 1e-2 },
                epochs: 25,
                eval_every: 2,
                ..Default::default()
            };
            let mut t = Trainer::new(exec.clone(), cfg)?;
            let m = t.run()?;
            let acc = m.best_val_test().map(|(_, a)| a).unwrap_or(f64::NAN);
            row += &format!(" {:>9.2}%", 100.0 * acc);
        }
        println!("{row}");
    }
    Ok(())
}
