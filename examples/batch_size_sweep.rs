//! Batch-size robustness demo (Table 3 shape): GAS vs LMC on arxiv-sim at
//! batch sizes of 1 and 5 clusters. LMC's backward compensation matters most
//! at small batches, where more messages are discarded.
//!
//! ```bash
//! make artifacts && cargo run --release --example batch_size_sweep
//! ```

use std::path::Path;
use std::sync::Arc;

use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;
use lmc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new(Path::new("artifacts"))?);
    println!("{:<12} {:>10} {:>10}", "batch_size", "GAS", "LMC");
    for bs in [1usize, 5] {
        let mut row = format!("{bs:<12}");
        for method in [Method::Gas, Method::Lmc] {
            let cfg = RunConfig {
                dataset: DatasetId::ArxivSim,
                arch: "gcn".into(),
                method,
                clusters_per_batch: bs,
                lr: if bs == 1 { 5e-3 } else { 1e-2 },
                epochs: 25,
                eval_every: 2,
                ..Default::default()
            };
            let mut t = Trainer::new(rt.clone(), cfg)?;
            let m = t.run()?;
            let acc = m.best_val_test().map(|(_, a)| a).unwrap_or(f64::NAN);
            row += &format!(" {:>9.2}%", 100.0 * acc);
        }
        println!("{row}");
    }
    Ok(())
}
