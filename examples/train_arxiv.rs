//! End-to-end driver (DESIGN.md "End-to-end validation"): train a GCN on the
//! arxiv-sim workload with LMC and with GAS, log the loss/accuracy curves,
//! and report the paper's headline metric — epochs and wall-clock to reach
//! the full-batch (GD) reference accuracy. Results land in
//! `results/train_arxiv_*.csv` and are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example train_arxiv
//! ```

use std::path::Path;
use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;

fn main() -> anyhow::Result<()> {
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new());
    let out = Path::new("results");
    std::fs::create_dir_all(out)?;

    // 1) full-batch GD reference accuracy (the target both methods chase)
    let mut gd_cfg = RunConfig {
        dataset: DatasetId::ArxivSim,
        arch: "gcn".into(),
        method: Method::Gd,
        epochs: 40,
        eval_every: 4,
        ..Default::default()
    };
    gd_cfg.lr = 2e-2;
    let mut gd = Trainer::new(exec.clone(), gd_cfg)?;
    let gd_metrics = gd.run()?;
    let (gd_val, gd_test) = gd_metrics.best_val_test().unwrap();
    println!(
        "GD reference: best val {:.2}%, test {:.2}% ({:.1}s)",
        100.0 * gd_val,
        100.0 * gd_test,
        gd_metrics.total_secs()
    );
    gd_metrics
        .curve_table("arxiv-sim/gcn/GD")
        .save(out, "train_arxiv_gd")?;
    let target = gd_test * 0.97;

    // 2) LMC vs GAS racing to the target, in the paper's memory-constrained
    //    regime: 1 cluster per mini-batch (small batches are where discarded
    //    messages — and hence LMC's compensation — matter most, cf. Fig. 4).
    let mut summary = Vec::new();
    for method in [Method::Lmc, Method::Gas, Method::Cluster] {
        let cfg = RunConfig {
            dataset: DatasetId::ArxivSim,
            arch: "gcn".into(),
            method,
            epochs: 80,
            clusters_per_batch: 1,
            lr: 5e-3,
            eval_every: 1,
            target_acc: Some(target),
            verbose: true,
            ..Default::default()
        };
        let mut t = Trainer::new(exec.clone(), cfg)?;
        println!(
            "\n=== {} on arxiv-sim ({} nodes, {} clusters, target test {:.2}%) ===",
            method.name(),
            t.graph.n(),
            t.clusters.len(),
            100.0 * target
        );
        let m = t.run()?;
        let stem = format!("train_arxiv_{}", method.name().to_lowercase());
        m.curve_table(&format!("arxiv-sim/gcn/{}", method.name())).save(out, &stem)?;
        let (ep, secs) = m
            .reached_target
            .map(|(e, s)| (e.to_string(), format!("{s:.1}")))
            .unwrap_or(("not reached".into(), "-".into()));
        println!(
            "{}: target @ epoch {} ({} s); final test {:.2}%",
            method.name(),
            ep,
            secs,
            100.0 * m.final_test().unwrap_or(f64::NAN)
        );
        summary.push((method.name(), ep, secs, m.final_test().unwrap_or(f64::NAN)));
    }

    println!("\n=== headline (Table 2 shape) ===");
    println!("GD reference test acc: {:.2}%", 100.0 * gd_test);
    for (name, ep, secs, fin) in &summary {
        println!(
            "{name:<4} epochs-to-target: {ep:<12} runtime: {secs:<8} final test {:.2}%",
            100.0 * fin
        );
    }
    println!("curves: results/train_arxiv_{{gd,lmc,gas}}.csv");
    Ok(())
}
