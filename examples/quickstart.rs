//! Quickstart: train a 3-layer GCN on cora-sim with LMC and print accuracy.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;
use std::sync::Arc;

use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;
use lmc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::new(Path::new("artifacts"))?);
    let cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method: Method::Lmc,
        epochs: 30,
        eval_every: 2,
        verbose: true,
        ..Default::default()
    };
    let mut trainer = Trainer::new(rt, cfg)?;
    println!(
        "quickstart: {} nodes, {} clusters, LMC + GCN",
        trainer.graph.n(),
        trainer.clusters.len()
    );
    let metrics = trainer.run()?;
    let (val, test) = metrics.best_val_test().unwrap();
    println!(
        "\nquickstart done in {:.1}s — best val {:.1}%, test {:.1}%",
        metrics.total_secs(),
        100.0 * val,
        100.0 * test
    );
    assert!(test > 0.4, "model should beat chance comfortably");
    Ok(())
}
