//! Quickstart: train a 3-layer GCN on cora-sim with LMC and print accuracy.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;

fn main() -> anyhow::Result<()> {
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new());
    let cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method: Method::Lmc,
        epochs: 30,
        eval_every: 2,
        verbose: true,
        ..Default::default()
    };
    let mut trainer = Trainer::new(exec, cfg)?;
    println!(
        "quickstart: {} nodes, {} clusters, LMC + GCN",
        trainer.graph.n(),
        trainer.clusters.len()
    );
    let metrics = trainer.run()?;
    let (val, test) = metrics.best_val_test().unwrap();
    println!(
        "\nquickstart done in {:.1}s — best val {:.1}%, test {:.1}%",
        metrics.total_secs(),
        100.0 * val,
        100.0 * test
    );
    assert!(test > 0.4, "model should beat chance comfortably");
    Ok(())
}
