//! Gradient-error demo (the Fig. 3 mechanism in one shot): from one trained
//! state, compare the mini-batch gradient *bias* (partition-summed relative
//! error vs the exact full-batch gradient) of CLUSTER, GAS and LMC — the
//! quantity Theorem 2 bounds and LMC's compensations shrink.
//!
//! ```bash
//! cargo run --release --example gradient_error
//! ```

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{grad_check, Method, Trainer};
use lmc::graph::DatasetId;

fn main() -> anyhow::Result<()> {
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new());
    let cfg = RunConfig {
        dataset: DatasetId::ArxivSim,
        arch: "gcn".into(),
        method: Method::Lmc,
        epochs: 3,
        lr: 3e-3,
        eval_every: 99,
        ..Default::default()
    };
    let mut t = Trainer::new(exec, cfg)?;
    for _ in 0..3 {
        t.train_epoch()?;
    }
    let mut rows = Vec::new();
    for method in [Method::Cluster, Method::Gas, Method::Lmc] {
        t.cfg.method = method;
        let bias = grad_check::measure_bias(&mut t)?;
        let rep = grad_check::measure(&mut t)?;
        println!(
            "{:<8} bias {:.4}   per-batch rel err (variance incl.) {:.4}   per-layer {:?}",
            method.name(),
            bias,
            rep.overall,
            rep.per_layer.iter().map(|e| format!("{e:.3}")).collect::<Vec<_>>()
        );
        rows.push((method, bias));
    }
    let lmc = rows.iter().find(|(m, _)| *m == Method::Lmc).unwrap().1;
    let gas = rows.iter().find(|(m, _)| *m == Method::Gas).unwrap().1;
    println!("\nexpected shape (paper Fig. 3 / Theorem 2): LMC bias < GAS bias < CLUSTER bias");
    assert!(lmc < gas, "LMC bias should beat GAS");
    Ok(())
}
