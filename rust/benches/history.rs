//! Bench: historical value store gather/scatter/momentum paths, at every
//! storage dtype — f32 rows move full-width, bf16/f16 rows encode on
//! scatter and decode on gather (momentum accumulates in f32 throughout).

use lmc::history::{HistDtype, History};
use lmc::util::bench::{black_box, Bencher};
use lmc::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    println!("== history store ==");
    let n = 3000;
    let dims = [64usize, 64];
    let mut rng = Rng::new(0);
    for dtype in [HistDtype::F32, HistDtype::Bf16, HistDtype::F16] {
        let mut h = History::with_dtype(n, &dims, dtype);
        let tag = dtype.name();
        for &k in &[256usize, 1024] {
            let idx: Vec<u32> = {
                let mut v: Vec<u32> =
                    rng.sample_indices(n, k).into_iter().map(|x| x as u32).collect();
                v.sort_unstable();
                v
            };
            let src: Vec<f32> = (0..k * 64).map(|_| rng.normal() as f32).collect();
            b.run(&format!("gather_h/{tag}/{k}x64"), || {
                black_box(h.gather_h(1, &idx, k + 64));
            });
            b.run(&format!("scatter_h/{tag}/{k}x64"), || {
                h.scatter_h(1, &idx, &src);
            });
            b.run(&format!("momentum_h/{tag}/{k}x64"), || {
                h.momentum_h(1, &idx, &src, 0.3);
            });
        }
        println!(
            "    {tag}: {:.1} MB resident ({} bytes/node)",
            h.bytes() as f64 / 1e6,
            h.bytes_per_node()
        );
    }
}
