//! Bench: subgraph densification (the gather/pad hot loop feeding the step).

use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, PartitionConfig};
use lmc::sampler::{build_subgraph, gather_rows, AdjacencyPolicy, Buckets, HaloSampler};
use lmc::util::bench::{black_box, Bencher};
use lmc::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    println!("== sampler ==");
    for &id in &[DatasetId::ArxivSim, DatasetId::RedditSim] {
        let g = load(id, 0);
        let k = id.default_parts();
        let part = partition(&g.csr, &PartitionConfig::new(k, 0));
        let g = g.permute(&part.contiguous_perm());
        let buckets = Buckets(vec![(192, 1024), (320, 1536), (768, 1792), (1408, 1792)]);
        for nclusters in [1usize, 2, 5] {
            let per = g.n() / k;
            let batch: Vec<u32> = (0..(per * nclusters) as u32).collect();
            let mut rng = Rng::new(1);
            b.run(
                &format!("subgraph/{}/c{}(B~{})", id.name(), nclusters, batch.len()),
                || {
                    black_box(
                        build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets, &HaloSampler::none(), &mut rng)
                            .unwrap(),
                    );
                },
            );
        }
        // feature gather throughput
        let idx: Vec<u32> = (0..512u32.min(g.n() as u32)).collect();
        b.run(&format!("gather_rows/{}/512xd{}", id.name(), g.d_x), || {
            black_box(gather_rows(&g.features, g.d_x, &idx, 768));
        });
    }
}
