//! Bench: cheap analytic table regeneration — Table 7 message/memory
//! accounting (no training). The full table/figure harness lives in
//! `lmc experiment <id>`.

use lmc::coordinator::memory::{gd_active_bytes, reserved_messages};
use lmc::coordinator::Method;
use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, PartitionConfig};
use lmc::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::default();
    println!("== table 7 accounting (reserved messages, union per epoch) ==");
    for &id in &[DatasetId::ArxivSim, DatasetId::RedditSim] {
        let g = load(id, 0);
        let k = id.default_parts();
        let part = partition(&g.csr, &PartitionConfig::new(k, 0));
        let g = g.permute(&part.contiguous_perm());
        let per = g.n().div_ceil(k);
        let batches: Vec<Vec<u32>> = (0..k)
            .map(|p| ((p * per) as u32..((p + 1) * per).min(g.n()) as u32).collect())
            .collect();
        for method in [Method::Cluster, Method::Gas, Method::Lmc] {
            let acct = reserved_messages(&g, &batches, method);
            println!(
                "  {:<10} {:<8} fwd {:>5.1}%  bwd {:>5.1}%",
                id.name(),
                method.name(),
                100.0 * acct.fwd_frac,
                100.0 * acct.bwd_frac
            );
            b.run(&format!("reserved_messages/{}/{}", id.name(), method.name()), || {
                black_box(reserved_messages(&g, &batches, method));
            });
        }
        let dims = vec![64usize, 64, 64, 16];
        println!(
            "  {:<10} GD active bytes: {:.1} MB",
            id.name(),
            gd_active_bytes(g.n(), &dims, g.d_x, g.csr.neighbors.len()) as f64 / 1e6
        );
    }
}
