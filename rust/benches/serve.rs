//! Bench: serve-path throughput over the native backend — the exact
//! (L-hop closure) vs cached (1-hop + history halo) tile paths across
//! request batch sizes, plus the history-refresh cost a parameter update
//! pays. Emits `BENCH_serve.json` at the repo root (provenance-stamped
//! with commit + runner + SIMD level); smoke runs (`BENCH_SMOKE=1` /
//! `--quick`) write `BENCH_serve.smoke.json` instead, so the numbers can
//! never be confused with full-run measurements.

use std::fmt::Write as _;

use lmc::config::RunConfig;
use lmc::graph::DatasetId;
use lmc::serve::{ServeEngine, ServeMode};
use lmc::util::bench::{black_box, provenance, Bencher};

fn main() {
    let smoke = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_SMOKE").is_ok();
    let id = if smoke { DatasetId::CoraSim } else { DatasetId::ArxivSim };
    let b = if smoke { Bencher::smoke() } else { Bencher::quick() };
    let cfg = RunConfig { dataset: id, arch: "gcn".into(), seed: 0, ..Default::default() };
    let mut eng = ServeEngine::from_config(&cfg, None).expect("serve engine");
    let warm = b.run("serve/refresh_history(full forward)", || {
        eng.refresh_history().expect("warm history");
    });
    let n = eng.graph().n();
    println!(
        "== serve bench ({}, {} nodes, arch {}, simd {}) ==",
        id.name(),
        n,
        eng.model().arch_name,
        lmc::backend::simd::level().name()
    );
    println!(
        "    history store: dtype {}, {} bytes/node",
        eng.history_dtype().name(),
        eng.history_bytes_per_node()
    );

    let sizes: &[usize] = if smoke { &[1, 16, 128] } else { &[1, 16, 128, 1024] };
    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for &bs in sizes {
        let bs = bs.min(n);
        // spread the request across the graph so tiles see realistic halos
        let nodes: Vec<u32> = (0..n as u32).step_by((n / bs).max(1)).take(bs).collect();
        let cached = b.run(&format!("serve/cached/batch{bs}"), || {
            black_box(eng.predict_in_mode(&nodes, ServeMode::Cached).expect("cached predict"));
        });
        let exact = b.run(&format!("serve/exact/batch{bs}"), || {
            black_box(eng.predict_in_mode(&nodes, ServeMode::Exact).expect("exact predict"));
        });
        println!(
            "    batch {bs:>5}: cached {:>10.1} nodes/s   exact {:>10.1} nodes/s",
            bs as f64 / cached.mean_s,
            bs as f64 / exact.mean_s
        );
        rows.push((bs, cached.mean_s, exact.mean_s));
    }

    // ---- emit BENCH_serve[.smoke].json at the repo root -----------------
    let mut json = String::from("{\n  \"bench\": \"serve\",\n");
    let _ = writeln!(json, "  \"provenance\": \"{}\",", provenance());
    let _ = writeln!(json, "  \"dataset\": \"{}\",", id.name());
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"arch\": \"{}\",", eng.model().arch_name);
    let _ = writeln!(json, "  \"nodes\": {n},");
    let _ = writeln!(json, "  \"history_dtype\": \"{}\",", eng.history_dtype().name());
    let _ = writeln!(json, "  \"history_bytes_per_node\": {},", eng.history_bytes_per_node());
    let _ = writeln!(json, "  \"refresh_history_s\": {:.6e},", warm.mean_s);
    json.push_str("  \"batches\": [\n");
    for (i, (bs, cached_s, exact_s)) in rows.iter().enumerate() {
        let _ = writeln!(
            json,
            "    {{\"batch\": {bs}, \"cached_s\": {cached_s:.6e}, \"cached_nodes_per_s\": \
             {:.1}, \"exact_s\": {exact_s:.6e}, \"exact_nodes_per_s\": {:.1}}}{}",
            *bs as f64 / cached_s,
            *bs as f64 / exact_s,
            if i + 1 < rows.len() { "," } else { "" }
        );
    }
    json.push_str("  ]\n}\n");
    let fname = if smoke { "/../BENCH_serve.smoke.json" } else { "/../BENCH_serve.json" };
    let path = format!("{}{}", env!("CARGO_MANIFEST_DIR"), fname);
    std::fs::write(&path, &json).expect("write BENCH_serve json");
    println!("wrote {path}");
}
