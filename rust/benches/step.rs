//! Bench: end-to-end train-step latency per method (the Table 6 shape) and
//! the breakdown between host assembly and PJRT execution.
//! Requires `make artifacts`.

use std::path::Path;
use std::sync::Arc;

use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;
use lmc::runtime::Runtime;
use lmc::util::bench::Bencher;

fn main() {
    let rt = match Runtime::new(Path::new("artifacts")) {
        Ok(r) => Arc::new(r),
        Err(e) => {
            eprintln!("skipping step bench (no artifacts): {e}");
            return;
        }
    };
    let b = Bencher::quick();
    println!("== train step (per mini-batch, warm executable) ==");
    for &id in &[DatasetId::ArxivSim, DatasetId::RedditSim, DatasetId::CoraSim] {
        for method in [Method::Cluster, Method::Gas, Method::Fm, Method::Lmc] {
            let cfg = RunConfig {
                dataset: id,
                arch: "gcn".into(),
                method,
                epochs: 1,
                ..Default::default()
            };
            let mut t = Trainer::new(rt.clone(), cfg).unwrap();
            let batches = t.batcher.epoch_batches();
            let batch = batches[0].clone();
            let exec_before = t.rt.total_exec_secs();
            let stats = b.run(
                &format!("step/{}/{}", id.name(), method.name()),
                || {
                    t.step(&batch).unwrap();
                },
            );
            let exec_after = t.rt.total_exec_secs();
            let exec_frac =
                (exec_after - exec_before) / (stats.mean_s * stats.iters as f64).max(1e-12);
            println!(
                "    PJRT-execute share of step: {:.0}%  (host assembly+writeback: {:.0}%)",
                100.0 * exec_frac,
                100.0 * (1.0 - exec_frac)
            );
        }
    }
    println!("== exact evaluation (full-graph tile-wise forward) ==");
    for &id in &[DatasetId::ArxivSim, DatasetId::CoraSim] {
        let cfg = RunConfig { dataset: id, arch: "gcn".into(), method: Method::Lmc, epochs: 1, ..Default::default() };
        let t = Trainer::new(rt.clone(), cfg).unwrap();
        b.run(&format!("evaluate/{}", id.name()), || {
            t.evaluate().unwrap();
        });
    }
}
