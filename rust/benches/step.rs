//! Bench: end-to-end train-step latency per method (the Table 6 shape) and
//! the breakdown between host assembly/write-back and backend execution.
//! Runs on the native backend — no artifacts required.

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::graph::DatasetId;
use lmc::util::bench::Bencher;

fn main() {
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new());
    let b = Bencher::quick();
    println!("== train step (per mini-batch, native backend) ==");
    for &id in &[DatasetId::ArxivSim, DatasetId::RedditSim, DatasetId::CoraSim] {
        for method in [Method::Cluster, Method::Gas, Method::Fm, Method::Lmc] {
            let cfg = RunConfig {
                dataset: id,
                arch: "gcn".into(),
                method,
                epochs: 1,
                ..Default::default()
            };
            let mut t = Trainer::new(exec.clone(), cfg).unwrap();
            let batches = t.batcher.epoch_batches();
            let batch = batches[0].clone();
            let exec_before = t.exec.exec_secs();
            let stats = b.run(
                &format!("step/{}/{}", id.name(), method.name()),
                || {
                    t.step(&batch).unwrap();
                },
            );
            let exec_after = t.exec.exec_secs();
            let exec_frac =
                (exec_after - exec_before) / (stats.mean_s * stats.iters as f64).max(1e-12);
            println!(
                "    backend-execute share of step: {:.0}%  (sampling+writeback: {:.0}%)",
                100.0 * exec_frac,
                100.0 * (1.0 - exec_frac)
            );
        }
    }
    println!("== exact evaluation (full-graph forward) ==");
    for &id in &[DatasetId::ArxivSim, DatasetId::CoraSim] {
        let cfg = RunConfig { dataset: id, arch: "gcn".into(), method: Method::Lmc, epochs: 1, ..Default::default() };
        let t = Trainer::new(exec.clone(), cfg).unwrap();
        b.run(&format!("evaluate/{}", id.name()), || {
            t.evaluate().unwrap();
        });
    }
}
