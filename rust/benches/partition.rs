//! Bench: METIS-substitute multilevel partitioner on every dataset.

use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, quality::quality, PartitionConfig};
use lmc::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::default();
    println!("== partitioner ==");
    for &id in DatasetId::all() {
        let g = load(id, 0);
        let k = id.default_parts();
        let cfg = PartitionConfig::new(k, 0);
        b.run(&format!("partition/{}/k{}", id.name(), k), || {
            black_box(partition(&g.csr, &cfg));
        });
        let p = partition(&g.csr, &cfg);
        let q = quality(&g.csr, &p.assign, k);
        println!(
            "    quality: cut {:.1}% balance {:.2}",
            100.0 * q.cut_fraction,
            q.balance
        );
    }
}
