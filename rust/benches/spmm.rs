//! Bench: dense padded-block aggregation vs CSR sparse aggregation — the
//! core trade the sparse-subgraph refactor makes. Dense cost is
//! O(bucket² · d) regardless of how many edges the subgraph actually has;
//! CSR cost is O(nnz · d). Emits `BENCH_spmm.json` (provenance-stamped
//! with commit + runner + SIMD level) with the measured speedups per
//! bucket size; smoke runs (`BENCH_SMOKE=1` / `--quick`) cover the two
//! smallest buckets only and write `BENCH_spmm.smoke.json` instead.

use std::fmt::Write as _;

use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, PartitionConfig};
use lmc::sampler::{build_subgraph, AdjacencyPolicy, Buckets, HaloSampler};
use lmc::util::bench::{black_box, provenance, Bencher};
use lmc::util::rng::Rng;

/// Dense aggregation over the padded stacked blocks, exactly as the padded
/// step programs compute it: out = [A_bb A_bh; A_bh^T A_hh] @ x.
fn dense_agg(
    abb: &[f32],
    abh: &[f32],
    ahh: &[f32],
    bb: usize,
    bh: usize,
    x: &[f32],
    d: usize,
) -> Vec<f32> {
    let m = bb + bh;
    let mut out = vec![0f32; m * d];
    for i in 0..bb {
        let row = &mut out[i * d..(i + 1) * d];
        for j in 0..bb {
            let w = abb[i * bb + j];
            if w != 0.0 {
                for (r, &s) in row.iter_mut().zip(&x[j * d..(j + 1) * d]) {
                    *r += w * s;
                }
            }
        }
        for j in 0..bh {
            let w = abh[i * bh + j];
            if w != 0.0 {
                for (r, &s) in row.iter_mut().zip(&x[(bb + j) * d..(bb + j + 1) * d]) {
                    *r += w * s;
                }
            }
        }
    }
    for i in 0..bh {
        let row = &mut out[(bb + i) * d..(bb + i + 1) * d];
        for j in 0..bb {
            // A_bh^T
            let w = abh[j * bh + i];
            if w != 0.0 {
                for (r, &s) in row.iter_mut().zip(&x[j * d..(j + 1) * d]) {
                    *r += w * s;
                }
            }
        }
        for j in 0..bh {
            let w = ahh[i * bh + j];
            if w != 0.0 {
                for (r, &s) in row.iter_mut().zip(&x[(bb + j) * d..(bb + j + 1) * d]) {
                    *r += w * s;
                }
            }
        }
    }
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_SMOKE").is_ok();
    let b = if smoke { Bencher::smoke() } else { Bencher::quick() };
    let d = 64usize;
    let id = DatasetId::ArxivSim;
    let g = load(id, 0);
    let k = id.default_parts();
    let part = partition(&g.csr, &PartitionConfig::new(k, 0));
    let g = g.permute(&part.contiguous_perm());
    let per = g.n() / k;

    // the std16 profile's compiled buckets, smallest to largest; smoke
    // runs keep the two smallest
    let all_cases: [(usize, (usize, usize)); 4] =
        [(1, (192, 1024)), (2, (320, 1536)), (5, (768, 1792)), (10, (1408, 1792))];
    let cases = &all_cases[..if smoke { 2 } else { all_cases.len() }];
    let mut rows = Vec::new();
    println!("== dense padded blocks vs CSR sparse aggregation (d = {d}, smoke = {smoke}) ==");
    for &(nclusters, (bb, bh)) in cases {
        let batch: Vec<u32> = (0..((per * nclusters).min(g.n())) as u32).collect();
        let mut rng = Rng::new(7);
        let sb = build_subgraph(
            &g,
            &batch,
            AdjacencyPolicy::GlobalWithHalo,
            &Buckets(vec![(bb, bh)]),
            &HaloSampler::none(),
            &mut rng,
        )
        .expect("bucket fits");
        let m_pad = bb + bh;
        let m = sb.batch.len() + sb.halo.len();
        let x_pad: Vec<f32> = (0..m_pad * d).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
        let (abb, abh, ahh) = sb.to_dense();
        let a_hb = &sb.a_hb; // cached transpose (built once by the sampler)

        let dense = b.run(&format!("dense/b{bb}_h{bh}"), || {
            black_box(dense_agg(&abb, &abh, &ahh, bb, bh, &x_pad, d));
        });
        let csr = b.run(&format!("csr/b{bb}_h{bh}(nnz={})", sb.nnz()), || {
            // batch rows then halo rows over the sparse blocks
            let mut out = vec![0f32; m * d];
            let (bpart, hpart) = out.split_at_mut(sb.batch.len() * d);
            sb.a_bb.spmm_acc(&x_pad[..sb.batch.len() * d], d, bpart);
            sb.a_bh.spmm_acc(&x_pad[bb * d..(bb + sb.halo.len()) * d], d, bpart);
            a_hb.spmm_acc(&x_pad[..sb.batch.len() * d], d, hpart);
            sb.a_hh.spmm_acc(&x_pad[bb * d..(bb + sb.halo.len()) * d], d, hpart);
            black_box(&out);
        });
        let par = b.run(&format!("csr-par/b{bb}_h{bh}"), || {
            // same four block products as the serial csr case
            black_box(sb.a_bb.par_spmm(&x_pad[..sb.batch.len() * d], d));
            black_box(sb.a_bh.par_spmm(&x_pad[bb * d..(bb + sb.halo.len()) * d], d));
            black_box(a_hb.par_spmm(&x_pad[..sb.batch.len() * d], d));
            black_box(sb.a_hh.par_spmm(&x_pad[bb * d..(bb + sb.halo.len()) * d], d));
        });
        let tiled = b.run(&format!("csr-tiled/b{bb}_h{bh}"), || {
            // blocked + feature-tiled accumulate, fused into one buffer
            let mut out = vec![0f32; m * d];
            let (bpart, hpart) = out.split_at_mut(sb.batch.len() * d);
            sb.a_bb.par_spmm_acc_tiled(&x_pad[..sb.batch.len() * d], d, 1.0, bpart);
            sb.a_bh.par_spmm_acc_tiled(&x_pad[bb * d..(bb + sb.halo.len()) * d], d, 1.0, bpart);
            a_hb.par_spmm_acc_tiled(&x_pad[..sb.batch.len() * d], d, 1.0, hpart);
            sb.a_hh.par_spmm_acc_tiled(&x_pad[bb * d..(bb + sb.halo.len()) * d], d, 1.0, hpart);
            black_box(&out);
        });
        let speedup = dense.mean_s / csr.mean_s;
        println!(
            "    bucket ({bb},{bh}) actual ({}, {}) nnz {}  dense/csr speedup {speedup:.1}x",
            sb.batch.len(),
            sb.halo.len(),
            sb.nnz()
        );
        rows.push((
            bb,
            bh,
            sb.batch.len(),
            sb.halo.len(),
            sb.nnz(),
            dense.mean_s,
            csr.mean_s,
            par.mean_s,
            tiled.mean_s,
            speedup,
        ));
    }

    // emit BENCH_spmm[.smoke].json at the repo root
    let mut json = String::from("{\n  \"bench\": \"spmm_dense_vs_csr\",\n");
    let _ = writeln!(json, "  \"provenance\": \"{}\",", provenance());
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    json.push_str("  \"d\": 64,\n  \"cases\": [\n");
    for (i, &(bb, bh, nb, nh, nnz, dense_s, csr_s, par_s, tiled_s, speedup)) in rows.iter().enumerate()
    {
        let _ = write!(
            json,
            "    {{\"bucket_b\": {bb}, \"bucket_h\": {bh}, \"batch\": {nb}, \"halo\": {nh}, \
             \"nnz\": {nnz}, \"dense_mean_s\": {dense_s:.6e}, \"csr_mean_s\": {csr_s:.6e}, \
             \"csr_par_mean_s\": {par_s:.6e}, \"csr_tiled_mean_s\": {tiled_s:.6e}, \
             \"speedup_dense_over_csr\": {speedup:.2}}}{}",
            if i + 1 < rows.len() { ",\n" } else { "\n" }
        );
    }
    json.push_str("  ]\n}\n");
    let fname = if smoke { "/../BENCH_spmm.smoke.json" } else { "/../BENCH_spmm.json" };
    let path = format!("{}{}", env!("CARGO_MANIFEST_DIR"), fname);
    std::fs::write(&path, &json).expect("write BENCH_spmm json");
    println!("wrote {path}");
    let largest = rows.last().unwrap();
    assert!(
        largest.9 > 1.0,
        "CSR aggregation should beat dense blocks at the largest bucket (got {:.2}x)",
        largest.9
    );
}
