//! Bench: per-phase train-step breakdown (sample / gather / aggregate /
//! gemm / compensate) plus the end-to-end single-step comparison between
//! the pre-optimization native configuration (serial reference kernels,
//! rebuild-per-step, allocate-per-step) and the optimized one (blocked
//! kernels, Fixed-mode subgraph cache semantics, workspace reuse).
//!
//! Emits `BENCH_step.json` at the repo root so subsequent PRs have a perf
//! trajectory to regress against. Timings are recorded, never gated: the
//! CI smoke job (`BENCH_SMOKE=1` or `--quick`) fails only on panic.

use std::fmt::Write as _;
use std::sync::Mutex;

use lmc::backend::native::combine;
use lmc::backend::{gemm, Executor, ModelSpec, NativeExecutor, StepInputs, StepWorkspace};
use lmc::coordinator::params::Params;
use lmc::graph::{load, DatasetId};
use lmc::history::History;
use lmc::partition::{partition, PartitionConfig};
use lmc::runtime::ArchInfo;
use lmc::sampler::{
    beta_vector, beta_vector_into, build_subgraph, AdjacencyPolicy, BetaScore, Buckets,
};
use lmc::util::bench::{black_box, Bencher};
use lmc::util::rng::Rng;

const D_HIDDEN: usize = 128;

fn main() {
    let smoke = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_SMOKE").is_ok();
    let id = if smoke { DatasetId::CoraSim } else { DatasetId::ArxivSim };
    let b = if smoke {
        Bencher { warmup_iters: 1, min_iters: 2, max_iters: 8, min_window_s: 0.05 }
    } else {
        Bencher::quick()
    };
    println!("== step breakdown (native backend, hidden d = {D_HIDDEN}, {}) ==", id.name());

    // graph, partition-contiguous relabeling, a 2-cluster batch
    let g = load(id, 0);
    let k = id.default_parts();
    let part = partition(&g.csr, &PartitionConfig::new(k, 0));
    let g = g.permute(&part.contiguous_perm());
    let per = g.n() / k;
    let batch: Vec<u32> = (0..(2 * per).min(g.n()) as u32).collect();

    // a 3-layer GCN at hidden width 128 (wider than any built-in profile,
    // to exercise the wide-d kernel paths the acceptance bar names)
    let arch = ArchInfo::gcn(3, g.d_x, D_HIDDEN, g.n_class);
    let dims = arch.dims.clone();
    let l_total = arch.l;
    let model = ModelSpec { profile: "bench".into(), arch_name: "gcn".into(), arch };
    let mut prng = Rng::new(1);
    let params = Params::init(&model.arch, &mut prng);
    let hist_dims: Vec<usize> = dims[1..l_total].to_vec();
    let history = History::new(g.n(), &hist_dims);
    let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);
    let vscale = 1.0 / n_train as f32;

    let mut rng = Rng::new(7);
    let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &mut rng)
        .expect("build_subgraph");
    let (nb, nh) = (sb.batch.len(), sb.halo.len());
    let m = nb + nh;
    println!("    batch {nb}  halo {nh}  nnz {}", sb.nnz());

    // ---- phase: sample (subgraph construction; a cache hit skips this) --
    let sample = b.run("phase/sample(build_subgraph)", || {
        let mut r = Rng::new(7);
        black_box(
            build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &mut r)
                .unwrap(),
        );
    });

    // ---- phase: gather (feature rows at step width) ---------------------
    let wide: Vec<f32> = (0..g.n() * D_HIDDEN).map(|i| (i % 23) as f32 * 0.1 - 1.1).collect();
    let stacked: Vec<u32> = sb.batch.iter().chain(sb.halo.iter()).copied().collect();
    let gather = b.run("phase/gather(rows at d=128)", || {
        black_box(lmc::sampler::gather_rows(&wide, D_HIDDEN, &stacked, m));
    });

    // ---- phase: aggregate (SpMM over the four blocks) -------------------
    let x: Vec<f32> = (0..m * D_HIDDEN).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
    let xb = &x[..nb * D_HIDDEN];
    let xh = &x[nb * D_HIDDEN..];
    let agg_naive = b.run("phase/aggregate/naive(serial spmm_acc)", || {
        let mut out = vec![0f32; m * D_HIDDEN];
        let (bpart, hpart) = out.split_at_mut(nb * D_HIDDEN);
        sb.a_bb.spmm_acc(xb, D_HIDDEN, bpart);
        sb.a_bh.spmm_acc(xh, D_HIDDEN, bpart);
        sb.a_hb.spmm_acc(xb, D_HIDDEN, hpart);
        sb.a_hh.spmm_acc(xh, D_HIDDEN, hpart);
        black_box(&out);
    });
    let agg_opt = b.run("phase/aggregate/tiled(par_spmm_acc_tiled)", || {
        let mut out = vec![0f32; m * D_HIDDEN];
        let (bpart, hpart) = out.split_at_mut(nb * D_HIDDEN);
        sb.a_bb.par_spmm_acc_tiled(xb, D_HIDDEN, 1.0, bpart);
        sb.a_bh.par_spmm_acc_tiled(xh, D_HIDDEN, 1.0, bpart);
        sb.a_hb.par_spmm_acc_tiled(xb, D_HIDDEN, 1.0, hpart);
        sb.a_hh.par_spmm_acc_tiled(xh, D_HIDDEN, 1.0, hpart);
        black_box(&out);
    });

    // ---- phase: gemm (the O(m·d²) dense-affine term) --------------------
    let w: Vec<f32> = (0..D_HIDDEN * D_HIDDEN).map(|i| (i % 19) as f32 * 0.05 - 0.45).collect();
    let gemm_naive = b.run("phase/gemm/reference(serial)", || {
        black_box(gemm::reference::matmul(&x, m, D_HIDDEN, &w, D_HIDDEN));
    });
    let gemm_opt = b.run("phase/gemm/blocked(parallel)", || {
        black_box(gemm::matmul(&x, m, D_HIDDEN, &w, D_HIDDEN));
    });

    // ---- phase: compensate (Eq. 9 convex combination on halo rows) ------
    let beta = beta_vector(&sb, 0.8, BetaScore::TwoXMinusXSquared);
    let hist_rows: Vec<f32> = (0..nh * D_HIDDEN).map(|i| (i % 11) as f32 * 0.2 - 1.0).collect();
    let compensate = b.run("phase/compensate(combine)", || {
        black_box(combine(&beta[..nh], &hist_rows, xh, nh, D_HIDDEN));
    });

    // ---- end-to-end single step -----------------------------------------
    // pre-PR configuration: reference kernels, rebuild the subgraph every
    // step, allocate every buffer
    let exec_ref = NativeExecutor::with_reference_kernels();
    let mut rng_n = Rng::new(7);
    let step_naive = b.run("step/naive(reference kernels, rebuild, alloc)", || {
        let sb_i = build_subgraph(
            &g,
            &batch,
            AdjacencyPolicy::GlobalWithHalo,
            &Buckets::unbounded(),
            &mut rng_n,
        )
        .unwrap();
        let hist_h: Vec<Vec<f32>> =
            (1..l_total).map(|l| history.gather_h(l, &sb_i.halo, sb_i.halo.len())).collect();
        let hist_v: Vec<Vec<f32>> =
            (1..l_total).map(|l| history.gather_v(l, &sb_i.halo, sb_i.halo.len())).collect();
        let beta_i = beta_vector(&sb_i, 0.8, BetaScore::TwoXMinusXSquared);
        let inputs = StepInputs {
            graph: &g,
            sb: &sb_i,
            model: &model,
            params: &params,
            hist_h,
            hist_v,
            beta: beta_i,
            bwd_scale: 1.0,
            vscale,
            grad_scale: 1.0,
            ws: None,
        };
        black_box(exec_ref.forward_backward(&inputs).unwrap());
    });
    // optimized configuration: blocked kernels, cached subgraph (Fixed-mode
    // steady state), workspace reuse with trainer-style recycling
    let exec_opt = NativeExecutor::new();
    let ws = Mutex::new(StepWorkspace::new());
    let step_opt = b.run("step/optimized(blocked, cached subgraph, workspace)", || {
        let (beta_i, hist_h, hist_v) = {
            let mut w = ws.lock().unwrap();
            let mut beta_i = w.grab(sb.bucket_h);
            beta_vector_into(&sb, 0.8, BetaScore::TwoXMinusXSquared, &mut beta_i);
            let mut hist_h: Vec<Vec<f32>> = Vec::with_capacity(l_total - 1);
            let mut hist_v: Vec<Vec<f32>> = Vec::with_capacity(l_total - 1);
            for l in 1..l_total {
                let mut buf = w.grab(sb.bucket_h * dims[l]);
                history.gather_h_into(l, &sb.halo, &mut buf);
                hist_h.push(buf);
                let mut buf = w.grab(sb.bucket_h * dims[l]);
                history.gather_v_into(l, &sb.halo, &mut buf);
                hist_v.push(buf);
            }
            (beta_i, hist_h, hist_v)
        };
        let inputs = StepInputs {
            graph: &g,
            sb: &sb,
            model: &model,
            params: &params,
            hist_h,
            hist_v,
            beta: beta_i,
            bwd_scale: 1.0,
            vscale,
            grad_scale: 1.0,
            ws: Some(&ws),
        };
        let mut outs = exec_opt.forward_backward(&inputs).unwrap();
        {
            let mut w = ws.lock().unwrap();
            let StepInputs { hist_h, hist_v, beta, .. } = inputs;
            w.put(beta);
            w.put_all(hist_h);
            w.put_all(hist_v);
            w.put_all(outs.new_h.drain(..));
            w.put_all(outs.new_v.drain(..));
            w.put_all(outs.htilde.drain(..));
        }
        black_box(&outs.grads);
    });

    let speedup = step_naive.mean_s / step_opt.mean_s;
    println!("    single-step speedup (naive/optimized): {speedup:.2}x");
    println!(
        "    workspace: {} grabs, {} misses",
        ws.lock().unwrap().grabs(),
        ws.lock().unwrap().misses()
    );

    // ---- emit BENCH_step.json at the repo root --------------------------
    let mut json = String::from("{\n  \"bench\": \"step_breakdown\",\n  \"provenance\": \"measured\",\n");
    let _ = writeln!(json, "  \"dataset\": \"{}\",", id.name());
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"d_hidden\": {D_HIDDEN},");
    let _ = writeln!(json, "  \"layers\": {l_total},");
    let _ = writeln!(json, "  \"batch\": {nb},");
    let _ = writeln!(json, "  \"halo\": {nh},");
    let _ = writeln!(json, "  \"nnz\": {},", sb.nnz());
    json.push_str("  \"phases\": {\n");
    let _ = writeln!(json, "    \"sample_s\": {:.6e},", sample.mean_s);
    let _ = writeln!(json, "    \"gather_s\": {:.6e},", gather.mean_s);
    let _ = writeln!(json, "    \"aggregate_naive_s\": {:.6e},", agg_naive.mean_s);
    let _ = writeln!(json, "    \"aggregate_s\": {:.6e},", agg_opt.mean_s);
    let _ = writeln!(json, "    \"gemm_naive_s\": {:.6e},", gemm_naive.mean_s);
    let _ = writeln!(json, "    \"gemm_s\": {:.6e},", gemm_opt.mean_s);
    let _ = writeln!(json, "    \"compensate_s\": {:.6e}", compensate.mean_s);
    json.push_str("  },\n");
    let _ = writeln!(json, "  \"step_naive_s\": {:.6e},", step_naive.mean_s);
    let _ = writeln!(json, "  \"step_optimized_s\": {:.6e},", step_opt.mean_s);
    let _ = writeln!(json, "  \"speedup_naive_over_optimized\": {speedup:.2}");
    json.push_str("}\n");
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_step.json");
    std::fs::write(path, &json).expect("write BENCH_step.json");
    println!("wrote {path}");
}
