//! Bench: per-phase train-step breakdown (sample / gather / aggregate /
//! gemm / compensate / history-gather at f32 and bf16, plus the resident
//! `history_bytes_per_node` accounting) with per-kernel
//! scalar-vs-SIMD-vs-fused timings, plus
//! the end-to-end single-step comparison across three configurations:
//!
//!   * `step_naive_s`     — serial reference kernels, rebuild-per-step,
//!     allocate-per-step (the pre-PR 2 backend);
//!   * `step_scalar_s`    — blocked scalar kernels, cached subgraph,
//!     workspace reuse (the PR 2 backend);
//!   * `step_optimized_s` — runtime-dispatched SIMD kernels + fused
//!     bias/ReLU epilogues, cached subgraph, workspace reuse (current).
//!
//! Full runs emit `BENCH_step.json` at the repo root (provenance-stamped
//! with commit + runner + SIMD level); smoke runs (`BENCH_SMOKE=1` /
//! `--quick`) emit `BENCH_step.smoke.json` so the CI perf gate can never
//! diff smoke numbers against full baselines. Pass `--write-baseline` on a
//! full run to regenerate `BENCH_baseline.json` (the committed file the CI
//! `perf-gate` job diffs against; see rust/README.md § Perf gate).

use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use lmc::backend::gemm::{self, Kernels};
use lmc::backend::native::combine;
use lmc::backend::simd::{self, SimdLevel};
use lmc::backend::{Executor, ModelSpec, NativeExecutor, StepInputs, StepWorkspace};
use lmc::checkpoint;
use lmc::config::RunConfig;
use lmc::coordinator::{params::Params, Method, Trainer};
use lmc::graph::{load, DatasetId};
use lmc::history::{HistDtype, History};
use lmc::partition::{partition, PartitionConfig};
use lmc::runtime::ArchInfo;
use lmc::sampler::{
    beta_vector, beta_vector_into, build_subgraph, AdjacencyPolicy, BetaScore, Buckets, HaloSampler,
};
use lmc::util::bench::{black_box, provenance, BenchStats, Bencher};
use lmc::util::perfgate::{GATED_METRICS, MEASURED_MAX_SLOWDOWN};
use lmc::util::rng::Rng;

const D_HIDDEN: usize = 128;

fn main() {
    let smoke = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_SMOKE").is_ok();
    let write_baseline = std::env::args().any(|a| a == "--write-baseline");
    let id = if smoke { DatasetId::CoraSim } else { DatasetId::ArxivSim };
    let b = if smoke { Bencher::smoke() } else { Bencher::quick() };
    println!(
        "== step breakdown (native backend, hidden d = {D_HIDDEN}, {}, simd = {}) ==",
        id.name(),
        simd::level().name()
    );

    // graph, partition-contiguous relabeling, a 2-cluster batch
    let g = load(id, 0);
    let k = id.default_parts();
    let part = partition(&g.csr, &PartitionConfig::new(k, 0));
    let g = g.permute(&part.contiguous_perm());
    let per = g.n() / k;
    let batch: Vec<u32> = (0..(2 * per).min(g.n()) as u32).collect();

    // a 3-layer GCN at hidden width 128 (wider than any built-in profile,
    // to exercise the wide-d kernel paths the acceptance bar names)
    let arch = ArchInfo::gcn(3, g.d_x, D_HIDDEN, g.n_class);
    let dims = arch.dims.clone();
    let l_total = arch.l;
    let model = ModelSpec { profile: "bench".into(), arch_name: "gcn".into(), arch };
    let mut prng = Rng::new(1);
    let params = Params::init(&model.arch, &mut prng);
    let hist_dims: Vec<usize> = dims[1..l_total].to_vec();
    let history = History::new(g.n(), &hist_dims);
    let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);
    let vscale = 1.0 / n_train as f32;

    let mut rng = Rng::new(7);
    let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut rng)
        .expect("build_subgraph");
    let (nb, nh) = (sb.batch.len(), sb.halo.len());
    let m = nb + nh;
    println!("    batch {nb}  halo {nh}  nnz {}", sb.nnz());

    // ---- phase: sample (subgraph construction; a cache hit skips this) --
    let sample = b.run("phase/sample(build_subgraph)", || {
        let mut r = Rng::new(7);
        black_box(
            build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut r)
                .unwrap(),
        );
    });

    // ---- phase: gather (feature rows at step width) ---------------------
    let wide: Vec<f32> = (0..g.n() * D_HIDDEN).map(|i| (i % 23) as f32 * 0.1 - 1.1).collect();
    let stacked: Vec<u32> = sb.batch.iter().chain(sb.halo.iter()).copied().collect();
    let gather = b.run("phase/gather(rows at d=128)", || {
        black_box(lmc::sampler::gather_rows(&wide, D_HIDDEN, &stacked, m));
    });

    // ---- phase: aggregate (SpMM over the four blocks) -------------------
    let scalar_ops = simd::ops(SimdLevel::Scalar);
    let auto_ops = simd::ops_auto();
    let x: Vec<f32> = (0..m * D_HIDDEN).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
    let xb = &x[..nb * D_HIDDEN];
    let xh = &x[nb * D_HIDDEN..];
    let agg_serial = b.run("phase/aggregate/serial(spmm_acc)", || {
        let mut out = vec![0f32; m * D_HIDDEN];
        let (bpart, hpart) = out.split_at_mut(nb * D_HIDDEN);
        sb.a_bb.spmm_acc(xb, D_HIDDEN, bpart);
        sb.a_bh.spmm_acc(xh, D_HIDDEN, bpart);
        sb.a_hb.spmm_acc(xb, D_HIDDEN, hpart);
        sb.a_hh.spmm_acc(xh, D_HIDDEN, hpart);
        black_box(&out);
    });
    let agg_scalar = b.run("phase/aggregate/tiled-scalar(PR2)", || {
        let mut out = vec![0f32; m * D_HIDDEN];
        let (bpart, hpart) = out.split_at_mut(nb * D_HIDDEN);
        sb.a_bb.par_spmm_acc_tiled_with(scalar_ops, xb, D_HIDDEN, 1.0, bpart);
        sb.a_bh.par_spmm_acc_tiled_with(scalar_ops, xh, D_HIDDEN, 1.0, bpart);
        sb.a_hb.par_spmm_acc_tiled_with(scalar_ops, xb, D_HIDDEN, 1.0, hpart);
        sb.a_hh.par_spmm_acc_tiled_with(scalar_ops, xh, D_HIDDEN, 1.0, hpart);
        black_box(&out);
    });
    let agg_opt = b.run(&format!("phase/aggregate/tiled-simd({})", auto_ops.level.name()), || {
        let mut out = vec![0f32; m * D_HIDDEN];
        let (bpart, hpart) = out.split_at_mut(nb * D_HIDDEN);
        sb.a_bb.par_spmm_acc_tiled(xb, D_HIDDEN, 1.0, bpart);
        sb.a_bh.par_spmm_acc_tiled(xh, D_HIDDEN, 1.0, bpart);
        sb.a_hb.par_spmm_acc_tiled(xb, D_HIDDEN, 1.0, hpart);
        sb.a_hh.par_spmm_acc_tiled(xh, D_HIDDEN, 1.0, hpart);
        black_box(&out);
    });

    // ---- phase: gemm (the O(m·d²) dense-affine term) --------------------
    let kern_scalar = Kernels::blocked_scalar();
    let kern_simd = Kernels::blocked();
    let w: Vec<f32> = (0..D_HIDDEN * D_HIDDEN).map(|i| (i % 19) as f32 * 0.05 - 0.45).collect();
    let gemm_naive = b.run("phase/gemm/reference(serial)", || {
        black_box(gemm::reference::matmul(&x, m, D_HIDDEN, &w, D_HIDDEN));
    });
    let mut zbuf = vec![0f32; m * D_HIDDEN];
    let gemm_scalar = b.run("phase/gemm/blocked-scalar(PR2)", || {
        kern_scalar.matmul_into(&mut zbuf, &x, m, D_HIDDEN, &w, D_HIDDEN);
        black_box(&zbuf);
    });
    let gemm_opt = b.run(&format!("phase/gemm/blocked-simd({})", kern_simd.simd.name()), || {
        kern_simd.matmul_into(&mut zbuf, &x, m, D_HIDDEN, &w, D_HIDDEN);
        black_box(&zbuf);
    });

    // ---- phase: fused bias+ReLU epilogue vs the unfused sequence --------
    let bias: Vec<f32> = (0..D_HIDDEN).map(|i| (i % 7) as f32 * 0.01 - 0.02).collect();
    let mut actbuf = vec![0f32; m * D_HIDDEN];
    let gemm_unfused = b.run("phase/gemm/bias-relu-unfused", || {
        kern_simd.matmul_bias_into(&mut zbuf, &x, m, D_HIDDEN, &w, D_HIDDEN, &bias);
        actbuf.copy_from_slice(&zbuf);
        for v in actbuf.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        black_box(&actbuf);
    });
    let gemm_fused = b.run("phase/gemm/bias-relu-fused", || {
        kern_simd
            .matmul_bias_relu_into(&mut zbuf, &mut actbuf, &x, m, D_HIDDEN, &w, D_HIDDEN, &bias);
        black_box(&actbuf);
    });

    // ---- phase: compensate (Eq. 9 convex combination on halo rows) ------
    let beta = beta_vector(&sb, 0.8, BetaScore::TwoXMinusXSquared);
    let hist_rows: Vec<f32> = (0..nh * D_HIDDEN).map(|i| (i % 11) as f32 * 0.2 - 1.0).collect();
    let compensate = b.run("phase/compensate(combine)", || {
        black_box(combine(&beta[..nh], &hist_rows, xh, nh, D_HIDDEN));
    });

    // ---- phase: history gather (halo reads through the dtype seam) ------
    // identical row data in an f32 store and a bf16 store; the bf16 path
    // decodes on the fly (dequant-fused gather) so it moves half the bytes
    // per halo row and never round-trips through a full-width scratch
    let hist_src: Vec<f32> = (0..g.n() * D_HIDDEN).map(|i| (i % 17) as f32 * 0.1 - 0.8).collect();
    let mut hist_f32 = History::with_dtype(g.n(), &hist_dims, HistDtype::F32);
    let mut hist_bf16 = History::with_dtype(g.n(), &hist_dims, HistDtype::Bf16);
    hist_f32.fill_h(1, &hist_src);
    hist_bf16.fill_h(1, &hist_src);
    let mut hbuf = vec![0f32; nh * D_HIDDEN];
    let hist_gather_f32 = b.run("phase/history-gather/f32", || {
        hist_f32.gather_h_into(1, &sb.halo, &mut hbuf);
        black_box(&hbuf);
    });
    let hist_gather_bf16 = b.run("phase/history-gather/bf16(dequant-fused)", || {
        hist_bf16.gather_h_into(1, &sb.halo, &mut hbuf);
        black_box(&hbuf);
    });
    let bpn_f32 = hist_f32.bytes_per_node();
    let bpn_bf16 = hist_bf16.bytes_per_node();
    println!(
        "    history bytes/node: {bpn_f32} f32, {bpn_bf16} bf16 ({:.2}x gather)",
        hist_gather_f32.mean_s / hist_gather_bf16.mean_s
    );

    // ---- end-to-end single step -----------------------------------------
    // pre-PR 2 configuration: reference kernels, rebuild the subgraph every
    // step, allocate every buffer
    let exec_ref = NativeExecutor::with_reference_kernels();
    let mut rng_n = Rng::new(7);
    let step_naive = b.run("step/naive(reference kernels, rebuild, alloc)", || {
        let sb_i = build_subgraph(
            &g,
            &batch,
            AdjacencyPolicy::GlobalWithHalo,
            &Buckets::unbounded(),
            &HaloSampler::none(),
            &mut rng_n,
        )
        .unwrap();
        let hist_h: Vec<Vec<f32>> =
            (1..l_total).map(|l| history.gather_h(l, &sb_i.halo, sb_i.halo.len())).collect();
        let hist_v: Vec<Vec<f32>> =
            (1..l_total).map(|l| history.gather_v(l, &sb_i.halo, sb_i.halo.len())).collect();
        let beta_i = beta_vector(&sb_i, 0.8, BetaScore::TwoXMinusXSquared);
        let inputs = StepInputs {
            graph: &g,
            sb: &sb_i,
            model: &model,
            params: &params,
            hist_h,
            hist_v,
            beta: beta_i,
            bwd_scale: 1.0,
            vscale,
            grad_scale: 1.0,
            top: None,
            ws: None,
        };
        black_box(exec_ref.forward_backward(&inputs).unwrap());
    });
    // cached-subgraph configurations (Fixed-mode steady state, workspace
    // reuse with trainer-style recycling), parameterized by kernel family
    type Ws = Mutex<StepWorkspace>;
    let run_cached_step = |exec: &NativeExecutor, ws: &Ws, name: &str| -> BenchStats {
        b.run(name, || {
            let (beta_i, hist_h, hist_v) = {
                let mut w = ws.lock().unwrap();
                let mut beta_i = w.grab(sb.bucket_h);
                beta_vector_into(&sb, 0.8, BetaScore::TwoXMinusXSquared, &mut beta_i);
                let mut hist_h: Vec<Vec<f32>> = Vec::with_capacity(l_total - 1);
                let mut hist_v: Vec<Vec<f32>> = Vec::with_capacity(l_total - 1);
                for l in 1..l_total {
                    let mut buf = w.grab(sb.bucket_h * dims[l]);
                    history.gather_h_into(l, &sb.halo, &mut buf);
                    hist_h.push(buf);
                    let mut buf = w.grab(sb.bucket_h * dims[l]);
                    history.gather_v_into(l, &sb.halo, &mut buf);
                    hist_v.push(buf);
                }
                (beta_i, hist_h, hist_v)
            };
            let inputs = StepInputs {
                graph: &g,
                sb: &sb,
                model: &model,
                params: &params,
                hist_h,
                hist_v,
                beta: beta_i,
                bwd_scale: 1.0,
                vscale,
                grad_scale: 1.0,
                top: None,
                ws: Some(ws),
            };
            let mut outs = exec.forward_backward(&inputs).unwrap();
            {
                let mut w = ws.lock().unwrap();
                let StepInputs { hist_h, hist_v, beta, .. } = inputs;
                w.put(beta);
                w.put_all(hist_h);
                w.put_all(hist_v);
                w.put_all(outs.new_h.drain(..));
                w.put_all(outs.new_v.drain(..));
                w.put_all(outs.htilde.drain(..));
            }
            black_box(&outs.grads);
        })
    };
    let exec_scalar = NativeExecutor::with_kernels(Kernels::blocked_scalar());
    let ws_scalar = Mutex::new(StepWorkspace::new());
    let step_scalar =
        run_cached_step(&exec_scalar, &ws_scalar, "step/blocked-scalar(PR2: cached, workspace)");
    let exec_opt = NativeExecutor::new();
    let ws = Mutex::new(StepWorkspace::new());
    let step_opt = run_cached_step(
        &exec_opt,
        &ws,
        &format!("step/optimized(simd {} + fused, cached, workspace)", simd::level().name()),
    );

    let speedup = step_naive.mean_s / step_opt.mean_s;
    let speedup_scalar = step_scalar.mean_s / step_opt.mean_s;
    println!("    single-step speedup (naive/optimized):  {speedup:.2}x");
    println!("    single-step speedup (scalar/optimized): {speedup_scalar:.2}x");
    {
        // one guard for both reads: two ws.lock() temporaries in a single
        // statement would coexist until the statement ends and self-deadlock
        let w = ws.lock().unwrap();
        println!("    workspace: {} grabs, {} misses", w.grabs(), w.misses());
    }

    // ---- checkpoint IO (informational; never part of the perf gate) -----
    // one LMCCKPT1 save/load cycle of a warm cora-sim trainer: the cost a
    // `checkpoint_every = 1` cadence adds per epoch boundary
    let ckpt_dir = std::env::temp_dir().join(format!("lmc_bench_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let ckpt_cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method: Method::Lmc,
        epochs: 1,
        eval_every: usize::MAX,
        seed: 1,
        ..Default::default()
    };
    let mut ckpt_t = Trainer::new(Arc::new(NativeExecutor::new()), ckpt_cfg.clone()).unwrap();
    ckpt_t.train_epoch().expect("warm trainer for checkpoint bench");
    let ckpt_state = checkpoint::TrainerState::capture(&ckpt_t);
    let ckpt_fp = checkpoint::config_fingerprint(&ckpt_cfg);
    let ckpt_run = checkpoint::RunState { epochs_done: 1, metrics: Default::default() };
    let ckpt_save = b.run("phase/checkpoint-save(atomic: tmp, fsync, rename)", || {
        checkpoint::save(&ckpt_dir, &ckpt_fp, 1, std::slice::from_ref(&ckpt_state), &ckpt_run)
            .expect("checkpoint save");
    });
    let ckpt_load = b.run("phase/checkpoint-load(verify + decode)", || {
        black_box(checkpoint::load(&ckpt_dir, &ckpt_fp, 1).expect("checkpoint load"));
    });
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    // ---- emit BENCH_step[.smoke].json at the repo root ------------------
    let prov = provenance();
    let mut json = String::from("{\n  \"bench\": \"step_breakdown\",\n");
    let _ = writeln!(json, "  \"provenance\": \"{prov}\",");
    let _ = writeln!(json, "  \"dataset\": \"{}\",", id.name());
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"simd_level\": \"{}\",", simd::level().name());
    let _ = writeln!(json, "  \"d_hidden\": {D_HIDDEN},");
    let _ = writeln!(json, "  \"layers\": {l_total},");
    let _ = writeln!(json, "  \"batch\": {nb},");
    let _ = writeln!(json, "  \"halo\": {nh},");
    let _ = writeln!(json, "  \"nnz\": {},", sb.nnz());
    json.push_str("  \"phases\": {\n");
    let _ = writeln!(json, "    \"sample_s\": {:.6e},", sample.mean_s);
    let _ = writeln!(json, "    \"gather_s\": {:.6e},", gather.mean_s);
    let _ = writeln!(json, "    \"aggregate_serial_s\": {:.6e},", agg_serial.mean_s);
    let _ = writeln!(json, "    \"aggregate_scalar_s\": {:.6e},", agg_scalar.mean_s);
    let _ = writeln!(json, "    \"aggregate_s\": {:.6e},", agg_opt.mean_s);
    let _ = writeln!(json, "    \"gemm_naive_s\": {:.6e},", gemm_naive.mean_s);
    let _ = writeln!(json, "    \"gemm_scalar_s\": {:.6e},", gemm_scalar.mean_s);
    let _ = writeln!(json, "    \"gemm_s\": {:.6e},", gemm_opt.mean_s);
    let _ = writeln!(json, "    \"gemm_bias_relu_unfused_s\": {:.6e},", gemm_unfused.mean_s);
    let _ = writeln!(json, "    \"gemm_bias_relu_fused_s\": {:.6e},", gemm_fused.mean_s);
    let _ = writeln!(json, "    \"compensate_s\": {:.6e},", compensate.mean_s);
    let _ = writeln!(json, "    \"history_gather_f32_s\": {:.6e},", hist_gather_f32.mean_s);
    let _ = writeln!(json, "    \"history_gather_bf16_s\": {:.6e}", hist_gather_bf16.mean_s);
    json.push_str("  },\n");
    // the gated bytes/node figure is the quantized (bf16) store — the
    // memory claim this round makes; the *_f32/_bf16 variants document both
    let _ = writeln!(json, "  \"history_bytes_per_node\": {bpn_bf16},");
    let _ = writeln!(json, "  \"history_bytes_per_node_f32\": {bpn_f32},");
    let _ = writeln!(json, "  \"history_bytes_per_node_bf16\": {bpn_bf16},");
    // informational only — checkpoint cadence cost; never a gated metric
    let _ = writeln!(json, "  \"checkpoint_save_s\": {:.6e},", ckpt_save.mean_s);
    let _ = writeln!(json, "  \"checkpoint_load_s\": {:.6e},", ckpt_load.mean_s);
    let _ = writeln!(json, "  \"step_naive_s\": {:.6e},", step_naive.mean_s);
    let _ = writeln!(json, "  \"step_scalar_s\": {:.6e},", step_scalar.mean_s);
    let _ = writeln!(json, "  \"step_optimized_s\": {:.6e},", step_opt.mean_s);
    let _ = writeln!(json, "  \"speedup_naive_over_optimized\": {speedup:.2},");
    let _ = writeln!(json, "  \"speedup_scalar_over_optimized\": {speedup_scalar:.2}");
    json.push_str("}\n");
    let fname = if smoke { "/../BENCH_step.smoke.json" } else { "/../BENCH_step.json" };
    let path = format!("{}{}", env!("CARGO_MANIFEST_DIR"), fname);
    std::fs::write(&path, &json).expect("write BENCH_step json");
    println!("wrote {path}");

    // ---- optionally regenerate the committed perf-gate baseline ---------
    if write_baseline {
        if smoke {
            println!("--write-baseline ignored: smoke numbers must never become a gate baseline");
        } else {
            let mut base = String::from("{\n  \"bench\": \"step_breakdown_baseline\",\n");
            let _ = writeln!(base, "  \"provenance\": \"{prov}\",");
            let _ = writeln!(base, "  \"dataset\": \"{}\",", id.name());
            let _ = writeln!(base, "  \"d_hidden\": {D_HIDDEN},");
            let _ = writeln!(base, "  \"layers\": {l_total},");
            let metrics = GATED_METRICS
                .iter()
                .map(|m| format!("\"{m}\""))
                .collect::<Vec<_>>()
                .join(", ");
            // measured baselines compare like-for-like on the same runner
            // class, so they carry the tightened noise band
            let _ = writeln!(base, "  \"gate\": {{");
            let _ = writeln!(base, "    \"max_slowdown\": {MEASURED_MAX_SLOWDOWN},");
            let _ = writeln!(base, "    \"metrics\": [{metrics}]");
            base.push_str("  },\n");
            base.push_str("  \"metrics\": {\n");
            let _ = writeln!(base, "    \"gemm_s\": {:.6e},", gemm_opt.mean_s);
            let _ = writeln!(base, "    \"aggregate_s\": {:.6e},", agg_opt.mean_s);
            let _ = writeln!(base, "    \"step_optimized_s\": {:.6e},", step_opt.mean_s);
            let _ = writeln!(base, "    \"history_gather_f32_s\": {:.6e},", hist_gather_f32.mean_s);
            let _ =
                writeln!(base, "    \"history_gather_bf16_s\": {:.6e},", hist_gather_bf16.mean_s);
            let _ = writeln!(base, "    \"history_bytes_per_node\": {bpn_bf16}");
            base.push_str("  }\n}\n");
            let bpath = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_baseline.json");
            std::fs::write(bpath, &base).expect("write BENCH_baseline.json");
            println!("wrote {bpath} (commit it to move the perf-gate baseline)");
        }
    }
}
