//! Runtime-dispatched, SIMD-explicit elementwise primitives for the native
//! backend's hot loops (GEMM microkernels, SpMM row loops, the fused
//! bias/ReLU/residual epilogues, the Eq. 9/12 convex combination, and the
//! bf16 history-row decode).
//!
//! Four dispatch levels:
//!
//!   * [`SimdLevel::Avx512`] — 16-wide f32 `std::arch` AVX-512F on x86_64,
//!     selected at runtime via `is_x86_feature_detected!("avx512f")`;
//!   * [`SimdLevel::Avx2Fma`] — 8-wide f32 AVX2 + FMA on x86_64 (also the
//!     fallback when `avx512` is requested on hardware without it);
//!   * [`SimdLevel::Neon`] — 8-wide (2 × 4-lane) NEON on aarch64;
//!   * [`SimdLevel::Scalar`] — the portable scalar kernels, bit-identical
//!     to the pre-SIMD blocked kernels. Always available; the property-test
//!     oracle the SIMD paths are pinned against
//!     (`tests/proptest_invariants.rs`, ≤ 1e-5).
//!
//! Dispatch is a [`SimdOps`] table of plain `fn` pointers resolved once per
//! kernel invocation (`Kernels::ops()` / [`ops_auto`]), so inner loops pay
//! one indirect call per row/panel, not per element.
//!
//! Numerics contract: every vector lane and every scalar tail of the
//! accumulating primitives computes `fma(a, x, acc)` with a single rounding
//! (`f32::mul_add` in the tails), so results are **independent of vector
//! width, tile boundaries, and slice alignment** — the serial and tiled
//! SpMM paths stay bitwise equal to each other at any level, and `axpy2`
//! (the register-blocked row-pair rank-1 update) is bitwise equal to two
//! `axpy` calls at every level. Relative to the scalar level, FMA removes
//! one rounding per multiply-add (≤ 1 ulp per op); only `dot` additionally
//! reassociates (multiple accumulators). `widen_bf16` is exact at every
//! level (bf16 → f32 widening is a bit shift, never a rounding). Force the
//! scalar level with `LMC_SIMD=scalar` to reproduce pre-SIMD bits exactly
//! (see rust/README.md § Kernel dispatch).

use std::sync::OnceLock;

/// Which SIMD instruction family the dispatched primitives use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// 16-wide AVX-512F (x86_64, runtime-detected).
    Avx512,
    /// 8-wide AVX2 + FMA (x86_64, runtime-detected).
    Avx2Fma,
    /// 2 × 4-lane NEON (aarch64).
    Neon,
    /// Portable scalar kernels (fallback + property-test oracle).
    Scalar,
}

impl SimdLevel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Avx2Fma => "avx2+fma",
            SimdLevel::Neon => "neon",
            SimdLevel::Scalar => "scalar",
        }
    }
}

// Per-family runtime support checks, cfg-duplicated so non-matching
// architectures compile them to a constant `false` (the avx512 bodies
// themselves are cfg-gated off non-x86_64 entirely — see the CI
// check-aarch64 lane).
#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}
#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn avx512_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx512f") && avx2_supported()
}
#[cfg(not(target_arch = "x86_64"))]
fn avx512_supported() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_supported() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn neon_supported() -> bool {
    false
}

/// Whether the running hardware can execute `level`'s instruction family.
pub fn supported(level: SimdLevel) -> bool {
    match level {
        SimdLevel::Avx512 => avx512_supported(),
        SimdLevel::Avx2Fma => avx2_supported(),
        SimdLevel::Neon => neon_supported(),
        SimdLevel::Scalar => true,
    }
}

/// Parse + validate an `LMC_SIMD` request. `Ok(None)` means "auto"
/// (hardware detection); `Ok(Some(level))` is an honored explicit request;
/// `Err` carries a clear message for an unknown name or a level the running
/// hardware cannot execute — an explicit request is never silently
/// downgraded (the silent avx512 → avx2 fallback applies only to
/// *hardware-detected* dispatch, see [`ops`]).
pub fn requested_level(s: &str) -> Result<Option<SimdLevel>, String> {
    let lvl = match s.to_ascii_lowercase().as_str() {
        "" | "auto" => return Ok(None),
        "scalar" | "off" | "0" => SimdLevel::Scalar,
        "avx2" | "avx2+fma" => SimdLevel::Avx2Fma,
        "avx512" => SimdLevel::Avx512,
        "neon" => SimdLevel::Neon,
        other => {
            return Err(format!(
                "unknown SIMD level '{other}' (expected auto|scalar|avx2|avx512|neon)"
            ))
        }
    };
    if !supported(lvl) {
        return Err(format!(
            "requested SIMD level '{}' is not supported on this hardware (best available: '{}')",
            lvl.name(),
            hw_level().name()
        ));
    }
    Ok(Some(lvl))
}

/// Best level the running hardware supports (no env override).
pub fn hw_level() -> SimdLevel {
    if avx512_supported() {
        return SimdLevel::Avx512;
    }
    if avx2_supported() {
        return SimdLevel::Avx2Fma;
    }
    if neon_supported() {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// The process-wide dispatch level: hardware detection, overridden by
/// `LMC_SIMD=scalar|avx2|avx512|neon` (an explicit request; panics with a
/// clear message when the name is unknown or the hardware cannot execute
/// the requested family, rather than silently running something else).
/// Cached after first use.
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("LMC_SIMD") {
        Ok(v) => match requested_level(&v) {
            Ok(Some(lvl)) => lvl,
            Ok(None) => hw_level(),
            Err(e) => panic!("LMC_SIMD: {e}"),
        },
        Err(_) => hw_level(),
    })
}

/// Dispatch table of the elementwise primitives the kernels hot-loop over.
/// All slice-length mismatches resolve to the shortest operand.
#[derive(Clone, Copy)]
pub struct SimdOps {
    pub level: SimdLevel,
    /// `dst[i] += a * src[i]` — the GEMM/SpMM accumulation inner loop.
    pub axpy: fn(&mut [f32], &[f32], f32),
    /// `dst0[i] += a0 * src[i]; dst1[i] += a1 * src[i]` — the
    /// register-blocked rank-1 update across an output-row pair: `src` is
    /// loaded once per lane and fed to both accumulator rows. Bitwise equal
    /// to two `axpy` calls at every level.
    pub axpy2: fn(&mut [f32], &mut [f32], &[f32], f32, f32),
    /// `dst[i] = a * src[i]` — the GCNII `α·h0` residual prefill.
    pub scale: fn(&mut [f32], &[f32], f32),
    /// Dot product (reassociates across accumulators) — the N/T kernel.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `act[i] = max(z[i], 0)` — the fused bias+ReLU epilogue pass.
    pub relu_copy: fn(&mut [f32], &[f32]),
    /// `z[i] = (1-g)·s[i] + g·z[i]; act[i] = max(z[i], 0)` — the fused
    /// GCNII residual-mix + ReLU epilogue (`z` holds `s @ W` on entry).
    pub mix_relu: fn(&mut [f32], &mut [f32], &[f32], f32),
    /// `out[i] = (1-b)·hist[i] + b·fresh[i]` — one Eq. 9/12 row.
    pub combine: fn(&mut [f32], &[f32], &[f32], f32),
    /// `dst[i] = f32::from_bits((src[i] as u32) << 16)` — the bf16 → f32
    /// history-row decode, fused into the halo gather so half-width rows
    /// widen straight into the destination buffer (exact, no rounding).
    pub widen_bf16: fn(&mut [f32], &[u16]),
}

/// The ops table for `level`. A level the running hardware cannot execute
/// degrades along the ladder ([`SimdLevel::Avx512`] → [`SimdLevel::Avx2Fma`]
/// → [`SimdLevel::Scalar`]) so a deserialized or hard-coded level can never
/// dispatch into unsupported instructions.
pub fn ops(level: SimdLevel) -> &'static SimdOps {
    #[cfg(target_arch = "x86_64")]
    {
        if level == SimdLevel::Avx512 && avx512_supported() {
            return &AVX512_OPS;
        }
        if (level == SimdLevel::Avx512 || level == SimdLevel::Avx2Fma) && avx2_supported() {
            return &AVX2_OPS;
        }
    }
    #[cfg(target_arch = "aarch64")]
    if level == SimdLevel::Neon && neon_supported() {
        return &NEON_OPS;
    }
    let _ = level;
    &SCALAR_OPS
}

/// The ops table for the process-wide [`level`].
pub fn ops_auto() -> &'static SimdOps {
    ops(level())
}

// ---------------------------------------------------------------------------
// scalar (portable fallback + oracle)
// ---------------------------------------------------------------------------

static SCALAR_OPS: SimdOps = SimdOps {
    level: SimdLevel::Scalar,
    axpy: scalar::axpy,
    axpy2: scalar::axpy2,
    scale: scalar::scale,
    dot: scalar::dot,
    relu_copy: scalar::relu_copy,
    mix_relu: scalar::mix_relu,
    combine: scalar::combine,
    widen_bf16: scalar::widen_bf16,
};

mod scalar {
    pub fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        for (d, &s) in dst[..n].iter_mut().zip(&src[..n]) {
            *d += a * s;
        }
    }

    /// Row-pair rank-1 update; per element identical to two `axpy` passes
    /// (same plain mul+add), so pairing never changes scalar-level bits.
    pub fn axpy2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
        let n = dst0.len().min(dst1.len()).min(src.len());
        for ((d0, d1), &s) in dst0[..n].iter_mut().zip(dst1[..n].iter_mut()).zip(&src[..n]) {
            *d0 += a0 * s;
            *d1 += a1 * s;
        }
    }

    pub fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        for (d, &s) in dst[..n].iter_mut().zip(&src[..n]) {
            *d = a * s;
        }
    }

    /// 4-way unrolled dot product (independent accumulators for ILP) — the
    /// pre-SIMD N/T kernel inner loop, retained verbatim.
    pub fn dot(x: &[f32], y: &[f32]) -> f32 {
        let len = x.len().min(y.len());
        let n4 = len - len % 4;
        let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
        let mut i = 0;
        while i < n4 {
            a0 += x[i] * y[i];
            a1 += x[i + 1] * y[i + 1];
            a2 += x[i + 2] * y[i + 2];
            a3 += x[i + 3] * y[i + 3];
            i += 4;
        }
        let mut s = (a0 + a1) + (a2 + a3);
        while i < len {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    pub fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        for (a, &v) in act[..n].iter_mut().zip(&z[..n]) {
            *a = if v > 0.0 { v } else { 0.0 };
        }
    }

    pub fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let (zs, acts) = (&mut z[..n], &mut act[..n]);
        for ((zv, av), &sv) in zs.iter_mut().zip(acts.iter_mut()).zip(&s[..n]) {
            let m = (1.0 - gam) * sv + gam * *zv;
            *zv = m;
            *av = if m > 0.0 { m } else { 0.0 };
        }
    }

    pub fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        for ((o, &h), &f) in out[..n].iter_mut().zip(&hist[..n]).zip(&fresh[..n]) {
            *o = (1.0 - b) * h + b * f;
        }
    }

    /// The bf16 decode oracle: widening is exact (bf16 is the upper half of
    /// an f32's bits), so every SIMD level must match this **bitwise**.
    pub fn widen_bf16(dst: &mut [f32], src: &[u16]) {
        let n = dst.len().min(src.len());
        for (d, &s) in dst[..n].iter_mut().zip(&src[..n]) {
            *d = f32::from_bits((s as u32) << 16);
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2_OPS: SimdOps = SimdOps {
    level: SimdLevel::Avx2Fma,
    axpy: axpy_avx2,
    axpy2: axpy2_avx2,
    scale: scale_avx2,
    dot: dot_avx2,
    relu_copy: relu_copy_avx2,
    mix_relu: mix_relu_avx2,
    combine: combine_avx2,
    widen_bf16: widen_bf16_avx2,
};

// Safe shims. SAFETY (all eight): these fn pointers are only installed in
// `AVX2_OPS`, which `ops()` returns only after `is_x86_feature_detected!`
// confirmed avx2+fma on the running CPU.
#[cfg(target_arch = "x86_64")]
fn axpy_avx2(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { x86::axpy(dst, src, a) }
}
#[cfg(target_arch = "x86_64")]
fn axpy2_avx2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
    unsafe { x86::axpy2(dst0, dst1, src, a0, a1) }
}
#[cfg(target_arch = "x86_64")]
fn scale_avx2(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { x86::scale(dst, src, a) }
}
#[cfg(target_arch = "x86_64")]
fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    unsafe { x86::dot(x, y) }
}
#[cfg(target_arch = "x86_64")]
fn relu_copy_avx2(act: &mut [f32], z: &[f32]) {
    unsafe { x86::relu_copy(act, z) }
}
#[cfg(target_arch = "x86_64")]
fn mix_relu_avx2(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
    unsafe { x86::mix_relu(z, act, s, gam) }
}
#[cfg(target_arch = "x86_64")]
fn combine_avx2(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
    unsafe { x86::combine(out, hist, fresh, b) }
}
#[cfg(target_arch = "x86_64")]
fn widen_bf16_avx2(dst: &mut [f32], src: &[u16]) {
    unsafe { x86::widen_bf16(dst, src) }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! 8-wide AVX2/FMA bodies. Every `fn` here requires avx2+fma at
    //! runtime; they are reachable only through the `AVX2_OPS` table.

    use core::arch::x86_64::*;

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dp.add(i));
            let s = _mm256_loadu_ps(sp.add(i));
            _mm256_storeu_ps(dp.add(i), _mm256_fmadd_ps(av, s, d));
            i += 8;
        }
        while i < n {
            *dp.add(i) = a.mul_add(*sp.add(i), *dp.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
        let n = dst0.len().min(dst1.len()).min(src.len());
        let d0p = dst0.as_mut_ptr();
        let d1p = dst1.as_mut_ptr();
        let sp = src.as_ptr();
        let a0v = _mm256_set1_ps(a0);
        let a1v = _mm256_set1_ps(a1);
        let mut i = 0usize;
        while i + 8 <= n {
            let s = _mm256_loadu_ps(sp.add(i));
            let d0 = _mm256_loadu_ps(d0p.add(i));
            let d1 = _mm256_loadu_ps(d1p.add(i));
            _mm256_storeu_ps(d0p.add(i), _mm256_fmadd_ps(a0v, s, d0));
            _mm256_storeu_ps(d1p.add(i), _mm256_fmadd_ps(a1v, s, d1));
            i += 8;
        }
        while i < n {
            let s = *sp.add(i);
            *d0p.add(i) = a0.mul_add(s, *d0p.add(i));
            *d1p.add(i) = a1.mul_add(s, *d1p.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(av, _mm256_loadu_ps(sp.add(i))));
            i += 8;
        }
        while i < n {
            *dp.add(i) = a * *sp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 8)),
                _mm256_loadu_ps(yp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut total = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
        while i < n {
            total = (*xp.add(i)).mul_add(*yp.add(i), total);
            i += 1;
        }
        total
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        let ap = act.as_mut_ptr();
        let zp = z.as_ptr();
        let zero = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(ap.add(i), _mm256_max_ps(_mm256_loadu_ps(zp.add(i)), zero));
            i += 8;
        }
        while i < n {
            let v = *zp.add(i);
            *ap.add(i) = if v > 0.0 { v } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let zp = z.as_mut_ptr();
        let ap = act.as_mut_ptr();
        let sp = s.as_ptr();
        let g = _mm256_set1_ps(gam);
        let omg = _mm256_set1_ps(1.0 - gam);
        let zero = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let zv = _mm256_loadu_ps(zp.add(i));
            let sv = _mm256_loadu_ps(sp.add(i));
            let mixed = _mm256_fmadd_ps(g, zv, _mm256_mul_ps(omg, sv));
            _mm256_storeu_ps(zp.add(i), mixed);
            _mm256_storeu_ps(ap.add(i), _mm256_max_ps(mixed, zero));
            i += 8;
        }
        while i < n {
            let m = gam.mul_add(*zp.add(i), (1.0 - gam) * *sp.add(i));
            *zp.add(i) = m;
            *ap.add(i) = if m > 0.0 { m } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        let op = out.as_mut_ptr();
        let hp = hist.as_ptr();
        let fp = fresh.as_ptr();
        let bv = _mm256_set1_ps(b);
        let omb = _mm256_set1_ps(1.0 - b);
        let mut i = 0usize;
        while i + 8 <= n {
            let hv = _mm256_loadu_ps(hp.add(i));
            let fv = _mm256_loadu_ps(fp.add(i));
            _mm256_storeu_ps(op.add(i), _mm256_fmadd_ps(bv, fv, _mm256_mul_ps(omb, hv)));
            i += 8;
        }
        while i < n {
            *op.add(i) = b.mul_add(*fp.add(i), (1.0 - b) * *hp.add(i));
            i += 1;
        }
    }

    /// bf16 → f32 widen: zero-extend 8 u16 lanes to u32, shift into the
    /// high half, bit-cast to f32 (exact — must match the scalar oracle
    /// bitwise).
    ///
    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn widen_bf16(dst: &mut [f32], src: &[u16]) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let h = _mm_loadu_si128(sp.add(i) as *const __m128i);
            let w = _mm256_slli_epi32(_mm256_cvtepu16_epi32(h), 16);
            _mm256_storeu_ps(dp.add(i), _mm256_castsi256_ps(w));
            i += 8;
        }
        while i < n {
            *dp.add(i) = f32::from_bits((*sp.add(i) as u32) << 16);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX-512F (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX512_OPS: SimdOps = SimdOps {
    level: SimdLevel::Avx512,
    axpy: axpy_avx512,
    axpy2: axpy2_avx512,
    scale: scale_avx512,
    dot: dot_avx512,
    relu_copy: relu_copy_avx512,
    mix_relu: mix_relu_avx512,
    combine: combine_avx512,
    widen_bf16: widen_bf16_avx512,
};

// Safe shims. SAFETY (all eight): these fn pointers are only installed in
// `AVX512_OPS`, which `ops()` returns only after `is_x86_feature_detected!`
// confirmed avx512f (plus avx2+fma for the sub-width loops) on the running
// CPU.
#[cfg(target_arch = "x86_64")]
fn axpy_avx512(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { x86_512::axpy(dst, src, a) }
}
#[cfg(target_arch = "x86_64")]
fn axpy2_avx512(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
    unsafe { x86_512::axpy2(dst0, dst1, src, a0, a1) }
}
#[cfg(target_arch = "x86_64")]
fn scale_avx512(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { x86_512::scale(dst, src, a) }
}
#[cfg(target_arch = "x86_64")]
fn dot_avx512(x: &[f32], y: &[f32]) -> f32 {
    unsafe { x86_512::dot(x, y) }
}
#[cfg(target_arch = "x86_64")]
fn relu_copy_avx512(act: &mut [f32], z: &[f32]) {
    unsafe { x86_512::relu_copy(act, z) }
}
#[cfg(target_arch = "x86_64")]
fn mix_relu_avx512(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
    unsafe { x86_512::mix_relu(z, act, s, gam) }
}
#[cfg(target_arch = "x86_64")]
fn combine_avx512(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
    unsafe { x86_512::combine(out, hist, fresh, b) }
}
#[cfg(target_arch = "x86_64")]
fn widen_bf16_avx512(dst: &mut [f32], src: &[u16]) {
    unsafe { x86_512::widen_bf16(dst, src) }
}

#[cfg(target_arch = "x86_64")]
mod x86_512 {
    //! 16-wide AVX-512F bodies (stable `_mm512_*` intrinsics). Every `fn`
    //! here requires avx512f (+ avx2+fma for the 8-wide sub-loops) at
    //! runtime; they are reachable only through the `AVX512_OPS` table.
    //! Same numerics contract as the avx2 bodies: single-rounded fma in
    //! every lane and every scalar tail, so results are independent of
    //! vector width.

    use core::arch::x86_64::*;

    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = _mm512_set1_ps(a);
        let mut i = 0usize;
        while i + 16 <= n {
            let d = _mm512_loadu_ps(dp.add(i));
            let s = _mm512_loadu_ps(sp.add(i));
            _mm512_storeu_ps(dp.add(i), _mm512_fmadd_ps(av, s, d));
            i += 16;
        }
        if i + 8 <= n {
            let av8 = _mm256_set1_ps(a);
            let d = _mm256_loadu_ps(dp.add(i));
            let s = _mm256_loadu_ps(sp.add(i));
            _mm256_storeu_ps(dp.add(i), _mm256_fmadd_ps(av8, s, d));
            i += 8;
        }
        while i < n {
            *dp.add(i) = a.mul_add(*sp.add(i), *dp.add(i));
            i += 1;
        }
    }

    /// Register-blocked rank-1 update across a row pair: one 16-wide load
    /// of `src` feeds two fma accumulator rows, halving panel-row traffic.
    ///
    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn axpy2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
        let n = dst0.len().min(dst1.len()).min(src.len());
        let d0p = dst0.as_mut_ptr();
        let d1p = dst1.as_mut_ptr();
        let sp = src.as_ptr();
        let a0v = _mm512_set1_ps(a0);
        let a1v = _mm512_set1_ps(a1);
        let mut i = 0usize;
        while i + 16 <= n {
            let s = _mm512_loadu_ps(sp.add(i));
            let d0 = _mm512_loadu_ps(d0p.add(i));
            let d1 = _mm512_loadu_ps(d1p.add(i));
            _mm512_storeu_ps(d0p.add(i), _mm512_fmadd_ps(a0v, s, d0));
            _mm512_storeu_ps(d1p.add(i), _mm512_fmadd_ps(a1v, s, d1));
            i += 16;
        }
        while i < n {
            let s = *sp.add(i);
            *d0p.add(i) = a0.mul_add(s, *d0p.add(i));
            *d1p.add(i) = a1.mul_add(s, *d1p.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = _mm512_set1_ps(a);
        let mut i = 0usize;
        while i + 16 <= n {
            _mm512_storeu_ps(dp.add(i), _mm512_mul_ps(av, _mm512_loadu_ps(sp.add(i))));
            i += 16;
        }
        while i < n {
            *dp.add(i) = a * *sp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = _mm512_setzero_ps();
        let mut acc1 = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm512_fmadd_ps(_mm512_loadu_ps(xp.add(i)), _mm512_loadu_ps(yp.add(i)), acc0);
            acc1 = _mm512_fmadd_ps(
                _mm512_loadu_ps(xp.add(i + 16)),
                _mm512_loadu_ps(yp.add(i + 16)),
                acc1,
            );
            i += 32;
        }
        while i + 16 <= n {
            acc0 = _mm512_fmadd_ps(_mm512_loadu_ps(xp.add(i)), _mm512_loadu_ps(yp.add(i)), acc0);
            i += 16;
        }
        let mut total = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
        while i < n {
            total = (*xp.add(i)).mul_add(*yp.add(i), total);
            i += 1;
        }
        total
    }

    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        let ap = act.as_mut_ptr();
        let zp = z.as_ptr();
        let zero = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            _mm512_storeu_ps(ap.add(i), _mm512_max_ps(_mm512_loadu_ps(zp.add(i)), zero));
            i += 16;
        }
        while i < n {
            let v = *zp.add(i);
            *ap.add(i) = if v > 0.0 { v } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let zp = z.as_mut_ptr();
        let ap = act.as_mut_ptr();
        let sp = s.as_ptr();
        let g = _mm512_set1_ps(gam);
        let omg = _mm512_set1_ps(1.0 - gam);
        let zero = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let zv = _mm512_loadu_ps(zp.add(i));
            let sv = _mm512_loadu_ps(sp.add(i));
            let mixed = _mm512_fmadd_ps(g, zv, _mm512_mul_ps(omg, sv));
            _mm512_storeu_ps(zp.add(i), mixed);
            _mm512_storeu_ps(ap.add(i), _mm512_max_ps(mixed, zero));
            i += 16;
        }
        while i < n {
            let m = gam.mul_add(*zp.add(i), (1.0 - gam) * *sp.add(i));
            *zp.add(i) = m;
            *ap.add(i) = if m > 0.0 { m } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        let op = out.as_mut_ptr();
        let hp = hist.as_ptr();
        let fp = fresh.as_ptr();
        let bv = _mm512_set1_ps(b);
        let omb = _mm512_set1_ps(1.0 - b);
        let mut i = 0usize;
        while i + 16 <= n {
            let hv = _mm512_loadu_ps(hp.add(i));
            let fv = _mm512_loadu_ps(fp.add(i));
            _mm512_storeu_ps(op.add(i), _mm512_fmadd_ps(bv, fv, _mm512_mul_ps(omb, hv)));
            i += 16;
        }
        while i < n {
            *op.add(i) = b.mul_add(*fp.add(i), (1.0 - b) * *hp.add(i));
            i += 1;
        }
    }

    /// bf16 → f32 widen, 16 lanes per iteration: zero-extend 16 u16 lanes
    /// to u32 (`vpmovzxwd zmm, ymm`, avx512f), shift into the high half,
    /// bit-cast to f32 (exact — must match the scalar oracle bitwise).
    ///
    /// # Safety
    /// Requires avx512f + avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx512f,avx2,fma")]
    pub unsafe fn widen_bf16(dst: &mut [f32], src: &[u16]) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let h = _mm256_loadu_si256(sp.add(i) as *const __m256i);
            let w = _mm512_slli_epi32(_mm512_cvtepu16_epi32(h), 16);
            _mm512_storeu_ps(dp.add(i), _mm512_castsi512_ps(w));
            i += 16;
        }
        while i < n {
            *dp.add(i) = f32::from_bits((*sp.add(i) as u32) << 16);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON_OPS: SimdOps = SimdOps {
    level: SimdLevel::Neon,
    axpy: axpy_neon,
    axpy2: axpy2_neon,
    scale: scale_neon,
    dot: dot_neon,
    relu_copy: relu_copy_neon,
    mix_relu: mix_relu_neon,
    combine: combine_neon,
    widen_bf16: widen_bf16_neon,
};

// Safe shims. SAFETY (all eight): installed only in `NEON_OPS`, which
// `ops()` returns only after `is_aarch64_feature_detected!("neon")`.
#[cfg(target_arch = "aarch64")]
fn axpy_neon(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { neon::axpy(dst, src, a) }
}
#[cfg(target_arch = "aarch64")]
fn axpy2_neon(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
    unsafe { neon::axpy2(dst0, dst1, src, a0, a1) }
}
#[cfg(target_arch = "aarch64")]
fn scale_neon(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { neon::scale(dst, src, a) }
}
#[cfg(target_arch = "aarch64")]
fn dot_neon(x: &[f32], y: &[f32]) -> f32 {
    unsafe { neon::dot(x, y) }
}
#[cfg(target_arch = "aarch64")]
fn relu_copy_neon(act: &mut [f32], z: &[f32]) {
    unsafe { neon::relu_copy(act, z) }
}
#[cfg(target_arch = "aarch64")]
fn mix_relu_neon(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
    unsafe { neon::mix_relu(z, act, s, gam) }
}
#[cfg(target_arch = "aarch64")]
fn combine_neon(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
    unsafe { neon::combine(out, hist, fresh, b) }
}
#[cfg(target_arch = "aarch64")]
fn widen_bf16_neon(dst: &mut [f32], src: &[u16]) {
    unsafe { neon::widen_bf16(dst, src) }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! 8-wide (2 × 4-lane) NEON bodies; reachable only through `NEON_OPS`.

    use core::arch::aarch64::*;

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let d0 = vld1q_f32(dp.add(i));
            let d1 = vld1q_f32(dp.add(i + 4));
            let s0 = vld1q_f32(sp.add(i));
            let s1 = vld1q_f32(sp.add(i + 4));
            vst1q_f32(dp.add(i), vfmaq_f32(d0, av, s0));
            vst1q_f32(dp.add(i + 4), vfmaq_f32(d1, av, s1));
            i += 8;
        }
        while i + 4 <= n {
            let d = vld1q_f32(dp.add(i));
            let s = vld1q_f32(sp.add(i));
            vst1q_f32(dp.add(i), vfmaq_f32(d, av, s));
            i += 4;
        }
        while i < n {
            *dp.add(i) = a.mul_add(*sp.add(i), *dp.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], a0: f32, a1: f32) {
        let n = dst0.len().min(dst1.len()).min(src.len());
        let d0p = dst0.as_mut_ptr();
        let d1p = dst1.as_mut_ptr();
        let sp = src.as_ptr();
        let a0v = vdupq_n_f32(a0);
        let a1v = vdupq_n_f32(a1);
        let mut i = 0usize;
        while i + 4 <= n {
            let s = vld1q_f32(sp.add(i));
            let d0 = vld1q_f32(d0p.add(i));
            let d1 = vld1q_f32(d1p.add(i));
            vst1q_f32(d0p.add(i), vfmaq_f32(d0, a0v, s));
            vst1q_f32(d1p.add(i), vfmaq_f32(d1, a1v, s));
            i += 4;
        }
        while i < n {
            let s = *sp.add(i);
            *d0p.add(i) = a0.mul_add(s, *d0p.add(i));
            *d1p.add(i) = a1.mul_add(s, *d1p.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(dp.add(i), vmulq_f32(av, vld1q_f32(sp.add(i))));
            i += 4;
        }
        while i < n {
            *dp.add(i) = a * *sp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(xp.add(i + 4)), vld1q_f32(yp.add(i + 4)));
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i)));
            i += 4;
        }
        let mut total = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            total = (*xp.add(i)).mul_add(*yp.add(i), total);
            i += 1;
        }
        total
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        let ap = act.as_mut_ptr();
        let zp = z.as_ptr();
        let zero = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(ap.add(i), vmaxq_f32(vld1q_f32(zp.add(i)), zero));
            i += 4;
        }
        while i < n {
            let v = *zp.add(i);
            *ap.add(i) = if v > 0.0 { v } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let zp = z.as_mut_ptr();
        let ap = act.as_mut_ptr();
        let sp = s.as_ptr();
        let g = vdupq_n_f32(gam);
        let omg = vdupq_n_f32(1.0 - gam);
        let zero = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let zv = vld1q_f32(zp.add(i));
            let sv = vld1q_f32(sp.add(i));
            let mixed = vfmaq_f32(vmulq_f32(omg, sv), g, zv);
            vst1q_f32(zp.add(i), mixed);
            vst1q_f32(ap.add(i), vmaxq_f32(mixed, zero));
            i += 4;
        }
        while i < n {
            let m = gam.mul_add(*zp.add(i), (1.0 - gam) * *sp.add(i));
            *zp.add(i) = m;
            *ap.add(i) = if m > 0.0 { m } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        let op = out.as_mut_ptr();
        let hp = hist.as_ptr();
        let fp = fresh.as_ptr();
        let bv = vdupq_n_f32(b);
        let omb = vdupq_n_f32(1.0 - b);
        let mut i = 0usize;
        while i + 4 <= n {
            let hv = vld1q_f32(hp.add(i));
            let fv = vld1q_f32(fp.add(i));
            vst1q_f32(op.add(i), vfmaq_f32(vmulq_f32(omb, hv), bv, fv));
            i += 4;
        }
        while i < n {
            *op.add(i) = b.mul_add(*fp.add(i), (1.0 - b) * *hp.add(i));
            i += 1;
        }
    }

    /// bf16 → f32 widen: zero-extend 2 × 4 u16 lanes to u32, shift into
    /// the high half, bit-cast to f32 (exact — must match the scalar
    /// oracle bitwise).
    ///
    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn widen_bf16(dst: &mut [f32], src: &[u16]) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let h = vld1q_u16(sp.add(i));
            let lo = vshlq_n_u32::<16>(vmovl_u16(vget_low_u16(h)));
            let hi = vshlq_n_u32::<16>(vmovl_u16(vget_high_u16(h)));
            vst1q_f32(dp.add(i), vreinterpretq_f32_u32(lo));
            vst1q_f32(dp.add(i + 4), vreinterpretq_f32_u32(hi));
            i += 8;
        }
        while i < n {
            *dp.add(i) = f32::from_bits((*sp.add(i) as u32) << 16);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requested_level_honors_explicit_requests_or_errors_clearly() {
        // auto sentinels
        assert_eq!(requested_level(""), Ok(None));
        assert_eq!(requested_level("auto"), Ok(None));
        // scalar is always supported, under every historical alias
        assert_eq!(requested_level("scalar"), Ok(Some(SimdLevel::Scalar)));
        assert_eq!(requested_level("OFF"), Ok(Some(SimdLevel::Scalar)));
        assert_eq!(requested_level("0"), Ok(Some(SimdLevel::Scalar)));
        // unknown names error with the accepted vocabulary
        let err = requested_level("avx1024").unwrap_err();
        assert!(err.contains("avx1024") && err.contains("avx512"), "{err}");
        // explicit avx2/avx512/neon requests: honored exactly when the
        // hardware supports them, a clear error otherwise — never a silent
        // downgrade
        for (name, lvl) in [
            ("avx2", SimdLevel::Avx2Fma),
            ("avx2+fma", SimdLevel::Avx2Fma),
            ("AVX512", SimdLevel::Avx512),
            ("neon", SimdLevel::Neon),
        ] {
            match requested_level(name) {
                Ok(got) => {
                    assert!(supported(lvl), "honored '{name}' without hardware support");
                    assert_eq!(got, Some(lvl));
                }
                Err(e) => {
                    assert!(!supported(lvl), "rejected supported level '{name}': {e}");
                    assert!(e.contains(lvl.name()), "{e}");
                    assert!(e.contains(hw_level().name()), "{e}");
                }
            }
        }
    }

    #[test]
    fn hw_level_is_supported_and_tops_the_ladder() {
        let hw = hw_level();
        assert!(supported(hw));
        // hw_level never under-reports: if avx512 is supported it is picked
        if supported(SimdLevel::Avx512) {
            assert_eq!(hw, SimdLevel::Avx512);
        } else if supported(SimdLevel::Avx2Fma) {
            assert_eq!(hw, SimdLevel::Avx2Fma);
        }
    }

    #[test]
    fn ops_degrades_unsupported_levels_along_the_ladder() {
        assert_eq!(ops(SimdLevel::Scalar).level, SimdLevel::Scalar);
        // an avx512 request on avx2-only hardware runs the avx2 table; on
        // non-x86 it runs scalar — never unsupported instructions
        let lvl = ops(SimdLevel::Avx512).level;
        if supported(SimdLevel::Avx512) {
            assert_eq!(lvl, SimdLevel::Avx512);
        } else if supported(SimdLevel::Avx2Fma) {
            assert_eq!(lvl, SimdLevel::Avx2Fma);
        } else {
            assert_eq!(lvl, SimdLevel::Scalar);
        }
    }

    #[test]
    fn ops_auto_matches_level() {
        assert_eq!(ops_auto().level, ops(level()).level);
        // the scalar table is always reachable
        assert_eq!(ops(SimdLevel::Scalar).level, SimdLevel::Scalar);
    }

    /// Small-integer values make every product/sum exact in f32, so the
    /// active level must agree with scalar **bitwise** regardless of FMA.
    #[test]
    fn active_level_exact_on_integer_values() {
        let active = ops_auto();
        let scalar = ops(SimdLevel::Scalar);
        let src: Vec<f32> = (0..37).map(|i| (i % 7) as f32 - 3.0).collect();
        let base: Vec<f32> = (0..37).map(|i| (i % 5) as f32).collect();

        let mut a1 = base.clone();
        (active.axpy)(&mut a1, &src, 2.0);
        let mut a2 = base.clone();
        (scalar.axpy)(&mut a2, &src, 2.0);
        assert_eq!(a1, a2);

        let mut s1 = vec![0f32; 37];
        (active.scale)(&mut s1, &src, -1.5);
        let mut s2 = vec![0f32; 37];
        (scalar.scale)(&mut s2, &src, -1.5);
        assert_eq!(s1, s2);

        assert_eq!((active.dot)(&src, &base), (scalar.dot)(&src, &base));

        let mut r1 = vec![7f32; 37];
        (active.relu_copy)(&mut r1, &src);
        assert!(r1.iter().zip(&src).all(|(&r, &z)| r == if z > 0.0 { z } else { 0.0 }));
    }

    /// `axpy2` must be bitwise equal to two `axpy` calls at every level —
    /// that is the contract that lets the GEMM pair rows without changing
    /// results (odd length exercises the scalar tails).
    #[test]
    fn axpy2_is_bitwise_two_axpys() {
        for lvl in [SimdLevel::Avx512, SimdLevel::Avx2Fma, SimdLevel::Neon, SimdLevel::Scalar] {
            let t = ops(lvl);
            let src: Vec<f32> = (0..37).map(|i| (i as f32) * 0.17 - 3.0).collect();
            let base0: Vec<f32> = (0..37).map(|i| (i as f32) * 0.05 - 1.0).collect();
            let base1: Vec<f32> = (0..37).map(|i| (i as f32) * -0.03 + 0.5).collect();
            let (mut p0, mut p1) = (base0.clone(), base1.clone());
            (t.axpy2)(&mut p0, &mut p1, &src, 0.7, -1.3);
            let (mut q0, mut q1) = (base0, base1);
            (t.axpy)(&mut q0, &src, 0.7);
            (t.axpy)(&mut q1, &src, -1.3);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&p0), bits(&q0), "level {}", t.level.name());
            assert_eq!(bits(&p1), bits(&q1), "level {}", t.level.name());
        }
    }

    /// bf16 widening is exact, so every level must match the scalar oracle
    /// bitwise — including NaN payloads, infinities, and signed zeros.
    #[test]
    fn widen_bf16_matches_scalar_bitwise_at_every_level() {
        let mut src: Vec<u16> = (0..997u32).map(|i| (i.wrapping_mul(2654435761) >> 16) as u16).collect();
        src.extend_from_slice(&[0x0000, 0x8000, 0x7F80, 0xFF80, 0x7FC1, 0x0001, 0x3F80]);
        for lvl in [SimdLevel::Avx512, SimdLevel::Avx2Fma, SimdLevel::Neon, SimdLevel::Scalar] {
            let t = ops(lvl);
            let mut got = vec![0f32; src.len()];
            (t.widen_bf16)(&mut got, &src);
            for (g, &s) in got.iter().zip(&src) {
                assert_eq!(g.to_bits(), (s as u32) << 16, "level {}", t.level.name());
            }
        }
    }

    #[test]
    fn mix_relu_and_combine_formulas() {
        let ops = ops(SimdLevel::Scalar);
        let mut z = vec![2.0f32, -4.0, 8.0];
        let mut act = vec![0f32; 3];
        let s = vec![4.0f32, 4.0, -16.0];
        // gam = 0.5: z' = 0.5*s + 0.5*z = [3, 0, -4]
        (ops.mix_relu)(&mut z, &mut act, &s, 0.5);
        assert_eq!(z, vec![3.0, 0.0, -4.0]);
        assert_eq!(act, vec![3.0, 0.0, 0.0]);

        let mut out = vec![0f32; 2];
        (ops.combine)(&mut out, &[4.0, 8.0], &[0.0, 0.0], 0.25);
        assert_eq!(out, vec![3.0, 6.0]);
    }

    #[test]
    fn length_mismatch_resolves_to_shortest() {
        let ops = ops_auto();
        let mut dst = vec![1f32; 10];
        (ops.axpy)(&mut dst, &[1.0, 1.0, 1.0], 1.0);
        assert_eq!(&dst[..3], &[2.0, 2.0, 2.0]);
        assert!(dst[3..].iter().all(|&v| v == 1.0));
        assert_eq!((ops.dot)(&[1.0, 2.0], &[3.0, 4.0, 100.0]), 11.0);
        let mut short = vec![0f32; 3];
        (ops.widen_bf16)(&mut short, &[0x3F80u16; 8]);
        assert_eq!(short, vec![1.0, 1.0, 1.0]);
    }
}
