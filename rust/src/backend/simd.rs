//! Runtime-dispatched, SIMD-explicit elementwise primitives for the native
//! backend's hot loops (GEMM microkernels, SpMM row loops, the fused
//! bias/ReLU/residual epilogues, and the Eq. 9/12 convex combination).
//!
//! Three dispatch levels:
//!
//!   * [`SimdLevel::Avx2Fma`] — 8-wide f32 `std::arch` AVX2 + FMA on
//!     x86_64, selected at runtime via `is_x86_feature_detected!`;
//!   * [`SimdLevel::Neon`] — 8-wide (2 × 4-lane) NEON on aarch64;
//!   * [`SimdLevel::Scalar`] — the portable scalar kernels, bit-identical
//!     to the pre-SIMD blocked kernels. Always available; the property-test
//!     oracle the SIMD paths are pinned against
//!     (`tests/proptest_invariants.rs`, ≤ 1e-5).
//!
//! Dispatch is a [`SimdOps`] table of plain `fn` pointers resolved once per
//! kernel invocation (`Kernels::ops()` / [`ops_auto`]), so inner loops pay
//! one indirect call per row/panel, not per element.
//!
//! Numerics contract: every vector lane and every scalar tail of the
//! accumulating primitives computes `fma(a, x, acc)` with a single rounding
//! (`f32::mul_add` in the tails), so results are **independent of vector
//! width, tile boundaries, and slice alignment** — the serial and tiled
//! SpMM paths stay bitwise equal to each other at any level. Relative to
//! the scalar level, FMA removes one rounding per multiply-add (≤ 1 ulp per
//! op); only `dot` additionally reassociates (multiple accumulators). Force
//! the scalar level with `LMC_SIMD=scalar` to reproduce pre-SIMD bits
//! exactly (see rust/README.md § Kernel dispatch).

use std::sync::OnceLock;

/// Which SIMD instruction family the dispatched primitives use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// 8-wide AVX2 + FMA (x86_64, runtime-detected).
    Avx2Fma,
    /// 2 × 4-lane NEON (aarch64).
    Neon,
    /// Portable scalar kernels (fallback + property-test oracle).
    Scalar,
}

impl SimdLevel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Avx2Fma => "avx2+fma",
            SimdLevel::Neon => "neon",
            SimdLevel::Scalar => "scalar",
        }
    }
}

/// Parse the `LMC_SIMD` env knob. Only an explicit request for the scalar
/// path is honored ("scalar" / "off" / "0"); anything else means "auto".
pub fn parse_level(s: &str) -> Option<SimdLevel> {
    match s.to_ascii_lowercase().as_str() {
        "scalar" | "off" | "0" => Some(SimdLevel::Scalar),
        _ => None,
    }
}

/// Best level the running hardware supports (no env override).
pub fn hw_level() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return SimdLevel::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Scalar
}

/// The process-wide dispatch level: hardware detection, overridden by
/// `LMC_SIMD=scalar` (forces the portable scalar kernels — for debugging
/// and for A/B timing outside the in-process bench handles). Cached after
/// first use.
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        if let Ok(v) = std::env::var("LMC_SIMD") {
            if parse_level(&v) == Some(SimdLevel::Scalar) {
                return SimdLevel::Scalar;
            }
        }
        hw_level()
    })
}

/// Dispatch table of the elementwise primitives the kernels hot-loop over.
/// All slice-length mismatches resolve to the shortest operand.
#[derive(Clone, Copy)]
pub struct SimdOps {
    pub level: SimdLevel,
    /// `dst[i] += a * src[i]` — the GEMM/SpMM accumulation inner loop.
    pub axpy: fn(&mut [f32], &[f32], f32),
    /// `dst[i] = a * src[i]` — the GCNII `α·h0` residual prefill.
    pub scale: fn(&mut [f32], &[f32], f32),
    /// Dot product (reassociates across accumulators) — the N/T kernel.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `act[i] = max(z[i], 0)` — the fused bias+ReLU epilogue pass.
    pub relu_copy: fn(&mut [f32], &[f32]),
    /// `z[i] = (1-g)·s[i] + g·z[i]; act[i] = max(z[i], 0)` — the fused
    /// GCNII residual-mix + ReLU epilogue (`z` holds `s @ W` on entry).
    pub mix_relu: fn(&mut [f32], &mut [f32], &[f32], f32),
    /// `out[i] = (1-b)·hist[i] + b·fresh[i]` — one Eq. 9/12 row.
    pub combine: fn(&mut [f32], &[f32], &[f32], f32),
}

/// The ops table for `level`, falling back to scalar when the requested
/// level is not supported by the running hardware (so a deserialized or
/// hard-coded level can never dispatch into unsupported instructions).
pub fn ops(level: SimdLevel) -> &'static SimdOps {
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2Fma && hw_level() == SimdLevel::Avx2Fma {
        return &AVX2_OPS;
    }
    #[cfg(target_arch = "aarch64")]
    if level == SimdLevel::Neon && hw_level() == SimdLevel::Neon {
        return &NEON_OPS;
    }
    let _ = level;
    &SCALAR_OPS
}

/// The ops table for the process-wide [`level`].
pub fn ops_auto() -> &'static SimdOps {
    ops(level())
}

// ---------------------------------------------------------------------------
// scalar (portable fallback + oracle)
// ---------------------------------------------------------------------------

static SCALAR_OPS: SimdOps = SimdOps {
    level: SimdLevel::Scalar,
    axpy: scalar::axpy,
    scale: scalar::scale,
    dot: scalar::dot,
    relu_copy: scalar::relu_copy,
    mix_relu: scalar::mix_relu,
    combine: scalar::combine,
};

mod scalar {
    pub fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        for (d, &s) in dst[..n].iter_mut().zip(&src[..n]) {
            *d += a * s;
        }
    }

    pub fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        for (d, &s) in dst[..n].iter_mut().zip(&src[..n]) {
            *d = a * s;
        }
    }

    /// 4-way unrolled dot product (independent accumulators for ILP) — the
    /// pre-SIMD N/T kernel inner loop, retained verbatim.
    pub fn dot(x: &[f32], y: &[f32]) -> f32 {
        let len = x.len().min(y.len());
        let n4 = len - len % 4;
        let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
        let mut i = 0;
        while i < n4 {
            a0 += x[i] * y[i];
            a1 += x[i + 1] * y[i + 1];
            a2 += x[i + 2] * y[i + 2];
            a3 += x[i + 3] * y[i + 3];
            i += 4;
        }
        let mut s = (a0 + a1) + (a2 + a3);
        while i < len {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    pub fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        for (a, &v) in act[..n].iter_mut().zip(&z[..n]) {
            *a = if v > 0.0 { v } else { 0.0 };
        }
    }

    pub fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let (zs, acts) = (&mut z[..n], &mut act[..n]);
        for ((zv, av), &sv) in zs.iter_mut().zip(acts.iter_mut()).zip(&s[..n]) {
            let m = (1.0 - gam) * sv + gam * *zv;
            *zv = m;
            *av = if m > 0.0 { m } else { 0.0 };
        }
    }

    pub fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        for ((o, &h), &f) in out[..n].iter_mut().zip(&hist[..n]).zip(&fresh[..n]) {
            *o = (1.0 - b) * h + b * f;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2_OPS: SimdOps = SimdOps {
    level: SimdLevel::Avx2Fma,
    axpy: axpy_avx2,
    scale: scale_avx2,
    dot: dot_avx2,
    relu_copy: relu_copy_avx2,
    mix_relu: mix_relu_avx2,
    combine: combine_avx2,
};

// Safe shims. SAFETY (all six): these fn pointers are only installed in
// `AVX2_OPS`, which `ops()` returns only after `is_x86_feature_detected!`
// confirmed avx2+fma on the running CPU.
#[cfg(target_arch = "x86_64")]
fn axpy_avx2(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { x86::axpy(dst, src, a) }
}
#[cfg(target_arch = "x86_64")]
fn scale_avx2(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { x86::scale(dst, src, a) }
}
#[cfg(target_arch = "x86_64")]
fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    unsafe { x86::dot(x, y) }
}
#[cfg(target_arch = "x86_64")]
fn relu_copy_avx2(act: &mut [f32], z: &[f32]) {
    unsafe { x86::relu_copy(act, z) }
}
#[cfg(target_arch = "x86_64")]
fn mix_relu_avx2(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
    unsafe { x86::mix_relu(z, act, s, gam) }
}
#[cfg(target_arch = "x86_64")]
fn combine_avx2(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
    unsafe { x86::combine(out, hist, fresh, b) }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! 8-wide AVX2/FMA bodies. Every `fn` here requires avx2+fma at
    //! runtime; they are reachable only through the `AVX2_OPS` table.

    use core::arch::x86_64::*;

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dp.add(i));
            let s = _mm256_loadu_ps(sp.add(i));
            _mm256_storeu_ps(dp.add(i), _mm256_fmadd_ps(av, s, d));
            i += 8;
        }
        while i < n {
            *dp.add(i) = a.mul_add(*sp.add(i), *dp.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(av, _mm256_loadu_ps(sp.add(i))));
            i += 8;
        }
        while i < n {
            *dp.add(i) = a * *sp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 8)),
                _mm256_loadu_ps(yp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut total = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
        while i < n {
            total = (*xp.add(i)).mul_add(*yp.add(i), total);
            i += 1;
        }
        total
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        let ap = act.as_mut_ptr();
        let zp = z.as_ptr();
        let zero = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(ap.add(i), _mm256_max_ps(_mm256_loadu_ps(zp.add(i)), zero));
            i += 8;
        }
        while i < n {
            let v = *zp.add(i);
            *ap.add(i) = if v > 0.0 { v } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let zp = z.as_mut_ptr();
        let ap = act.as_mut_ptr();
        let sp = s.as_ptr();
        let g = _mm256_set1_ps(gam);
        let omg = _mm256_set1_ps(1.0 - gam);
        let zero = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let zv = _mm256_loadu_ps(zp.add(i));
            let sv = _mm256_loadu_ps(sp.add(i));
            let mixed = _mm256_fmadd_ps(g, zv, _mm256_mul_ps(omg, sv));
            _mm256_storeu_ps(zp.add(i), mixed);
            _mm256_storeu_ps(ap.add(i), _mm256_max_ps(mixed, zero));
            i += 8;
        }
        while i < n {
            let m = gam.mul_add(*zp.add(i), (1.0 - gam) * *sp.add(i));
            *zp.add(i) = m;
            *ap.add(i) = if m > 0.0 { m } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires avx2 + fma (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        let op = out.as_mut_ptr();
        let hp = hist.as_ptr();
        let fp = fresh.as_ptr();
        let bv = _mm256_set1_ps(b);
        let omb = _mm256_set1_ps(1.0 - b);
        let mut i = 0usize;
        while i + 8 <= n {
            let hv = _mm256_loadu_ps(hp.add(i));
            let fv = _mm256_loadu_ps(fp.add(i));
            _mm256_storeu_ps(op.add(i), _mm256_fmadd_ps(bv, fv, _mm256_mul_ps(omb, hv)));
            i += 8;
        }
        while i < n {
            *op.add(i) = b.mul_add(*fp.add(i), (1.0 - b) * *hp.add(i));
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON_OPS: SimdOps = SimdOps {
    level: SimdLevel::Neon,
    axpy: axpy_neon,
    scale: scale_neon,
    dot: dot_neon,
    relu_copy: relu_copy_neon,
    mix_relu: mix_relu_neon,
    combine: combine_neon,
};

// Safe shims. SAFETY (all six): installed only in `NEON_OPS`, which `ops()`
// returns only after `is_aarch64_feature_detected!("neon")`.
#[cfg(target_arch = "aarch64")]
fn axpy_neon(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { neon::axpy(dst, src, a) }
}
#[cfg(target_arch = "aarch64")]
fn scale_neon(dst: &mut [f32], src: &[f32], a: f32) {
    unsafe { neon::scale(dst, src, a) }
}
#[cfg(target_arch = "aarch64")]
fn dot_neon(x: &[f32], y: &[f32]) -> f32 {
    unsafe { neon::dot(x, y) }
}
#[cfg(target_arch = "aarch64")]
fn relu_copy_neon(act: &mut [f32], z: &[f32]) {
    unsafe { neon::relu_copy(act, z) }
}
#[cfg(target_arch = "aarch64")]
fn mix_relu_neon(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
    unsafe { neon::mix_relu(z, act, s, gam) }
}
#[cfg(target_arch = "aarch64")]
fn combine_neon(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
    unsafe { neon::combine(out, hist, fresh, b) }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! 8-wide (2 × 4-lane) NEON bodies; reachable only through `NEON_OPS`.

    use core::arch::aarch64::*;

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let d0 = vld1q_f32(dp.add(i));
            let d1 = vld1q_f32(dp.add(i + 4));
            let s0 = vld1q_f32(sp.add(i));
            let s1 = vld1q_f32(sp.add(i + 4));
            vst1q_f32(dp.add(i), vfmaq_f32(d0, av, s0));
            vst1q_f32(dp.add(i + 4), vfmaq_f32(d1, av, s1));
            i += 8;
        }
        while i + 4 <= n {
            let d = vld1q_f32(dp.add(i));
            let s = vld1q_f32(sp.add(i));
            vst1q_f32(dp.add(i), vfmaq_f32(d, av, s));
            i += 4;
        }
        while i < n {
            *dp.add(i) = a.mul_add(*sp.add(i), *dp.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn scale(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let dp = dst.as_mut_ptr();
        let sp = src.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(dp.add(i), vmulq_f32(av, vld1q_f32(sp.add(i))));
            i += 4;
        }
        while i < n {
            *dp.add(i) = a * *sp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(xp.add(i + 4)), vld1q_f32(yp.add(i + 4)));
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(xp.add(i)), vld1q_f32(yp.add(i)));
            i += 4;
        }
        let mut total = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            total = (*xp.add(i)).mul_add(*yp.add(i), total);
            i += 1;
        }
        total
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn relu_copy(act: &mut [f32], z: &[f32]) {
        let n = act.len().min(z.len());
        let ap = act.as_mut_ptr();
        let zp = z.as_ptr();
        let zero = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(ap.add(i), vmaxq_f32(vld1q_f32(zp.add(i)), zero));
            i += 4;
        }
        while i < n {
            let v = *zp.add(i);
            *ap.add(i) = if v > 0.0 { v } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn mix_relu(z: &mut [f32], act: &mut [f32], s: &[f32], gam: f32) {
        let n = z.len().min(act.len()).min(s.len());
        let zp = z.as_mut_ptr();
        let ap = act.as_mut_ptr();
        let sp = s.as_ptr();
        let g = vdupq_n_f32(gam);
        let omg = vdupq_n_f32(1.0 - gam);
        let zero = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let zv = vld1q_f32(zp.add(i));
            let sv = vld1q_f32(sp.add(i));
            let mixed = vfmaq_f32(vmulq_f32(omg, sv), g, zv);
            vst1q_f32(zp.add(i), mixed);
            vst1q_f32(ap.add(i), vmaxq_f32(mixed, zero));
            i += 4;
        }
        while i < n {
            let m = gam.mul_add(*zp.add(i), (1.0 - gam) * *sp.add(i));
            *zp.add(i) = m;
            *ap.add(i) = if m > 0.0 { m } else { 0.0 };
            i += 1;
        }
    }

    /// # Safety
    /// Requires neon (guaranteed by the dispatch in `ops()`).
    #[target_feature(enable = "neon")]
    pub unsafe fn combine(out: &mut [f32], hist: &[f32], fresh: &[f32], b: f32) {
        let n = out.len().min(hist.len()).min(fresh.len());
        let op = out.as_mut_ptr();
        let hp = hist.as_ptr();
        let fp = fresh.as_ptr();
        let bv = vdupq_n_f32(b);
        let omb = vdupq_n_f32(1.0 - b);
        let mut i = 0usize;
        while i + 4 <= n {
            let hv = vld1q_f32(hp.add(i));
            let fv = vld1q_f32(fp.add(i));
            vst1q_f32(op.add(i), vfmaq_f32(vmulq_f32(omb, hv), bv, fv));
            i += 4;
        }
        while i < n {
            *op.add(i) = b.mul_add(*fp.add(i), (1.0 - b) * *hp.add(i));
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_only_forces_scalar() {
        assert_eq!(parse_level("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(parse_level("OFF"), Some(SimdLevel::Scalar));
        assert_eq!(parse_level("0"), Some(SimdLevel::Scalar));
        assert_eq!(parse_level("auto"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level("avx512"), None);
    }

    #[test]
    fn ops_auto_matches_level() {
        assert_eq!(ops_auto().level, ops(level()).level);
        // the scalar table is always reachable
        assert_eq!(ops(SimdLevel::Scalar).level, SimdLevel::Scalar);
    }

    /// Small-integer values make every product/sum exact in f32, so the
    /// active level must agree with scalar **bitwise** regardless of FMA.
    #[test]
    fn active_level_exact_on_integer_values() {
        let active = ops_auto();
        let scalar = ops(SimdLevel::Scalar);
        let src: Vec<f32> = (0..21).map(|i| (i % 7) as f32 - 3.0).collect();
        let base: Vec<f32> = (0..21).map(|i| (i % 5) as f32).collect();

        let mut a1 = base.clone();
        (active.axpy)(&mut a1, &src, 2.0);
        let mut a2 = base.clone();
        (scalar.axpy)(&mut a2, &src, 2.0);
        assert_eq!(a1, a2);

        let mut s1 = vec![0f32; 21];
        (active.scale)(&mut s1, &src, -1.5);
        let mut s2 = vec![0f32; 21];
        (scalar.scale)(&mut s2, &src, -1.5);
        assert_eq!(s1, s2);

        assert_eq!((active.dot)(&src, &base), (scalar.dot)(&src, &base));

        let mut r1 = vec![7f32; 21];
        (active.relu_copy)(&mut r1, &src);
        assert!(r1.iter().zip(&src).all(|(&r, &z)| r == if z > 0.0 { z } else { 0.0 }));
    }

    #[test]
    fn mix_relu_and_combine_formulas() {
        let ops = ops(SimdLevel::Scalar);
        let mut z = vec![2.0f32, -4.0, 8.0];
        let mut act = vec![0f32; 3];
        let s = vec![4.0f32, 4.0, -16.0];
        // gam = 0.5: z' = 0.5*s + 0.5*z = [3, 0, -4]
        (ops.mix_relu)(&mut z, &mut act, &s, 0.5);
        assert_eq!(z, vec![3.0, 0.0, -4.0]);
        assert_eq!(act, vec![3.0, 0.0, 0.0]);

        let mut out = vec![0f32; 2];
        (ops.combine)(&mut out, &[4.0, 8.0], &[0.0, 0.0], 0.25);
        assert_eq!(out, vec![3.0, 6.0]);
    }

    #[test]
    fn length_mismatch_resolves_to_shortest() {
        let ops = ops_auto();
        let mut dst = vec![1f32; 10];
        (ops.axpy)(&mut dst, &[1.0, 1.0, 1.0], 1.0);
        assert_eq!(&dst[..3], &[2.0, 2.0, 2.0]);
        assert!(dst[3..].iter().all(|&v| v == 1.0));
        assert_eq!((ops.dot)(&[1.0, 2.0], &[3.0, 4.0, 100.0]), 11.0);
    }
}
