//! Reusable per-step scratch buffers.
//!
//! Every buffer a native train step needs — the stacked feature gather,
//! per-layer pre-activation / linearized-input / activation caches,
//! cotangent scratch, history gather buffers — is grabbed from a
//! [`StepWorkspace`] pool and returned when the step (and the trainer's
//! history write-back) is done. In steady state the pool has one buffer
//! per live slot, so repeated train steps perform **zero heap allocation**
//! for the O(m · d) layer buffers: `misses()` stabilizes after the first
//! epoch or two (asserted by `workspace_steady_state_has_no_new_allocations`
//! in `tests/integration_training.rs`).
//!
//! The trainer owns the workspace behind a `Mutex` and threads a reference
//! through `StepInputs::ws`; backends without a native notion of host
//! scratch (PJRT) simply ignore it, and callers that pass `ws: None` get
//! the old allocate-per-step behaviour.
//!
//! Out of scope: parameter-gradient tensors (O(d²), returned to the caller
//! for diagnostics and optimizer updates) and tiny per-step metadata
//! vectors (labels, masks, the per-layer `Vec` spines).

/// Upper bound on pooled buffers; beyond it, returned buffers are dropped.
/// A step holds well under this many buffers concurrently.
const MAX_POOL: usize = 96;

#[derive(Debug, Default)]
pub struct StepWorkspace {
    pool: Vec<Vec<f32>>,
    grabs: u64,
    misses: u64,
}

impl StepWorkspace {
    pub fn new() -> StepWorkspace {
        StepWorkspace::default()
    }

    /// Take a zeroed buffer of exactly `len` elements, reusing the pooled
    /// buffer with the smallest sufficient capacity when one exists.
    ///
    /// Required for accumulate-into destinations (`+=` aggregation,
    /// `axpy`), sparsely-written buffers (`masked_ce_into` skips unmasked
    /// rows), and padded buffers whose tail must read as zero.
    pub fn grab(&mut self, len: usize) -> Vec<f32> {
        self.grabs += 1;
        if len == 0 {
            // empty slices (no halo, degenerate dims) never allocate — and
            // must not steal a pooled buffer from an exact-size slot
            return Vec::new();
        }
        match self.take_fit(len) {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                self.misses += 1;
                vec![0f32; len]
            }
        }
    }

    /// Like [`StepWorkspace::grab`] but without the zero-fill pass: a
    /// recycled buffer keeps its stale prefix contents. Only for
    /// destinations that are fully overwritten before being read —
    /// gathers, `copy_from_slice` targets, overwrite-mode
    /// `matmul_*_into` outputs, and the fused-epilogue `z`/`act` pairs
    /// (`matmul_bias_relu_into` / `matmul_mix_relu_into` write every
    /// element of both buffers). (The repeated-step property test
    /// `prop_optimized_step_matches_reference_step` would catch a
    /// misclassified site as a round-2 divergence.)
    pub fn grab_dirty(&mut self, len: usize) -> Vec<f32> {
        self.grabs += 1;
        if len == 0 {
            return Vec::new();
        }
        match self.take_fit(len) {
            Some(mut v) => {
                // resize both grows (zeroed extension) and shrinks; the
                // reused prefix keeps whatever it last held
                v.resize(len, 0.0);
                v
            }
            None => {
                self.misses += 1;
                vec![0f32; len]
            }
        }
    }

    /// Pop the pooled buffer with the smallest capacity >= `len`.
    fn take_fit(&mut self, len: usize) -> Option<Vec<f32>> {
        let mut best: Option<(usize, usize)> = None; // (capacity, index)
        for (i, v) in self.pool.iter().enumerate() {
            let cap = v.capacity();
            let tighter = match best {
                None => true,
                Some((bc, _)) => cap < bc,
            };
            if cap >= len && tighter {
                best = Some((cap, i));
            }
        }
        best.map(|(_, i)| self.pool.swap_remove(i))
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.pool.len() < MAX_POOL {
            self.pool.push(v);
        }
    }

    /// Return a batch of buffers to the pool.
    pub fn put_all(&mut self, vs: impl IntoIterator<Item = Vec<f32>>) {
        for v in vs {
            self.put(v);
        }
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Total `grab` calls.
    pub fn grabs(&self) -> u64 {
        self.grabs
    }

    /// `grab` calls that had to heap-allocate a fresh buffer. Constant
    /// across steady-state epochs when workspace reuse works.
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grab_reuses_returned_buffers() {
        let mut ws = StepWorkspace::new();
        let a = ws.grab(100);
        assert_eq!(a.len(), 100);
        assert_eq!(ws.misses(), 1);
        ws.put(a);
        // smaller request reuses the same allocation, zeroed
        let b = ws.grab(40);
        assert_eq!(ws.misses(), 1);
        assert!(b.iter().all(|&x| x == 0.0));
        ws.put(b);
        // larger request must allocate
        let c = ws.grab(200);
        assert_eq!(ws.misses(), 2);
        ws.put(c);
    }

    #[test]
    fn grab_zeroes_previous_contents() {
        let mut ws = StepWorkspace::new();
        let mut a = ws.grab(8);
        a.iter_mut().for_each(|x| *x = 7.0);
        ws.put(a);
        let b = ws.grab(8);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn grab_dirty_reuses_without_zeroing_pass() {
        let mut ws = StepWorkspace::new();
        let mut a = ws.grab(16);
        a.iter_mut().for_each(|x| *x = 7.0);
        ws.put(a);
        // shrink-reuse: no allocation, exact length, prefix unspecified
        let b = ws.grab_dirty(8);
        assert_eq!(b.len(), 8);
        assert_eq!(ws.misses(), 1);
        ws.put(b);
        // grow-reuse within capacity: the extension past the recycled
        // length must read as zero
        let c = ws.grab_dirty(12);
        assert_eq!(c.len(), 12);
        assert_eq!(ws.misses(), 1);
        assert!(c[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn best_fit_prefers_tightest_capacity() {
        let mut ws = StepWorkspace::new();
        let small = ws.grab(10);
        let big = ws.grab(1000);
        ws.put(small);
        ws.put(big);
        let got = ws.grab(5);
        assert!(got.capacity() < 1000, "picked the oversized buffer");
        assert_eq!(ws.misses(), 2); // only the two initial allocations
    }

    #[test]
    fn steady_state_sequence_stops_missing() {
        let mut ws = StepWorkspace::new();
        let sizes = [64usize, 128, 64, 32, 256, 128];
        for _ in 0..3 {
            let held: Vec<Vec<f32>> = sizes.iter().map(|&s| ws.grab(s)).collect();
            ws.put_all(held);
        }
        let misses_after_warmup = ws.misses();
        for _ in 0..5 {
            let held: Vec<Vec<f32>> = sizes.iter().map(|&s| ws.grab(s)).collect();
            ws.put_all(held);
        }
        assert_eq!(ws.misses(), misses_after_warmup, "steady state still allocating");
    }
}
