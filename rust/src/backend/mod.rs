//! Pluggable execution backends (DESIGN.md layer L2').
//!
//! The coordinator is written against the [`Executor`] trait: one fused
//! `forward_backward` over a sampled [`SubgraphBatch`] plus the exact
//! full-graph oracle operations (evaluation / full-batch gradients). Two
//! implementations exist:
//!
//!   * [`NativeExecutor`] — pure-Rust CPU math over the sparse CSR blocks
//!     with rayon-parallel row-wise SpMM. O(nnz · d) per step, no padding,
//!     no AOT artifacts, runs everywhere. The default.
//!   * `PjrtExecutor` (`--features pjrt`) — the original AOT/HLO path: the
//!     blocks are densified on demand to the compiled bucket shapes and the
//!     PJRT `Runtime` executes the train_step / layer programs.
//!
//! Both backends implement the same LMC semantics (paper Algorithm 1):
//! forward compensation via convex combination with historical embeddings
//! (Eqs. 8-10), backward compensation of the auxiliary variables
//! (Eqs. 11-13), Eq. 7 parameter gradients from in-batch cotangents only.

pub mod gemm;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod simd;
pub mod workspace;

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::compensation::TopFit;
use crate::coordinator::exact::{EvalResult, OracleResult};
use crate::coordinator::params::Params;
use crate::graph::Graph;
use crate::runtime::{ArchInfo, ProfileInfo, Tensor};
use crate::sampler::{Buckets, SubgraphBatch};

pub use native::NativeExecutor;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtExecutor;
pub use workspace::StepWorkspace;

/// Which executor a run uses (`backend = "native" | "pjrt"` in RunConfig).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Native,
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s.to_ascii_lowercase().as_str() {
            "native" | "cpu" | "rust" => Backend::Native,
            "pjrt" | "xla" => Backend::Pjrt,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// The (profile, arch) pair a trainer executes, with resolved metadata.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub profile: String,
    pub arch_name: String,
    pub arch: ArchInfo,
}

/// Everything one fused train step consumes. History rows are gathered by
/// the caller (padded to `sb.bucket_h` rows per layer) so backends never
/// touch the mutable history store.
pub struct StepInputs<'a> {
    pub graph: &'a Graph,
    pub sb: &'a SubgraphBatch,
    pub model: &'a ModelSpec,
    pub params: &'a Params,
    /// Historical halo embeddings Hbar^l, l = 1..L-1 (`[bucket_h * d_l]`).
    pub hist_h: Vec<Vec<f32>>,
    /// Historical auxiliary variables Vbar^l, l = 1..L-1.
    pub hist_v: Vec<Vec<f32>>,
    /// Per-halo-node convex combination coefficients (`[bucket_h]`).
    pub beta: Vec<f32>,
    /// 1 = backward compensation C_b on (LMC), 0 = off (GAS/CLUSTER).
    pub bwd_scale: f32,
    /// 1/|V_train| — folds the loss normalization into V^L.
    pub vscale: f32,
    /// Cluster-sampling reweighting b/c (Eqs. 14-15).
    pub grad_scale: f32,
    /// TOP message-invariance transforms (arXiv 2502.19693). When set, the
    /// backend synthesizes halo values from fresh in-batch ones via the
    /// learned per-layer transforms instead of the Eq. 9/12 history
    /// combination (`hist_h`/`hist_v` are then zero placeholder buffers).
    pub top: Option<TopStepInputs<'a>>,
    /// Optional reusable scratch pool (owned by the trainer). Backends that
    /// support it grab every per-layer buffer from here instead of
    /// allocating; `None` restores allocate-per-step behaviour. The escaped
    /// output buffers (`new_h`/`new_v`/`htilde`) and the gather buffers in
    /// `hist_h`/`hist_v`/`beta` come from the same pool and are recycled by
    /// the trainer after history write-back.
    pub ws: Option<&'a Mutex<StepWorkspace>>,
}

/// Borrowed view of a [`crate::compensation::Top`] policy's learned
/// transforms for one step. `fwd[l-1]` is the `d_l × d_l` transform T_l
/// applied to fresh layer-`l` activations; `bwd[l-2]` is the transform S_l
/// applied to layer-`l` auxiliary cotangents. `fit` asks the backend to
/// also return the in-batch least-squares fit gradients (skipped during
/// pure measurement passes so grad-check never mutates the transforms).
pub struct TopStepInputs<'a> {
    pub fwd: &'a [Tensor],
    pub bwd: &'a [Tensor],
    pub fit: bool,
}

/// Host-visible results of one fused train step.
pub struct StepOutputs {
    /// Sum of masked training CE over in-batch nodes (unnormalized).
    pub loss_sum: f64,
    /// Count of correct training predictions over in-batch nodes.
    pub correct: f64,
    /// Parameter gradients in canonical manifest order.
    pub grads: Vec<Tensor>,
    /// Updated in-batch histories Hbar^l, l = 1..L-1 (first
    /// `batch.len()` rows are valid).
    pub new_h: Vec<Vec<f32>>,
    /// Updated in-batch auxiliary variables Vbar^l, l = 1..L-1.
    pub new_v: Vec<Vec<f32>>,
    /// Incomplete up-to-date halo values Htilde^l, l = 1..L-1 (for FM's
    /// momentum push; first `halo.len()` rows are valid).
    pub htilde: Vec<Vec<f32>>,
    /// Simulated accelerator-resident bytes for this step.
    pub active_bytes: usize,
    /// TOP transform fit gradients (present iff `StepInputs::top` was set
    /// with `fit: true`); applied by the trainer via `Compensation::fit`.
    pub top_fit: Option<TopFit>,
}

/// A pluggable execution backend: the fused subgraph train step plus the
/// exact full-graph oracle operations the coordinator needs.
pub trait Executor: Send + Sync {
    fn backend_name(&self) -> &'static str;

    /// Profile metadata (dims every program of a dataset family shares).
    fn resolve_profile(&self, profile: &str) -> Result<ProfileInfo>;

    /// Arch metadata (canonical parameter order, layer dims).
    fn resolve_arch(&self, profile: &str, arch_name: &str) -> Result<ArchInfo>;

    /// Shape buckets the sampler must pad to. Unbounded (exact fit) for
    /// backends without compiled shapes.
    fn buckets(&self, profile: &str) -> Result<Buckets>;

    /// One fused train step (forward + LMC-compensated backward) over a
    /// sampled subgraph.
    fn forward_backward(&self, inp: &StepInputs) -> Result<StepOutputs>;

    /// Exact full-graph forward: H^l for all nodes, l = 0..L (index 0 is
    /// the embed0 output).
    fn full_forward(&self, g: &Graph, params: &Params, model: &ModelSpec)
        -> Result<Vec<Vec<f32>>>;

    /// Exact full-batch gradient oracle (paper Theorem 1 with V_B = V).
    fn full_grad(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<OracleResult>;

    /// Exact evaluation: per-split accuracy + mean training loss.
    fn evaluate(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<EvalResult>;

    /// Cumulative seconds spent inside backend execution (telemetry).
    fn exec_secs(&self) -> f64 {
        0.0
    }
}

/// Build the executor selected by `cfg.backend`.
pub fn make_executor(cfg: &crate::config::RunConfig) -> Result<Arc<dyn Executor>> {
    match cfg.backend {
        Backend::Native => Ok(Arc::new(NativeExecutor::new())),
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(Arc::new(PjrtExecutor::new(std::path::Path::new(
            &cfg.artifact_dir,
        ))?)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => anyhow::bail!(
            "backend = \"pjrt\" requires building with `--features pjrt` \
             (and AOT artifacts from `make artifacts`); the default build \
             ships the native backend only"
        ),
    }
}
