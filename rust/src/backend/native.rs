//! Native CPU executor: the GCN / GCNII forward + LMC-compensated backward
//! of `python/compile/step.py`, re-implemented directly over the sampler's
//! sparse CSR blocks with blocked, rayon-parallel kernels.
//!
//! No buckets, no padding, no AOT artifacts: per-step cost is
//! O(nnz · d + m · d²) for m = |V_B| + |halo| instead of the padded
//! O(bucket² · d) the dense path pays. Semantics follow the paper exactly:
//!
//!   * forward: Eq. (8) for in-batch rows, Eq. (10) for the incomplete
//!     up-to-date halo rows, Eq. (9) convex combination with the
//!     historical embeddings (`combine`);
//!   * backward: auxiliary variables propagated through the local layer
//!     map (Eqs. 11 & 13), halo cotangents compensated with historical
//!     auxiliary variables (Eq. 12), parameter gradients from in-batch
//!     cotangents only (Eq. 7);
//!   * full-graph oracle (Theorem 1 with V_B = V): exact forward,
//!     evaluation and full-batch gradients over the global CSR.
//!
//! Aggregation operates on the *stacked* `[batch; halo]` node space with
//! the symmetric block operator `[[A_bb, A_bh], [A_bh^T, A_hh]]`, so the
//! backward aggregation reuses the forward one.
//!
//! Performance architecture (see rust/README.md § Performance):
//!
//!   * dense products run through the cache-blocked kernels in
//!     [`super::gemm`] (`Kernels::blocked()`), whose inner loops dispatch
//!     to runtime-detected SIMD ([`super::simd`]: AVX2/FMA, NEON, or the
//!     scalar fallback — `LMC_SIMD=scalar` forces the latter); the serial
//!     reference kernels remain selectable via
//!     [`NativeExecutor::with_reference_kernels`] for baselines and
//!     cross-checks;
//!   * forward layers use the fused GEMM epilogues: the pre-activation
//!     `z` and the activation `relu(z)` (plus, for GCNII, the
//!     `(1-γ)·s + γ·s@W` residual mix) are written per cache-hot row
//!     block instead of re-traversing `m · d` floats per pass, and the
//!     GCNII `α·h0` initial residual is a SIMD prefill of the
//!     aggregation destination;
//!   * aggregation accumulates *into* caller-provided buffers
//!     ([`agg_full_scaled_into`]) with feature-dim tiling for wide `d`,
//!     and the affine bias/residual terms are fused into the destination
//!     before the product/SpMM lands on it;
//!   * every O(m · d) buffer is grabbed from the [`StepWorkspace`]
//!     threaded through `StepInputs::ws`, so steady-state steps perform
//!     no per-layer heap allocation (the fused path drops the per-layer
//!     `sw` scratch buffer entirely).

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use rayon::prelude::*;

use crate::compensation::TopFit;
use crate::coordinator::exact::{acc, argmax, EvalResult, OracleResult};
use crate::coordinator::memory;
use crate::coordinator::params::Params;
use crate::graph::Graph;
use crate::runtime::{ArchInfo, ProfileInfo, Tensor};
use crate::sampler::sparse::{SPMM_D_TILE, SPMM_PAR_MIN, SPMM_ROW_BLOCK};
use crate::sampler::{gather_rows_into, Buckets, SubgraphBatch};

use super::gemm::{self, GemmMode, Kernels};
use super::simd::{self, SimdOps};
use super::workspace::StepWorkspace;
use super::{Executor, ModelSpec, StepInputs, StepOutputs};

/// GCNII hyperparameters (python/compile/spec.py profile defaults).
pub(crate) const GCNII_ALPHA: f32 = 0.1;
const GCNII_LAM: f64 = 0.5;

/// Below this many elements `combine` stays serial.
const COMBINE_PAR_MIN: usize = 1 << 14;

#[inline]
pub(crate) fn gcnii_gamma(l: usize) -> f32 {
    (GCNII_LAM / l as f64 + 1.0).ln() as f32
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Kind {
    Gcn,
    Gcnii,
}

pub(crate) fn kind_of(arch_name: &str) -> Result<Kind> {
    match arch_name {
        "gcn" => Ok(Kind::Gcn),
        "gcnii" => Ok(Kind::Gcnii),
        other => bail!("native backend: unknown arch '{other}' (expected gcn|gcnii)"),
    }
}

/// Cumulative exec-clock state: `depth` counts the *live* timed scopes
/// across every calling thread. The first scope to open records `t0`; the
/// last one to close accumulates the elapsed busy interval. Nested scopes
/// on one thread therefore count once, and concurrent scopes from many
/// threads (sharded workers, serve requests) merge into the union of busy
/// wall-clock intervals — `exec_secs` can never exceed wall time.
struct TimerState {
    secs: f64,
    depth: u32,
    t0: Instant,
}

/// RAII scope for the exec clock. Closing the scope happens in `Drop`, so
/// a panicking workload (one bad serve request out of many concurrent
/// ones) still decrements `depth` during unwind instead of wedging the
/// timer at depth > 0 and silently stopping all future accumulation.
struct TimerScope<'a> {
    timer: &'a Mutex<TimerState>,
}

impl<'a> TimerScope<'a> {
    fn enter(timer: &'a Mutex<TimerState>) -> TimerScope<'a> {
        let mut st = lock_timer(timer);
        st.depth += 1;
        if st.depth == 1 {
            st.t0 = Instant::now();
        }
        TimerScope { timer }
    }
}

impl Drop for TimerScope<'_> {
    fn drop(&mut self) {
        let mut st = lock_timer(self.timer);
        st.depth -= 1;
        if st.depth == 0 {
            st.secs += st.t0.elapsed().as_secs_f64();
        }
    }
}

/// Lock the timer even when a previous holder panicked: the state is a
/// counter plus two plain numbers, always consistent at lock release, so
/// poisoning carries no information worth propagating.
fn lock_timer(timer: &Mutex<TimerState>) -> MutexGuard<'_, TimerState> {
    timer.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Lock a shared step workspace, shrugging off poisoning: a panic while
/// the pool was held can only leak buffers that were grabbed and never
/// returned (the pool shrinks; every pooled `Vec` stays valid), so a
/// long-lived serve engine must not let one panicking request wedge every
/// later step/predict on the same pool.
fn lock_workspace(ws: &Mutex<StepWorkspace>) -> MutexGuard<'_, StepWorkspace> {
    ws.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Pure-Rust CPU backend (the default): sparse-block train steps + exact
/// full-graph oracle, no artifacts required.
pub struct NativeExecutor {
    timer: Mutex<TimerState>,
    kern: Kernels,
}

impl NativeExecutor {
    pub fn new() -> NativeExecutor {
        NativeExecutor::with_kernels(Kernels::blocked())
    }

    /// Pre-optimization configuration: the retained serial reference
    /// GEMM/SpMM kernels. Used by `benches/step_breakdown.rs` as the
    /// speedup baseline and by cross-check tests.
    pub fn with_reference_kernels() -> NativeExecutor {
        NativeExecutor::with_kernels(Kernels::reference())
    }

    /// Executor over an explicit kernel configuration — benches use
    /// `Kernels::blocked_scalar()` here to time the PR 2 (blocked, no
    /// SIMD) step against the dispatched one within a single process.
    pub fn with_kernels(kern: Kernels) -> NativeExecutor {
        NativeExecutor {
            timer: Mutex::new(TimerState { secs: 0.0, depth: 0, t0: Instant::now() }),
            kern,
        }
    }

    /// Time `f` against the cumulative exec clock. Re-entrant: when timed
    /// scopes nest (executor entry points share helpers like the full
    /// forward), only the outermost scope accumulates elapsed time, so
    /// nested scopes can never overlap-count
    /// (`exec_secs_counts_nested_scopes_once`). Safe under *concurrent*
    /// callers (sharded workers, rayon-parallel serve requests):
    /// overlapping scopes merge into the union of busy wall-clock
    /// intervals, scope exit is an RAII drop so a panicking workload
    /// cannot wedge the clock, and the lock shrugs off poisoning
    /// (`exec_secs_safe_under_concurrent_rayon_callers`,
    /// `exec_secs_survives_panicking_scope`). Telemetry only — "how long
    /// was the backend busy", not summed per-caller compute.
    fn time<T>(&self, f: impl FnOnce() -> Result<T>) -> Result<T> {
        let _scope = TimerScope::enter(&self.timer);
        f()
    }

    /// Time an external forward-only workload (the serve engine's
    /// exact-tile assembly) on this executor's exec clock. Same semantics
    /// as the trait entry points: nested scopes count once, concurrent
    /// scopes merge.
    pub fn time_scope<T>(&self, f: impl FnOnce() -> Result<T>) -> Result<T> {
        self.time(f)
    }

    /// Forward-only compensated subgraph pass for online inference (the
    /// serve engine's cached-history tile path): Eq. 8/10 forward with the
    /// Eq. 9 halo combination against caller-gathered history rows,
    /// returning output-head logits for the batch rows. No backward, no
    /// history write-back, no optimizer state.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_logits(
        &self,
        g: &Graph,
        sb: &SubgraphBatch,
        model: &ModelSpec,
        params: &Params,
        hist_h: &[Vec<f32>],
        beta: &[f32],
        ws: Option<&Mutex<StepWorkspace>>,
    ) -> Result<Vec<f32>> {
        let kern = self.kern;
        self.time(|| subgraph_forward_logits(kern, g, sb, model, params, hist_h, beta, ws))
    }
}

impl Default for NativeExecutor {
    fn default() -> Self {
        NativeExecutor::new()
    }
}

impl Executor for NativeExecutor {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn resolve_profile(&self, profile: &str) -> Result<ProfileInfo> {
        ProfileInfo::builtin(profile)
            .ok_or_else(|| anyhow!("native backend: unknown profile '{profile}'"))
    }

    fn resolve_arch(&self, profile: &str, arch_name: &str) -> Result<ArchInfo> {
        ArchInfo::for_profile(&self.resolve_profile(profile)?, arch_name)
    }

    fn buckets(&self, _profile: &str) -> Result<Buckets> {
        Ok(Buckets::unbounded())
    }

    fn forward_backward(&self, inp: &StepInputs) -> Result<StepOutputs> {
        let kern = self.kern;
        self.time(|| step_native(inp, kern))
    }

    fn full_forward(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<Vec<Vec<f32>>> {
        self.time(|| Ok(full_forward_cached(g, params, model, false)?.hs))
    }

    fn full_grad(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<OracleResult> {
        self.time(|| full_grad_native(g, params, model))
    }

    fn evaluate(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<EvalResult> {
        self.time(|| evaluate_native(g, params, model))
    }

    fn exec_secs(&self) -> f64 {
        lock_timer(&self.timer).secs
    }
}

// ---------------------------------------------------------------------------
// elementwise helpers
// ---------------------------------------------------------------------------

pub(crate) fn add_bias_rows(z: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    for row in z.chunks_mut(n) {
        for (r, &b) in row.iter_mut().zip(bias) {
            *r += b;
        }
    }
}

fn colsum(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; n];
    for i in 0..m {
        for (o, &v) in out.iter_mut().zip(&a[i * n..(i + 1) * n]) {
            *o += v;
        }
    }
    out
}

/// `dst += scale · colsum(a[m, n])` without materializing the column sums
/// (bias-gradient accumulation on the step's hot path).
fn colsum_axpy(dst: &mut [f32], a: &[f32], m: usize, n: usize, scale: f32) {
    for i in 0..m {
        for (d, &v) in dst.iter_mut().zip(&a[i * n..(i + 1) * n]) {
            *d += scale * v;
        }
    }
}

pub(crate) fn relu_inplace(z: &mut [f32]) {
    for v in z.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// dz ⊙= relu'(z) (JAX convention: relu'(0) = 0).
fn relu_bwd_mask(dz: &mut [f32], z: &[f32]) {
    for (d, &v) in dz.iter_mut().zip(z) {
        if v <= 0.0 {
            *d = 0.0;
        }
    }
}

/// dst += scale * src (runtime-dispatched SIMD).
fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
    debug_assert_eq!(dst.len(), src.len());
    (simd::ops_auto().axpy)(dst, src, scale);
}

/// Eq. (9)/(12): out[i, :] = (1 - beta[i]) * hist[i, :] + beta[i] * fresh[i, :],
/// rayon-parallel for large row blocks (it sits between the sampler and the
/// GEMM on the per-step critical path).
pub fn combine_into(out: &mut [f32], beta: &[f32], hist: &[f32], fresh: &[f32], rows: usize, d: usize) {
    debug_assert!(beta.len() >= rows && hist.len() >= rows * d && fresh.len() >= rows * d);
    debug_assert!(out.len() >= rows * d);
    if rows == 0 || d == 0 {
        return;
    }
    let out = &mut out[..rows * d];
    let cmb = simd::ops_auto().combine;
    if rows * d >= COMBINE_PAR_MIN {
        out.par_chunks_mut(d).enumerate().for_each(|(i, o)| {
            cmb(o, &hist[i * d..(i + 1) * d], &fresh[i * d..(i + 1) * d], beta[i]);
        });
    } else {
        for (i, o) in out.chunks_mut(d).enumerate() {
            cmb(o, &hist[i * d..(i + 1) * d], &fresh[i * d..(i + 1) * d], beta[i]);
        }
    }
}

/// Allocating wrapper around [`combine_into`] (tests, benches).
pub fn combine(beta: &[f32], hist: &[f32], fresh: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    combine_into(&mut out, beta, hist, fresh, rows, d);
    out
}

/// Numerically-stable masked softmax cross-entropy over `[rows, c]` logits
/// into a caller-provided (pre-zeroed) `dl` buffer. Returns
/// (loss_sum, correct); dl = (softmax - onehot) ⊙ mask, unscaled — callers
/// fold in vscale / bwd_scale.
fn masked_ce_into(
    logits: &[f32],
    rows: usize,
    c: usize,
    y: &[u16],
    mask: &[f32],
    dl: &mut [f32],
) -> (f64, f64) {
    debug_assert!(dl.len() >= rows * c);
    let mut loss = 0f64;
    let mut correct = 0f64;
    for i in 0..rows {
        let row = &logits[i * c..(i + 1) * c];
        let mk = mask[i];
        let mut mx = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                arg = j;
            }
        }
        let mut denom = 0f64;
        for &v in row {
            denom += ((v - mx) as f64).exp();
        }
        let yi = y[i] as usize;
        if mk != 0.0 {
            let logp = (row[yi] - mx) as f64 - denom.ln();
            loss -= mk as f64 * logp;
            if arg == yi {
                correct += mk as f64;
            }
            let drow = &mut dl[i * c..(i + 1) * c];
            for (j, d) in drow.iter_mut().enumerate() {
                let p = (((row[j] - mx) as f64).exp() / denom) as f32;
                *d = mk * (p - if j == yi { 1.0 } else { 0.0 });
            }
        }
    }
    (loss, correct)
}

/// Allocating wrapper around [`masked_ce_into`] (oracle paths, tests).
fn masked_ce(logits: &[f32], rows: usize, c: usize, y: &[u16], mask: &[f32]) -> (f64, f64, Vec<f32>) {
    let mut dl = vec![0f32; rows * c];
    let (loss, correct) = masked_ce_into(logits, rows, c, y, mask, &mut dl);
    (loss, correct, dl)
}

// ---------------------------------------------------------------------------
// subgraph step
// ---------------------------------------------------------------------------

/// Gather feature rows for the stacked `[batch; halo]` node space into a
/// caller-provided buffer (parallel for large gathers).
fn gather_stacked_into(src: &[f32], d: usize, batch: &[u32], halo: &[u32], out: &mut [f32]) {
    gather_rows_into(src, d, batch, out);
    gather_rows_into(src, d, halo, &mut out[batch.len() * d..]);
}

/// `out += scale · [[A_bb, A_bh], [A_bh^T, A_hh]] @ x` over the stacked
/// node space — the backend's SpMM hot path. Accumulating into the
/// caller's buffer is what fuses the affine/residual term: the step
/// pre-fills `out` (bias rows, `α·h0`, or zeros) and the aggregate lands
/// directly in the pre-activation buffer. Blocked mode parallelizes over
/// row blocks with feature-dim tiling (the same scheme as
/// `CsrBlock::par_spmm_acc_tiled`); reference mode is the pre-optimization
/// one-row-per-task loop.
fn agg_full_scaled_into(
    kern: Kernels,
    sb: &SubgraphBatch,
    x: &[f32],
    d: usize,
    scale: f32,
    out: &mut [f32],
) {
    let m = sb.batch.len() + sb.halo.len();
    debug_assert!(x.len() >= m * d);
    debug_assert!(out.len() >= m * d);
    if m == 0 || d == 0 {
        return;
    }
    let out = &mut out[..m * d];
    if kern.mode == GemmMode::Reference {
        out.par_chunks_mut(d)
            .enumerate()
            .for_each(|(r, row)| agg_row(sb, x, d, scale, r, row));
        return;
    }
    let ops = kern.ops();
    if m * d <= SPMM_PAR_MIN {
        agg_rows_tiled(ops, sb, x, d, scale, 0, out);
        return;
    }
    out.par_chunks_mut(SPMM_ROW_BLOCK * d).enumerate().for_each(|(blk, orows)| {
        agg_rows_tiled(ops, sb, x, d, scale, blk * SPMM_ROW_BLOCK, orows);
    });
}

/// One stacked-operator row: `row += scale · (A @ x)[r, :]`.
fn agg_row(sb: &SubgraphBatch, x: &[f32], d: usize, scale: f32, r: usize, row: &mut [f32]) {
    let nb = sb.batch.len();
    let (lo, hi) = if r < nb {
        (sb.a_bb.row(r), sb.a_bh.row(r))
    } else {
        (sb.a_hb.row(r - nb), sb.a_hh.row(r - nb))
    };
    let (cols, vals) = lo;
    for (&j, &w) in cols.iter().zip(vals) {
        let sw = scale * w;
        let src = &x[j as usize * d..(j as usize + 1) * d];
        for (o, &s) in row.iter_mut().zip(src) {
            *o += sw * s;
        }
    }
    let (cols, vals) = hi;
    for (&j, &w) in cols.iter().zip(vals) {
        let sw = scale * w;
        let src = &x[(nb + j as usize) * d..(nb + j as usize + 1) * d];
        for (o, &s) in row.iter_mut().zip(src) {
            *o += sw * s;
        }
    }
}

/// A block of stacked-operator rows starting at `r0`, feature-tiled so the
/// active `x` tile stays cache-resident across the block's rows; the
/// per-edge inner loop is the dispatched SIMD `axpy`.
fn agg_rows_tiled(
    ops: &SimdOps,
    sb: &SubgraphBatch,
    x: &[f32],
    d: usize,
    scale: f32,
    r0: usize,
    orows: &mut [f32],
) {
    let nb = sb.batch.len();
    let rows = orows.len() / d;
    let axpy = ops.axpy;
    let mut d0 = 0;
    while d0 < d {
        let d1 = (d0 + SPMM_D_TILE).min(d);
        for rr in 0..rows {
            let r = r0 + rr;
            let (lo, hi) = if r < nb {
                (sb.a_bb.row(r), sb.a_bh.row(r))
            } else {
                (sb.a_hb.row(r - nb), sb.a_hh.row(r - nb))
            };
            let orow = &mut orows[rr * d + d0..rr * d + d1];
            let (cols, vals) = lo;
            for (&j, &w) in cols.iter().zip(vals) {
                axpy(orow, &x[j as usize * d + d0..j as usize * d + d1], scale * w);
            }
            let (cols, vals) = hi;
            for (&j, &w) in cols.iter().zip(vals) {
                axpy(orow, &x[(nb + j as usize) * d + d0..(nb + j as usize) * d + d1], scale * w);
            }
        }
        d0 = d1;
    }
}

fn labels_of(g: &Graph, idx: &[u32]) -> Vec<u16> {
    idx.iter().map(|&u| g.labels[u as usize]).collect()
}

fn train_mask_of(g: &Graph, idx: &[u32]) -> Vec<f32> {
    idx.iter().map(|&u| if g.split[u as usize] == 0 { 1.0 } else { 0.0 }).collect()
}

fn param<'p>(params: &'p Params, name: &str) -> Result<&'p Tensor> {
    params.get(name).ok_or_else(|| anyhow!("missing parameter {name}"))
}

fn step_native(inp: &StepInputs, kern: Kernels) -> Result<StepOutputs> {
    let g = inp.graph;
    let sb = inp.sb;
    let arch = &inp.model.arch;
    let kind = kind_of(&inp.model.arch_name)?;
    let l_total = arch.l;
    let dims = &arch.dims;
    let nb = sb.batch.len();
    let nh = sb.halo.len();
    let m = nb + nh;

    if inp.top.is_some() && kind != Kind::Gcn {
        bail!("TOP compensation is implemented for arch gcn only");
    }
    // TOP transform fit gradients, collected per layer when requested.
    let mut fit_fwd: Vec<Tensor> = Vec::new();
    let mut fit_bwd: Vec<Tensor> = Vec::new();

    // Scratch: the trainer-owned pool (held for the whole step), or a
    // step-local pool for callers without one (old allocate-per-step
    // behaviour, bit-identical results).
    let mut local_ws;
    let mut guard;
    let ws: &mut StepWorkspace = match inp.ws {
        Some(mtx) => {
            guard = lock_workspace(mtx);
            &mut guard
        }
        None => {
            local_ws = StepWorkspace::new();
            &mut local_ws
        }
    };

    // ---- embed0 ----------------------------------------------------------
    // For GCN the features flow straight into layer 1 (embed0 = identity),
    // so the gather buffer is moved, not copied; GCNII keeps `x_full` for
    // the W0 gradient and `h0_full` for the initial-residual connection.
    let mut x_full = ws.grab_dirty(m * g.d_x);
    gather_stacked_into(&g.features, g.d_x, &sb.batch, &sb.halo, &mut x_full);
    let (mut h, h0_full, z0_full, x_embed0) = match kind {
        Kind::Gcn => (x_full, Vec::new(), Vec::new(), Vec::new()),
        Kind::Gcnii => {
            let w0 = param(inp.params, "W0")?;
            let b0 = param(inp.params, "b0")?;
            // fused affine + ReLU epilogue: z0 and h0 = relu(z0) are each
            // written exactly once, per cache-hot row block
            let mut z0 = ws.grab_dirty(m * dims[0]);
            let mut h0 = ws.grab_dirty(m * dims[0]);
            let (w0d, b0d) = (&w0.data, &b0.data);
            kern.matmul_bias_relu_into(&mut z0, &mut h0, &x_full, m, g.d_x, w0d, dims[0], b0d);
            let mut h = ws.grab_dirty(m * dims[0]);
            h.copy_from_slice(&h0);
            (h, h0, z0, x_full)
        }
    };

    // ---- forward ---------------------------------------------------------
    // caches: per layer the stacked pre-activation `pre` (relu mask) and the
    // linearized input `lin` (GCN: aggregated messages, the dW operand;
    // GCNII: the residual-mixed s).
    let mut pre: Vec<Vec<f32>> = Vec::with_capacity(l_total);
    let mut lin: Vec<Vec<f32>> = Vec::with_capacity(l_total);
    let mut new_h: Vec<Vec<f32>> = Vec::new();
    let mut htilde: Vec<Vec<f32>> = Vec::new();
    for l in 1..=l_total {
        let d_prev = dims[l - 1];
        let d_l = dims[l];
        let relu = l < l_total || kind == Kind::Gcnii;
        let (z, mut act) = match kind {
            Kind::Gcn => {
                let w = param(inp.params, &format!("W{l}"))?;
                let b = param(inp.params, &format!("b{l}"))?;
                let mut agg = ws.grab(m * d_prev);
                agg_full_scaled_into(kern, sb, &h, d_prev, 1.0, &mut agg);
                let mut z = ws.grab_dirty(m * d_l);
                let mut act = ws.grab_dirty(m * d_l);
                if relu {
                    // fused epilogue: z and act = relu(z) in one traversal
                    let (wd, bd) = (&w.data, &b.data);
                    kern.matmul_bias_relu_into(&mut z, &mut act, &agg, m, d_prev, wd, d_l, bd);
                } else {
                    kern.matmul_bias_into(&mut z, &agg, m, d_prev, &w.data, d_l, &b.data);
                    act.copy_from_slice(&z);
                }
                if let Some(top) = &inp.top {
                    if top.fit && l < l_total {
                        // TOP fit pair: the in-batch-only incomplete
                        // activation (A_bb carries the self loops, so this
                        // is exactly the message-dropped forward) against
                        // the complete in-batch value just computed.
                        let mut aggb = ws.grab(nb * d_prev);
                        sb.a_bb.par_spmm_acc_tiled(&h[..nb * d_prev], d_prev, 1.0, &mut aggb);
                        let mut zi = ws.grab_dirty(nb * d_l);
                        let mut inc = ws.grab_dirty(nb * d_l);
                        let (wd, bd) = (&w.data, &b.data);
                        kern.matmul_bias_relu_into(
                            &mut zi, &mut inc, &aggb, nb, d_prev, wd, d_l, bd,
                        );
                        let full = &act[..nb * d_l];
                        fit_fwd.push(top_fit_grad(kern, ws, &inc, full, &top.fwd[l - 1], nb, d_l));
                        ws.put(aggb);
                        ws.put(zi);
                        ws.put(inc);
                    }
                }
                lin.push(agg);
                (z, act)
            }
            Kind::Gcnii => {
                let w = param(inp.params, &format!("W{l}"))?;
                let gam = gcnii_gamma(l);
                // fused residual + aggregate: s = α·h0 + (1-α)·(A @ h);
                // the α·h0 prefill is the SIMD scaled copy, the aggregate
                // then accumulates on top of it
                let mut s = ws.grab_dirty(m * d_prev);
                (kern.ops().scale)(&mut s, &h0_full, GCNII_ALPHA);
                agg_full_scaled_into(kern, sb, &h, d_prev, 1.0 - GCNII_ALPHA, &mut s);
                let mut z = ws.grab_dirty(m * d_l);
                let mut act = ws.grab_dirty(m * d_l);
                if d_prev == d_l {
                    // fused epilogue: s@W lands per row block, the
                    // (1-γ)·s + γ·s@W mix and ReLU run on the hot block
                    kern.matmul_mix_relu_into(&mut z, &mut act, &s, m, d_prev, &w.data, d_l, gam);
                } else {
                    let mut sw = ws.grab_dirty(m * d_l);
                    kern.matmul_into(&mut sw, &s, m, d_prev, &w.data, d_l);
                    for ((zv, &sv), &swv) in z.iter_mut().zip(&s[..m * d_l]).zip(&sw) {
                        *zv = (1.0 - gam) * sv + gam * swv;
                    }
                    ws.put(sw);
                    act.copy_from_slice(&z);
                    relu_inplace(&mut act);
                }
                lin.push(s);
                (z, act)
            }
        };
        pre.push(z);
        if l < l_total {
            let mut ht = ws.grab_dirty(nh * d_l);
            ht.copy_from_slice(&act[nb * d_l..]);
            if let Some(top) = &inp.top {
                // TOP (arXiv 2502.19693): halo rows are synthesized from
                // the fresh incomplete values via the learned transform
                // T_l — no history, no staleness.
                let t = &top.fwd[l - 1];
                kern.matmul_into(&mut act[nb * d_l..], &ht, nh, d_l, &t.data, d_l);
            } else {
                // Eq. (9): halo rows become a convex combination of the
                // fresh incomplete value and the historical embedding.
                combine_into(
                    &mut act[nb * d_l..],
                    &inp.beta[..nh],
                    &inp.hist_h[l - 1],
                    &ht,
                    nh,
                    d_l,
                );
            }
            let mut newh_l = ws.grab_dirty(nb * d_l);
            newh_l.copy_from_slice(&act[..nb * d_l]);
            new_h.push(newh_l);
            htilde.push(ht);
        }
        ws.put(std::mem::replace(&mut h, act));
    }

    // ---- loss head (Vbar^L and Vhat^L initialization, Alg. 1 line 11) ----
    let d_last = dims[l_total];
    let y_b = labels_of(g, &sb.batch);
    let mask_b = train_mask_of(g, &sb.batch);
    let y_h = labels_of(g, &sb.halo);
    let mask_h = train_mask_of(g, &sb.halo);

    let mut grads: Vec<Tensor> = arch.params.iter().map(|(_, s)| Tensor::zeros(s)).collect();
    let gidx: HashMap<&str, usize> =
        arch.params.iter().enumerate().map(|(i, (n, _))| (n.as_str(), i)).collect();

    let hb = &h[..nb * d_last];
    let hh = &h[nb * d_last..];
    let (loss_sum, correct, mut vb, mut vh) = match kind {
        Kind::Gcn => {
            let c = d_last;
            let mut dlb = ws.grab(nb * c);
            let (ls, cor) = masked_ce_into(hb, nb, c, &y_b, &mask_b, &mut dlb);
            for v in dlb.iter_mut() {
                *v *= inp.vscale;
            }
            let mut dlh = ws.grab(nh * c);
            masked_ce_into(hh, nh, c, &y_h, &mask_h, &mut dlh);
            let s = inp.bwd_scale * inp.vscale;
            for v in dlh.iter_mut() {
                *v *= s;
            }
            (ls, cor, dlb, dlh)
        }
        Kind::Gcnii => {
            let wc = param(inp.params, "Wc")?;
            let bc = param(inp.params, "bc")?;
            let c = wc.shape[1];
            let mut logit_b = ws.grab_dirty(nb * c);
            kern.matmul_bias_into(&mut logit_b, hb, nb, d_last, &wc.data, c, &bc.data);
            let mut dlb = ws.grab(nb * c);
            let (ls, cor) = masked_ce_into(&logit_b, nb, c, &y_b, &mask_b, &mut dlb);
            let mut gtmp = ws.grab_dirty(d_last * c);
            kern.matmul_tn_into(&mut gtmp, hb, nb, d_last, &dlb, c);
            axpy(&mut grads[gidx["Wc"]].data, &gtmp, inp.grad_scale * inp.vscale);
            ws.put(gtmp);
            colsum_axpy(&mut grads[gidx["bc"]].data, &dlb, nb, c, inp.grad_scale * inp.vscale);
            let mut vbv = ws.grab_dirty(nb * d_last);
            kern.matmul_nt_into(&mut vbv, &dlb, nb, c, &wc.data, d_last);
            for v in vbv.iter_mut() {
                *v *= inp.vscale;
            }
            let mut logit_h = ws.grab_dirty(nh * c);
            kern.matmul_bias_into(&mut logit_h, hh, nh, d_last, &wc.data, c, &bc.data);
            let mut dlh = ws.grab(nh * c);
            masked_ce_into(&logit_h, nh, c, &y_h, &mask_h, &mut dlh);
            let mut vhv = ws.grab_dirty(nh * d_last);
            kern.matmul_nt_into(&mut vhv, &dlh, nh, c, &wc.data, d_last);
            let s = inp.bwd_scale * inp.vscale;
            for v in vhv.iter_mut() {
                *v *= s;
            }
            ws.put(logit_b);
            ws.put(logit_h);
            ws.put(dlb);
            ws.put(dlh);
            (ls, cor, vbv, vhv)
        }
    };

    // ---- backward (Eqs. 11-13 propagation, Eq. 7 parameter grads) --------
    let mut new_v: Vec<Vec<f32>> = vec![Vec::new(); l_total.saturating_sub(1)];
    let mut acc_h0 = ws.grab(nb * dims[0]);
    for l in (1..=l_total).rev() {
        let d_prev = dims[l - 1];
        let d_l = dims[l];
        let mut dz = ws.grab_dirty(m * d_l);
        dz[..nb * d_l].copy_from_slice(&vb);
        dz[nb * d_l..].copy_from_slice(&vh);
        if l < l_total || kind == Kind::Gcnii {
            relu_bwd_mask(&mut dz, &pre[l - 1]);
        }
        let v_full = match kind {
            Kind::Gcn => {
                let w = param(inp.params, &format!("W{l}"))?;
                // Eq. (7): in-batch cotangents only feed parameter grads.
                let mut gw = ws.grab_dirty(d_prev * d_l);
                kern.matmul_tn_into(&mut gw, &lin[l - 1], nb, d_prev, &dz, d_l);
                axpy(&mut grads[gidx[format!("W{l}").as_str()]].data, &gw, inp.grad_scale);
                ws.put(gw);
                colsum_axpy(
                    &mut grads[gidx[format!("b{l}").as_str()]].data,
                    &dz[..nb * d_l],
                    nb,
                    d_l,
                    inp.grad_scale,
                );
                // Eqs. (11) & (13): propagate with full (batch, halo) rows.
                let mut dagg = ws.grab_dirty(m * d_prev);
                kern.matmul_nt_into(&mut dagg, &dz, m, d_l, &w.data, d_prev);
                let mut vf = ws.grab(m * d_prev);
                agg_full_scaled_into(kern, sb, &dagg, d_prev, 1.0, &mut vf);
                if let Some(top) = &inp.top {
                    if top.fit && l > 1 {
                        // TOP fit pair: in-batch-only propagated cotangent
                        // against the complete one (mirrors the forward).
                        let mut incv = ws.grab(nb * d_prev);
                        sb.a_bb.par_spmm_acc_tiled(&dagg[..nb * d_prev], d_prev, 1.0, &mut incv);
                        let full = &vf[..nb * d_prev];
                        let tr = &top.bwd[l - 2];
                        fit_bwd.push(top_fit_grad(kern, ws, &incv, full, tr, nb, d_prev));
                        ws.put(incv);
                    }
                }
                ws.put(dagg);
                vf
            }
            Kind::Gcnii => {
                let w = param(inp.params, &format!("W{l}"))?;
                let gam = gcnii_gamma(l);
                let mut gw = ws.grab_dirty(d_prev * d_l);
                kern.matmul_tn_into(&mut gw, &lin[l - 1], nb, d_prev, &dz, d_l);
                axpy(&mut grads[gidx[format!("W{l}").as_str()]].data, &gw, inp.grad_scale * gam);
                ws.put(gw);
                let mut dzw = ws.grab_dirty(m * d_prev);
                kern.matmul_nt_into(&mut dzw, &dz, m, d_l, &w.data, d_prev);
                let mut ds = ws.grab_dirty(m * d_prev);
                for ((dv, &zv), &zwv) in ds.iter_mut().zip(&dz[..m * d_prev]).zip(&dzw) {
                    *dv = (1.0 - gam) * zv + gam * zwv;
                }
                ws.put(dzw);
                // initial-residual cotangent into embed0, batch rows (Eq. 7)
                axpy(&mut acc_h0, &ds[..nb * d_prev], GCNII_ALPHA);
                // (1 - α) factor folded into the aggregation scale
                let mut vf = ws.grab(m * d_prev);
                agg_full_scaled_into(kern, sb, &ds, d_prev, 1.0 - GCNII_ALPHA, &mut vf);
                ws.put(ds);
                vf
            }
        };
        ws.put(dz);
        if l > 1 {
            let mut vh_next = ws.grab_dirty(nh * d_prev);
            if let Some(top) = &inp.top {
                // TOP backward: synthesize the halo cotangents from the
                // fresh propagated ones via the learned transform S_l.
                let s = &top.bwd[l - 2];
                kern.matmul_into(&mut vh_next, &v_full[nb * d_prev..], nh, d_prev, &s.data, d_prev);
            } else {
                // Eq. (12): compensate halo auxiliaries with history.
                combine_into(
                    &mut vh_next,
                    &inp.beta[..nh],
                    &inp.hist_v[l - 2],
                    &v_full[nb * d_prev..],
                    nh,
                    d_prev,
                );
            }
            for v in vh_next.iter_mut() {
                *v *= inp.bwd_scale;
            }
            ws.put(std::mem::replace(&mut vh, vh_next));
            let mut vb_next = ws.grab_dirty(nb * d_prev);
            vb_next.copy_from_slice(&v_full[..nb * d_prev]);
            // Vbar^{l-1} write-back equals the propagated Vb
            let mut vbar = ws.grab_dirty(nb * d_prev);
            vbar.copy_from_slice(&vb_next);
            new_v[l - 2] = vbar;
            ws.put(std::mem::replace(&mut vb, vb_next));
            ws.put(v_full);
        } else {
            // V^0 feeds embed0 through the compensated propagation
            axpy(&mut acc_h0, &v_full[..nb * d_prev], 1.0);
            ws.put(v_full);
        }
    }

    // ---- embed0 parameter gradients (GCNII's W0/b0; no-op for GCN) -------
    if kind == Kind::Gcnii {
        let mut dz0 = acc_h0;
        relu_bwd_mask(&mut dz0, &z0_full[..nb * dims[0]]);
        let mut gw0 = ws.grab_dirty(g.d_x * dims[0]);
        kern.matmul_tn_into(&mut gw0, &x_embed0, nb, g.d_x, &dz0, dims[0]);
        axpy(&mut grads[gidx["W0"]].data, &gw0, inp.grad_scale);
        ws.put(gw0);
        colsum_axpy(&mut grads[gidx["b0"]].data, &dz0, nb, dims[0], inp.grad_scale);
        ws.put(dz0);
        ws.put(x_embed0);
        ws.put(h0_full);
        ws.put(z0_full);
    } else {
        ws.put(acc_h0);
        ws.put(x_embed0);
        ws.put(h0_full);
        ws.put(z0_full);
    }

    // remaining caches back to the pool
    ws.put(h);
    ws.put(vb);
    ws.put(vh);
    ws.put_all(pre);
    ws.put_all(lin);

    let active_bytes = memory::sparse_step_active_bytes(sb, arch, g.d_x);
    let top_fit = match &inp.top {
        Some(t) if t.fit => {
            // the backward loop runs l = L..2 descending; flip so
            // `bwd[l-2]` lines up with the transform indexing
            fit_bwd.reverse();
            Some(TopFit { fwd: fit_fwd, bwd: fit_bwd })
        }
        _ => None,
    };
    Ok(StepOutputs { loss_sum, correct, grads, new_h, new_v, htilde, active_bytes, top_fit })
}

/// Normalized least-squares gradient for one TOP transform: with residual
/// `R = inc·T − full`, returns `incᵀR / (‖inc‖_F²/d + ε)` — a relaxation
/// step toward the in-batch least-squares fit whose scale is invariant to
/// the magnitude of the incoming activations (exact relaxation in the
/// scalar case).
fn top_fit_grad(
    kern: Kernels,
    ws: &mut StepWorkspace,
    inc: &[f32],
    full: &[f32],
    t: &Tensor,
    nb: usize,
    d: usize,
) -> Tensor {
    let mut resid = ws.grab_dirty(nb * d);
    kern.matmul_into(&mut resid, inc, nb, d, &t.data, d);
    for (r, &f) in resid.iter_mut().zip(full) {
        *r -= f;
    }
    let mut g = Tensor::zeros(&[d, d]);
    kern.matmul_tn_into(&mut g.data, inc, nb, d, &resid, d);
    ws.put(resid);
    let norm: f32 = inc.iter().map(|v| v * v).sum();
    let scale = 1.0 / (norm / d as f32 + 1e-12);
    for v in g.data.iter_mut() {
        *v *= scale;
    }
    g
}

// ---------------------------------------------------------------------------
// forward-only subgraph pass (online inference)
// ---------------------------------------------------------------------------

/// The forward half of [`step_native`] for a serve tile: stacked
/// `[batch; halo]` gather, fused GEMM epilogues, Eq. 9 halo combination
/// against caller-gathered history rows, output-head logits for the batch
/// rows. Shares every kernel with the train step (the subgraph cache, the
/// fused epilogues, the workspace pool) but materializes no backward
/// caches, so a serve tile touches O(m · d) scratch and returns only
/// `batch.len() · n_class` floats.
#[allow(clippy::too_many_arguments)]
pub fn subgraph_forward_logits(
    kern: Kernels,
    g: &Graph,
    sb: &SubgraphBatch,
    model: &ModelSpec,
    params: &Params,
    hist_h: &[Vec<f32>],
    beta: &[f32],
    ws: Option<&Mutex<StepWorkspace>>,
) -> Result<Vec<f32>> {
    let arch = &model.arch;
    let kind = kind_of(&model.arch_name)?;
    let l_total = arch.l;
    let dims = &arch.dims;
    let nb = sb.batch.len();
    let nh = sb.halo.len();
    let m = nb + nh;
    debug_assert!(beta.len() >= nh, "beta must cover every halo row");

    let mut local_ws;
    let mut guard;
    let ws: &mut StepWorkspace = match ws {
        Some(mtx) => {
            guard = lock_workspace(mtx);
            &mut guard
        }
        None => {
            local_ws = StepWorkspace::new();
            &mut local_ws
        }
    };

    // ---- embed0 ----------------------------------------------------------
    let mut x_full = ws.grab_dirty(m * g.d_x);
    gather_stacked_into(&g.features, g.d_x, &sb.batch, &sb.halo, &mut x_full);
    let (mut h, h0_full) = match kind {
        Kind::Gcn => (x_full, Vec::new()),
        Kind::Gcnii => {
            let w0 = param(params, "W0")?;
            let b0 = param(params, "b0")?;
            let mut z0 = ws.grab_dirty(m * dims[0]);
            let mut h0 = ws.grab_dirty(m * dims[0]);
            let (w0d, b0d) = (&w0.data, &b0.data);
            kern.matmul_bias_relu_into(&mut z0, &mut h0, &x_full, m, g.d_x, w0d, dims[0], b0d);
            ws.put(z0);
            ws.put(x_full);
            let mut h = ws.grab_dirty(m * dims[0]);
            h.copy_from_slice(&h0);
            (h, h0)
        }
    };

    // ---- layers ----------------------------------------------------------
    for l in 1..=l_total {
        let d_prev = dims[l - 1];
        let d_l = dims[l];
        let relu = l < l_total || kind == Kind::Gcnii;
        let mut act = ws.grab_dirty(m * d_l);
        match kind {
            Kind::Gcn => {
                let w = param(params, &format!("W{l}"))?;
                let b = param(params, &format!("b{l}"))?;
                let mut agg = ws.grab(m * d_prev);
                agg_full_scaled_into(kern, sb, &h, d_prev, 1.0, &mut agg);
                if relu {
                    let mut z = ws.grab_dirty(m * d_l);
                    let (wd, bd) = (&w.data, &b.data);
                    kern.matmul_bias_relu_into(&mut z, &mut act, &agg, m, d_prev, wd, d_l, bd);
                    ws.put(z);
                } else {
                    kern.matmul_bias_into(&mut act, &agg, m, d_prev, &w.data, d_l, &b.data);
                }
                ws.put(agg);
            }
            Kind::Gcnii => {
                let w = param(params, &format!("W{l}"))?;
                let gam = gcnii_gamma(l);
                let mut s = ws.grab_dirty(m * d_prev);
                (kern.ops().scale)(&mut s, &h0_full, GCNII_ALPHA);
                agg_full_scaled_into(kern, sb, &h, d_prev, 1.0 - GCNII_ALPHA, &mut s);
                if d_prev == d_l {
                    let mut z = ws.grab_dirty(m * d_l);
                    kern.matmul_mix_relu_into(&mut z, &mut act, &s, m, d_prev, &w.data, d_l, gam);
                    ws.put(z);
                } else {
                    let mut sw = ws.grab_dirty(m * d_l);
                    kern.matmul_into(&mut sw, &s, m, d_prev, &w.data, d_l);
                    for ((av, &sv), &swv) in act.iter_mut().zip(&s[..m * d_l]).zip(&sw) {
                        *av = (1.0 - gam) * sv + gam * swv;
                    }
                    ws.put(sw);
                    relu_inplace(&mut act);
                }
                ws.put(s);
            }
        }
        if l < l_total {
            // Eq. (9): halo rows become the convex combination of the
            // incomplete fresh value and the cached-history embedding
            // (beta = 0 serves pure history, the GAS-style serve default).
            let mut ht = ws.grab_dirty(nh * d_l);
            ht.copy_from_slice(&act[nb * d_l..]);
            combine_into(&mut act[nb * d_l..], &beta[..nh], &hist_h[l - 1], &ht, nh, d_l);
            ws.put(ht);
        }
        ws.put(std::mem::replace(&mut h, act));
    }

    // ---- output head -----------------------------------------------------
    let d_last = dims[l_total];
    let hb = &h[..nb * d_last];
    let logits = match kind {
        Kind::Gcn => hb.to_vec(),
        Kind::Gcnii => {
            let wc = param(params, "Wc")?;
            let bc = param(params, "bc")?;
            let c = wc.shape[1];
            let mut out = vec![0f32; nb * c];
            kern.matmul_bias_into(&mut out, hb, nb, d_last, &wc.data, c, &bc.data);
            out
        }
    };
    ws.put(h);
    ws.put(h0_full);
    Ok(logits)
}

// ---------------------------------------------------------------------------
// exact full-graph oracle
// ---------------------------------------------------------------------------

/// `Ahat @ x` over the global normalized adjacency (self-loops folded in).
fn full_aggregate(g: &Graph, x: &[f32], d: usize) -> Vec<f32> {
    let n = g.n();
    debug_assert!(x.len() >= n * d);
    let mut out = vec![0f32; n * d];
    out.par_chunks_mut(d).enumerate().for_each(|(u, row)| {
        let sw = g.self_w[u];
        let src = &x[u * d..(u + 1) * d];
        for (o, &s) in row.iter_mut().zip(src) {
            *o = sw * s;
        }
        for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
            let v = g.csr.neighbors[ei] as usize;
            let w = g.edge_w[ei];
            let src = &x[v * d..(v + 1) * d];
            for (o, &s) in row.iter_mut().zip(src) {
                *o += w * s;
            }
        }
    });
    out
}

struct FullFwd {
    /// H^l for l = 0..L (index 0 = embed0 output).
    hs: Vec<Vec<f32>>,
    /// Pre-activations z_l, l = 1..L (index l-1).
    pre: Vec<Vec<f32>>,
    /// GCN: aggregated messages; GCNII: residual-mixed s (index l-1).
    lin: Vec<Vec<f32>>,
    /// GCNII embed0 pre-activation (empty for GCN).
    z0: Vec<f32>,
}

/// Exact full-graph forward. With `keep_caches` the per-layer backward
/// operands (`pre`, `lin`, `z0`) are retained for `full_grad_native`;
/// evaluation-only callers skip them to keep peak memory at one activation
/// per layer.
fn full_forward_cached(g: &Graph, params: &Params, model: &ModelSpec, keep_caches: bool) -> Result<FullFwd> {
    let arch = &model.arch;
    let kind = kind_of(&model.arch_name)?;
    let n = g.n();
    let dims = &arch.dims;
    let (h0, z0) = match kind {
        Kind::Gcn => (g.features.clone(), Vec::new()),
        Kind::Gcnii => {
            let w0 = param(params, "W0")?;
            let b0 = param(params, "b0")?;
            let mut z0 = gemm::matmul(&g.features, n, g.d_x, &w0.data, dims[0]);
            add_bias_rows(&mut z0, &b0.data);
            let mut h0 = z0.clone();
            relu_inplace(&mut h0);
            (h0, z0)
        }
    };
    let mut hs = vec![h0.clone()];
    let mut pre = Vec::with_capacity(arch.l);
    let mut lin = Vec::with_capacity(arch.l);
    let mut h = h0;
    for l in 1..=arch.l {
        let d_prev = dims[l - 1];
        let d_l = dims[l];
        let agg = full_aggregate(g, &h, d_prev);
        let z = match kind {
            Kind::Gcn => {
                let w = param(params, &format!("W{l}"))?;
                let b = param(params, &format!("b{l}"))?;
                let mut z = gemm::matmul(&agg, n, d_prev, &w.data, d_l);
                add_bias_rows(&mut z, &b.data);
                lin.push(agg);
                z
            }
            Kind::Gcnii => {
                let w = param(params, &format!("W{l}"))?;
                let gam = gcnii_gamma(l);
                let mut s = agg;
                for (sv, &h0v) in s.iter_mut().zip(&hs[0]) {
                    *sv = (1.0 - GCNII_ALPHA) * *sv + GCNII_ALPHA * h0v;
                }
                let sw = gemm::matmul(&s, n, d_prev, &w.data, d_l);
                let mut z = vec![0f32; n * d_l];
                for ((zv, &sv), &swv) in z.iter_mut().zip(&s).zip(&sw) {
                    *zv = (1.0 - gam) * sv + gam * swv;
                }
                lin.push(s);
                z
            }
        };
        let act = if keep_caches {
            let mut act = z.clone();
            if l < arch.l || kind == Kind::Gcnii {
                relu_inplace(&mut act);
            }
            pre.push(z);
            act
        } else {
            lin.clear();
            let mut act = z;
            if l < arch.l || kind == Kind::Gcnii {
                relu_inplace(&mut act);
            }
            act
        };
        hs.push(act.clone());
        h = act;
    }
    if !keep_caches {
        return Ok(FullFwd { hs, pre: Vec::new(), lin: Vec::new(), z0: Vec::new() });
    }
    Ok(FullFwd { hs, pre, lin, z0 })
}

/// Output-head logits for a `[rows, d_last]` representation — shared by
/// the oracle paths here and the serve engine's tile/oracle heads, so the
/// head computation cannot drift between them.
pub(crate) fn logits_of(kind: Kind, params: &Params, h: &[f32], rows: usize, d_last: usize) -> Result<Vec<f32>> {
    match kind {
        Kind::Gcn => Ok(h[..rows * d_last].to_vec()),
        Kind::Gcnii => {
            let wc = param(params, "Wc")?;
            let bc = param(params, "bc")?;
            let mut l = gemm::matmul(h, rows, d_last, &wc.data, wc.shape[1]);
            add_bias_rows(&mut l, &bc.data);
            Ok(l)
        }
    }
}

/// Full-graph train mask straight from the split labels.
fn full_train_mask(g: &Graph) -> Vec<f32> {
    g.split.iter().map(|&s| if s == 0 { 1.0 } else { 0.0 }).collect()
}

fn evaluate_native(g: &Graph, params: &Params, model: &ModelSpec) -> Result<EvalResult> {
    let kind = kind_of(&model.arch_name)?;
    let fwd = full_forward_cached(g, params, model, false)?;
    let n = g.n();
    let d_last = model.arch.dims[model.arch.l];
    let logits = logits_of(kind, params, &fwd.hs[model.arch.l], n, d_last)?;
    let c = logits.len() / n;
    let mask = full_train_mask(g);
    let (loss_sum, _, _) = masked_ce(&logits, n, c, &g.labels, &mask);
    let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);
    // slot 3 absorbs sentinel splits (e.g. sharded halo rows, which belong
    // to no train/val/test set of the worker graph) without counting them
    // toward any reported accuracy
    let mut correct = [0usize; 4];
    let mut total = [0usize; 4];
    for u in 0..n {
        let pred = argmax(&logits[u * c..(u + 1) * c]);
        let split = (g.split[u] as usize).min(3);
        total[split] += 1;
        if pred == g.labels[u] as usize {
            correct[split] += 1;
        }
    }
    Ok(EvalResult {
        train_loss: loss_sum / n_train as f64,
        train_acc: acc(correct[0], total[0]),
        val_acc: acc(correct[1], total[1]),
        test_acc: acc(correct[2], total[2]),
    })
}

fn full_grad_native(g: &Graph, params: &Params, model: &ModelSpec) -> Result<OracleResult> {
    let arch = &model.arch;
    let kind = kind_of(&model.arch_name)?;
    let fwd = full_forward_cached(g, params, model, true)?;
    let n = g.n();
    let dims = &arch.dims;
    let l_total = arch.l;
    let d_last = dims[l_total];
    let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);
    let vscale = 1.0 / n_train as f32;

    let mut grads: Vec<Tensor> = arch.params.iter().map(|(_, s)| Tensor::zeros(s)).collect();
    let gidx: HashMap<&str, usize> =
        arch.params.iter().enumerate().map(|(i, (nm, _))| (nm.as_str(), i)).collect();

    let mask = full_train_mask(g);

    // V^L from the loss head
    let logits = logits_of(kind, params, &fwd.hs[l_total], n, d_last)?;
    let c = logits.len() / n;
    let (loss_sum, _, dlogits) = masked_ce(&logits, n, c, &g.labels, &mask);
    let mut v: Vec<f32> = match kind {
        Kind::Gcn => dlogits.iter().map(|&x| x * vscale).collect(),
        Kind::Gcnii => {
            let wc = param(params, "Wc")?;
            axpy(
                &mut grads[gidx["Wc"]].data,
                &gemm::matmul_tn(&fwd.hs[l_total], n, d_last, &dlogits, c),
                vscale,
            );
            axpy(&mut grads[gidx["bc"]].data, &colsum(&dlogits, n, c), vscale);
            let mut vv = gemm::matmul_nt(&dlogits, n, c, &wc.data, d_last);
            for x in vv.iter_mut() {
                *x *= vscale;
            }
            vv
        }
    };

    let mut v_layers: Vec<Vec<f32>> = vec![Vec::new(); l_total + 1];
    v_layers[l_total] = v.clone();
    let mut acc_h0 = vec![0f32; n * dims[0]];
    for l in (1..=l_total).rev() {
        let d_prev = dims[l - 1];
        let d_l = dims[l];
        let mut dz = v;
        if l < l_total || kind == Kind::Gcnii {
            relu_bwd_mask(&mut dz, &fwd.pre[l - 1]);
        }
        let vprev = match kind {
            Kind::Gcn => {
                let w = param(params, &format!("W{l}"))?;
                axpy(
                    &mut grads[gidx[format!("W{l}").as_str()]].data,
                    &gemm::matmul_tn(&fwd.lin[l - 1], n, d_prev, &dz, d_l),
                    1.0,
                );
                axpy(&mut grads[gidx[format!("b{l}").as_str()]].data, &colsum(&dz, n, d_l), 1.0);
                let dagg = gemm::matmul_nt(&dz, n, d_l, &w.data, d_prev);
                full_aggregate(g, &dagg, d_prev)
            }
            Kind::Gcnii => {
                let w = param(params, &format!("W{l}"))?;
                let gam = gcnii_gamma(l);
                axpy(
                    &mut grads[gidx[format!("W{l}").as_str()]].data,
                    &gemm::matmul_tn(&fwd.lin[l - 1], n, d_prev, &dz, d_l),
                    gam,
                );
                let dzw = gemm::matmul_nt(&dz, n, d_l, &w.data, d_prev);
                let mut ds = vec![0f32; n * d_prev];
                for ((dv, &zv), &zwv) in ds.iter_mut().zip(&dz).zip(&dzw) {
                    *dv = (1.0 - gam) * zv + gam * zwv;
                }
                axpy(&mut acc_h0, &ds, GCNII_ALPHA);
                for x in ds.iter_mut() {
                    *x *= 1.0 - GCNII_ALPHA;
                }
                full_aggregate(g, &ds, d_prev)
            }
        };
        v = vprev;
        if l >= 2 {
            v_layers[l - 1] = v.clone();
        }
    }
    axpy(&mut acc_h0, &v, 1.0);

    if kind == Kind::Gcnii {
        let mut dz0 = acc_h0;
        relu_bwd_mask(&mut dz0, &fwd.z0);
        axpy(&mut grads[gidx["W0"]].data, &gemm::matmul_tn(&g.features, n, g.d_x, &dz0, dims[0]), 1.0);
        axpy(&mut grads[gidx["b0"]].data, &colsum(&dz0, n, dims[0]), 1.0);
    }

    Ok(OracleResult {
        grads,
        train_loss: loss_sum / n_train as f64,
        h_layers: fwd.hs,
        v_layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_ce_grads_sum_to_zero_per_masked_row() {
        let logits = vec![0.3, -0.2, 1.0, 0.5, 0.1, -0.4];
        let (loss, correct, dl) = masked_ce(&logits, 2, 3, &[2, 0], &[1.0, 0.0]);
        assert!(loss > 0.0);
        assert_eq!(correct, 1.0); // row 0 argmax = 2 = label
        // masked row: gradient rows sum to 0 (softmax - onehot)
        let s0: f32 = dl[..3].iter().sum();
        assert!(s0.abs() < 1e-6);
        // unmasked row: zero gradient
        assert!(dl[3..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn combine_is_convex() {
        let out = combine(&[0.25], &[4.0, 8.0], &[0.0, 0.0], 1, 2);
        assert_eq!(out, vec![3.0, 6.0]);
    }

    #[test]
    fn combine_parallel_path_matches_serial() {
        // rows * d above COMBINE_PAR_MIN exercises the rayon path. The
        // dispatched SIMD primitive may fuse the multiply-add (one fewer
        // rounding than the written-out formula), so compare to ≤ 1 ulp
        // tolerance rather than bitwise.
        let rows = 300;
        let d = 64;
        let beta: Vec<f32> = (0..rows).map(|i| (i % 11) as f32 / 10.0).collect();
        let hist: Vec<f32> = (0..rows * d).map(|i| (i % 17) as f32 * 0.25 - 2.0).collect();
        let fresh: Vec<f32> = (0..rows * d).map(|i| (i % 13) as f32 * 0.5 - 3.0).collect();
        let got = combine(&beta, &hist, &fresh, rows, d);
        for i in 0..rows {
            let b = beta[i];
            for j in 0..d {
                let want = (1.0 - b) * hist[i * d + j] + b * fresh[i * d + j];
                let g = got[i * d + j];
                assert!(
                    (g - want).abs() <= 1e-6 * (1.0 + want.abs()),
                    "row {i} col {j}: {g} vs {want}"
                );
            }
        }
        // parallel and serial paths of combine_into itself agree bitwise
        // (same primitive, same per-row calls)
        let small_rows = 4;
        let serial = combine(&beta[..small_rows], &hist[..small_rows * d], &fresh[..small_rows * d], small_rows, d);
        assert_eq!(&serial[..], &got[..small_rows * d]);
    }

    #[test]
    fn gamma_matches_archs_py() {
        // gamma_l = log(lam / l + 1), lam = 0.5
        assert!((gcnii_gamma(1) - (1.5f64).ln() as f32).abs() < 1e-6);
        assert!((gcnii_gamma(4) - (1.125f64).ln() as f32).abs() < 1e-6);
    }

    #[test]
    fn exec_secs_counts_nested_scopes_once() {
        let ex = NativeExecutor::new();
        let d = std::time::Duration::from_millis(20);
        let t0 = Instant::now();
        ex.time(|| {
            ex.time(|| {
                std::thread::sleep(d);
                Ok(())
            })?;
            std::thread::sleep(d);
            Ok(())
        })
        .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let secs = ex.exec_secs();
        // the outer scope alone is ~2 sleeps; double-counting the nested
        // scope would add a third
        assert!(secs >= 0.035, "outer scope undercounted: {secs}");
        assert!(secs <= wall + 1e-3, "nested scope double-counted: {secs} > wall {wall}");
        // a second top-level scope keeps accumulating
        ex.time(|| {
            std::thread::sleep(d);
            Ok(())
        })
        .unwrap();
        assert!(ex.exec_secs() >= secs + 0.015);
    }

    #[test]
    fn exec_secs_safe_under_concurrent_rayon_callers() {
        // The serve engine shares one executor across rayon-parallel
        // requests. Concurrent scopes must merge into the union of busy
        // intervals: cumulative secs stays positive, monotone, and never
        // exceeds wall clock (summing per-caller time would).
        use rayon::prelude::*;
        let ex = NativeExecutor::new();
        let wall = Instant::now();
        (0..48).into_par_iter().for_each(|_| {
            ex.time(|| {
                // nested scope on the same thread while siblings overlap
                ex.time(|| {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    Ok(())
                })?;
                std::thread::sleep(std::time::Duration::from_millis(1));
                Ok(())
            })
            .unwrap();
        });
        let secs = ex.exec_secs();
        let w = wall.elapsed().as_secs_f64();
        assert!(secs > 0.0, "concurrent scopes recorded nothing");
        assert!(secs <= w + 1e-3, "busy union exceeded wall clock: {secs} > {w}");
        // the clock keeps accumulating after the hammer
        ex.time(|| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            Ok(())
        })
        .unwrap();
        assert!(ex.exec_secs() >= secs + 0.004, "clock stalled after concurrent use");
    }

    #[test]
    fn exec_secs_survives_panicking_scope() {
        // One bad request out of many concurrent ones must not wedge the
        // clock: scope exit is a Drop, so depth returns to zero during
        // unwind and later scopes still accumulate.
        let ex = NativeExecutor::new();
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = ex.time(|| -> Result<()> { panic!("bad serve request") });
        }));
        assert!(panicked.is_err());
        let before = ex.exec_secs();
        ex.time(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            Ok(())
        })
        .unwrap();
        assert!(
            ex.exec_secs() >= before + 0.008,
            "timer wedged after a panicking scope: {} -> {}",
            before,
            ex.exec_secs()
        );
    }

    #[test]
    fn colsum_axpy_matches_colsum() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut dst = vec![1.0f32, 1.0];
        colsum_axpy(&mut dst, &a, 3, 2, 0.5);
        let cs = colsum(&a, 3, 2);
        assert_eq!(dst, vec![1.0 + 0.5 * cs[0], 1.0 + 0.5 * cs[1]]);
    }
}
