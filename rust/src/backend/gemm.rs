//! Blocked, rayon-parallel dense GEMM kernels for the native backend, with
//! runtime-dispatched SIMD inner loops ([`super::simd`]).
//!
//! Three layouts cover every dense product a train step needs:
//!
//!   * `matmul`     — `a[m, k] @ b[k, n]`        (forward affine, `dagg`)
//!   * `matmul_nt`  — `a[m, n] @ b[p, n]^T`      (cotangent through `W`)
//!   * `matmul_tn`  — `a[m, k]^T @ c[m, n]`      (parameter gradients)
//!
//! Each kernel tiles over output row blocks ([`ROW_BLOCK`] rows per rayon
//! task) and, for the N/N and T/N layouts, over k-panels ([`K_PANEL`]) so
//! the `b`/`c` panel in flight stays cache-resident while it is reused
//! across the block's rows; within a block those rows are processed in
//! register-blocked *pairs* (`SimdOps::axpy2` rank-1 updates: one panel-row
//! load feeds two accumulator rows, halving panel traffic). The innermost
//! loops run through the [`SimdOps`] dispatch table (16-wide AVX-512F or
//! 8-wide AVX2/FMA on x86_64, NEON on aarch64, scalar fallback): per
//! output element the accumulation *order* is
//! identical to the naive kernel (`k` resp. `i` ascending), so results are
//! deterministic and thread-count independent at every level. At the
//! scalar level the N/N and T/N kernels are bit-identical to the
//! [`reference`] implementations; with FMA active each multiply-add loses
//! one rounding (≤ 1 ulp per op — property-pinned to the scalar kernels at
//! ≤ 1e-5 in `tests/proptest_invariants.rs`). The N/T kernel uses an
//! unrolled/vectorized dot product (different association, same value to
//! ≤ 1e-6 relative at the scalar level).
//!
//! Fused epilogues write downstream buffers while the output row block is
//! still cache-hot instead of re-traversing `m · n` floats afterwards:
//!
//!   * `matmul_bias_into`      — output initialized with the bias row, the
//!     product accumulates on top (no separate `add_bias_rows` pass);
//!   * `matmul_bias_relu_into` — additionally writes `act = relu(z)` per
//!     row block (the pre-activation and activation buffers are each
//!     written exactly once);
//!   * `matmul_mix_relu_into`  — the GCNII layer epilogue: `z = (1-γ)·s +
//!     γ·(s@W)` and `act = relu(z)` fused into the product's row blocks
//!     (the `α·h0` initial-residual term is already folded into `s` by the
//!     aggregation prefill; see `native::step_native`).
//!
//! The serial [`reference`] module retains the pre-optimization kernels;
//! [`Kernels`] dispatches between the families so benches can measure the
//! old configurations (`benches/step_breakdown.rs`) and property tests can
//! cross-check the blocked/SIMD kernels against the naive ones.

use rayon::prelude::*;

use super::simd::{self, SimdLevel, SimdOps};

/// Output rows per rayon task (and per T/N output-row block).
const ROW_BLOCK: usize = 16;
/// k-panel length for the N/N and T/N kernels.
const K_PANEL: usize = 64;
/// Column block for the N/T kernel (rows of `b` kept hot per pass).
const COL_BLOCK: usize = 32;
/// Below this many output elements the serial path is used (a rayon
/// dispatch costs more than it saves).
const PAR_MIN: usize = 1 << 12;

/// Which kernel family executes the dense products of a train step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmMode {
    /// Cache-blocked, rayon-parallel kernels (the default).
    Blocked,
    /// The retained serial reference kernels (pre-optimization behaviour;
    /// used by `benches/step_breakdown.rs` to measure the old backend).
    Reference,
}

/// Kernel dispatch handle carried by `NativeExecutor`: the kernel family
/// plus the SIMD level its inner loops dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kernels {
    pub mode: GemmMode,
    pub simd: SimdLevel,
}

impl Kernels {
    /// Blocked kernels at the process-wide detected SIMD level
    /// (`LMC_SIMD=scalar` forces the scalar inner loops). The default.
    pub fn blocked() -> Kernels {
        Kernels { mode: GemmMode::Blocked, simd: simd::level() }
    }

    /// Blocked kernels with the scalar inner loops regardless of hardware —
    /// the PR 2 configuration. Used by `benches/step_breakdown.rs` for the
    /// scalar-vs-SIMD A/B and by the SIMD property tests as the oracle.
    pub fn blocked_scalar() -> Kernels {
        Kernels { mode: GemmMode::Blocked, simd: SimdLevel::Scalar }
    }

    pub fn reference() -> Kernels {
        Kernels { mode: GemmMode::Reference, simd: SimdLevel::Scalar }
    }

    /// The SIMD primitive table this handle's blocked kernels dispatch to.
    #[inline]
    pub fn ops(&self) -> &'static SimdOps {
        simd::ops(self.simd)
    }

    /// `out = a[m, k] @ b[k, n]` (overwrites `out`).
    pub fn matmul_into(&self, out: &mut [f32], a: &[f32], m: usize, k: usize, b: &[f32], n: usize) {
        match self.mode {
            GemmMode::Blocked => matmul_into_with(self.ops(), out, a, m, k, b, n),
            GemmMode::Reference => reference::matmul_into(out, a, m, k, b, n),
        }
    }

    /// `out = a[m, k] @ b[k, n] + bias` (fused affine; overwrites `out`).
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_bias_into(
        &self,
        out: &mut [f32],
        a: &[f32],
        m: usize,
        k: usize,
        b: &[f32],
        n: usize,
        bias: &[f32],
    ) {
        match self.mode {
            GemmMode::Blocked => matmul_bias_into_with(self.ops(), out, a, m, k, b, n, bias),
            GemmMode::Reference => {
                reference::matmul_into(out, a, m, k, b, n);
                reference::add_bias_rows(&mut out[..m * n], bias);
            }
        }
    }

    /// Fused affine + ReLU epilogue: `z = a @ b + bias`, `act = relu(z)`,
    /// both written in one traversal of each output row block.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_bias_relu_into(
        &self,
        z: &mut [f32],
        act: &mut [f32],
        a: &[f32],
        m: usize,
        k: usize,
        b: &[f32],
        n: usize,
        bias: &[f32],
    ) {
        match self.mode {
            GemmMode::Blocked => {
                matmul_bias_relu_into_with(self.ops(), z, act, a, m, k, b, n, bias)
            }
            GemmMode::Reference => {
                reference::matmul_into(z, a, m, k, b, n);
                reference::add_bias_rows(&mut z[..m * n], bias);
                let (z, act) = (&z[..m * n], &mut act[..m * n]);
                for (av, &zv) in act.iter_mut().zip(z) {
                    *av = if zv > 0.0 { zv } else { 0.0 };
                }
            }
        }
    }

    /// Fused GCNII layer epilogue: `z = (1-gam)·s + gam·(s @ w)`,
    /// `act = relu(z)`, computed per row block while `s @ w` is cache-hot.
    /// Requires a square layer (`k == n`); callers with `k != n` use the
    /// unfused sequence.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_mix_relu_into(
        &self,
        z: &mut [f32],
        act: &mut [f32],
        s: &[f32],
        m: usize,
        k: usize,
        w: &[f32],
        n: usize,
        gam: f32,
    ) {
        debug_assert_eq!(k, n, "fused mix epilogue requires a square layer");
        match self.mode {
            GemmMode::Blocked => matmul_mix_relu_into_with(self.ops(), z, act, s, m, k, w, n, gam),
            GemmMode::Reference => {
                let sw = reference::matmul(s, m, k, w, n);
                let (z, act) = (&mut z[..m * n], &mut act[..m * n]);
                for ((zv, &sv), &swv) in z.iter_mut().zip(&s[..m * n]).zip(&sw) {
                    *zv = (1.0 - gam) * sv + gam * swv;
                }
                for (av, &zv) in act.iter_mut().zip(z.iter()) {
                    *av = if zv > 0.0 { zv } else { 0.0 };
                }
            }
        }
    }

    /// `out = a[m, n] @ b[p, n]^T` (overwrites `out`).
    pub fn matmul_nt_into(&self, out: &mut [f32], a: &[f32], m: usize, n: usize, b: &[f32], p: usize) {
        match self.mode {
            GemmMode::Blocked => matmul_nt_into_with(self.ops(), out, a, m, n, b, p),
            GemmMode::Reference => reference::matmul_nt_into(out, a, m, n, b, p),
        }
    }

    /// `out = a[m, k]^T @ c[m, n]` (overwrites `out`).
    pub fn matmul_tn_into(&self, out: &mut [f32], a: &[f32], m: usize, k: usize, c: &[f32], n: usize) {
        match self.mode {
            GemmMode::Blocked => matmul_tn_into_with(self.ops(), out, a, m, k, c, n),
            GemmMode::Reference => reference::matmul_tn_into(out, a, m, k, c, n),
        }
    }
}

// ---------------------------------------------------------------------------
// blocked kernels
// ---------------------------------------------------------------------------

/// Allocating convenience: `a[m, k] @ b[k, n]`.
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    matmul_into(&mut out, a, m, k, b, n);
    out
}

/// Allocating convenience: `a[m, n] @ b[p, n]^T`.
pub fn matmul_nt(a: &[f32], m: usize, n: usize, b: &[f32], p: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * p];
    matmul_nt_into(&mut out, a, m, n, b, p);
    out
}

/// Allocating convenience: `a[m, k]^T @ c[m, n]`.
pub fn matmul_tn(a: &[f32], m: usize, k: usize, c: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    matmul_tn_into(&mut out, a, m, k, c, n);
    out
}

/// `out = a[m, k] @ b[k, n]` at the process-wide SIMD level.
pub fn matmul_into(out: &mut [f32], a: &[f32], m: usize, k: usize, b: &[f32], n: usize) {
    matmul_into_with(simd::ops_auto(), out, a, m, k, b, n)
}

/// `out = a[m, k] @ b[k, n] + bias` at the process-wide SIMD level.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_into(
    out: &mut [f32],
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    bias: &[f32],
) {
    matmul_bias_into_with(simd::ops_auto(), out, a, m, k, b, n, bias)
}

/// `out = a[m, n] @ b[p, n]^T` at the process-wide SIMD level.
pub fn matmul_nt_into(out: &mut [f32], a: &[f32], m: usize, n: usize, b: &[f32], p: usize) {
    matmul_nt_into_with(simd::ops_auto(), out, a, m, n, b, p)
}

/// `out = a[m, k]^T @ c[m, n]` at the process-wide SIMD level.
pub fn matmul_tn_into(out: &mut [f32], a: &[f32], m: usize, k: usize, c: &[f32], n: usize) {
    matmul_tn_into_with(simd::ops_auto(), out, a, m, k, c, n)
}

/// `out = a[m, k] @ b[k, n]`, row-blocked and k-paneled.
#[allow(clippy::too_many_arguments)]
fn matmul_into_with(
    ops: &SimdOps,
    out: &mut [f32],
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    if m == 0 || n == 0 {
        return;
    }
    let out = &mut out[..m * n];
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let a = &a[..m * k];
    if m * n <= PAR_MIN {
        out.fill(0.0);
        nn_block(ops, out, a, k, b, n);
        return;
    }
    out.par_chunks_mut(ROW_BLOCK * n)
        .zip(a.par_chunks(ROW_BLOCK * k))
        .for_each(|(orows, arows)| {
            orows.fill(0.0);
            nn_block(ops, orows, arows, k, b, n);
        });
}

/// `out = a[m, k] @ b[k, n] + bias` (bias broadcast over rows).
#[allow(clippy::too_many_arguments)]
fn matmul_bias_into_with(
    ops: &SimdOps,
    out: &mut [f32],
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    bias: &[f32],
) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    debug_assert!(bias.len() >= n);
    if m == 0 || n == 0 {
        return;
    }
    let out = &mut out[..m * n];
    let bias = &bias[..n];
    if k == 0 {
        fill_bias(out, n, bias);
        return;
    }
    let a = &a[..m * k];
    if m * n <= PAR_MIN {
        fill_bias(out, n, bias);
        nn_block(ops, out, a, k, b, n);
        return;
    }
    out.par_chunks_mut(ROW_BLOCK * n)
        .zip(a.par_chunks(ROW_BLOCK * k))
        .for_each(|(orows, arows)| {
            fill_bias(orows, n, bias);
            nn_block(ops, orows, arows, k, b, n);
        });
}

/// `z = a @ b + bias`, `act = relu(z)` — the fused affine + ReLU epilogue:
/// `act` is written per row block right after the block's product lands,
/// while the block is still cache-hot.
#[allow(clippy::too_many_arguments)]
fn matmul_bias_relu_into_with(
    ops: &SimdOps,
    z: &mut [f32],
    act: &mut [f32],
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    bias: &[f32],
) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n);
    debug_assert!(z.len() >= m * n && act.len() >= m * n && bias.len() >= n);
    if m == 0 || n == 0 {
        return;
    }
    let z = &mut z[..m * n];
    let act = &mut act[..m * n];
    let bias = &bias[..n];
    if k == 0 {
        fill_bias(z, n, bias);
        (ops.relu_copy)(act, z);
        return;
    }
    let a = &a[..m * k];
    if m * n <= PAR_MIN {
        fill_bias(z, n, bias);
        nn_block(ops, z, a, k, b, n);
        (ops.relu_copy)(act, z);
        return;
    }
    z.par_chunks_mut(ROW_BLOCK * n)
        .zip(act.par_chunks_mut(ROW_BLOCK * n))
        .zip(a.par_chunks(ROW_BLOCK * k))
        .for_each(|((zrows, actrows), arows)| {
            fill_bias(zrows, n, bias);
            nn_block(ops, zrows, arows, k, b, n);
            (ops.relu_copy)(actrows, zrows);
        });
}

/// `z = (1-gam)·s + gam·(s @ w)`, `act = relu(z)` — the fused GCNII layer
/// epilogue. `s @ w` accumulates into `z` per row block (identical order to
/// the standalone product), then the residual mix and ReLU run over the
/// cache-hot block. Requires `k == n` so `s`'s rows align with `z`'s.
#[allow(clippy::too_many_arguments)]
fn matmul_mix_relu_into_with(
    ops: &SimdOps,
    z: &mut [f32],
    act: &mut [f32],
    s: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    gam: f32,
) {
    debug_assert_eq!(k, n, "fused mix epilogue requires a square layer");
    debug_assert!(s.len() >= m * k && w.len() >= k * n);
    debug_assert!(z.len() >= m * n && act.len() >= m * n);
    if m == 0 || n == 0 {
        return;
    }
    let z = &mut z[..m * n];
    let act = &mut act[..m * n];
    let s = &s[..m * k];
    if m * n <= PAR_MIN {
        z.fill(0.0);
        nn_block(ops, z, s, k, w, n);
        (ops.mix_relu)(z, act, s, gam);
        return;
    }
    z.par_chunks_mut(ROW_BLOCK * n)
        .zip(act.par_chunks_mut(ROW_BLOCK * n))
        .zip(s.par_chunks(ROW_BLOCK * k))
        .for_each(|((zrows, actrows), srows)| {
            zrows.fill(0.0);
            nn_block(ops, zrows, srows, k, w, n);
            (ops.mix_relu)(zrows, actrows, srows, gam);
        });
}

fn fill_bias(orows: &mut [f32], n: usize, bias: &[f32]) {
    for row in orows.chunks_mut(n) {
        row.copy_from_slice(bias);
    }
}

/// Accumulate `arows @ b` into `orows` (one row block), k-paneled so the
/// active `b` panel is reused across the block's rows, and register-blocked
/// across output-row *pairs*: each `b` panel row is loaded once per pair
/// and rank-1-updates both accumulator rows (`SimdOps::axpy2`). Per output
/// element the accumulation order is unchanged (`k` ascending) and `axpy2`
/// is bitwise equal to two `axpy` calls at every SIMD level, so pairing
/// never changes results; rows whose `a` coefficient is zero keep the
/// skip-entirely behaviour of the unpaired kernel.
fn nn_block(ops: &SimdOps, orows: &mut [f32], arows: &[f32], k: usize, b: &[f32], n: usize) {
    let rows = orows.len() / n;
    let axpy = ops.axpy;
    let axpy2 = ops.axpy2;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + K_PANEL).min(k);
        let mut r = 0;
        while r + 2 <= rows {
            let (o0, rest) = orows[r * n..].split_at_mut(n);
            let o1 = &mut rest[..n];
            let a0row = &arows[r * k + k0..r * k + k1];
            let a1row = &arows[(r + 1) * k + k0..(r + 1) * k + k1];
            for (i, (&a0, &a1)) in a0row.iter().zip(a1row).enumerate() {
                let brow = &b[(k0 + i) * n..(k0 + i + 1) * n];
                if a0 != 0.0 && a1 != 0.0 {
                    axpy2(o0, o1, brow, a0, a1);
                } else if a0 != 0.0 {
                    axpy(o0, brow, a0);
                } else if a1 != 0.0 {
                    axpy(o1, brow, a1);
                }
            }
            r += 2;
        }
        if r < rows {
            let arow = &arows[r * k + k0..r * k + k1];
            let orow = &mut orows[r * n..(r + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    axpy(orow, &b[(k0 + i) * n..(k0 + i + 1) * n], av);
                }
            }
        }
        k0 = k1;
    }
}

/// `out = a[m, n] @ b[p, n]^T`, row-blocked with column blocks of `b` rows
/// and a vectorized dot product.
#[allow(clippy::too_many_arguments)]
fn matmul_nt_into_with(
    ops: &SimdOps,
    out: &mut [f32],
    a: &[f32],
    m: usize,
    n: usize,
    b: &[f32],
    p: usize,
) {
    debug_assert!(a.len() >= m * n && b.len() >= p * n && out.len() >= m * p);
    if m == 0 || p == 0 {
        return;
    }
    let out = &mut out[..m * p];
    if n == 0 {
        out.fill(0.0);
        return;
    }
    let a = &a[..m * n];
    if m * p <= PAR_MIN {
        nt_block(ops, out, a, n, b, p);
        return;
    }
    out.par_chunks_mut(ROW_BLOCK * p)
        .zip(a.par_chunks(ROW_BLOCK * n))
        .for_each(|(orows, arows)| nt_block(ops, orows, arows, n, b, p));
}

fn nt_block(ops: &SimdOps, orows: &mut [f32], arows: &[f32], n: usize, b: &[f32], p: usize) {
    let rows = orows.len() / p;
    let dot = ops.dot;
    let mut j0 = 0;
    while j0 < p {
        let j1 = (j0 + COL_BLOCK).min(p);
        for r in 0..rows {
            let arow = &arows[r * n..(r + 1) * n];
            let orow = &mut orows[r * p..(r + 1) * p];
            for j in j0..j1 {
                orow[j] = dot(arow, &b[j * n..(j + 1) * n]);
            }
        }
        j0 = j1;
    }
}

/// `out = a[m, k]^T @ c[m, n]`, parallel over blocks of the `k` output rows;
/// every block streams `a`'s column slab and `c` once, in fixed `i` order.
#[allow(clippy::too_many_arguments)]
fn matmul_tn_into_with(
    ops: &SimdOps,
    out: &mut [f32],
    a: &[f32],
    m: usize,
    k: usize,
    c: &[f32],
    n: usize,
) {
    debug_assert!(a.len() >= m * k && c.len() >= m * n && out.len() >= k * n);
    if k == 0 || n == 0 {
        return;
    }
    let out = &mut out[..k * n];
    if k * n <= PAR_MIN {
        out.fill(0.0);
        tn_block(ops, out, 0, a, m, k, c, n);
        return;
    }
    out.par_chunks_mut(ROW_BLOCK * n).enumerate().for_each(|(blk, orows)| {
        orows.fill(0.0);
        tn_block(ops, orows, blk * ROW_BLOCK, a, m, k, c, n);
    });
}

/// Accumulate rows `kk0..kk0 + orows.len()/n` of `a^T @ c` into `orows`,
/// register-blocked across output-row pairs (one `crow` load feeds both
/// accumulator rows via `SimdOps::axpy2`; `i` order per output element is
/// unchanged, so results are identical to the unpaired kernel).
#[allow(clippy::too_many_arguments)]
fn tn_block(
    ops: &SimdOps,
    orows: &mut [f32],
    kk0: usize,
    a: &[f32],
    m: usize,
    k: usize,
    c: &[f32],
    n: usize,
) {
    let kb = orows.len() / n;
    let axpy = ops.axpy;
    let axpy2 = ops.axpy2;
    for i in 0..m {
        let crow = &c[i * n..(i + 1) * n];
        let arow = &a[i * k + kk0..i * k + kk0 + kb];
        let mut r = 0;
        while r + 2 <= kb {
            let (a0, a1) = (arow[r], arow[r + 1]);
            if a0 != 0.0 && a1 != 0.0 {
                let (o0, rest) = orows[r * n..].split_at_mut(n);
                axpy2(o0, &mut rest[..n], crow, a0, a1);
            } else if a0 != 0.0 {
                axpy(&mut orows[r * n..(r + 1) * n], crow, a0);
            } else if a1 != 0.0 {
                axpy(&mut orows[(r + 1) * n..(r + 2) * n], crow, a1);
            }
            r += 2;
        }
        if r < kb {
            let av = arow[r];
            if av != 0.0 {
                axpy(&mut orows[r * n..(r + 1) * n], crow, av);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// retained naive reference kernels
// ---------------------------------------------------------------------------

/// The serial pre-optimization kernels, retained verbatim as the ground
/// truth the blocked kernels are property-tested against and as the
/// baseline `benches/step_breakdown.rs` measures.
pub mod reference {
    /// `a[m, k] @ b[k, n]`, serial triple loop.
    pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        matmul_into(&mut out, a, m, k, b, n);
        out
    }

    pub fn matmul_into(out: &mut [f32], a: &[f32], m: usize, k: usize, b: &[f32], n: usize) {
        debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
        let out = &mut out[..m * n];
        out.fill(0.0);
        for (i, row) in out.chunks_mut(n).enumerate() {
            let ar = &a[i * k..(i + 1) * k];
            for (kk, &av) in ar.iter().enumerate() {
                if av != 0.0 {
                    let br = &b[kk * n..(kk + 1) * n];
                    for (r, &bv) in row.iter_mut().zip(br) {
                        *r += av * bv;
                    }
                }
            }
        }
    }

    /// `a[m, n] @ b[p, n]^T`, serial.
    pub fn matmul_nt(a: &[f32], m: usize, n: usize, b: &[f32], p: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * p];
        matmul_nt_into(&mut out, a, m, n, b, p);
        out
    }

    pub fn matmul_nt_into(out: &mut [f32], a: &[f32], m: usize, n: usize, b: &[f32], p: usize) {
        debug_assert!(a.len() >= m * n && b.len() >= p * n && out.len() >= m * p);
        let out = &mut out[..m * p];
        for (i, row) in out.chunks_mut(p).enumerate() {
            let ar = &a[i * n..(i + 1) * n];
            for (j, r) in row.iter_mut().enumerate() {
                let br = &b[j * n..(j + 1) * n];
                let mut acc = 0f32;
                for (&x, &y) in ar.iter().zip(br) {
                    acc += x * y;
                }
                *r = acc;
            }
        }
    }

    /// `a[m, k]^T @ c[m, n]`, serial.
    pub fn matmul_tn(a: &[f32], m: usize, k: usize, c: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0f32; k * n];
        matmul_tn_into(&mut out, a, m, k, c, n);
        out
    }

    pub fn matmul_tn_into(out: &mut [f32], a: &[f32], m: usize, k: usize, c: &[f32], n: usize) {
        debug_assert!(a.len() >= m * k && c.len() >= m * n && out.len() >= k * n);
        let out = &mut out[..k * n];
        out.fill(0.0);
        for (kk, row) in out.chunks_mut(n).enumerate() {
            for i in 0..m {
                let av = a[i * k + kk];
                if av != 0.0 {
                    let cr = &c[i * n..(i + 1) * n];
                    for (r, &cv) in row.iter_mut().zip(cr) {
                        *r += av * cv;
                    }
                }
            }
        }
    }

    /// `z[i, :] += bias` for every row.
    pub fn add_bias_rows(z: &mut [f32], bias: &[f32]) {
        let n = bias.len();
        for row in z.chunks_mut(n) {
            for (r, &b) in row.iter_mut().zip(bias) {
                *r += b;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_shapes_and_values() {
        // a = [[1,2],[3,4],[5,6]] (3x2), b = [[1,0,2],[0,1,3]] (2x3)
        let a = vec![1., 2., 3., 4., 5., 6.];
        let b = vec![1., 0., 2., 0., 1., 3.];
        let c = matmul(&a, 3, 2, &b, 3);
        assert_eq!(c, vec![1., 2., 8., 3., 4., 18., 5., 6., 28.]);
        // a @ bT where bT rows are b's columns
        let bt = vec![1., 0., 0., 1., 2., 3.]; // (3x2): rows of b^T
        let c2 = matmul_nt(&a, 3, 2, &bt, 3);
        assert_eq!(c2, c);
        // aT @ c: (2x3) @ (3x3)
        let atc = matmul_tn(&a, 3, 2, &c, 3);
        // column 0 of a = [1,3,5]; aT@c row 0 = 1*c0 + 3*c1 + 5*c2
        let want0: Vec<f32> = (0..3).map(|j| c[j] + 3. * c[3 + j] + 5. * c[6 + j]).collect();
        assert_eq!(&atc[..3], &want0[..]);
    }

    #[test]
    fn fused_bias_matches_separate_passes() {
        let a = vec![1., 2., 3., 4., 5., 6.];
        let b = vec![1., 0., 2., 0., 1., 3.];
        let bias = vec![0.5, -1.0, 2.0];
        let mut fused = vec![0f32; 9];
        matmul_bias_into(&mut fused, &a, 3, 2, &b, 3, &bias);
        let mut want = reference::matmul(&a, 3, 2, &b, 3);
        reference::add_bias_rows(&mut want, &bias);
        assert_eq!(fused, want);
    }

    #[test]
    fn fused_bias_relu_matches_separate_passes() {
        // integer-valued inputs => exact arithmetic at every SIMD level
        let a = vec![1., -2., 3., 4., -5., 6.];
        let b = vec![1., 0., -2., 0., 1., 3.];
        let bias = vec![0.5, -1.0, 2.0];
        for kern in [Kernels::blocked(), Kernels::blocked_scalar(), Kernels::reference()] {
            let mut z = vec![0f32; 9];
            let mut act = vec![7f32; 9];
            kern.matmul_bias_relu_into(&mut z, &mut act, &a, 3, 2, &b, 3, &bias);
            let mut want_z = reference::matmul(&a, 3, 2, &b, 3);
            reference::add_bias_rows(&mut want_z, &bias);
            assert_eq!(z, want_z, "{kern:?}");
            for (i, (&av, &zv)) in act.iter().zip(&want_z).enumerate() {
                assert_eq!(av, if zv > 0.0 { zv } else { 0.0 }, "{kern:?} elem {i}");
            }
        }
    }

    #[test]
    fn fused_mix_relu_matches_separate_passes() {
        let s = vec![2., -4., 8., 2., -1., 0.5, 4., -8., 2.];
        let w = vec![1., 0., 0., 0., 1., 0., -1., 0., 2.]; // 3x3
        let gam = 0.5f32;
        for kern in [Kernels::blocked(), Kernels::blocked_scalar(), Kernels::reference()] {
            let mut z = vec![0f32; 9];
            let mut act = vec![0f32; 9];
            kern.matmul_mix_relu_into(&mut z, &mut act, &s, 3, 3, &w, 3, gam);
            let sw = reference::matmul(&s, 3, 3, &w, 3);
            for i in 0..9 {
                let want = (1.0 - gam) * s[i] + gam * sw[i];
                assert_eq!(z[i], want, "{kern:?} z elem {i}");
                assert_eq!(act[i], if want > 0.0 { want } else { 0.0 }, "{kern:?} act elem {i}");
            }
        }
    }

    #[test]
    fn kernels_dispatch_agrees() {
        let a = vec![1., -2., 3., 0., 5., 6., -7., 8.];
        let b = vec![0.5, 1., -1., 2., 0., 3., 1., -2.];
        for kern in [Kernels::blocked(), Kernels::blocked_scalar(), Kernels::reference()] {
            let mut out = vec![0f32; 8];
            kern.matmul_into(&mut out, &a, 4, 2, &b, 2);
            assert_eq!(out, reference::matmul(&a, 4, 2, &b, 2), "{kern:?}");
            let mut out = vec![0f32; 16];
            kern.matmul_nt_into(&mut out, &a, 4, 2, &b, 4);
            assert_eq!(out, reference::matmul_nt(&a, 4, 2, &b, 4), "{kern:?}");
            let mut out = vec![0f32; 4];
            kern.matmul_tn_into(&mut out, &a, 4, 2, &b, 2);
            assert_eq!(out, reference::matmul_tn(&a, 4, 2, &b, 2), "{kern:?}");
        }
    }

    #[test]
    fn row_pair_blocking_matches_reference_on_odd_shapes() {
        // Odd row counts exercise the unpaired remainder row; interleaved
        // zero coefficients exercise every branch of the paired loop
        // (both-nonzero, first-only, second-only, both-zero). Integer
        // values keep the arithmetic exact at every SIMD level.
        let m = 17;
        let k = 9;
        let n = 13;
        let a: Vec<f32> = (0..m * k)
            .map(|i| if i % 4 == 1 { 0.0 } else { (i % 7) as f32 - 3.0 })
            .collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let c: Vec<f32> = (0..m * n).map(|i| ((i % 3) as f32 - 1.0) * (i % 2) as f32).collect();
        for kern in [Kernels::blocked(), Kernels::blocked_scalar()] {
            let mut out = vec![0f32; m * n];
            kern.matmul_into(&mut out, &a, m, k, &b, n);
            assert_eq!(out, reference::matmul(&a, m, k, &b, n), "{kern:?} nn");
            let mut out = vec![0f32; k * n];
            kern.matmul_tn_into(&mut out, &a, m, k, &c, n);
            assert_eq!(out, reference::matmul_tn(&a, m, k, &c, n), "{kern:?} tn");
        }
    }

    #[test]
    fn empty_dims_are_noops() {
        let a: Vec<f32> = Vec::new();
        let b = vec![1.0, 2.0];
        let mut out: Vec<f32> = Vec::new();
        matmul_into(&mut out, &a, 0, 2, &b, 1);
        matmul_nt_into(&mut out, &a, 0, 2, &b, 1);
        matmul_tn_into(&mut out, &b, 2, 0, &b, 1);
        assert!(out.is_empty());
        // fused entries tolerate empty dims too
        let mut act: Vec<f32> = Vec::new();
        matmul_bias_relu_into_with(simd::ops_auto(), &mut out, &mut act, &a, 0, 2, &b, 1, &b);
        matmul_mix_relu_into_with(simd::ops_auto(), &mut out, &mut act, &a, 0, 2, &b, 2, 0.5);
        assert!(out.is_empty() && act.is_empty());
    }
}
