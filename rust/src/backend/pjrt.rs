//! PJRT executor (`--features pjrt`): the original AOT/HLO execution path
//! behind the [`Executor`] trait.
//!
//! The sampler's sparse CSR blocks are densified on demand
//! ([`SubgraphBatch::to_dense`]) to the compiled bucket shapes, and the
//! fused train_step / per-layer programs from the artifact manifest are
//! executed on the PJRT CPU client. The exact full-graph operations use
//! the tile-wise oracle (contiguous node ranges with exact halos, paper
//! Theorem 1 with V_B = V) previously housed in `coordinator/exact.rs`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::exact::{acc, argmax, exact_halo, EvalResult, OracleResult};
use crate::coordinator::memory;
use crate::coordinator::params::Params;
use crate::graph::Graph;
use crate::runtime::{lit_f32, lit_i32, lit_scalar, to_vec_f32, ArchInfo, ProfileInfo, Runtime, Tensor};
use crate::sampler::{gather_rows, Buckets, SubgraphBatch};

use super::{Executor, ModelSpec, StepInputs, StepOutputs};

pub struct PjrtExecutor {
    rt: Runtime,
}

impl PjrtExecutor {
    pub fn new(artifact_dir: &Path) -> Result<PjrtExecutor> {
        Ok(PjrtExecutor { rt: Runtime::new(artifact_dir)? })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl Executor for PjrtExecutor {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn resolve_profile(&self, profile: &str) -> Result<ProfileInfo> {
        self.rt
            .manifest
            .profiles
            .get(profile)
            .cloned()
            .ok_or_else(|| anyhow!("profile {profile} missing from manifest"))
    }

    fn resolve_arch(&self, profile: &str, arch_name: &str) -> Result<ArchInfo> {
        Ok(self.rt.manifest.arch(profile, arch_name)?.clone())
    }

    fn buckets(&self, profile: &str) -> Result<Buckets> {
        Ok(Buckets(self.resolve_profile(profile)?.step_buckets))
    }

    fn forward_backward(&self, inp: &StepInputs) -> Result<StepOutputs> {
        if inp.top.is_some() {
            anyhow::bail!("the pjrt backend does not implement TOP compensation");
        }
        let sb = inp.sb;
        let spec = self
            .rt
            .manifest
            .train_step(&inp.model.profile, &inp.model.arch_name, sb.bucket_b, sb.bucket_h)?
            .clone();
        let inputs = assemble_inputs(&spec, inp)?;
        let outs = self.rt.execute(&spec.name, &inputs)?;

        let loss_sum = to_vec_f32(&outs[spec.output_index("loss_sum")?])?[0] as f64;
        let correct = to_vec_f32(&outs[spec.output_index("correct")?])?[0] as f64;

        // gradients in canonical order
        let mut grads = Vec::with_capacity(inp.params.names.len());
        for (pi, name) in inp.params.names.iter().enumerate() {
            let g = to_vec_f32(&outs[spec.output_index(&format!("g_{name}"))?])?;
            grads.push(Tensor::from_vec(&inp.params.tensors[pi].shape, g));
        }

        let l_total = inp.model.arch.l;
        let mut new_h = Vec::with_capacity(l_total - 1);
        let mut new_v = Vec::with_capacity(l_total - 1);
        let mut htilde = Vec::with_capacity(l_total - 1);
        for l in 1..l_total {
            new_h.push(to_vec_f32(&outs[spec.output_index(&format!("newH{l}"))?])?);
            new_v.push(to_vec_f32(&outs[spec.output_index(&format!("newV{l}"))?])?);
            htilde.push(to_vec_f32(&outs[spec.output_index(&format!("htilde{l}"))?])?);
        }

        Ok(StepOutputs {
            loss_sum,
            correct,
            grads,
            new_h,
            new_v,
            htilde,
            active_bytes: memory::program_active_bytes(&spec),
            top_fit: None,
        })
    }

    fn full_forward(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<Vec<Vec<f32>>> {
        TileOracle::new(&self.rt, g, model)?.forward(g, params)
    }

    fn full_grad(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<OracleResult> {
        TileOracle::new(&self.rt, g, model)?.full_grad(g, params)
    }

    fn evaluate(&self, g: &Graph, params: &Params, model: &ModelSpec) -> Result<EvalResult> {
        TileOracle::new(&self.rt, g, model)?.evaluate(g, params)
    }

    fn exec_secs(&self) -> f64 {
        self.rt.total_exec_secs()
    }
}

/// Assemble the positional input literals for the train_step program from
/// the sparse subgraph (densified on demand) + gathered histories.
fn assemble_inputs(
    spec: &crate::runtime::ProgramSpec,
    inp: &StepInputs,
) -> Result<Vec<xla::Literal>> {
    let g = inp.graph;
    let sb = inp.sb;
    let params = inp.params;
    let (bb, bh) = (sb.bucket_b, sb.bucket_h);
    let (a_bb, a_bh, a_hh) = sb.to_dense();
    let mut out = Vec::with_capacity(spec.inputs.len());
    for ts in &spec.inputs {
        let name = ts.name.as_str();
        let lit = if let Some(pi) = params.index_of(name) {
            params.tensors[pi].to_literal()?
        } else if name == "X_b" {
            lit_f32(&gather_rows(&g.features, g.d_x, &sb.batch, bb), &[bb, g.d_x])?
        } else if name == "X_h" {
            lit_f32(&gather_rows(&g.features, g.d_x, &sb.halo, bh), &[bh, g.d_x])?
        } else if name == "A_bb" {
            lit_f32(&a_bb, &[bb, bb])?
        } else if name == "A_bh" {
            lit_f32(&a_bh, &[bb, bh])?
        } else if name == "A_hh" {
            lit_f32(&a_hh, &[bh, bh])?
        } else if let Some(l) = name.strip_prefix("histH") {
            let l: usize = l.parse()?;
            lit_f32(&inp.hist_h[l - 1], &[bh, ts.shape[1]])?
        } else if let Some(l) = name.strip_prefix("histV") {
            let l: usize = l.parse()?;
            lit_f32(&inp.hist_v[l - 1], &[bh, ts.shape[1]])?
        } else if name == "y_b" {
            lit_i32(&padded_labels(g, &sb.batch, bb), &[bb])?
        } else if name == "y_h" {
            lit_i32(&padded_labels(g, &sb.halo, bh), &[bh])?
        } else if name == "mask_b" {
            lit_f32(&train_mask(g, &sb.batch, bb), &[bb])?
        } else if name == "mask_h" {
            lit_f32(&train_mask(g, &sb.halo, bh), &[bh])?
        } else if name == "beta" {
            lit_f32(&inp.beta, &[bh])?
        } else if name == "bwd_scale" {
            lit_scalar(inp.bwd_scale)
        } else if name == "vscale" {
            lit_scalar(inp.vscale)
        } else if name == "grad_scale" {
            lit_scalar(inp.grad_scale)
        } else {
            return Err(anyhow!("unknown train_step input '{name}'"));
        };
        out.push(lit);
    }
    Ok(out)
}

fn padded_labels(g: &Graph, idx: &[u32], rows: usize) -> Vec<i32> {
    let mut y = vec![0i32; rows];
    for (i, &u) in idx.iter().enumerate() {
        y[i] = g.labels[u as usize] as i32;
    }
    y
}

fn train_mask(g: &Graph, idx: &[u32], rows: usize) -> Vec<f32> {
    let mut m = vec![0f32; rows];
    for (i, &u) in idx.iter().enumerate() {
        if g.split[u as usize] == 0 {
            m[i] = 1.0;
        }
    }
    m
}

// ---------------------------------------------------------------------------
// exact tile-wise oracle (ported from coordinator/exact.rs)
// ---------------------------------------------------------------------------

/// Exact tile-wise computation over the full graph: contiguous node ranges
/// (the trainer permutes the graph so partition clusters are contiguous,
/// giving tiles locality and small exact halos). Per-tile adjacency blocks
/// are densified once and cached.
struct TileOracle<'a> {
    rt: &'a Runtime,
    profile: String,
    arch_name: String,
    l: usize,
    dims: Vec<usize>,
    bt: usize,
    ht: usize,
    tiles: Vec<(usize, usize)>,
    halos: Vec<Vec<u32>>,
    /// cached (A_bb, A_bh) dense padded blocks per tile
    blocks: Vec<(Vec<f32>, Vec<f32>)>,
}

impl<'a> TileOracle<'a> {
    fn new(rt: &'a Runtime, g: &Graph, model: &ModelSpec) -> Result<TileOracle<'a>> {
        let arch = rt.manifest.arch(&model.profile, &model.arch_name)?.clone();
        let prof = rt
            .manifest
            .profiles
            .get(&model.profile)
            .ok_or_else(|| anyhow!("no profile {}", model.profile))?;
        let (bt, ht) = prof.exact_bucket;
        let n = g.n();

        // contiguous tiles whose exact halo fits the bucket
        let mut tiles = Vec::new();
        let mut s = 0usize;
        while s < n {
            let mut e = (s + bt).min(n);
            loop {
                let halo = exact_halo(g, s, e);
                if halo.len() <= ht {
                    tiles.push((s, e));
                    break;
                }
                let new_e = s + (e - s) / 2;
                if new_e <= s {
                    bail!(
                        "exact halo of single-node tile exceeds bucket H={ht}; \
                         rebuild artifacts with a larger exact_bucket"
                    );
                }
                e = new_e;
            }
            s = e;
        }

        let halos: Vec<Vec<u32>> = tiles.iter().map(|&(s, e)| exact_halo(g, s, e)).collect();
        let mut blocks = Vec::with_capacity(tiles.len());
        for (ti, &(s, e)) in tiles.iter().enumerate() {
            blocks.push(dense_blocks(g, s, e, &halos[ti], bt, ht));
        }
        Ok(TileOracle {
            rt,
            profile: model.profile.clone(),
            arch_name: model.arch_name.clone(),
            l: arch.l,
            dims: arch.dims,
            bt,
            ht,
            tiles,
            halos,
            blocks,
        })
    }

    fn layer_param_lits(&self, params: &Params, l: usize) -> Result<Vec<xla::Literal>> {
        let arch = self.rt.manifest.arch(&self.profile, &self.arch_name)?;
        let names = arch
            .layer_params
            .get(&l)
            .ok_or_else(|| anyhow!("no layer_params for layer {l}"))?;
        names
            .iter()
            .map(|n| {
                params
                    .get(n)
                    .ok_or_else(|| anyhow!("missing param {n}"))?
                    .to_literal()
            })
            .collect()
    }

    /// h0 (embed0 output) for all nodes. Identity for GCN.
    fn embed0_full(&self, g: &Graph, params: &Params) -> Result<Vec<f32>> {
        if self.arch_name == "gcn" {
            return Ok(g.features.clone());
        }
        let prog = self.rt.manifest.embed0(&self.profile, &self.arch_name)?.name.clone();
        let d0 = self.dims[0];
        let mut out = vec![0f32; g.n() * d0];
        let w0 = params.get("W0").unwrap().to_literal()?;
        let b0 = params.get("b0").unwrap().to_literal()?;
        for &(s, e) in &self.tiles {
            let xt = gather_range(&g.features, g.d_x, s, e, self.bt);
            let res = self.rt.execute(
                &prog,
                &[lit_f32(&xt, &[self.bt, g.d_x])?, w0.clone(), b0.clone()],
            )?;
            let h0 = to_vec_f32(&res[0])?;
            out[s * d0..e * d0].copy_from_slice(&h0[..(e - s) * d0]);
        }
        Ok(out)
    }

    /// Exact forward: H^l for all nodes, l = 0..L.
    fn forward(&self, g: &Graph, params: &Params) -> Result<Vec<Vec<f32>>> {
        let h0 = self.embed0_full(g, params)?;
        let mut hs = vec![h0.clone()];
        let mut cur = h0.clone();
        for l in 1..=self.l {
            let d_prev = self.dims[l - 1];
            let d_l = self.dims[l];
            let prog = self.rt.manifest.fwd_layer(&self.profile, &self.arch_name, l)?.name.clone();
            let pl = self.layer_param_lits(params, l)?;
            let mut next = vec![0f32; g.n() * d_l];
            for (ti, &(s, e)) in self.tiles.iter().enumerate() {
                let (abb, abh) = &self.blocks[ti];
                let hp_t = gather_range(&cur, d_prev, s, e, self.bt);
                let hp_h = gather_rows(&cur, d_prev, &self.halos[ti], self.ht);
                let h0_t = gather_range(&h0, self.dims[0], s, e, self.bt);
                let mut inputs = vec![
                    lit_f32(abb, &[self.bt, self.bt])?,
                    lit_f32(abh, &[self.bt, self.ht])?,
                    lit_f32(&hp_t, &[self.bt, d_prev])?,
                    lit_f32(&hp_h, &[self.ht, d_prev])?,
                    lit_f32(&h0_t, &[self.bt, self.dims[0]])?,
                ];
                inputs.extend(pl.iter().cloned());
                let res = self.rt.execute(&prog, &inputs)?;
                let ht_out = to_vec_f32(&res[0])?;
                next[s * d_l..e * d_l].copy_from_slice(&ht_out[..(e - s) * d_l]);
            }
            hs.push(next.clone());
            cur = next;
        }
        Ok(hs)
    }

    /// Evaluation: accuracy per split and the mean training loss.
    fn evaluate(&self, g: &Graph, params: &Params) -> Result<EvalResult> {
        let hs = self.forward(g, params)?;
        let hl = &hs[self.l];
        let d_l = self.dims[self.l];
        let prog = self.rt.manifest.loss_grad(&self.profile, &self.arch_name)?.clone();
        let arch = self.rt.manifest.arch(&self.profile, &self.arch_name)?;
        let head_lits: Vec<xla::Literal> = arch
            .head_params
            .iter()
            .map(|n| params.get(n).unwrap().to_literal().unwrap())
            .collect();
        let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);
        let mut loss_sum = 0f64;
        // slot 3 absorbs sentinel splits (sharded halo rows); see native.rs
        let mut correct = [0usize; 4];
        let mut total = [0usize; 4];
        let nc = g.n_class;
        let logits_idx = prog.output_index("logits_t")?;
        for &(s, e) in &self.tiles {
            let hl_t = gather_range(hl, d_l, s, e, self.bt);
            let y: Vec<i32> = (s..e)
                .map(|u| g.labels[u] as i32)
                .chain(std::iter::repeat_n(0, self.bt - (e - s)))
                .collect();
            let mask: Vec<f32> = (s..e)
                .map(|u| if g.split[u] == 0 { 1.0 } else { 0.0 })
                .chain(std::iter::repeat_n(0.0, self.bt - (e - s)))
                .collect();
            let mut inputs = vec![
                lit_f32(&hl_t, &[self.bt, d_l])?,
                lit_i32(&y, &[self.bt])?,
                lit_f32(&mask, &[self.bt])?,
                lit_scalar(1.0 / n_train as f32),
            ];
            inputs.extend(head_lits.iter().cloned());
            let res = self.rt.execute(&prog.name, &inputs)?;
            loss_sum += to_vec_f32(&res[0])?[0] as f64;
            let logits = to_vec_f32(&res[logits_idx])?;
            for u in s..e {
                let row = &logits[(u - s) * nc..(u - s + 1) * nc];
                let pred = argmax(row);
                let split = (g.split[u] as usize).min(3);
                total[split] += 1;
                if pred == g.labels[u] as usize {
                    correct[split] += 1;
                }
            }
        }
        Ok(EvalResult {
            train_loss: loss_sum / n_train as f64,
            train_acc: acc(correct[0], total[0]),
            val_acc: acc(correct[1], total[1]),
            test_acc: acc(correct[2], total[2]),
        })
    }

    /// Full-batch gradient via backward SGD over all tiles (exact).
    fn full_grad(&self, g: &Graph, params: &Params) -> Result<OracleResult> {
        let hs = self.forward(g, params)?;
        let arch = self.rt.manifest.arch(&self.profile, &self.arch_name)?.clone();
        let n = g.n();
        let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);
        let vscale = 1.0 / n_train as f32;
        let mut grads: Vec<Tensor> =
            arch.params.iter().map(|(_, s)| Tensor::zeros(s)).collect();
        let pidx: HashMap<&str, usize> = arch
            .params
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.as_str(), i))
            .collect();

        // V^L from the loss head, tile by tile
        let d_l = self.dims[self.l];
        let mut v = vec![0f32; n * d_l];
        let mut loss_sum = 0f64;
        {
            let prog = self.rt.manifest.loss_grad(&self.profile, &self.arch_name)?.clone();
            let head_lits: Vec<xla::Literal> = arch
                .head_params
                .iter()
                .map(|nm| params.get(nm).unwrap().to_literal().unwrap())
                .collect();
            for &(s, e) in &self.tiles {
                let hl_t = gather_range(&hs[self.l], d_l, s, e, self.bt);
                let y: Vec<i32> = (s..e)
                    .map(|u| g.labels[u] as i32)
                    .chain(std::iter::repeat_n(0, self.bt - (e - s)))
                    .collect();
                let mask: Vec<f32> = (s..e)
                    .map(|u| if g.split[u] == 0 { 1.0 } else { 0.0 })
                    .chain(std::iter::repeat_n(0.0, self.bt - (e - s)))
                    .collect();
                let mut inputs = vec![
                    lit_f32(&hl_t, &[self.bt, d_l])?,
                    lit_i32(&y, &[self.bt])?,
                    lit_f32(&mask, &[self.bt])?,
                    lit_scalar(vscale),
                ];
                inputs.extend(head_lits.iter().cloned());
                let res = self.rt.execute(&prog.name, &inputs)?;
                loss_sum += to_vec_f32(&res[0])?[0] as f64;
                let vt = to_vec_f32(&res[prog.output_index("V_t")?])?;
                v[s * d_l..e * d_l].copy_from_slice(&vt[..(e - s) * d_l]);
                for nm in arch.head_params.iter() {
                    let gh = to_vec_f32(&res[prog.output_index(&format!("g_{nm}"))?])?;
                    add_into(&mut grads[pidx[nm.as_str()]].data, &gh);
                }
            }
        }

        // backward layer by layer, scatter-adding contributions
        let mut c0 = vec![0f32; n * self.dims[0]];
        let mut v_layers: Vec<Vec<f32>> = vec![Vec::new(); self.l + 1]; // [l] = V^l
        v_layers[self.l] = v.clone();
        let h0 = &hs[0];
        for l in (1..=self.l).rev() {
            let d_prev = self.dims[l - 1];
            let d_cur = self.dims[l];
            let prog = self.rt.manifest.bwd_layer(&self.profile, &self.arch_name, l)?.clone();
            let lp = arch.layer_params.get(&l).unwrap().clone();
            let pl = self.layer_param_lits(params, l)?;
            let mut vprev = vec![0f32; n * d_prev];
            for (ti, &(s, e)) in self.tiles.iter().enumerate() {
                let (abb, abh) = &self.blocks[ti];
                let hp_t = gather_range(&hs[l - 1], d_prev, s, e, self.bt);
                let hp_h = gather_rows(&hs[l - 1], d_prev, &self.halos[ti], self.ht);
                let h0_t = gather_range(h0, self.dims[0], s, e, self.bt);
                let v_t = gather_range(&v, d_cur, s, e, self.bt);
                let mut inputs = vec![
                    lit_f32(abb, &[self.bt, self.bt])?,
                    lit_f32(abh, &[self.bt, self.ht])?,
                    lit_f32(&hp_t, &[self.bt, d_prev])?,
                    lit_f32(&hp_h, &[self.ht, d_prev])?,
                    lit_f32(&h0_t, &[self.bt, self.dims[0]])?,
                    lit_f32(&v_t, &[self.bt, d_cur])?,
                ];
                inputs.extend(pl.iter().cloned());
                let res = self.rt.execute(&prog.name, &inputs)?;
                for (gi, nm) in lp.iter().enumerate() {
                    let gv = to_vec_f32(&res[gi])?;
                    add_into(&mut grads[pidx[nm.as_str()]].data, &gv);
                }
                let vt = to_vec_f32(&res[prog.output_index("Vprev_t")?])?;
                for u in s..e {
                    add_into(
                        &mut vprev[u * d_prev..(u + 1) * d_prev],
                        &vt[(u - s) * d_prev..(u - s + 1) * d_prev],
                    );
                }
                let vh = to_vec_f32(&res[prog.output_index("Vprev_h")?])?;
                for (hi, &u) in self.halos[ti].iter().enumerate() {
                    let u = u as usize;
                    add_into(
                        &mut vprev[u * d_prev..(u + 1) * d_prev],
                        &vh[hi * d_prev..(hi + 1) * d_prev],
                    );
                }
                let ch = to_vec_f32(&res[prog.output_index("Ch0_t")?])?;
                for u in s..e {
                    add_into(
                        &mut c0[u * self.dims[0]..(u + 1) * self.dims[0]],
                        &ch[(u - s) * self.dims[0]..(u - s + 1) * self.dims[0]],
                    );
                }
            }
            v = vprev;
            if l >= 2 {
                v_layers[l - 1] = v.clone();
            }
        }
        // V^0 is the h0 cotangent via the h_prev path
        add_into(&mut c0, &v);

        if self.arch_name == "gcnii" {
            let prog = self.rt.manifest.embed0_bwd(&self.profile, &self.arch_name)?.clone();
            let w0 = params.get("W0").unwrap().to_literal()?;
            let b0 = params.get("b0").unwrap().to_literal()?;
            for &(s, e) in &self.tiles {
                let xt = gather_range(&g.features, g.d_x, s, e, self.bt);
                let ct = gather_range(&c0, self.dims[0], s, e, self.bt);
                let res = self.rt.execute(
                    &prog.name,
                    &[
                        lit_f32(&xt, &[self.bt, g.d_x])?,
                        lit_f32(&ct, &[self.bt, self.dims[0]])?,
                        w0.clone(),
                        b0.clone(),
                    ],
                )?;
                add_into(&mut grads[pidx["W0"]].data, &to_vec_f32(&res[0])?);
                add_into(&mut grads[pidx["b0"]].data, &to_vec_f32(&res[1])?);
            }
        }

        Ok(OracleResult { grads, train_loss: loss_sum / n_train as f64, h_layers: hs, v_layers })
    }
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Dense padded (A_bb, A_bh) for a contiguous tile + halo list, with global
/// GCN normalization and self-loops on the diagonal.
fn dense_blocks(g: &Graph, s: usize, e: usize, halo: &[u32], bt: usize, ht: usize) -> (Vec<f32>, Vec<f32>) {
    let mut abb = vec![0f32; bt * bt];
    let mut abh = vec![0f32; bt * ht];
    let hpos: HashMap<u32, usize> =
        halo.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    for u in s..e {
        let i = u - s;
        abb[i * bt + i] = g.self_w[u];
        let (es, ee) = (g.csr.offsets[u] as usize, g.csr.offsets[u + 1] as usize);
        for ei in es..ee {
            let v = g.csr.neighbors[ei] as usize;
            let w = g.edge_w[ei];
            if v >= s && v < e {
                abb[i * bt + (v - s)] = w;
            } else {
                abh[i * ht + hpos[&(v as u32)]] = w;
            }
        }
    }
    (abb, abh)
}

fn gather_range(src: &[f32], d: usize, s: usize, e: usize, rows: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    out[..(e - s) * d].copy_from_slice(&src[s * d..e * d]);
    out
}
