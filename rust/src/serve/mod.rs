//! Online inference service over the native backend (ROADMAP "serve
//! path"): a long-lived [`ServeEngine`] that holds a read-only graph,
//! trained [`Params`], and a warm [`History`], and answers
//! `predict(node_ids)` requests by assembling batched tiles through the
//! fused SIMD forward kernels — no backward, no optimizer state.
//!
//! Two tile-assembly paths, selected by [`ServeMode`]:
//!
//!   * **Exact** — the requested core set is expanded one hop per layer
//!     into its L-hop closure and every layer is evaluated only on the
//!     rows the next layer needs, mirroring the full-graph oracle's
//!     per-row operations exactly (same GEMM kernels, same per-row
//!     aggregation order). Served logits are **bit-identical** to
//!     `Executor::full_forward` + the output head
//!     (`tests/integration_serve.rs`); cost grows with the closure size.
//!   * **Cached** — LMC's own trick turned into a serving strategy: a
//!     1-hop tile (core + halo) through the sampler's [`CsrBlock`]
//!     machinery, with halo rows at layers 1..L-1 combined against the
//!     cached-history embeddings (Eq. 9; `beta = 0` serves pure history).
//!     With a warm history this tracks the oracle to ~1e-4 at 1-hop cost
//!     — the transductive mini-batch inference argument of "Accurate and
//!     Scalable GNNs via Message Invariance" (PAPERS.md).
//!
//! Parameter updates go through [`ServeEngine::set_params`], which bumps
//! the params version and *invalidates* the warm history; the refresh
//! hook ([`ServeEngine::refresh_history`]) recomputes every cached row
//! from an exact full forward, so an update → refresh → re-predict
//! sequence is deterministic. Requests are micro-batched by
//! [`MicroBatcher`] (size/latency knob; see [`batcher`]).
//!
//! [`CsrBlock`]: crate::sampler::CsrBlock

pub mod batcher;
pub mod net;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};
use rayon::prelude::*;

use crate::backend::native::{self, kind_of, Kind};
use crate::backend::{gemm, Backend, Executor, ModelSpec, NativeExecutor, StepWorkspace};
use crate::compensation::{self, Compensation, NoComp};
use crate::config::RunConfig;
use crate::coordinator::exact::argmax;
use crate::coordinator::params::Params;
use crate::graph::{load, Graph};
use crate::history::{HistDtype, History};
use crate::runtime::ArchInfo;
use crate::sampler::{build_subgraph, gather_rows, AdjacencyPolicy, Buckets, HaloSampler};
use crate::util::rng::Rng;

pub use batcher::{BatchPolicy, MicroBatcher, ServeRequest};
pub use net::{serve_tcp, LoopStats, ServeLoop, Sink};

/// Which tile-assembly path answers a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// L-hop exact closure; bit-identical to the full-graph oracle.
    Exact,
    /// 1-hop core + cached-history halo (Eq. 9 combination).
    Cached,
}

impl ServeMode {
    pub fn parse(s: &str) -> Option<ServeMode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "exact" | "oracle" => ServeMode::Exact,
            "cached" | "history" | "lmc" => ServeMode::Cached,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServeMode::Exact => "exact",
            ServeMode::Cached => "cached",
        }
    }
}

/// Engine knobs (`serve_*` keys in the run config).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub mode: ServeMode,
    /// Maximum core nodes per assembled tile; a larger request is split
    /// into this many-node tiles (each requested node lands in exactly
    /// one tile — `prop_serve_tiling_covers_each_requested_node_once`).
    pub tile_nodes: usize,
    /// Storage dtype for the warm history rows (`history_dtype` knob):
    /// halo reads on the cached path decode through the same
    /// [`History`] seam training uses, so bf16/f16 serving halves the
    /// resident bytes per node at a bounded per-element decode error.
    pub history_dtype: HistDtype,
    /// Halo subsampling policy for the cached path's tile assembly
    /// (`halo_sampler`/`halo_keep` knobs): a subsampling policy shrinks
    /// each tile's halo with Horvitz–Thompson rescaled edges, trading a
    /// little logit noise for smaller history gathers per tile. The
    /// default passthrough serves with the full 1-hop halo, bit-identical
    /// to the pre-sampler behaviour.
    pub halo_sampler: HaloSampler,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            mode: ServeMode::Cached,
            tile_nodes: 256,
            history_dtype: HistDtype::F32,
            halo_sampler: HaloSampler::none(),
        }
    }
}

/// One served node: predicted class plus the full output-head logits.
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub node: u32,
    pub label: u16,
    pub logits: Vec<f32>,
}

/// Split a sorted, deduplicated request set into tiles of at most
/// `max_tile` core nodes. Tiles partition the set: union covers it and
/// every node appears in exactly one tile.
pub fn plan_tiles(sorted_unique: &[u32], max_tile: usize) -> Vec<Vec<u32>> {
    debug_assert!(sorted_unique.windows(2).all(|w| w[0] < w[1]), "tiles need sorted unique ids");
    sorted_unique.chunks(max_tile.max(1)).map(|c| c.to_vec()).collect()
}

/// Long-lived inference engine over the native backend.
pub struct ServeEngine {
    graph: Arc<Graph>,
    model: ModelSpec,
    opts: ServeOptions,
    exec: NativeExecutor,
    params: Params,
    /// Warm per-layer embeddings Hbar^l (l = 1..L-1) for the cached path;
    /// refreshed wholesale from an exact full forward.
    history: History,
    /// Compensation policy for the cached path's halo rows — it yields the
    /// per-halo-node Eq. 9 coefficients (all-zero = pure history, the
    /// default; the LMC policy mixes in the fresh incomplete value). This
    /// replaces the former `serve_beta` special case.
    comp: Box<dyn Compensation>,
    params_version: u64,
    /// The params version the history was last refreshed at; `None`
    /// until the first refresh and after every `set_params`.
    warm_version: Option<u64>,
    /// Steady-state tile buffers: repeated predicts reuse the same
    /// workspace pool the train step uses.
    ws: Mutex<StepWorkspace>,
    /// Exact-path scratch pool: epoch-stamped visited buffers and position
    /// maps, checked out per tile so steady-state serve does no O(n)
    /// allocations (the old `expand_one_hop` zeroed an O(n) bitmap per
    /// call, L times per tile).
    tile_ws: Mutex<Vec<TileWorkspace>>,
    tile_ws_misses: AtomicU64,
}

/// Exact-path tile workspaces retained for reuse; beyond this the engine is
/// answering that many tiles concurrently and extra workspaces are dropped
/// back to the allocator rather than hoarded.
const MAX_TILE_WS: usize = 8;

/// Reusable scratch for one exact-tile evaluation.
#[derive(Default)]
struct TileWorkspace {
    /// `visited[u] == epoch` ⟺ `u` is in the set being built this pass;
    /// bumping the epoch invalidates the whole buffer in O(1) instead of
    /// re-zeroing O(n) bytes per expansion.
    visited: Vec<u32>,
    epoch: u32,
    /// Scatter maps node id → row index in the current layer's (`pos`) and
    /// embed0's (`pos0`) row blocks. Stale entries are never read — every
    /// lookup is for a node the same pass just scattered (closure
    /// property) — so reuse needs no clearing and stays bit-identical.
    pos: Vec<u32>,
    pos0: Vec<u32>,
}

impl TileWorkspace {
    fn ensure(&mut self, n: usize) {
        if self.visited.len() < n {
            self.visited.resize(n, 0);
            self.pos.resize(n, u32::MAX);
            self.pos0.resize(n, u32::MAX);
        }
    }

    /// `nodes ∪ N(nodes)`, sorted unique — one closure-expansion step.
    fn expand_one_hop(&mut self, g: &Graph, nodes: &[u32]) -> Vec<u32> {
        self.ensure(g.n());
        self.epoch = match self.epoch.checked_add(1) {
            Some(e) => e,
            None => {
                // epoch wrap: one O(n) re-zero every u32::MAX expansions
                self.visited.iter_mut().for_each(|v| *v = 0);
                1
            }
        };
        let ep = self.epoch;
        let mut out: Vec<u32> = Vec::with_capacity(nodes.len() * 2);
        for &u in nodes {
            if self.visited[u as usize] != ep {
                self.visited[u as usize] = ep;
                out.push(u);
            }
            for &v in g.csr.neighbors(u as usize) {
                if self.visited[v as usize] != ep {
                    self.visited[v as usize] = ep;
                    out.push(v);
                }
            }
        }
        out.sort_unstable();
        out
    }
}

impl ServeEngine {
    /// Engine over explicit parts (tests, embedding into other runtimes).
    /// Serves with the `NoComp` policy — halo rows on the cached path read
    /// pure warm history, the historical default.
    pub fn new(
        graph: Arc<Graph>,
        model: ModelSpec,
        params: Params,
        opts: ServeOptions,
    ) -> Result<ServeEngine> {
        Self::with_exec(NativeExecutor::new(), graph, model, params, opts, Box::new(NoComp))
    }

    /// [`ServeEngine::new`] with an explicit compensation policy for the
    /// cached path.
    pub fn with_comp(
        graph: Arc<Graph>,
        model: ModelSpec,
        params: Params,
        opts: ServeOptions,
        comp: Box<dyn Compensation>,
    ) -> Result<ServeEngine> {
        Self::with_exec(NativeExecutor::new(), graph, model, params, opts, comp)
    }

    fn with_exec(
        exec: NativeExecutor,
        graph: Arc<Graph>,
        model: ModelSpec,
        params: Params,
        opts: ServeOptions,
        comp: Box<dyn Compensation>,
    ) -> Result<ServeEngine> {
        validate_params(&model.arch, &params)?;
        let hist_dims: Vec<usize> = model.arch.dims[1..model.arch.l].to_vec();
        let history = History::with_dtype(graph.n(), &hist_dims, opts.history_dtype);
        Ok(ServeEngine {
            graph,
            model,
            opts,
            exec,
            params,
            history,
            comp,
            params_version: 0,
            warm_version: None,
            ws: Mutex::new(StepWorkspace::new()),
            tile_ws: Mutex::new(Vec::new()),
            tile_ws_misses: AtomicU64::new(0),
        })
    }

    /// Engine from a run config: loads the dataset, resolves the arch
    /// through the native executor, and uses `params` when given (the
    /// `lmc train --save-params` → `Params::load` round-trip) or fresh
    /// seeded Glorot parameters otherwise.
    pub fn from_config(cfg: &RunConfig, params: Option<Params>) -> Result<ServeEngine> {
        if cfg.backend != Backend::Native {
            bail!(
                "the serve path runs on the native backend (got backend = \"{}\")",
                cfg.backend.name()
            );
        }
        let exec = NativeExecutor::new();
        let graph = Arc::new(load(cfg.dataset, cfg.seed));
        let profile = cfg.dataset.profile().to_string();
        let prof = exec.resolve_profile(&profile)?;
        if graph.d_x != prof.d_x || graph.n_class != prof.n_class {
            bail!(
                "dataset {} dims (d_x={}, c={}) do not match profile {} (d_x={}, c={})",
                cfg.dataset.name(),
                graph.d_x,
                graph.n_class,
                profile,
                prof.d_x,
                prof.n_class
            );
        }
        let arch = exec.resolve_arch(&profile, &cfg.arch)?;
        let params =
            params.unwrap_or_else(|| Params::init(&arch, &mut Rng::new(cfg.seed ^ 0x7E57)));
        let model = ModelSpec { profile, arch_name: cfg.arch.clone(), arch };
        let opts = ServeOptions {
            mode: cfg.serve_mode,
            tile_nodes: cfg.serve_max_batch,
            history_dtype: cfg.history_dtype,
            halo_sampler: cfg.halo_sampler(),
        };
        let comp = compensation::for_serve(cfg)?;
        Self::with_exec(exec, graph, model, params, opts, comp)
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    pub fn opts(&self) -> &ServeOptions {
        &self.opts
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    pub fn params_version(&self) -> u64 {
        self.params_version
    }

    /// Backend executor (exec-clock telemetry: `exec.exec_secs()`).
    pub fn exec(&self) -> &NativeExecutor {
        &self.exec
    }

    /// Storage dtype of the warm history rows.
    pub fn history_dtype(&self) -> HistDtype {
        self.history.dtype()
    }

    /// Resident history bytes per graph node (`2·(H+V)·Σ d_l·sizeof`,
    /// the startup-log / BENCH_serve accounting figure).
    pub fn history_bytes_per_node(&self) -> usize {
        self.history.bytes_per_node()
    }

    /// Times the exact path allocated a fresh tile workspace because the
    /// pool was empty. Steady-state serve must not climb — pinned by
    /// `exact_serve_tile_workspace_misses_stay_flat`.
    pub fn tile_ws_misses(&self) -> u64 {
        self.tile_ws_misses.load(Ordering::Relaxed)
    }

    /// True when the cached-history rows were computed at the current
    /// parameters.
    pub fn is_warm(&self) -> bool {
        self.warm_version == Some(self.params_version)
    }

    /// Swap in updated parameters (e.g. from a concurrent training run).
    /// Bumps the params version and invalidates the warm history — every
    /// cached row was computed under the old parameters, so the cached
    /// path refuses to serve until [`ServeEngine::refresh_history`] runs.
    pub fn set_params(&mut self, params: Params) -> Result<()> {
        validate_params(&self.model.arch, &params)?;
        self.params = params;
        self.params_version += 1;
        self.warm_version = None;
        Ok(())
    }

    /// The history-refresh hook: recompute every cached row from an exact
    /// full-graph forward at the current parameters. Deterministic — two
    /// refreshes at the same params produce bit-identical rows — so an
    /// update → invalidate → refresh → re-predict sequence replays
    /// exactly (`param_update_then_repredict_is_deterministic`).
    pub fn refresh_history(&mut self) -> Result<()> {
        let hs = self.exec.full_forward(self.graph.as_ref(), &self.params, &self.model)?;
        for l in 1..self.model.arch.l {
            // bulk write through the dtype seam: quantized stores encode
            // here and halo gathers decode on the fly, so cached-path
            // reads never see a full-width scratch copy of these rows
            self.history.fill_h(l, &hs[l]);
        }
        // every cached row is freshly written as of this refresh
        self.history.iter += 1;
        let it = self.history.iter;
        self.history.last_update.iter_mut().for_each(|t| *t = it);
        self.warm_version = Some(self.params_version);
        Ok(())
    }

    /// Predict the configured mode for a list of node ids (duplicates
    /// allowed; output is aligned with the input order).
    pub fn predict(&self, nodes: &[u32]) -> Result<Vec<Prediction>> {
        self.predict_in_mode(nodes, self.opts.mode)
    }

    /// Predict with an explicit mode (benches A/B the two paths).
    pub fn predict_in_mode(&self, nodes: &[u32], mode: ServeMode) -> Result<Vec<Prediction>> {
        if nodes.is_empty() {
            return Ok(Vec::new());
        }
        let n = self.graph.n() as u32;
        for &u in nodes {
            if u >= n {
                bail!("node id {u} out of range (graph has {n} nodes)");
            }
        }
        if mode == ServeMode::Cached && !self.is_warm() {
            bail!(
                "cached-history serve path is stale (params at version {}, history warmed at \
                 {:?}): call refresh_history() after set_params()",
                self.params_version,
                self.warm_version
            );
        }
        // tiles are a partition of the deduplicated request set, so every
        // requested node is assembled and served exactly once
        let mut unique = nodes.to_vec();
        unique.sort_unstable();
        unique.dedup();
        let mut logits: Vec<f32> = Vec::new();
        for tile in plan_tiles(&unique, self.opts.tile_nodes) {
            logits.extend(self.tile_logits(&tile, mode)?);
        }
        let c = logits.len() / unique.len();
        Ok(nodes
            .iter()
            .map(|&u| {
                let i = unique.binary_search(&u).expect("requested node was tiled");
                let row = &logits[i * c..(i + 1) * c];
                Prediction { node: u, label: argmax(row) as u16, logits: row.to_vec() }
            })
            .collect())
    }

    /// Answer a micro-batch drained from [`MicroBatcher`] in one engine
    /// pass: all requests' nodes are tiled together, then results are
    /// routed back per request id.
    pub fn answer(&self, batch: &[ServeRequest]) -> Result<Vec<(u64, Vec<Prediction>)>> {
        let all: Vec<u32> = batch.iter().flat_map(|r| r.nodes.iter().copied()).collect();
        let preds = self.predict(&all)?;
        let mut out = Vec::with_capacity(batch.len());
        let mut off = 0;
        for r in batch {
            out.push((r.id, preds[off..off + r.nodes.len()].to_vec()));
            off += r.nodes.len();
        }
        Ok(out)
    }

    /// Full-graph output-head logits (`[n, c]`) through the exact oracle
    /// forward — the reference the integration tests compare served
    /// logits against.
    pub fn oracle_logits(&self) -> Result<Vec<f32>> {
        let hs = self.exec.full_forward(self.graph.as_ref(), &self.params, &self.model)?;
        self.head_logits(&hs[self.model.arch.l], self.graph.n())
    }

    fn tile_logits(&self, tile: &[u32], mode: ServeMode) -> Result<Vec<f32>> {
        match mode {
            ServeMode::Exact => self.exec.time_scope(|| self.exact_tile_logits(tile)),
            ServeMode::Cached => self.cached_tile_logits(tile),
        }
    }

    /// 1-hop tile through the sampler's CSR-block machinery: core rows are
    /// computed with full in-tile messages, halo rows come from the warm
    /// history via the Eq. 9 combination inside the forward-only backend
    /// entry.
    fn cached_tile_logits(&self, tile: &[u32]) -> Result<Vec<f32>> {
        let l_total = self.model.arch.l;
        // With the default passthrough sampler and unbounded buckets the
        // build never consumes randomness, so the fixed-seed stream is
        // inert and a tile's logits are deterministic. A subsampling
        // policy draws from this per-tile stream: seeding by the tile's
        // first node keeps repeated requests for the same tile identical.
        let mut rng = Rng::new(tile.first().copied().unwrap_or(0) as u64 ^ 0x5EED);
        let sb = build_subgraph(
            self.graph.as_ref(),
            tile,
            AdjacencyPolicy::GlobalWithHalo,
            &Buckets::unbounded(),
            &self.opts.halo_sampler,
            &mut rng,
        )?;
        let hist_h: Vec<Vec<f32>> = (1..l_total)
            .map(|l| self.history.gather_h(l, &sb.halo, sb.halo.len()))
            .collect();
        let beta = self.comp.serve_beta(&sb);
        self.exec.forward_logits(
            self.graph.as_ref(),
            &sb,
            &self.model,
            &self.params,
            &hist_h,
            &beta,
            Some(&self.ws),
        )
    }

    /// Exact L-hop tile: evaluate layer l only on the closure set that
    /// still influences the requested rows, mirroring the full-graph
    /// oracle's per-row operations exactly (same GEMM kernels, identical
    /// per-row aggregation order: self-loop first, then neighbors in
    /// global CSR order), so served logits are bit-identical to
    /// [`ServeEngine::oracle_logits`] rows.
    fn exact_tile_logits(&self, tile: &[u32]) -> Result<Vec<f32>> {
        // check a workspace out of the pool (allocating only when every
        // pooled one is in use by a concurrent tile), return it after
        let mut ws = match self.tile_ws.lock().unwrap_or_else(|p| p.into_inner()).pop() {
            Some(ws) => ws,
            None => {
                self.tile_ws_misses.fetch_add(1, Ordering::Relaxed);
                TileWorkspace::default()
            }
        };
        let out = self.exact_tile_logits_in(tile, &mut ws);
        let mut pool = self.tile_ws.lock().unwrap_or_else(|p| p.into_inner());
        if pool.len() < MAX_TILE_WS {
            pool.push(ws);
        }
        out
    }

    fn exact_tile_logits_in(&self, tile: &[u32], ws: &mut TileWorkspace) -> Result<Vec<f32>> {
        let g = self.graph.as_ref();
        let arch = &self.model.arch;
        let dims = &arch.dims;
        let l_total = arch.l;
        let kind = kind_of(&self.model.arch_name)?;
        ws.ensure(g.n());

        // sets[l] = nodes whose exact H^l must be materialized;
        // sets[l_total] is the request tile, sets[l-1] = sets[l] ∪ N(sets[l])
        let mut sets: Vec<Vec<u32>> = Vec::with_capacity(l_total + 1);
        sets.push(tile.to_vec());
        for _ in 0..l_total {
            let wider = ws.expand_one_hop(g, sets.last().unwrap());
            sets.push(wider);
        }
        sets.reverse();
        let TileWorkspace { pos, pos0, .. } = ws;

        let p = |name: &str| {
            self.params.get(name).ok_or_else(|| anyhow!("missing parameter {name}"))
        };

        // H^0 rows over the widest set; GCNII keeps the embed0 output and
        // its position map for the α·h0 initial residual
        let s0 = &sets[0];
        let (mut h_prev, h0_rows) = match kind {
            Kind::Gcn => (gather_rows(&g.features, g.d_x, s0, s0.len()), Vec::new()),
            Kind::Gcnii => {
                let (w0, b0) = (p("W0")?, p("b0")?);
                let x = gather_rows(&g.features, g.d_x, s0, s0.len());
                let mut h0 = gemm::matmul(&x, s0.len(), g.d_x, &w0.data, dims[0]);
                native::add_bias_rows(&mut h0, &b0.data);
                native::relu_inplace(&mut h0);
                for (i, &u) in s0.iter().enumerate() {
                    pos0[u as usize] = i as u32;
                }
                (h0.clone(), h0)
            }
        };

        for l in 1..=l_total {
            let cur = &sets[l];
            let prev = &sets[l - 1];
            let d_prev = dims[l - 1];
            let d_l = dims[l];
            for (i, &u) in prev.iter().enumerate() {
                pos[u as usize] = i as u32;
            }
            // per-row aggregation in exactly full_aggregate's order; every
            // neighbor of a cur node is in prev by closure construction
            let mut agg = vec![0f32; cur.len() * d_prev];
            agg.par_chunks_mut(d_prev).enumerate().for_each(|(r, row)| {
                let u = cur[r] as usize;
                let sw = g.self_w[u];
                let src = row_of(&h_prev, pos[u], d_prev);
                for (o, &s) in row.iter_mut().zip(src) {
                    *o = sw * s;
                }
                for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
                    let v = g.csr.neighbors[ei] as usize;
                    let w = g.edge_w[ei];
                    let src = row_of(&h_prev, pos[v], d_prev);
                    for (o, &s) in row.iter_mut().zip(src) {
                        *o += w * s;
                    }
                }
            });
            let mut act = match kind {
                Kind::Gcn => {
                    let (w, b) = (p(&format!("W{l}"))?, p(&format!("b{l}"))?);
                    let mut z = gemm::matmul(&agg, cur.len(), d_prev, &w.data, d_l);
                    native::add_bias_rows(&mut z, &b.data);
                    z
                }
                Kind::Gcnii => {
                    let w = p(&format!("W{l}"))?;
                    let gam = native::gcnii_gamma(l);
                    let mut s = agg;
                    for (i, &u) in cur.iter().enumerate() {
                        let h0row = row_of(&h0_rows, pos0[u as usize], d_prev);
                        for (sv, &h0v) in
                            s[i * d_prev..(i + 1) * d_prev].iter_mut().zip(h0row)
                        {
                            *sv = (1.0 - native::GCNII_ALPHA) * *sv + native::GCNII_ALPHA * h0v;
                        }
                    }
                    let sw = gemm::matmul(&s, cur.len(), d_prev, &w.data, d_l);
                    let mut z = vec![0f32; cur.len() * d_l];
                    for ((zv, &sv), &swv) in z.iter_mut().zip(&s).zip(&sw) {
                        *zv = (1.0 - gam) * sv + gam * swv;
                    }
                    z
                }
            };
            if l < l_total || kind == Kind::Gcnii {
                native::relu_inplace(&mut act);
            }
            h_prev = act;
        }
        self.head_logits(&h_prev, sets[l_total].len())
    }

    /// Output head over `[rows, d_last]` representations: the backend's
    /// own `logits_of`, so tiles, the oracle reference, and training-side
    /// evaluation all share one head implementation (per-row identity is
    /// structural, not maintained by hand).
    fn head_logits(&self, h: &[f32], rows: usize) -> Result<Vec<f32>> {
        let d_last = self.model.arch.dims[self.model.arch.l];
        native::logits_of(kind_of(&self.model.arch_name)?, &self.params, h, rows, d_last)
    }
}

fn row_of(buf: &[f32], pos: u32, d: usize) -> &[f32] {
    let i = pos as usize;
    &buf[i * d..(i + 1) * d]
}

fn validate_params(arch: &ArchInfo, params: &Params) -> Result<()> {
    if params.names.len() != arch.params.len() {
        bail!(
            "parameter set has {} tensors, arch expects {}",
            params.names.len(),
            arch.params.len()
        );
    }
    for ((name, shape), (pn, pt)) in
        arch.params.iter().zip(params.names.iter().zip(&params.tensors))
    {
        if name != pn || shape != &pt.shape {
            bail!(
                "parameter mismatch: arch expects {name} {shape:?}, got {pn} {:?} \
                 (were these params saved for a different arch/profile?)",
                pt.shape
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_mode_parses() {
        assert_eq!(ServeMode::parse("exact"), Some(ServeMode::Exact));
        assert_eq!(ServeMode::parse("CACHED"), Some(ServeMode::Cached));
        assert_eq!(ServeMode::parse("lmc"), Some(ServeMode::Cached));
        assert!(ServeMode::parse("nope").is_none());
        assert_eq!(ServeMode::Exact.name(), "exact");
        assert_eq!(ServeMode::Cached.name(), "cached");
    }

    #[test]
    fn plan_tiles_partitions_and_caps() {
        let ids: Vec<u32> = (0..10).collect();
        let tiles = plan_tiles(&ids, 4);
        assert_eq!(tiles.len(), 3);
        assert!(tiles.iter().all(|t| t.len() <= 4 && !t.is_empty()));
        let flat: Vec<u32> = tiles.into_iter().flatten().collect();
        assert_eq!(flat, ids);
        // exact boundary: one tile
        assert_eq!(plan_tiles(&ids, 10).len(), 1);
        // zero knob degenerates to single-node tiles instead of dividing by zero
        assert_eq!(plan_tiles(&ids, 0).len(), 10);
        // empty request: no tiles
        assert!(plan_tiles(&[], 4).is_empty());
    }

    #[test]
    fn tile_workspace_expand_matches_naive_and_survives_epoch_wrap() {
        let g = load(crate::graph::DatasetId::CoraSim, 0);
        let naive = |nodes: &[u32]| -> Vec<u32> {
            let mut mark = vec![false; g.n()];
            let mut out = Vec::new();
            for &u in nodes {
                if !mark[u as usize] {
                    mark[u as usize] = true;
                    out.push(u);
                }
                for &v in g.csr.neighbors(u as usize) {
                    if !mark[v as usize] {
                        mark[v as usize] = true;
                        out.push(v);
                    }
                }
            }
            out.sort_unstable();
            out
        };
        let mut ws = TileWorkspace::default();
        let seeds: Vec<u32> = (0..g.n() as u32).step_by(97).collect();
        assert_eq!(ws.expand_one_hop(&g, &seeds), naive(&seeds));
        // repeated expansions reuse the stamped buffer, no re-zeroing
        assert_eq!(ws.expand_one_hop(&g, &seeds), naive(&seeds));
        // force the epoch counter to wrap: the visited buffer re-zeroes
        // once and results stay correct
        ws.epoch = u32::MAX;
        assert_eq!(ws.expand_one_hop(&g, &seeds), naive(&seeds));
        assert_eq!(ws.epoch, 1);
        assert_eq!(ws.expand_one_hop(&g, &[0]), naive(&[0]));
    }

    #[test]
    fn validate_params_rejects_mismatched_shapes() {
        let arch = ArchInfo::gcn(2, 4, 8, 3);
        let mut p = Params::init(&arch, &mut Rng::new(1));
        assert!(validate_params(&arch, &p).is_ok());
        p.tensors[0] = crate::runtime::Tensor::zeros(&[5, 5]);
        assert!(validate_params(&arch, &p).is_err());
        let q = Params { names: vec![], tensors: vec![] };
        assert!(validate_params(&arch, &q).is_err());
    }
}
