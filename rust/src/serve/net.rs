//! Networked front-end for the serve path, plus the transport-agnostic
//! [`ServeLoop`] core both the stdin JSONL loop and the TCP server drive.
//!
//! Wire protocol (`lmc serve --listen ADDR`): length-prefixed JSONL — each
//! frame is a little-endian `u32` byte count followed by that many bytes of
//! UTF-8 JSON, one request or response per frame. Requests are the same
//! shapes the stdin loop accepts (`[ids...]`, `{"id":N,"nodes":[ids...]}`,
//! `{"op":"shutdown"}`); responses are the same JSON lines the stdin loop
//! prints. Many client connections feed one shared [`MicroBatcher`] through
//! an mpsc channel, so micro-batches form *across* streams; each response
//! is routed back to the connection its request arrived on (the route queue
//! is FIFO-aligned with the batcher queue, which always drains whole
//! batches in push order).
//!
//! Shutdown reuses the stdin loop's graceful-drain semantics: on
//! SIGTERM/SIGINT (`should_stop`) or an `{"op":"shutdown"}` frame from any
//! connection, input already received is still parsed and answered, the
//! queue is flushed, and a final `{"op":"shutdown",...}` line carrying the
//! loop stats is broadcast to every open connection.
//!
//! Failpoint sites (`LMC_FAILPOINTS`): `serve.net.accept` rejects incoming
//! connections at the accept loop, `serve.net.read` injects a read failure
//! on an established connection — both leave the server itself up.
//!
//! [`run_loadtest`] is the `lmc loadtest` harness: open-loop arrival (every
//! request has a precomputed send time derived from the target qps, so a
//! slow server cannot slow the arrival process down) across N connections
//! with mixed request sizes, measuring per-request latency from the
//! *scheduled* send time to the response frame.

use std::collections::{BTreeMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::{BatchPolicy, MicroBatcher, Prediction, ServeEngine, ServeRequest};
use crate::util::failpoint;
use crate::util::json::Json;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// frame protocol
// ---------------------------------------------------------------------------

/// Hard cap on a single frame payload; a corrupt or hostile length prefix
/// must not trigger a multi-gigabyte allocation.
pub const MAX_FRAME: usize = 16 << 20;

/// Write one length-prefixed frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> io::Result<()> {
    let bytes = payload.as_bytes();
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Read one frame. `Ok(None)` is a clean close (EOF at a frame boundary);
/// EOF inside a frame, an oversized length prefix, or non-UTF-8 payload are
/// errors.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<String>> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid frame header",
                ))
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {n} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map(Some)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
}

// ---------------------------------------------------------------------------
// request parsing and response formatting (shared by both transports)
// ---------------------------------------------------------------------------

/// One parsed input line.
#[derive(Debug, PartialEq, Eq)]
pub enum Parsed {
    Request(ServeRequest),
    /// The documented `{"op":"shutdown"}` control line: graceful drain.
    Shutdown,
}

/// A rejected input line; `id` is the request's own id when it carried one,
/// so the error response can be correlated client-side.
#[derive(Debug)]
pub struct ParseErr {
    pub id: Option<u64>,
    pub msg: String,
}

fn node_id(j: &Json) -> Result<u32, String> {
    let x = j
        .as_f64()
        .ok_or_else(|| format!("node ids must be numbers, got {j}"))?;
    // `x as u32` would saturate -1 to 0 and truncate 3.7 to 3 — a silently
    // *wrong* prediction; non-integers and out-of-range values are errors
    if !x.is_finite() || x.fract() != 0.0 {
        return Err(format!("node id {j} is not an integer"));
    }
    if !(0.0..=u32::MAX as f64).contains(&x) {
        return Err(format!("node id {j} is out of u32 range"));
    }
    Ok(x as u32)
}

/// Parse one input line: a bare JSON array of node ids, an object
/// `{"id": N, "nodes": [ids...]}`, or the `{"op":"shutdown"}` control
/// line. Requests without an id get sequential ones.
pub fn parse_line(line: &str, next_id: &mut u64) -> Result<Parsed, ParseErr> {
    let bad = |id: Option<u64>, msg: String| ParseErr { id, msg };
    let v = Json::parse(line).map_err(|e| bad(None, format!("bad request line: {e}")))?;
    let id = v.get("id").and_then(Json::as_f64).map(|x| x as u64);
    if let Some(op) = v.get("op").and_then(Json::as_str) {
        return match op {
            "shutdown" => Ok(Parsed::Shutdown),
            other => Err(bad(id, format!("unknown op \"{other}\" (supported: \"shutdown\")"))),
        };
    }
    let nodes = match v.as_arr() {
        Some(arr) => arr,
        None => v.get("nodes").and_then(Json::as_arr).ok_or_else(|| {
            bad(
                id,
                "request must be '[ids...]', '{\"nodes\": [ids...]}', or '{\"op\": \"shutdown\"}'"
                    .to_string(),
            )
        })?,
    };
    let nodes: Vec<u32> = nodes
        .iter()
        .map(|j| node_id(j).map_err(|msg| bad(id, msg)))
        .collect::<Result<_, _>>()?;
    let id = id.unwrap_or(*next_id);
    *next_id += 1;
    Ok(Parsed::Request(ServeRequest { id, nodes }))
}

/// One JSON error response (`{"id": N, "error": "..."}`; id omitted when
/// the request never got one).
pub fn error_line(id: Option<u64>, msg: &str) -> String {
    let mut top = BTreeMap::new();
    if let Some(id) = id {
        top.insert("id".to_string(), Json::Num(id as f64));
    }
    top.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(top).to_string()
}

/// One JSON response line for an answered request.
pub fn response_line(id: u64, preds: &[Prediction]) -> String {
    let items: Vec<Json> = preds
        .iter()
        .map(|p| {
            let mut m = BTreeMap::new();
            m.insert("node".to_string(), Json::Num(p.node as f64));
            m.insert("label".to_string(), Json::Num(p.label as f64));
            m.insert("logit".to_string(), Json::Num(p.logits[p.label as usize] as f64));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("id".to_string(), Json::Num(id as f64));
    top.insert("predictions".to_string(), Json::Arr(items));
    Json::Obj(top).to_string()
}

/// Counters a finished [`ServeLoop`] reports; `served / batches` and
/// `requests / batches` are the batch-occupancy figures the loadtest and
/// the final shutdown line expose.
#[derive(Clone, Debug)]
pub struct LoopStats {
    pub reason: &'static str,
    /// Node predictions answered.
    pub served: usize,
    /// Requests answered.
    pub requests: usize,
    /// Engine passes (micro-batch flushes).
    pub batches: usize,
}

/// The final `{"op":"shutdown",...}` status line (a superset of the PR 7
/// format: `op`/`reason`/`served` plus the batching counters).
pub fn shutdown_line(stats: &LoopStats) -> String {
    let mut top = BTreeMap::new();
    top.insert("op".to_string(), Json::Str("shutdown".to_string()));
    top.insert("reason".to_string(), Json::Str(stats.reason.to_string()));
    top.insert("served".to_string(), Json::Num(stats.served as f64));
    top.insert("requests".to_string(), Json::Num(stats.requests as f64));
    top.insert("batches".to_string(), Json::Num(stats.batches as f64));
    Json::Obj(top).to_string()
}

// ---------------------------------------------------------------------------
// transport seam
// ---------------------------------------------------------------------------

/// Where a request's responses go. Cheap to clone; one per input line.
#[derive(Clone)]
pub enum Sink {
    /// The stdin transport: responses print to the process stdout.
    Stdout,
    /// A TCP connection: responses queue to its writer thread.
    Chan(Sender<String>),
}

impl Sink {
    pub fn send(&self, line: String) {
        match self {
            Sink::Stdout => println!("{line}"),
            // a connection that died cannot stall the loop; its responses
            // are dropped with it
            Sink::Chan(tx) => {
                let _ = tx.send(line);
            }
        }
    }
}

/// One input line tagged with the transport it arrived on.
pub struct Event {
    pub sink: Sink,
    pub line: String,
}

// ---------------------------------------------------------------------------
// the shared serve loop
// ---------------------------------------------------------------------------

/// Transport-agnostic serve loop: parses request lines, feeds one shared
/// [`MicroBatcher`], answers drained batches through the engine, and routes
/// each response to the sink its request arrived on. The stdin loop and
/// the TCP server are both thin transports over this core, so the two
/// paths cannot drift.
pub struct ServeLoop {
    engine: Arc<ServeEngine>,
    mb: MicroBatcher,
    /// One sink per queued request, FIFO-aligned with the batcher queue:
    /// the batcher always drains whole batches in push order, so the first
    /// `batch.len()` routes always belong to the drained batch.
    routes: VecDeque<Sink>,
    clock: Instant,
    next_id: u64,
    served: usize,
    requests: usize,
    batches: usize,
}

impl ServeLoop {
    pub fn new(engine: Arc<ServeEngine>, policy: BatchPolicy) -> ServeLoop {
        ServeLoop {
            engine,
            mb: MicroBatcher::new(policy),
            routes: VecDeque::new(),
            clock: Instant::now(),
            next_id: 0,
            served: 0,
            requests: 0,
            batches: 0,
        }
    }

    fn now(&self) -> u64 {
        self.clock.elapsed().as_millis() as u64
    }

    /// Parse and enqueue one input line, answering any batch it flushes.
    /// Returns `true` when the line was an `{"op":"shutdown"}` request.
    pub fn handle_line(&mut self, sink: &Sink, line: &str) -> bool {
        if line.trim().is_empty() {
            return false;
        }
        let now = self.now();
        match parse_line(line, &mut self.next_id) {
            Ok(Parsed::Shutdown) => true,
            Ok(Parsed::Request(req)) => {
                self.routes.push_back(sink.clone());
                if let Some(batch) = self.mb.push(req, now) {
                    self.answer(&batch);
                }
                false
            }
            // a malformed line gets an error response, not a service
            // abort: queued requests stay alive
            Err(e) => {
                sink.send(error_line(e.id, &e.msg));
                false
            }
        }
    }

    fn poll(&mut self) {
        let now = self.now();
        if let Some(batch) = self.mb.poll(now) {
            self.answer(&batch);
        }
    }

    /// Answer one drained micro-batch: a response line per request, routed
    /// to its own sink. A failing request (e.g. an out-of-range node id)
    /// must not take the batch — or the loop — down with it, so on a
    /// batch-level error each request is retried alone and only the
    /// offender gets an error response.
    fn answer(&mut self, batch: &[ServeRequest]) {
        let sinks: Vec<Sink> = self.routes.drain(..batch.len()).collect();
        self.batches += 1;
        self.requests += batch.len();
        if let Err(e) = failpoint::fire("serve.request") {
            // injected request-path failure: every request in the batch
            // gets an error response, the loop itself stays up
            for (r, sink) in batch.iter().zip(&sinks) {
                sink.send(error_line(Some(r.id), &format!("{e:#}")));
            }
            return;
        }
        match self.engine.answer(batch) {
            Ok(answers) => {
                for ((id, preds), sink) in answers.iter().zip(&sinks) {
                    self.served += preds.len();
                    sink.send(response_line(*id, preds));
                }
            }
            Err(_) => {
                for (r, sink) in batch.iter().zip(&sinks) {
                    match self.engine.answer(std::slice::from_ref(r)) {
                        Ok(answers) => {
                            for (id, preds) in &answers {
                                self.served += preds.len();
                                sink.send(response_line(*id, preds));
                            }
                        }
                        Err(e) => sink.send(error_line(Some(r.id), &format!("{e:#}"))),
                    }
                }
            }
        }
    }

    /// Drive the loop over an event stream until shutdown: `should_stop`
    /// returns a reason (signal delivery), any sink sends
    /// `{"op":"shutdown"}` (reason `"op"`), or the stream disconnects
    /// (stdin EOF, reason `"eof"`). Input already received is still parsed
    /// and answered, and the queue is flushed, before the stats return —
    /// graceful drain on every path.
    pub fn run<F: Fn() -> Option<&'static str>>(
        mut self,
        rx: &Receiver<Event>,
        should_stop: F,
    ) -> LoopStats {
        let max_wait = Duration::from_millis(self.mb.policy().max_wait.max(1));
        let reason;
        loop {
            if let Some(r) = should_stop() {
                reason = r;
                break;
            }
            // wake exactly when the oldest queued request's latency
            // deadline expires; with an empty queue, max_wait bounds the
            // signal-poll cadence
            let wait = match self.mb.next_deadline() {
                Some(dl) => {
                    Duration::from_millis(dl.saturating_sub(self.now()).max(1)).min(max_wait)
                }
                None => max_wait,
            };
            match rx.recv_timeout(wait) {
                Ok(ev) => {
                    if self.handle_line(&ev.sink, &ev.line) {
                        reason = "op";
                        break;
                    }
                }
                Err(RecvTimeoutError::Timeout) => self.poll(),
                Err(RecvTimeoutError::Disconnected) => {
                    reason = "eof";
                    break;
                }
            }
        }
        // graceful drain: the channel may hold lines the loop never got
        // to; answer them, then flush whatever sits in the micro-batcher
        while let Ok(ev) = rx.try_recv() {
            let _ = self.handle_line(&ev.sink, &ev.line);
        }
        if let Some(batch) = self.mb.flush() {
            self.answer(&batch);
        }
        LoopStats {
            reason,
            served: self.served,
            requests: self.requests,
            batches: self.batches,
        }
    }
}

// ---------------------------------------------------------------------------
// TCP server
// ---------------------------------------------------------------------------

type SinkRegistry = Arc<Mutex<Vec<Sender<String>>>>;

/// Serve over TCP: every accepted connection gets a reader thread (frames →
/// the shared event channel) and a writer thread (response queue → frames),
/// all feeding one [`ServeLoop`]. Returns after a graceful drain; the final
/// shutdown line is broadcast to every open connection so clients observe
/// the drain completing.
pub fn serve_tcp<F: Fn() -> Option<&'static str>>(
    engine: Arc<ServeEngine>,
    policy: BatchPolicy,
    listener: TcpListener,
    should_stop: F,
) -> Result<LoopStats> {
    let (tx, rx) = mpsc::channel::<Event>();
    let stop = Arc::new(AtomicBool::new(false));
    let sinks: SinkRegistry = Arc::new(Mutex::new(Vec::new()));
    // non-blocking accept so the thread can notice `stop` between clients
    listener.set_nonblocking(true)?;
    let accept = {
        let stop = Arc::clone(&stop);
        let sinks = Arc::clone(&sinks);
        std::thread::spawn(move || {
            // `tx` lives on this thread, so the loop's receiver can only
            // disconnect after shutdown is already under way
            let tx = tx;
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if let Err(e) = failpoint::fire("serve.net.accept") {
                            eprintln!("accept: {e:#}");
                            continue;
                        }
                        if let Err(e) = spawn_connection(stream, tx.clone(), &sinks) {
                            eprintln!("connection setup failed: {e:#}");
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        eprintln!("accept failed: {e}");
                        std::thread::sleep(Duration::from_millis(5));
                    }
                }
            }
        })
    };
    let stats = ServeLoop::new(engine, policy).run(&rx, should_stop);
    // stop accepting, then broadcast the final status line: every response
    // was already queued to its sink, and per-sink channels are FIFO, so
    // clients always see their answers before the shutdown frame
    stop.store(true, Ordering::SeqCst);
    let line = shutdown_line(&stats);
    for out in sinks.lock().unwrap_or_else(|p| p.into_inner()).drain(..) {
        let _ = out.send(line.clone());
    }
    let _ = accept.join();
    Ok(stats)
}

fn spawn_connection(stream: TcpStream, events: Sender<Event>, sinks: &SinkRegistry) -> Result<()> {
    // accepted sockets can inherit the listener's O_NONBLOCK on some
    // platforms; both per-connection threads want blocking IO
    stream.set_nonblocking(false)?;
    let (out_tx, out_rx) = mpsc::channel::<String>();
    sinks.lock().unwrap_or_else(|p| p.into_inner()).push(out_tx.clone());
    let mut writer = stream.try_clone()?;
    std::thread::spawn(move || {
        // ends when every sender is gone (reader exited and the server
        // broadcast its shutdown line) or the client stopped reading
        while let Ok(line) = out_rx.recv() {
            if write_frame(&mut writer, &line).is_err() {
                break;
            }
        }
        let _ = writer.shutdown(std::net::Shutdown::Both);
    });
    let mut reader = stream;
    std::thread::spawn(move || {
        let sink = Sink::Chan(out_tx);
        loop {
            if let Err(e) = failpoint::fire("serve.net.read") {
                sink.send(error_line(None, &format!("{e:#}")));
                break;
            }
            match read_frame(&mut reader) {
                Ok(Some(line)) => {
                    if events.send(Event { sink: sink.clone(), line }).is_err() {
                        break; // loop already shut down
                    }
                }
                Ok(None) => break, // clean close
                Err(e) => {
                    sink.send(error_line(None, &format!("connection error: {e}")));
                    break;
                }
            }
        }
    });
    Ok(())
}

// ---------------------------------------------------------------------------
// loadtest harness
// ---------------------------------------------------------------------------

/// `lmc loadtest` knobs.
#[derive(Clone, Debug)]
pub struct LoadtestOptions {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent client connections.
    pub conns: usize,
    /// Target open-loop arrival rate, requests/second across all
    /// connections.
    pub qps: f64,
    /// Duration of the arrival schedule, seconds.
    pub secs: f64,
    /// Request sizes (node ids per request), cycled across requests.
    pub sizes: Vec<usize>,
    pub seed: u64,
    /// Node-id space to sample requests from (the served graph's `n`).
    pub n_nodes: u32,
}

/// Server-side counters parsed from the broadcast shutdown line.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub served: usize,
    pub requests: usize,
    pub batches: usize,
}

/// What one loadtest run measured.
#[derive(Clone, Debug)]
pub struct LoadtestReport {
    pub sent: usize,
    pub completed: usize,
    pub errors: usize,
    pub wall_s: f64,
    pub achieved_qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    pub server: Option<ServerStats>,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

/// Run the open-loop load generator against a `lmc serve --listen` server
/// and shut the server down when done (the shutdown broadcast carries the
/// server-side batching counters back). Latency is measured from each
/// request's *scheduled* send time, so queueing delay from an overloaded
/// server counts against it — the open-loop discipline.
pub fn run_loadtest(opts: &LoadtestOptions) -> Result<LoadtestReport> {
    if opts.conns == 0 || opts.qps <= 0.0 || opts.secs <= 0.0 || opts.sizes.is_empty() {
        bail!("loadtest needs conns >= 1, qps > 0, secs > 0, and at least one request size");
    }
    let total = ((opts.qps * opts.secs).round() as usize).max(opts.conns);
    // request k is sent at start + k/qps by connection k % conns
    let offs: Arc<Vec<Duration>> =
        Arc::new((0..total).map(|k| Duration::from_secs_f64(k as f64 / opts.qps)).collect());
    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::with_capacity(total)));
    let errors = Arc::new(AtomicUsize::new(0));

    // the control connection goes first: it registers with the server
    // before any load, sends the shutdown op at the end, and reads the
    // broadcast stats line back
    let mut control = TcpStream::connect(&opts.addr)
        .with_context(|| format!("loadtest cannot connect to {}", opts.addr))?;
    control.set_read_timeout(Some(Duration::from_secs(30)))?;

    let start = Instant::now() + Duration::from_millis(50);
    let mut writers = Vec::new();
    let mut readers = Vec::new();
    for c in 0..opts.conns {
        let stream = TcpStream::connect(&opts.addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        let mut rd = stream.try_clone()?;
        let offs_r = Arc::clone(&offs);
        let lat = Arc::clone(&latencies);
        let errs = Arc::clone(&errors);
        readers.push(std::thread::spawn(move || {
            loop {
                match read_frame(&mut rd) {
                    Ok(Some(line)) => {
                        let Ok(v) = Json::parse(&line) else {
                            errs.fetch_add(1, Ordering::SeqCst);
                            continue;
                        };
                        if v.get("op").and_then(Json::as_str) == Some("shutdown") {
                            break; // server drained; this stream is done
                        }
                        match v.get("id").and_then(Json::as_f64) {
                            Some(id) if (id as usize) < offs_r.len() => {
                                if v.get("error").is_some() {
                                    errs.fetch_add(1, Ordering::SeqCst);
                                } else {
                                    let ms = (start + offs_r[id as usize])
                                        .elapsed()
                                        .as_secs_f64()
                                        * 1e3;
                                    lat.lock().unwrap_or_else(|p| p.into_inner()).push(ms);
                                }
                            }
                            _ => {
                                errs.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    }
                    Ok(None) | Err(_) => break,
                }
            }
        }));
        let mut wr = stream;
        let offs_w = Arc::clone(&offs);
        let sizes = opts.sizes.clone();
        let (seed, n_nodes, conns) = (opts.seed, opts.n_nodes, opts.conns);
        writers.push(std::thread::spawn(move || {
            let mut sent = 0usize;
            for k in (c..offs_w.len()).step_by(conns) {
                // open-loop: sleep until the scheduled send time; never
                // wait for responses
                let target = start + offs_w[k];
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let mut rng = Rng::new(seed ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let sz = sizes[k % sizes.len()].max(1);
                let nodes: Vec<Json> = (0..sz)
                    .map(|_| Json::Num(rng.below(n_nodes.max(1) as usize) as f64))
                    .collect();
                let mut top = BTreeMap::new();
                top.insert("id".to_string(), Json::Num(k as f64));
                top.insert("nodes".to_string(), Json::Arr(nodes));
                if write_frame(&mut wr, &Json::Obj(top).to_string()).is_err() {
                    break;
                }
                sent += 1;
            }
            sent
        }));
    }

    let mut sent = 0usize;
    for w in writers {
        sent += w.join().map_err(|_| anyhow!("loadtest writer thread panicked"))?;
    }
    // give in-flight requests one batching window to be read and answered
    // before asking the server to drain
    std::thread::sleep(Duration::from_millis(300));
    write_frame(&mut control, "{\"op\":\"shutdown\"}")?;
    let mut server = None;
    while let Ok(Some(line)) = read_frame(&mut control) {
        let Ok(v) = Json::parse(&line) else { continue };
        if v.get("op").and_then(Json::as_str) == Some("shutdown") {
            let count = |k: &str| v.get(k).and_then(Json::as_usize).unwrap_or(0);
            server = Some(ServerStats {
                served: count("served"),
                requests: count("requests"),
                batches: count("batches"),
            });
            break;
        }
    }
    for r in readers {
        let _ = r.join();
    }

    let wall_s = start.elapsed().as_secs_f64();
    let mut lat = Arc::try_unwrap(latencies)
        .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
        .unwrap_or_else(|arc| arc.lock().unwrap_or_else(|p| p.into_inner()).clone());
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let completed = lat.len();
    Ok(LoadtestReport {
        sent,
        completed,
        errors: errors.load(Ordering::SeqCst),
        wall_s,
        achieved_qps: completed as f64 / wall_s.max(1e-9),
        p50_ms: percentile(&lat, 0.50),
        p95_ms: percentile(&lat, 0.95),
        p99_ms: percentile(&lat, 0.99),
        mean_ms: if lat.is_empty() {
            f64::NAN
        } else {
            lat.iter().sum::<f64>() / lat.len() as f64
        },
        max_ms: lat.last().copied().unwrap_or(f64::NAN),
        server,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_and_eof_is_clean() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"id\":1}").unwrap();
        write_frame(&mut buf, "").unwrap();
        let mut r = io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some("{\"id\":1}"));
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some(""));
        assert!(read_frame(&mut r).unwrap().is_none(), "EOF at a boundary is a clean close");
    }

    #[test]
    fn oversized_and_truncated_frames_are_errors() {
        let mut huge = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        huge.extend_from_slice(b"x");
        let err = read_frame(&mut io::Cursor::new(huge)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // header promises 9 bytes, stream holds 3
        let mut torn = 9u32.to_le_bytes().to_vec();
        torn.extend_from_slice(b"abc");
        assert!(read_frame(&mut io::Cursor::new(torn)).is_err());
        // EOF inside the header itself
        assert!(read_frame(&mut io::Cursor::new(vec![1u8, 0])).is_err());
    }

    #[test]
    fn parse_line_accepts_shutdown_op() {
        // the documented control line must not be "bad request" (ISSUE 8)
        let mut id = 0;
        assert_eq!(parse_line("{\"op\":\"shutdown\"}", &mut id).unwrap(), Parsed::Shutdown);
        assert_eq!(id, 0, "control lines must not consume request ids");
        let err = parse_line("{\"op\":\"reboot\"}", &mut id).unwrap_err();
        assert!(err.msg.contains("unknown op"), "{}", err.msg);
    }

    #[test]
    fn parse_line_request_shapes_and_sequential_ids() {
        let mut id = 0;
        let Parsed::Request(r) = parse_line("[3,1,2]", &mut id).unwrap() else {
            panic!("array form must parse as a request")
        };
        assert_eq!((r.id, r.nodes), (0, vec![3, 1, 2]));
        let Parsed::Request(r) = parse_line("{\"id\":9,\"nodes\":[5]}", &mut id).unwrap() else {
            panic!("object form must parse as a request")
        };
        assert_eq!((r.id, r.nodes), (9, vec![5]));
        let Parsed::Request(r) = parse_line("{\"nodes\":[7]}", &mut id).unwrap() else {
            panic!("id-less object form must parse as a request")
        };
        assert_eq!(r.id, 2, "ids stay sequential across explicit-id requests");
        assert!(parse_line("not json", &mut id).is_err());
        assert!(parse_line("{\"noodles\":[1]}", &mut id).is_err());
    }

    #[test]
    fn parse_line_rejects_non_integer_and_out_of_range_ids() {
        let mut id = 0;
        // -1 used to saturate to node 0, 3.7 truncated to node 3: silently
        // wrong predictions (ISSUE 8); both must be per-request errors now
        for bad in ["[-1]", "[3.7]", "[4294967296]", "[1e300]", "[\"7\"]"] {
            let err = parse_line(bad, &mut id).unwrap_err();
            assert!(err.id.is_none(), "{bad}: bare arrays carry no id");
            assert!(
                err.msg.contains("node id") || err.msg.contains("numbers"),
                "{bad}: {}",
                err.msg
            );
        }
        // the error response keeps the request's own id for correlation
        let err = parse_line("{\"id\":42,\"nodes\":[-1]}", &mut id).unwrap_err();
        assert_eq!(err.id, Some(42));
        // boundary: u32::MAX itself is a valid id
        let Parsed::Request(r) = parse_line("[4294967295]", &mut id).unwrap() else {
            panic!("u32::MAX must parse")
        };
        assert_eq!(r.nodes, vec![u32::MAX]);
    }

    #[test]
    fn shutdown_line_carries_stats() {
        let line = shutdown_line(&LoopStats { reason: "op", served: 7, requests: 3, batches: 2 });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("op").and_then(Json::as_str), Some("shutdown"));
        assert_eq!(v.get("reason").and_then(Json::as_str), Some("op"));
        assert_eq!(v.get("served").and_then(Json::as_usize), Some(7));
        assert_eq!(v.get("requests").and_then(Json::as_usize), Some(3));
        assert_eq!(v.get("batches").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn error_and_response_lines_format() {
        assert_eq!(error_line(None, "boom"), "{\"error\":\"boom\"}");
        assert_eq!(error_line(Some(4), "boom"), "{\"error\":\"boom\",\"id\":4}");
        let p = Prediction { node: 3, label: 1, logits: vec![0.25, 0.5] };
        let line = response_line(8, &[p]);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(8));
        assert_eq!(v.path("predictions.0.node").and_then(Json::as_usize), Some(3));
        assert_eq!(v.path("predictions.0.logit").and_then(Json::as_f64), Some(0.5));
    }

    #[test]
    fn percentiles_interpolate_to_nearest_rank() {
        let s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&s, 0.50), 51.0);
        assert_eq!(percentile(&s, 0.99), 99.0);
        assert_eq!(percentile(&s, 1.0), 100.0);
        assert!(percentile(&[], 0.5).is_nan());
    }
}
