//! Micro-batching request queue for the serve path.
//!
//! Requests accumulate until either the pending node count reaches
//! `BatchPolicy::max_nodes` (throughput: bigger tiles amortize the GEMM
//! and SpMM launches) or the oldest request has waited `max_wait` clock
//! units (latency: nobody is held hostage by a quiet stream). Time is an
//! explicit logical clock passed by the caller — the CLI loop feeds real
//! milliseconds, tests feed deterministic ticks — so flush decisions are
//! reproducible and the queue needs no threads of its own.

/// One inference request: caller-chosen id plus the node ids to predict.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeRequest {
    pub id: u64,
    pub nodes: Vec<u32>,
}

/// The size/latency trade-off knob.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many node ids are queued (counted with
    /// multiplicity — the cost driver is tile assembly work, not
    /// uniqueness).
    pub max_nodes: usize,
    /// Flush once the oldest queued request has waited this many clock
    /// units (milliseconds in the CLI loop).
    pub max_wait: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_nodes: 256, max_wait: 4 }
    }
}

/// FIFO micro-batch queue. `push` and `poll` return a drained batch when a
/// flush condition holds; the caller answers the whole batch in one
/// engine pass.
#[derive(Debug, Default)]
pub struct MicroBatcher {
    policy: BatchPolicy,
    queue: Vec<(u64, ServeRequest)>,
    queued_nodes: usize,
}

impl MicroBatcher {
    pub fn new(policy: BatchPolicy) -> MicroBatcher {
        MicroBatcher { policy, queue: Vec::new(), queued_nodes: 0 }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request at logical time `now`; returns the drained batch
    /// (FIFO order) when the size threshold is reached or the oldest
    /// request's deadline has passed.
    pub fn push(&mut self, req: ServeRequest, now: u64) -> Option<Vec<ServeRequest>> {
        self.queued_nodes += req.nodes.len();
        self.queue.push((now, req));
        if self.queued_nodes >= self.policy.max_nodes.max(1) {
            return Some(self.drain());
        }
        self.poll(now)
    }

    /// Deadline check without enqueuing: returns the drained batch when
    /// the oldest request has waited at least `max_wait`.
    pub fn poll(&mut self, now: u64) -> Option<Vec<ServeRequest>> {
        match self.queue.first() {
            Some(&(t0, _)) if now.saturating_sub(t0) >= self.policy.max_wait => Some(self.drain()),
            _ => None,
        }
    }

    /// Unconditional drain (stream end).
    pub fn flush(&mut self) -> Option<Vec<ServeRequest>> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.drain())
        }
    }

    /// Logical time at which the oldest queued request's `max_wait`
    /// deadline expires (`None` on an empty queue) — the serve loop's
    /// wake-up time, so a sub-threshold request is answered on schedule
    /// without polling.
    pub fn next_deadline(&self) -> Option<u64> {
        self.queue.first().map(|&(t0, _)| t0.saturating_add(self.policy.max_wait))
    }

    /// Queued requests.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Queued node ids (with multiplicity).
    pub fn queued_nodes(&self) -> usize {
        self.queued_nodes
    }

    fn drain(&mut self) -> Vec<ServeRequest> {
        self.queued_nodes = 0;
        self.queue.drain(..).map(|(_, r)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, nodes: &[u32]) -> ServeRequest {
        ServeRequest { id, nodes: nodes.to_vec() }
    }

    #[test]
    fn flushes_on_node_count_threshold() {
        let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 5, max_wait: 100 });
        assert!(mb.push(req(1, &[0, 1]), 0).is_none());
        assert_eq!(mb.queued(), 1);
        assert_eq!(mb.queued_nodes(), 2);
        // 2 + 3 = 5 >= max_nodes: flush, FIFO order preserved
        let batch = mb.push(req(2, &[2, 3, 4]), 1).expect("size flush");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(mb.queued(), 0);
        assert_eq!(mb.queued_nodes(), 0);
    }

    #[test]
    fn flushes_on_oldest_request_deadline() {
        let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 100, max_wait: 10 });
        assert!(mb.push(req(7, &[3]), 0).is_none());
        assert!(mb.poll(9).is_none(), "deadline not reached yet");
        let batch = mb.poll(10).expect("deadline flush");
        assert_eq!(batch, vec![req(7, &[3])]);
        // a later push measures its wait from its own enqueue time
        assert!(mb.push(req(8, &[4]), 50).is_none());
        assert!(mb.poll(59).is_none());
        assert!(mb.poll(60).is_some());
    }

    #[test]
    fn push_honors_deadline_of_older_requests() {
        let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 100, max_wait: 10 });
        assert!(mb.push(req(1, &[0]), 0).is_none());
        // the new request rides along with the expired older one
        let batch = mb.push(req(2, &[1]), 15).expect("deadline flush on push");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn flush_drains_everything_and_empty_flush_is_none() {
        let mut mb = MicroBatcher::new(BatchPolicy::default());
        assert!(mb.flush().is_none());
        mb.push(req(1, &[0]), 0);
        mb.push(req(2, &[1]), 1);
        let batch = mb.flush().expect("explicit flush");
        assert_eq!(batch.len(), 2);
        assert!(mb.flush().is_none());
    }

    #[test]
    fn next_deadline_tracks_the_oldest_request() {
        let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 100, max_wait: 10 });
        assert_eq!(mb.next_deadline(), None);
        mb.push(req(1, &[0]), 5);
        mb.push(req(2, &[1]), 9);
        // the oldest request sets the deadline
        assert_eq!(mb.next_deadline(), Some(15));
        assert!(mb.poll(15).is_some());
        assert_eq!(mb.next_deadline(), None);
        // saturates instead of overflowing at the end of logical time
        let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 100, max_wait: u64::MAX });
        mb.push(req(3, &[2]), 7);
        assert_eq!(mb.next_deadline(), Some(u64::MAX));
    }

    #[test]
    fn zero_max_nodes_flushes_every_push() {
        // max(1) guard: a zero knob degenerates to per-request batches
        // instead of never flushing on size.
        let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 0, max_wait: 1000 });
        assert!(mb.push(req(1, &[5]), 0).is_some());
    }
}
