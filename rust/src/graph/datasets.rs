//! Dataset registry: scaled-down, statistic-matched analogues of the paper's
//! benchmarks (DESIGN.md §5 documents the substitution). Dimensions must
//! agree with `python/compile/spec.py` profiles — the runtime cross-checks
//! them against the artifact manifest at load time.

use super::csr::Graph;
use super::gen::{disjoint_union, sbm, SbmSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetId {
    ArxivSim,
    FlickrSim,
    RedditSim,
    PpiSim,
    CoraSim,
    CiteseerSim,
    PubmedSim,
}

impl DatasetId {
    pub fn parse(name: &str) -> Option<DatasetId> {
        Some(match name {
            "arxiv-sim" | "arxiv" => DatasetId::ArxivSim,
            "flickr-sim" | "flickr" => DatasetId::FlickrSim,
            "reddit-sim" | "reddit" => DatasetId::RedditSim,
            "ppi-sim" | "ppi" => DatasetId::PpiSim,
            "cora-sim" | "cora" => DatasetId::CoraSim,
            "citeseer-sim" | "citeseer" => DatasetId::CiteseerSim,
            "pubmed-sim" | "pubmed" => DatasetId::PubmedSim,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetId::ArxivSim => "arxiv-sim",
            DatasetId::FlickrSim => "flickr-sim",
            DatasetId::RedditSim => "reddit-sim",
            DatasetId::PpiSim => "ppi-sim",
            DatasetId::CoraSim => "cora-sim",
            DatasetId::CiteseerSim => "citeseer-sim",
            DatasetId::PubmedSim => "pubmed-sim",
        }
    }

    /// Artifact profile this dataset's programs were compiled for
    /// (must match python/compile/spec.py).
    pub fn profile(&self) -> &'static str {
        match self {
            DatasetId::ArxivSim | DatasetId::RedditSim => "std16",
            DatasetId::FlickrSim => "flickr",
            DatasetId::PpiSim => "ppi",
            DatasetId::CoraSim | DatasetId::CiteseerSim | DatasetId::PubmedSim => "planetoid",
        }
    }

    /// Default METIS-substitute partition count (paper uses 40-150 parts on
    /// the full-size datasets; scaled proportionally here).
    pub fn default_parts(&self) -> usize {
        match self {
            DatasetId::ArxivSim => 20,
            DatasetId::FlickrSim => 16,
            DatasetId::RedditSim => 24,
            DatasetId::PpiSim => 24,
            DatasetId::CoraSim | DatasetId::CiteseerSim | DatasetId::PubmedSim => 8,
        }
    }

    pub fn all() -> &'static [DatasetId] {
        &[
            DatasetId::ArxivSim,
            DatasetId::FlickrSim,
            DatasetId::RedditSim,
            DatasetId::PpiSim,
            DatasetId::CoraSim,
            DatasetId::CiteseerSim,
            DatasetId::PubmedSim,
        ]
    }
}

/// Build a dataset. Deterministic in (dataset, seed).
pub fn load(id: DatasetId, seed: u64) -> Graph {
    match id {
        // ogbn-arxiv: 169k nodes, 40 classes, deg ~13, 54/18/28 split
        // -> 2400 nodes, 16 classes, deg ~10.
        DatasetId::ArxivSim => sbm(&SbmSpec {
            n: 2400,
            n_class: 16,
            d_x: 64,
            avg_deg_in: 5.5,
            avg_deg_out: 4.5,
            signal: 0.08,
            train_frac: 0.54,
            val_frac: 0.18,
            seed: seed ^ 0xA12F,
            mu_seed: None,
        }),
        // Flickr: 89k nodes, 7 classes, deg ~10, 50/25/25 split
        // -> 1800 nodes, 7 classes, low signal (Flickr is the hard one).
        DatasetId::FlickrSim => sbm(&SbmSpec {
            n: 1800,
            n_class: 7,
            d_x: 64,
            avg_deg_in: 5.0,
            avg_deg_out: 5.0,
            signal: 0.07,
            train_frac: 0.5,
            val_frac: 0.25,
            seed: seed ^ 0xF11C,
            mu_seed: None,
        }),
        // Reddit: 233k nodes, 41 classes, deg ~100 (dense!), 66/10/24 split
        // -> 3000 nodes, 16 classes, deg ~24: the dense workload where
        // discarded messages (and hence LMC's compensation) matter most.
        DatasetId::RedditSim => sbm(&SbmSpec {
            n: 3000,
            n_class: 16,
            d_x: 64,
            avg_deg_in: 13.0,
            avg_deg_out: 11.0,
            signal: 0.09,
            train_frac: 0.66,
            val_frac: 0.10,
            seed: seed ^ 0x9EDD,
            mu_seed: None,
        }),
        // PPI: 24 graphs, 121 targets, deg ~28, inductive (20/2/2 graphs)
        // -> 6 graphs x 400 nodes, 12 classes, train 4 / val 1 / test 1.
        DatasetId::PpiSim => {
            let mut parts = Vec::new();
            for gi in 0..6u64 {
                parts.push(sbm(&SbmSpec {
                    n: 400,
                    n_class: 12,
                    d_x: 48,
                    avg_deg_in: 8.0,
                    avg_deg_out: 6.0,
                    signal: 0.12,
                    // intra-graph split irrelevant; overridden by union
                    train_frac: 1.0,
                    val_frac: 0.0,
                    seed: seed ^ (0x99A0 + gi),
                    // shared class means: inductive transfer requires it
                    mu_seed: Some(seed ^ 0x99A0),
                }));
            }
            disjoint_union(parts, &[0, 0, 0, 0, 1, 2])
        }
        // Planetoid trio: small citation graphs, deg ~4, low label rate.
        DatasetId::CoraSim => sbm(&SbmSpec {
            n: 900,
            n_class: 7,
            d_x: 48,
            avg_deg_in: 2.6,
            avg_deg_out: 1.6,
            signal: 0.14,
            train_frac: 0.15,
            val_frac: 0.25,
            seed: seed ^ 0xC02A,
            mu_seed: None,
        }),
        DatasetId::CiteseerSim => sbm(&SbmSpec {
            n: 1100,
            n_class: 7,
            d_x: 48,
            avg_deg_in: 2.2,
            avg_deg_out: 1.5,
            signal: 0.12,
            train_frac: 0.15,
            val_frac: 0.25,
            seed: seed ^ 0xC17E,
            mu_seed: None,
        }),
        DatasetId::PubmedSim => sbm(&SbmSpec {
            n: 1500,
            n_class: 7,
            d_x: 48,
            avg_deg_in: 3.0,
            avg_deg_out: 1.8,
            signal: 0.13,
            train_frac: 0.15,
            val_frac: 0.25,
            seed: seed ^ 0x90BE,
            mu_seed: None,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_datasets_load_and_match_profiles() {
        for &id in DatasetId::all() {
            let g = load(id, 0);
            assert!(g.n() > 0, "{}", id.name());
            match id.profile() {
                "std16" => {
                    assert_eq!(g.d_x, 64);
                    assert_eq!(g.n_class, 16);
                }
                "flickr" => {
                    assert_eq!(g.d_x, 64);
                    assert_eq!(g.n_class, 7);
                }
                "ppi" => {
                    assert_eq!(g.d_x, 48);
                    assert_eq!(g.n_class, 12);
                }
                "planetoid" => {
                    assert_eq!(g.d_x, 48);
                    assert_eq!(g.n_class, 7);
                }
                other => panic!("unknown profile {other}"),
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for &id in DatasetId::all() {
            assert_eq!(DatasetId::parse(id.name()), Some(id));
        }
        assert_eq!(DatasetId::parse("nope"), None);
    }

    #[test]
    fn ppi_is_inductive() {
        let g = load(DatasetId::PpiSim, 1);
        // split constant within each graph id
        for u in 0..g.n() {
            let gid = g.graph_id[u] as usize;
            let expect = [0u8, 0, 0, 0, 1, 2][gid];
            assert_eq!(g.split[u], expect);
        }
    }
}
