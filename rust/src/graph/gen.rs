//! Synthetic dataset substrate (DESIGN.md §5 substitution).
//!
//! Stochastic block model with class-homophilous communities plus
//! Gaussian-mixture node features. Every mechanism LMC exercises — cluster
//! locality, halo-vs-batch ratios, message discarding, history staleness —
//! is a function of structure/homophily, which the SBM reproduces at a scale
//! where the CPU interpret-mode PJRT substrate can run full experiment
//! suites.

use super::csr::{Csr, Graph};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SbmSpec {
    pub n: usize,
    pub n_class: usize,
    pub d_x: usize,
    /// Average intra-class degree contribution.
    pub avg_deg_in: f64,
    /// Average inter-class degree contribution.
    pub avg_deg_out: f64,
    /// Feature signal strength: x_i = signal * mu_class + noise.
    pub signal: f32,
    /// Fractions (train, val); test is the rest.
    pub train_frac: f64,
    pub val_frac: f64,
    pub seed: u64,
    /// Seed for the class feature means. Defaults to `seed`; multi-graph
    /// inductive datasets (ppi-sim) share it across graphs so class
    /// signatures transfer between train and test graphs.
    pub mu_seed: Option<u64>,
}

/// Sample an SBM graph with features. Communities are assigned uniformly.
pub fn sbm(spec: &SbmSpec) -> Graph {
    let mut rng = Rng::new(spec.seed);
    let n = spec.n;
    let k = spec.n_class;

    // class assignment: balanced, then shuffled
    let mut labels: Vec<u16> = (0..n).map(|i| (i % k) as u16).collect();
    rng.shuffle(&mut labels);

    // pairwise probabilities from target degrees
    let per_class = n as f64 / k as f64;
    let p_in = (spec.avg_deg_in / per_class).min(1.0);
    let p_out = (spec.avg_deg_out / (n as f64 - per_class)).min(1.0);

    // geometric skipping over the upper triangle for O(E) sampling
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let sample_pairs = |p: f64, same: bool, rng: &mut Rng, edges: &mut Vec<(u32, u32)>| {
        if p <= 0.0 {
            return;
        }
        // iterate pairs (u < v) with a skip distribution
        let logq = (1.0 - p).ln();
        let total = n * (n - 1) / 2;
        let mut idx: f64 = 0.0;
        loop {
            let r = rng.next_f64().max(1e-300);
            idx += 1.0 + (r.ln() / logq).floor();
            if idx >= total as f64 {
                break;
            }
            let t = idx as usize;
            // unrank pair index -> (u, v)
            let u = pair_row(t, n);
            let v = t - row_start(u, n) + u + 1;
            let same_class = labels[u] == labels[v];
            if same_class == same {
                edges.push((u as u32, v as u32));
            }
        }
    };
    // Sample candidate edges at the max rate, then thin per class relation.
    // (Simpler: sample p_in over all pairs keeping same-class hits, then
    // p_out keeping cross-class hits; correct marginal probabilities.)
    sample_pairs(p_in, true, &mut rng, &mut edges);
    sample_pairs(p_out, false, &mut rng, &mut edges);

    let csr = Csr::from_edges(n, &edges);

    // Gaussian mixture features: one random unit mean per class
    let mut mu_rng = Rng::new(spec.mu_seed.unwrap_or(spec.seed) ^ 0x5EED);
    let mut mu = vec![0f32; k * spec.d_x];
    for c in 0..k {
        let mut norm = 0f32;
        for d in 0..spec.d_x {
            let g = mu_rng.normal() as f32;
            mu[c * spec.d_x + d] = g;
            norm += g * g;
        }
        let norm = norm.sqrt().max(1e-6);
        for d in 0..spec.d_x {
            mu[c * spec.d_x + d] /= norm;
        }
    }
    let mut features = vec![0f32; n * spec.d_x];
    for i in 0..n {
        let c = labels[i] as usize;
        for d in 0..spec.d_x {
            features[i * spec.d_x + d] =
                spec.signal * mu[c * spec.d_x + d] * (spec.d_x as f32).sqrt() + rng.normal() as f32;
        }
    }

    // stratified split
    let mut split = vec![2u8; n];
    for c in 0..k as u16 {
        let mut idx: Vec<usize> = (0..n).filter(|&i| labels[i] == c).collect();
        rng.shuffle(&mut idx);
        let ntr = (idx.len() as f64 * spec.train_frac).round() as usize;
        let nva = (idx.len() as f64 * spec.val_frac).round() as usize;
        for (j, &i) in idx.iter().enumerate() {
            split[i] = if j < ntr {
                0
            } else if j < ntr + nva {
                1
            } else {
                2
            };
        }
    }

    Graph::new(csr, spec.d_x, k, features, labels, split)
}

#[inline]
fn row_start(u: usize, n: usize) -> usize {
    // index of pair (u, u+1) in the linearized upper triangle
    u * n - u * (u + 1) / 2
}

fn pair_row(t: usize, n: usize) -> usize {
    // binary search largest u with row_start(u) <= t
    let (mut lo, mut hi) = (0usize, n - 1);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if row_start(mid, n) <= t {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Disjoint union of graphs (PPI-style multi-graph), tagging graph_id and
/// overriding the split to be *inductive*: whole graphs are train/val/test.
pub fn disjoint_union(parts: Vec<Graph>, split_per_graph: &[u8]) -> Graph {
    assert_eq!(parts.len(), split_per_graph.len());
    let d_x = parts[0].d_x;
    let n_class = parts[0].n_class;
    let total: usize = parts.iter().map(|g| g.n()).sum();
    let mut edges = Vec::new();
    let mut features = Vec::with_capacity(total * d_x);
    let mut labels = Vec::with_capacity(total);
    let mut split = Vec::with_capacity(total);
    let mut graph_id = Vec::with_capacity(total);
    let mut base = 0u32;
    for (gi, g) in parts.iter().enumerate() {
        assert_eq!(g.d_x, d_x);
        assert_eq!(g.n_class, n_class);
        for u in 0..g.n() {
            for &v in g.csr.neighbors(u) {
                if (v as usize) > u {
                    edges.push((base + u as u32, base + v));
                }
            }
        }
        features.extend_from_slice(&g.features);
        labels.extend_from_slice(&g.labels);
        split.extend(std::iter::repeat(split_per_graph[gi]).take(g.n()));
        graph_id.extend(std::iter::repeat(gi as u16).take(g.n()));
        base += g.n() as u32;
    }
    let csr = Csr::from_edges(total, &edges);
    let mut out = Graph::new(csr, d_x, n_class, features, labels, split);
    out.graph_id = graph_id;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SbmSpec {
        SbmSpec {
            n: 600,
            n_class: 6,
            d_x: 16,
            avg_deg_in: 6.0,
            avg_deg_out: 2.0,
            signal: 0.5,
            train_frac: 0.3,
            val_frac: 0.2,
            seed: 5,
            mu_seed: None,
        }
    }

    #[test]
    fn sbm_degree_and_homophily() {
        let g = sbm(&small_spec());
        assert_eq!(g.n(), 600);
        let avg_deg = 2.0 * g.csr.num_undirected_edges() as f64 / g.n() as f64;
        assert!((avg_deg - 8.0).abs() < 2.0, "avg degree {avg_deg}");
        // homophily: most edges intra-class
        let mut intra = 0usize;
        let mut total = 0usize;
        for u in 0..g.n() {
            for &v in g.csr.neighbors(u) {
                total += 1;
                if g.labels[u] == g.labels[v as usize] {
                    intra += 1;
                }
            }
        }
        let h = intra as f64 / total as f64;
        assert!(h > 0.6, "homophily {h}");
    }

    #[test]
    fn sbm_split_stratified() {
        let g = sbm(&small_spec());
        let ntr = g.split.iter().filter(|&&s| s == 0).count();
        let nva = g.split.iter().filter(|&&s| s == 1).count();
        assert!((ntr as f64 / 600.0 - 0.3).abs() < 0.05);
        assert!((nva as f64 / 600.0 - 0.2).abs() < 0.05);
    }

    #[test]
    fn sbm_deterministic() {
        let a = sbm(&small_spec());
        let b = sbm(&small_spec());
        assert_eq!(a.csr, b.csr);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn union_is_disjoint_and_inductive() {
        let mut s = small_spec();
        s.n = 100;
        let g1 = sbm(&s);
        s.seed = 6;
        let g2 = sbm(&s);
        let u = disjoint_union(vec![g1.clone(), g2], &[0, 2]);
        assert_eq!(u.n(), 200);
        assert!(u.split[..100].iter().all(|&s| s == 0));
        assert!(u.split[100..].iter().all(|&s| s == 2));
        // no cross edges
        for a in 0..100usize {
            for &b in u.csr.neighbors(a) {
                assert!((b as usize) < 100);
            }
        }
        assert_eq!(u.graph_id[150], 1);
    }
}
