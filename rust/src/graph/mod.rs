//! Graph substrate: CSR storage, GCN normalization, synthetic dataset
//! generators, and the dataset registry (paper §3 + §7.1 substitutes).

pub mod csr;
pub mod datasets;
pub mod gen;

pub use csr::{gcn_normalize, local_normalized_dense, random_graph, Csr, Graph};
pub use datasets::{load, DatasetId};
pub use gen::{disjoint_union, sbm, SbmSpec};
