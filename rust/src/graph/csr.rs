//! CSR graph with GCN symmetric normalization (paper §3.1-3.2 substrate).
//!
//! Edges are stored undirected (both directions present), without self-loops;
//! the GCN normalization `Ahat = D~^{-1/2} (A + I) D~^{-1/2}` is precomputed
//! as per-edge weights plus a per-node self-loop weight, so the sampler can
//! densify any subgraph block by simple gathers.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n: usize,
    pub offsets: Vec<u32>,   // len n+1
    pub neighbors: Vec<u32>, // len 2|E|
}

impl Csr {
    /// Build from an undirected edge list (u < v pairs or any mix;
    /// deduplicates, drops self-loops, symmetrizes).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            let (u, v) = (u as usize, v as usize);
            assert!(u < n && v < n, "edge ({u},{v}) out of range n={n}");
            if u == v {
                continue;
            }
            adj[u].push(v as u32);
            adj[v].push(u as u32);
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::new();
        offsets.push(0u32);
        for l in adj.iter_mut() {
            l.sort_unstable();
            l.dedup();
            neighbors.extend_from_slice(l);
            offsets.push(neighbors.len() as u32);
        }
        Csr { n, offsets, neighbors }
    }

    #[inline]
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.neighbors[self.offsets[u] as usize..self.offsets[u + 1] as usize]
    }

    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        (self.offsets[u + 1] - self.offsets[u]) as usize
    }

    pub fn num_undirected_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// Symmetry check (every stored arc has its reverse).
    pub fn is_symmetric(&self) -> bool {
        (0..self.n).all(|u| self.neighbors(u).iter().all(|&v| self.has_edge(v as usize, u)))
    }

    /// Relabel nodes: `perm[new] = old`. Returns the relabeled graph.
    pub fn permute(&self, perm: &[u32]) -> Csr {
        assert_eq!(perm.len(), self.n);
        let mut inv = vec![0u32; self.n];
        for (newi, &old) in perm.iter().enumerate() {
            inv[old as usize] = newi as u32;
        }
        let mut edges = Vec::with_capacity(self.neighbors.len() / 2);
        for u in 0..self.n {
            for &v in self.neighbors(u) {
                if (v as usize) > u {
                    edges.push((inv[u], inv[v as usize]));
                }
            }
        }
        Csr::from_edges(self.n, &edges)
    }
}

/// A fully-attributed dataset graph (features, labels, splits, normalization).
#[derive(Clone, Debug)]
pub struct Graph {
    pub csr: Csr,
    pub d_x: usize,
    pub n_class: usize,
    /// Row-major [n, d_x].
    pub features: Vec<f32>,
    pub labels: Vec<u16>,
    /// 0 = train, 1 = val, 2 = test.
    pub split: Vec<u8>,
    /// GCN-normalized edge weight per stored arc, aligned with csr.neighbors.
    pub edge_w: Vec<f32>,
    /// GCN-normalized self-loop weight per node: 1/(deg+1).
    pub self_w: Vec<f32>,
    /// Connected-component / sub-graph id per node (PPI-style multi-graph).
    pub graph_id: Vec<u16>,
}

impl Graph {
    pub fn new(csr: Csr, d_x: usize, n_class: usize, features: Vec<f32>, labels: Vec<u16>, split: Vec<u8>) -> Graph {
        let n = csr.n;
        assert_eq!(features.len(), n * d_x);
        assert_eq!(labels.len(), n);
        assert_eq!(split.len(), n);
        let (edge_w, self_w) = gcn_normalize(&csr);
        Graph { csr, d_x, n_class, features, labels, split, edge_w, self_w, graph_id: vec![0; n] }
    }

    pub fn n(&self) -> usize {
        self.csr.n
    }

    pub fn feature_row(&self, u: usize) -> &[f32] {
        &self.features[u * self.d_x..(u + 1) * self.d_x]
    }

    /// Normalized weight of arc index `e` (aligned with csr.neighbors).
    #[inline]
    pub fn arc_weight(&self, e: usize) -> f32 {
        self.edge_w[e]
    }

    pub fn split_indices(&self, which: u8) -> Vec<u32> {
        (0..self.n() as u32).filter(|&i| self.split[i as usize] == which).collect()
    }

    pub fn num_labeled_train(&self) -> usize {
        self.split.iter().filter(|&&s| s == 0).count()
    }

    /// Permute node ids (used to lay clusters out contiguously for locality).
    pub fn permute(&self, perm: &[u32]) -> Graph {
        let n = self.n();
        assert_eq!(perm.len(), n);
        let csr = self.csr.permute(perm);
        let mut features = vec![0f32; n * self.d_x];
        let mut labels = vec![0u16; n];
        let mut split = vec![0u8; n];
        let mut graph_id = vec![0u16; n];
        for (newi, &old) in perm.iter().enumerate() {
            let old = old as usize;
            features[newi * self.d_x..(newi + 1) * self.d_x]
                .copy_from_slice(&self.features[old * self.d_x..(old + 1) * self.d_x]);
            labels[newi] = self.labels[old];
            split[newi] = self.split[old];
            graph_id[newi] = self.graph_id[old];
        }
        let mut g = Graph::new(csr, self.d_x, self.n_class, features, labels, split);
        g.graph_id = graph_id;
        g
    }
}

/// GCN symmetric normalization with self-loops: for arc (u, v),
/// `w = 1/sqrt((deg(u)+1)(deg(v)+1))`; self weight `1/(deg(u)+1)`.
pub fn gcn_normalize(csr: &Csr) -> (Vec<f32>, Vec<f32>) {
    let n = csr.n;
    let inv_sqrt: Vec<f32> = (0..n).map(|u| 1.0 / ((csr.degree(u) + 1) as f32).sqrt()).collect();
    let mut edge_w = vec![0f32; csr.neighbors.len()];
    for u in 0..n {
        let (s, e) = (csr.offsets[u] as usize, csr.offsets[u + 1] as usize);
        for i in s..e {
            let v = csr.neighbors[i] as usize;
            edge_w[i] = inv_sqrt[u] * inv_sqrt[v];
        }
    }
    let self_w: Vec<f32> = (0..n).map(|u| inv_sqrt[u] * inv_sqrt[u]).collect();
    (edge_w, self_w)
}

/// Local re-normalization of an induced subgraph (CLUSTER-GCN policy,
/// paper §E.2): degrees counted inside the subgraph only. Returns the dense
/// [b, b] row-major normalized adjacency including self-loops.
pub fn local_normalized_dense(csr: &Csr, nodes: &[u32]) -> Vec<f32> {
    let b = nodes.len();
    let pos: std::collections::HashMap<u32, usize> =
        nodes.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    let mut adj = vec![false; b * b];
    let mut deg = vec![1f32; b]; // +1 self-loop
    for (i, &u) in nodes.iter().enumerate() {
        for &v in csr.neighbors(u as usize) {
            if let Some(&j) = pos.get(&v) {
                adj[i * b + j] = true;
                deg[i] += 1.0;
            }
        }
    }
    let inv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
    let mut out = vec![0f32; b * b];
    for i in 0..b {
        out[i * b + i] = inv[i] * inv[i];
        for j in 0..b {
            if adj[i * b + j] {
                out[i * b + j] = inv[i] * inv[j];
            }
        }
    }
    out
}

/// Random graph helper used by tests/benches: Erdos-Renyi G(n, p).
pub fn random_graph(n: usize, p: f64, rng: &mut Rng) -> Csr {
    let mut edges = Vec::new();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            if rng.next_f64() < p {
                edges.push((u, v));
            }
        }
    }
    Csr::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_symmetric_dedup() {
        let c = Csr::from_edges(4, &[(0, 1), (1, 0), (1, 2), (2, 3), (3, 3)]);
        assert!(c.is_symmetric());
        assert_eq!(c.num_undirected_edges(), 3);
        assert_eq!(c.neighbors(1), &[0, 2]);
        assert_eq!(c.degree(3), 1); // self-loop dropped
    }

    #[test]
    fn normalization_matches_formula() {
        // Ahat = D~^{-1/2}(A+I)D~^{-1/2}: arc (u,v) -> 1/sqrt(d~u d~v),
        // self-loop -> 1/d~u. Symmetric by construction.
        let mut rng = Rng::new(1);
        let c = random_graph(30, 0.2, &mut rng);
        let (ew, sw) = gcn_normalize(&c);
        for u in 0..c.n {
            let du = (c.degree(u) + 1) as f32;
            assert!((sw[u] - 1.0 / du).abs() < 1e-6);
            for i in c.offsets[u] as usize..c.offsets[u + 1] as usize {
                let v = c.neighbors[i] as usize;
                let dv = (c.degree(v) + 1) as f32;
                assert!((ew[i] - 1.0 / (du * dv).sqrt()).abs() < 1e-6);
                // symmetry: find reverse arc weight
                let j = c.offsets[v] as usize
                    + c.neighbors(v).binary_search(&(u as u32)).unwrap();
                assert_eq!(ew[i], ew[j]);
            }
        }
    }

    #[test]
    fn permute_preserves_structure() {
        let mut rng = Rng::new(2);
        let c = random_graph(20, 0.2, &mut rng);
        let mut perm: Vec<u32> = (0..20).collect();
        rng.shuffle(&mut perm);
        let p = c.permute(&perm);
        assert_eq!(p.num_undirected_edges(), c.num_undirected_edges());
        // spot check: edge (perm-mapped) preserved
        let mut inv = vec![0u32; 20];
        for (newi, &old) in perm.iter().enumerate() {
            inv[old as usize] = newi as u32;
        }
        for u in 0..20usize {
            for &v in c.neighbors(u) {
                assert!(p.has_edge(inv[u] as usize, inv[v as usize] as usize));
            }
        }
    }

    #[test]
    fn local_normalization_dense() {
        let c = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let nodes = [0u32, 1, 2];
        let d = local_normalized_dense(&c, &nodes);
        // node 0 in-subgraph degree 1 (+1 self) -> self weight 1/2
        assert!((d[0] - 0.5).abs() < 1e-6);
        // (0,1): 1/sqrt(2*3)
        assert!((d[1] - 1.0 / (6f32).sqrt()).abs() < 1e-6);
        // no (0,2) edge
        assert_eq!(d[2], 0.0);
    }
}
