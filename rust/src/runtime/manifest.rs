//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed with the in-repo JSON parser; every program's
//! positional input/output signature is validated before execution.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub profile: String,
    pub arch: String,
    pub b: usize,
    pub h: usize,
    pub layer: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ProgramSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("program {} has no output {name}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct ArchInfo {
    pub l: usize,
    pub dims: Vec<usize>,
    /// Canonical parameter order: (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
    pub head_params: Vec<String>,
    /// layer index (1-based, as string key in json) -> param names.
    pub layer_params: BTreeMap<usize, Vec<String>>,
}

impl ArchInfo {
    /// GCN metadata mirroring `python/compile/archs.py::GCN` — same
    /// canonical parameter order (`W1, b1, ..., WL, bL`) so the native
    /// backend and the AOT manifest agree on gradient layout.
    pub fn gcn(l: usize, d_x: usize, hidden: usize, n_class: usize) -> ArchInfo {
        let mut dims = vec![d_x];
        dims.extend(std::iter::repeat(hidden).take(l - 1));
        dims.push(n_class);
        let mut params = Vec::new();
        let mut layer_params = BTreeMap::new();
        for li in 1..=l {
            params.push((format!("W{li}"), vec![dims[li - 1], dims[li]]));
            params.push((format!("b{li}"), vec![dims[li]]));
            layer_params.insert(li, vec![format!("W{li}"), format!("b{li}")]);
        }
        ArchInfo { l, dims, params, head_params: Vec::new(), layer_params }
    }

    /// GCNII metadata mirroring `python/compile/archs.py::GCNII`
    /// (`W0, b0, W1..WL, Wc, bc`; head = `Wc, bc`).
    pub fn gcnii(l: usize, d_x: usize, hidden: usize, n_class: usize) -> ArchInfo {
        let dims = vec![hidden; l + 1];
        let mut params = vec![("W0".to_string(), vec![d_x, hidden]), ("b0".to_string(), vec![hidden])];
        let mut layer_params = BTreeMap::new();
        for li in 1..=l {
            params.push((format!("W{li}"), vec![hidden, hidden]));
            layer_params.insert(li, vec![format!("W{li}")]);
        }
        params.push(("Wc".to_string(), vec![hidden, n_class]));
        params.push(("bc".to_string(), vec![n_class]));
        ArchInfo {
            l,
            dims,
            params,
            head_params: vec!["Wc".to_string(), "bc".to_string()],
            layer_params,
        }
    }

    /// Arch metadata for a profile by name ("gcn" | "gcnii").
    pub fn for_profile(prof: &ProfileInfo, arch_name: &str) -> Result<ArchInfo> {
        match arch_name {
            "gcn" => Ok(ArchInfo::gcn(prof.gcn_layers, prof.d_x, prof.hidden, prof.n_class)),
            "gcnii" => Ok(ArchInfo::gcnii(prof.gcnii_layers, prof.d_x, prof.hidden, prof.n_class)),
            other => bail!("unknown arch '{other}' (expected gcn|gcnii)"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ProfileInfo {
    pub d_x: usize,
    pub n_class: usize,
    pub hidden: usize,
    pub gcn_layers: usize,
    pub gcnii_layers: usize,
    pub step_buckets: Vec<(usize, usize)>,
    pub exact_bucket: (usize, usize),
}

impl ProfileInfo {
    /// Built-in profile table mirroring `python/compile/spec.py::PROFILES`,
    /// used by the native backend (no manifest file required). The bucket
    /// fields are kept for reference but the native backend never pads.
    pub fn builtin(name: &str) -> Option<ProfileInfo> {
        let p = match name {
            "std16" => ProfileInfo {
                d_x: 64,
                n_class: 16,
                hidden: 64,
                gcn_layers: 3,
                gcnii_layers: 4,
                step_buckets: vec![(192, 1024), (320, 1536), (768, 1792), (1408, 1792)],
                exact_bucket: (256, 1792),
            },
            "flickr" => ProfileInfo {
                d_x: 64,
                n_class: 7,
                hidden: 64,
                gcn_layers: 3,
                gcnii_layers: 4,
                step_buckets: vec![(160, 768), (320, 1024)],
                exact_bucket: (256, 1024),
            },
            "ppi" => ProfileInfo {
                d_x: 48,
                n_class: 12,
                hidden: 64,
                gcn_layers: 3,
                gcnii_layers: 4,
                step_buckets: vec![(160, 640), (320, 896)],
                exact_bucket: (160, 640),
            },
            "planetoid" => ProfileInfo {
                d_x: 48,
                n_class: 7,
                hidden: 64,
                gcn_layers: 3,
                gcnii_layers: 4,
                step_buckets: vec![(256, 768), (640, 1024)],
                exact_bucket: (256, 1024),
            },
            _ => return None,
        };
        Some(p)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub use_pallas: bool,
    pub profiles: BTreeMap<String, ProfileInfo>,
    /// key: "profile/arch"
    pub archs: BTreeMap<String, ArchInfo>,
    pub programs: BTreeMap<String, ProgramSpec>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

fn tensors_of(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("tensor list not an array"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tensor missing name"))?
                    .to_string(),
                shape: shape_of(t.get("shape").ok_or_else(|| anyhow!("tensor missing shape"))?)?,
                dtype: DType::parse(t.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts` first)", mpath.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut profiles = BTreeMap::new();
        for (name, p) in root
            .get("profiles")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing profiles"))?
        {
            let buckets = p
                .get("step_buckets")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("profile {name} missing step_buckets"))?
                .iter()
                .map(|b| {
                    let s = shape_of(b)?;
                    Ok((s[0], s[1]))
                })
                .collect::<Result<Vec<_>>>()?;
            let eb = shape_of(p.get("exact_bucket").ok_or_else(|| anyhow!("missing exact_bucket"))?)?;
            profiles.insert(
                name.clone(),
                ProfileInfo {
                    d_x: p.get("d_x").and_then(Json::as_usize).unwrap_or(0),
                    n_class: p.get("n_class").and_then(Json::as_usize).unwrap_or(0),
                    hidden: p.get("hidden").and_then(Json::as_usize).unwrap_or(0),
                    gcn_layers: p.get("gcn_layers").and_then(Json::as_usize).unwrap_or(0),
                    gcnii_layers: p.get("gcnii_layers").and_then(Json::as_usize).unwrap_or(0),
                    step_buckets: buckets,
                    exact_bucket: (eb[0], eb[1]),
                },
            );
        }

        let mut archs = BTreeMap::new();
        for (key, a) in root
            .get("archs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing archs"))?
        {
            let params = a
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("arch {key} missing params"))?
                .iter()
                .map(|p| {
                    Ok((
                        p.get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape_of(p.get("shape").ok_or_else(|| anyhow!("param missing shape"))?)?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let head_params = a
                .get("head_params")
                .and_then(Json::as_arr)
                .map(|v| v.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let mut layer_params = BTreeMap::new();
            if let Some(lp) = a.get("layer_params").and_then(Json::as_obj) {
                for (l, names) in lp {
                    let l: usize = l.parse().context("layer_params key")?;
                    let names = names
                        .as_arr()
                        .map(|v| v.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                        .unwrap_or_default();
                    layer_params.insert(l, names);
                }
            }
            archs.insert(
                key.clone(),
                ArchInfo {
                    l: a.get("L").and_then(Json::as_usize).unwrap_or(0),
                    dims: shape_of(a.get("dims").ok_or_else(|| anyhow!("arch missing dims"))?)?,
                    params,
                    head_params,
                    layer_params,
                },
            );
        }

        let mut programs = BTreeMap::new();
        for p in root
            .get("programs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing programs"))?
        {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("program missing name"))?
                .to_string();
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name,
                    file: p.get("file").and_then(Json::as_str).unwrap_or_default().to_string(),
                    kind: p.get("kind").and_then(Json::as_str).unwrap_or_default().to_string(),
                    profile: p.get("profile").and_then(Json::as_str).unwrap_or_default().to_string(),
                    arch: p.get("arch").and_then(Json::as_str).unwrap_or_default().to_string(),
                    b: p.get("B").and_then(Json::as_usize).unwrap_or(0),
                    h: p.get("H").and_then(Json::as_usize).unwrap_or(0),
                    layer: p.get("layer").and_then(Json::as_usize).unwrap_or(0),
                    inputs: tensors_of(p.get("inputs").ok_or_else(|| anyhow!("program missing inputs"))?)?,
                    outputs: tensors_of(p.get("outputs").ok_or_else(|| anyhow!("program missing outputs"))?)?,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            use_pallas: root.get("use_pallas").and_then(Json::as_bool).unwrap_or(true),
            profiles,
            archs,
            programs,
        })
    }

    pub fn arch(&self, profile: &str, arch: &str) -> Result<&ArchInfo> {
        self.archs
            .get(&format!("{profile}/{arch}"))
            .ok_or_else(|| anyhow!("manifest has no arch {profile}/{arch}"))
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no program {name} (re-run `make artifacts`)"))
    }

    /// Find the train_step program for (profile, arch, bucket).
    pub fn train_step(&self, profile: &str, arch: &str, b: usize, h: usize) -> Result<&ProgramSpec> {
        self.program(&format!("{profile}_train_step_{arch}_b{b}_h{h}"))
    }

    pub fn fwd_layer(&self, profile: &str, arch: &str, l: usize) -> Result<&ProgramSpec> {
        self.program(&format!("{profile}_fwd_{arch}_l{l}"))
    }

    pub fn bwd_layer(&self, profile: &str, arch: &str, l: usize) -> Result<&ProgramSpec> {
        self.program(&format!("{profile}_bwd_{arch}_l{l}"))
    }

    pub fn loss_grad(&self, profile: &str, arch: &str) -> Result<&ProgramSpec> {
        self.program(&format!("{profile}_loss_{arch}"))
    }

    pub fn embed0(&self, profile: &str, arch: &str) -> Result<&ProgramSpec> {
        self.program(&format!("{profile}_embed0_{arch}"))
    }

    pub fn embed0_bwd(&self, profile: &str, arch: &str) -> Result<&ProgramSpec> {
        self.program(&format!("{profile}_embed0bwd_{arch}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_profiles_and_archs_consistent() {
        for name in ["std16", "flickr", "ppi", "planetoid"] {
            let p = ProfileInfo::builtin(name).unwrap();
            for arch_name in ["gcn", "gcnii"] {
                let a = ArchInfo::for_profile(&p, arch_name).unwrap();
                assert_eq!(a.dims.len(), a.l + 1, "{name}/{arch_name}");
                assert_eq!(*a.dims.last().unwrap(), if arch_name == "gcn" { p.n_class } else { p.hidden });
                assert!(!a.params.is_empty());
                // every layer has its params listed
                for l in 1..=a.l {
                    assert!(a.layer_params.contains_key(&l));
                }
                // shapes align with dims
                for (pname, shape) in &a.params {
                    if let Some(l) = pname.strip_prefix('W').and_then(|s| s.parse::<usize>().ok()) {
                        if l >= 1 {
                            assert_eq!(shape[1], a.dims[l], "{pname}");
                        }
                    }
                }
            }
        }
        assert!(ProfileInfo::builtin("nope").is_none());
        // canonical ordering matches archs.py: W1, b1, W2, b2, ...
        let g = ArchInfo::gcn(3, 48, 64, 7);
        let names: Vec<&str> = g.params.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["W1", "b1", "W2", "b2", "W3", "b3"]);
        let g2 = ArchInfo::gcnii(4, 48, 64, 7);
        let names2: Vec<&str> = g2.params.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names2, ["W0", "b0", "W1", "W2", "W3", "W4", "Wc", "bc"]);
    }
}
