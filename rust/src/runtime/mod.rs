//! Artifact metadata + (feature-gated) PJRT runtime.
//!
//! The manifest and host `Tensor` type are always available — the native
//! backend uses them without any artifacts on disk. The `Runtime` that
//! loads AOT HLO-text artifacts and executes them on the PJRT CPU client
//! (adapted from /opt/xla-example/load_hlo) only exists under the `pjrt`
//! feature, which pulls in the `xla` bindings; see `rust/README.md`.
//!
//! Python never runs here: the `xla` crate wraps the PJRT C API and the
//! artifacts are self-contained HLO text (see aot.py for why text, not
//! serialized protos).

pub mod manifest;
pub mod tensor;

pub use manifest::{ArchInfo, DType, Manifest, ProfileInfo, ProgramSpec, TensorSpec};
pub use tensor::Tensor;
#[cfg(feature = "pjrt")]
pub use tensor::{lit_f32, lit_i32, lit_scalar, scalar_f32, to_vec_f32};

#[cfg(feature = "pjrt")]
mod rt {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use anyhow::{bail, Context, Result};

    use super::manifest::Manifest;

    pub struct Runtime {
        pub client: xla::PjRtClient,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
        /// Cumulative executions per program (telemetry).
        pub exec_counts: Mutex<HashMap<String, u64>>,
        /// Cumulative seconds inside PJRT execute calls.
        pub exec_secs: Mutex<f64>,
    }

    impl Runtime {
        pub fn new(artifact_dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                manifest,
                cache: Mutex::new(HashMap::new()),
                exec_counts: Mutex::new(HashMap::new()),
                exec_secs: Mutex::new(0.0),
            })
        }

        /// Compile (or fetch the cached) executable for a program.
        pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(e) = self.cache.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let spec = self.manifest.program(name)?;
            let path = self.manifest.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("loading HLO {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
            let exe = std::sync::Arc::new(exe);
            self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Pre-compile a set of programs (hides compile latency from the loop).
        pub fn warmup(&self, names: &[&str]) -> Result<()> {
            for n in names {
                self.executable(n)?;
            }
            Ok(())
        }

        /// Execute a program with positional inputs, validating arity and
        /// element counts against the manifest. Returns the output tuple.
        pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let spec = self.manifest.program(name)?.clone();
            if inputs.len() != spec.inputs.len() {
                bail!(
                    "program {name}: got {} inputs, manifest expects {}",
                    inputs.len(),
                    spec.inputs.len()
                );
            }
            for (i, (lit, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
                let got = lit.element_count();
                if got != ts.elems() {
                    bail!(
                        "program {name} input #{i} ({}): {} elements, expected {} {:?}",
                        ts.name,
                        got,
                        ts.elems(),
                        ts.shape
                    );
                }
            }
            let exe = self.executable(name)?;
            let t0 = std::time::Instant::now();
            let bufs = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
            let result = bufs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching result of {name}: {e}"))?;
            let outs = result
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("untupling result of {name}: {e}"))?;
            *self.exec_secs.lock().unwrap() += t0.elapsed().as_secs_f64();
            if outs.len() != spec.outputs.len() {
                bail!(
                    "program {name}: got {} outputs, manifest expects {}",
                    outs.len(),
                    spec.outputs.len()
                );
            }
            *self
                .exec_counts
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert(0) += 1;
            Ok(outs)
        }

        pub fn total_exec_secs(&self) -> f64 {
            *self.exec_secs.lock().unwrap()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use rt::Runtime;
