//! Host tensor type + (feature-gated) XLA literal construction/extraction.

/// Host-side f32 tensor (row-major) used by the coordinator and backends.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0f32; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        lit_f32(&self.data, &self.shape)
    }

    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// Build an f32 literal of the given shape.
#[cfg(feature = "pjrt")]
pub fn lit_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let l = xla::Literal::vec1(data);
    if shape.is_empty() {
        // rank-0 scalar
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {shape:?}: {e}"))
}

/// Build an i32 literal of the given shape.
#[cfg(feature = "pjrt")]
pub fn lit_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let l = xla::Literal::vec1(data);
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    l.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {shape:?}: {e}"))
}

/// Rank-0 f32 scalar.
#[cfg(feature = "pjrt")]
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract an f32 literal's data (any rank).
#[cfg(feature = "pjrt")]
pub fn to_vec_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec<f32>: {e}"))
}

/// Extract a rank-0 f32.
#[cfg(feature = "pjrt")]
pub fn scalar_f32(lit: &xla::Literal) -> anyhow::Result<f32> {
    Ok(to_vec_f32(lit)?[0])
}
