//! Tiny CLI argument parser (no `clap` in the offline registry).
//!
//! Grammar: `lmc <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut out = Args::default();
        if let Some(sub) = it.peek() {
            if !sub.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.opt(key).and_then(|v| v.parse().ok())
    }

    pub fn opt_f64(&self, key: &str) -> Option<f64> {
        self.opt(key).and_then(|v| v.parse().ok())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        // NOTE: a bare `--flag` followed by a non-dash token consumes it as
        // a value (`--key value` form); positionals go before flags.
        let a = Args::parse(v(&[
            "train", "extra", "--dataset", "arxiv-sim", "--lr=0.01", "--verbose",
        ]));
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("dataset"), Some("arxiv-sim"));
        assert_eq!(a.opt_f64("lr"), Some(0.01));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn flag_before_value_option() {
        let a = Args::parse(v(&["x", "--flag", "--k", "v"]));
        assert!(a.has_flag("flag"));
        assert_eq!(a.opt("k"), Some("v"));
    }
}
