//! Perf-regression gate: diff a freshly measured `BENCH_step.json` against
//! the committed `BENCH_baseline.json` and fail CI on a real slowdown.
//!
//! Policy (see rust/README.md § Perf gate):
//!
//!   * only the metrics listed in the baseline's `gate.metrics` are gated
//!     (currently `gemm_s`, `aggregate_s`, `step_optimized_s`, the
//!     `history_gather_{f32,bf16}_s` pair, and the dimensionless
//!     `history_bytes_per_node` footprint) — every other phase in
//!     `BENCH_step.json` stays informational;
//!   * a metric fails only when `measured / baseline > gate.max_slowdown`
//!     (a generous noise band, default [`DEFAULT_MAX_SLOWDOWN`], so runner
//!     jitter and modest machine differences never flake the gate — it
//!     exists to catch step-function kernel regressions, not 10% drift);
//!   * improvements are reported but never gated;
//!   * smoke outputs (`BENCH_step.smoke.json`, `"smoke": true`) are
//!     refused outright: smoke iteration counts are not comparable to
//!     full-run baselines.
//!
//! Driven by `lmc bench-gate` (see `main.rs`); the markdown table it
//! returns is appended to the CI job summary.

use anyhow::{anyhow, bail, Result};

use crate::util::bench::fmt_secs;
use crate::util::json::Json;

/// Fallback noise band when the baseline omits `gate.max_slowdown` — kept
/// generous because such a file may come from a different machine class.
pub const DEFAULT_MAX_SLOWDOWN: f64 = 1.8;

/// The tightened band `--write-baseline` stamps into *measured*
/// baselines: a baseline regenerated on the CI perf-gate runner fleet
/// compares like-for-like (same runner class, same build flags), so the
/// cross-machine headroom of [`DEFAULT_MAX_SLOWDOWN`] is no longer
/// needed; 1.45x still clears observed same-runner jitter with margin
/// while catching well under half of a 2x kernel regression's slack.
pub const MEASURED_MAX_SLOWDOWN: f64 = 1.45;

/// The phases a regenerated baseline gates (single source of truth shared
/// with `benches/step_breakdown.rs --write-baseline`; a committed baseline
/// may list a different set — `compare` follows the file). Names ending in
/// `_s` are phase timings in seconds; `history_bytes_per_node` gates the
/// resident history footprint the same way (ratio over baseline), so a
/// change that silently widens the quantized store fails the gate.
pub const GATED_METRICS: [&str; 6] = [
    "gemm_s",
    "aggregate_s",
    "step_optimized_s",
    "history_gather_f32_s",
    "history_gather_bf16_s",
    "history_bytes_per_node",
];

/// One gated metric's comparison.
#[derive(Debug, Clone)]
pub struct GateRow {
    pub name: String,
    pub baseline_s: f64,
    pub measured_s: f64,
    /// `measured / baseline` (> 1 means slower than baseline).
    pub ratio: f64,
    pub pass: bool,
}

/// The full gate verdict.
#[derive(Debug, Clone)]
pub struct GateReport {
    pub max_slowdown: f64,
    pub rows: Vec<GateRow>,
    /// The baseline's provenance marks it as an estimate (never measured
    /// on real hardware) — the gate still enforces its generous headroom,
    /// but the summary carries a bootstrap warning until a measured
    /// baseline is committed.
    pub baseline_estimated: bool,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.rows.iter().all(|r| r.pass)
    }

    /// Markdown delta table for the CI job summary.
    pub fn markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("### perf gate: step-breakdown bench vs committed baseline\n\n");
        s.push_str(&format!(
            "noise band: a metric fails only above {:.2}x its baseline time\n\n",
            self.max_slowdown
        ));
        s.push_str("| metric | baseline | measured | ratio | status |\n");
        s.push_str("|---|---:|---:|---:|---|\n");
        for r in &self.rows {
            // only `_s`-suffixed metrics are durations; counters like
            // `history_bytes_per_node` print as plain numbers
            let (b, m) = if r.name.ends_with("_s") {
                (fmt_secs(r.baseline_s), fmt_secs(r.measured_s))
            } else {
                (format!("{}", r.baseline_s), format!("{}", r.measured_s))
            };
            s.push_str(&format!(
                "| {} | {} | {} | {:.2}x | {} |\n",
                r.name,
                b,
                m,
                r.ratio,
                if r.pass { "ok" } else { "**REGRESSION**" },
            ));
        }
        if self.passed() {
            s.push_str("\nperf gate: **pass**\n");
        } else {
            s.push_str("\nperf gate: **FAIL**\n");
        }
        if self.baseline_estimated {
            s.push_str(
                "\n> warning: the committed baseline is an *estimate* (see its \
                 provenance) — ratios above compare against projected headroom \
                 values, not measured hardware. Bootstrap a real baseline with \
                 `cargo bench --bench step_breakdown -- --write-baseline` on a \
                 representative runner and commit BENCH_baseline.json.\n",
            );
        }
        s
    }
}

/// Compare a measured bench output against the committed baseline.
///
/// `baseline` is `BENCH_baseline.json` (carries `gate.metrics`,
/// `gate.max_slowdown`, and `metrics.<name>` seconds); `bench` is a
/// full-run `BENCH_step.json` (gated values read from `phases.<name>`,
/// falling back to a top-level `<name>` field for the end-to-end step
/// timings).
pub fn compare(baseline: &Json, bench: &Json) -> Result<GateReport> {
    if bench.get("smoke").and_then(Json::as_bool) == Some(true) {
        bail!(
            "refusing to gate smoke bench output (BENCH_step.smoke.json): \
             smoke iteration counts are not comparable to full-run baselines"
        );
    }
    let max_slowdown = baseline
        .path("gate.max_slowdown")
        .and_then(Json::as_f64)
        .unwrap_or(DEFAULT_MAX_SLOWDOWN);
    if !(max_slowdown.is_finite() && max_slowdown >= 1.0) {
        bail!("baseline gate.max_slowdown must be a finite value >= 1.0, got {max_slowdown}");
    }
    let metrics = baseline
        .path("gate.metrics")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("baseline missing gate.metrics (list of gated phase names)"))?;
    let mut rows = Vec::new();
    for m in metrics {
        let name = m
            .as_str()
            .ok_or_else(|| anyhow!("gate.metrics entries must be strings, got {m}"))?;
        let baseline_s = baseline
            .path(&format!("metrics.{name}"))
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("baseline missing metrics.{name}"))?;
        if !(baseline_s.is_finite() && baseline_s > 0.0) {
            bail!("baseline metrics.{name} must be positive, got {baseline_s}");
        }
        let measured_s = bench
            .path(&format!("phases.{name}"))
            .or_else(|| bench.get(name))
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                anyhow!("bench output missing phase '{name}' (schema drift? regenerate both files)")
            })?;
        let ratio = measured_s / baseline_s;
        rows.push(GateRow {
            name: name.to_string(),
            baseline_s,
            measured_s,
            ratio,
            pass: ratio <= max_slowdown,
        });
    }
    if rows.is_empty() {
        bail!("gate.metrics is empty — nothing to gate");
    }
    let baseline_estimated = baseline
        .get("provenance")
        .and_then(Json::as_str)
        .is_some_and(|p| p.starts_with("estimated"));
    Ok(GateReport { max_slowdown, rows, baseline_estimated })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline_json() -> Json {
        Json::parse(
            r#"{
              "bench": "step_breakdown_baseline",
              "gate": {"max_slowdown": 1.8, "metrics": ["gemm_s", "aggregate_s", "step_optimized_s"]},
              "metrics": {"gemm_s": 1.0e-3, "aggregate_s": 2.0e-4, "step_optimized_s": 8.0e-3}
            }"#,
        )
        .unwrap()
    }

    fn bench_json(gemm: f64, agg: f64, step: f64, smoke: bool) -> Json {
        Json::parse(&format!(
            r#"{{
              "bench": "step_breakdown",
              "smoke": {smoke},
              "phases": {{"gemm_s": {gemm:e}, "aggregate_s": {agg:e}, "compensate_s": 1e-5}},
              "step_optimized_s": {step:e}
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn passes_at_parity_and_when_faster() {
        let base = baseline_json();
        let report = compare(&base, &bench_json(1.0e-3, 2.0e-4, 8.0e-3, false)).unwrap();
        assert!(report.passed());
        // a 2x improvement is reported (ratio 0.5) but never gated
        let report = compare(&base, &bench_json(5.0e-4, 1.0e-4, 4.0e-3, false)).unwrap();
        assert!(report.passed());
        assert!(report.rows.iter().all(|r| r.ratio < 0.6));
    }

    #[test]
    fn passes_inside_noise_band() {
        // 1.7x < 1.8x band: noisy-but-fine
        let report =
            compare(&baseline_json(), &bench_json(1.7e-3, 3.4e-4, 1.36e-2, false)).unwrap();
        assert!(report.passed(), "{:?}", report.rows);
    }

    /// The acceptance check: an injected 2x slowdown of a gated kernel
    /// metric must fail the gate.
    #[test]
    fn gate_fails_on_injected_2x_slowdown() {
        let report = compare(&baseline_json(), &bench_json(2.0e-3, 2.0e-4, 8.0e-3, false)).unwrap();
        assert!(!report.passed());
        let gemm = report.rows.iter().find(|r| r.name == "gemm_s").unwrap();
        assert!(!gemm.pass);
        assert!((gemm.ratio - 2.0).abs() < 1e-9);
        // the other metrics still read ok
        assert!(report.rows.iter().filter(|r| r.name != "gemm_s").all(|r| r.pass));
        assert!(report.markdown().contains("REGRESSION"));
        // end-to-end step regression is gated too
        let report = compare(&baseline_json(), &bench_json(1.0e-3, 2.0e-4, 1.7e-2, false)).unwrap();
        assert!(!report.passed());
    }

    #[test]
    fn refuses_smoke_outputs() {
        let err = compare(&baseline_json(), &bench_json(1.0e-3, 2.0e-4, 8.0e-3, true)).unwrap_err();
        assert!(err.to_string().contains("smoke"), "{err}");
    }

    #[test]
    fn missing_metric_is_an_error_not_a_pass() {
        let base = Json::parse(
            r#"{"gate": {"max_slowdown": 1.8, "metrics": ["nope_s"]}, "metrics": {"nope_s": 1.0e-3}}"#,
        )
        .unwrap();
        let err = compare(&base, &bench_json(1.0e-3, 2.0e-4, 8.0e-3, false)).unwrap_err();
        assert!(err.to_string().contains("nope_s"), "{err}");
    }

    #[test]
    fn measured_band_is_tighter_and_honored_from_the_file() {
        // a measured baseline carries MEASURED_MAX_SLOWDOWN in-file; the
        // gate follows the file, so a 1.6x slip that the legacy 1.8x band
        // would wave through now fails
        assert!(MEASURED_MAX_SLOWDOWN < DEFAULT_MAX_SLOWDOWN);
        let base = Json::parse(&format!(
            r#"{{
              "provenance": "measured commit=abc runner=github:Linux/X64 target=linux/x86_64 simd=avx2",
              "gate": {{"max_slowdown": {MEASURED_MAX_SLOWDOWN}, "metrics": ["gemm_s"]}},
              "metrics": {{"gemm_s": 1.0e-3}}
            }}"#
        ))
        .unwrap();
        let slow = compare(&base, &bench_json(1.6e-3, 0.0, 0.0, false)).unwrap();
        assert!(!slow.passed(), "1.6x must fail the measured band");
        assert!(!slow.baseline_estimated);
        let ok = compare(&base, &bench_json(1.4e-3, 0.0, 0.0, false)).unwrap();
        assert!(ok.passed(), "1.4x is inside the measured band");
    }

    #[test]
    fn default_band_applies_when_baseline_omits_it() {
        let base = Json::parse(
            r#"{"gate": {"metrics": ["gemm_s"]}, "metrics": {"gemm_s": 1.0e-3}}"#,
        )
        .unwrap();
        let report = compare(&base, &bench_json(1.79e-3, 0.0, 0.0, false)).unwrap();
        assert!((report.max_slowdown - DEFAULT_MAX_SLOWDOWN).abs() < 1e-12);
        assert!(report.passed());
    }

    #[test]
    fn estimated_baseline_carries_bootstrap_warning() {
        let base = Json::parse(
            r#"{
              "provenance": "estimated-no-toolchain headroom baseline",
              "gate": {"max_slowdown": 1.8, "metrics": ["gemm_s"]},
              "metrics": {"gemm_s": 1.0e-3}
            }"#,
        )
        .unwrap();
        let report = compare(&base, &bench_json(1.0e-3, 0.0, 0.0, false)).unwrap();
        assert!(report.baseline_estimated);
        assert!(report.passed());
        assert!(report.markdown().contains("warning"));
        // a measured baseline carries no warning
        let report =
            compare(&baseline_json(), &bench_json(1.0e-3, 2.0e-4, 8.0e-3, false)).unwrap();
        assert!(!report.baseline_estimated);
        assert!(!report.markdown().contains("warning"));
    }

    #[test]
    fn bytes_per_node_gates_by_ratio_and_prints_plain() {
        // the footprint counter rides the same ratio machinery: holding at
        // or below baseline passes, silently widening the store fails
        let base = Json::parse(
            r#"{
              "gate": {"max_slowdown": 1.45, "metrics": ["history_bytes_per_node"]},
              "metrics": {"history_bytes_per_node": 1024}
            }"#,
        )
        .unwrap();
        let bench = |v: u32| {
            Json::parse(&format!(
                r#"{{"smoke": false, "phases": {{}}, "history_bytes_per_node": {v}}}"#
            ))
            .unwrap()
        };
        let ok = compare(&base, &bench(1024)).unwrap();
        assert!(ok.passed());
        // plain-number formatting, not fmt_secs (no "µs"/"ms" suffix)
        let md = ok.markdown();
        assert!(md.contains("| history_bytes_per_node | 1024 | 1024 |"), "{md}");
        let fail = compare(&base, &bench(2048)).unwrap();
        assert!(!fail.passed(), "doubling the footprint must fail the gate");
    }

    #[test]
    fn markdown_lists_every_gated_metric() {
        let report = compare(&baseline_json(), &bench_json(1.0e-3, 2.0e-4, 8.0e-3, false)).unwrap();
        let md = report.markdown();
        for name in ["gemm_s", "aggregate_s", "step_optimized_s"] {
            assert!(md.contains(name), "missing {name} in:\n{md}");
        }
        assert!(md.contains("perf gate: **pass**"));
    }
}
