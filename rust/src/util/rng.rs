//! Deterministic, seedable RNG (xoshiro256++ seeded via SplitMix64).
//!
//! The offline crate registry has no `rand`; this is a small, well-known
//! generator that makes every experiment in the repo bit-reproducible from a
//! single `seed` config key.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for parallel/substream use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Raw xoshiro256++ state words — the exact stream position, for
    /// checkpointing.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a stream position saved by [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3])).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut v: Vec<usize> = (0..n).collect();
            self.shuffle(&mut v);
            v.truncate(k);
            v
        } else {
            // Floyd's algorithm for small k.
            let mut set = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let pick = if set.contains(&t) { j } else { t };
                set.insert(pick);
                out.push(pick);
            }
            self.shuffle(&mut out);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_exact_stream() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        for &(n, k) in &[(100, 5), (100, 80), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), k);
            assert!(d.iter().all(|&x| x < n));
        }
    }
}
