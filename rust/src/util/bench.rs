//! Micro-benchmark harness (no `criterion` in the offline registry).
//!
//! Criterion-style flow: warmup, then timed iterations until both a minimum
//! iteration count and a minimum measurement window are reached; reports
//! mean / p50 / p95 and throughput. Used by the `[[bench]]` targets
//! (`harness = false`) and the Table 6 / §Perf experiments.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}",
            self.name,
            self.iters,
            fmt_secs(self.mean_s),
            fmt_secs(self.p50_s),
            fmt_secs(self.p95_s),
            fmt_secs(self.min_s),
        )
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_window_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, min_iters: 10, max_iters: 1000, min_window_s: 1.0 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, min_iters: 5, max_iters: 100, min_window_s: 0.3 }
    }

    /// CI smoke caps (`BENCH_SMOKE=1` / `--quick` in the bench targets):
    /// just enough iterations to prove the path runs; the numbers land in
    /// namespaced `*.smoke.json` files and are never gated.
    pub fn smoke() -> Self {
        Bencher { warmup_iters: 1, min_iters: 2, max_iters: 8, min_window_s: 0.05 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            let done_window = start.elapsed().as_secs_f64() >= self.min_window_s;
            if (samples.len() >= self.min_iters && done_window) || samples.len() >= self.max_iters {
                break;
            }
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
            p50_s: sorted[sorted.len() / 2],
            p95_s: sorted[(sorted.len() as f64 * 0.95) as usize % sorted.len()],
            min_s: sorted[0],
        };
        println!("{}", stats.report());
        stats
    }
}

/// `black_box` stand-in: defeat const-propagation of benched values.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Provenance string stamped into regenerated `BENCH_*.json` files, so a
/// measured file is distinguishable from a committed estimate at a glance:
/// commit SHA (CI's `GITHUB_SHA`, else `git rev-parse`), runner identity
/// (`RUNNER_OS`/`RUNNER_ARCH` on GitHub, `local` otherwise), the compile
/// target, and the dispatched SIMD level.
pub fn provenance() -> String {
    let commit = std::env::var("GITHUB_SHA")
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(|| {
            std::process::Command::new("git")
                .args(["rev-parse", "--short=12", "HEAD"])
                .output()
                .ok()
                .filter(|o| o.status.success())
                .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string());
    let runner = match (std::env::var("RUNNER_OS"), std::env::var("RUNNER_ARCH")) {
        (Ok(os), Ok(arch)) => format!("github:{os}/{arch}"),
        _ => "local".to_string(),
    };
    format!(
        "measured commit={commit} runner={runner} target={}/{} simd={}",
        std::env::consts::OS,
        std::env::consts::ARCH,
        crate::backend::simd::level().name()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { warmup_iters: 1, min_iters: 3, max_iters: 5, min_window_s: 0.0 };
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 3);
        assert!(s.mean_s >= 0.0);
    }

    #[test]
    fn provenance_is_measured_and_stamped() {
        let p = provenance();
        assert!(p.starts_with("measured "), "{p}");
        assert!(p.contains("commit="), "{p}");
        assert!(p.contains("runner="), "{p}");
        assert!(p.contains("simd="), "{p}");
    }
}
