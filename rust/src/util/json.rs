//! Minimal JSON parser (no serde in the offline registry).
//!
//! Covers everything `artifacts/manifest.json` uses: objects, arrays,
//! strings with escapes, numbers, booleans, null. Strict enough to reject
//! malformed input with a positioned error message.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (panic-free, used all over the runtime) ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access with a dotted path for error clarity.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(seg)?,
                Json::Arr(v) => v.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = match self.b[start] {
                        b if b < 0x80 => 1,
                        b if b >> 5 == 0b110 => 2,
                        b if b >> 4 == 0b1110 => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version":1,"programs":[{"name":"a","B":128,"inputs":[{"shape":[2,3],"dtype":"f32"}]}],"flag":true,"x":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("programs.0.name").unwrap().as_str(), Some("a"));
        assert_eq!(v.path("programs.0.inputs.0.shape.1").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn escapes_and_numbers() {
        let v = Json::parse(r#"{"s":"a\nb\"cA","n":-1.5e3}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\nb\"cA"));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(-1500.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("tru").is_err());
    }
}
