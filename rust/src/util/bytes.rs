//! Little-endian byte-stream helpers shared by the on-disk formats
//! (`LMCPAR1` params, `LMCCKPT1` checkpoints): push/read primitives, a
//! bounds-checked cursor, and the CRC32 integrity trailer both formats
//! append so truncation or bit-flips surface as a readable error instead
//! of garbage state.

use std::sync::OnceLock;

use anyhow::{bail, Result};

pub fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// f64 as raw LE bits — bitwise round-trip, NaN payloads included.
pub fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn push_f32_slice(out: &mut Vec<u8>, vs: &[f32]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn push_u16_slice(out: &mut Vec<u8>, vs: &[u16]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn push_str(out: &mut Vec<u8>, s: &str) {
    push_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked reader over a byte slice; every decode error is a
/// readable `anyhow` message rather than a panic or silent wrap.
pub struct Cursor<'a> {
    pub b: &'a [u8],
    pub i: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, i: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!(
                "truncated input: wanted {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn u16_vec(&mut self, n: usize) -> Result<Vec<u16>> {
        let raw = self.take(n * 2)?;
        Ok(raw.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn u64_vec(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        Ok(String::from_utf8(raw.to_vec())?)
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
}

/// Magic prefix of the 8-byte integrity trailer: `b"LMCC"` + CRC32 (LE)
/// of every byte before the trailer.
pub const CRC_TRAILER_MAGIC: &[u8; 4] = b"LMCC";

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), byte-at-a-time table
/// driven — plenty for integrity checking at checkpoint sizes.
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append the `LMCC` + CRC32 trailer covering everything currently in
/// `out`.
pub fn append_crc_trailer(out: &mut Vec<u8>) {
    let c = crc32(out);
    out.extend_from_slice(CRC_TRAILER_MAGIC);
    out.extend_from_slice(&c.to_le_bytes());
}

/// Verify and strip a required `LMCC` trailer, returning the payload.
pub fn check_crc_trailer<'a>(bytes: &'a [u8], what: &str) -> Result<&'a [u8]> {
    if bytes.len() < 8 {
        bail!("{what}: too short to carry the CRC trailer ({} bytes)", bytes.len());
    }
    let (payload, trailer) = bytes.split_at(bytes.len() - 8);
    if &trailer[..4] != CRC_TRAILER_MAGIC {
        bail!("{what}: missing CRC trailer magic (file truncated or not this format)");
    }
    let stored = u32::from_le_bytes(trailer[4..].try_into().unwrap());
    let actual = crc32(payload);
    if stored != actual {
        bail!(
            "{what}: checksum mismatch (stored {stored:08x}, computed {actual:08x}) — \
             the file is truncated or bit-flipped"
        );
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard check value for the IEEE polynomial.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn trailer_roundtrip_and_corruption_detection() {
        let mut buf = b"some payload bytes".to_vec();
        append_crc_trailer(&mut buf);
        let payload = check_crc_trailer(&buf, "test").unwrap();
        assert_eq!(payload, b"some payload bytes");
        // flip one payload bit
        let mut bad = buf.clone();
        bad[3] ^= 0x40;
        let err = check_crc_trailer(&bad, "test").unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        // truncate into the trailer
        bad = buf[..buf.len() - 1].to_vec();
        assert!(check_crc_trailer(&bad, "test").is_err());
    }

    #[test]
    fn cursor_reports_truncation() {
        let mut out = Vec::new();
        push_u32(&mut out, 7);
        push_str(&mut out, "hi");
        let mut cur = Cursor::new(&out);
        assert_eq!(cur.u32().unwrap(), 7);
        assert_eq!(cur.str().unwrap(), "hi");
        assert_eq!(cur.remaining(), 0);
        let err = cur.u64().unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }
}
