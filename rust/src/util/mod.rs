//! In-repo replacements for crates absent from the offline registry:
//! RNG (`rand`), JSON (`serde_json`), TOML (`toml`), CLI args (`clap`),
//! bench timing (`criterion`), CSV/markdown table emission.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod failpoint;
pub mod json;
pub mod perfgate;
pub mod rng;
pub mod table;
pub mod toml;

/// Wall-clock stopwatch returning seconds.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}
