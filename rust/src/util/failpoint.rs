//! Env-driven fault-injection seam for crash-safety testing.
//!
//! `LMC_FAILPOINTS=site:when:action[,site:when:action...]` arms named
//! sites in the trainer step loop, sharded worker bodies, history
//! exchange, checkpoint IO, and the serve request path:
//!
//! * `when` — `N` (the Nth hit of that site, 1-based), `N+` (every hit
//!   from the Nth on), or `*` (every hit);
//! * `action` — `panic` (unwind at the site), `io-error` (the site
//!   returns an injected `Err`), `torn-write` (file-write sites only:
//!   write half the bytes to the temp file, then fail), or `sleep`
//!   (block ~120 s so an external harness can SIGKILL the process
//!   mid-run).
//!
//! When the variable is unset the seam is a single relaxed atomic load
//! per site visit — effectively free in the hot loop. Malformed entries
//! are reported to stderr and ignored rather than silently arming.
//!
//! Sites currently wired: `trainer.step`, `sharded.worker`,
//! `sharded.exchange`, `ckpt.save`, `ckpt.load`, `ckpt.write`,
//! `serve.request`, `serve.net.accept`, `serve.net.read`
//! (see rust/README.md § Fault tolerance).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{OnceLock, RwLock};

use anyhow::{anyhow, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    Panic,
    IoError,
    TornWrite,
    Sleep,
}

struct Rule {
    site: String,
    /// 1-based inclusive hit window `[from, to]` this rule triggers in.
    from: u64,
    to: u64,
    action: Action,
    hits: AtomicU64,
}

const ST_UNINIT: u8 = 0;
const ST_DISARMED: u8 = 1;
const ST_ARMED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(ST_UNINIT);

fn rules() -> &'static RwLock<Vec<Rule>> {
    static RULES: OnceLock<RwLock<Vec<Rule>>> = OnceLock::new();
    RULES.get_or_init(|| RwLock::new(Vec::new()))
}

fn parse_when(s: &str) -> Option<(u64, u64)> {
    if s == "*" {
        return Some((1, u64::MAX));
    }
    if let Some(n) = s.strip_suffix('+') {
        return n.parse::<u64>().ok().filter(|&n| n > 0).map(|n| (n, u64::MAX));
    }
    s.parse::<u64>().ok().filter(|&n| n > 0).map(|n| (n, n))
}

fn parse_spec(spec: &str) -> Vec<Rule> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let parts: Vec<&str> = entry.split(':').collect();
        let parsed = match parts.as_slice() {
            [site, when, action] => {
                let action = match *action {
                    "panic" => Some(Action::Panic),
                    "io-error" => Some(Action::IoError),
                    "torn-write" => Some(Action::TornWrite),
                    "sleep" => Some(Action::Sleep),
                    _ => None,
                };
                parse_when(when).zip(action).map(|((from, to), action)| Rule {
                    site: site.to_string(),
                    from,
                    to,
                    action,
                    hits: AtomicU64::new(0),
                })
            }
            _ => None,
        };
        match parsed {
            Some(rule) => out.push(rule),
            None => eprintln!(
                "warning: ignoring malformed LMC_FAILPOINTS entry {entry:?} \
                 (expected site:when:action, when = N|N+|*, \
                 action = panic|io-error|torn-write|sleep)"
            ),
        }
    }
    out
}

fn install(parsed: Vec<Rule>) {
    let mut w = rules().write().unwrap();
    let armed = !parsed.is_empty();
    *w = parsed;
    STATE.store(if armed { ST_ARMED } else { ST_DISARMED }, Ordering::SeqCst);
}

fn init_from_env() {
    install(parse_spec(&std::env::var("LMC_FAILPOINTS").unwrap_or_default()));
}

/// Replace the armed rules (tests; bypasses the env). An empty spec
/// disarms every site.
pub fn set_for_test(spec: &str) {
    install(parse_spec(spec));
}

fn check_slow(site: &str) -> Option<Action> {
    let r = rules().read().unwrap();
    let mut fire = None;
    for rule in r.iter().filter(|r| r.site == site) {
        // Every matching rule's hit counter advances on every visit, so
        // exact-N windows stay aligned even when several rules share a
        // site; the first rule whose window contains this visit wins.
        let hit = rule.hits.fetch_add(1, Ordering::SeqCst) + 1;
        if fire.is_none() && hit >= rule.from && hit <= rule.to {
            fire = Some(rule.action);
        }
    }
    fire
}

/// Consult the seam at `site`. `None` means proceed normally; callers
/// with special handling (the torn-write file sites) branch on the
/// action themselves, everyone else goes through [`fire`].
#[inline]
pub fn check(site: &str) -> Option<Action> {
    match STATE.load(Ordering::Relaxed) {
        ST_DISARMED => None,
        ST_UNINIT => {
            init_from_env();
            check_slow(site)
        }
        _ => check_slow(site),
    }
}

/// Visit the seam at `site` and perform the armed action, if any:
/// panic, return an injected error, or sleep. A `torn-write` rule on a
/// non-write site degrades to an injected error.
#[inline]
pub fn fire(site: &str) -> Result<()> {
    match check(site) {
        None => Ok(()),
        Some(Action::Panic) => panic!("failpoint {site}: injected panic"),
        Some(Action::IoError) => Err(anyhow!("failpoint {site}: injected io error")),
        Some(Action::TornWrite) => {
            Err(anyhow!("failpoint {site}: torn-write armed at a non-write site"))
        }
        Some(Action::Sleep) => {
            eprintln!("failpoint {site}: sleeping (waiting to be killed)");
            std::thread::sleep(std::time::Duration::from_secs(120));
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    use super::*;

    // The rule table is process-global; tests that arm it must not
    // interleave.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disarmed_seam_is_a_noop() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_for_test("");
        for _ in 0..100 {
            assert!(fire("trainer.step").is_ok());
        }
    }

    #[test]
    fn exact_hit_window_fires_once() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_for_test("a.site:3:io-error");
        assert!(fire("a.site").is_ok());
        assert!(fire("other.site").is_ok(), "site names must not cross-fire");
        assert!(fire("a.site").is_ok());
        let err = fire("a.site").unwrap_err().to_string();
        assert!(err.contains("a.site") && err.contains("injected"), "{err}");
        assert!(fire("a.site").is_ok(), "exact window must not refire");
        set_for_test("");
    }

    #[test]
    fn from_hit_window_fires_repeatedly() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_for_test("b.site:2+:io-error");
        assert!(fire("b.site").is_ok());
        assert!(fire("b.site").is_err());
        assert!(fire("b.site").is_err());
        set_for_test("");
    }

    #[test]
    fn panic_action_unwinds_with_site_name() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_for_test("c.site:1:panic");
        let r = std::panic::catch_unwind(|| fire("c.site"));
        set_for_test("");
        let payload = r.unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("c.site"), "{msg}");
    }

    #[test]
    fn malformed_entries_are_ignored() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_for_test("nonsense,too:few,x.site:0:panic,y.site:abc:panic,z.site:1:explode");
        assert!(fire("x.site").is_ok());
        assert!(fire("y.site").is_ok());
        assert!(fire("z.site").is_ok());
        set_for_test("");
    }

    #[test]
    fn check_exposes_raw_action_for_write_sites() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_for_test("w.site:1:torn-write");
        assert_eq!(check("w.site"), Some(Action::TornWrite));
        assert_eq!(check("w.site"), None);
        set_for_test("");
    }
}
