//! CSV + aligned-markdown table emission for the experiment harness.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch in '{}'", self.title);
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        writeln!(s, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")).unwrap();
        for r in &self.rows {
            writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")).unwrap();
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                write!(s, " {:<width$} |", c, width = w[i]).unwrap();
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            writeln!(out, "### {}\n", self.title).unwrap();
        }
        writeln!(out, "{}", fmt_row(&self.header)).unwrap();
        let sep: Vec<String> = w.iter().map(|&x| "-".repeat(x)).collect();
        writeln!(out, "{}", fmt_row(&sep)).unwrap();
        for r in &self.rows {
            writeln!(out, "{}", fmt_row(r)).unwrap();
        }
        out
    }

    pub fn save(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        Ok(())
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_markdown() {
        let mut t = Table::new("Demo", &["method", "acc"]);
        t.row(vec!["lmc".into(), "71.5".into()]);
        t.row(vec!["gas, inc".into(), "70.1".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"gas, inc\""));
        let md = t.to_markdown();
        assert!(md.contains("| method"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
