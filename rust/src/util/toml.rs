//! Minimal TOML-subset parser for the config system (no `toml` crate in the
//! offline registry).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / homogeneous inline arrays, `#` comments. That covers all
//! of `configs/*.toml`. Unsupported syntax fails loudly with a line number.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// `section.key -> value`; keys before any section header live under `""`.
pub type TomlDoc = BTreeMap<String, TomlValue>;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(ln, "unterminated section header"))?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(ln, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(ln, "empty key"));
        }
        let val = parse_value(line[eq + 1..].trim(), ln)?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.insert(full, val);
    }
    Ok(doc)
}

fn err(ln: usize, msg: &str) -> TomlError {
    TomlError { line: ln + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, ln: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(ln, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(ln, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(ln, "unsupported embedded quote"));
        }
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(ln, "unterminated array"))?
            .trim();
        let mut out = Vec::new();
        if !inner.is_empty() {
            for item in inner.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    continue; // allow trailing comma
                }
                out.push(parse_value(item, ln)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(ln, &format!("cannot parse value '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_config_shape() {
        let doc = parse(
            r#"
# experiment config
seed = 7
[train]
method = "lmc"   # the paper's method
lr = 1e-2
epochs = 200
betas = [0.4, 0.6]
fixed = true
"#,
        )
        .unwrap();
        assert_eq!(doc["seed"].as_i64(), Some(7));
        assert_eq!(doc["train.method"].as_str(), Some("lmc"));
        assert_eq!(doc["train.lr"].as_f64(), Some(1e-2));
        assert_eq!(doc["train.epochs"].as_i64(), Some(200));
        assert_eq!(doc["train.fixed"].as_bool(), Some(true));
        assert_eq!(doc["train.betas"].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[oops").is_err());
        assert!(parse("key").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"abc").is_err());
    }
}
