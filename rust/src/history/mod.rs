//! Historical value store (paper §5): per-layer embeddings `Hbar^l` and
//! auxiliary variables `Vbar^l` for l = 1..L-1, with staleness tracking and
//! the per-method write-back policies:
//!
//!   - LMC / GAS: scatter in-batch rows after each step (Algorithm 1).
//!   - FM (GraphFM-OB): additionally push a momentum update of the incomplete
//!     up-to-date halo values into halo rows.
//!   - CLUSTER: store unused.
//!
//! As in GAS, the store lives in host memory ("RAM or hard drive storage"),
//! so its footprint does not count against the simulated accelerator memory
//! (see coordinator::memory).
//!
//! ## Quantized storage ([`HistDtype`])
//!
//! The history is the dominant O(n·L·d) memory term and the halo gather is
//! bandwidth-bound on it, so rows can optionally be stored in bf16 or f16
//! (`history_dtype` config knob). The paper's convergence argument already
//! tolerates bounded *staleness* error in `Hbar`/`Vbar` (the Eq. 9/12
//! combination bounds); a ≤ 2⁻⁸-relative *quantization* error per element
//! is strictly smaller than typical inter-iteration drift, so it slots into
//! the same bound (see rust/README.md § Memory & precision).
//!
//! Every read/write goes through the private [`HistStore`] seam — the train
//! step's halo gathers, serve's cached-mode reads and `refresh_history`
//! bulk fill, and the sharded boundary exchange (`export_rows` /
//! `import_rows`) all encode/decode in one place:
//!
//!   * reads decode **directly into the caller's f32 destination** (the
//!     dequant-fused gather: bf16 rows widen via the dispatched SIMD
//!     [`simd::SimdOps::widen_bf16`], exact) — half-width rows never
//!     round-trip through a full-width scratch buffer;
//!   * writes encode with round-to-nearest-even; all arithmetic between a
//!     read and a write (momentum pushes included) runs in f32;
//!   * `HistDtype::F32` keeps the exact pre-quantization code path
//!     (`gather_rows`/`copy_from_slice`), so f32 mode stays bit-identical
//!     to the unquantized store.

use crate::backend::simd;
use crate::sampler::gather_rows_into;

/// Element type of the history store rows. Accumulation is always f32;
/// this only selects the at-rest encoding.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum HistDtype {
    /// 4 bytes/elem, bit-identical to the unquantized store (default).
    #[default]
    F32,
    /// 2 bytes/elem, f32's upper half: ~3 significant decimal digits,
    /// full f32 exponent range. Relative error ≤ 2⁻⁸ per element.
    Bf16,
    /// 2 bytes/elem IEEE half: ~3.3 digits but range capped at ±65504 —
    /// only safe when activations are known-bounded. Secondary option.
    F16,
}

impl HistDtype {
    pub fn parse(s: &str) -> Result<HistDtype, String> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(HistDtype::F32),
            "bf16" | "bfloat16" => Ok(HistDtype::Bf16),
            "f16" | "fp16" | "float16" | "half" => Ok(HistDtype::F16),
            other => Err(format!("unknown history dtype '{other}' (expected f32|bf16|f16)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            HistDtype::F32 => "f32",
            HistDtype::Bf16 => "bf16",
            HistDtype::F16 => "f16",
        }
    }

    pub fn bytes_per_elem(&self) -> usize {
        match self {
            HistDtype::F32 => 4,
            HistDtype::Bf16 | HistDtype::F16 => 2,
        }
    }
}

/// bf16 encode (round-to-nearest-even on the discarded 16 mantissa bits).
/// NaN payloads are squashed onto a canonical quiet NaN so rounding can
/// never turn a NaN into an infinity.
#[inline]
pub fn bf16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// bf16 decode — exact (bf16 is the upper half of an f32's bits). The
/// scalar oracle for the SIMD `widen_bf16` primitive.
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// IEEE binary16 encode (round-to-nearest-even; overflow → ±inf, underflow
/// through the subnormal range to ±0).
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / NaN (force a mantissa bit so NaN stays NaN)
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00;
    }
    if e <= 0 {
        if e < -10 {
            return sign;
        }
        // subnormal: significand = (implicit-1 mantissa) >> (14 - e), RNE
        let full = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            if rem > halfway || (rem == halfway && half & 1 != 0) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    // normal: drop 13 mantissa bits with RNE (a carry naturally overflows
    // into the exponent field, including 0x7BFF + 1 = inf)
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && half & 1 != 0) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE binary16 decode — exact (every half value is representable in f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    if exp == 0 {
        // subnormal: man × 2⁻²⁴ (exact in f32)
        let v = man as f32 * (1.0 / 16_777_216.0);
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// At-rest row storage behind one encode/decode seam. The `F32` variant is
/// the original store verbatim; the half variants hold raw 16-bit words.
#[derive(Clone, Debug)]
enum HistStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    F16(Vec<u16>),
}

/// Borrowed raw at-rest words of a [`LayerStore`] — the checkpoint
/// serialization view. Which 16-bit encoding a `U16` view holds (bf16 or
/// f16) is the store's [`HistDtype`]; the words are persisted verbatim so
/// quantized stores round-trip bit-exactly.
pub enum HistRaw<'a> {
    F32(&'a [f32]),
    U16(&'a [u16]),
}

#[derive(Clone, Debug)]
pub struct LayerStore {
    pub d: usize,
    store: HistStore, // [n, d] row-major
}

impl LayerStore {
    fn new(n: usize, d: usize, dtype: HistDtype) -> Self {
        let store = match dtype {
            HistDtype::F32 => HistStore::F32(vec![0f32; n * d]),
            HistDtype::Bf16 => HistStore::Bf16(vec![0u16; n * d]),
            HistDtype::F16 => HistStore::F16(vec![0u16; n * d]),
        };
        LayerStore { d, store }
    }

    pub fn dtype(&self) -> HistDtype {
        match self.store {
            HistStore::F32(_) => HistDtype::F32,
            HistStore::Bf16(_) => HistDtype::Bf16,
            HistStore::F16(_) => HistDtype::F16,
        }
    }

    /// Decode rows `idx` into the head of `out` (the dequant-fused gather):
    /// row `i` of `out` receives the decoded row `idx[i]`; rows past
    /// `idx.len()` are the caller's padding and stay untouched.
    fn gather_into(&self, idx: &[u32], out: &mut [f32]) {
        let d = self.d;
        match &self.store {
            HistStore::F32(data) => gather_rows_into(data, d, idx, out),
            HistStore::Bf16(data) => {
                let widen = simd::ops_auto().widen_bf16;
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    widen(&mut out[i * d..(i + 1) * d], &data[u * d..(u + 1) * d]);
                }
            }
            HistStore::F16(data) => {
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    let dst = &mut out[i * d..(i + 1) * d];
                    for (o, &h) in dst.iter_mut().zip(&data[u * d..(u + 1) * d]) {
                        *o = f16_to_f32(h);
                    }
                }
            }
        }
    }

    /// Encode the first `idx.len()` rows of `src` into rows `idx`.
    fn scatter(&mut self, idx: &[u32], src: &[f32]) {
        let d = self.d;
        debug_assert!(src.len() >= idx.len() * d, "scatter src too small");
        match &mut self.store {
            HistStore::F32(data) => {
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    data[u * d..(u + 1) * d].copy_from_slice(&src[i * d..(i + 1) * d]);
                }
            }
            HistStore::Bf16(data) => {
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    let row = &mut data[u * d..(u + 1) * d];
                    for (r, &x) in row.iter_mut().zip(&src[i * d..(i + 1) * d]) {
                        *r = bf16_from_f32(x);
                    }
                }
            }
            HistStore::F16(data) => {
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    let row = &mut data[u * d..(u + 1) * d];
                    for (r, &x) in row.iter_mut().zip(&src[i * d..(i + 1) * d]) {
                        *r = f16_from_f32(x);
                    }
                }
            }
        }
    }

    /// Bulk-encode a dense `[n, d]` buffer into the whole store — serve's
    /// `refresh_history` write path, routed through the same seam.
    fn fill(&mut self, src: &[f32]) {
        match &mut self.store {
            HistStore::F32(data) => data.copy_from_slice(src),
            HistStore::Bf16(data) => {
                debug_assert_eq!(data.len(), src.len());
                for (r, &x) in data.iter_mut().zip(src) {
                    *r = bf16_from_f32(x);
                }
            }
            HistStore::F16(data) => {
                debug_assert_eq!(data.len(), src.len());
                for (r, &x) in data.iter_mut().zip(src) {
                    *r = f16_from_f32(x);
                }
            }
        }
    }

    /// FM momentum push rows: `row <- (1-m)·row + m·fresh`, accumulated in
    /// f32 (half rows decode, mix, re-encode — one rounding per write).
    fn momentum_rows(&mut self, idx: &[u32], fresh: &[f32], m: f32) {
        let d = self.d;
        match &mut self.store {
            HistStore::F32(data) => {
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    let row = &mut data[u * d..(u + 1) * d];
                    for (r, &x) in row.iter_mut().zip(&fresh[i * d..(i + 1) * d]) {
                        *r = (1.0 - m) * *r + m * x;
                    }
                }
            }
            HistStore::Bf16(data) => {
                let widen = simd::ops_auto().widen_bf16;
                let mut tmp = vec![0f32; d];
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    widen(&mut tmp, &data[u * d..(u + 1) * d]);
                    for (t, &x) in tmp.iter_mut().zip(&fresh[i * d..(i + 1) * d]) {
                        *t = (1.0 - m) * *t + m * x;
                    }
                    let row = &mut data[u * d..(u + 1) * d];
                    for (r, &t) in row.iter_mut().zip(&tmp) {
                        *r = bf16_from_f32(t);
                    }
                }
            }
            HistStore::F16(data) => {
                for (i, &u) in idx.iter().enumerate() {
                    let u = u as usize;
                    let row = &mut data[u * d..(u + 1) * d];
                    for (r, &x) in row.iter_mut().zip(&fresh[i * d..(i + 1) * d]) {
                        let t = (1.0 - m) * f16_to_f32(*r) + m * x;
                        *r = f16_from_f32(t);
                    }
                }
            }
        }
    }

    /// Borrowed view of the raw at-rest words — the checkpoint encode
    /// path, which must persist the store bit-exactly at its configured
    /// dtype (no decode/re-encode round trip).
    pub fn raw_words(&self) -> HistRaw<'_> {
        match &self.store {
            HistStore::F32(data) => HistRaw::F32(data),
            HistStore::Bf16(data) | HistStore::F16(data) => HistRaw::U16(data),
        }
    }

    /// Overwrite an f32 store from raw words (checkpoint decode); the
    /// store's dtype and element count must match.
    pub fn set_raw_f32(&mut self, words: &[f32]) -> Result<(), String> {
        match &mut self.store {
            HistStore::F32(data) if data.len() == words.len() => {
                data.copy_from_slice(words);
                Ok(())
            }
            HistStore::F32(data) => {
                Err(format!("raw f32 word count {} != store size {}", words.len(), data.len()))
            }
            _ => Err(format!("raw f32 words offered to a {} store", self.dtype().name())),
        }
    }

    /// Overwrite a bf16/f16 store from raw 16-bit words (checkpoint
    /// decode); the store's dtype and element count must match.
    pub fn set_raw_u16(&mut self, words: &[u16]) -> Result<(), String> {
        match &mut self.store {
            HistStore::Bf16(data) | HistStore::F16(data) if data.len() == words.len() => {
                data.copy_from_slice(words);
                Ok(())
            }
            HistStore::Bf16(data) | HistStore::F16(data) => {
                Err(format!("raw u16 word count {} != store size {}", words.len(), data.len()))
            }
            _ => Err("raw u16 words offered to an f32 store".to_string()),
        }
    }

    /// Host bytes held by this store.
    fn bytes(&self) -> usize {
        match &self.store {
            HistStore::F32(data) => std::mem::size_of_val(data.as_slice()),
            HistStore::Bf16(data) | HistStore::F16(data) => {
                std::mem::size_of_val(data.as_slice())
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct History {
    pub n: usize,
    /// Hbar^l for l = 1..L-1 (index 0 = layer 1).
    pub h: Vec<LayerStore>,
    /// Vbar^l for l = 1..L-1.
    pub v: Vec<LayerStore>,
    /// Iteration at which each node's histories were last written.
    pub last_update: Vec<u64>,
    pub iter: u64,
    dtype: HistDtype,
}

impl History {
    /// f32 store — bit-identical to the pre-quantization `History`.
    pub fn new(n: usize, layer_dims: &[usize]) -> History {
        History::with_dtype(n, layer_dims, HistDtype::F32)
    }

    /// Store with an explicit at-rest dtype (`history_dtype` config knob).
    pub fn with_dtype(n: usize, layer_dims: &[usize], dtype: HistDtype) -> History {
        History {
            n,
            h: layer_dims.iter().map(|&d| LayerStore::new(n, d, dtype)).collect(),
            v: layer_dims.iter().map(|&d| LayerStore::new(n, d, dtype)).collect(),
            last_update: vec![0; n],
            iter: 0,
            dtype,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.h.len()
    }

    pub fn dtype(&self) -> HistDtype {
        self.dtype
    }

    /// Gather halo rows of layer `l` (1-based) into a padded [rows, d] buffer.
    pub fn gather_h(&self, l: usize, idx: &[u32], rows: usize) -> Vec<f32> {
        let s = &self.h[l - 1];
        let mut out = vec![0f32; rows * s.d];
        s.gather_into(idx, &mut out);
        out
    }

    pub fn gather_v(&self, l: usize, idx: &[u32], rows: usize) -> Vec<f32> {
        let s = &self.v[l - 1];
        let mut out = vec![0f32; rows * s.d];
        s.gather_into(idx, &mut out);
        out
    }

    /// [`History::gather_h`] into a caller-provided (pre-zeroed) buffer —
    /// the workspace-reuse path: no allocation, rows past `idx.len()` are
    /// the caller's padding. Half-width rows decode directly into `out`
    /// (no full-width scratch round-trip).
    pub fn gather_h_into(&self, l: usize, idx: &[u32], out: &mut [f32]) {
        self.h[l - 1].gather_into(idx, out);
    }

    pub fn gather_v_into(&self, l: usize, idx: &[u32], out: &mut [f32]) {
        self.v[l - 1].gather_into(idx, out);
    }

    /// Scatter (encode) the first `idx.len()` rows of `src` (padded buffer)
    /// into layer `l`'s H store.
    pub fn scatter_h(&mut self, l: usize, idx: &[u32], src: &[f32]) {
        self.h[l - 1].scatter(idx, src);
    }

    pub fn scatter_v(&mut self, l: usize, idx: &[u32], src: &[f32]) {
        self.v[l - 1].scatter(idx, src);
    }

    /// Bulk-encode a dense `[n, d]` buffer into layer `l`'s H store —
    /// serve's `refresh_history` write path (full-graph forward output).
    pub fn fill_h(&mut self, l: usize, src: &[f32]) {
        self.h[l - 1].fill(src);
    }

    /// Pack layer-`l` H and V rows `idx` into dense `[idx.len(), d]` f32
    /// buffers — the send side of the cross-shard boundary exchange (a
    /// shard exports the rows other shards see as halo). Rows are exported
    /// *decoded*, so shards agree on boundary values whatever the at-rest
    /// dtype, and re-encoding an exported row is lossless (the values are
    /// already on the dtype's grid).
    pub fn export_rows(&self, l: usize, idx: &[u32]) -> (Vec<f32>, Vec<f32>) {
        (self.gather_h(l, idx, idx.len()), self.gather_v(l, idx, idx.len()))
    }

    /// Unpack buffers packed by [`History::export_rows`] into rows `idx` —
    /// the receive side of the boundary exchange. `h`/`v` must hold
    /// `idx.len()` rows each. Imported rows count as freshly written at the
    /// current iteration, so the staleness metric sees the exchange (the
    /// whole point of hist-mode sync is lowering boundary staleness).
    pub fn import_rows(&mut self, l: usize, idx: &[u32], h: &[f32], v: &[f32]) {
        self.scatter_h(l, idx, h);
        self.scatter_v(l, idx, v);
        for &u in idx {
            self.last_update[u as usize] = self.iter;
        }
    }

    /// FM momentum push: hist <- (1-m) * hist + m * fresh for halo rows.
    pub fn momentum_h(&mut self, l: usize, idx: &[u32], fresh: &[f32], m: f32) {
        self.h[l - 1].momentum_rows(idx, fresh, m);
    }

    /// Mark in-batch nodes updated at the current iteration, then advance.
    pub fn tick(&mut self, batch: &[u32]) {
        self.iter += 1;
        for &u in batch {
            self.last_update[u as usize] = self.iter;
        }
    }

    /// Mean staleness (iterations since last write) over all nodes.
    pub fn mean_staleness(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let total: u64 = self.last_update.iter().map(|&t| self.iter - t).sum();
        total as f64 / self.n as f64
    }

    /// Total host bytes held by the store.
    pub fn bytes(&self) -> usize {
        self.h.iter().chain(self.v.iter()).map(|s| s.bytes()).sum()
    }

    /// At-rest bytes per node: `2 · Σ_l d_l · sizeof(dtype)` (H and V
    /// stores) — the capacity-per-machine number the perf gate tracks.
    pub fn bytes_per_node(&self) -> usize {
        self.h
            .iter()
            .chain(self.v.iter())
            .map(|s| s.d * s.dtype().bytes_per_elem())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let mut h = History::new(10, &[3, 4]);
        let idx = [2u32, 5, 7];
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect(); // 3 rows of d=3 + pad
        h.scatter_h(1, &idx, &src);
        let back = h.gather_h(1, &idx, 5);
        assert_eq!(&back[..9], &src[..9]);
        assert!(back[9..].iter().all(|&x| x == 0.0)); // padding
        // untouched rows stay zero
        let other = h.gather_h(1, &[0, 1], 2);
        assert!(other.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn half_stores_roundtrip_exact_on_grid_values() {
        // small integers are exactly representable in bf16 and f16, so the
        // quantized stores must round-trip them bit-for-bit
        for dtype in [HistDtype::Bf16, HistDtype::F16] {
            let mut h = History::with_dtype(10, &[3, 4], dtype);
            assert_eq!(h.dtype(), dtype);
            let idx = [2u32, 5, 7];
            let src: Vec<f32> = (0..12).map(|x| x as f32).collect();
            h.scatter_h(1, &idx, &src);
            let back = h.gather_h(1, &idx, 5);
            assert_eq!(&back[..9], &src[..9], "{}", dtype.name());
            assert!(back[9..].iter().all(|&x| x == 0.0));
            // gather_into leaves padding rows untouched
            let mut out = vec![7f32; 4 * 3];
            h.gather_h_into(1, &idx, &mut out);
            assert_eq!(&out[..9], &src[..9]);
            assert!(out[9..].iter().all(|&x| x == 7.0));
        }
    }

    #[test]
    fn bf16_roundtrip_error_is_bounded() {
        // RNE to 8 significand bits: relative error ≤ 2⁻⁸ per element
        // (half-ULP bound; the proptest in tests/ sweeps this broadly)
        for &x in &[1.0f32, -1.0, 3.14159, 1e-3, -2.7e4, 6.55e4, 1e-30, -1e30] {
            let back = bf16_to_f32(bf16_from_f32(x));
            assert!(
                (back - x).abs() <= x.abs() / 256.0,
                "bf16 roundtrip of {x} gave {back}"
            );
        }
        // specials survive
        assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_from_f32(0.0), 0);
        assert_eq!(bf16_from_f32(-0.0), 0x8000);
    }

    #[test]
    fn f16_roundtrip_matches_ieee_half() {
        // exactly-representable halves round-trip bitwise
        for &x in &[0.0f32, -0.0, 1.0, -2.0, 0.5, 65504.0, 6.103515625e-5] {
            assert_eq!(f16_to_f32(f16_from_f32(x)), x);
        }
        // known encodings
        assert_eq!(f16_from_f32(1.0), 0x3C00);
        assert_eq!(f16_from_f32(-2.0), 0xC000);
        assert_eq!(f16_from_f32(65504.0), 0x7BFF);
        // overflow → inf; tiny → zero; subnormals exact
        assert_eq!(f16_from_f32(1e6), 0x7C00);
        assert_eq!(f16_from_f32(1e-10), 0);
        let sub = f16_to_f32(0x0001);
        assert_eq!(sub, 1.0 / 16_777_216.0);
        assert_eq!(f16_from_f32(sub), 0x0001);
        // RNE at 11 significand bits: relative error ≤ 2⁻¹¹ in range
        for &x in &[3.14159f32, 0.1, -123.456, 999.9] {
            let back = f16_to_f32(f16_from_f32(x));
            assert!((back - x).abs() <= x.abs() / 2048.0, "f16 roundtrip of {x} gave {back}");
        }
        assert!(f16_to_f32(f16_from_f32(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f16_from_f32(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn export_import_rows_roundtrip_across_stores() {
        let mut a = History::new(6, &[3]);
        a.scatter_h(1, &[1, 4], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        a.scatter_v(1, &[1, 4], &[6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        let (h, v) = a.export_rows(1, &[1, 4]);
        assert_eq!(h, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(v, vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        // import into different rows of a differently-sized store
        let mut b = History::new(10, &[3]);
        b.import_rows(1, &[0, 9], &h, &v);
        assert_eq!(b.gather_h(1, &[0, 9], 2), h);
        assert_eq!(b.gather_v(1, &[0, 9], 2), v);
        // rows not addressed stay zero
        assert!(b.gather_h(1, &[5], 1).iter().all(|&x| x == 0.0));
        // imported rows count as freshly written for staleness purposes
        let mut c = History::new(4, &[3]);
        c.tick(&[0, 1, 2, 3]);
        c.tick(&[0]); // iter = 2; rows 1..4 last written at iter 1
        c.import_rows(1, &[1, 2], &h, &v);
        assert_eq!(c.last_update[1], 2);
        assert_eq!(c.last_update[2], 2);
        assert_eq!(c.last_update[3], 1);
    }

    #[test]
    fn export_import_is_lossless_between_same_dtype_stores() {
        // the sharded boundary-sync equivalence check compares export_rows
        // outputs: exported rows sit on the dtype grid, so a second
        // encode/decode hop must be the identity
        for dtype in [HistDtype::F32, HistDtype::Bf16, HistDtype::F16] {
            let mut a = History::with_dtype(6, &[3], dtype);
            a.scatter_h(1, &[1, 4], &[1.0, 0.333, 3.0, 4.0, 5.5, 6.0]);
            a.scatter_v(1, &[1, 4], &[6.0, 5.0, 0.777, 3.0, 2.0, 1.0]);
            let (h, v) = a.export_rows(1, &[1, 4]);
            let mut b = History::with_dtype(6, &[3], dtype);
            b.import_rows(1, &[1, 4], &h, &v);
            let (h2, v2) = b.export_rows(1, &[1, 4]);
            assert_eq!(h, h2, "{}", dtype.name());
            assert_eq!(v, v2, "{}", dtype.name());
        }
    }

    #[test]
    fn momentum_push() {
        let mut h = History::new(4, &[2]);
        h.scatter_h(1, &[1], &[1.0, 1.0]);
        h.momentum_h(1, &[1], &[3.0, 5.0], 0.5);
        let row = h.gather_h(1, &[1], 1);
        assert_eq!(row, vec![2.0, 3.0]);
    }

    #[test]
    fn momentum_push_accumulates_in_f32_on_half_stores() {
        // grid-exact inputs and m = 0.5 keep the f32 mix exact, so the
        // re-encoded result must equal the f32-store result exactly
        for dtype in [HistDtype::Bf16, HistDtype::F16] {
            let mut h = History::with_dtype(4, &[2], dtype);
            h.scatter_h(1, &[1], &[1.0, 1.0]);
            h.momentum_h(1, &[1], &[3.0, 5.0], 0.5);
            assert_eq!(h.gather_h(1, &[1], 1), vec![2.0, 3.0], "{}", dtype.name());
        }
    }

    #[test]
    fn fill_h_routes_through_encode() {
        let mut h = History::with_dtype(3, &[2], HistDtype::Bf16);
        h.fill_h(1, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(h.gather_h(1, &[0, 1, 2], 3), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // non-grid values land on the bf16 grid
        let mut q = History::with_dtype(1, &[1], HistDtype::Bf16);
        q.fill_h(1, &[1.0 + 1.0 / 1024.0]);
        let got = q.gather_h(1, &[0], 1)[0];
        assert_eq!(got.to_bits() & 0xFFFF, 0, "bf16 store held low mantissa bits");
        assert!((got - 1.0).abs() <= 1.0 / 256.0);
    }

    #[test]
    fn raw_words_roundtrip_preserves_quantized_bits() {
        for dtype in [HistDtype::F32, HistDtype::Bf16, HistDtype::F16] {
            let mut a = History::with_dtype(5, &[3], dtype);
            a.scatter_h(1, &[0, 2, 4], &[0.1, -2.7, 3.3, 1e-8, -0.0, 7.25, 0.333, 9.9, -1.5]);
            let mut b = History::with_dtype(5, &[3], dtype);
            match a.h[0].raw_words() {
                HistRaw::F32(w) => b.h[0].set_raw_f32(w).unwrap(),
                HistRaw::U16(w) => b.h[0].set_raw_u16(w).unwrap(),
            }
            // the copy is word-exact, not value-approximate
            match (a.h[0].raw_words(), b.h[0].raw_words()) {
                (HistRaw::F32(x), HistRaw::F32(y)) => assert_eq!(
                    x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    y.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                ),
                (HistRaw::U16(x), HistRaw::U16(y)) => assert_eq!(x, y),
                _ => panic!("dtype drifted"),
            }
        }
        // mismatched dtype or length is refused
        let mut f32s = History::with_dtype(2, &[2], HistDtype::F32);
        assert!(f32s.h[0].set_raw_u16(&[0, 0, 0, 0]).is_err());
        assert!(f32s.h[0].set_raw_f32(&[0.0; 3]).is_err());
        let mut halves = History::with_dtype(2, &[2], HistDtype::Bf16);
        assert!(halves.h[0].set_raw_f32(&[0.0; 4]).is_err());
        assert!(halves.h[0].set_raw_u16(&[0; 5]).is_err());
    }

    #[test]
    fn staleness_tracks() {
        let mut h = History::new(4, &[2]);
        h.tick(&[0, 1]);
        h.tick(&[2]);
        // iter=2: node0,1 age 1; node2 age 0; node3 age 2
        assert!((h.mean_staleness() - (1.0 + 1.0 + 0.0 + 2.0) / 4.0).abs() < 1e-9);
    }

    #[test]
    fn bytes_accounting() {
        let h = History::new(100, &[8, 8]);
        assert_eq!(h.bytes(), 2 * 2 * 100 * 8 * 4);
        assert_eq!(h.bytes_per_node(), 2 * 2 * 8 * 4);
        // bf16 halves both numbers
        let q = History::with_dtype(100, &[8, 8], HistDtype::Bf16);
        assert_eq!(q.bytes(), 2 * 2 * 100 * 8 * 2);
        assert_eq!(q.bytes_per_node(), 2 * 2 * 8 * 2);
        assert_eq!(q.bytes() * 2, h.bytes());
    }
}
