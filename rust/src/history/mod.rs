//! Historical value store (paper §5): per-layer embeddings `Hbar^l` and
//! auxiliary variables `Vbar^l` for l = 1..L-1, with staleness tracking and
//! the per-method write-back policies:
//!
//!   - LMC / GAS: scatter in-batch rows after each step (Algorithm 1).
//!   - FM (GraphFM-OB): additionally push a momentum update of the incomplete
//!     up-to-date halo values into halo rows.
//!   - CLUSTER: store unused.
//!
//! As in GAS, the store lives in host memory ("RAM or hard drive storage"),
//! so its footprint does not count against the simulated accelerator memory
//! (see coordinator::memory).

use crate::sampler::{gather_rows, gather_rows_into};

#[derive(Clone, Debug)]
pub struct LayerStore {
    pub d: usize,
    pub data: Vec<f32>, // [n, d] row-major
}

impl LayerStore {
    fn new(n: usize, d: usize) -> Self {
        LayerStore { d, data: vec![0f32; n * d] }
    }
}

#[derive(Clone, Debug)]
pub struct History {
    pub n: usize,
    /// Hbar^l for l = 1..L-1 (index 0 = layer 1).
    pub h: Vec<LayerStore>,
    /// Vbar^l for l = 1..L-1.
    pub v: Vec<LayerStore>,
    /// Iteration at which each node's histories were last written.
    pub last_update: Vec<u64>,
    pub iter: u64,
}

impl History {
    pub fn new(n: usize, layer_dims: &[usize]) -> History {
        History {
            n,
            h: layer_dims.iter().map(|&d| LayerStore::new(n, d)).collect(),
            v: layer_dims.iter().map(|&d| LayerStore::new(n, d)).collect(),
            last_update: vec![0; n],
            iter: 0,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.h.len()
    }

    /// Gather halo rows of layer `l` (1-based) into a padded [rows, d] buffer.
    pub fn gather_h(&self, l: usize, idx: &[u32], rows: usize) -> Vec<f32> {
        let s = &self.h[l - 1];
        gather_rows(&s.data, s.d, idx, rows)
    }

    pub fn gather_v(&self, l: usize, idx: &[u32], rows: usize) -> Vec<f32> {
        let s = &self.v[l - 1];
        gather_rows(&s.data, s.d, idx, rows)
    }

    /// [`History::gather_h`] into a caller-provided (pre-zeroed) buffer —
    /// the workspace-reuse path: no allocation, rows past `idx.len()` are
    /// the caller's padding.
    pub fn gather_h_into(&self, l: usize, idx: &[u32], out: &mut [f32]) {
        let s = &self.h[l - 1];
        gather_rows_into(&s.data, s.d, idx, out);
    }

    pub fn gather_v_into(&self, l: usize, idx: &[u32], out: &mut [f32]) {
        let s = &self.v[l - 1];
        gather_rows_into(&s.data, s.d, idx, out);
    }

    /// Scatter the first `idx.len()` rows of `src` (padded buffer) into
    /// layer `l`'s H store.
    pub fn scatter_h(&mut self, l: usize, idx: &[u32], src: &[f32]) {
        scatter(&mut self.h[l - 1], idx, src);
    }

    pub fn scatter_v(&mut self, l: usize, idx: &[u32], src: &[f32]) {
        scatter(&mut self.v[l - 1], idx, src);
    }

    /// Pack layer-`l` H and V rows `idx` into dense `[idx.len(), d]`
    /// buffers — the send side of the cross-shard boundary exchange (a
    /// shard exports the rows other shards see as halo).
    pub fn export_rows(&self, l: usize, idx: &[u32]) -> (Vec<f32>, Vec<f32>) {
        (self.gather_h(l, idx, idx.len()), self.gather_v(l, idx, idx.len()))
    }

    /// Unpack buffers packed by [`History::export_rows`] into rows `idx` —
    /// the receive side of the boundary exchange. `h`/`v` must hold
    /// `idx.len()` rows each. Imported rows count as freshly written at the
    /// current iteration, so the staleness metric sees the exchange (the
    /// whole point of hist-mode sync is lowering boundary staleness).
    pub fn import_rows(&mut self, l: usize, idx: &[u32], h: &[f32], v: &[f32]) {
        self.scatter_h(l, idx, h);
        self.scatter_v(l, idx, v);
        for &u in idx {
            self.last_update[u as usize] = self.iter;
        }
    }

    /// FM momentum push: hist <- (1-m) * hist + m * fresh for halo rows.
    pub fn momentum_h(&mut self, l: usize, idx: &[u32], fresh: &[f32], m: f32) {
        let store = &mut self.h[l - 1];
        let d = store.d;
        for (i, &u) in idx.iter().enumerate() {
            let row = &mut store.data[u as usize * d..(u as usize + 1) * d];
            let f = &fresh[i * d..(i + 1) * d];
            for (r, &x) in row.iter_mut().zip(f) {
                *r = (1.0 - m) * *r + m * x;
            }
        }
    }

    /// Mark in-batch nodes updated at the current iteration, then advance.
    pub fn tick(&mut self, batch: &[u32]) {
        self.iter += 1;
        for &u in batch {
            self.last_update[u as usize] = self.iter;
        }
    }

    /// Mean staleness (iterations since last write) over all nodes.
    pub fn mean_staleness(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let total: u64 = self.last_update.iter().map(|&t| self.iter - t).sum();
        total as f64 / self.n as f64
    }

    /// Total host bytes held by the store.
    pub fn bytes(&self) -> usize {
        self.h
            .iter()
            .chain(self.v.iter())
            .map(|s| s.data.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

fn scatter(store: &mut LayerStore, idx: &[u32], src: &[f32]) {
    let d = store.d;
    debug_assert!(src.len() >= idx.len() * d, "scatter src too small");
    for (i, &u) in idx.iter().enumerate() {
        store.data[u as usize * d..(u as usize + 1) * d]
            .copy_from_slice(&src[i * d..(i + 1) * d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let mut h = History::new(10, &[3, 4]);
        let idx = [2u32, 5, 7];
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect(); // 3 rows of d=3 + pad
        h.scatter_h(1, &idx, &src);
        let back = h.gather_h(1, &idx, 5);
        assert_eq!(&back[..9], &src[..9]);
        assert!(back[9..].iter().all(|&x| x == 0.0)); // padding
        // untouched rows stay zero
        let other = h.gather_h(1, &[0, 1], 2);
        assert!(other.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn export_import_rows_roundtrip_across_stores() {
        let mut a = History::new(6, &[3]);
        a.scatter_h(1, &[1, 4], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        a.scatter_v(1, &[1, 4], &[6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        let (h, v) = a.export_rows(1, &[1, 4]);
        assert_eq!(h, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(v, vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        // import into different rows of a differently-sized store
        let mut b = History::new(10, &[3]);
        b.import_rows(1, &[0, 9], &h, &v);
        assert_eq!(b.gather_h(1, &[0, 9], 2), h);
        assert_eq!(b.gather_v(1, &[0, 9], 2), v);
        // rows not addressed stay zero
        assert!(b.gather_h(1, &[5], 1).iter().all(|&x| x == 0.0));
        // imported rows count as freshly written for staleness purposes
        let mut c = History::new(4, &[3]);
        c.tick(&[0, 1, 2, 3]);
        c.tick(&[0]); // iter = 2; rows 1..4 last written at iter 1
        c.import_rows(1, &[1, 2], &h, &v);
        assert_eq!(c.last_update[1], 2);
        assert_eq!(c.last_update[2], 2);
        assert_eq!(c.last_update[3], 1);
    }

    #[test]
    fn momentum_push() {
        let mut h = History::new(4, &[2]);
        h.scatter_h(1, &[1], &[1.0, 1.0]);
        h.momentum_h(1, &[1], &[3.0, 5.0], 0.5);
        let row = h.gather_h(1, &[1], 1);
        assert_eq!(row, vec![2.0, 3.0]);
    }

    #[test]
    fn staleness_tracks() {
        let mut h = History::new(4, &[2]);
        h.tick(&[0, 1]);
        h.tick(&[2]);
        // iter=2: node0,1 age 1; node2 age 0; node3 age 2
        assert!((h.mean_staleness() - (1.0 + 1.0 + 0.0 + 2.0) / 4.0).abs() < 1e-9);
    }

    #[test]
    fn bytes_accounting() {
        let h = History::new(100, &[8, 8]);
        assert_eq!(h.bytes(), 2 * 2 * 100 * 8 * 4);
    }
}
