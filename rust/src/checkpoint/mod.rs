//! Crash-safe checkpoint/resume for the trainer (`LMCCKPT1`).
//!
//! A checkpoint directory after epoch `E` of an `S`-shard run holds
//!
//! ```text
//! MANIFEST.json          # renamed into place LAST — the commit point
//! run.eE.ckpt            # epoch counter + metrics trace
//! shard-0.eE.ckpt        # per-trainer state (params, Adam, history, RNG)
//! ...
//! shard-{S-1}.eE.ckpt
//! ```
//!
//! Every file is written atomically (temp file → fsync → rename → dir
//! fsync), so a crash at any instant leaves either the previous complete
//! checkpoint or the new one — never a torn live file. The manifest is
//! written last: until it lands, a resume still sees the previous epoch.
//! Old-epoch files are garbage-collected only after the new manifest is
//! durable.
//!
//! Checkpoints are taken at epoch-sync barriers. Because every stream of
//! randomness is captured (trainer RNG, batcher RNG) and the transient
//! caches rebuild deterministically, a run killed at an arbitrary step
//! and resumed from the last checkpoint replays the remaining epochs
//! **bit-identically** to the uninterrupted run (see
//! `tests/integration_faults.rs`). A config fingerprint stored in the
//! manifest and in every state file refuses resume under an incompatible
//! config.

mod format;

pub use format::{
    decode_run_state, decode_state, encode_run_state, encode_state, RunState, TrainerState,
    CKPT_MAGIC, CKPT_VERSION,
};

use std::collections::BTreeMap;
use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
use crate::util::failpoint::{self, Action};
use crate::util::json::Json;

/// The commit-point file; a directory without it has no checkpoint.
pub const MANIFEST_NAME: &str = "MANIFEST.json";

/// Whether epoch `epoch` (1-based) should be checkpointed under cadence
/// `every` (clamped to ≥ 1). The final epoch is always checkpointed so a
/// finished run can be reloaded regardless of cadence.
pub fn due(epoch: usize, every: usize, total_epochs: usize) -> bool {
    epoch % every.max(1) == 0 || epoch == total_epochs
}

/// Canonical string of every config knob that shapes the training
/// trajectory. Presentation- and cadence-only knobs (eval cadence,
/// artifact/checkpoint dirs, serve settings, `epochs` itself) are
/// deliberately excluded so they may differ across a resume — e.g.
/// resuming with a larger `--epochs` to extend a finished run.
pub fn config_fingerprint(cfg: &RunConfig) -> String {
    let fields: Vec<String> = vec![
        format!("dataset={}", cfg.dataset.name()),
        format!("arch={}", cfg.arch),
        format!("method={}", cfg.method.name()),
        format!("backend={}", cfg.backend.name()),
        format!("seed={}", cfg.seed),
        format!("parts={}", cfg.parts_or_default()),
        format!("cpb={}", cfg.clusters_per_batch),
        format!("lr={}", cfg.lr),
        format!("wd={}", cfg.weight_decay),
        format!("balpha={}", cfg.beta.alpha),
        format!("bscore={}", cfg.beta.score.name()),
        format!("batcher={:?}", cfg.batcher_mode),
        format!("shards={}", cfg.shards.max(1)),
        format!("sync_every={}", cfg.sync_every),
        format!("sync_mode={}", cfg.sync_mode.name()),
        format!("spider={}", cfg.spider_period),
        format!("hist={}", cfg.history_dtype.name()),
        format!("bwd_off={}", cfg.force_bwd_off),
        // compensation override + TOP fit rate shape the trajectory;
        // `comp_beta` is serve-only and deliberately excluded
        format!(
            "comp={}",
            cfg.compensation.map(|k| k.name()).unwrap_or("method")
        ),
        format!("toplr={}", cfg.top_lr),
        // halo subsampling reshapes every mini-batch's blocks
        format!("hsampler={}", cfg.halo_sampler.name()),
        format!("hkeep={}", cfg.halo_keep),
    ];
    format!("v1;{}", fields.join(";"))
}

/// A decoded checkpoint: the epoch it was taken at, one state per shard
/// (index = shard id; serial runs have exactly one), and the run trace.
pub struct Loaded {
    pub epoch: usize,
    pub states: Vec<TrainerState>,
    pub run: RunState,
}

fn shard_file(epoch: usize, shard: usize) -> String {
    format!("shard-{shard}.e{epoch}.ckpt")
}

fn run_file(epoch: usize) -> String {
    format!("run.e{epoch}.ckpt")
}

/// Write `bytes` to `dir/name` atomically: temp file, fsync, rename,
/// best-effort directory fsync. The `ckpt.write` failpoint sits here —
/// its `torn-write` action emulates a crash mid-write (half the bytes in
/// the temp file, no rename), which must leave the previous checkpoint
/// intact and loadable.
fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    let path = dir.join(name);
    match failpoint::check("ckpt.write") {
        None => {}
        Some(Action::TornWrite) => {
            let _ = fs::write(&tmp, &bytes[..bytes.len() / 2]);
            bail!("failpoint ckpt.write: injected torn write of {name} (temp file left truncated)");
        }
        Some(Action::Panic) => panic!("failpoint ckpt.write: injected panic"),
        Some(Action::IoError) => bail!("failpoint ckpt.write: injected io error"),
        Some(Action::Sleep) => {
            eprintln!("failpoint ckpt.write: sleeping (waiting to be killed)");
            std::thread::sleep(std::time::Duration::from_secs(120));
        }
    }
    let mut f = File::create(&tmp).map_err(|e| anyhow!("creating {}: {e}", tmp.display()))?;
    f.write_all(bytes).map_err(|e| anyhow!("writing {}: {e}", tmp.display()))?;
    f.sync_all().map_err(|e| anyhow!("fsyncing {}: {e}", tmp.display()))?;
    drop(f);
    fs::rename(&tmp, &path)
        .map_err(|e| anyhow!("renaming {} into place: {e}", path.display()))?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Take a checkpoint of `states` (one per shard) at `epoch`. Files land
/// in this order: shard states, run state, then — the commit point —
/// the manifest. Only after the manifest is durable are the previous
/// epoch's files garbage-collected.
pub fn save(
    dir: &Path,
    fingerprint: &str,
    epoch: usize,
    states: &[TrainerState],
    run: &RunState,
) -> Result<()> {
    fs::create_dir_all(dir)
        .map_err(|e| anyhow!("creating checkpoint dir {}: {e}", dir.display()))?;
    failpoint::fire("ckpt.save")?;
    for (i, s) in states.iter().enumerate() {
        write_atomic(dir, &shard_file(epoch, i), &format::encode_state(s, fingerprint))?;
    }
    write_atomic(dir, &run_file(epoch), &format::encode_run_state(run, fingerprint))?;
    let mut m = BTreeMap::new();
    m.insert("format".to_string(), Json::Str("LMCCKPT1".to_string()));
    m.insert("version".to_string(), Json::Num(CKPT_VERSION as f64));
    m.insert("epoch".to_string(), Json::Num(epoch as f64));
    m.insert("shards".to_string(), Json::Num(states.len() as f64));
    m.insert("fingerprint".to_string(), Json::Str(fingerprint.to_string()));
    m.insert("run_file".to_string(), Json::Str(run_file(epoch)));
    m.insert(
        "shard_files".to_string(),
        Json::Arr((0..states.len()).map(|i| Json::Str(shard_file(epoch, i))).collect()),
    );
    write_atomic(dir, MANIFEST_NAME, Json::Obj(m).to_string().as_bytes())?;
    gc_old_epochs(dir, epoch);
    Ok(())
}

/// Epoch encoded in a checkpoint file name (`shard-3.e12.ckpt` → 12).
fn file_epoch(name: &str) -> Option<usize> {
    if !(name.starts_with("shard-") || name.starts_with("run.")) {
        return None;
    }
    let stem = name.strip_suffix(".ckpt")?;
    let (_, e) = stem.rsplit_once(".e")?;
    e.parse().ok()
}

/// Best-effort removal of state files from epochs other than `keep`,
/// plus any stale `.tmp` leftovers from an interrupted write. Failures
/// are ignored — stale files are harmless; the manifest names the live
/// set.
fn gc_old_epochs(dir: &Path, keep: usize) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stale_tmp = name.ends_with(".tmp");
        let old_epoch = file_epoch(name).map(|e| e != keep).unwrap_or(false);
        if stale_tmp || old_epoch {
            let _ = fs::remove_file(entry.path());
        }
    }
}

/// Load the checkpoint committed in `dir`'s manifest, refusing a
/// fingerprint mismatch or a shard-count mismatch. Integrity (CRC32) and
/// the fingerprint are re-verified on every state file, not just the
/// manifest.
pub fn load(dir: &Path, fingerprint: &str, expect_shards: usize) -> Result<Loaded> {
    failpoint::fire("ckpt.load")?;
    let mpath = dir.join(MANIFEST_NAME);
    let text = fs::read_to_string(&mpath)
        .map_err(|e| anyhow!("no resumable checkpoint at {}: {e}", dir.display()))?;
    let m = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", mpath.display()))?;
    let fmt = m.get("format").and_then(Json::as_str).unwrap_or("");
    if fmt != "LMCCKPT1" {
        bail!("{}: not an lmc checkpoint manifest (format {fmt:?})", mpath.display());
    }
    let version = m.get("version").and_then(Json::as_usize).unwrap_or(0);
    if version != CKPT_VERSION as usize {
        bail!(
            "{}: unsupported checkpoint version {version} (this build reads {CKPT_VERSION})",
            mpath.display()
        );
    }
    let mfp = m.get("fingerprint").and_then(Json::as_str).unwrap_or("");
    if mfp != fingerprint {
        bail!(
            "checkpoint at {} was written under an incompatible config and cannot be \
             resumed with this one\n  checkpoint: {mfp}\n  current:    {fingerprint}",
            dir.display()
        );
    }
    let epoch = m
        .get("epoch")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("{}: missing epoch", mpath.display()))?;
    let shard_files: Vec<&str> = m
        .get("shard_files")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_str).collect())
        .unwrap_or_default();
    if shard_files.len() != expect_shards {
        bail!(
            "checkpoint at {} holds {} shard state(s) but this run needs {expect_shards} — \
             resume with a matching --shards",
            dir.display(),
            shard_files.len()
        );
    }
    let mut states = Vec::with_capacity(shard_files.len());
    for f in &shard_files {
        let bytes =
            fs::read(dir.join(f)).map_err(|e| anyhow!("reading checkpoint file {f}: {e}"))?;
        states.push(decode_state(&bytes, fingerprint).map_err(|e| anyhow!("{f}: {e}"))?);
    }
    let rf = m
        .get("run_file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{}: missing run_file", mpath.display()))?;
    let bytes = fs::read(dir.join(rf)).map_err(|e| anyhow!("reading checkpoint file {rf}: {e}"))?;
    let run = decode_run_state(&bytes, fingerprint).map_err(|e| anyhow!("{rf}: {e}"))?;
    Ok(Loaded { epoch, states, run })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn due_respects_cadence_and_always_fires_on_the_last_epoch() {
        assert!(due(1, 1, 10));
        assert!(due(2, 1, 10));
        assert!(!due(1, 3, 10));
        assert!(!due(2, 3, 10));
        assert!(due(3, 3, 10));
        assert!(due(10, 3, 10), "final epoch is always checkpointed");
        assert!(due(4, 0, 10), "a zero cadence clamps to every epoch");
    }

    #[test]
    fn fingerprint_tracks_trajectory_knobs_only() {
        let a = config_fingerprint(&RunConfig::default());
        let reseeded = RunConfig { seed: 99, ..Default::default() };
        assert_ne!(a, config_fingerprint(&reseeded), "seed must change the fingerprint");
        let cadence_only = RunConfig {
            epochs: RunConfig::default().epochs + 5,
            eval_every: 1,
            checkpoint_every: 7,
            checkpoint_dir: Some("elsewhere".into()),
            ..Default::default()
        };
        assert_eq!(a, config_fingerprint(&cadence_only), "cadence knobs must not block a resume");
    }

    #[test]
    fn file_epoch_parses_checkpoint_names_only() {
        assert_eq!(file_epoch("shard-0.e12.ckpt"), Some(12));
        assert_eq!(file_epoch("shard-13.e7.ckpt"), Some(7));
        assert_eq!(file_epoch("run.e3.ckpt"), Some(3));
        assert_eq!(file_epoch("MANIFEST.json"), None);
        assert_eq!(file_epoch("shard-0.e12.ckpt.tmp"), None);
        assert_eq!(file_epoch("unrelated.e4.ckpt"), None);
    }
}
