//! `LMCCKPT1` binary encoding: full trainer state (params, Adam moments,
//! history at its at-rest dtype, RNG stream positions, step counter,
//! SPIDER state) and the run-level trace, each as one self-delimiting
//! little-endian blob ending in the shared CRC32 trailer.
//!
//! History stores are persisted as their **raw at-rest words** (f32 bits,
//! or the 16-bit bf16/f16 words) — a checkpointed quantized store
//! round-trips bit-exactly, never through a decode/re-encode hop.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::metrics::{EpochRecord, RunMetrics};
use crate::coordinator::params::Params;
use crate::coordinator::Trainer;
use crate::history::{HistDtype, HistRaw, History};
use crate::runtime::Tensor;
use crate::util::bytes::{
    append_crc_trailer, check_crc_trailer, push_f32_slice, push_f64, push_str, push_u16_slice,
    push_u32, push_u64, Cursor,
};
use crate::util::rng::Rng;

/// File magic of the `lmc` checkpoint format. Version 2 appends the
/// compensation-policy state blob (TOP transforms; empty for the
/// stateless policies) after the SPIDER section.
pub const CKPT_MAGIC: &[u8; 8] = b"LMCCKPT1";
pub const CKPT_VERSION: u32 = 2;

const KIND_SHARD: u8 = 1;
const KIND_RUN: u8 = 2;

/// Everything a [`Trainer`] needs to continue a run bit-identically:
/// params, Adam moments + step counter, the full history store, both RNG
/// stream positions, and SPIDER state. Also the sharded recovery
/// snapshot — workers roll back to a captured state when an epoch fails.
pub struct TrainerState {
    pub params: Params,
    pub adam_m: Vec<Vec<f32>>,
    pub adam_v: Vec<Vec<f32>>,
    pub adam_t: u64,
    pub history: History,
    pub rng: [u64; 4],
    pub batcher_rng: [u64; 4],
    pub step_count: u64,
    pub spider: Option<(Params, Vec<Tensor>)>,
    /// Opaque compensation-policy state (`Compensation::encode_state`):
    /// the learned TOP transforms, or empty for stateless policies.
    pub comp: Vec<u8>,
}

impl TrainerState {
    pub fn capture(t: &Trainer) -> TrainerState {
        let (m, v, at) = t.opt.state();
        TrainerState {
            params: t.params.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
            adam_t: at,
            history: t.history.clone(),
            rng: t.rng.state(),
            batcher_rng: t.batcher.rng_state(),
            step_count: t.step_count(),
            spider: t.spider_state().cloned(),
            comp: t.comp.encode_state(),
        }
    }

    /// Install this state into `t`, which must have been built from the
    /// same config (shapes are re-validated here as a defense in depth —
    /// the fingerprint check on load is the primary gate). Transient
    /// caches are reset; they rebuild deterministically.
    pub fn restore_into(&self, t: &mut Trainer) -> Result<()> {
        if self.params.names != t.params.names {
            bail!(
                "checkpoint param names do not match the model ({} vs {} tensors)",
                self.params.names.len(),
                t.params.names.len()
            );
        }
        for ((name, a), b) in
            self.params.names.iter().zip(&self.params.tensors).zip(&t.params.tensors)
        {
            if a.shape != b.shape {
                bail!("checkpoint tensor {name} shape {:?} != model {:?}", a.shape, b.shape);
            }
        }
        let (h, m) = (&self.history, &t.history);
        if h.n != m.n || h.num_layers() != m.num_layers() || h.dtype() != m.dtype() {
            bail!(
                "checkpoint history (n={}, layers={}, {}) does not match the model \
                 (n={}, layers={}, {})",
                h.n,
                h.num_layers(),
                h.dtype().name(),
                m.n,
                m.num_layers(),
                m.dtype().name()
            );
        }
        for (a, b) in h.h.iter().zip(&m.h) {
            if a.d != b.d {
                bail!("checkpoint history layer width {} != model {}", a.d, b.d);
            }
        }
        t.params = self.params.clone();
        t.opt.restore_state(self.adam_m.clone(), self.adam_v.clone(), self.adam_t)?;
        t.history = self.history.clone();
        t.rng = Rng::from_state(self.rng);
        t.batcher.restore_rng_state(self.batcher_rng);
        t.set_step_count(self.step_count);
        t.set_spider_state(self.spider.clone());
        t.comp.decode_state(&self.comp)?;
        t.reset_transient_state();
        Ok(())
    }
}

/// Run-level progress: the completed-epoch counter the resumed loop
/// continues from, plus the metrics trace so far.
pub struct RunState {
    pub epochs_done: usize,
    pub metrics: RunMetrics,
}

fn dtype_code(d: HistDtype) -> u8 {
    match d {
        HistDtype::F32 => 0,
        HistDtype::Bf16 => 1,
        HistDtype::F16 => 2,
    }
}

fn dtype_from_code(c: u8) -> Result<HistDtype> {
    match c {
        0 => Ok(HistDtype::F32),
        1 => Ok(HistDtype::Bf16),
        2 => Ok(HistDtype::F16),
        other => bail!("unknown history dtype code {other}"),
    }
}

fn push_header(out: &mut Vec<u8>, kind: u8, fingerprint: &str) {
    out.extend_from_slice(CKPT_MAGIC);
    push_u32(out, CKPT_VERSION);
    out.push(kind);
    push_str(out, fingerprint);
}

/// Parse and validate the common header; returns a cursor positioned
/// after it. The fingerprint check is what refuses resume under an
/// incompatible config.
fn open_payload<'a>(
    bytes: &'a [u8],
    kind: u8,
    expect_fingerprint: &str,
    what: &str,
) -> Result<Cursor<'a>> {
    let payload = check_crc_trailer(bytes, what)?;
    let mut cur = Cursor::new(payload);
    if cur.take(CKPT_MAGIC.len())? != CKPT_MAGIC {
        bail!("{what}: not an lmc checkpoint (bad magic)");
    }
    let version = cur.u32()?;
    if version != CKPT_VERSION {
        bail!("{what}: unsupported checkpoint version {version} (this build reads {CKPT_VERSION})");
    }
    let k = cur.take(1)?[0];
    if k != kind {
        bail!("{what}: wrong section kind {k} (expected {kind})");
    }
    let fp = cur.str()?;
    if fp != expect_fingerprint {
        bail!(
            "{what}: checkpoint was written under an incompatible config and cannot be \
             resumed with this one\n  checkpoint: {fp}\n  current:    {expect_fingerprint}"
        );
    }
    Ok(cur)
}

fn push_params(out: &mut Vec<u8>, p: &Params) {
    let b = p.to_bytes();
    push_u32(out, b.len() as u32);
    out.extend_from_slice(&b);
}

fn read_params(cur: &mut Cursor) -> Result<Params> {
    let len = cur.u32()? as usize;
    Params::from_bytes(cur.take(len)?)
}

fn push_history(out: &mut Vec<u8>, h: &History) {
    push_u64(out, h.n as u64);
    out.push(dtype_code(h.dtype()));
    push_u32(out, h.num_layers() as u32);
    for ls in &h.h {
        push_u32(out, ls.d as u32);
    }
    for ls in h.h.iter().chain(h.v.iter()) {
        match ls.raw_words() {
            HistRaw::F32(w) => push_f32_slice(out, w),
            HistRaw::U16(w) => push_u16_slice(out, w),
        }
    }
    for &t in &h.last_update {
        push_u64(out, t);
    }
    push_u64(out, h.iter);
}

fn read_history(cur: &mut Cursor) -> Result<History> {
    let n = cur.u64()? as usize;
    let dtype = dtype_from_code(cur.take(1)?[0])?;
    let layers = cur.u32()? as usize;
    let mut dims = Vec::with_capacity(layers);
    for _ in 0..layers {
        dims.push(cur.u32()? as usize);
    }
    let mut h = History::with_dtype(n, &dims, dtype);
    for li in 0..2 * layers {
        let d = dims[li % layers];
        let ls = if li < layers { &mut h.h[li] } else { &mut h.v[li - layers] };
        let res = match dtype {
            HistDtype::F32 => ls.set_raw_f32(&cur.f32_vec(n * d)?),
            _ => ls.set_raw_u16(&cur.u16_vec(n * d)?),
        };
        res.map_err(|e| anyhow!("history layer {li}: {e}"))?;
    }
    h.last_update = cur.u64_vec(n)?;
    h.iter = cur.u64()?;
    Ok(h)
}

fn push_tensors(out: &mut Vec<u8>, ts: &[Tensor]) {
    push_u32(out, ts.len() as u32);
    for t in ts {
        push_u32(out, t.shape.len() as u32);
        for &d in &t.shape {
            push_u32(out, d as u32);
        }
        push_f32_slice(out, &t.data);
    }
}

fn read_tensors(cur: &mut Cursor) -> Result<Vec<Tensor>> {
    let count = cur.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = cur.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(cur.u32()? as usize);
        }
        let elems = shape.iter().product::<usize>();
        out.push(Tensor::from_vec(&shape, cur.f32_vec(elems)?));
    }
    Ok(out)
}

/// Encode one trainer's state (one shard file's contents).
pub fn encode_state(s: &TrainerState, fingerprint: &str) -> Vec<u8> {
    let mut out = Vec::new();
    push_header(&mut out, KIND_SHARD, fingerprint);
    push_u64(&mut out, s.step_count);
    for &w in s.rng.iter().chain(s.batcher_rng.iter()) {
        push_u64(&mut out, w);
    }
    push_params(&mut out, &s.params);
    push_u32(&mut out, s.adam_m.len() as u32);
    for moments in [&s.adam_m, &s.adam_v] {
        for m in moments.iter() {
            push_u32(&mut out, m.len() as u32);
            push_f32_slice(&mut out, m);
        }
    }
    push_u64(&mut out, s.adam_t);
    push_history(&mut out, &s.history);
    match &s.spider {
        None => out.push(0),
        Some((prev, est)) => {
            out.push(1);
            push_params(&mut out, prev);
            push_tensors(&mut out, est);
        }
    }
    push_u32(&mut out, s.comp.len() as u32);
    out.extend_from_slice(&s.comp);
    append_crc_trailer(&mut out);
    out
}

/// Decode a shard-state blob written by [`encode_state`], refusing a
/// mismatched fingerprint or a failed checksum.
pub fn decode_state(bytes: &[u8], expect_fingerprint: &str) -> Result<TrainerState> {
    let mut cur = open_payload(bytes, KIND_SHARD, expect_fingerprint, "checkpoint state")?;
    let step_count = cur.u64()?;
    let mut rng = [0u64; 4];
    let mut batcher_rng = [0u64; 4];
    for w in rng.iter_mut().chain(batcher_rng.iter_mut()) {
        *w = cur.u64()?;
    }
    let params = read_params(&mut cur)?;
    let n_tensors = cur.u32()? as usize;
    let read_moments = |cur: &mut Cursor| -> Result<Vec<Vec<f32>>> {
        (0..n_tensors)
            .map(|_| {
                let len = cur.u32()? as usize;
                cur.f32_vec(len)
            })
            .collect()
    };
    let adam_m = read_moments(&mut cur)?;
    let adam_v = read_moments(&mut cur)?;
    let adam_t = cur.u64()?;
    let history = read_history(&mut cur)?;
    let spider = match cur.take(1)?[0] {
        0 => None,
        1 => Some((read_params(&mut cur)?, read_tensors(&mut cur)?)),
        other => bail!("bad spider-state flag {other}"),
    };
    let comp_len = cur.u32()? as usize;
    let comp = cur.take(comp_len)?.to_vec();
    if cur.remaining() != 0 {
        bail!("checkpoint state: {} trailing bytes", cur.remaining());
    }
    Ok(TrainerState {
        params,
        adam_m,
        adam_v,
        adam_t,
        history,
        rng,
        batcher_rng,
        step_count,
        spider,
        comp,
    })
}

/// Encode the run-level file (epoch counter + metrics trace).
pub fn encode_run_state(r: &RunState, fingerprint: &str) -> Vec<u8> {
    let mut out = Vec::new();
    push_header(&mut out, KIND_RUN, fingerprint);
    push_u64(&mut out, r.epochs_done as u64);
    push_u32(&mut out, r.metrics.records.len() as u32);
    for rec in &r.metrics.records {
        push_u64(&mut out, rec.epoch as u64);
        push_f64(&mut out, rec.wall_secs);
        push_f64(&mut out, rec.epoch_secs);
        push_f64(&mut out, rec.train_loss);
        push_f64(&mut out, rec.train_acc);
        push_f64(&mut out, rec.val_acc);
        push_f64(&mut out, rec.test_acc);
        push_u64(&mut out, rec.active_bytes as u64);
        push_f64(&mut out, rec.staleness);
    }
    match r.metrics.reached_target {
        None => out.push(0),
        Some((epoch, secs)) => {
            out.push(1);
            push_u64(&mut out, epoch as u64);
            push_f64(&mut out, secs);
        }
    }
    append_crc_trailer(&mut out);
    out
}

/// Decode a run-state blob written by [`encode_run_state`].
pub fn decode_run_state(bytes: &[u8], expect_fingerprint: &str) -> Result<RunState> {
    let mut cur = open_payload(bytes, KIND_RUN, expect_fingerprint, "checkpoint run state")?;
    let epochs_done = cur.u64()? as usize;
    let n_records = cur.u32()? as usize;
    let mut metrics = RunMetrics::default();
    for _ in 0..n_records {
        metrics.push(EpochRecord {
            epoch: cur.u64()? as usize,
            wall_secs: cur.f64()?,
            epoch_secs: cur.f64()?,
            train_loss: cur.f64()?,
            train_acc: cur.f64()?,
            val_acc: cur.f64()?,
            test_acc: cur.f64()?,
            active_bytes: cur.u64()? as usize,
            staleness: cur.f64()?,
        });
    }
    metrics.reached_target = match cur.take(1)?[0] {
        0 => None,
        1 => Some((cur.u64()? as usize, cur.f64()?)),
        other => bail!("bad reached-target flag {other}"),
    };
    if cur.remaining() != 0 {
        bail!("checkpoint run state: {} trailing bytes", cur.remaining());
    }
    Ok(RunState { epochs_done, metrics })
}
