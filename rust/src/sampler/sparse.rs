//! CSR sparse blocks for subgraph adjacency (`{offsets, cols, vals}`).
//!
//! `SubgraphBatch` stores its `A_bb` / `A_bh` / `A_hh` blocks in this format
//! so per-step aggregation cost is O(nnz · d) instead of O(bucket² · d).
//! The PJRT backend densifies on demand via [`CsrBlock::to_dense`], which
//! reproduces the zero-padded row-major layout the AOT programs consume.
//!
//! The SpMM inner loops (`row += w · x[j, :]`) run through the dispatched
//! SIMD `axpy` primitive (`crate::backend::simd`). Because that primitive
//! computes the same per-element operation regardless of vector width,
//! tile boundaries, or slice alignment (single-rounded `fma` in both the
//! lanes and the scalar tail at the SIMD levels), the serial
//! ([`CsrBlock::spmm_acc`]) and blocked/tiled
//! ([`CsrBlock::par_spmm_acc_tiled`]) paths stay **bitwise identical** to
//! each other at any one level (pinned by
//! `tiled_spmm_matches_serial_across_widths`).

use rayon::prelude::*;

use crate::backend::simd::{self, SimdOps};

/// Rows per rayon task in the blocked SpMM paths.
pub(crate) const SPMM_ROW_BLOCK: usize = 32;
/// Feature-dimension tile width: wide `d` is processed in column tiles so
/// the gathered source tile stays cache-resident across a block's rows.
pub(crate) const SPMM_D_TILE: usize = 128;
/// Below this many output elements the serial path is used.
pub(crate) const SPMM_PAR_MIN: usize = 1 << 12;

/// A sparse `n_rows × n_cols` matrix in compressed-sparse-row form.
///
/// `offsets` has `n_rows + 1` entries; row `i`'s nonzeros live at
/// `cols[offsets[i]..offsets[i+1]]` / `vals[..]`, with column indices
/// strictly increasing within a row.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrBlock {
    pub n_rows: usize,
    pub n_cols: usize,
    pub offsets: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl CsrBlock {
    /// All-zero block.
    pub fn empty(n_rows: usize, n_cols: usize) -> CsrBlock {
        CsrBlock { n_rows, n_cols, offsets: vec![0; n_rows + 1], cols: Vec::new(), vals: Vec::new() }
    }

    /// Build from a dense row-major `[n_rows, n_cols]` buffer (tests/benches).
    pub fn from_dense(n_rows: usize, n_cols: usize, dense: &[f32]) -> CsrBlock {
        assert_eq!(dense.len(), n_rows * n_cols);
        let mut b = CsrBuilder::new(n_cols);
        for i in 0..n_rows {
            for j in 0..n_cols {
                let w = dense[i * n_cols + j];
                if w != 0.0 {
                    b.push(j as u32, w);
                }
            }
            b.finish_row();
        }
        b.build()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Row `i`'s (column, value) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        (&self.cols[s..e], &self.vals[s..e])
    }

    /// Densify into a zero-padded row-major `[pad_rows, pad_cols]` buffer —
    /// exactly the layout the padded AOT step programs consume.
    pub fn to_dense(&self, pad_rows: usize, pad_cols: usize) -> Vec<f32> {
        assert!(pad_rows >= self.n_rows && pad_cols >= self.n_cols);
        let mut out = vec![0f32; pad_rows * pad_cols];
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            let row = &mut out[i * pad_cols..(i + 1) * pad_cols];
            for (&j, &w) in cols.iter().zip(vals) {
                row[j as usize] = w;
            }
        }
        out
    }

    /// Transposed block (counting sort; preserves sorted columns).
    pub fn transpose(&self) -> CsrBlock {
        let mut counts = vec![0u32; self.n_cols + 1];
        for &j in &self.cols {
            counts[j as usize + 1] += 1;
        }
        for j in 0..self.n_cols {
            counts[j + 1] += counts[j];
        }
        let offsets = counts.clone();
        let mut cols = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut cursor = counts;
        for i in 0..self.n_rows {
            let (rc, rv) = self.row(i);
            for (&j, &w) in rc.iter().zip(rv) {
                let at = cursor[j as usize] as usize;
                cols[at] = i as u32;
                vals[at] = w;
                cursor[j as usize] += 1;
            }
        }
        CsrBlock { n_rows: self.n_cols, n_cols: self.n_rows, offsets, cols, vals }
    }

    /// `out[i, :] += Σ_j A[i, j] · x[j, :]` for all rows (serial row loop,
    /// dispatched SIMD inner loop).
    /// `x` is row-major `[n_cols, d]`, `out` row-major `[n_rows, d]`.
    pub fn spmm_acc(&self, x: &[f32], d: usize, out: &mut [f32]) {
        debug_assert!(x.len() >= self.n_cols * d);
        debug_assert!(out.len() >= self.n_rows * d);
        let axpy = simd::ops_auto().axpy;
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            let row = &mut out[i * d..(i + 1) * d];
            for (&j, &w) in cols.iter().zip(vals) {
                axpy(row, &x[j as usize * d..(j as usize + 1) * d], w);
            }
        }
    }

    /// `A @ x` with rayon-parallel rows. `x` is row-major `[n_cols, d]`.
    pub fn par_spmm(&self, x: &[f32], d: usize) -> Vec<f32> {
        debug_assert!(x.len() >= self.n_cols * d);
        let mut out = vec![0f32; self.n_rows * d];
        let axpy = simd::ops_auto().axpy;
        out.par_chunks_mut(d).enumerate().for_each(|(i, row)| {
            let (cols, vals) = self.row(i);
            for (&j, &w) in cols.iter().zip(vals) {
                axpy(row, &x[j as usize * d..(j as usize + 1) * d], w);
            }
        });
        out
    }

    /// `A @ x` through the blocked + feature-tiled kernel.
    pub fn par_spmm_tiled(&self, x: &[f32], d: usize) -> Vec<f32> {
        let mut out = vec![0f32; self.n_rows * d];
        self.par_spmm_acc_tiled(x, d, 1.0, &mut out);
        out
    }

    /// `out[i, :] += scale · Σ_j A[i, j] · x[j, :]` — the optimized SpMM:
    /// rayon-parallel over [`SPMM_ROW_BLOCK`]-row blocks, with the feature
    /// dimension processed in [`SPMM_D_TILE`] tiles for wide `d`. Per
    /// output element the accumulation order (columns ascending) matches
    /// [`CsrBlock::spmm_acc`], so results are thread-count independent.
    /// Accumulating into a caller-provided buffer makes this the fused
    /// entry point: the step pre-fills `out` with the bias/residual term
    /// and aggregates straight into the pre-activation buffer.
    pub fn par_spmm_acc_tiled(&self, x: &[f32], d: usize, scale: f32, out: &mut [f32]) {
        self.par_spmm_acc_tiled_with(simd::ops_auto(), x, d, scale, out)
    }

    /// [`CsrBlock::par_spmm_acc_tiled`] with an explicit SIMD ops table —
    /// `benches/step_breakdown.rs` uses this to A/B the scalar and SIMD
    /// aggregation paths inside one process, and the property tests pin
    /// the dispatched level against `SimdLevel::Scalar`.
    pub fn par_spmm_acc_tiled_with(
        &self,
        ops: &SimdOps,
        x: &[f32],
        d: usize,
        scale: f32,
        out: &mut [f32],
    ) {
        debug_assert!(x.len() >= self.n_cols * d);
        debug_assert!(out.len() >= self.n_rows * d);
        if d == 0 || self.n_rows == 0 {
            return;
        }
        let out = &mut out[..self.n_rows * d];
        if self.n_rows * d <= SPMM_PAR_MIN {
            spmm_rows_tiled(ops, self, 0, out, x, d, scale);
            return;
        }
        out.par_chunks_mut(SPMM_ROW_BLOCK * d).enumerate().for_each(|(blk, orows)| {
            spmm_rows_tiled(ops, self, blk * SPMM_ROW_BLOCK, orows, x, d, scale);
        });
    }
}

/// Accumulate `scale · A[r0.., :] @ x` into `orows` (one row block),
/// feature-tiled; per-edge inner loop is the dispatched SIMD `axpy`.
#[allow(clippy::too_many_arguments)]
fn spmm_rows_tiled(
    ops: &SimdOps,
    a: &CsrBlock,
    r0: usize,
    orows: &mut [f32],
    x: &[f32],
    d: usize,
    scale: f32,
) {
    let rows = orows.len() / d;
    let axpy = ops.axpy;
    let mut d0 = 0;
    while d0 < d {
        let d1 = (d0 + SPMM_D_TILE).min(d);
        for rr in 0..rows {
            let (cols, vals) = a.row(r0 + rr);
            let orow = &mut orows[rr * d + d0..rr * d + d1];
            for (&j, &w) in cols.iter().zip(vals) {
                axpy(orow, &x[j as usize * d + d0..j as usize * d + d1], scale * w);
            }
        }
        d0 = d1;
    }
}

/// Incremental row-by-row CSR construction (columns must be pushed in
/// increasing order within each row).
pub struct CsrBuilder {
    n_cols: usize,
    offsets: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f32>,
}

impl CsrBuilder {
    pub fn new(n_cols: usize) -> CsrBuilder {
        CsrBuilder { n_cols, offsets: vec![0], cols: Vec::new(), vals: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, col: u32, val: f32) {
        debug_assert!((col as usize) < self.n_cols);
        debug_assert!(
            self.cols.len() == *self.offsets.last().unwrap() as usize
                || *self.cols.last().unwrap() < col,
            "columns must be strictly increasing within a row"
        );
        self.cols.push(col);
        self.vals.push(val);
    }

    #[inline]
    pub fn finish_row(&mut self) {
        self.offsets.push(self.cols.len() as u32);
    }

    pub fn build(self) -> CsrBlock {
        CsrBlock {
            n_rows: self.offsets.len() - 1,
            n_cols: self.n_cols,
            offsets: self.offsets,
            cols: self.cols,
            vals: self.vals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_block(rng: &mut Rng, n_rows: usize, n_cols: usize, p: f64) -> (CsrBlock, Vec<f32>) {
        let mut dense = vec![0f32; n_rows * n_cols];
        for v in dense.iter_mut() {
            if rng.next_f64() < p {
                *v = rng.normal() as f32;
            }
        }
        (CsrBlock::from_dense(n_rows, n_cols, &dense), dense)
    }

    #[test]
    fn dense_roundtrip_exact() {
        let mut rng = Rng::new(1);
        for &(r, c) in &[(5usize, 7usize), (1, 1), (16, 3), (0, 4)] {
            let (blk, dense) = random_block(&mut rng, r, c, 0.4);
            assert_eq!(blk.to_dense(r, c), dense);
            // padded: original entries in place, padding zero
            let pad = blk.to_dense(r + 3, c + 2);
            for i in 0..r + 3 {
                for j in 0..c + 2 {
                    let want = if i < r && j < c { dense[i * c + j] } else { 0.0 };
                    assert_eq!(pad[i * (c + 2) + j], want);
                }
            }
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Rng::new(2);
        let (blk, dense) = random_block(&mut rng, 9, 6, 0.3);
        let t = blk.transpose();
        assert_eq!(t.n_rows, 6);
        assert_eq!(t.n_cols, 9);
        let td = t.to_dense(6, 9);
        for i in 0..9 {
            for j in 0..6 {
                assert_eq!(td[j * 9 + i], dense[i * 6 + j]);
            }
        }
        // columns sorted in each row
        for i in 0..t.n_rows {
            let (cols, _) = t.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let mut rng = Rng::new(3);
        let (blk, dense) = random_block(&mut rng, 11, 8, 0.35);
        let d = 5;
        let x: Vec<f32> = (0..8 * d).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0f32; 11 * d];
        for i in 0..11 {
            for j in 0..8 {
                let w = dense[i * 8 + j];
                for k in 0..d {
                    want[i * d + k] += w * x[j * d + k];
                }
            }
        }
        let mut got = vec![0f32; 11 * d];
        blk.spmm_acc(&x, d, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        let par = blk.par_spmm(&x, d);
        assert_eq!(par, got);
        let tiled = blk.par_spmm_tiled(&x, d);
        assert_eq!(tiled, got);
    }

    #[test]
    fn tiled_spmm_matches_serial_across_widths() {
        let mut rng = Rng::new(9);
        // d values straddle the tile width, including d = 1 and non-multiples
        for &d in &[1usize, 3, 64, 128, 130, 300] {
            let (blk, _) = random_block(&mut rng, 23, 17, 0.3);
            let x: Vec<f32> = (0..17 * d).map(|_| rng.normal() as f32).collect();
            let mut want = vec![0f32; 23 * d];
            blk.spmm_acc(&x, d, &mut want);
            let got = blk.par_spmm_tiled(&x, d);
            // identical per-element accumulation order => bitwise equal
            assert_eq!(got, want, "d = {d}");
            // scaled accumulate into a pre-filled buffer
            let mut acc = vec![1f32; 23 * d];
            blk.par_spmm_acc_tiled(&x, d, 0.5, &mut acc);
            for (i, (&a, &w)) in acc.iter().zip(&want).enumerate() {
                let expect = 1.0 + 0.5 * w;
                assert!((a - expect).abs() <= 1e-5 * (1.0 + expect.abs()), "d={d} i={i}: {a} vs {expect}");
            }
        }
    }

    #[test]
    fn tiled_spmm_handles_empty_rows_and_blocks() {
        // all-zero block: output untouched
        let blk = CsrBlock::empty(5, 4);
        let x = vec![1f32; 4 * 7];
        let mut out = vec![2f32; 5 * 7];
        blk.par_spmm_acc_tiled(&x, 7, 1.0, &mut out);
        assert!(out.iter().all(|&v| v == 2.0));
        // zero-row block: no panic
        let blk0 = CsrBlock::empty(0, 4);
        let mut empty: Vec<f32> = Vec::new();
        blk0.par_spmm_acc_tiled(&x, 7, 1.0, &mut empty);
    }

    #[test]
    fn builder_roundtrip() {
        let mut b = CsrBuilder::new(4);
        b.push(1, 2.0);
        b.push(3, -1.0);
        b.finish_row();
        b.finish_row(); // empty row
        b.push(0, 0.5);
        b.finish_row();
        let blk = b.build();
        assert_eq!(blk.n_rows, 3);
        assert_eq!(blk.nnz(), 3);
        assert_eq!(blk.to_dense(3, 4), vec![0.0, 2.0, 0.0, -1.0, 0.0, 0.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.0]);
    }
}
