//! Mini-batch scheduling over partition clusters (paper Algorithm 1 line 4
//! and §E.2).
//!
//! Two modes:
//!   - `Stochastic`: each epoch reshuffles clusters and groups `c` of them
//!     per step (CLUSTER-GCN style stochastic subgraph construction) — the
//!     default, matching the paper's main experiments.
//!   - `Fixed`: groups are formed once at preprocessing and reused every
//!     epoch (paper §E.2: avoids per-step sampling cost; LMC's convergence
//!     analysis covers this too).

use std::sync::Arc;

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatcherMode {
    Stochastic,
    Fixed,
}

#[derive(Clone, Debug)]
pub struct Batcher {
    clusters: Vec<Vec<u32>>,
    clusters_per_batch: usize,
    mode: BatcherMode,
    /// Fixed-mode groups behind `Arc` so [`Batcher::epoch_batches`] hands
    /// out shared references instead of deep-cloning every node list each
    /// epoch — steady-state Fixed epochs allocate only the outer Vec.
    fixed_groups: Vec<Arc<[u32]>>,
    rng: Rng,
}

impl Batcher {
    pub fn new(
        clusters: Vec<Vec<u32>>,
        clusters_per_batch: usize,
        mode: BatcherMode,
        seed: u64,
    ) -> Batcher {
        let mut rng = Rng::new(seed);
        let c = clusters_per_batch.max(1).min(clusters.len().max(1));
        let fixed_groups = if mode == BatcherMode::Fixed {
            group_once(&clusters, c, &mut rng)
        } else {
            Vec::new()
        };
        Batcher { clusters, clusters_per_batch: c, mode, fixed_groups, rng }
    }

    pub fn num_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// The scheduling mode. `Fixed` emits identical groups every epoch,
    /// which is what makes the trainer's [`crate::sampler::SubgraphCache`]
    /// applicable; `Stochastic` reshuffles and must rebuild per step.
    pub fn mode(&self) -> BatcherMode {
        self.mode
    }

    pub fn steps_per_epoch(&self) -> usize {
        match self.mode {
            BatcherMode::Fixed => self.fixed_groups.len(),
            BatcherMode::Stochastic => {
                let b = self.clusters.len();
                b.div_ceil(self.clusters_per_batch)
            }
        }
    }

    /// Normalization factor b/c of Eqs. (14)-(15): #parts / #parts-per-batch.
    ///
    /// This is the *constant* factor — exact for every step except a ragged
    /// last stochastic batch; the training loop uses
    /// [`Batcher::grad_scale_at`], which corrects that step.
    pub fn grad_scale(&self) -> f32 {
        self.clusters.len() as f32 / self.clusters_per_batch as f32
    }

    /// The Eq. 14-15 factor for step `step` of the current epoch:
    /// b/|clusters in that step's chunk|. In `Stochastic` mode the shuffled
    /// cluster list is chunked by `c`, so every chunk holds `c` clusters
    /// except a ragged last one with `b mod c` — scaling *it* by the
    /// constant b/c over-weights its gradient (each cluster must contribute
    /// with weight b/|chunk| for the epoch-summed estimator to be
    /// unbiased, Theorem 1). `Fixed` mode keeps the constant factor:
    /// its groups were built once at preprocessing, and changing their
    /// scaling would break bit-identical reproduction of existing runs.
    pub fn grad_scale_at(&self, step: usize) -> f32 {
        match self.mode {
            BatcherMode::Fixed => self.grad_scale(),
            BatcherMode::Stochastic => {
                let b = self.clusters.len();
                let c = self.clusters_per_batch;
                let chunk = c.min(b.saturating_sub(step * c)).max(1);
                b as f32 / chunk as f32
            }
        }
    }

    /// Raw RNG stream position — checkpointed so a resumed run replays
    /// the exact epoch shuffles the uninterrupted run would have drawn.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore a stream position saved by [`Batcher::rng_state`].
    pub fn restore_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Mini-batches (node-id lists) for one epoch. `Fixed` mode returns
    /// shared handles to the preprocessing-time groups (no per-epoch node
    /// copies — `fixed_groups_are_shared_not_recopied`); `Stochastic` mode
    /// assembles fresh groups from a reshuffle.
    pub fn epoch_batches(&mut self) -> Vec<Arc<[u32]>> {
        match self.mode {
            BatcherMode::Fixed => self.fixed_groups.clone(),
            BatcherMode::Stochastic => {
                let mut order: Vec<usize> = (0..self.clusters.len()).collect();
                self.rng.shuffle(&mut order);
                order
                    .chunks(self.clusters_per_batch)
                    .map(|ids| {
                        let mut nodes = Vec::new();
                        for &ci in ids {
                            nodes.extend_from_slice(&self.clusters[ci]);
                        }
                        nodes.sort_unstable();
                        Arc::from(nodes)
                    })
                    .collect()
            }
        }
    }
}

fn group_once(clusters: &[Vec<u32>], c: usize, rng: &mut Rng) -> Vec<Arc<[u32]>> {
    let mut order: Vec<usize> = (0..clusters.len()).collect();
    rng.shuffle(&mut order);
    order
        .chunks(c)
        .map(|ids| {
            let mut nodes = Vec::new();
            for &ci in ids {
                nodes.extend_from_slice(&clusters[ci]);
            }
            nodes.sort_unstable();
            Arc::from(nodes)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clusters(n: usize, k: usize) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); k];
        for u in 0..n as u32 {
            out[u as usize % k].push(u);
        }
        out
    }

    #[test]
    fn stochastic_epoch_covers_every_node_once() {
        let mut b = Batcher::new(clusters(100, 10), 3, BatcherMode::Stochastic, 7);
        assert_eq!(b.steps_per_epoch(), 4);
        let mut seen: Vec<u32> =
            b.epoch_batches().iter().flat_map(|g| g.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn stochastic_epochs_differ() {
        let mut b = Batcher::new(clusters(100, 10), 2, BatcherMode::Stochastic, 7);
        let e1 = b.epoch_batches();
        let e2 = b.epoch_batches();
        assert_ne!(e1, e2);
    }

    #[test]
    fn fixed_epochs_identical() {
        let mut b = Batcher::new(clusters(90, 9), 2, BatcherMode::Fixed, 7);
        let e1 = b.epoch_batches();
        let e2 = b.epoch_batches();
        assert_eq!(e1, e2);
        let mut seen: Vec<u32> = e1.iter().flat_map(|g| g.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..90u32).collect::<Vec<_>>());
    }

    #[test]
    fn fixed_groups_are_shared_not_recopied() {
        // The allocation-stability pin: Fixed epochs hand out Arc clones of
        // the same preprocessing-time groups, never fresh node-list copies.
        let mut b = Batcher::new(clusters(90, 9), 2, BatcherMode::Fixed, 7);
        let e1 = b.epoch_batches();
        let e2 = b.epoch_batches();
        assert_eq!(e1.len(), e2.len());
        for (a, c) in e1.iter().zip(&e2) {
            assert!(Arc::ptr_eq(a, c), "fixed groups must share one allocation");
        }
        // and a third epoch still points at the same buffers
        for (a, c) in e1.iter().zip(&b.epoch_batches()) {
            assert!(Arc::ptr_eq(a, c));
        }
    }

    #[test]
    fn grad_scale_is_b_over_c() {
        let b = Batcher::new(clusters(100, 20), 5, BatcherMode::Stochastic, 0);
        assert!((b.grad_scale() - 4.0).abs() < 1e-6);
    }

    #[test]
    fn grad_scale_at_corrects_ragged_last_stochastic_chunk() {
        // 7 clusters, 3 per batch -> chunks of 3, 3, 1
        let b = Batcher::new(clusters(70, 7), 3, BatcherMode::Stochastic, 0);
        assert_eq!(b.steps_per_epoch(), 3);
        assert!((b.grad_scale_at(0) - 7.0 / 3.0).abs() < 1e-6);
        assert!((b.grad_scale_at(1) - 7.0 / 3.0).abs() < 1e-6);
        assert!((b.grad_scale_at(2) - 7.0).abs() < 1e-6, "ragged chunk holds 1 cluster");
        // evenly divisible: every step matches the constant factor
        let e = Batcher::new(clusters(60, 6), 3, BatcherMode::Stochastic, 0);
        for i in 0..e.steps_per_epoch() {
            assert_eq!(e.grad_scale_at(i), e.grad_scale());
        }
        // Fixed mode intentionally keeps the constant factor on every step
        let f = Batcher::new(clusters(70, 7), 3, BatcherMode::Fixed, 0);
        for i in 0..f.steps_per_epoch() {
            assert_eq!(f.grad_scale_at(i), f.grad_scale());
        }
    }
}
