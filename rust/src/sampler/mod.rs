//! Subgraph sampler: mini-batch construction with 1-hop halos, stored as
//! CSR sparse adjacency blocks (DESIGN.md §1 step 2-3, paper Algorithm 1
//! lines 4-5). The native backend aggregates straight over the sparse
//! blocks (O(nnz · d) per step); the PJRT backend densifies them on demand
//! into the zero-padded bucket layout via [`SubgraphBatch::to_dense`].
//!
//! Per method:
//!   - LMC / GAS / FM: blocks over `Nbar(V_B)` with *global* GCN
//!     normalization; `A_hh` holds only halo-halo edges visible inside
//!     `N(V_B)` — the paper's "incomplete" messages (Eq. 10).
//!   - CLUSTER: no halo; `A_bb` re-normalized with subgraph-local degrees
//!     (paper §E.2 footnote).

pub mod batcher;
pub mod halo;
pub mod sparse;

use std::sync::Arc;

use rayon::prelude::*;

use crate::graph::{Csr, Graph};
use crate::util::rng::Rng;

pub use batcher::{Batcher, BatcherMode};
pub use halo::{HaloSampler, HaloSamplerKind};
pub use sparse::{CsrBlock, CsrBuilder};

/// Below this many gathered elements `gather_rows` stays serial.
const GATHER_PAR_MIN: usize = 1 << 14;

/// Shape buckets available for a profile.
///
/// A non-empty list comes from the artifact manifest (PJRT backend: every
/// subgraph must be padded to a compiled shape). The empty list means
/// *unbounded exact fit* — the native backend has no compiled shapes, so
/// `pick` returns the subgraph's own dimensions and nothing is ever padded
/// or dropped.
#[derive(Clone, Debug)]
pub struct Buckets(pub Vec<(usize, usize)>);

impl Buckets {
    /// Exact-fit buckets for backends without compiled shapes.
    pub fn unbounded() -> Buckets {
        Buckets(Vec::new())
    }

    /// True when nothing is ever padded or dropped (exact-fit mode) —
    /// subgraph construction is then deterministic given the batch, which
    /// is what makes [`SubgraphCache`] sound.
    pub fn is_unbounded(&self) -> bool {
        self.0.is_empty()
    }

    /// Smallest bucket with B >= nb; among those, the one whose H fits nh if
    /// possible, else the largest-H bucket at that B (halo then capped).
    /// Unbounded buckets fit exactly.
    pub fn pick(&self, nb: usize, nh: usize) -> Option<(usize, usize)> {
        if self.0.is_empty() {
            return Some((nb, nh));
        }
        let mut fitting: Vec<(usize, usize)> = self
            .0
            .iter()
            .copied()
            .filter(|&(b, _)| b >= nb)
            .collect();
        if fitting.is_empty() {
            return None;
        }
        let min_b = fitting.iter().map(|&(b, _)| b).min().unwrap();
        fitting.retain(|&(b, _)| b == min_b);
        fitting.sort_by_key(|&(_, h)| h);
        if let Some(&(b, h)) = fitting.iter().find(|&&(_, h)| h >= nh) {
            return Some((b, h));
        }
        fitting.last().copied()
    }
}

/// How the sampler should build adjacency blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjacencyPolicy {
    /// Global normalization + halo blocks (LMC / GAS / FM).
    GlobalWithHalo,
    /// Local re-normalization, halo discarded (CLUSTER-GCN).
    LocalNoHalo,
}

/// A sampled mini-batch subgraph with CSR adjacency blocks.
///
/// `bucket_b` / `bucket_h` are the padded shapes the PJRT step programs
/// expect (`batch.len() <= bucket_b`); with unbounded buckets they equal
/// the actual `batch.len()` / `halo.len()`.
#[derive(Clone, Debug)]
pub struct SubgraphBatch {
    /// In-batch node ids (unpadded; `batch.len() <= bucket_b`).
    pub batch: Vec<u32>,
    /// Halo node ids (out-of-batch 1-hop neighbors, possibly capped).
    pub halo: Vec<u32>,
    pub bucket_b: usize,
    pub bucket_h: usize,
    /// Sparse adjacency blocks over local indices: `a_bb` is
    /// `batch × batch` (self-loops on the diagonal), `a_bh` is
    /// `batch × halo`, `a_hh` is `halo × halo`.
    pub a_bb: CsrBlock,
    pub a_bh: CsrBlock,
    pub a_hh: CsrBlock,
    /// `a_bh.transpose()`, built once at construction: the halo→batch block
    /// the symmetric stacked operator needs every aggregation. Caching it
    /// here removes an O(nnz) counting sort from each step's hot path.
    pub a_hb: CsrBlock,
    /// Halo neighbors dropped by the bucket cap (0 in normal operation).
    pub dropped_halo: usize,
    /// Horvitz–Thompson rescale factors `1/p_i` per kept halo node when a
    /// subsampling [`HaloSampler`] built this batch; empty means all-ones
    /// (the full halo survived, no rescale was applied). The factors are
    /// already baked into the `a_bh`/`a_hh`/`a_hb` weights — this vector is
    /// diagnostic (tests, experiments).
    pub halo_inv_p: Vec<f32>,
    /// Degree of each halo node inside the sampled subgraph (for beta
    /// scores, paper §A.4) and in the full graph.
    pub halo_deg_local: Vec<u32>,
    pub halo_deg_global: Vec<u32>,
    /// Count of directed messages (adjacency nonzeros incl. self-loops)
    /// reserved by this subgraph in forward passes (Table 7 accounting).
    pub nnz_fwd: usize,
}

impl SubgraphBatch {
    /// Total adjacency nonzeros stored across the three blocks.
    pub fn nnz(&self) -> usize {
        self.a_bb.nnz() + self.a_bh.nnz() + self.a_hh.nnz()
    }

    /// Densify the blocks into the zero-padded row-major bucket layout the
    /// AOT train_step programs consume: `([bucket_b, bucket_b],
    /// [bucket_b, bucket_h], [bucket_h, bucket_h])`.
    pub fn to_dense(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            self.a_bb.to_dense(self.bucket_b, self.bucket_b),
            self.a_bh.to_dense(self.bucket_b, self.bucket_h),
            self.a_hh.to_dense(self.bucket_h, self.bucket_h),
        )
    }
}

/// Build the sparse subgraph blocks for `batch` under `policy`.
///
/// `batch` must be sorted ascending (the batcher and the exact tiler both
/// emit sorted node lists); this keeps every CSR row's columns sorted.
///
/// `sampler` selects the halo subsampling policy (see [`halo`]): a
/// subsampling policy keeps each halo node with a known inclusion
/// probability `p_i` and rescales that node's outgoing edge weights (its
/// `A_bh`/`A_hh` columns) by `1/p_i`, so the *expected* aggregation into
/// every surviving row equals the full-halo one. [`HaloSampler::none`] is
/// bit-identical to the pre-policy behaviour, including the legacy
/// unrescaled bucket cap and its RNG consumption.
pub fn build_subgraph(
    g: &Graph,
    batch: &[u32],
    policy: AdjacencyPolicy,
    buckets: &Buckets,
    sampler: &HaloSampler,
    rng: &mut Rng,
) -> anyhow::Result<SubgraphBatch> {
    debug_assert!(batch.windows(2).all(|w| w[0] < w[1]), "batch must be sorted");
    let n = g.n();
    let nb = batch.len();
    // membership: 0 = outside, 1 = batch, 2 = halo
    let mut mark = vec![0u8; n];
    for &u in batch {
        mark[u as usize] = 1;
    }
    let mut halo: Vec<u32> = Vec::new();
    if policy == AdjacencyPolicy::GlobalWithHalo {
        for &u in batch {
            for &v in g.csr.neighbors(u as usize) {
                if mark[v as usize] == 0 {
                    mark[v as usize] = 2;
                    halo.push(v);
                }
            }
        }
        halo.sort_unstable();
    }

    // Policy-driven halo subsampling (stage 1): explicit inclusion
    // probabilities, Horvitz–Thompson rescale carried in `halo_inv_p`.
    let mut dropped = 0usize;
    let mut halo_inv_p: Vec<f32> = Vec::new();
    if sampler.is_subsampling() && !halo.is_empty() {
        let (kept, inv_p, d) = sampler.subsample(g, &mark, &halo, rng);
        for &h in &halo {
            mark[h as usize] = 0;
        }
        for &h in &kept {
            mark[h as usize] = 2;
        }
        halo = kept;
        halo_inv_p = inv_p;
        dropped = d;
    }

    let (bucket_b, bucket_h) = buckets.pick(nb, halo.len()).ok_or_else(|| {
        anyhow::anyhow!(
            "no artifact bucket fits batch of {nb} nodes (buckets: {:?}); \
             re-run `make artifacts` with a larger step bucket",
            buckets.0
        )
    })?;
    if halo.len() > bucket_h {
        // Bucket overflow (stage 2): uniform subsample down to the compiled
        // shape. Under `HaloSampler::none` this is the legacy GAS-style
        // buffer cap — dropped nodes' messages fall back to being
        // discarded, like CLUSTER, with no rescale (the historical bias
        // this PR's policies fix). When a policy already assigned
        // probabilities, the second uniform stage multiplies them by
        // bucket_h/n1, so the combined `1/p` stays conditionally unbiased.
        let n1 = halo.len();
        dropped += n1 - bucket_h;
        let second_stage_inv = n1 as f32 / bucket_h as f32;
        let keep = rng.sample_indices(n1, bucket_h);
        let mut kept: Vec<(u32, f32)> = keep
            .iter()
            .map(|&i| {
                let ip = if halo_inv_p.is_empty() {
                    1.0
                } else {
                    halo_inv_p[i] * second_stage_inv
                };
                (halo[i], ip)
            })
            .collect();
        kept.sort_unstable_by_key(|&(u, _)| u);
        for &h in &halo {
            mark[h as usize] = 0;
        }
        for &(h, _) in &kept {
            mark[h as usize] = 2;
        }
        if !halo_inv_p.is_empty() {
            halo_inv_p = kept.iter().map(|&(_, ip)| ip).collect();
        }
        halo = kept.into_iter().map(|(u, _)| u).collect();
    }

    // position maps
    let mut pos = vec![u32::MAX; n];
    for (i, &u) in batch.iter().enumerate() {
        pos[u as usize] = i as u32;
    }
    for (i, &u) in halo.iter().enumerate() {
        pos[u as usize] = i as u32;
    }

    let nh = halo.len();
    let mut nnz = 0usize;
    let (a_bb, a_bh, a_hh) = match policy {
        AdjacencyPolicy::LocalNoHalo => {
            let blk = local_normalized_csr(&g.csr, batch, &pos, &mark);
            nnz += blk.nnz();
            (blk, CsrBlock::empty(nb, 0), CsrBlock::empty(0, 0))
        }
        AdjacencyPolicy::GlobalWithHalo => {
            // Horvitz–Thompson rescale for edges whose message *source* is a
            // subsampled halo node: the source's `A_bh`/`A_hh` column scales
            // by 1/p. Self-loops are never scaled (the node's own state is
            // not subsampled). `a_hb`, built below as the transpose of the
            // scaled `a_bh`, inherits the factors, so the symmetric stacked
            // operator the backend applies forward *and* backward sees one
            // consistently rescaled coupling. `A_hh` becomes asymmetric
            // under subsampling — each direction carries its own source's
            // factor — which keeps every row's expected aggregation equal
            // to the full-halo one.
            let hscale = |j: u32| -> f32 {
                if halo_inv_p.is_empty() {
                    1.0
                } else {
                    halo_inv_p[j as usize]
                }
            };
            let mut bb = CsrBuilder::new(nb);
            let mut bh = CsrBuilder::new(nh);
            for (i, &u) in batch.iter().enumerate() {
                let u = u as usize;
                // batch is sorted and neighbor lists are sorted, so local
                // columns arrive in increasing order; the self-loop at the
                // diagonal is merged in at its sorted position.
                let mut diag_emitted = false;
                for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
                    let v = g.csr.neighbors[ei] as usize;
                    let w = g.edge_w[ei];
                    match mark[v] {
                        1 => {
                            let j = pos[v];
                            if !diag_emitted && j > i as u32 {
                                bb.push(i as u32, g.self_w[u]);
                                diag_emitted = true;
                            }
                            bb.push(j, w);
                            nnz += 1;
                        }
                        2 => {
                            let j = pos[v];
                            bh.push(j, w * hscale(j));
                            nnz += 1;
                        }
                        _ => {}
                    }
                }
                if !diag_emitted {
                    bb.push(i as u32, g.self_w[u]);
                }
                nnz += 1; // self-loop
                bb.finish_row();
                bh.finish_row();
            }
            let mut hh = CsrBuilder::new(nh);
            for (i, &u) in halo.iter().enumerate() {
                let u = u as usize;
                let mut diag_emitted = false;
                for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
                    let v = g.csr.neighbors[ei] as usize;
                    if mark[v] == 2 {
                        let j = pos[v];
                        if !diag_emitted && j > i as u32 {
                            hh.push(i as u32, g.self_w[u]);
                            diag_emitted = true;
                        }
                        hh.push(j, g.edge_w[ei] * hscale(j));
                        nnz += 1;
                    }
                    // halo -> batch arcs are A_bh^T; the step transposes, so
                    // count them (they are used) but don't store twice.
                    if mark[v] == 1 {
                        nnz += 1;
                    }
                }
                if !diag_emitted {
                    hh.push(i as u32, g.self_w[u]);
                }
                nnz += 1; // self-loop
                hh.finish_row();
            }
            (bb.build(), bh.build(), hh.build())
        }
    };

    // halo degree stats for beta scores
    let mut halo_deg_local = vec![0u32; nh];
    let mut halo_deg_global = vec![0u32; nh];
    for (i, &u) in halo.iter().enumerate() {
        let u = u as usize;
        halo_deg_global[i] = g.csr.degree(u) as u32;
        let mut dl = 0u32;
        for &v in g.csr.neighbors(u) {
            if mark[v as usize] != 0 {
                dl += 1;
            }
        }
        halo_deg_local[i] = dl;
    }

    let a_hb = a_bh.transpose();
    Ok(SubgraphBatch {
        batch: batch.to_vec(),
        halo,
        bucket_b,
        bucket_h,
        a_bb,
        a_bh,
        a_hh,
        a_hb,
        dropped_halo: dropped,
        halo_inv_p,
        halo_deg_local,
        halo_deg_global,
        nnz_fwd: nnz,
    })
}

/// CLUSTER-GCN local re-normalization (paper §E.2) straight into CSR:
/// degrees counted inside the induced subgraph only, self-loops on the
/// diagonal. `pos`/`mark` are the sampler's position/membership maps.
fn local_normalized_csr(csr: &Csr, batch: &[u32], pos: &[u32], mark: &[u8]) -> CsrBlock {
    let nb = batch.len();
    let mut deg = vec![1f32; nb]; // +1 self-loop
    for (i, &u) in batch.iter().enumerate() {
        for &v in csr.neighbors(u as usize) {
            if mark[v as usize] == 1 {
                deg[i] += 1.0;
            }
        }
    }
    let inv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
    let mut b = CsrBuilder::new(nb);
    for (i, &u) in batch.iter().enumerate() {
        let mut diag_emitted = false;
        for &v in csr.neighbors(u as usize) {
            if mark[v as usize] == 1 {
                let j = pos[v as usize];
                if !diag_emitted && j > i as u32 {
                    b.push(i as u32, inv[i] * inv[i]);
                    diag_emitted = true;
                }
                b.push(j, inv[i] * inv[j as usize]);
            }
        }
        if !diag_emitted {
            b.push(i as u32, inv[i] * inv[i]);
        }
        b.finish_row();
    }
    b.build()
}

/// Beta score functions from the paper's Appendix A.4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BetaScore {
    XSquared,
    TwoXMinusXSquared,
    X,
    One,
    SinX,
}

impl BetaScore {
    pub fn parse(s: &str) -> Option<BetaScore> {
        Some(match s {
            "x2" | "x^2" => BetaScore::XSquared,
            "2x-x2" | "2x-x^2" => BetaScore::TwoXMinusXSquared,
            "x" => BetaScore::X,
            "1" | "one" => BetaScore::One,
            "sinx" | "sin" => BetaScore::SinX,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BetaScore::XSquared => "x^2",
            BetaScore::TwoXMinusXSquared => "2x-x^2",
            BetaScore::X => "x",
            BetaScore::One => "1",
            BetaScore::SinX => "sin(x)",
        }
    }

    #[inline]
    pub fn eval(&self, x: f32) -> f32 {
        match self {
            BetaScore::XSquared => x * x,
            BetaScore::TwoXMinusXSquared => 2.0 * x - x * x,
            BetaScore::X => x,
            BetaScore::One => 1.0,
            BetaScore::SinX => x.sin(),
        }
    }
}

/// beta_i = alpha * score(deg_local(i) / deg_global(i)), padded to bucket_h.
pub fn beta_vector(sb: &SubgraphBatch, alpha: f32, score: BetaScore) -> Vec<f32> {
    let mut beta = vec![0f32; sb.bucket_h];
    beta_vector_into(sb, alpha, score, &mut beta);
    beta
}

/// [`beta_vector`] into a caller-provided buffer of at least `bucket_h`
/// entries. The padding tail `out[halo.len()..bucket_h]` is zeroed here —
/// callers may hand in a dirty (reused) buffer.
pub fn beta_vector_into(sb: &SubgraphBatch, alpha: f32, score: BetaScore, out: &mut [f32]) {
    debug_assert!(out.len() >= sb.bucket_h);
    for i in 0..sb.halo.len() {
        let x = if sb.halo_deg_global[i] > 0 {
            sb.halo_deg_local[i] as f32 / sb.halo_deg_global[i] as f32
        } else {
            0.0
        };
        out[i] = (alpha * score.eval(x)).clamp(0.0, 1.0);
    }
    out[sb.halo.len()..sb.bucket_h].fill(0.0);
}

/// Gather rows of a [n, d] row-major array into a zero-padded [rows, d] buffer.
pub fn gather_rows(src: &[f32], d: usize, idx: &[u32], rows: usize) -> Vec<f32> {
    debug_assert!(idx.len() <= rows);
    let mut out = vec![0f32; rows * d];
    gather_rows_into(src, d, idx, &mut out);
    out
}

/// [`gather_rows`] into a caller-provided buffer, rayon-parallel for large
/// gathers (it sits on the per-step critical path between sampler and
/// GEMM). Rows past `idx.len()` are left untouched — callers provide a
/// zeroed buffer when they need padding.
pub fn gather_rows_into(src: &[f32], d: usize, idx: &[u32], out: &mut [f32]) {
    debug_assert!(out.len() >= idx.len() * d);
    if d == 0 || idx.is_empty() {
        return;
    }
    let used = &mut out[..idx.len() * d];
    if used.len() >= GATHER_PAR_MIN {
        used.par_chunks_mut(d).zip(idx.par_iter()).for_each(|(row, &u)| {
            row.copy_from_slice(&src[u as usize * d..(u as usize + 1) * d]);
        });
    } else {
        for (row, &u) in used.chunks_mut(d).zip(idx) {
            row.copy_from_slice(&src[u as usize * d..(u as usize + 1) * d]);
        }
    }
}

/// Reusable subgraph blocks for deterministic batch schedules.
///
/// Applicability (checked by the trainer at construction):
///
/// | batcher mode | buckets       | halo sampler | cached? |
/// |--------------|---------------|--------------|---------|
/// | `Fixed`      | unbounded     | passthrough  | yes — identical groups every epoch and no halo subsampling, so blocks are bit-identical across epochs |
/// | `Fixed`      | unbounded     | subsampling  | no — the policy redraws the halo subset every build |
/// | `Fixed`      | capped        | any          | no — a bucket cap subsamples the halo through the per-batch RNG stream |
/// | `Stochastic` | any           | any          | no — groups reshuffle every epoch |
///
/// Entries are keyed by step index within the epoch and validated against
/// the batch node list on every hit, so a schedule change falls back to a
/// rebuild instead of serving stale blocks.
#[derive(Clone, Debug, Default)]
pub struct SubgraphCache {
    enabled: bool,
    entries: Vec<Option<Arc<SubgraphBatch>>>,
    complete: bool,
}

impl SubgraphCache {
    pub fn new(enabled: bool) -> SubgraphCache {
        SubgraphCache { enabled, entries: Vec::new(), complete: false }
    }

    pub fn disabled() -> SubgraphCache {
        SubgraphCache::new(false)
    }

    /// The trainer-side applicability gate for the table above: caching is
    /// sound only when the schedule is deterministic — `Fixed` groups,
    /// unbounded (exact-fit) buckets, and a passthrough halo sampler (a
    /// subsampling policy redraws the halo every build) — and the config
    /// has not disabled it. Every other combination must fall back to
    /// per-step rebuilds.
    pub fn applicable(
        cfg_flag: bool,
        mode: BatcherMode,
        buckets: &Buckets,
        sampler: &HaloSampler,
    ) -> bool {
        cfg_flag
            && mode == BatcherMode::Fixed
            && buckets.is_unbounded()
            && !sampler.is_subsampling()
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Cached entries so far.
    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once a full epoch of `n` groups is cached — the steady state in
    /// which epochs skip subgraph construction (and the prefetch thread)
    /// entirely.
    pub fn is_complete(&self, n: usize) -> bool {
        self.enabled && self.complete && self.entries.len() == n
    }

    /// The cached blocks for step `i`, if they exist and match `batch`.
    pub fn get(&self, i: usize, batch: &[u32]) -> Option<Arc<SubgraphBatch>> {
        if !self.enabled {
            return None;
        }
        let e = self.entries.get(i)?.as_ref()?;
        if e.batch.as_slice() != batch {
            return None;
        }
        Some(e.clone())
    }

    pub fn insert(&mut self, i: usize, sb: Arc<SubgraphBatch>) {
        if !self.enabled {
            return;
        }
        if self.entries.len() <= i {
            self.entries.resize(i + 1, None);
        }
        self.entries[i] = Some(sb);
    }

    /// Mark the cache complete after an epoch of `n` groups if every slot
    /// was filled.
    pub fn seal(&mut self, n: usize) {
        if self.enabled && self.entries.len() == n && self.entries.iter().all(|e| e.is_some()) {
            self.complete = true;
        }
    }

    /// Host bytes retained by the cached blocks (CSR arrays + node/degree
    /// vectors). The cache trades this host memory — roughly one extra
    /// copy of the partitioned adjacency across all groups — for skipping
    /// per-step subgraph construction; it is host-side and, like the
    /// history store, does not count against the simulated accelerator
    /// memory in `coordinator::memory`.
    pub fn bytes(&self) -> usize {
        self.entries
            .iter()
            .flatten()
            .map(|sb| {
                let blocks = [&sb.a_bb, &sb.a_bh, &sb.a_hh, &sb.a_hb];
                let csr: usize = blocks
                    .iter()
                    .map(|b| b.offsets.len() * 4 + b.nnz() * 8)
                    .sum();
                csr + (sb.batch.len() + sb.halo.len() * 3 + sb.halo_inv_p.len()) * 4
            })
            .sum()
    }

    /// Drop all entries (e.g. after a schedule change).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.complete = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{load, local_normalized_dense, DatasetId};

    fn test_graph() -> Graph {
        load(DatasetId::CoraSim, 3)
    }

    fn buckets() -> Buckets {
        Buckets(vec![(128, 512), (256, 768)])
    }

    #[test]
    fn halo_is_exactly_one_hop() {
        let g = test_graph();
        let mut rng = Rng::new(0);
        let batch: Vec<u32> = (0..100u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        let batch_set: std::collections::HashSet<u32> = batch.iter().copied().collect();
        // every halo node neighbors the batch and is not in it
        for &h in &sb.halo {
            assert!(!batch_set.contains(&h));
            assert!(g.csr.neighbors(h as usize).iter().any(|v| batch_set.contains(v)));
        }
        // every out-of-batch neighbor is in the halo (nothing dropped here)
        assert_eq!(sb.dropped_halo, 0);
        let halo_set: std::collections::HashSet<u32> = sb.halo.iter().copied().collect();
        for &u in &batch {
            for &v in g.csr.neighbors(u as usize) {
                assert!(batch_set.contains(&v) || halo_set.contains(&v));
            }
        }
    }

    #[test]
    fn blocks_match_graph_weights() {
        let g = test_graph();
        let mut rng = Rng::new(1);
        let batch: Vec<u32> = (40..160u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        let (bb, bh) = (sb.bucket_b, sb.bucket_h);
        let (a_bb, a_bh, a_hh) = sb.to_dense();
        for (i, &u) in sb.batch.iter().enumerate() {
            // diagonal self weight
            assert_eq!(a_bb[i * bb + i], g.self_w[u as usize]);
            for (j, &v) in sb.batch.iter().enumerate() {
                if i != j {
                    let w = a_bb[i * bb + j];
                    assert_eq!(w != 0.0, g.csr.has_edge(u as usize, v as usize));
                }
            }
            for (j, &v) in sb.halo.iter().enumerate() {
                let w = a_bh[i * bh + j];
                assert_eq!(w != 0.0, g.csr.has_edge(u as usize, v as usize));
            }
        }
        // A_hh symmetric where defined
        for i in 0..sb.halo.len() {
            for j in 0..sb.halo.len() {
                assert_eq!(a_hh[i * bh + j], a_hh[j * bh + i]);
            }
        }
    }

    #[test]
    fn sparse_rows_sorted_and_counted() {
        let g = test_graph();
        let mut rng = Rng::new(7);
        let batch: Vec<u32> = (40..160u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        for blk in [&sb.a_bb, &sb.a_bh, &sb.a_hh] {
            assert_eq!(blk.offsets.len(), blk.n_rows + 1);
            assert_eq!(blk.offsets[blk.n_rows] as usize, blk.nnz());
            for i in 0..blk.n_rows {
                let (cols, _) = blk.row(i);
                assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {i} unsorted");
                assert!(cols.iter().all(|&c| (c as usize) < blk.n_cols));
            }
        }
        assert_eq!(sb.a_bb.n_rows, sb.batch.len());
        assert_eq!(sb.a_bh.n_rows, sb.batch.len());
        assert_eq!(sb.a_bh.n_cols, sb.halo.len());
        assert_eq!(sb.a_hh.n_rows, sb.halo.len());
        // nnz_fwd = stored nonzeros + the implicit halo->batch (A_bh^T) arcs
        assert_eq!(sb.nnz_fwd, sb.nnz() + sb.a_bh.nnz());
    }

    #[test]
    fn padding_is_zero() {
        let g = test_graph();
        let mut rng = Rng::new(2);
        let batch: Vec<u32> = (0..50u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        let (bb, bh, nb, nh) = (sb.bucket_b, sb.bucket_h, sb.batch.len(), sb.halo.len());
        let (a_bb, a_bh, _) = sb.to_dense();
        for i in 0..bb {
            for j in 0..bb {
                if i >= nb || j >= nb {
                    assert_eq!(a_bb[i * bb + j], 0.0);
                }
            }
        }
        for i in 0..bb {
            for j in 0..bh {
                if i >= nb || j >= nh {
                    assert_eq!(a_bh[i * bh + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn cluster_policy_has_no_halo() {
        let g = test_graph();
        let mut rng = Rng::new(3);
        let batch: Vec<u32> = (0..80u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::LocalNoHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        assert!(sb.halo.is_empty());
        assert_eq!(sb.a_bh.nnz(), 0);
        assert_eq!(sb.a_hh.nnz(), 0);
        // matches the dense local-normalization reference exactly
        let nb = sb.batch.len();
        let want = local_normalized_dense(&g.csr, &sb.batch);
        let got = sb.a_bb.to_dense(nb, nb);
        assert_eq!(got, want);
        // local normalization rows: positive diagonal, finite weights
        for i in 0..nb {
            assert!(got[i * nb + i] > 0.0);
            let row: f32 = got[i * nb..(i + 1) * nb].iter().sum();
            assert!(row.is_finite() && row > 0.0);
        }
    }

    #[test]
    fn halo_cap_drops_and_reports() {
        let g = test_graph();
        let mut rng = Rng::new(4);
        let batch: Vec<u32> = (0..100u32).collect();
        let tiny = Buckets(vec![(128, 16)]);
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &tiny, &HaloSampler::none(), &mut rng).unwrap();
        assert_eq!(sb.halo.len(), 16);
        assert!(sb.dropped_halo > 0);
    }

    #[test]
    fn unbounded_buckets_fit_exactly() {
        let g = test_graph();
        let mut rng = Rng::new(6);
        let batch: Vec<u32> = (0..100u32).collect();
        let sb =
            build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut rng)
                .unwrap();
        assert_eq!(sb.bucket_b, sb.batch.len());
        assert_eq!(sb.bucket_h, sb.halo.len());
        assert_eq!(sb.dropped_halo, 0);
    }

    #[test]
    fn beta_scores_bounded() {
        let g = test_graph();
        let mut rng = Rng::new(5);
        let batch: Vec<u32> = (0..120u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        for score in [
            BetaScore::XSquared,
            BetaScore::TwoXMinusXSquared,
            BetaScore::X,
            BetaScore::One,
            BetaScore::SinX,
        ] {
            let beta = beta_vector(&sb, 0.8, score);
            assert_eq!(beta.len(), sb.bucket_h);
            assert!(beta.iter().all(|&b| (0.0..=1.0).contains(&b)));
            // padding entries must be zero
            for i in sb.halo.len()..sb.bucket_h {
                assert_eq!(beta[i], 0.0);
            }
        }
    }

    #[test]
    fn a_hb_is_cached_transpose() {
        let g = test_graph();
        let mut rng = Rng::new(8);
        let batch: Vec<u32> = (20..140u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        assert_eq!(sb.a_hb, sb.a_bh.transpose());
        // CLUSTER policy: degenerate but well-formed transpose
        let sbc = build_subgraph(&g, &batch, AdjacencyPolicy::LocalNoHalo, &buckets(), &HaloSampler::none(), &mut rng).unwrap();
        assert_eq!(sbc.a_hb.n_rows, 0);
        assert_eq!(sbc.a_hb.nnz(), 0);
    }

    #[test]
    fn gather_rows_into_parallel_matches_serial() {
        let mut rng = Rng::new(11);
        let n = 500;
        let d = 40; // 500 * 40 = 20000 > GATHER_PAR_MIN, exercises the par path
        let src: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let idx: Vec<u32> = (0..n as u32).step_by(3).collect();
        let rows = idx.len() + 5;
        let got = gather_rows(&src, d, &idx, rows);
        assert_eq!(got.len(), rows * d);
        for (i, &u) in idx.iter().enumerate() {
            assert_eq!(&got[i * d..(i + 1) * d], &src[u as usize * d..(u as usize + 1) * d]);
        }
        assert!(got[idx.len() * d..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn subgraph_cache_hits_validates_and_seals() {
        let g = test_graph();
        let mut rng = Rng::new(12);
        let b0: Vec<u32> = (0..60u32).collect();
        let b1: Vec<u32> = (60..120u32).collect();
        let sb0 = std::sync::Arc::new(
            build_subgraph(&g, &b0, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut rng)
                .unwrap(),
        );
        let sb1 = std::sync::Arc::new(
            build_subgraph(&g, &b1, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut rng)
                .unwrap(),
        );
        let mut cache = SubgraphCache::new(true);
        assert!(cache.get(0, &b0).is_none());
        cache.insert(0, sb0.clone());
        assert!(!cache.is_complete(2));
        cache.insert(1, sb1.clone());
        cache.seal(2);
        assert!(cache.is_complete(2));
        // footprint accounting covers all four blocks of both entries
        assert!(cache.bytes() > (sb0.nnz() + sb1.nnz()) * 8);
        // hits return the same blocks; a mismatched batch misses
        let hit = cache.get(0, &b0).unwrap();
        assert_eq!(hit.a_bb, sb0.a_bb);
        assert!(cache.get(0, &b1).is_none());
        // disabled cache never stores
        let mut off = SubgraphCache::disabled();
        off.insert(0, sb0);
        assert!(off.is_empty());
        assert!(!off.is_complete(0));
        // clearing drops completeness
        cache.clear();
        assert!(!cache.is_complete(2));
    }

    #[test]
    fn cache_applicability_matrix() {
        let capped = Buckets(vec![(128, 64)]);
        let none = HaloSampler::none();
        let sub = HaloSampler::new(HaloSamplerKind::Uniform, 0.5);
        assert!(SubgraphCache::applicable(true, BatcherMode::Fixed, &Buckets::unbounded(), &none));
        // a bucket cap subsamples the halo through the per-batch RNG stream
        assert!(!SubgraphCache::applicable(true, BatcherMode::Fixed, &capped, &none));
        // stochastic groups reshuffle every epoch
        assert!(!SubgraphCache::applicable(
            true,
            BatcherMode::Stochastic,
            &Buckets::unbounded(),
            &none
        ));
        assert!(!SubgraphCache::applicable(true, BatcherMode::Stochastic, &capped, &none));
        // a subsampling policy redraws the halo subset every build
        assert!(!SubgraphCache::applicable(true, BatcherMode::Fixed, &Buckets::unbounded(), &sub));
        // a policy at frac = 1 is a passthrough, so caching stays sound
        let full = HaloSampler::new(HaloSamplerKind::Labor, 1.0);
        assert!(SubgraphCache::applicable(true, BatcherMode::Fixed, &Buckets::unbounded(), &full));
        // config off wins regardless
        assert!(!SubgraphCache::applicable(false, BatcherMode::Fixed, &Buckets::unbounded(), &none));
    }

    #[test]
    fn beta_vector_into_zeroes_dirty_padding_tail() {
        // Regression: reuse one workspace across batches with a shrinking
        // halo — the stale entries past halo.len() must be zeroed by the
        // callee, not trusted to a caller-side pre-zero.
        let g = test_graph();
        let mut rng = Rng::new(13);
        let big: Vec<u32> = (0..120u32).collect();
        let small: Vec<u32> = (0..20u32).collect();
        let pad = Buckets(vec![(128, 512)]);
        let sb_big =
            build_subgraph(&g, &big, AdjacencyPolicy::GlobalWithHalo, &pad, &HaloSampler::none(), &mut rng).unwrap();
        let sb_small =
            build_subgraph(&g, &small, AdjacencyPolicy::GlobalWithHalo, &pad, &HaloSampler::none(), &mut rng).unwrap();
        assert!(sb_small.halo.len() < sb_big.halo.len(), "need a shrinking halo");
        assert_eq!(sb_big.bucket_h, sb_small.bucket_h, "same compiled shape");
        let mut ws = vec![f32::NAN; sb_big.bucket_h];
        beta_vector_into(&sb_big, 0.8, BetaScore::X, &mut ws);
        beta_vector_into(&sb_small, 0.8, BetaScore::X, &mut ws);
        for i in sb_small.halo.len()..sb_small.bucket_h {
            assert_eq!(ws[i], 0.0, "stale tail entry at {i} survived reuse");
        }
        assert_eq!(ws, beta_vector(&sb_small, 0.8, BetaScore::X));
    }

    #[test]
    fn subsampled_build_rescales_source_columns() {
        let g = test_graph();
        let batch: Vec<u32> = (0..100u32).collect();
        let mut rng = Rng::new(21);
        let full = build_subgraph(
            &g,
            &batch,
            AdjacencyPolicy::GlobalWithHalo,
            &Buckets::unbounded(),
            &HaloSampler::none(),
            &mut rng,
        )
        .unwrap();
        for kind in [HaloSamplerKind::Uniform, HaloSamplerKind::Labor, HaloSamplerKind::Importance] {
            let sampler = HaloSampler::new(kind, 0.5);
            let mut r = Rng::new(22);
            let sb = build_subgraph(
                &g,
                &batch,
                AdjacencyPolicy::GlobalWithHalo,
                &Buckets::unbounded(),
                &sampler,
                &mut r,
            )
            .unwrap();
            assert!(sb.halo.len() < full.halo.len(), "{kind:?} kept the whole halo");
            assert_eq!(sb.halo_inv_p.len(), sb.halo.len());
            assert_eq!(sb.dropped_halo, full.halo.len() - sb.halo.len());
            assert!(sb.halo_inv_p.iter().all(|&ip| ip >= 1.0 - 1e-6 && ip.is_finite()));
            // a_hb stays the exact transpose of the rescaled a_bh
            assert_eq!(sb.a_hb, sb.a_bh.transpose());
            // kept halo is a subset of the full halo, and each kept column of
            // A_bh equals the unsampled weight times that node's 1/p
            let full_idx: std::collections::HashMap<u32, usize> =
                full.halo.iter().enumerate().map(|(i, &u)| (u, i)).collect();
            for (i, &u) in sb.batch.iter().enumerate() {
                let (cols, vals) = sb.a_bh.row(i);
                for (&j, &w) in cols.iter().zip(vals) {
                    let hj = sb.halo[j as usize];
                    let fj = full_idx[&hj];
                    let (fcols, fvals) = full.a_bh.row(i);
                    let k = fcols.iter().position(|&c| c as usize == fj).unwrap();
                    let base = fvals[k];
                    let want = base * sb.halo_inv_p[j as usize];
                    assert!(
                        (w - want).abs() <= 1e-6 * want.abs().max(1.0),
                        "{kind:?} batch {u} halo {hj}: got {w}, want {want}"
                    );
                }
            }
            // self-loops on A_hh's diagonal are never rescaled
            for (i, &u) in sb.halo.iter().enumerate() {
                let (cols, vals) = sb.a_hh.row(i);
                if let Some(k) = cols.iter().position(|&c| c as usize == i) {
                    assert_eq!(vals[k], g.self_w[u as usize], "{kind:?} scaled a self-loop");
                }
            }
        }
    }

    #[test]
    fn fixed_mode_rebuild_is_bit_identical() {
        // The cache-soundness property: with unbounded buckets the blocks
        // are a deterministic function of the batch, so a cached entry and
        // a fresh rebuild are interchangeable.
        let g = test_graph();
        let batch: Vec<u32> = (10..170u32).collect();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999); // different RNG stream: must not matter
        let a = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut r1)
            .unwrap();
        let b = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut r2)
            .unwrap();
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.halo, b.halo);
        assert_eq!(a.a_bb, b.a_bb);
        assert_eq!(a.a_bh, b.a_bh);
        assert_eq!(a.a_hh, b.a_hh);
        assert_eq!(a.a_hb, b.a_hb);
        assert_eq!(a.halo_deg_local, b.halo_deg_local);
        assert_eq!(a.nnz_fwd, b.nnz_fwd);
    }

    #[test]
    fn bucket_pick_logic() {
        let b = Buckets(vec![(128, 512), (128, 1024), (256, 768)]);
        assert_eq!(b.pick(100, 400), Some((128, 512)));
        assert_eq!(b.pick(100, 600), Some((128, 1024)));
        assert_eq!(b.pick(100, 2000), Some((128, 1024))); // cap
        assert_eq!(b.pick(200, 100), Some((256, 768)));
        assert_eq!(b.pick(300, 100), None);
        assert_eq!(Buckets::unbounded().pick(300, 100), Some((300, 100)));
    }
}
