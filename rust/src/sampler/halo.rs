//! Halo subsampling policies with explicit inclusion probabilities.
//!
//! The legacy bucket cap in [`crate::sampler::build_subgraph`] drops halo
//! nodes uniformly *without* reweighting the surviving edges, so the
//! expected batch-row aggregation shrinks by the keep fraction — a bias the
//! paper's compensation cannot see. A [`HaloSampler`] instead subsamples
//! halo nodes with a known per-node inclusion probability `p_i` and reports
//! `1/p_i` so the sampler can rescale the kept `A_bh`/`A_hh` edge weights
//! (Horvitz–Thompson): `E[sum_{i kept} w_i/p_i * x_i] = sum_i w_i * x_i`.
//!
//! Policies:
//!   - `uniform`: exactly-k uniform without replacement, `p_i = k/n` —
//!     the rescaled (unbiased) version of the legacy cap.
//!   - `importance`: FastGCN/LADIES-style layer-dependent importance
//!     `pi_i = sum_b w(b,i)^2` over in-batch neighbors (the column-sum
//!     `pi = sum(L∘L)` idiom), Bernoulli coins with `p_i = min(1, c·pi_i)`
//!     water-filled so `sum p_i = k`.
//!   - `labor`: LABOR-style (Balın & Çatalyürek) with L1 importance
//!     `pi_i = sum_b |w(b,i)|` and a *per-vertex* hashed coin shared across
//!     the epoch's batches, so a vertex kept in one batch tends to be kept
//!     in others — maximizing history/cache overlap at the same variance.

use crate::graph::Graph;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HaloSamplerKind {
    /// Legacy path: no policy subsampling; the bucket cap (if any) drops
    /// uniformly without rescaling — bit-identical to pre-sampler-zoo
    /// behaviour.
    None,
    /// Exactly-k uniform subsample with 1/p rescale (`p = k/n`).
    Uniform,
    /// LABOR layer-dependent: L1 importance + shared per-vertex coins.
    Labor,
    /// FastGCN/LADIES importance-weighted: L2 importance + fresh coins.
    Importance,
}

impl HaloSamplerKind {
    pub fn parse(s: &str) -> Option<HaloSamplerKind> {
        Some(match s {
            "none" => HaloSamplerKind::None,
            "uniform" | "uniform-cap" => HaloSamplerKind::Uniform,
            "labor" => HaloSamplerKind::Labor,
            "importance" | "ladies" | "fastgcn" => HaloSamplerKind::Importance,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            HaloSamplerKind::None => "none",
            HaloSamplerKind::Uniform => "uniform",
            HaloSamplerKind::Labor => "labor",
            HaloSamplerKind::Importance => "importance",
        }
    }
}

/// A halo subsampling policy: which scheme, and what fraction of the halo
/// to keep. `kind = None` or `frac >= 1` is a passthrough (no subsampling,
/// no RNG consumption) — the bit-identical legacy path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HaloSampler {
    pub kind: HaloSamplerKind,
    /// Target keep fraction of the halo (budget `k = ceil(frac * n)`).
    pub frac: f32,
}

impl Default for HaloSampler {
    fn default() -> Self {
        HaloSampler::none()
    }
}

impl HaloSampler {
    pub fn none() -> HaloSampler {
        HaloSampler { kind: HaloSamplerKind::None, frac: 1.0 }
    }

    pub fn new(kind: HaloSamplerKind, frac: f32) -> HaloSampler {
        HaloSampler { kind, frac }
    }

    /// True when this policy actually subsamples (and therefore consumes
    /// RNG and varies per build). The negation is what keeps the
    /// no-subsampling path bit-identical and the subgraph cache sound.
    pub fn is_subsampling(&self) -> bool {
        self.kind != HaloSamplerKind::None && self.frac < 1.0
    }

    /// Subsample `halo` (sorted node ids, membership in `mark`: 1 = batch,
    /// 2 = halo). Returns the kept halo sorted ascending, the aligned
    /// `1/p_i` rescale factors, and the dropped count.
    pub(crate) fn subsample(
        &self,
        g: &Graph,
        mark: &[u8],
        halo: &[u32],
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f32>, usize) {
        let n = halo.len();
        let k = ((self.frac as f64 * n as f64).ceil() as usize).clamp(1, n);
        if k >= n {
            return (halo.to_vec(), vec![1.0; n], 0);
        }
        match self.kind {
            HaloSamplerKind::None => (halo.to_vec(), vec![1.0; n], 0),
            HaloSamplerKind::Uniform => {
                let p = k as f32 / n as f32;
                let mut keep = rng.sample_indices(n, k);
                keep.sort_unstable();
                let kept: Vec<u32> = keep.iter().map(|&i| halo[i]).collect();
                let inv_p = vec![1.0 / p; kept.len()];
                (kept, inv_p, n - k)
            }
            HaloSamplerKind::Labor | HaloSamplerKind::Importance => {
                let l1 = self.kind == HaloSamplerKind::Labor;
                let pi: Vec<f64> = halo
                    .iter()
                    .map(|&u| batch_importance(g, mark, u as usize, l1))
                    .collect();
                let p = inclusion_probs(&pi, k);
                // LABOR: one seed word per build, then per-vertex hashed
                // coins — the same vertex draws the same coin in every batch
                // of the epoch. Importance: fresh stream coins.
                let seed_word = if l1 { rng.next_u64() } else { 0 };
                let mut kept = Vec::with_capacity(k + k / 4 + 1);
                let mut inv_p = Vec::with_capacity(k + k / 4 + 1);
                for (i, &u) in halo.iter().enumerate() {
                    let coin =
                        if l1 { vertex_coin(seed_word, u) } else { rng.next_f64() };
                    if coin < p[i] {
                        kept.push(u);
                        inv_p.push((1.0 / p[i]) as f32);
                    }
                }
                let dropped = n - kept.len();
                (kept, inv_p, dropped)
            }
        }
    }
}

/// Importance of halo node `u` w.r.t. the current batch: the column sum of
/// squared (L2, FastGCN/LADIES `pi = sum(L∘L)`) or absolute (L1, LABOR)
/// normalized edge weights into in-batch rows.
fn batch_importance(g: &Graph, mark: &[u8], u: usize, l1: bool) -> f64 {
    let mut pi = 0f64;
    for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
        let v = g.csr.neighbors[ei] as usize;
        if mark[v] == 1 {
            let w = g.edge_w[ei] as f64;
            pi += if l1 { w.abs() } else { w * w };
        }
    }
    pi
}

/// Water-filling solver for `p_i = min(1, c * pi_i)` with `sum p_i = k`:
/// saturated nodes pin at 1, the scale `c` redistributes the remaining
/// budget over the rest until no new node saturates. Terminates in at most
/// `n` rounds (each round saturates at least one new node or stops).
pub(crate) fn inclusion_probs(pi: &[f64], k: usize) -> Vec<f64> {
    let n = pi.len();
    if k >= n {
        return vec![1.0; n];
    }
    let mut p = vec![0f64; n];
    let mut saturated = vec![false; n];
    loop {
        let mut mass = 0f64;
        let mut nsat = 0usize;
        for i in 0..n {
            if saturated[i] {
                nsat += 1;
            } else {
                mass += pi[i];
            }
        }
        let budget = k.saturating_sub(nsat) as f64;
        if mass <= 0.0 || budget <= 0.0 {
            // degenerate tail (all-zero importances): spread uniformly
            let rem = (n - nsat) as f64;
            for i in 0..n {
                if !saturated[i] {
                    p[i] = (budget / rem).clamp(0.0, 1.0);
                }
            }
            return floor_probs(p);
        }
        let c = budget / mass;
        let mut newly_saturated = false;
        for i in 0..n {
            if !saturated[i] {
                let v = c * pi[i];
                if v >= 1.0 {
                    saturated[i] = true;
                    p[i] = 1.0;
                    newly_saturated = true;
                } else {
                    p[i] = v;
                }
            }
        }
        if !newly_saturated {
            return floor_probs(p);
        }
    }
}

/// Floor inclusion probabilities away from zero so `1/p` edge rescales stay
/// finite. The coin uses the floored probability too, so the estimator
/// remains exactly unbiased.
fn floor_probs(mut p: Vec<f64>) -> Vec<f64> {
    for v in &mut p {
        if *v < 1e-9 {
            *v = 1e-9;
        }
    }
    p
}

/// LABOR's shared per-vertex coin: a splitmix-style hash of (epoch seed
/// word, vertex id) mapped to [0, 1). Deterministic per (seed, vertex), so
/// the same vertex flips the same coin across all batches built with the
/// same seed word.
fn vertex_coin(seed_word: u64, u: u32) -> f64 {
    let mut z = seed_word ^ (u as u64).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [
            HaloSamplerKind::None,
            HaloSamplerKind::Uniform,
            HaloSamplerKind::Labor,
            HaloSamplerKind::Importance,
        ] {
            assert_eq!(HaloSamplerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(HaloSamplerKind::parse("uniform-cap"), Some(HaloSamplerKind::Uniform));
        assert_eq!(HaloSamplerKind::parse("ladies"), Some(HaloSamplerKind::Importance));
        assert!(HaloSamplerKind::parse("bogus").is_none());
    }

    #[test]
    fn passthrough_detection() {
        assert!(!HaloSampler::none().is_subsampling());
        assert!(!HaloSampler::new(HaloSamplerKind::Labor, 1.0).is_subsampling());
        assert!(!HaloSampler::new(HaloSamplerKind::None, 0.5).is_subsampling());
        assert!(HaloSampler::new(HaloSamplerKind::Uniform, 0.5).is_subsampling());
    }

    #[test]
    fn inclusion_probs_sum_to_budget_and_cap_at_one() {
        let pi = vec![10.0, 1.0, 1.0, 1.0, 0.5, 0.5];
        let p = inclusion_probs(&pi, 3);
        let sum: f64 = p.iter().sum();
        assert!((sum - 3.0).abs() < 1e-9, "sum {sum}");
        assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // the dominant node saturates; the rest split the remaining budget
        // proportionally to their importance
        assert_eq!(p[0], 1.0);
        assert!((p[1] / p[4] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inclusion_probs_degenerate_importances() {
        // all-zero importances fall back to uniform
        let p = inclusion_probs(&[0.0; 5], 2);
        assert!((p.iter().sum::<f64>() - 2.0).abs() < 1e-9);
        assert!(p.iter().all(|&v| (v - 0.4).abs() < 1e-9));
        // k >= n keeps everything
        assert_eq!(inclusion_probs(&[1.0, 2.0], 5), vec![1.0, 1.0]);
    }

    #[test]
    fn vertex_coin_is_deterministic_and_uniformish() {
        assert_eq!(vertex_coin(42, 7), vertex_coin(42, 7));
        assert_ne!(vertex_coin(42, 7), vertex_coin(43, 7));
        let n = 4000;
        let mean: f64 = (0..n).map(|u| vertex_coin(9, u)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
        assert!((0..n).all(|u| (0.0..1.0).contains(&vertex_coin(9, u))));
    }
}
