//! `lmc` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   train             train one configuration (flags or --config file)
//!   eval              exact full-graph evaluation of a fresh model
//!   partition-stats   METIS-substitute quality report for a dataset
//!   datasets          list datasets and their stats
//!   programs          list compiled artifact programs (pjrt builds)
//!   grad-error        per-layer mini-batch gradient error (Fig. 3 point)
//!   bench-gate        diff BENCH_step.json vs BENCH_baseline.json and fail
//!                     on a gated-phase slowdown (CI perf-gate job)
//!   predict           one-shot batched inference over the serve engine
//!   serve             long-lived inference loop: JSONL requests on stdin,
//!                     or length-prefixed JSONL over TCP with --listen,
//!                     micro-batched through the shared serve loop
//!   loadtest          open-loop load generator + latency harness against
//!                     a serve server (emits BENCH_serve_e2e.json)
//!   experiment <id>   regenerate a paper table/figure (table1, table2,
//!                     table3, table6, table7, table8, table9, fig2, fig3,
//!                     fig4, fig5, sharded, all)

use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, Result};

use lmc::backend::{make_executor, Executor};
use lmc::config::RunConfig;
use lmc::coordinator::{grad_check, Params, RunMetrics, ShardedTrainer, Trainer};
use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, quality::quality, PartitionConfig};
use lmc::serve::{net, BatchPolicy, ServeEngine, ServeLoop, ServeMode};
use lmc::util::cli::Args;
use lmc::util::json::Json;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "partition-stats" => cmd_partition_stats(args),
        "datasets" => cmd_datasets(),
        "programs" => cmd_programs(args),
        "grad-error" => cmd_grad_error(args),
        "bench-gate" => cmd_bench_gate(args),
        "predict" => cmd_predict(args),
        "serve" => cmd_serve(args),
        "loadtest" => cmd_loadtest(args),
        "experiment" => lmc::experiments::dispatch(args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}' (try `lmc help`)")),
    }
}

const HELP: &str = "\
lmc — LMC (ICLR 2023) reproduction: subgraph-wise GNN training with local
message compensation. rust coordinator + pluggable execution backends
(native sparse CPU by default; AOT JAX/Pallas PJRT with --features pjrt).

usage: lmc <subcommand> [--flags]

subcommands:
  train            --dataset D --arch gcn|gcnii
                   --method lmc|gas|fm|cluster|gd|lmc-spider|top
                   (aliases: graphfm|graphfm-ob=fm, cluster-gcn=cluster,
                   full|full-batch=gd, spider=lmc-spider,
                   mi|message-invariance=top)
                   [--backend native|pjrt] [--epochs N] [--lr F]
                   [--clusters-per-batch C] [--parts K]
                   [--shards S] [--sync-every K] [--sync-mode avg|hist]
                   [--worker-retries N]
                   [--beta-alpha F] [--beta-score x2|2x-x2|x|1|sinx]
                   [--compensation lmc|top|none]   override the method's
                   compensation policy   [--top-lr F] TOP transform fit rate
                   [--history-dtype f32|bf16|f16]
                   [--halo-sampler none|uniform|labor|importance]
                   [--halo-keep F]   keep fraction for subsampling policies
                   [--checkpoint-dir DIR] [--checkpoint-every N]
                   [--resume DIR]   continue from the last checkpoint in DIR
                   [--target-acc F] [--config file.toml] [--seed N]
                   [--save-params FILE] [--verbose]
  eval             exact inference with fresh params (pipeline smoke test)
  predict          one-shot serve-engine inference: --nodes 1,2,3
                   [--dataset D] [--arch A] [--params FILE]
                   [--serve-mode exact|cached]
                   [--compensation lmc|none] [--comp-beta F]
                   (--serve-beta is a deprecated alias for --comp-beta)
  serve            JSONL request loop ('[ids...]', '{\"id\":N,\"nodes\":[ids...]}',
                   or '{\"op\":\"shutdown\"}' per line; one JSON response per
                   request; on stdin EOF, SIGTERM, SIGINT, or a shutdown op
                   the queue is drained and answered, then a final
                   {\"op\":\"shutdown\",...} status line is emitted). Default
                   transport is stdin/stdout; --listen HOST:PORT serves the
                   same protocol as length-prefixed frames (u32 LE byte
                   count + JSON) over TCP, micro-batching across
                   connections.
                   [--listen ADDR] [--params FILE] [--serve-mode exact|cached]
                   [--serve-max-batch N] [--serve-max-wait-ms MS]
                   [--compensation lmc|none] [--comp-beta F]
                   [--history-dtype f32|bf16|f16]
  loadtest         open-loop load generator against a serve server: spawns
                   an in-process `serve --listen` twin (or targets --addr),
                   sends --loadtest-qps requests/s over --loadtest-conns
                   connections for --loadtest-secs seconds (sizes cycled
                   from --loadtest-sizes), then drains the server and
                   writes p50/p95/p99 latency, achieved qps, and mean batch
                   occupancy to BENCH_serve_e2e.json.
                   [--addr HOST:PORT] [--out FILE] [--smoke]
                   [--require-occupancy F]   exit 1 when the mean batch
                   occupancy comes in below F requests/batch
  partition-stats  --dataset D [--parts K] [--seed N]
  datasets         list registered datasets
  programs         list artifact programs (--artifacts DIR; pjrt builds only)
  grad-error       --dataset D --method M [--warm-epochs N]
  bench-gate       [--bench ../BENCH_step.json] [--baseline ../BENCH_baseline.json]
                   [--summary FILE]   diff gated phases, exit 1 on regression
  experiment ID    table1|table2|table3|table6|table7|table8|table9|
                   fig2|fig3|fig4|fig5|sharded|grad-error|samplers|all
                   [--out results/]

environment:
  LMC_FAILPOINTS   fault-injection seam for crash-safety testing:
                   site:when:action[,...] (see rust/README.md § Fault
                   tolerance for the site list and grammar)
";

fn make_trainer(args: &Args) -> Result<Trainer> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let exec = make_executor(&cfg)?;
    Trainer::new(exec, cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let resume_dir = args.opt("resume");
    if let Some(dir) = resume_dir {
        // resuming implies continued checkpointing into the same directory
        // unless --checkpoint-dir points elsewhere
        if cfg.checkpoint_dir.is_none() {
            cfg.checkpoint_dir = Some(dir.to_string());
        }
    }
    let exec = make_executor(&cfg)?;
    if cfg.shards > 1 {
        let mut st = match resume_dir {
            Some(dir) => {
                let st = ShardedTrainer::resume(exec, cfg, Path::new(dir))?;
                println!("resumed from {dir} (epoch {})", st.epochs_done());
                st
            }
            None => ShardedTrainer::new(exec, cfg)?,
        };
        println!(
            "training {} / {} / {} on {} backend — {} nodes, {} shards, sync {} every {} epoch(s), {} epochs",
            st.cfg.dataset.name(),
            st.cfg.arch,
            st.cfg.method.name(),
            st.exec.backend_name(),
            st.parent.n(),
            st.num_workers(),
            st.cfg.sync_mode.name(),
            st.cfg.sync_every.max(1),
            st.cfg.epochs
        );
        let metrics = st.run()?;
        if let Some(path) = args.opt("save-params") {
            st.averaged_params().save(Path::new(path))?;
            println!("averaged worker params saved to {path}");
        }
        return report_metrics(
            &metrics,
            st.cfg.dataset.name(),
            &st.cfg.arch,
            st.cfg.method.name(),
            args,
        );
    }
    let mut trainer = match resume_dir {
        Some(dir) => {
            let t = Trainer::resume(exec, cfg, Path::new(dir))?;
            println!("resumed from {dir} (epoch {})", t.epochs_done());
            t
        }
        None => Trainer::new(exec, cfg)?,
    };
    println!(
        "training {} / {} / {} on {} backend — {} nodes, {} clusters, {} epochs",
        trainer.cfg.dataset.name(),
        trainer.cfg.arch,
        trainer.cfg.method.name(),
        trainer.exec.backend_name(),
        trainer.graph.n(),
        trainer.clusters.len(),
        trainer.cfg.epochs
    );
    let metrics = trainer.run()?;
    if let Some(path) = args.opt("save-params") {
        trainer.params.save(Path::new(path))?;
        println!("params saved to {path}");
    }
    report_metrics(
        &metrics,
        trainer.cfg.dataset.name(),
        &trainer.cfg.arch,
        trainer.cfg.method.name(),
        args,
    )
}

// ---------------------------------------------------------------------------
// serve path
// ---------------------------------------------------------------------------

/// Build a serve engine from the CLI config, loading `--params FILE` when
/// given (the `train --save-params` round-trip) and warming the history
/// for the cached path.
fn make_engine(args: &Args) -> Result<ServeEngine> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let params = match args.opt("params") {
        Some(p) => Some(Params::load(Path::new(p))?),
        None => None,
    };
    let mut engine = ServeEngine::from_config(&cfg, params)?;
    if engine.opts().mode == ServeMode::Cached {
        engine.refresh_history()?;
    }
    Ok(engine)
}

fn parse_nodes(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<u32>().map_err(|e| anyhow!("bad node id '{t}': {e}")))
        .collect()
}

fn cmd_predict(args: &Args) -> Result<()> {
    let engine = make_engine(args)?;
    let nodes = parse_nodes(
        args.opt("nodes")
            .ok_or_else(|| anyhow!("predict needs --nodes 1,2,3 (comma-separated ids)"))?,
    )?;
    let preds = engine.predict(&nodes)?;
    println!(
        "{}-node graph / arch {} — {} mode, {} prediction(s):",
        engine.graph().n(),
        engine.model().arch_name,
        engine.opts().mode.name(),
        preds.len()
    );
    for p in &preds {
        println!(
            "node {:>7}  class {:>3}  logit {:.4}",
            p.node,
            p.label,
            p.logits[p.label as usize]
        );
    }
    Ok(())
}

/// SIGTERM/SIGINT handling without a libc crate: a direct `extern "C"`
/// binding to `signal(2)` records the delivered signal in an atomic the
/// serve loop polls, so a terminated (or Ctrl-C'd) service drains and
/// answers its queue before exiting instead of dropping requests on the
/// floor. SIGINT used to take the default kill-the-process disposition —
/// an interactive Ctrl-C lost queued requests a SIGTERM would have
/// answered (ISSUE 8).
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicI32, Ordering};

    static SIGNUM: AtomicI32 = AtomicI32::new(0);

    type Handler = extern "C" fn(i32);

    extern "C" {
        fn signal(signum: i32, handler: Handler) -> usize;
    }

    extern "C" fn on_signal(signum: i32) {
        // async-signal-safe: a single atomic store
        SIGNUM.store(signum, Ordering::SeqCst);
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    pub fn install_handlers() {
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }

    /// Shutdown reason when a handled signal has been delivered.
    pub fn signal_reason() -> Option<&'static str> {
        match SIGNUM.load(Ordering::SeqCst) {
            SIGTERM => Some("sigterm"),
            SIGINT => Some("sigint"),
            _ => None,
        }
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install_handlers() {}

    pub fn signal_reason() -> Option<&'static str> {
        None
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let engine = Arc::new(make_engine(args)?);
    sig::install_handlers();
    eprintln!(
        "serving {} / {} on the native backend — {} nodes, {} mode, tiles of {} node(s), \
         flush at {} queued node(s) or {} ms",
        engine.model().profile,
        engine.model().arch_name,
        engine.graph().n(),
        engine.opts().mode.name(),
        engine.opts().tile_nodes,
        cfg.serve_max_batch,
        cfg.serve_max_wait_ms
    );
    eprintln!(
        "history store: dtype {}, {} bytes/node resident",
        engine.history_dtype().name(),
        engine.history_bytes_per_node()
    );
    let policy = BatchPolicy { max_nodes: cfg.serve_max_batch, max_wait: cfg.serve_max_wait_ms };
    let clock = Instant::now();
    let listen = args.opt("listen").map(str::to_string).or_else(|| cfg.serve_listen.clone());
    let stats = match listen {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(&addr)
                .map_err(|e| anyhow!("cannot listen on {addr}: {e}"))?;
            // tests and loadtest bind port 0; the resolved address must be
            // discoverable, so it goes to stderr before the first accept
            eprintln!("listening on {}", listener.local_addr()?);
            net::serve_tcp(Arc::clone(&engine), policy, listener, sig::signal_reason)?
        }
        None => {
            // stdin transport: a reader thread feeds the shared loop so it
            // can wake on the micro-batcher's latency deadline even while
            // no input arrives — a queued sub-threshold request is
            // answered within ~serve_max_wait_ms, not held hostage until
            // the next line or EOF.
            let (tx, rx) = mpsc::channel::<net::Event>();
            let reader = std::thread::spawn(move || {
                let stdin = std::io::stdin();
                for line in stdin.lock().lines() {
                    let Ok(line) = line else { break };
                    if tx.send(net::Event { sink: net::Sink::Stdout, line }).is_err() {
                        break;
                    }
                }
            });
            let stats = ServeLoop::new(Arc::clone(&engine), policy).run(&rx, sig::signal_reason);
            if stats.reason == "eof" {
                // after a signal the reader may be blocked in stdin.read
                // forever; join only on EOF, where it is guaranteed to
                // have exited
                let _ = reader.join();
            }
            stats
        }
    };
    // both transports end with the status line on stdout (the TCP path
    // additionally broadcast it to every open connection)
    println!("{}", net::shutdown_line(&stats));
    eprintln!(
        "served {} node prediction(s) in {:.3}s (backend busy {:.3}s, shutdown: {})",
        stats.served,
        clock.elapsed().as_secs_f64(),
        engine.exec().exec_secs(),
        stats.reason
    );
    Ok(())
}

/// Finite-or-zero JSON number: percentiles over an empty latency set are
/// NaN, which is not representable in JSON.
fn json_num(x: f64) -> Json {
    Json::Num(if x.is_finite() { x } else { 0.0 })
}

fn cmd_loadtest(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let smoke = args.has_flag("smoke") || std::env::var("BENCH_SMOKE").is_ok();
    let (qps, secs) = if smoke {
        // CI smoke caps: a few seconds of load, numbers recorded but never
        // gated (namespaced *.smoke.json, like the other benches)
        (cfg.loadtest_qps.min(400.0), cfg.loadtest_secs.min(2.0))
    } else {
        (cfg.loadtest_qps, cfg.loadtest_secs)
    };
    let policy = BatchPolicy { max_nodes: cfg.serve_max_batch, max_wait: cfg.serve_max_wait_ms };
    // target an external server with --addr, or spin up an in-process
    // `serve --listen` twin on a loopback port
    let (addr, server, n_nodes) = match args.opt("addr") {
        Some(a) => (a.to_string(), None, load(cfg.dataset, cfg.seed).n() as u32),
        None => {
            let engine = Arc::new(make_engine(args)?);
            let n = engine.graph().n() as u32;
            let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?.to_string();
            let h = std::thread::spawn(move || net::serve_tcp(engine, policy, listener, || None));
            (addr, Some(h), n)
        }
    };
    let opts = net::LoadtestOptions {
        addr,
        conns: cfg.loadtest_conns.max(1),
        qps,
        secs,
        sizes: cfg.loadtest_sizes.clone(),
        seed: cfg.seed,
        n_nodes,
    };
    eprintln!(
        "loadtest: {} conns at {} qps for {}s against {} (sizes {:?})",
        opts.conns, opts.qps, opts.secs, opts.addr, opts.sizes
    );
    let report = net::run_loadtest(&opts)?;
    if let Some(h) = server {
        // run_loadtest sent the shutdown op; the server thread drains and
        // exits on it
        h.join().map_err(|_| anyhow!("serve thread panicked"))??;
    }
    let occupancy = report.server.map(|s| {
        if s.batches > 0 {
            s.requests as f64 / s.batches as f64
        } else {
            0.0
        }
    });
    println!(
        "sent {} completed {} errors {} in {:.2}s — achieved {:.1} qps (target {})",
        report.sent, report.completed, report.errors, report.wall_s, report.achieved_qps, qps
    );
    println!(
        "latency ms: p50 {:.2}  p95 {:.2}  p99 {:.2}  mean {:.2}  max {:.2}",
        report.p50_ms, report.p95_ms, report.p99_ms, report.mean_ms, report.max_ms
    );
    if let (Some(s), Some(occ)) = (report.server, occupancy) {
        println!(
            "server: {} requests in {} batches (mean occupancy {:.2} requests/batch), \
             {} predictions served",
            s.requests, s.batches, occ, s.served
        );
    }

    let out_default =
        if smoke { "../BENCH_serve_e2e.smoke.json" } else { "../BENCH_serve_e2e.json" };
    let out = args.opt_or("out", out_default);
    let mut lat = BTreeMap::new();
    lat.insert("p50".to_string(), json_num(report.p50_ms));
    lat.insert("p95".to_string(), json_num(report.p95_ms));
    lat.insert("p99".to_string(), json_num(report.p99_ms));
    lat.insert("mean".to_string(), json_num(report.mean_ms));
    lat.insert("max".to_string(), json_num(report.max_ms));
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("serve_e2e".to_string()));
    top.insert("provenance".to_string(), Json::Str(lmc::util::bench::provenance()));
    top.insert("smoke".to_string(), Json::Bool(smoke));
    top.insert("dataset".to_string(), Json::Str(cfg.dataset.name().to_string()));
    top.insert("serve_mode".to_string(), Json::Str(cfg.serve_mode.name().to_string()));
    top.insert("conns".to_string(), Json::Num(opts.conns as f64));
    top.insert("target_qps".to_string(), json_num(qps));
    top.insert("duration_s".to_string(), json_num(secs));
    top.insert("sent".to_string(), Json::Num(report.sent as f64));
    top.insert("completed".to_string(), Json::Num(report.completed as f64));
    top.insert("errors".to_string(), Json::Num(report.errors as f64));
    top.insert("achieved_qps".to_string(), json_num(report.achieved_qps));
    top.insert("latency_ms".to_string(), Json::Obj(lat));
    if let (Some(s), Some(occ)) = (report.server, occupancy) {
        let mut srv = BTreeMap::new();
        srv.insert("served".to_string(), Json::Num(s.served as f64));
        srv.insert("requests".to_string(), Json::Num(s.requests as f64));
        srv.insert("batches".to_string(), Json::Num(s.batches as f64));
        srv.insert("mean_batch_occupancy".to_string(), json_num(occ));
        top.insert("server".to_string(), Json::Obj(srv));
    }
    std::fs::write(out, format!("{}\n", Json::Obj(top)))?;
    println!("wrote {out}");

    if let Some(min) = args.opt_f64("require-occupancy") {
        let occ = occupancy
            .ok_or_else(|| anyhow!("server stats missing from the shutdown broadcast"))?;
        if occ < min {
            return Err(anyhow!(
                "mean batch occupancy {occ:.2} is below the required {min} requests/batch — \
                 cross-stream batching is not forming"
            ));
        }
    }
    Ok(())
}

/// Post-run summary + optional curve export, shared by the serial and
/// sharded train paths.
fn report_metrics(
    metrics: &RunMetrics,
    dataset: &str,
    arch: &str,
    method: &str,
    args: &Args,
) -> Result<()> {
    let (bv, bt) = metrics.best_val_test().unwrap_or((f64::NAN, f64::NAN));
    println!(
        "done in {:.1}s — best val {:.4}, test@best-val {:.4}, final test {:.4}",
        metrics.total_secs(),
        bv,
        bt,
        metrics.final_test().unwrap_or(f64::NAN)
    );
    if let Some((ep, secs)) = metrics.reached_target {
        println!("target accuracy reached at epoch {ep} ({secs:.1}s)");
    }
    if let Some(out) = args.opt("out") {
        let label = format!("{dataset}_{arch}_{method}");
        metrics.curve_table(&label).save(Path::new(out), &label)?;
        println!("curve saved to {out}/{label}.csv");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let trainer = make_trainer(args)?;
    let e = trainer.evaluate()?;
    println!(
        "fresh-params exact eval: train_loss {:.4} train {:.4} val {:.4} test {:.4}",
        e.train_loss, e.train_acc, e.val_acc, e.test_acc
    );
    Ok(())
}

fn cmd_partition_stats(args: &Args) -> Result<()> {
    let id = DatasetId::parse(args.opt_or("dataset", "arxiv-sim"))
        .ok_or_else(|| anyhow!("unknown dataset"))?;
    let seed = args.opt_usize("seed").unwrap_or(0) as u64;
    let g = load(id, seed);
    let k = args.opt_usize("parts").unwrap_or_else(|| id.default_parts());
    let p = partition(&g.csr, &PartitionConfig::new(k, seed));
    let q = quality(&g.csr, &p.assign, k);
    println!(
        "{}: n={} |E|={} k={} edge_cut={} ({:.1}%) balance={:.3} part sizes [{}, {}]",
        id.name(),
        g.n(),
        g.csr.num_undirected_edges(),
        k,
        q.edge_cut,
        100.0 * q.cut_fraction,
        q.balance,
        q.min_part,
        q.max_part
    );
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("{:<14} {:>7} {:>9} {:>5} {:>4} {:>8} profile", "dataset", "nodes", "edges", "dx", "cls", "avg_deg");
    for &id in DatasetId::all() {
        let g = load(id, 0);
        println!(
            "{:<14} {:>7} {:>9} {:>5} {:>4} {:>8.1} {}",
            id.name(),
            g.n(),
            g.csr.num_undirected_edges(),
            g.d_x,
            g.n_class,
            2.0 * g.csr.num_undirected_edges() as f64 / g.n() as f64,
            id.profile()
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_programs(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let rt = lmc::runtime::Runtime::new(Path::new(dir))?;
    println!("{} programs in {}", rt.manifest.programs.len(), dir);
    for (name, p) in &rt.manifest.programs {
        println!(
            "  {:<44} kind={:<10} profile={:<9} arch={:<5} B={} H={} in={} out={}",
            name,
            p.kind,
            p.profile,
            p.arch,
            p.b,
            p.h,
            p.inputs.len(),
            p.outputs.len()
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_programs(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "`lmc programs` lists compiled PJRT artifacts; this build ships the \
         native backend only (rebuild with `--features pjrt`)"
    ))
}

/// CI perf gate: compare a freshly measured `BENCH_step.json` against the
/// committed `BENCH_baseline.json` (noise-banded; see util::perfgate),
/// print the markdown delta table (CI appends it to the job summary), and
/// exit nonzero when a gated phase regressed.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let bench_path = args.opt_or("bench", "../BENCH_step.json");
    let base_path = args.opt_or("baseline", "../BENCH_baseline.json");
    let read = |path: &str| -> Result<lmc::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        lmc::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
    };
    let baseline = read(base_path)?;
    let bench = read(bench_path)?;
    let report = lmc::util::perfgate::compare(&baseline, &bench)?;
    let md = report.markdown();
    println!("{md}");
    if let Some(path) = args.opt("summary") {
        std::fs::write(path, &md)?;
    }
    if !report.passed() {
        let failed: Vec<&str> = report
            .rows
            .iter()
            .filter(|r| !r.pass)
            .map(|r| r.name.as_str())
            .collect();
        return Err(anyhow!(
            "perf gate failed: {} regressed past {:.2}x of {base_path} \
             (if the slowdown is intended, regenerate the baseline with \
             `cargo bench --bench step_breakdown -- --write-baseline` and commit it)",
            failed.join(", "),
            report.max_slowdown
        ));
    }
    Ok(())
}

fn cmd_grad_error(args: &Args) -> Result<()> {
    let mut trainer = make_trainer(args)?;
    let warm = args.opt_usize("warm-epochs").unwrap_or(3);
    let rep = grad_check::measure_after_warmup(&mut trainer, warm)?;
    println!(
        "{} / {} / {} after {} warm epochs:",
        trainer.cfg.dataset.name(),
        trainer.cfg.arch,
        trainer.cfg.method.name(),
        warm
    );
    for (l, e) in rep.per_layer.iter().enumerate() {
        println!("  layer {}: rel err {:.4}", l + 1, e);
    }
    println!("  overall: {:.4}", rep.overall);
    Ok(())
}
