//! `lmc` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   train             train one configuration (flags or --config file)
//!   eval              exact full-graph evaluation of a fresh model
//!   partition-stats   METIS-substitute quality report for a dataset
//!   datasets          list datasets and their stats
//!   programs          list compiled artifact programs (pjrt builds)
//!   grad-error        per-layer mini-batch gradient error (Fig. 3 point)
//!   bench-gate        diff BENCH_step.json vs BENCH_baseline.json and fail
//!                     on a gated-phase slowdown (CI perf-gate job)
//!   predict           one-shot batched inference over the serve engine
//!   serve             long-lived inference loop: JSONL requests on stdin,
//!                     micro-batched through the serve engine
//!   experiment <id>   regenerate a paper table/figure (table1, table2,
//!                     table3, table6, table7, table8, table9, fig2, fig3,
//!                     fig4, fig5, sharded, all)

use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use lmc::backend::{make_executor, Executor};
use lmc::config::RunConfig;
use lmc::coordinator::{grad_check, Params, RunMetrics, ShardedTrainer, Trainer};
use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, quality::quality, PartitionConfig};
use lmc::serve::{BatchPolicy, MicroBatcher, ServeEngine, ServeMode, ServeRequest};
use lmc::util::cli::Args;
use lmc::util::failpoint;
use lmc::util::json::Json;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "partition-stats" => cmd_partition_stats(args),
        "datasets" => cmd_datasets(),
        "programs" => cmd_programs(args),
        "grad-error" => cmd_grad_error(args),
        "bench-gate" => cmd_bench_gate(args),
        "predict" => cmd_predict(args),
        "serve" => cmd_serve(args),
        "experiment" => lmc::experiments::dispatch(args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}' (try `lmc help`)")),
    }
}

const HELP: &str = "\
lmc — LMC (ICLR 2023) reproduction: subgraph-wise GNN training with local
message compensation. rust coordinator + pluggable execution backends
(native sparse CPU by default; AOT JAX/Pallas PJRT with --features pjrt).

usage: lmc <subcommand> [--flags]

subcommands:
  train            --dataset D --arch gcn|gcnii --method lmc|gas|fm|cluster|gd
                   [--backend native|pjrt] [--epochs N] [--lr F]
                   [--clusters-per-batch C] [--parts K]
                   [--shards S] [--sync-every K] [--sync-mode avg|hist]
                   [--worker-retries N]
                   [--beta-alpha F] [--beta-score x2|2x-x2|x|1|sinx]
                   [--history-dtype f32|bf16|f16]
                   [--checkpoint-dir DIR] [--checkpoint-every N]
                   [--resume DIR]   continue from the last checkpoint in DIR
                   [--target-acc F] [--config file.toml] [--seed N]
                   [--save-params FILE] [--verbose]
  eval             exact inference with fresh params (pipeline smoke test)
  predict          one-shot serve-engine inference: --nodes 1,2,3
                   [--dataset D] [--arch A] [--params FILE]
                   [--serve-mode exact|cached] [--serve-beta F]
  serve            JSONL request loop on stdin ('[ids...]' or
                   '{\"id\":N,\"nodes\":[ids...]}' per line; one JSON response
                   per request on stdout, status on stderr; on stdin EOF or
                   SIGTERM the queue is drained and answered, then a final
                   {\"op\":\"shutdown\",\"served\":N} line is emitted)
                   [--params FILE] [--serve-mode exact|cached]
                   [--serve-max-batch N] [--serve-max-wait-ms MS]
                   [--serve-beta F] [--history-dtype f32|bf16|f16]
  partition-stats  --dataset D [--parts K] [--seed N]
  datasets         list registered datasets
  programs         list artifact programs (--artifacts DIR; pjrt builds only)
  grad-error       --dataset D --method M [--warm-epochs N]
  bench-gate       [--bench ../BENCH_step.json] [--baseline ../BENCH_baseline.json]
                   [--summary FILE]   diff gated phases, exit 1 on regression
  experiment ID    table1|table2|table3|table6|table7|table8|table9|
                   fig2|fig3|fig4|fig5|sharded|all   [--out results/]

environment:
  LMC_FAILPOINTS   fault-injection seam for crash-safety testing:
                   site:when:action[,...] (see rust/README.md § Fault
                   tolerance for the site list and grammar)
";

fn make_trainer(args: &Args) -> Result<Trainer> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let exec = make_executor(&cfg)?;
    Trainer::new(exec, cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let resume_dir = args.opt("resume");
    if let Some(dir) = resume_dir {
        // resuming implies continued checkpointing into the same directory
        // unless --checkpoint-dir points elsewhere
        if cfg.checkpoint_dir.is_none() {
            cfg.checkpoint_dir = Some(dir.to_string());
        }
    }
    let exec = make_executor(&cfg)?;
    if cfg.shards > 1 {
        let mut st = match resume_dir {
            Some(dir) => {
                let st = ShardedTrainer::resume(exec, cfg, Path::new(dir))?;
                println!("resumed from {dir} (epoch {})", st.epochs_done());
                st
            }
            None => ShardedTrainer::new(exec, cfg)?,
        };
        println!(
            "training {} / {} / {} on {} backend — {} nodes, {} shards, sync {} every {} epoch(s), {} epochs",
            st.cfg.dataset.name(),
            st.cfg.arch,
            st.cfg.method.name(),
            st.exec.backend_name(),
            st.parent.n(),
            st.num_workers(),
            st.cfg.sync_mode.name(),
            st.cfg.sync_every.max(1),
            st.cfg.epochs
        );
        let metrics = st.run()?;
        if let Some(path) = args.opt("save-params") {
            st.averaged_params().save(Path::new(path))?;
            println!("averaged worker params saved to {path}");
        }
        return report_metrics(
            &metrics,
            st.cfg.dataset.name(),
            &st.cfg.arch,
            st.cfg.method.name(),
            args,
        );
    }
    let mut trainer = match resume_dir {
        Some(dir) => {
            let t = Trainer::resume(exec, cfg, Path::new(dir))?;
            println!("resumed from {dir} (epoch {})", t.epochs_done());
            t
        }
        None => Trainer::new(exec, cfg)?,
    };
    println!(
        "training {} / {} / {} on {} backend — {} nodes, {} clusters, {} epochs",
        trainer.cfg.dataset.name(),
        trainer.cfg.arch,
        trainer.cfg.method.name(),
        trainer.exec.backend_name(),
        trainer.graph.n(),
        trainer.clusters.len(),
        trainer.cfg.epochs
    );
    let metrics = trainer.run()?;
    if let Some(path) = args.opt("save-params") {
        trainer.params.save(Path::new(path))?;
        println!("params saved to {path}");
    }
    report_metrics(
        &metrics,
        trainer.cfg.dataset.name(),
        &trainer.cfg.arch,
        trainer.cfg.method.name(),
        args,
    )
}

// ---------------------------------------------------------------------------
// serve path
// ---------------------------------------------------------------------------

/// Build a serve engine from the CLI config, loading `--params FILE` when
/// given (the `train --save-params` round-trip) and warming the history
/// for the cached path.
fn make_engine(args: &Args) -> Result<ServeEngine> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let params = match args.opt("params") {
        Some(p) => Some(Params::load(Path::new(p))?),
        None => None,
    };
    let mut engine = ServeEngine::from_config(&cfg, params)?;
    if engine.opts().mode == ServeMode::Cached {
        engine.refresh_history()?;
    }
    Ok(engine)
}

fn parse_nodes(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<u32>().map_err(|e| anyhow!("bad node id '{t}': {e}")))
        .collect()
}

fn cmd_predict(args: &Args) -> Result<()> {
    let engine = make_engine(args)?;
    let nodes = parse_nodes(
        args.opt("nodes")
            .ok_or_else(|| anyhow!("predict needs --nodes 1,2,3 (comma-separated ids)"))?,
    )?;
    let preds = engine.predict(&nodes)?;
    println!(
        "{}-node graph / arch {} — {} mode, {} prediction(s):",
        engine.graph().n(),
        engine.model().arch_name,
        engine.opts().mode.name(),
        preds.len()
    );
    for p in &preds {
        println!(
            "node {:>7}  class {:>3}  logit {:.4}",
            p.node,
            p.label,
            p.logits[p.label as usize]
        );
    }
    Ok(())
}

/// One stdin request line: a bare JSON array of node ids, or an object
/// `{"id": N, "nodes": [ids...]}`. Requests without an id get sequential
/// ones.
fn parse_request(line: &str, next_id: &mut u64) -> Result<ServeRequest> {
    let v = Json::parse(line).map_err(|e| anyhow!("bad request line: {e}"))?;
    let (id, nodes) = match v.as_arr() {
        Some(arr) => (None, arr),
        None => {
            let nodes = v.get("nodes").and_then(Json::as_arr).ok_or_else(|| {
                anyhow!("request must be '[ids...]' or '{{\"nodes\": [ids...]}}'")
            })?;
            (v.get("id").and_then(Json::as_f64).map(|x| x as u64), nodes)
        }
    };
    let nodes: Vec<u32> = nodes
        .iter()
        .map(|j| {
            j.as_f64()
                .map(|x| x as u32)
                .ok_or_else(|| anyhow!("node ids must be numbers, got {j}"))
        })
        .collect::<Result<_>>()?;
    let id = id.unwrap_or(*next_id);
    *next_id += 1;
    Ok(ServeRequest { id, nodes })
}

/// One JSON error response line (`{"id": N, "error": "..."}`; id omitted
/// when the request never got one).
fn print_error_line(id: Option<u64>, msg: &str) {
    let mut top = BTreeMap::new();
    if let Some(id) = id {
        top.insert("id".to_string(), Json::Num(id as f64));
    }
    top.insert("error".to_string(), Json::Str(msg.to_string()));
    println!("{}", Json::Obj(top));
}

fn print_answers(answers: &[(u64, Vec<lmc::serve::Prediction>)]) -> usize {
    let mut served = 0usize;
    for (id, preds) in answers {
        let items: Vec<Json> = preds
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("node".to_string(), Json::Num(p.node as f64));
                m.insert("label".to_string(), Json::Num(p.label as f64));
                m.insert(
                    "logit".to_string(),
                    Json::Num(p.logits[p.label as usize] as f64),
                );
                Json::Obj(m)
            })
            .collect();
        served += preds.len();
        let mut top = BTreeMap::new();
        top.insert("id".to_string(), Json::Num(*id as f64));
        top.insert("predictions".to_string(), Json::Arr(items));
        println!("{}", Json::Obj(top));
    }
    served
}

/// Answer one drained micro-batch: a JSON response line per request. A
/// failing request (e.g. an out-of-range node id) must not take the batch
/// — or the long-lived loop — down with it, so on a batch-level error
/// each request is retried alone and only the offender gets an error
/// response.
fn answer_batch(engine: &ServeEngine, batch: &[ServeRequest]) -> usize {
    if let Err(e) = failpoint::fire("serve.request") {
        // injected request-path failure: every request in the batch gets
        // an error response, the loop itself stays up
        for r in batch {
            print_error_line(Some(r.id), &format!("{e:#}"));
        }
        return 0;
    }
    match engine.answer(batch) {
        Ok(answers) => print_answers(&answers),
        Err(_) => {
            let mut served = 0usize;
            for r in batch {
                match engine.answer(std::slice::from_ref(r)) {
                    Ok(answers) => served += print_answers(&answers),
                    Err(e) => print_error_line(Some(r.id), &format!("{e:#}")),
                }
            }
            served
        }
    }
}

/// SIGTERM handling without a libc crate: a direct `extern "C"` binding
/// to `signal(2)` flips an atomic flag the serve loop polls, so a
/// terminated service drains and answers its queue before exiting
/// instead of dropping requests on the floor.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    type Handler = extern "C" fn(i32);

    extern "C" {
        fn signal(signum: i32, handler: Handler) -> usize;
    }

    extern "C" fn on_term(_signum: i32) {
        // async-signal-safe: a single atomic store
        TERM.store(true, Ordering::SeqCst);
    }

    const SIGTERM: i32 = 15;

    pub fn install_term_handler() {
        unsafe {
            signal(SIGTERM, on_term);
        }
    }

    pub fn term_requested() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install_term_handler() {}

    pub fn term_requested() -> bool {
        false
    }
}

/// Parse and enqueue one stdin line; returns the number of predictions
/// served by any batch this line flushed.
fn handle_line(
    engine: &ServeEngine,
    mb: &mut MicroBatcher,
    line: &str,
    next_id: &mut u64,
    clock: Instant,
) -> usize {
    if line.trim().is_empty() {
        return 0;
    }
    let now = clock.elapsed().as_millis() as u64;
    match parse_request(line, next_id) {
        Ok(req) => match mb.push(req, now) {
            Some(batch) => answer_batch(engine, &batch),
            None => 0,
        },
        // a malformed line gets an error response, not a service abort:
        // queued requests stay alive
        Err(e) => {
            print_error_line(None, &format!("{e:#}"));
            0
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let engine = make_engine(args)?;
    sig::install_term_handler();
    eprintln!(
        "serving {} / {} on the native backend — {} nodes, {} mode, tiles of {} node(s), \
         flush at {} queued node(s) or {} ms",
        engine.model().profile,
        engine.model().arch_name,
        engine.graph().n(),
        engine.opts().mode.name(),
        engine.opts().tile_nodes,
        cfg.serve_max_batch,
        cfg.serve_max_wait_ms
    );
    eprintln!(
        "history store: dtype {}, {} bytes/node resident",
        engine.history_dtype().name(),
        engine.history_bytes_per_node()
    );
    let policy = BatchPolicy { max_nodes: cfg.serve_max_batch, max_wait: cfg.serve_max_wait_ms };
    let mut mb = MicroBatcher::new(policy);
    let clock = Instant::now();
    let mut next_id = 0u64;
    let mut served = 0usize;
    // stdin is read on its own thread so the main loop can wake on the
    // micro-batcher's latency deadline even while no input arrives — a
    // queued sub-threshold request is answered within ~serve_max_wait_ms,
    // not held hostage until the next line or EOF.
    let (tx, rx) = mpsc::channel::<String>();
    let reader = std::thread::spawn(move || {
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    let wait = Duration::from_millis(cfg.serve_max_wait_ms.max(1));
    let reason;
    loop {
        if sig::term_requested() {
            reason = "sigterm";
            break;
        }
        match rx.recv_timeout(wait) {
            Ok(line) => {
                served += handle_line(&engine, &mut mb, &line, &mut next_id, clock);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let now = clock.elapsed().as_millis() as u64;
                if let Some(batch) = mb.poll(now) {
                    served += answer_batch(&engine, &batch);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                reason = "eof";
                break;
            }
        }
    }
    // Graceful shutdown: requests already read from stdin are still
    // answered. On SIGTERM the channel may hold lines the loop never got
    // to; drain them first, then flush whatever sits in the micro-batcher.
    if reason == "sigterm" {
        while let Ok(line) = rx.try_recv() {
            served += handle_line(&engine, &mut mb, &line, &mut next_id, clock);
        }
    }
    if let Some(batch) = mb.flush() {
        served += answer_batch(&engine, &batch);
    }
    if reason == "eof" {
        // after SIGTERM the reader may be blocked in stdin.read forever;
        // join only on EOF, where it is guaranteed to have exited
        let _ = reader.join();
    }
    let mut top = BTreeMap::new();
    top.insert("op".to_string(), Json::Str("shutdown".to_string()));
    top.insert("reason".to_string(), Json::Str(reason.to_string()));
    top.insert("served".to_string(), Json::Num(served as f64));
    println!("{}", Json::Obj(top));
    eprintln!(
        "served {served} node prediction(s) in {:.3}s (backend busy {:.3}s, shutdown: {reason})",
        clock.elapsed().as_secs_f64(),
        engine.exec().exec_secs()
    );
    Ok(())
}

/// Post-run summary + optional curve export, shared by the serial and
/// sharded train paths.
fn report_metrics(
    metrics: &RunMetrics,
    dataset: &str,
    arch: &str,
    method: &str,
    args: &Args,
) -> Result<()> {
    let (bv, bt) = metrics.best_val_test().unwrap_or((f64::NAN, f64::NAN));
    println!(
        "done in {:.1}s — best val {:.4}, test@best-val {:.4}, final test {:.4}",
        metrics.total_secs(),
        bv,
        bt,
        metrics.final_test().unwrap_or(f64::NAN)
    );
    if let Some((ep, secs)) = metrics.reached_target {
        println!("target accuracy reached at epoch {ep} ({secs:.1}s)");
    }
    if let Some(out) = args.opt("out") {
        let label = format!("{dataset}_{arch}_{method}");
        metrics.curve_table(&label).save(Path::new(out), &label)?;
        println!("curve saved to {out}/{label}.csv");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let trainer = make_trainer(args)?;
    let e = trainer.evaluate()?;
    println!(
        "fresh-params exact eval: train_loss {:.4} train {:.4} val {:.4} test {:.4}",
        e.train_loss, e.train_acc, e.val_acc, e.test_acc
    );
    Ok(())
}

fn cmd_partition_stats(args: &Args) -> Result<()> {
    let id = DatasetId::parse(args.opt_or("dataset", "arxiv-sim"))
        .ok_or_else(|| anyhow!("unknown dataset"))?;
    let seed = args.opt_usize("seed").unwrap_or(0) as u64;
    let g = load(id, seed);
    let k = args.opt_usize("parts").unwrap_or_else(|| id.default_parts());
    let p = partition(&g.csr, &PartitionConfig::new(k, seed));
    let q = quality(&g.csr, &p.assign, k);
    println!(
        "{}: n={} |E|={} k={} edge_cut={} ({:.1}%) balance={:.3} part sizes [{}, {}]",
        id.name(),
        g.n(),
        g.csr.num_undirected_edges(),
        k,
        q.edge_cut,
        100.0 * q.cut_fraction,
        q.balance,
        q.min_part,
        q.max_part
    );
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("{:<14} {:>7} {:>9} {:>5} {:>4} {:>8} profile", "dataset", "nodes", "edges", "dx", "cls", "avg_deg");
    for &id in DatasetId::all() {
        let g = load(id, 0);
        println!(
            "{:<14} {:>7} {:>9} {:>5} {:>4} {:>8.1} {}",
            id.name(),
            g.n(),
            g.csr.num_undirected_edges(),
            g.d_x,
            g.n_class,
            2.0 * g.csr.num_undirected_edges() as f64 / g.n() as f64,
            id.profile()
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_programs(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let rt = lmc::runtime::Runtime::new(Path::new(dir))?;
    println!("{} programs in {}", rt.manifest.programs.len(), dir);
    for (name, p) in &rt.manifest.programs {
        println!(
            "  {:<44} kind={:<10} profile={:<9} arch={:<5} B={} H={} in={} out={}",
            name,
            p.kind,
            p.profile,
            p.arch,
            p.b,
            p.h,
            p.inputs.len(),
            p.outputs.len()
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_programs(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "`lmc programs` lists compiled PJRT artifacts; this build ships the \
         native backend only (rebuild with `--features pjrt`)"
    ))
}

/// CI perf gate: compare a freshly measured `BENCH_step.json` against the
/// committed `BENCH_baseline.json` (noise-banded; see util::perfgate),
/// print the markdown delta table (CI appends it to the job summary), and
/// exit nonzero when a gated phase regressed.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let bench_path = args.opt_or("bench", "../BENCH_step.json");
    let base_path = args.opt_or("baseline", "../BENCH_baseline.json");
    let read = |path: &str| -> Result<lmc::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        lmc::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
    };
    let baseline = read(base_path)?;
    let bench = read(bench_path)?;
    let report = lmc::util::perfgate::compare(&baseline, &bench)?;
    let md = report.markdown();
    println!("{md}");
    if let Some(path) = args.opt("summary") {
        std::fs::write(path, &md)?;
    }
    if !report.passed() {
        let failed: Vec<&str> = report
            .rows
            .iter()
            .filter(|r| !r.pass)
            .map(|r| r.name.as_str())
            .collect();
        return Err(anyhow!(
            "perf gate failed: {} regressed past {:.2}x of {base_path} \
             (if the slowdown is intended, regenerate the baseline with \
             `cargo bench --bench step_breakdown -- --write-baseline` and commit it)",
            failed.join(", "),
            report.max_slowdown
        ));
    }
    Ok(())
}

fn cmd_grad_error(args: &Args) -> Result<()> {
    let mut trainer = make_trainer(args)?;
    let warm = args.opt_usize("warm-epochs").unwrap_or(3);
    let rep = grad_check::measure_after_warmup(&mut trainer, warm)?;
    println!(
        "{} / {} / {} after {} warm epochs:",
        trainer.cfg.dataset.name(),
        trainer.cfg.arch,
        trainer.cfg.method.name(),
        warm
    );
    for (l, e) in rep.per_layer.iter().enumerate() {
        println!("  layer {}: rel err {:.4}", l + 1, e);
    }
    println!("  overall: {:.4}", rep.overall);
    Ok(())
}
