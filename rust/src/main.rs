//! `lmc` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   train             train one configuration (flags or --config file)
//!   eval              exact full-graph evaluation of a fresh model
//!   partition-stats   METIS-substitute quality report for a dataset
//!   datasets          list datasets and their stats
//!   programs          list compiled artifact programs (pjrt builds)
//!   grad-error        per-layer mini-batch gradient error (Fig. 3 point)
//!   bench-gate        diff BENCH_step.json vs BENCH_baseline.json and fail
//!                     on a gated-phase slowdown (CI perf-gate job)
//!   experiment <id>   regenerate a paper table/figure (table1, table2,
//!                     table3, table6, table7, table8, table9, fig2, fig3,
//!                     fig4, fig5, sharded, all)

use std::path::Path;

use anyhow::{anyhow, Result};

use lmc::backend::make_executor;
use lmc::config::RunConfig;
use lmc::coordinator::{grad_check, RunMetrics, ShardedTrainer, Trainer};
use lmc::graph::{load, DatasetId};
use lmc::partition::{partition, quality::quality, PartitionConfig};
use lmc::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "partition-stats" => cmd_partition_stats(args),
        "datasets" => cmd_datasets(),
        "programs" => cmd_programs(args),
        "grad-error" => cmd_grad_error(args),
        "bench-gate" => cmd_bench_gate(args),
        "experiment" => lmc::experiments::dispatch(args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}' (try `lmc help`)")),
    }
}

const HELP: &str = "\
lmc — LMC (ICLR 2023) reproduction: subgraph-wise GNN training with local
message compensation. rust coordinator + pluggable execution backends
(native sparse CPU by default; AOT JAX/Pallas PJRT with --features pjrt).

usage: lmc <subcommand> [--flags]

subcommands:
  train            --dataset D --arch gcn|gcnii --method lmc|gas|fm|cluster|gd
                   [--backend native|pjrt] [--epochs N] [--lr F]
                   [--clusters-per-batch C] [--parts K]
                   [--shards S] [--sync-every K] [--sync-mode avg|hist]
                   [--beta-alpha F] [--beta-score x2|2x-x2|x|1|sinx]
                   [--target-acc F] [--config file.toml] [--seed N] [--verbose]
  eval             exact inference with fresh params (pipeline smoke test)
  partition-stats  --dataset D [--parts K] [--seed N]
  datasets         list registered datasets
  programs         list artifact programs (--artifacts DIR; pjrt builds only)
  grad-error       --dataset D --method M [--warm-epochs N]
  bench-gate       [--bench ../BENCH_step.json] [--baseline ../BENCH_baseline.json]
                   [--summary FILE]   diff gated phases, exit 1 on regression
  experiment ID    table1|table2|table3|table6|table7|table8|table9|
                   fig2|fig3|fig4|fig5|sharded|all   [--out results/]
";

fn make_trainer(args: &Args) -> Result<Trainer> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let exec = make_executor(&cfg)?;
    Trainer::new(exec, cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_cli(args)?;
    let exec = make_executor(&cfg)?;
    if cfg.shards > 1 {
        let mut st = ShardedTrainer::new(exec, cfg)?;
        println!(
            "training {} / {} / {} on {} backend — {} nodes, {} shards, sync {} every {} epoch(s), {} epochs",
            st.cfg.dataset.name(),
            st.cfg.arch,
            st.cfg.method.name(),
            st.exec.backend_name(),
            st.parent.n(),
            st.num_workers(),
            st.cfg.sync_mode.name(),
            st.cfg.sync_every.max(1),
            st.cfg.epochs
        );
        let metrics = st.run()?;
        return report_metrics(
            &metrics,
            st.cfg.dataset.name(),
            &st.cfg.arch,
            st.cfg.method.name(),
            args,
        );
    }
    let mut trainer = Trainer::new(exec, cfg)?;
    println!(
        "training {} / {} / {} on {} backend — {} nodes, {} clusters, {} epochs",
        trainer.cfg.dataset.name(),
        trainer.cfg.arch,
        trainer.cfg.method.name(),
        trainer.exec.backend_name(),
        trainer.graph.n(),
        trainer.clusters.len(),
        trainer.cfg.epochs
    );
    let metrics = trainer.run()?;
    report_metrics(
        &metrics,
        trainer.cfg.dataset.name(),
        &trainer.cfg.arch,
        trainer.cfg.method.name(),
        args,
    )
}

/// Post-run summary + optional curve export, shared by the serial and
/// sharded train paths.
fn report_metrics(
    metrics: &RunMetrics,
    dataset: &str,
    arch: &str,
    method: &str,
    args: &Args,
) -> Result<()> {
    let (bv, bt) = metrics.best_val_test().unwrap_or((f64::NAN, f64::NAN));
    println!(
        "done in {:.1}s — best val {:.4}, test@best-val {:.4}, final test {:.4}",
        metrics.total_secs(),
        bv,
        bt,
        metrics.final_test().unwrap_or(f64::NAN)
    );
    if let Some((ep, secs)) = metrics.reached_target {
        println!("target accuracy reached at epoch {ep} ({secs:.1}s)");
    }
    if let Some(out) = args.opt("out") {
        let label = format!("{dataset}_{arch}_{method}");
        metrics.curve_table(&label).save(Path::new(out), &label)?;
        println!("curve saved to {out}/{label}.csv");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let trainer = make_trainer(args)?;
    let e = trainer.evaluate()?;
    println!(
        "fresh-params exact eval: train_loss {:.4} train {:.4} val {:.4} test {:.4}",
        e.train_loss, e.train_acc, e.val_acc, e.test_acc
    );
    Ok(())
}

fn cmd_partition_stats(args: &Args) -> Result<()> {
    let id = DatasetId::parse(args.opt_or("dataset", "arxiv-sim"))
        .ok_or_else(|| anyhow!("unknown dataset"))?;
    let seed = args.opt_usize("seed").unwrap_or(0) as u64;
    let g = load(id, seed);
    let k = args.opt_usize("parts").unwrap_or_else(|| id.default_parts());
    let p = partition(&g.csr, &PartitionConfig::new(k, seed));
    let q = quality(&g.csr, &p.assign, k);
    println!(
        "{}: n={} |E|={} k={} edge_cut={} ({:.1}%) balance={:.3} part sizes [{}, {}]",
        id.name(),
        g.n(),
        g.csr.num_undirected_edges(),
        k,
        q.edge_cut,
        100.0 * q.cut_fraction,
        q.balance,
        q.min_part,
        q.max_part
    );
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("{:<14} {:>7} {:>9} {:>5} {:>4} {:>8} profile", "dataset", "nodes", "edges", "dx", "cls", "avg_deg");
    for &id in DatasetId::all() {
        let g = load(id, 0);
        println!(
            "{:<14} {:>7} {:>9} {:>5} {:>4} {:>8.1} {}",
            id.name(),
            g.n(),
            g.csr.num_undirected_edges(),
            g.d_x,
            g.n_class,
            2.0 * g.csr.num_undirected_edges() as f64 / g.n() as f64,
            id.profile()
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_programs(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let rt = lmc::runtime::Runtime::new(Path::new(dir))?;
    println!("{} programs in {}", rt.manifest.programs.len(), dir);
    for (name, p) in &rt.manifest.programs {
        println!(
            "  {:<44} kind={:<10} profile={:<9} arch={:<5} B={} H={} in={} out={}",
            name,
            p.kind,
            p.profile,
            p.arch,
            p.b,
            p.h,
            p.inputs.len(),
            p.outputs.len()
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_programs(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "`lmc programs` lists compiled PJRT artifacts; this build ships the \
         native backend only (rebuild with `--features pjrt`)"
    ))
}

/// CI perf gate: compare a freshly measured `BENCH_step.json` against the
/// committed `BENCH_baseline.json` (noise-banded; see util::perfgate),
/// print the markdown delta table (CI appends it to the job summary), and
/// exit nonzero when a gated phase regressed.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let bench_path = args.opt_or("bench", "../BENCH_step.json");
    let base_path = args.opt_or("baseline", "../BENCH_baseline.json");
    let read = |path: &str| -> Result<lmc::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
        lmc::util::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
    };
    let baseline = read(base_path)?;
    let bench = read(bench_path)?;
    let report = lmc::util::perfgate::compare(&baseline, &bench)?;
    let md = report.markdown();
    println!("{md}");
    if let Some(path) = args.opt("summary") {
        std::fs::write(path, &md)?;
    }
    if !report.passed() {
        let failed: Vec<&str> = report
            .rows
            .iter()
            .filter(|r| !r.pass)
            .map(|r| r.name.as_str())
            .collect();
        return Err(anyhow!(
            "perf gate failed: {} regressed past {:.2}x of {base_path} \
             (if the slowdown is intended, regenerate the baseline with \
             `cargo bench --bench step_breakdown -- --write-baseline` and commit it)",
            failed.join(", "),
            report.max_slowdown
        ));
    }
    Ok(())
}

fn cmd_grad_error(args: &Args) -> Result<()> {
    let mut trainer = make_trainer(args)?;
    let warm = args.opt_usize("warm-epochs").unwrap_or(3);
    let rep = grad_check::measure_after_warmup(&mut trainer, warm)?;
    println!(
        "{} / {} / {} after {} warm epochs:",
        trainer.cfg.dataset.name(),
        trainer.cfg.arch,
        trainer.cfg.method.name(),
        warm
    );
    for (l, e) in rep.per_layer.iter().enumerate() {
        println!("  layer {}: rel err {:.4}", l + 1, e);
    }
    println!("  overall: {:.4}", rep.overall);
    Ok(())
}
