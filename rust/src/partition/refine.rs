//! Boundary FM/KL refinement: greedily move boundary nodes to the adjacent
//! part with the largest edge-cut gain, subject to a balance constraint.

use super::WGraph;
use crate::util::rng::Rng;

/// One refinement driver: `passes` sweeps over boundary nodes.
pub(crate) fn refine(
    g: &WGraph,
    assign: &mut [u32],
    k: usize,
    imbalance: f64,
    passes: usize,
    rng: &mut Rng,
) {
    if k <= 1 || g.n == 0 {
        return;
    }
    let total = g.total_node_weight();
    let max_w = ((total as f64 / k as f64) * (1.0 + imbalance)).ceil() as u64;
    let mut weights = vec![0u64; k];
    for u in 0..g.n {
        weights[assign[u] as usize] += g.nw[u] as u64;
    }
    let mut order: Vec<u32> = (0..g.n as u32).collect();
    // reusable per-part connectivity scratch
    let mut conn = vec![0i64; k];
    let mut touched: Vec<u32> = Vec::new();
    for _ in 0..passes {
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        for &u32u in &order {
            let u = u32u as usize;
            let from = assign[u] as usize;
            // connectivity to each adjacent part
            touched.clear();
            let mut is_boundary = false;
            for (v, w) in g.adj(u) {
                let p = assign[v as usize] as usize;
                if conn[p] == 0 {
                    touched.push(p as u32);
                }
                conn[p] += w as i64;
                if p != from {
                    is_boundary = true;
                }
            }
            if is_boundary {
                let here = conn[from];
                let mut best_part = from;
                let mut best_gain = 0i64;
                for &p in &touched {
                    let p = p as usize;
                    if p == from {
                        continue;
                    }
                    let gain = conn[p] - here;
                    // never empty the source part
                    let fits = weights[p] + g.nw[u] as u64 <= max_w
                        && weights[from] > g.nw[u] as u64;
                    // strictly positive gain, or zero-gain move that improves balance
                    let improves_balance = gain == 0 && weights[p] + (g.nw[u] as u64) < weights[from];
                    if fits && (gain > best_gain || (improves_balance && best_gain <= 0 && best_part == from)) {
                        best_part = p;
                        best_gain = gain.max(best_gain);
                    }
                }
                if best_part != from {
                    assign[u] = best_part as u32;
                    weights[from] -= g.nw[u] as u64;
                    weights[best_part] += g.nw[u] as u64;
                    moved += 1;
                }
            }
            for &p in &touched {
                conn[p as usize] = 0;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::partition::quality::edge_cut;

    fn wgraph(csr: &Csr) -> WGraph {
        WGraph {
            n: csr.n,
            offsets: csr.offsets.clone(),
            nbr: csr.neighbors.clone(),
            ew: vec![1; csr.neighbors.len()],
            nw: vec![1; csr.n],
        }
    }

    #[test]
    fn refine_reduces_cut_on_two_cliques() {
        // two 6-cliques joined by one edge; a scrambled assignment must
        // refine to (nearly) the natural split.
        let mut edges = Vec::new();
        for a in 0..6u32 {
            for b in (a + 1)..6 {
                edges.push((a, b));
                edges.push((a + 6, b + 6));
            }
        }
        edges.push((0, 6));
        let csr = Csr::from_edges(12, &edges);
        let g = wgraph(&csr);
        let mut assign: Vec<u32> = vec![0, 1, 0, 1, 0, 1, 1, 0, 1, 0, 1, 0];
        let before = edge_cut(&csr, &assign);
        let mut rng = Rng::new(4);
        refine(&g, &mut assign, 2, 0.2, 8, &mut rng);
        let after = edge_cut(&csr, &assign);
        assert!(after < before, "cut {before} -> {after}");
        assert!(after <= 3, "cut after refine: {after}");
    }

    #[test]
    fn refine_respects_balance() {
        let mut rng = Rng::new(5);
        let csr = crate::graph::random_graph(100, 0.1, &mut rng);
        let g = wgraph(&csr);
        let mut assign: Vec<u32> = (0..100).map(|i| (i % 4) as u32).collect();
        refine(&g, &mut assign, 4, 0.1, 6, &mut rng);
        let mut w = [0u64; 4];
        for &a in &assign {
            w[a as usize] += 1;
        }
        let max = *w.iter().max().unwrap() as f64;
        assert!(max <= 25.0 * 1.1 + 1.0, "weights {w:?}");
    }
}
