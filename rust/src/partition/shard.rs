//! Shard-local graph views for partition-parallel training.
//!
//! A `ShardView` is the per-trainer slice of a k-way partition: the shard's
//! own ("core") nodes, the 1-hop out-of-shard boundary ("halo") nodes, and a
//! shard-local CSR over core + halo. The view keeps every parent edge with
//! at least one core endpoint — core-core edges live in exactly one shard,
//! cut edges appear in both incident shards (core→halo on each side), and
//! halo-halo edges are dropped (they belong to some other shard's core).
//! That makes the union of all views' edge sets round-trip the parent edge
//! set exactly (`prop_shard_local_csr_roundtrips_parent_edges`).
//!
//! [`shard_graph`] materializes the attributed worker [`Graph`] the sharded
//! coordinator trains on (see `coordinator::sharded`).

use crate::graph::{Csr, Graph};

/// Split value assigned to halo rows in a worker graph: halo nodes are
/// visible for aggregation and history compensation but belong to *no*
/// train/val/test set of the shard — a dedicated sentinel (train = 0,
/// val = 1, test = 2), so no label is optimized by more than one shard and
/// an accidental per-worker evaluation cannot count halo rows as real
/// val/test examples (the backends' split accounting reserves a slot for
/// this sentinel).
pub const HALO_SPLIT: u8 = 3;

/// One shard's local slice of the parent graph.
#[derive(Clone, Debug)]
pub struct ShardView {
    pub shard_id: usize,
    /// Sorted global ids this shard owns ("core" nodes).
    pub nodes: Vec<u32>,
    /// Sorted global ids of 1-hop out-of-shard neighbors ("halo").
    pub halo: Vec<u32>,
    /// Shard-local CSR over `nodes.len() + halo.len()` locals, core ids
    /// first: every parent edge with >= 1 core endpoint, halo-halo dropped.
    pub csr: Csr,
}

impl ShardView {
    pub fn n_core(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_local(&self) -> usize {
        self.nodes.len() + self.halo.len()
    }

    /// Global id of shard-local node `local` (core ids come first).
    #[inline]
    pub fn global_of(&self, local: u32) -> u32 {
        let l = local as usize;
        if l < self.nodes.len() {
            self.nodes[l]
        } else {
            self.halo[l - self.nodes.len()]
        }
    }

    /// Shard-local id of global node `g`, if visible in this shard.
    pub fn local_of(&self, g: u32) -> Option<u32> {
        if let Ok(i) = self.nodes.binary_search(&g) {
            return Some(i as u32);
        }
        self.halo
            .binary_search(&g)
            .ok()
            .map(|i| (self.nodes.len() + i) as u32)
    }
}

/// Build the per-shard local views of `csr` under the k-way `assign`ment.
/// Empty shards are skipped, so the result may hold fewer than `k` views;
/// every node is core in exactly one returned view.
pub fn shard_views(csr: &Csr, assign: &[u32], k: usize) -> Vec<ShardView> {
    assert_eq!(assign.len(), csr.n, "assignment must cover every node");
    let mut views = Vec::with_capacity(k);
    for s in 0..k {
        let sid = s as u32;
        let nodes: Vec<u32> =
            (0..csr.n as u32).filter(|&u| assign[u as usize] == sid).collect();
        if nodes.is_empty() {
            continue;
        }
        let mut halo: Vec<u32> = Vec::new();
        let mut seen = vec![false; csr.n];
        for &u in &nodes {
            for &v in csr.neighbors(u as usize) {
                if assign[v as usize] != sid && !seen[v as usize] {
                    seen[v as usize] = true;
                    halo.push(v);
                }
            }
        }
        halo.sort_unstable();
        let nb = nodes.len();
        let mut pos = vec![u32::MAX; csr.n];
        for (i, &u) in nodes.iter().enumerate() {
            pos[u as usize] = i as u32;
        }
        for (i, &u) in halo.iter().enumerate() {
            pos[u as usize] = (nb + i) as u32;
        }
        // Emit each kept undirected edge once; `Csr::from_edges`
        // symmetrizes. Core-core from the lower local endpoint, core-halo
        // always from the core side (the halo side is never iterated).
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for &u in &nodes {
            let lu = pos[u as usize];
            for &v in csr.neighbors(u as usize) {
                let lv = pos[v as usize];
                debug_assert!(lv != u32::MAX, "core neighbor must be core or halo");
                if (lv as usize) >= nb || lu < lv {
                    edges.push((lu, lv));
                }
            }
        }
        let local = Csr::from_edges(nb + halo.len(), &edges);
        views.push(ShardView { shard_id: s, nodes, halo, csr: local });
    }
    views
}

/// Materialize the attributed worker [`Graph`] for `view`: features, labels
/// and split copied from the parent, GCN normalization recomputed on the
/// shard-local topology, halo rows demoted to [`HALO_SPLIT`] so they are
/// never trained (or double-counted) by this shard.
pub fn shard_graph(parent: &Graph, view: &ShardView) -> Graph {
    let nl = view.n_local();
    let d = parent.d_x;
    let mut features = Vec::with_capacity(nl * d);
    let mut labels = Vec::with_capacity(nl);
    let mut split = Vec::with_capacity(nl);
    let mut graph_id = Vec::with_capacity(nl);
    for &g in view.nodes.iter().chain(view.halo.iter()) {
        let g = g as usize;
        features.extend_from_slice(parent.feature_row(g));
        labels.push(parent.labels[g]);
        split.push(parent.split[g]);
        graph_id.push(parent.graph_id[g]);
    }
    for sp in split[view.n_core()..].iter_mut() {
        *sp = HALO_SPLIT;
    }
    let mut g = Graph::new(view.csr.clone(), d, parent.n_class, features, labels, split);
    g.graph_id = graph_id;
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph() -> Csr {
        // 0-1-2-3-4-5
        Csr::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)])
    }

    #[test]
    fn views_split_a_path() {
        let csr = path_graph();
        let assign = vec![0, 0, 0, 1, 1, 1];
        let views = shard_views(&csr, &assign, 2);
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].nodes, vec![0, 1, 2]);
        assert_eq!(views[0].halo, vec![3]);
        assert_eq!(views[1].nodes, vec![3, 4, 5]);
        assert_eq!(views[1].halo, vec![2]);
        // shard 0 locals: 0,1,2 core; 3 (global 3) halo — edges 0-1, 1-2, 2-3
        assert_eq!(views[0].csr.num_undirected_edges(), 3);
        assert!(views[0].csr.has_edge(2, 3));
        assert_eq!(views[0].global_of(3), 3);
        assert_eq!(views[0].local_of(3), Some(3));
        assert_eq!(views[0].local_of(4), None);
        assert_eq!(views[1].global_of(3), 2);
    }

    #[test]
    fn single_shard_view_is_the_whole_graph() {
        let csr = path_graph();
        let assign = vec![0; 6];
        let views = shard_views(&csr, &assign, 1);
        assert_eq!(views.len(), 1);
        assert_eq!(views[0].nodes, (0..6u32).collect::<Vec<_>>());
        assert!(views[0].halo.is_empty());
        assert_eq!(views[0].csr, csr);
    }

    #[test]
    fn empty_shards_are_skipped() {
        let csr = path_graph();
        let assign = vec![0, 0, 0, 2, 2, 2]; // shard 1 empty
        let views = shard_views(&csr, &assign, 3);
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].shard_id, 0);
        assert_eq!(views[1].shard_id, 2);
    }

    #[test]
    fn shard_graph_demotes_halo_split() {
        let csr = path_graph();
        let parent = Graph::new(
            csr,
            2,
            2,
            (0..12).map(|x| x as f32).collect(),
            vec![0, 0, 0, 1, 1, 1],
            vec![0, 0, 0, 0, 1, 2],
        );
        let views = shard_views(&parent.csr, &[0, 0, 0, 1, 1, 1], 2);
        let g0 = shard_graph(&parent, &views[0]);
        assert_eq!(g0.n(), 4);
        // core rows keep the parent split; the halo row (global 3, train in
        // the parent) is demoted so shard 0 never optimizes its label
        assert_eq!(g0.split, vec![0, 0, 0, HALO_SPLIT]);
        assert_eq!(g0.labels, vec![0, 0, 0, 1]);
        assert_eq!(&g0.features[..2], parent.feature_row(0));
        assert_eq!(&g0.features[6..8], parent.feature_row(3));
        // local normalization is recomputed on the shard topology
        assert_eq!(g0.self_w.len(), 4);
    }
}
