//! METIS substitute: multilevel k-way graph partitioning (DESIGN.md §5).
//!
//! The paper (and CLUSTER-GCN / GAS) relies on METIS to produce clusters
//! with few cut edges; LMC only needs that property, not METIS itself.
//! Pipeline: heavy-edge-matching coarsening -> greedy region-growing initial
//! partition on the coarsest graph -> uncoarsening with boundary
//! Kernighan-Lin/FM refinement under a balance constraint.

pub mod quality;
pub mod refine;
pub mod shard;

use crate::graph::Csr;
use crate::util::rng::Rng;

pub use quality::{balance, edge_cut, PartitionQuality};
pub use shard::{shard_graph, shard_views, ShardView, HALO_SPLIT};

/// A k-way node assignment.
#[derive(Clone, Debug)]
pub struct Partition {
    pub k: usize,
    pub assign: Vec<u32>,
}

impl Partition {
    /// Cluster membership lists, index = part id.
    pub fn clusters(&self) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.k];
        for (u, &p) in self.assign.iter().enumerate() {
            out[p as usize].push(u as u32);
        }
        out
    }

    /// Permutation laying parts out contiguously: perm[new] = old.
    pub fn contiguous_perm(&self) -> Vec<u32> {
        let mut perm = Vec::with_capacity(self.assign.len());
        for c in self.clusters() {
            perm.extend(c);
        }
        perm
    }
}

/// Internal weighted graph used across coarsening levels.
#[derive(Clone, Debug)]
pub(crate) struct WGraph {
    pub n: usize,
    pub offsets: Vec<u32>,
    pub nbr: Vec<u32>,
    pub ew: Vec<u32>, // edge weights (contracted multiplicity)
    pub nw: Vec<u32>, // node weights (contracted original nodes)
}

impl WGraph {
    fn from_csr(csr: &Csr) -> WGraph {
        WGraph {
            n: csr.n,
            offsets: csr.offsets.clone(),
            nbr: csr.neighbors.clone(),
            ew: vec![1; csr.neighbors.len()],
            nw: vec![1; csr.n],
        }
    }

    #[inline]
    pub fn adj(&self, u: usize) -> impl Iterator<Item = (u32, u32)> + '_ {
        let (s, e) = (self.offsets[u] as usize, self.offsets[u + 1] as usize);
        self.nbr[s..e].iter().copied().zip(self.ew[s..e].iter().copied())
    }

    pub fn total_node_weight(&self) -> u64 {
        self.nw.iter().map(|&w| w as u64).sum()
    }
}

/// Heavy-edge matching: each unmatched node matches its heaviest unmatched
/// neighbor. Returns (coarse graph, map fine -> coarse).
pub(crate) fn coarsen(g: &WGraph, rng: &mut Rng) -> (WGraph, Vec<u32>) {
    let n = g.n;
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; n];
    let mut coarse_id = vec![u32::MAX; n];
    let mut next = 0u32;
    for &u in &order {
        let u = u as usize;
        if matched[u] != u32::MAX {
            continue;
        }
        let mut best = u32::MAX;
        let mut best_w = 0u32;
        for (v, w) in g.adj(u) {
            if matched[v as usize] == u32::MAX && v as usize != u && w >= best_w {
                best = v;
                best_w = w;
            }
        }
        if best != u32::MAX {
            matched[u] = best;
            matched[best as usize] = u as u32;
            coarse_id[u] = next;
            coarse_id[best as usize] = next;
        } else {
            matched[u] = u as u32;
            coarse_id[u] = next;
        }
        next += 1;
    }
    let cn = next as usize;
    // aggregate edges
    let mut agg: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); cn];
    let mut nw = vec![0u32; cn];
    for u in 0..n {
        let cu = coarse_id[u];
        nw[cu as usize] += g.nw[u];
        for (v, w) in g.adj(u) {
            let cv = coarse_id[v as usize];
            if cv != cu {
                *agg[cu as usize].entry(cv).or_insert(0) += w;
            }
        }
    }
    let mut offsets = Vec::with_capacity(cn + 1);
    let mut nbr = Vec::new();
    let mut ew = Vec::new();
    offsets.push(0u32);
    for m in agg.iter() {
        let mut items: Vec<(u32, u32)> = m.iter().map(|(&v, &w)| (v, w)).collect();
        items.sort_unstable();
        for (v, w) in items {
            nbr.push(v);
            ew.push(w);
        }
        offsets.push(nbr.len() as u32);
    }
    (WGraph { n: cn, offsets, nbr, ew, nw }, coarse_id)
}

/// Greedy region growing: multi-source BFS growing all k regions
/// round-robin (lightest part grows next), so no part is starved. Growth is
/// capped at (1+imb)·target so a single region cannot swallow a whole
/// connected component (disconnected multi-graphs like ppi-sim); when every
/// reachable frontier is exhausted, the lightest part is re-seeded in
/// unassigned territory.
pub(crate) fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.n;
    let k = k.min(n.max(1));
    let total = g.total_node_weight();
    let cap = ((total as f64 / k as f64) * 1.1).ceil() as u64;
    let mut assign = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    // k distinct seeds
    let mut queues: Vec<std::collections::VecDeque<u32>> = Vec::with_capacity(k);
    let mut weights = vec![0u64; k];
    for (part, &seed) in order.iter().take(k).enumerate() {
        assign[seed as usize] = part as u32;
        weights[part] += g.nw[seed as usize] as u64;
        let mut q = std::collections::VecDeque::new();
        for (v, _) in g.adj(seed as usize) {
            q.push_back(v);
        }
        queues.push(q);
    }
    let mut fallback = k; // cursor into `order` for disconnected leftovers
    let mut assigned = k.min(n);
    while assigned < n {
        // grow the lightest part that can still grow
        let mut grew = false;
        let mut by_weight: Vec<usize> = (0..k).collect();
        by_weight.sort_by_key(|&p| weights[p]);
        'parts: for &p in &by_weight {
            if weights[p] >= cap {
                continue;
            }
            while let Some(u) = queues[p].pop_front() {
                let u = u as usize;
                if assign[u] != u32::MAX {
                    continue;
                }
                assign[u] = p as u32;
                weights[p] += g.nw[u] as u64;
                assigned += 1;
                for (v, _) in g.adj(u) {
                    if assign[v as usize] == u32::MAX {
                        queues[p].push_back(v);
                    }
                }
                grew = true;
                break 'parts;
            }
        }
        if !grew {
            // disconnected remainder: seed the lightest part somewhere new
            while fallback < n && assign[order[fallback] as usize] != u32::MAX {
                fallback += 1;
            }
            if fallback >= n {
                break;
            }
            let u = order[fallback] as usize;
            let p = (0..k).min_by_key(|&p| weights[p]).unwrap();
            assign[u] = p as u32;
            weights[p] += g.nw[u] as u64;
            assigned += 1;
            for (v, _) in g.adj(u) {
                if assign[v as usize] == u32::MAX {
                    queues[p].push_back(v);
                }
            }
        }
    }
    // leftovers: attach to the lightest adjacent part (or globally lightest)
    let mut weights = vec![0u64; k];
    for u in 0..n {
        if assign[u] != u32::MAX {
            weights[assign[u] as usize] += g.nw[u] as u64;
        }
    }
    for u in 0..n {
        if assign[u] == u32::MAX {
            let mut best = u32::MAX;
            let mut best_w = u64::MAX;
            for (v, _) in g.adj(u) {
                let p = assign[v as usize];
                if p != u32::MAX && weights[p as usize] < best_w {
                    best = p;
                    best_w = weights[p as usize];
                }
            }
            if best == u32::MAX {
                best = weights
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &w)| w)
                    .map(|(i, _)| i as u32)
                    .unwrap_or(0);
            }
            assign[u] = best;
            weights[best as usize] += g.nw[u] as u64;
        }
    }
    assign
}

#[derive(Clone, Debug)]
pub struct PartitionConfig {
    pub k: usize,
    /// Coarsening stops at this many nodes (>= 4k).
    pub coarsest: usize,
    /// Allowed imbalance, e.g. 0.1 = parts up to 1.1x average weight.
    pub imbalance: f64,
    /// Refinement passes per level.
    pub refine_passes: usize,
    pub seed: u64,
}

impl PartitionConfig {
    pub fn new(k: usize, seed: u64) -> Self {
        PartitionConfig {
            k,
            coarsest: (8 * k).max(64),
            imbalance: 0.15,
            refine_passes: 4,
            seed,
        }
    }
}

/// Multilevel k-way partition of `csr`.
pub fn partition(csr: &Csr, cfg: &PartitionConfig) -> Partition {
    let mut rng = Rng::new(cfg.seed);
    let k = cfg.k.max(1).min(csr.n.max(1));
    let mut levels: Vec<(WGraph, Vec<u32>)> = Vec::new(); // (fine graph, map fine->coarse)
    let mut g = WGraph::from_csr(csr);
    while g.n > cfg.coarsest {
        let (coarse, map) = coarsen(&g, &mut rng);
        // stop if coarsening stalls (e.g. star graphs)
        if coarse.n as f64 > g.n as f64 * 0.95 {
            break;
        }
        levels.push((g, map));
        g = coarse;
    }
    let mut assign = initial_partition(&g, k, &mut rng);
    refine::refine(&g, &mut assign, k, cfg.imbalance, cfg.refine_passes, &mut rng);
    // uncoarsen with refinement at every level
    while let Some((fine, map)) = levels.pop() {
        let mut fine_assign = vec![0u32; fine.n];
        for u in 0..fine.n {
            fine_assign[u] = assign[map[u] as usize];
        }
        assign = fine_assign;
        refine::refine(&fine, &mut assign, k, cfg.imbalance, cfg.refine_passes, &mut rng);
    }
    Partition { k, assign }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{random_graph, sbm, SbmSpec};

    #[test]
    fn partition_covers_all_nodes_balanced() {
        let mut rng = Rng::new(1);
        let csr = random_graph(500, 0.02, &mut rng);
        let p = partition(&csr, &PartitionConfig::new(8, 3));
        assert_eq!(p.assign.len(), 500);
        assert!(p.assign.iter().all(|&a| (a as usize) < 8));
        let sizes: Vec<usize> = p.clusters().iter().map(|c| c.len()).collect();
        let max = *sizes.iter().max().unwrap() as f64;
        let avg = 500.0 / 8.0;
        assert!(max <= avg * 1.6, "max part size {max} vs avg {avg}");
        assert!(sizes.iter().all(|&s| s > 0), "empty part: {sizes:?}");
    }

    #[test]
    fn partition_beats_random_on_sbm() {
        // On a homophilous SBM, multilevel partitioning must cut far fewer
        // edges than a random assignment (the property LMC needs).
        let g = sbm(&SbmSpec {
            n: 800,
            n_class: 8,
            d_x: 4,
            avg_deg_in: 8.0,
            avg_deg_out: 2.0,
            signal: 0.3,
            train_frac: 0.3,
            val_frac: 0.2,
            seed: 11,
            mu_seed: None,
        });
        let p = partition(&g.csr, &PartitionConfig::new(8, 5));
        let cut = edge_cut(&g.csr, &p.assign);
        let mut rng = Rng::new(7);
        let rand_assign: Vec<u32> = (0..g.n()).map(|_| rng.below(8) as u32).collect();
        let rand_cut = edge_cut(&g.csr, &rand_assign);
        assert!(
            (cut as f64) < 0.7 * rand_cut as f64,
            "cut {cut} vs random {rand_cut}"
        );
    }

    #[test]
    fn contiguous_perm_is_permutation() {
        let mut rng = Rng::new(2);
        let csr = random_graph(200, 0.03, &mut rng);
        let p = partition(&csr, &PartitionConfig::new(5, 1));
        let mut perm = p.contiguous_perm();
        perm.sort_unstable();
        assert_eq!(perm, (0..200u32).collect::<Vec<_>>());
    }

    #[test]
    fn handles_degenerate_graphs() {
        // empty graph
        let csr = Csr::from_edges(10, &[]);
        let p = partition(&csr, &PartitionConfig::new(3, 0));
        assert_eq!(p.assign.len(), 10);
        // k = 1
        let mut rng = Rng::new(3);
        let csr = random_graph(50, 0.1, &mut rng);
        let p = partition(&csr, &PartitionConfig::new(1, 0));
        assert!(p.assign.iter().all(|&a| a == 0));
    }
}
