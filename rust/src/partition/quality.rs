//! Partition quality metrics: edge cut, balance, cluster-locality stats.

use crate::graph::Csr;

/// Number of undirected edges crossing parts.
pub fn edge_cut(csr: &Csr, assign: &[u32]) -> usize {
    let mut cut = 0usize;
    for u in 0..csr.n {
        for &v in csr.neighbors(u) {
            if (v as usize) > u && assign[u] != assign[v as usize] {
                cut += 1;
            }
        }
    }
    cut
}

/// max part size / average part size (1.0 = perfectly balanced).
pub fn balance(assign: &[u32], k: usize) -> f64 {
    if assign.is_empty() || k == 0 {
        return 1.0;
    }
    let mut sizes = vec![0usize; k];
    for &a in assign {
        sizes[a as usize] += 1;
    }
    let max = *sizes.iter().max().unwrap() as f64;
    max / (assign.len() as f64 / k as f64)
}

#[derive(Debug, Clone)]
pub struct PartitionQuality {
    pub k: usize,
    pub edge_cut: usize,
    pub total_edges: usize,
    pub cut_fraction: f64,
    pub balance: f64,
    pub min_part: usize,
    pub max_part: usize,
}

pub fn quality(csr: &Csr, assign: &[u32], k: usize) -> PartitionQuality {
    let cut = edge_cut(csr, assign);
    let total = csr.num_undirected_edges();
    let mut sizes = vec![0usize; k];
    for &a in assign {
        sizes[a as usize] += 1;
    }
    PartitionQuality {
        k,
        edge_cut: cut,
        total_edges: total,
        cut_fraction: if total > 0 { cut as f64 / total as f64 } else { 0.0 },
        balance: balance(assign, k),
        min_part: sizes.iter().copied().min().unwrap_or(0),
        max_part: sizes.iter().copied().max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_and_balance() {
        let csr = Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let assign = vec![0u32, 0, 1, 1];
        assert_eq!(edge_cut(&csr, &assign), 1);
        assert!((balance(&assign, 2) - 1.0).abs() < 1e-9);
        let q = quality(&csr, &assign, 2);
        assert_eq!(q.edge_cut, 1);
        assert!((q.cut_fraction - 1.0 / 3.0).abs() < 1e-9);
    }
}
