//! Partition-parallel sharded training: the first multi-trainer control
//! plane over the [`Executor`](crate::backend::Executor) seam.
//!
//! The parent graph is partitioned into `cfg.shards` top-level shards with
//! the METIS-substitute partitioner; each shard becomes a full worker — its
//! own [`Trainer`] (executor handle, parameters, Adam state, history store,
//! step workspace, subgraph cache) over a shard-local graph view
//! ([`crate::partition::ShardView`]): the shard's core nodes plus the 1-hop
//! halo of cut neighbors, GCN-renormalized locally, halo rows demoted out
//! of the train split. Workers run their epochs concurrently on the rayon
//! pool and the coordinator synchronizes them at epoch barriers with a
//! pluggable [`SyncMode`]:
//!
//!   * [`SyncMode::Average`] — synchronous parameter averaging (weighted by
//!     each shard's labeled-train count) every `cfg.sync_every` epochs;
//!     per-worker Adam moments stay local (local-SGD style).
//!   * [`SyncMode::HistoryExchange`] — additionally exchanges boundary
//!     history rows every epoch: each worker's halo H/V rows are overwritten
//!     with the owning shard's fresh core rows, so LMC's compensation sees
//!     cross-shard neighbors ("Provably Convergent Subgraph-wise Sampling"-
//!     style staleness tolerance). Parameter averaging still runs every
//!     `sync_every` epochs, which can therefore be larger.
//!
//! All synchronization happens on the coordinator thread in fixed shard
//! order, so results are bit-deterministic regardless of worker scheduling
//! (`sharded_runs_are_deterministic_under_scheduling`), and a single-shard
//! run degenerates to the plain serial trainer bit-for-bit
//! (`shards_one_is_bit_identical_to_plain_trainer`).

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use anyhow::{anyhow, Result};
use rayon::prelude::*;

use super::exact::EvalResult;
use super::metrics::RunMetrics;
use super::params::Params;
use super::trainer::{record_epoch, EpochObs, StepStats, Trainer};
use crate::backend::{Executor, ModelSpec};
use crate::checkpoint;
use crate::config::RunConfig;
use crate::graph::{load, Graph};
use crate::partition::{partition, shard_graph, shard_views, PartitionConfig, ShardView};
use crate::util::failpoint;
use crate::util::Stopwatch;

/// How sharded workers are synchronized at epoch barriers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Synchronous parameter averaging every `sync_every` epochs.
    Average,
    /// Boundary history-row exchange every epoch + parameter averaging
    /// every `sync_every` epochs (staleness-tolerant: LMC compensation
    /// covers the drift between averages).
    HistoryExchange,
}

impl SyncMode {
    pub fn parse(s: &str) -> Option<SyncMode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "avg" | "average" | "sync" => SyncMode::Average,
            "hist" | "history" | "history-exchange" | "async" => SyncMode::HistoryExchange,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::Average => "avg",
            SyncMode::HistoryExchange => "hist",
        }
    }
}

/// One shard's worker: a full [`Trainer`] over the shard-local graph plus
/// the row-level routing metadata the parameter/history bus needs.
///
/// Workers hold their own handle to one shared executor — executors are
/// stateless apart from a telemetry timer, which under concurrent workers
/// reports the wall-clock union of busy intervals (see
/// `NativeExecutor::time`), never affecting results.
pub struct WorkerState {
    /// Index into [`ShardedTrainer::workers`] (== index into `views`).
    pub id: usize,
    /// The reusable serial training core, over the shard-local graph.
    pub trainer: Trainer,
    /// Worker-internal node id -> parent-global node id (composes the
    /// trainer's cluster-contiguous relabeling with the shard view map).
    pub global_of: Vec<u32>,
}

/// One shard-to-shard boundary batch of the exchange plan: history rows
/// `src_rows` of `src_worker` (its core copies) are copied into rows
/// `dst_rows` of `dst_worker` (its halo copies of the same global nodes).
#[derive(Clone, Debug)]
struct ExchangeGroup {
    src_worker: u32,
    dst_worker: u32,
    src_rows: Vec<u32>,
    dst_rows: Vec<u32>,
}

pub struct ShardedTrainer {
    pub exec: Arc<dyn Executor>,
    pub cfg: RunConfig,
    /// The unpartitioned parent graph (exact evaluation runs here).
    pub parent: Arc<Graph>,
    /// Resolved (profile, arch) — identical across workers.
    pub model: ModelSpec,
    pub workers: Vec<WorkerState>,
    /// Shard views aligned with `workers`.
    pub views: Vec<ShardView>,
    /// Precomputed boundary-row routing, grouped per (src, dst) shard pair
    /// in deterministic order.
    plan: Vec<ExchangeGroup>,
    pub metrics: RunMetrics,
    epochs_done: usize,
}

impl ShardedTrainer {
    pub fn new(exec: Arc<dyn Executor>, cfg: RunConfig) -> Result<ShardedTrainer> {
        let raw = load(cfg.dataset, cfg.seed);
        // clamp to [1, n]: more shards than nodes can never be non-empty,
        // and an absurd config value must not turn the O(shards · n) view
        // construction into a hang
        let s = cfg.shards.clamp(1, raw.n().max(1));
        let assign: Vec<u32> = if s == 1 {
            vec![0; raw.n()]
        } else {
            partition(&raw.csr, &PartitionConfig::new(s, cfg.seed ^ 0x5AAD)).assign
        };
        let views = shard_views(&raw.csr, &assign, s);
        if views.is_empty() {
            return Err(anyhow!("sharding produced no non-empty shards"));
        }
        let mut workers: Vec<WorkerState> = Vec::with_capacity(views.len());
        for (wid, view) in views.iter().enumerate() {
            let wg = shard_graph(&raw, view);
            let mut wcfg = cfg.clone();
            // worker 0 keeps the parent seed so `shards = 1` is bit-identical
            // to the plain Trainer; later workers get decorrelated streams
            wcfg.seed = cfg.seed ^ (wid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let trainer = Trainer::from_parent_graph(exec.clone(), wcfg, wg)?;
            let global_of: Vec<u32> =
                trainer.orig_of.iter().map(|&old| view.global_of(old)).collect();
            workers.push(WorkerState { id: wid, trainer, global_of });
        }
        // Common initialization: data-parallel training starts every worker
        // from worker 0's Glorot draw (which is the serial trainer's draw,
        // since worker 0 keeps the parent seed). Averaging independent
        // inits would shrink the weights toward zero instead.
        if workers.len() > 1 {
            let init = workers[0].trainer.params.clone();
            for w in workers.iter_mut().skip(1) {
                for (dst, src) in w.trainer.params.tensors.iter_mut().zip(&init.tensors) {
                    dst.data.copy_from_slice(&src.data);
                }
            }
        }

        // Ownership maps: the worker (and its internal row) where each
        // global node is a *core* node. Every node is core in exactly one
        // shard, so both maps are total.
        let n = raw.n();
        let mut owner_worker = vec![u32::MAX; n];
        let mut owner_row = vec![u32::MAX; n];
        for (wid, w) in workers.iter().enumerate() {
            let nc = views[wid].n_core();
            for (row, &old) in w.trainer.orig_of.iter().enumerate() {
                if (old as usize) < nc {
                    let g = w.global_of[row] as usize;
                    owner_worker[g] = wid as u32;
                    owner_row[g] = row as u32;
                }
            }
        }
        // Exchange plan: route every worker's halo row to the owning
        // worker's core row, batched per (src, dst) pair. BTreeMap keys the
        // groups deterministically; rows within a group follow the dst
        // worker's internal row order.
        let mut groups: BTreeMap<(u32, u32), (Vec<u32>, Vec<u32>)> = BTreeMap::new();
        for (wid, w) in workers.iter().enumerate() {
            let nc = views[wid].n_core();
            for (row, &old) in w.trainer.orig_of.iter().enumerate() {
                if (old as usize) >= nc {
                    let g = w.global_of[row] as usize;
                    let (src_w, src_r) = (owner_worker[g], owner_row[g]);
                    debug_assert!(src_w != u32::MAX, "halo node {g} has no owner");
                    let e = groups.entry((src_w, wid as u32)).or_default();
                    e.0.push(src_r);
                    e.1.push(row as u32);
                }
            }
        }
        let plan = groups
            .into_iter()
            .map(|((src_worker, dst_worker), (src_rows, dst_rows))| ExchangeGroup {
                src_worker,
                dst_worker,
                src_rows,
                dst_rows,
            })
            .collect();

        let model = workers[0].trainer.model.clone();
        Ok(ShardedTrainer {
            exec,
            cfg,
            parent: Arc::new(raw),
            model,
            workers,
            views,
            plan,
            metrics: RunMetrics::default(),
            epochs_done: 0,
        })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Total boundary history rows routed per exchange round.
    pub fn boundary_rows(&self) -> usize {
        self.plan.iter().map(|g| g.src_rows.len()).sum()
    }

    /// One sharded epoch: every worker trains one epoch concurrently on the
    /// rayon pool, then the coordinator synchronizes at the barrier.
    /// Returns labeled-weighted aggregate stats across shards.
    ///
    /// When `cfg.worker_retries > 0`, the epoch is crash-tolerant: each
    /// worker's state is snapshotted at the barrier before the epoch
    /// starts, a worker that panics or errors is rolled back to that
    /// snapshot and retried (its panic is caught; the other workers'
    /// results stand), and only after the retry budget is exhausted does
    /// the epoch fail with a readable error. Because workers interact only
    /// at barriers, a recovered epoch is bit-identical to one that never
    /// failed. Recovery is skipped at one worker so `shards = 1` stays
    /// bit-identical to (and as cheap as) the plain serial trainer.
    pub fn train_epoch(&mut self) -> Result<StepStats> {
        let snapshot: Option<Vec<checkpoint::TrainerState>> =
            if self.cfg.worker_retries > 0 && self.workers.len() > 1 {
                Some(
                    self.workers
                        .iter()
                        .map(|w| checkpoint::TrainerState::capture(&w.trainer))
                        .collect(),
                )
            } else {
                None
            };
        let mut results: Vec<Result<StepStats, String>> =
            self.workers.par_iter_mut().map(run_worker_epoch).collect();
        let mut retries_left = self.cfg.worker_retries;
        while results.iter().any(|r| r.is_err()) {
            let failed: Vec<(usize, String)> = results
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r.as_ref().err().map(|e| (i, e.clone())))
                .collect();
            let Some(snap) = &snapshot else {
                return Err(anyhow!("{}", failed[0].1));
            };
            if retries_left == 0 {
                let who: Vec<String> = failed.iter().map(|(i, _)| format!("worker {i}")).collect();
                return Err(anyhow!(
                    "sharded epoch {} failed after {} rollback retr{}: {} still failing \
                     (last error: {}); raise --worker-retries or resume from the last \
                     checkpoint with --resume",
                    self.epochs_done + 1,
                    self.cfg.worker_retries,
                    if self.cfg.worker_retries == 1 { "y" } else { "ies" },
                    who.join(", "),
                    failed[0].1
                ));
            }
            retries_left -= 1;
            for (i, msg) in &failed {
                eprintln!(
                    "warning: {msg}; rolling back to the epoch-start snapshot and retrying \
                     ({retries_left} more after this)"
                );
                snap[*i]
                    .restore_into(&mut self.workers[*i].trainer)
                    .map_err(|e| anyhow!("rolling back worker {i}: {e}"))?;
                results[*i] = run_worker_epoch(&mut self.workers[*i]);
            }
        }
        let stats: Vec<StepStats> =
            results.into_iter().map(|r| r.expect("all failures handled above")).collect();
        self.epochs_done += 1;
        if self.cfg.sync_mode == SyncMode::HistoryExchange
            && self.cfg.method.compensation().uses_history
        {
            // methods without a history store (TOP, CLUSTER) have no
            // boundary rows to exchange
            failpoint::fire("sharded.exchange")?;
            self.exchange_boundary_histories();
        }
        if self.epochs_done % self.cfg.sync_every.max(1) == 0 {
            self.average_params();
        }
        Ok(combine_stats(&stats))
    }

    /// Copy every worker's halo history rows (H and V, all stored layers)
    /// from the owning shard's fresh core rows. Two-phase (gather all
    /// payloads, then scatter) so no worker is read and written in the same
    /// pass; runs on the coordinator thread in plan order.
    pub fn exchange_boundary_histories(&mut self) {
        for l in 1..self.model.arch.l {
            let payload = self
                .plan
                .iter()
                .map(|g| {
                    self.workers[g.src_worker as usize]
                        .trainer
                        .history
                        .export_rows(l, &g.src_rows)
                })
                .collect::<Vec<_>>();
            for (g, (h, v)) in self.plan.iter().zip(payload) {
                self.workers[g.dst_worker as usize]
                    .trainer
                    .history
                    .import_rows(l, &g.dst_rows, &h, &v);
            }
        }
    }

    /// True when every worker's halo history rows (layer `l`) bitwise match
    /// the owning shard's core rows — the post-exchange invariant.
    pub fn boundary_in_sync(&self, l: usize) -> bool {
        self.plan.iter().all(|g| {
            let src =
                self.workers[g.src_worker as usize].trainer.history.export_rows(l, &g.src_rows);
            let dst =
                self.workers[g.dst_worker as usize].trainer.history.export_rows(l, &g.dst_rows);
            src == dst
        })
    }

    /// Labeled-train-count weights of the averaging bus (uniform when no
    /// shard holds labeled nodes).
    fn shard_weights(&self) -> Vec<f64> {
        let total: f64 = self.workers.iter().map(|w| w.trainer.n_train as f64).sum();
        if total > 0.0 {
            self.workers.iter().map(|w| w.trainer.n_train as f64 / total).collect()
        } else {
            vec![1.0 / self.workers.len() as f64; self.workers.len()]
        }
    }

    /// The weighted parameter average across workers (does not mutate
    /// worker state; evaluation uses this without forcing a sync).
    pub fn averaged_params(&self) -> Params {
        let weights = self.shard_weights();
        let mut avg = self.workers[0].trainer.params.clone();
        for (ti, t) in avg.tensors.iter_mut().enumerate() {
            for (i, x) in t.data.iter_mut().enumerate() {
                let mut acc = 0f64;
                for (w, wt) in self.workers.iter().zip(&weights) {
                    acc += w.trainer.params.tensors[ti].data[i] as f64 * wt;
                }
                *x = acc as f32;
            }
        }
        avg
    }

    /// Synchronous averaging: overwrite every worker's parameters with the
    /// weighted average. Adam moments stay local.
    pub fn average_params(&mut self) {
        let avg = self.averaged_params();
        for w in &mut self.workers {
            for (dst, src) in w.trainer.params.tensors.iter_mut().zip(&avg.tensors) {
                dst.data.copy_from_slice(&src.data);
            }
        }
    }

    /// Exact evaluation of the (averaged) model on the parent graph.
    pub fn evaluate(&self) -> Result<EvalResult> {
        let params = self.averaged_params();
        self.exec.evaluate(self.parent.as_ref(), &params, &self.model)
    }

    /// Node-weighted mean history staleness across workers.
    pub fn mean_staleness(&self) -> f64 {
        if self.workers.len() == 1 {
            return self.workers[0].trainer.history.mean_staleness();
        }
        let total: usize = self.workers.iter().map(|w| w.trainer.graph.n()).sum();
        if total == 0 {
            return 0.0;
        }
        self.workers
            .iter()
            .map(|w| w.trainer.history.mean_staleness() * w.trainer.graph.n() as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Full sharded training run: the same epoch protocol as
    /// [`Trainer::run`] (shared via `record_epoch`), with evaluation of the
    /// averaged model on the parent graph.
    ///
    /// Starts after [`ShardedTrainer::epochs_done`] (0 on a fresh trainer,
    /// the checkpoint epoch after [`ShardedTrainer::resume`]) and writes an
    /// epoch-sync-barrier checkpoint — one manifest plus one state file per
    /// shard — whenever `checkpoint_dir` is set and the epoch lands on the
    /// `checkpoint_every` grid.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let sw = Stopwatch::start();
        for epoch in (self.epochs_done + 1)..=self.cfg.epochs {
            let es = Stopwatch::start();
            let stats = self.train_epoch()?;
            let epoch_secs = es.secs();
            let do_eval = epoch % self.cfg.eval_every.max(1) == 0 || epoch == self.cfg.epochs;
            let eval = if do_eval { Some(self.evaluate()?) } else { None };
            let staleness = self.mean_staleness();
            let obs = EpochObs {
                epoch,
                epoch_secs,
                stats: &stats,
                eval: eval.as_ref(),
                staleness,
                shards: Some(self.workers.len()),
            };
            if record_epoch(&mut self.metrics, &self.cfg, &sw, obs) {
                break;
            }
            self.maybe_checkpoint(epoch)?;
        }
        Ok(self.metrics.clone())
    }

    /// Completed sharded epochs ([`ShardedTrainer::run`] continues after
    /// this count).
    pub fn epochs_done(&self) -> usize {
        self.epochs_done
    }

    /// Write an epoch-sync-barrier checkpoint (all workers) when one is
    /// due.
    fn maybe_checkpoint(&self, epoch: usize) -> Result<()> {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return Ok(());
        };
        if !checkpoint::due(epoch, self.cfg.checkpoint_every, self.cfg.epochs) {
            return Ok(());
        }
        let states: Vec<checkpoint::TrainerState> =
            self.workers.iter().map(|w| checkpoint::TrainerState::capture(&w.trainer)).collect();
        let run = checkpoint::RunState { epochs_done: epoch, metrics: self.metrics.clone() };
        checkpoint::save(
            std::path::Path::new(dir),
            &checkpoint::config_fingerprint(&self.cfg),
            epoch,
            &states,
            &run,
        )
    }

    /// Rebuild a sharded trainer from the latest checkpoint in `dir` —
    /// one state per shard, written at an epoch-sync barrier — verifying
    /// the config fingerprint and shard count. The resumed run continues
    /// at `checkpoint epoch + 1`, bit-identically to the uninterrupted
    /// run (`sharded_interrupt_then_resume_is_bit_identical`).
    pub fn resume(
        exec: Arc<dyn Executor>,
        cfg: RunConfig,
        dir: &std::path::Path,
    ) -> Result<ShardedTrainer> {
        let mut st = ShardedTrainer::new(exec, cfg)?;
        let loaded =
            checkpoint::load(dir, &checkpoint::config_fingerprint(&st.cfg), st.workers.len())?;
        for (w, s) in st.workers.iter_mut().zip(&loaded.states) {
            s.restore_into(&mut w.trainer)
                .map_err(|e| anyhow!("restoring worker {}: {e}", w.id))?;
        }
        st.epochs_done = loaded.epoch;
        st.metrics = loaded.run.metrics;
        Ok(st)
    }
}

/// Run one worker's epoch with the `sharded.worker` failpoint armed at
/// the top, catching panics so a crashing worker can be rolled back and
/// retried by the coordinator instead of aborting the whole run. The
/// `Err` string carries the worker id and the panic payload (or training
/// error) for the retry-budget report.
fn run_worker_epoch(w: &mut WorkerState) -> Result<StepStats, String> {
    let wid = w.id;
    match catch_unwind(AssertUnwindSafe(|| {
        failpoint::fire("sharded.worker")?;
        w.trainer.train_epoch()
    })) {
        Ok(Ok(stats)) => Ok(stats),
        Ok(Err(e)) => Err(format!("worker {wid} failed: {e:#}")),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(format!("worker {wid} panicked: {msg}"))
        }
    }
}

/// Labeled-weighted aggregate of per-shard epoch stats. `active_bytes` sums
/// across shards (the workers run concurrently, so their simulated
/// accelerator footprints coexist). The single-shard case passes stats
/// through untouched so `shards = 1` stays bit-identical to the serial
/// trainer.
fn combine_stats(per_shard: &[StepStats]) -> StepStats {
    if per_shard.len() == 1 {
        return per_shard[0].clone();
    }
    let labeled: usize = per_shard.iter().map(|s| s.labeled).sum();
    let lw: f64 = per_shard.iter().map(|s| s.loss_mean * s.labeled as f64).sum();
    let aw: f64 = per_shard.iter().map(|s| s.train_acc * s.labeled as f64).sum();
    StepStats {
        loss_mean: lw / labeled.max(1) as f64,
        train_acc: aw / labeled.max(1) as f64,
        labeled,
        active_bytes: per_shard.iter().map(|s| s.active_bytes).sum(),
        dropped_halo: per_shard.iter().map(|s| s.dropped_halo).sum(),
    }
}
