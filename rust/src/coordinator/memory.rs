//! Simulated accelerator-memory model and reserved-message accounting
//! (paper Tables 2, 5, 7).
//!
//! The paper measures GPU MB on a 2080 Ti; our substrate is CPU PJRT, so we
//! report the *active tensor bytes* a step holds resident (inputs + outputs
//! of the executed program), which reproduces the complexity rows of Table 5
//! (O(n_max L |V_B| d) for CLUSTER/GAS/LMC vs O(L |V| d) for GD) and the
//! between-method ordering of Tables 2/7. Histories live in host RAM (as in
//! GAS) and are excluded.

use crate::coordinator::methods::Method;
use crate::graph::Graph;
use crate::runtime::{ArchInfo, ProgramSpec};
use crate::sampler::SubgraphBatch;

/// Bytes held by one execution of a program: inputs + outputs.
pub fn program_active_bytes(spec: &ProgramSpec) -> usize {
    let elems: usize = spec
        .inputs
        .iter()
        .map(|t| t.elems())
        .chain(spec.outputs.iter().map(|t| t.elems()))
        .sum();
    elems * 4
}

/// Bytes held by one native sparse-block step: adjacency nonzeros (col
/// index + value + row offsets), node tensors (features, per-layer
/// aggregate/pre-activation/activation, histories and their updates) and
/// params + grads. Unlike [`program_active_bytes`] this scales with the
/// *actual* subgraph (O(nnz + m·d)) rather than the padded bucket area —
/// the Table 5 complexity row the sparse refactor buys.
pub fn sparse_step_active_bytes(sb: &SubgraphBatch, arch: &ArchInfo, d_x: usize) -> usize {
    let nb = sb.batch.len();
    let nh = sb.halo.len();
    let m = nb + nh;
    let block_bytes = sb.nnz() * 8
        + (sb.a_bb.offsets.len() + sb.a_bh.offsets.len() + sb.a_hh.offsets.len()) * 4;
    let mut elems = m * d_x;
    for l in 1..=arch.l {
        elems += 3 * m * arch.dims[l]; // agg, pre-activation, activation
    }
    for l in 1..arch.l {
        elems += 2 * nh * arch.dims[l]; // histH, histV gathers
        elems += 2 * nb * arch.dims[l]; // newH, newV write-backs
    }
    let params: usize = arch.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    (elems + 2 * params) * 4 + block_bytes
}

/// Full-batch GD: all layer activations + gradients + the adjacency.
pub fn gd_active_bytes(n: usize, dims: &[usize], d_x: usize, arcs: usize) -> usize {
    let acts: usize = dims.iter().map(|&d| n * d).sum::<usize>() + n * d_x;
    // forward + backward (auxiliary variables) + sparse adjacency (8B/arc)
    (2 * acts) * 4 + arcs * 8
}

/// Reserved-message proportions over one epoch's batches (Table 7):
/// the fraction of `Ahat` nonzeros (2|E| + n self-loops) whose message is
/// computed in forward (resp. used in backward) passes, as a union over the
/// epoch's mini-batches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MessageAccounting {
    pub fwd_frac: f64,
    pub bwd_frac: f64,
}

pub fn reserved_messages<B: AsRef<[u32]>>(
    g: &Graph,
    batches: &[B],
    method: Method,
) -> MessageAccounting {
    let n = g.n();
    let arcs = g.csr.neighbors.len();
    let total = arcs + n; // + self-loops
    if method == Method::Gd {
        return MessageAccounting { fwd_frac: 1.0, bwd_frac: 1.0 };
    }
    let mut fwd = vec![false; arcs];
    let mut bwd = vec![false; arcs];
    let mut fwd_self = vec![false; n];
    let mut bwd_self = vec![false; n];
    let mut mark = vec![0u8; n];
    for batch in batches {
        let batch = batch.as_ref();
        for &u in batch {
            mark[u as usize] = 1;
        }
        let mut halo: Vec<u32> = Vec::new();
        if method != Method::Cluster {
            for &u in batch {
                for &v in g.csr.neighbors(u as usize) {
                    if mark[v as usize] == 0 {
                        mark[v as usize] = 2;
                        halo.push(v);
                    }
                }
            }
        }
        for &u in batch {
            let u = u as usize;
            fwd_self[u] = true;
            bwd_self[u] = true;
            let (s, e) = (g.csr.offsets[u] as usize, g.csr.offsets[u + 1] as usize);
            for ei in s..e {
                let v = g.csr.neighbors[ei] as usize;
                match method {
                    Method::Cluster => {
                        // only in-batch messages, both directions of the pass
                        if mark[v] == 1 {
                            fwd[ei] = true;
                            bwd[ei] = true;
                        }
                    }
                    Method::Gas | Method::Fm => {
                        // forward: full row (history for out-of-batch);
                        // backward: in-batch messages only (C_b discarded)
                        fwd[ei] = true;
                        if mark[v] == 1 {
                            bwd[ei] = true;
                        }
                    }
                    Method::Lmc | Method::LmcSpider => {
                        fwd[ei] = true;
                        bwd[ei] = true;
                    }
                    Method::Gd => unreachable!(),
                }
            }
        }
        if matches!(method, Method::Lmc | Method::LmcSpider) {
            // compensation rows: halo messages from within Nbar(V_B)
            for &u in &halo {
                let u = u as usize;
                fwd_self[u] = true;
                bwd_self[u] = true;
                let (s, e) = (g.csr.offsets[u] as usize, g.csr.offsets[u + 1] as usize);
                for ei in s..e {
                    if mark[g.csr.neighbors[ei] as usize] != 0 {
                        fwd[ei] = true;
                        bwd[ei] = true;
                    }
                }
            }
        }
        for &u in batch {
            mark[u as usize] = 0;
        }
        for &u in &halo {
            mark[u as usize] = 0;
        }
    }
    let count = |arcv: &[bool], selfv: &[bool]| {
        arcv.iter().filter(|&&b| b).count() + selfv.iter().filter(|&&b| b).count()
    };
    MessageAccounting {
        fwd_frac: count(&fwd, &fwd_self) as f64 / total as f64,
        bwd_frac: count(&bwd, &bwd_self) as f64 / total as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{load, DatasetId};

    fn partition_batches(n: usize, parts: usize) -> Vec<Vec<u32>> {
        let per = n.div_ceil(parts);
        (0..parts)
            .map(|p| ((p * per) as u32..(((p + 1) * per).min(n)) as u32).collect())
            .collect()
    }

    #[test]
    fn message_accounting_orderings() {
        // Table 7's shape: GAS fwd = 100%, GAS bwd < 100%; LMC = 100/100;
        // CLUSTER fwd = bwd < GAS bwd-equal... (CLUSTER == GAS bwd here).
        let g = load(DatasetId::CoraSim, 0);
        let batches = partition_batches(g.n(), 8);
        let gas = reserved_messages(&g, &batches, Method::Gas);
        let lmc = reserved_messages(&g, &batches, Method::Lmc);
        let clu = reserved_messages(&g, &batches, Method::Cluster);
        let gd = reserved_messages(&g, &batches, Method::Gd);
        assert!((gas.fwd_frac - 1.0).abs() < 1e-9, "GAS fwd {}", gas.fwd_frac);
        assert!(gas.bwd_frac < 1.0);
        assert!((lmc.fwd_frac - 1.0).abs() < 1e-9);
        assert!((lmc.bwd_frac - 1.0).abs() < 1e-9);
        assert!(clu.fwd_frac < gas.fwd_frac);
        assert!((clu.fwd_frac - clu.bwd_frac).abs() < 1e-12);
        assert_eq!(clu.fwd_frac, gas.bwd_frac);
        assert_eq!(gd.fwd_frac, 1.0);
    }

    #[test]
    fn gd_bytes_dominate_minibatch() {
        let dims = vec![64usize, 64, 64, 16];
        let gd = gd_active_bytes(2400, &dims, 64, 2400 * 10);
        assert!(gd > 2400 * 64 * 4);
    }
}
