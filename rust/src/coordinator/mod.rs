//! L3 coordinator: the paper's training system as scheduling policies over
//! a pluggable execution backend (see DESIGN.md §1 and `crate::backend`).

pub mod exact;
pub mod grad_check;
pub mod memory;
pub mod methods;
pub mod metrics;
pub mod params;
pub mod sharded;
pub mod trainer;

pub use exact::{EvalResult, OracleResult};
pub use methods::{BetaConfig, Method};
pub use metrics::{EpochRecord, RunMetrics};
pub use params::{Adam, AdamConfig, Params};
pub use sharded::{ShardedTrainer, SyncMode, WorkerState};
pub use trainer::{StepStats, Trainer};
