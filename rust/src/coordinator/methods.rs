//! Training method policies (DESIGN.md §1 table): every subgraph-wise
//! baseline is the same compiled train_step under a different policy.
//! The compensation-shaped knobs live in one place —
//! [`Method::compensation`] — instead of scattered boolean predicates.

use crate::compensation::CompensationSpec;
use crate::sampler::{AdjacencyPolicy, BetaScore};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Local Message Compensation (the paper's contribution).
    Lmc,
    /// GNNAutoScale (Fey et al. 2021): historical halo values, no backward
    /// compensation.
    Gas,
    /// GraphFM-OB (Yu et al. 2022): GAS + momentum push of incomplete
    /// up-to-date halo values into the history store.
    Fm,
    /// CLUSTER-GCN (Chiang et al. 2019): edges outside the batch pruned,
    /// local re-normalization.
    Cluster,
    /// Full-batch gradient descent via the exact tile oracle (the accuracy
    /// and gradient reference).
    Gd,
    /// LMC + SPIDER variance reduction (paper Appendix F): periodic exact
    /// full-batch anchor gradients with LMC correction steps in between.
    LmcSpider,
    /// TOP message invariance (arXiv 2502.19693, the LMC authors'
    /// follow-up): learned per-layer transforms synthesize out-of-batch
    /// messages from fresh in-batch ones — no history store, no staleness.
    Top,
}

impl Method {
    /// Accepted names (all case-insensitive):
    ///   lmc · gas · fm | graphfm | graphfm-ob · cluster | cluster-gcn ·
    ///   gd | full | full-batch · lmc-spider | spider ·
    ///   top | mi | message-invariance
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lmc" => Method::Lmc,
            "gas" => Method::Gas,
            "fm" | "graphfm" | "graphfm-ob" => Method::Fm,
            "cluster" | "cluster-gcn" => Method::Cluster,
            "gd" | "full" | "full-batch" => Method::Gd,
            "lmc-spider" | "spider" => Method::LmcSpider,
            "top" | "mi" | "message-invariance" => Method::Top,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Lmc => "LMC",
            Method::Gas => "GAS",
            Method::Fm => "FM",
            Method::Cluster => "CLUSTER",
            Method::Gd => "GD",
            Method::LmcSpider => "LMC-SPIDER",
            Method::Top => "TOP",
        }
    }

    pub fn adjacency_policy(&self) -> AdjacencyPolicy {
        match self {
            Method::Cluster => AdjacencyPolicy::LocalNoHalo,
            _ => AdjacencyPolicy::GlobalWithHalo,
        }
    }

    /// The method's compensation policy — the single table that used to be
    /// spread across `uses_beta` / `bwd_scale` / `uses_history` /
    /// `stores_aux` / `halo_momentum` predicates.
    pub fn compensation(&self) -> CompensationSpec {
        match self {
            Method::Lmc | Method::LmcSpider => CompensationSpec::lmc(),
            Method::Gas => CompensationSpec::gas(),
            Method::Fm => CompensationSpec::fm(),
            Method::Cluster | Method::Gd => CompensationSpec::none(),
            Method::Top => CompensationSpec::top(),
        }
    }

    pub fn is_minibatch(&self) -> bool {
        !matches!(self, Method::Gd)
    }

    pub fn all_minibatch() -> &'static [Method] {
        &[Method::Cluster, Method::Gas, Method::Fm, Method::Lmc]
    }
}

/// Per-run beta configuration (paper §A.4: beta_i = alpha * score(x_i)).
#[derive(Clone, Copy, Debug)]
pub struct BetaConfig {
    pub alpha: f32,
    pub score: BetaScore,
}

impl Default for BetaConfig {
    fn default() -> Self {
        // Paper §A.4/§E.4: alpha=1, score=1 wins only at large batch sizes;
        // alpha=0.4 with score 2x-x^2 is the robust small/medium-batch
        // choice (Table 8/9), which matches our default 2-cluster batches.
        BetaConfig { alpha: 0.4, score: BetaScore::TwoXMinusXSquared }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compensation::CompKind;

    #[test]
    fn policies_match_paper_table() {
        assert_eq!(Method::Cluster.adjacency_policy(), AdjacencyPolicy::LocalNoHalo);
        assert_eq!(Method::Lmc.adjacency_policy(), AdjacencyPolicy::GlobalWithHalo);
        assert_eq!(Method::Gas.compensation().bwd_scale, 0.0);
        assert_eq!(Method::Lmc.compensation().bwd_scale, 1.0);
        assert!(!Method::Gas.compensation().uses_beta);
        assert!(Method::Lmc.compensation().stores_aux);
        assert!(!Method::Gas.compensation().stores_aux);
        assert!(Method::Fm.compensation().halo_momentum.is_some());
        assert!(!Method::Gd.is_minibatch());
        // LMC-SPIDER shares the full LMC compensation policy
        assert_eq!(Method::LmcSpider.compensation(), Method::Lmc.compensation());
    }

    #[test]
    fn top_policy_is_fresh_transforms_no_history() {
        let spec = Method::Top.compensation();
        assert_eq!(spec.kind, CompKind::Top);
        assert!(!spec.uses_history, "TOP reads no history store");
        assert!(!spec.stores_aux);
        assert!(!spec.uses_beta);
        assert_eq!(spec.bwd_scale, 1.0, "TOP compensates the backward pass");
        assert_eq!(Method::Top.adjacency_policy(), AdjacencyPolicy::GlobalWithHalo);
        assert!(Method::Top.is_minibatch());
    }

    #[test]
    fn parse_names() {
        for m in [
            Method::Lmc,
            Method::Gas,
            Method::Fm,
            Method::Cluster,
            Method::Gd,
            Method::LmcSpider,
            Method::Top,
        ] {
            assert_eq!(Method::parse(&m.name().to_ascii_lowercase()), Some(m));
        }
        // every documented alias resolves
        for (alias, m) in [
            ("graphfm", Method::Fm),
            ("graphfm-ob", Method::Fm),
            ("cluster-gcn", Method::Cluster),
            ("full", Method::Gd),
            ("full-batch", Method::Gd),
            ("spider", Method::LmcSpider),
            ("mi", Method::Top),
            ("message-invariance", Method::Top),
        ] {
            assert_eq!(Method::parse(alias), Some(m), "{alias}");
        }
        assert!(Method::parse("nope").is_none());
    }
}
