//! Training method policies (DESIGN.md §1 table): every subgraph-wise
//! baseline is the same compiled train_step under a different policy.

use crate::sampler::{AdjacencyPolicy, BetaScore};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Local Message Compensation (the paper's contribution).
    Lmc,
    /// GNNAutoScale (Fey et al. 2021): historical halo values, no backward
    /// compensation.
    Gas,
    /// GraphFM-OB (Yu et al. 2022): GAS + momentum push of incomplete
    /// up-to-date halo values into the history store.
    Fm,
    /// CLUSTER-GCN (Chiang et al. 2019): edges outside the batch pruned,
    /// local re-normalization.
    Cluster,
    /// Full-batch gradient descent via the exact tile oracle (the accuracy
    /// and gradient reference).
    Gd,
    /// LMC + SPIDER variance reduction (paper Appendix F): periodic exact
    /// full-batch anchor gradients with LMC correction steps in between.
    LmcSpider,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lmc" => Method::Lmc,
            "gas" => Method::Gas,
            "fm" | "graphfm" | "graphfm-ob" => Method::Fm,
            "cluster" | "cluster-gcn" => Method::Cluster,
            "gd" | "full" | "full-batch" => Method::Gd,
            "lmc-spider" | "spider" => Method::LmcSpider,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Lmc => "LMC",
            Method::Gas => "GAS",
            Method::Fm => "FM",
            Method::Cluster => "CLUSTER",
            Method::Gd => "GD",
            Method::LmcSpider => "LMC-SPIDER",
        }
    }

    pub fn adjacency_policy(&self) -> AdjacencyPolicy {
        match self {
            Method::Cluster => AdjacencyPolicy::LocalNoHalo,
            _ => AdjacencyPolicy::GlobalWithHalo,
        }
    }

    /// Forward compensation on? (beta > 0 allowed)
    pub fn uses_beta(&self) -> bool {
        matches!(self, Method::Lmc | Method::LmcSpider)
    }

    /// Backward compensation C_b on? (Eqs. 11-13)
    pub fn bwd_scale(&self) -> f32 {
        match self {
            Method::Lmc | Method::LmcSpider => 1.0,
            _ => 0.0,
        }
    }

    /// Does the method read historical embeddings for the halo?
    pub fn uses_history(&self) -> bool {
        !matches!(self, Method::Cluster | Method::Gd)
    }

    /// Does the method store auxiliary-variable histories (Vbar)?
    pub fn stores_aux(&self) -> bool {
        matches!(self, Method::Lmc | Method::LmcSpider)
    }

    /// FM's momentum push to halo histories.
    pub fn halo_momentum(&self) -> Option<f32> {
        match self {
            Method::Fm => Some(0.3),
            _ => None,
        }
    }

    pub fn is_minibatch(&self) -> bool {
        !matches!(self, Method::Gd)
    }

    pub fn all_minibatch() -> &'static [Method] {
        &[Method::Cluster, Method::Gas, Method::Fm, Method::Lmc]
    }
}

/// Per-run beta configuration (paper §A.4: beta_i = alpha * score(x_i)).
#[derive(Clone, Copy, Debug)]
pub struct BetaConfig {
    pub alpha: f32,
    pub score: BetaScore,
}

impl Default for BetaConfig {
    fn default() -> Self {
        // Paper §A.4/§E.4: alpha=1, score=1 wins only at large batch sizes;
        // alpha=0.4 with score 2x-x^2 is the robust small/medium-batch
        // choice (Table 8/9), which matches our default 2-cluster batches.
        BetaConfig { alpha: 0.4, score: BetaScore::TwoXMinusXSquared }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policies_match_paper_table() {
        assert_eq!(Method::Cluster.adjacency_policy(), AdjacencyPolicy::LocalNoHalo);
        assert_eq!(Method::Lmc.adjacency_policy(), AdjacencyPolicy::GlobalWithHalo);
        assert_eq!(Method::Gas.bwd_scale(), 0.0);
        assert_eq!(Method::Lmc.bwd_scale(), 1.0);
        assert!(!Method::Gas.uses_beta());
        assert!(Method::Lmc.stores_aux());
        assert!(!Method::Gas.stores_aux());
        assert!(Method::Fm.halo_momentum().is_some());
        assert!(!Method::Gd.is_minibatch());
    }

    #[test]
    fn parse_names() {
        for m in [Method::Lmc, Method::Gas, Method::Fm, Method::Cluster, Method::Gd] {
            assert_eq!(Method::parse(&m.name().to_ascii_lowercase()), Some(m));
        }
    }
}
