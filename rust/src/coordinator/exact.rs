//! Exact full-graph computation (paper Theorem 1 with V_B = V): the result
//! types of the evaluation / full-batch-gradient oracle, shared by every
//! backend.
//!
//! The implementations live behind the [`crate::backend::Executor`] trait:
//! the native backend computes the oracle directly over the global CSR
//! (`backend/native.rs`); the PJRT backend runs the tile-wise compiled
//! programs (`backend/pjrt.rs`, which also hosts the tile partitioner that
//! used to live here).

use crate::graph::Graph;
use crate::runtime::Tensor;

/// Per-split accuracy + mean training loss of an exact forward pass.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub train_loss: f64,
    pub train_acc: f64,
    pub val_acc: f64,
    pub test_acc: f64,
}

/// Exact full-batch gradient oracle output.
#[derive(Debug)]
pub struct OracleResult {
    /// Full-batch gradients in canonical param order.
    pub grads: Vec<Tensor>,
    pub train_loss: f64,
    /// Exact H^l for l = 0..L.
    pub h_layers: Vec<Vec<f32>>,
    /// Exact V^l; index l valid for l = 1..L.
    pub v_layers: Vec<Vec<f32>>,
}

/// Accuracy ratio, 0 for an empty split (shared by both backends).
pub fn acc(correct: usize, total: usize) -> f64 {
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

/// First index of the row maximum (ties break low, like `jnp.argmax`).
pub fn argmax(row: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi
}

/// Out-of-tile neighbors of the contiguous range [s, e).
pub fn exact_halo(g: &Graph, s: usize, e: usize) -> Vec<u32> {
    let mut seen = std::collections::HashSet::new();
    let mut halo = Vec::new();
    for u in s..e {
        for &v in g.csr.neighbors(u) {
            let vu = v as usize;
            if (vu < s || vu >= e) && seen.insert(v) {
                halo.push(v);
            }
        }
    }
    halo.sort_unstable();
    halo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{random_graph, Csr, Graph};
    use crate::util::rng::Rng;

    fn graph_of(csr: Csr) -> Graph {
        let n = csr.n;
        Graph::new(csr, 4, 2, vec![0.0; n * 4], vec![0; n], vec![0; n])
    }

    #[test]
    fn exact_halo_is_out_of_range_neighbors() {
        let mut rng = Rng::new(5);
        let g = graph_of(random_graph(60, 0.1, &mut rng));
        let (s, e) = (10usize, 30usize);
        let halo = exact_halo(&g, s, e);
        // sorted, unique, disjoint from [s, e)
        assert!(halo.windows(2).all(|w| w[0] < w[1]));
        assert!(halo.iter().all(|&v| (v as usize) < s || (v as usize) >= e));
        // complete: every out-of-range neighbor present
        for u in s..e {
            for &v in g.csr.neighbors(u) {
                let vu = v as usize;
                if vu < s || vu >= e {
                    assert!(halo.binary_search(&v).is_ok());
                }
            }
        }
    }
}
