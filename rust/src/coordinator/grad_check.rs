//! Gradient-error measurement (paper Fig. 3): relative error of each
//! method's mini-batch gradients against the exact full-batch gradient,
//! per message-passing layer.

use anyhow::Result;

use super::params::{grad_rel_err, Params};
use super::trainer::Trainer;
use crate::runtime::Tensor;

#[derive(Debug, Clone)]
pub struct GradErrorReport {
    /// Relative error per MP layer (‖g~ - ∇L‖ / ‖∇L‖ over that layer's params),
    /// averaged over the epoch's mini-batches with Eq. 15 weights applied.
    pub per_layer: Vec<f64>,
    /// Overall relative error of the epoch-summed mini-batch gradient.
    pub overall: f64,
}

/// Measure mini-batch gradient errors at the trainer's current parameters.
///
/// Protocol (paper §7.2): full-batch gradient from the exact oracle; for
/// each mini-batch in one epoch, the per-batch relative errors are averaged;
/// dropout is absent by construction (deterministic programs).
pub fn measure(trainer: &mut Trainer) -> Result<GradErrorReport> {
    let oracle = trainer
        .exec
        .full_grad(trainer.graph.as_ref(), &trainer.params, &trainer.model)?;
    let arch = trainer.model.arch.clone();
    let l_total = arch.l;

    // layer -> indices of its params (plus embed0/head assigned to layer 1/L)
    let layer_of = |name: &str| -> usize {
        for (l, names) in &arch.layer_params {
            if names.iter().any(|n| n == name) {
                return *l;
            }
        }
        if name == "W0" || name == "b0" {
            1
        } else {
            l_total
        }
    };

    // Clone the batcher so (a) the trainer's sampling stream is untouched
    // and (b) repeated measurements at the same state (e.g. toggling the
    // method policy) see the *same* mini-batches — the sampling variance
    // then cancels in method comparisons and only the bias differs.
    let batches = trainer.batcher.clone().epoch_batches();
    let nb = batches.len().max(1);
    let mut per_layer_acc = vec![0f64; l_total];
    let mut overall_acc = 0f64;
    for (i, batch) in batches.iter().enumerate() {
        let (_, grads) = trainer.compute_minibatch_grads_at(i, batch, None, false)?;
        overall_acc += grad_rel_err(&grads, &oracle.grads);
        for l in 1..=l_total {
            let sel: Vec<usize> = trainer
                .params
                .names
                .iter()
                .enumerate()
                .filter(|(_, n)| layer_of(n) == l)
                .map(|(i, _)| i)
                .collect();
            let g: Vec<Tensor> = sel.iter().map(|&i| grads[i].clone()).collect();
            let r: Vec<Tensor> = sel.iter().map(|&i| oracle.grads[i].clone()).collect();
            per_layer_acc[l - 1] += grad_rel_err(&g, &r);
        }
    }
    Ok(GradErrorReport {
        per_layer: per_layer_acc.iter().map(|x| x / nb as f64).collect(),
        overall: overall_acc / nb as f64,
    })
}

/// Convenience: measure errors after `warm_epochs` of training (histories
/// need a few epochs to populate before the comparison is meaningful).
pub fn measure_after_warmup(trainer: &mut Trainer, warm_epochs: usize) -> Result<GradErrorReport> {
    for _ in 0..warm_epochs {
        trainer.train_epoch()?;
    }
    measure(trainer)
}

/// Gradient *bias*: the relative error of the partition-summed mini-batch
/// gradient (each batch's grads divided by its own Eq. 15 weight
/// b/|chunk|, then summed over one epoch's batches) against the exact
/// full-batch gradient. The cluster sampling variance cancels in the sum
/// (Theorem 1), isolating the bias term of Theorem 2 that LMC's
/// compensations shrink. Using the per-step weight (not the constant b/c)
/// keeps a ragged last stochastic batch from skewing the sum.
pub fn measure_bias(trainer: &mut Trainer) -> Result<f64> {
    let oracle = trainer
        .exec
        .full_grad(trainer.graph.as_ref(), &trainer.params, &trainer.model)?;
    let batches = trainer.batcher.clone().epoch_batches();
    let mut sum: Option<Vec<Tensor>> = None;
    for (i, batch) in batches.iter().enumerate() {
        let (_, grads) = trainer.compute_minibatch_grads_at(i, batch, None, false)?;
        let gs = trainer.batcher.grad_scale_at(i) as f64;
        sum = Some(match sum {
            None => grads
                .iter()
                .map(|g| {
                    Tensor::from_vec(
                        &g.shape,
                        g.data.iter().map(|x| (*x as f64 / gs) as f32).collect(),
                    )
                })
                .collect(),
            Some(acc) => acc
                .iter()
                .zip(&grads)
                .map(|(a, b)| {
                    Tensor::from_vec(
                        &a.shape,
                        a.data
                            .iter()
                            .zip(&b.data)
                            .map(|(x, y)| x + (*y as f64 / gs) as f32)
                            .collect(),
                    )
                })
                .collect(),
        });
    }
    let mean = sum.unwrap_or_else(|| trainer.params.zeros_like());
    Ok(grad_rel_err(&mean, &oracle.grads))
}

#[allow(dead_code)]
fn _assert_params_api(p: &Params) -> usize {
    p.num_scalars()
}
