//! Model parameters (host-resident, canonical manifest order) and the Adam
//! optimizer (paper uses Adam across all experiments), plus the bitwise
//! save/load round-trip the serve path uses to hand trained parameters to
//! a long-lived inference engine.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{ArchInfo, Tensor};
use crate::util::bytes::{
    append_crc_trailer, check_crc_trailer, push_u32, Cursor, CRC_TRAILER_MAGIC,
};
use crate::util::rng::Rng;

/// File magic of the `lmc` binary params format (version 1).
const PARAMS_MAGIC: &[u8; 8] = b"LMCPAR1\n";

#[derive(Clone, Debug)]
pub struct Params {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl Params {
    /// Glorot-uniform matrices, zero vectors — same scheme as
    /// `python/compile/archs.py` so Rust-initialized training matches the
    /// Python-side tests' regime.
    pub fn init(arch: &ArchInfo, rng: &mut Rng) -> Params {
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for (name, shape) in &arch.params {
            let t = if shape.len() >= 2 {
                let fan_in = shape[0] as f64;
                let fan_out = shape[1] as f64;
                let scale = (6.0 / (fan_in + fan_out)).sqrt();
                let data: Vec<f32> = (0..shape.iter().product::<usize>())
                    .map(|_| rng.uniform(-scale, scale) as f32)
                    .collect();
                Tensor::from_vec(shape, data)
            } else {
                Tensor::zeros(shape)
            };
            names.push(name.clone());
            tensors.push(t);
        }
        Params { names, tensors }
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index_of(name).map(|i| &self.tensors[i])
    }

    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.elems()).sum()
    }

    /// Zero gradients with matching shapes.
    pub fn zeros_like(&self) -> Vec<Tensor> {
        self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect()
    }

    /// Serialize to the `lmc` binary params format: magic, tensor count,
    /// per tensor name / shape / little-endian f32 bit patterns, then a
    /// CRC32 integrity trailer over the whole payload. The round-trip is
    /// **bitwise** — every float (including -0.0, subnormals and NaN
    /// payloads) reloads with identical bits
    /// (`prop_params_save_load_roundtrip_is_bitwise`).
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload: usize = self
            .tensors
            .iter()
            .map(|t| 8 + 4 * t.shape.len() + 4 * t.elems())
            .sum();
        let mut out = Vec::with_capacity(PARAMS_MAGIC.len() + 12 + payload + 16 * self.names.len());
        out.extend_from_slice(PARAMS_MAGIC);
        push_u32(&mut out, self.tensors.len() as u32);
        for (name, t) in self.names.iter().zip(&self.tensors) {
            push_u32(&mut out, name.len() as u32);
            out.extend_from_slice(name.as_bytes());
            push_u32(&mut out, t.shape.len() as u32);
            for &d in &t.shape {
                push_u32(&mut out, d as u32);
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        append_crc_trailer(&mut out);
        out
    }

    /// Parse the [`Params::to_bytes`] format, validating the checksum
    /// trailer (when present — trailer-less legacy files are accepted
    /// with a warning), magic, bounds and shape/data consistency.
    pub fn from_bytes(bytes: &[u8]) -> Result<Params> {
        // Integrity first: files written since the checksum round end in
        // `LMCC` + CRC32; a mismatch means truncation or bit-flips and
        // must surface as a readable error, never as garbage params.
        let has_trailer =
            bytes.len() >= 8 && &bytes[bytes.len() - 8..bytes.len() - 4] == CRC_TRAILER_MAGIC;
        let payload = if has_trailer {
            check_crc_trailer(bytes, "params file")?
        } else {
            if bytes.len() >= PARAMS_MAGIC.len() && &bytes[..PARAMS_MAGIC.len()] == PARAMS_MAGIC {
                eprintln!(
                    "warning: params file has no CRC trailer (pre-checksum format); \
                     loading unverified — re-save to add integrity checking"
                );
            }
            bytes
        };
        let mut cur = Cursor::new(payload);
        let magic = cur.take(PARAMS_MAGIC.len())?;
        if magic != PARAMS_MAGIC {
            bail!("not an lmc params file (bad magic)");
        }
        let count = cur.u32()? as usize;
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for ti in 0..count {
            let name_len = cur.u32()? as usize;
            let name = std::str::from_utf8(cur.take(name_len)?)
                .map_err(|_| anyhow!("tensor #{ti}: name is not valid utf-8"))?
                .to_string();
            let rank = cur.u32()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(cur.u32()? as usize);
            }
            let elems: usize = shape.iter().product();
            let raw = cur.take(4 * elems)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            names.push(name);
            tensors.push(Tensor::from_vec(&shape, data));
        }
        if cur.i != payload.len() {
            bail!("trailing bytes after tensor {} of {}", count, count);
        }
        Ok(Params { names, tensors })
    }

    /// Write the binary params format to `path` (the `lmc train
    /// --save-params` side of the round-trip).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow!("writing params to {}: {e}", path.display()))
    }

    /// Load a file written by [`Params::save`] (the `lmc serve --params`
    /// side).
    pub fn load(path: &Path) -> Result<Params> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow!("reading params from {}: {e}", path.display()))?;
        Params::from_bytes(&bytes).map_err(|e| anyhow!("{}: {e}", path.display()))
    }
}

/// Gradient norm helpers (Fig. 3 and convergence diagnostics).
pub fn grad_l2(grads: &[Tensor]) -> f64 {
    grads.iter().map(|g| g.norm().powi(2)).sum::<f64>().sqrt()
}

pub fn grad_rel_err(g: &[Tensor], reference: &[Tensor]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (a, b) in g.iter().zip(reference) {
        for (x, y) in a.data.iter().zip(&b.data) {
            let d = (*x - *y) as f64;
            num += d * d;
            den += (*y as f64) * (*y as f64);
        }
    }
    (num / den.max(1e-30)).sqrt()
}

#[derive(Clone, Debug)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

#[derive(Clone, Debug)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    pub fn new(params: &Params, cfg: AdamConfig) -> Adam {
        Adam {
            cfg,
            m: params.tensors.iter().map(|t| vec![0f32; t.elems()]).collect(),
            v: params.tensors.iter().map(|t| vec![0f32; t.elems()]).collect(),
            t: 0,
        }
    }

    /// Optimizer state snapshot — first/second moments and the step
    /// counter — for checkpointing.
    pub fn state(&self) -> (&[Vec<f32>], &[Vec<f32>], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Restore a snapshot captured by [`Adam::state`]; moment shapes
    /// must match the params this optimizer was built for.
    pub fn restore_state(&mut self, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, t: u64) -> Result<()> {
        let shape = |x: &[Vec<f32>]| x.iter().map(|e| e.len()).collect::<Vec<_>>();
        if shape(&m) != shape(&self.m) || shape(&v) != shape(&self.v) {
            bail!(
                "adam moment shapes do not match the model: checkpoint {:?}/{:?}, model {:?}",
                shape(&m),
                shape(&v),
                shape(&self.m)
            );
        }
        self.m = m;
        self.v = v;
        self.t = t;
        Ok(())
    }

    pub fn step(&mut self, params: &mut Params, grads: &[Tensor]) {
        assert_eq!(grads.len(), params.tensors.len());
        self.t += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.cfg.lr;
        for (pi, g) in grads.iter().enumerate() {
            let p = &mut params.tensors[pi].data;
            let m = &mut self.m[pi];
            let v = &mut self.v[pi];
            for i in 0..p.len() {
                let mut gi = g.data[i] as f64;
                if self.cfg.weight_decay != 0.0 {
                    gi += self.cfg.weight_decay * p[i] as f64;
                }
                let mi = b1 * m[i] as f64 + (1.0 - b1) * gi;
                let vi = b2 * v[i] as f64 + (1.0 - b2) * gi * gi;
                m[i] = mi as f32;
                v[i] = vi as f32;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                p[i] -= (lr * mhat / (vhat.sqrt() + self.cfg.eps)) as f32;
            }
        }
    }
}

/// Plain SGD (used by the convergence-theory sanity tests; Theorems 2-3 are
/// stated for SGD).
pub fn sgd_step(params: &mut Params, grads: &[Tensor], lr: f64) {
    for (pi, g) in grads.iter().enumerate() {
        let p = &mut params.tensors[pi].data;
        for i in 0..p.len() {
            p[i] -= (lr * g.data[i] as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_params() -> Params {
        Params {
            names: vec!["w".into()],
            tensors: vec![Tensor::from_vec(&[2], vec![3.0, -2.0])],
        }
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let mut p = quad_params();
        let mut opt = Adam::new(&p, AdamConfig { lr: 0.1, ..Default::default() });
        for _ in 0..500 {
            let g = Tensor::from_vec(&[2], p.tensors[0].data.iter().map(|&x| 2.0 * x).collect());
            opt.step(&mut p, &[g]);
        }
        assert!(p.tensors[0].data.iter().all(|&x| x.abs() < 1e-2), "{:?}", p.tensors[0].data);
    }

    #[test]
    fn sgd_minimizes_quadratic() {
        let mut p = quad_params();
        for _ in 0..200 {
            let g = Tensor::from_vec(&[2], p.tensors[0].data.iter().map(|&x| 2.0 * x).collect());
            sgd_step(&mut p, &[g], 0.1);
        }
        assert!(p.tensors[0].data.iter().all(|&x| x.abs() < 1e-3));
    }

    #[test]
    fn glorot_bounds() {
        let arch = ArchInfo {
            l: 1,
            dims: vec![4, 8],
            params: vec![("W1".into(), vec![4, 8]), ("b1".into(), vec![8])],
            head_params: vec![],
            layer_params: Default::default(),
        };
        let mut rng = Rng::new(0);
        let p = Params::init(&arch, &mut rng);
        let bound = (6.0f64 / 12.0).sqrt() as f32;
        assert!(p.get("W1").unwrap().data.iter().all(|&x| x.abs() <= bound));
        assert!(p.get("b1").unwrap().data.iter().all(|&x| x == 0.0));
        assert_eq!(p.num_scalars(), 40);
    }

    #[test]
    fn params_bytes_roundtrip_is_bitwise() {
        let mut p = Params {
            names: vec!["W1".into(), "b1".into()],
            tensors: vec![
                Tensor::from_vec(&[2, 3], vec![1.5, -0.0, f32::MIN_POSITIVE, -2.25, 1e-40, 0.0]),
                Tensor::from_vec(&[3], vec![0.0, -1.0, 3.75]),
            ],
        };
        // NaN payload must survive the trip bit-for-bit
        p.tensors[1].data[0] = f32::from_bits(0x7fc0_1234);
        let q = Params::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(p.names, q.names);
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a.shape, b.shape);
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                a.data.iter().map(|v| v.to_bits()).collect(),
                b.data.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(ab, bb, "bit pattern drifted through serialization");
        }
    }

    #[test]
    fn params_save_load_file_roundtrip() {
        let arch = ArchInfo {
            l: 1,
            dims: vec![4, 8],
            params: vec![("W1".into(), vec![4, 8]), ("b1".into(), vec![8])],
            head_params: vec![],
            layer_params: Default::default(),
        };
        let p = Params::init(&arch, &mut Rng::new(9));
        let path = std::env::temp_dir()
            .join(format!("lmc_params_unit_{}.bin", std::process::id()));
        p.save(&path).unwrap();
        let q = Params::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(p.names, q.names);
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn params_from_bytes_rejects_garbage() {
        assert!(Params::from_bytes(b"nope").is_err());
        let good = Params {
            names: vec!["w".into()],
            tensors: vec![Tensor::from_vec(&[2], vec![1.0, 2.0])],
        }
        .to_bytes();
        // truncation anywhere inside the payload is an error
        assert!(Params::from_bytes(&good[..good.len() - 1]).is_err());
        // trailing bytes are an error, not silently ignored
        let mut long = good.clone();
        long.push(0);
        assert!(Params::from_bytes(&long).is_err());
        // bad magic
        let mut bad = good;
        bad[0] ^= 0xFF;
        assert!(Params::from_bytes(&bad).is_err());
    }

    #[test]
    fn params_crc_detects_payload_corruption() {
        let good = Params {
            names: vec!["w".into()],
            tensors: vec![Tensor::from_vec(&[2], vec![1.0, 2.0])],
        }
        .to_bytes();
        // flip one bit inside a tensor's data: the trailer parses, the
        // checksum doesn't — a readable error, not garbage floats
        let mut flipped = good.clone();
        let mid = good.len() - 12;
        flipped[mid] ^= 0x01;
        let err = Params::from_bytes(&flipped).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn params_legacy_files_without_trailer_still_load() {
        let p = Params {
            names: vec!["w".into()],
            tensors: vec![Tensor::from_vec(&[2], vec![1.5, -2.5])],
        };
        let full = p.to_bytes();
        // a pre-checksum file is exactly the payload without the trailer
        let legacy = &full[..full.len() - 8];
        let q = Params::from_bytes(legacy).unwrap();
        assert_eq!(p.names, q.names);
        assert_eq!(p.tensors[0].data, q.tensors[0].data);
    }

    #[test]
    fn adam_state_roundtrip_and_shape_check() {
        let mut p = quad_params();
        let mut opt = Adam::new(&p, AdamConfig { lr: 0.1, ..Default::default() });
        for _ in 0..3 {
            let g = Tensor::from_vec(&[2], p.tensors[0].data.iter().map(|&x| 2.0 * x).collect());
            opt.step(&mut p, &[g]);
        }
        let (m, v, t) = opt.state();
        let (m, v) = (m.to_vec(), v.to_vec());
        let mut opt2 = Adam::new(&quad_params(), AdamConfig { lr: 0.1, ..Default::default() });
        opt2.restore_state(m.clone(), v.clone(), t).unwrap();
        let mut pa = p.clone();
        let mut pb = p.clone();
        let g = Tensor::from_vec(&[2], vec![0.5, -0.25]);
        opt.step(&mut pa, &[g.clone()]);
        opt2.step(&mut pb, &[g]);
        assert_eq!(pa.tensors[0].data, pb.tensors[0].data, "restored adam diverged");
        // wrong moment shapes must be refused
        assert!(opt2.restore_state(vec![vec![0.0; 3]], v, t).is_err());
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let g = vec![Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0])];
        assert!(grad_rel_err(&g, &g) < 1e-12);
        assert!((grad_l2(&g) - (14f64).sqrt()).abs() < 1e-9);
    }
}
