//! Model parameters (host-resident, canonical manifest order) and the Adam
//! optimizer (paper uses Adam across all experiments).

use crate::runtime::{ArchInfo, Tensor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Params {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl Params {
    /// Glorot-uniform matrices, zero vectors — same scheme as
    /// `python/compile/archs.py` so Rust-initialized training matches the
    /// Python-side tests' regime.
    pub fn init(arch: &ArchInfo, rng: &mut Rng) -> Params {
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for (name, shape) in &arch.params {
            let t = if shape.len() >= 2 {
                let fan_in = shape[0] as f64;
                let fan_out = shape[1] as f64;
                let scale = (6.0 / (fan_in + fan_out)).sqrt();
                let data: Vec<f32> = (0..shape.iter().product::<usize>())
                    .map(|_| rng.uniform(-scale, scale) as f32)
                    .collect();
                Tensor::from_vec(shape, data)
            } else {
                Tensor::zeros(shape)
            };
            names.push(name.clone());
            tensors.push(t);
        }
        Params { names, tensors }
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index_of(name).map(|i| &self.tensors[i])
    }

    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.elems()).sum()
    }

    /// Zero gradients with matching shapes.
    pub fn zeros_like(&self) -> Vec<Tensor> {
        self.tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect()
    }
}

/// Gradient norm helpers (Fig. 3 and convergence diagnostics).
pub fn grad_l2(grads: &[Tensor]) -> f64 {
    grads.iter().map(|g| g.norm().powi(2)).sum::<f64>().sqrt()
}

pub fn grad_rel_err(g: &[Tensor], reference: &[Tensor]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (a, b) in g.iter().zip(reference) {
        for (x, y) in a.data.iter().zip(&b.data) {
            let d = (*x - *y) as f64;
            num += d * d;
            den += (*y as f64) * (*y as f64);
        }
    }
    (num / den.max(1e-30)).sqrt()
}

#[derive(Clone, Debug)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

#[derive(Clone, Debug)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    pub fn new(params: &Params, cfg: AdamConfig) -> Adam {
        Adam {
            cfg,
            m: params.tensors.iter().map(|t| vec![0f32; t.elems()]).collect(),
            v: params.tensors.iter().map(|t| vec![0f32; t.elems()]).collect(),
            t: 0,
        }
    }

    pub fn step(&mut self, params: &mut Params, grads: &[Tensor]) {
        assert_eq!(grads.len(), params.tensors.len());
        self.t += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.cfg.lr;
        for (pi, g) in grads.iter().enumerate() {
            let p = &mut params.tensors[pi].data;
            let m = &mut self.m[pi];
            let v = &mut self.v[pi];
            for i in 0..p.len() {
                let mut gi = g.data[i] as f64;
                if self.cfg.weight_decay != 0.0 {
                    gi += self.cfg.weight_decay * p[i] as f64;
                }
                let mi = b1 * m[i] as f64 + (1.0 - b1) * gi;
                let vi = b2 * v[i] as f64 + (1.0 - b2) * gi * gi;
                m[i] = mi as f32;
                v[i] = vi as f32;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                p[i] -= (lr * mhat / (vhat.sqrt() + self.cfg.eps)) as f32;
            }
        }
    }
}

/// Plain SGD (used by the convergence-theory sanity tests; Theorems 2-3 are
/// stated for SGD).
pub fn sgd_step(params: &mut Params, grads: &[Tensor], lr: f64) {
    for (pi, g) in grads.iter().enumerate() {
        let p = &mut params.tensors[pi].data;
        for i in 0..p.len() {
            p[i] -= (lr * g.data[i] as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_params() -> Params {
        Params {
            names: vec!["w".into()],
            tensors: vec![Tensor::from_vec(&[2], vec![3.0, -2.0])],
        }
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let mut p = quad_params();
        let mut opt = Adam::new(&p, AdamConfig { lr: 0.1, ..Default::default() });
        for _ in 0..500 {
            let g = Tensor::from_vec(&[2], p.tensors[0].data.iter().map(|&x| 2.0 * x).collect());
            opt.step(&mut p, &[g]);
        }
        assert!(p.tensors[0].data.iter().all(|&x| x.abs() < 1e-2), "{:?}", p.tensors[0].data);
    }

    #[test]
    fn sgd_minimizes_quadratic() {
        let mut p = quad_params();
        for _ in 0..200 {
            let g = Tensor::from_vec(&[2], p.tensors[0].data.iter().map(|&x| 2.0 * x).collect());
            sgd_step(&mut p, &[g], 0.1);
        }
        assert!(p.tensors[0].data.iter().all(|&x| x.abs() < 1e-3));
    }

    #[test]
    fn glorot_bounds() {
        let arch = ArchInfo {
            l: 1,
            dims: vec![4, 8],
            params: vec![("W1".into(), vec![4, 8]), ("b1".into(), vec![8])],
            head_params: vec![],
            layer_params: Default::default(),
        };
        let mut rng = Rng::new(0);
        let p = Params::init(&arch, &mut rng);
        let bound = (6.0f64 / 12.0).sqrt() as f32;
        assert!(p.get("W1").unwrap().data.iter().all(|&x| x.abs() <= bound));
        assert!(p.get("b1").unwrap().data.iter().all(|&x| x == 0.0));
        assert_eq!(p.num_scalars(), 40);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let g = vec![Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0])];
        assert!(grad_rel_err(&g, &g) < 1e-12);
        assert!((grad_l2(&g) - (14f64).sqrt()).abs() < 1e-9);
    }
}
