//! The training coordinator: paper Algorithm 1 as an event loop over the
//! compiled train_step program, with per-method policies for adjacency,
//! compensation scalars, and history write-back.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::exact::{EvalResult, Evaluator};
use super::memory;
use super::methods::Method;
use super::metrics::{EpochRecord, RunMetrics};
use super::params::{Adam, AdamConfig, Params, sgd_step};
use crate::config::RunConfig;
use crate::graph::{load, Graph};
use crate::history::History;
use crate::partition::{partition, PartitionConfig};
use crate::runtime::{lit_f32, lit_i32, lit_scalar, to_vec_f32, ProgramSpec, Runtime, Tensor};
use crate::sampler::{beta_vector, build_subgraph, gather_rows, Batcher, Buckets, SubgraphBatch};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

pub struct Trainer {
    pub rt: Arc<Runtime>,
    pub cfg: RunConfig,
    pub graph: Arc<Graph>,
    pub clusters: Vec<Vec<u32>>,
    pub profile: String,
    pub params: Params,
    pub opt: Adam,
    pub history: History,
    pub batcher: Batcher,
    pub rng: Rng,
    pub n_train: usize,
    pub buckets: Buckets,
    pub metrics: RunMetrics,
    /// SPIDER state (Appendix F): previous params + running estimator.
    spider_prev: Option<(Params, Vec<Tensor>)>,
    step_count: u64,
}

/// One mini-batch step's host-visible results.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss_mean: f64,
    pub train_acc: f64,
    pub labeled: usize,
    pub active_bytes: usize,
    pub dropped_halo: usize,
}

impl Trainer {
    pub fn new(rt: Arc<Runtime>, cfg: RunConfig) -> Result<Trainer> {
        let raw = load(cfg.dataset, cfg.seed);
        let profile = cfg.dataset.profile().to_string();
        let arch = rt.manifest.arch(&profile, &cfg.arch)?.clone();
        let prof = rt
            .manifest
            .profiles
            .get(&profile)
            .ok_or_else(|| anyhow!("profile {profile} missing from manifest"))?
            .clone();
        // cross-check dataset dims vs compiled artifacts
        if raw.d_x != prof.d_x || raw.n_class != prof.n_class {
            return Err(anyhow!(
                "dataset {} dims (d_x={}, c={}) do not match manifest profile {} (d_x={}, c={})",
                cfg.dataset.name(),
                raw.d_x,
                raw.n_class,
                profile,
                prof.d_x,
                prof.n_class
            ));
        }

        // METIS-substitute partition, then relabel nodes cluster-contiguously
        let k = cfg.parts_or_default();
        let part = partition(&raw.csr, &PartitionConfig::new(k, cfg.seed ^ 0x9A27));
        let perm = part.contiguous_perm();
        let graph = Arc::new(raw.permute(&perm));
        // clusters in the permuted id space are contiguous ranges
        let mut clusters: Vec<Vec<u32>> = Vec::with_capacity(k);
        let mut base = 0u32;
        for c in part.clusters() {
            let len = c.len() as u32;
            clusters.push((base..base + len).collect());
            base += len;
        }
        clusters.retain(|c| !c.is_empty());

        let mut rng = Rng::new(cfg.seed ^ 0x7E57);
        let params = Params::init(&arch, &mut rng);
        let opt = Adam::new(
            &params,
            AdamConfig { lr: cfg.lr, weight_decay: cfg.weight_decay, ..Default::default() },
        );
        let hist_dims: Vec<usize> = arch.dims[1..arch.l].to_vec();
        let history = History::new(graph.n(), &hist_dims);
        let batcher = Batcher::new(
            clusters.clone(),
            cfg.clusters_per_batch,
            cfg.batcher_mode,
            cfg.seed ^ 0xBA7C,
        );
        let n_train = graph.split.iter().filter(|&&s| s == 0).count();
        let buckets = Buckets(prof.step_buckets.clone());
        Ok(Trainer {
            rt,
            cfg,
            graph,
            clusters,
            profile,
            params,
            opt,
            history,
            batcher,
            rng,
            n_train,
            buckets,
            metrics: RunMetrics::default(),
            spider_prev: None,
            step_count: 0,
        })
    }

    pub fn arch_l(&self) -> usize {
        self.rt.manifest.arch(&self.profile, &self.cfg.arch).unwrap().l
    }

    /// Assemble the positional input literals for the train_step program.
    fn assemble_inputs(
        &self,
        spec: &ProgramSpec,
        sb: &SubgraphBatch,
        params: &Params,
    ) -> Result<Vec<xla::Literal>> {
        let g = &self.graph;
        let (bb, bh) = (sb.bucket_b, sb.bucket_h);
        let method = self.cfg.method;
        let mut out = Vec::with_capacity(spec.inputs.len());
        for ts in &spec.inputs {
            let name = ts.name.as_str();
            let lit = if let Some(pi) = params.index_of(name) {
                params.tensors[pi].to_literal()?
            } else if name == "X_b" {
                lit_f32(&gather_rows(&g.features, g.d_x, &sb.batch, bb), &[bb, g.d_x])?
            } else if name == "X_h" {
                lit_f32(&gather_rows(&g.features, g.d_x, &sb.halo, bh), &[bh, g.d_x])?
            } else if name == "A_bb" {
                lit_f32(&sb.a_bb, &[bb, bb])?
            } else if name == "A_bh" {
                lit_f32(&sb.a_bh, &[bb, bh])?
            } else if name == "A_hh" {
                lit_f32(&sb.a_hh, &[bh, bh])?
            } else if let Some(l) = name.strip_prefix("histH") {
                let l: usize = l.parse()?;
                if method.uses_history() {
                    lit_f32(&self.history.gather_h(l, &sb.halo, bh), &[bh, ts.shape[1]])?
                } else {
                    lit_f32(&vec![0f32; bh * ts.shape[1]], &[bh, ts.shape[1]])?
                }
            } else if let Some(l) = name.strip_prefix("histV") {
                let l: usize = l.parse()?;
                if method.stores_aux() {
                    lit_f32(&self.history.gather_v(l, &sb.halo, bh), &[bh, ts.shape[1]])?
                } else {
                    lit_f32(&vec![0f32; bh * ts.shape[1]], &[bh, ts.shape[1]])?
                }
            } else if name == "y_b" {
                let y: Vec<i32> = padded_labels(g, &sb.batch, bb);
                lit_i32(&y, &[bb])?
            } else if name == "y_h" {
                let y: Vec<i32> = padded_labels(g, &sb.halo, bh);
                lit_i32(&y, &[bh])?
            } else if name == "mask_b" {
                lit_f32(&train_mask(g, &sb.batch, bb), &[bb])?
            } else if name == "mask_h" {
                lit_f32(&train_mask(g, &sb.halo, bh), &[bh])?
            } else if name == "beta" {
                let beta = if method.uses_beta() {
                    beta_vector(sb, self.cfg.beta.alpha, self.cfg.beta.score)
                } else {
                    vec![0f32; bh]
                };
                lit_f32(&beta, &[bh])?
            } else if name == "bwd_scale" {
                let bs = if self.cfg.force_bwd_off { 0.0 } else { method.bwd_scale() };
                lit_scalar(bs)
            } else if name == "vscale" {
                lit_scalar(1.0 / self.n_train.max(1) as f32)
            } else if name == "grad_scale" {
                lit_scalar(self.batcher.grad_scale())
            } else {
                return Err(anyhow!("unknown train_step input '{name}'"));
            };
            out.push(lit);
        }
        Ok(out)
    }

    /// Run one mini-batch step end-to-end (sample -> execute -> write-back ->
    /// optimize). Returns stats and the raw gradients (for diagnostics).
    pub fn step(&mut self, batch: &[u32]) -> Result<(StepStats, Vec<Tensor>)> {
        let (stats, grads) = self.compute_minibatch_grads(batch, None, true)?;
        let grads_t = grads;
        if self.cfg.method == Method::LmcSpider {
            self.spider_step(batch, &stats, &grads_t)?;
        } else {
            self.opt.step(&mut self.params, &grads_t);
        }
        self.step_count += 1;
        Ok((stats, grads_t))
    }

    /// Compute mini-batch gradients (optionally at explicitly-given params,
    /// for SPIDER), with or without history write-back.
    pub fn compute_minibatch_grads(
        &mut self,
        batch: &[u32],
        at_params: Option<&Params>,
        write_back: bool,
    ) -> Result<(StepStats, Vec<Tensor>)> {
        let sb = build_subgraph(
            &self.graph,
            batch,
            self.cfg.method.adjacency_policy(),
            &self.buckets,
            &mut self.rng,
        )?;
        self.grads_for_subgraph(&sb, at_params, write_back)
    }

    /// Execute the train_step for a pre-built subgraph (the pipeline path
    /// builds subgraphs on a prefetch thread; history gathers stay on this
    /// thread at execute time, so results are identical to the serial path).
    pub fn grads_for_subgraph(
        &mut self,
        sb: &SubgraphBatch,
        at_params: Option<&Params>,
        write_back: bool,
    ) -> Result<(StepStats, Vec<Tensor>)> {
        let method = self.cfg.method;
        let spec = self
            .rt
            .manifest
            .train_step(&self.profile, &self.cfg.arch, sb.bucket_b, sb.bucket_h)?
            .clone();
        let params_ref = at_params.unwrap_or(&self.params);
        let inputs = self.assemble_inputs(&spec, sb, params_ref)?;
        let active_bytes = memory::program_active_bytes(&spec);
        let outs = self.rt.execute(&spec.name, &inputs)?;

        let loss_sum = to_vec_f32(&outs[spec.output_index("loss_sum")?])?[0] as f64;
        let correct = to_vec_f32(&outs[spec.output_index("correct")?])?[0] as f64;
        let labeled = sb
            .batch
            .iter()
            .filter(|&&u| self.graph.split[u as usize] == 0)
            .count();

        // gradients in canonical order
        let mut grads = Vec::with_capacity(self.params.names.len());
        for (pi, name) in self.params.names.iter().enumerate() {
            let g = to_vec_f32(&outs[spec.output_index(&format!("g_{name}"))?])?;
            grads.push(Tensor::from_vec(&self.params.tensors[pi].shape, g));
        }

        if write_back {
            let l_total = self.arch_l();
            if method.uses_history() {
                for l in 1..l_total {
                    let new_h = to_vec_f32(&outs[spec.output_index(&format!("newH{l}"))?])?;
                    self.history.scatter_h(l, &sb.batch, &new_h);
                }
            }
            if method.stores_aux() {
                for l in 1..l_total {
                    let new_v = to_vec_f32(&outs[spec.output_index(&format!("newV{l}"))?])?;
                    self.history.scatter_v(l, &sb.batch, &new_v);
                }
            }
            if let Some(m) = method.halo_momentum() {
                for l in 1..l_total {
                    let fresh = to_vec_f32(&outs[spec.output_index(&format!("htilde{l}"))?])?;
                    self.history.momentum_h(l, &sb.halo, &fresh, m);
                }
            }
            if method.uses_history() {
                self.history.tick(&sb.batch);
            }
        }

        let stats = StepStats {
            loss_mean: loss_sum / labeled.max(1) as f64,
            train_acc: correct / labeled.max(1) as f64,
            labeled,
            active_bytes,
            dropped_halo: sb.dropped_halo,
        };
        Ok((stats, grads))
    }

    /// SPIDER update (Appendix F): periodic anchors via the exact oracle;
    /// in between, v_k = g(W_k; B_k) - g(W_{k-1}; B_k) + v_{k-1}.
    fn spider_step(&mut self, batch: &[u32], _stats: &StepStats, grads_now: &[Tensor]) -> Result<()> {
        let anchor_due = self.step_count % self.cfg.spider_period as u64 == 0;
        let estimator: Vec<Tensor> = if anchor_due || self.spider_prev.is_none() {
            let eval = Evaluator::new(&self.rt, &self.graph, &self.profile, &self.cfg.arch)?;
            eval.full_grad(&self.graph, &self.params)?.grads
        } else {
            let (prev_params, prev_est) = self.spider_prev.take().unwrap();
            let (_, grads_prev) = self.compute_minibatch_grads(batch, Some(&prev_params), false)?;
            grads_now
                .iter()
                .zip(&grads_prev)
                .zip(&prev_est)
                .map(|((gn, gp), pe)| {
                    let data: Vec<f32> = gn
                        .data
                        .iter()
                        .zip(&gp.data)
                        .zip(&pe.data)
                        .map(|((a, b), c)| a - b + c)
                        .collect();
                    Tensor::from_vec(&gn.shape, data)
                })
                .collect()
        };
        let prev_params = self.params.clone();
        sgd_step(&mut self.params, &estimator, self.cfg.lr);
        self.spider_prev = Some((prev_params, estimator));
        Ok(())
    }

    /// One full training epoch; returns aggregate stats.
    ///
    /// With `cfg.pipeline`, subgraph densification for step i+1 overlaps the
    /// PJRT execution of step i on a prefetch thread (GAS §E.2-style
    /// concurrent mini-batch execution). Only graph *structure* is
    /// prefetched; history gathers stay on this thread at execute time, so
    /// results are bit-identical to the serial path.
    pub fn train_epoch(&mut self) -> Result<StepStats> {
        if self.cfg.method == Method::Gd {
            return self.gd_epoch();
        }
        let batches = self.batcher.epoch_batches();
        let mut agg = EpochAgg::default();
        if self.cfg.pipeline && batches.len() > 1 {
            let policy = self.cfg.method.adjacency_policy();
            let graph = self.graph.clone();
            let buckets = self.buckets.clone();
            // per-batch deterministic rng streams
            let mut rngs: Vec<Rng> =
                (0..batches.len()).map(|i| self.rng.fork(i as u64)).collect();
            let batches_bg = batches.clone();
            let (tx, rx) = std::sync::mpsc::sync_channel::<Result<SubgraphBatch>>(2);
            let handle = std::thread::spawn(move || {
                for (i, b) in batches_bg.iter().enumerate() {
                    let sb = build_subgraph(&graph, b, policy, &buckets, &mut rngs[i]);
                    if tx.send(sb).is_err() {
                        break;
                    }
                }
            });
            // densification of batches i+1, i+2 overlaps execution of batch i
            // (channel capacity 2 bounds prefetch memory)
            for _ in 0..batches.len() {
                let sb = rx
                    .recv()
                    .map_err(|e| anyhow!("prefetch thread died: {e}"))??;
                let (s, grads) = self.grads_for_subgraph(&sb, None, true)?;
                self.opt.step(&mut self.params, &grads);
                self.step_count += 1;
                agg.add(&s);
            }
            handle.join().ok();
        } else {
            for b in &batches {
                let (s, _) = self.step(b)?;
                agg.add(&s);
            }
        }
        Ok(agg.finish())
    }

    fn gd_epoch(&mut self) -> Result<StepStats> {
        let eval = Evaluator::new(&self.rt, &self.graph, &self.profile, &self.cfg.arch)?;
        let oracle = eval.full_grad(&self.graph, &self.params)?;
        let bytes = memory::gd_active_bytes(
            self.graph.n(),
            &self.rt.manifest.arch(&self.profile, &self.cfg.arch)?.dims,
            self.graph.d_x,
            self.graph.csr.neighbors.len(),
        );
        self.opt.step(&mut self.params, &oracle.grads);
        self.step_count += 1;
        Ok(StepStats {
            loss_mean: oracle.train_loss,
            train_acc: 0.0,
            labeled: self.n_train,
            active_bytes: bytes,
            dropped_halo: 0,
        })
    }

    pub fn evaluate(&self) -> Result<EvalResult> {
        let eval = Evaluator::new(&self.rt, &self.graph, &self.profile, &self.cfg.arch)?;
        eval.evaluate(&self.graph, &self.params)
    }

    /// Full training run with periodic evaluation; honors `target_acc` early
    /// stop (Table 2 protocol). Returns the metrics trace.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let sw = Stopwatch::start();
        for epoch in 1..=self.cfg.epochs {
            let es = Stopwatch::start();
            let stats = self.train_epoch()?;
            let epoch_secs = es.secs();
            let do_eval = epoch % self.cfg.eval_every.max(1) == 0 || epoch == self.cfg.epochs;
            let eval = if do_eval { Some(self.evaluate()?) } else { None };
            let rec = EpochRecord {
                epoch,
                wall_secs: sw.secs(),
                epoch_secs,
                train_loss: stats.loss_mean,
                train_acc: stats.train_acc,
                val_acc: eval.as_ref().map(|e| e.val_acc).unwrap_or(f64::NAN),
                test_acc: eval.as_ref().map(|e| e.test_acc).unwrap_or(f64::NAN),
                active_bytes: stats.active_bytes,
                staleness: self.history.mean_staleness(),
            };
            if self.cfg.verbose {
                println!(
                    "epoch {:>4}  loss {:.4}  val {:.4}  test {:.4}  ({:.2}s)",
                    epoch,
                    rec.train_loss,
                    rec.val_acc,
                    rec.test_acc,
                    rec.wall_secs
                );
            }
            self.metrics.push(rec);
            if let (Some(target), Some(e)) = (self.cfg.target_acc, eval.as_ref()) {
                if e.test_acc >= target {
                    self.metrics.reached_target = Some((epoch, sw.secs()));
                    break;
                }
            }
        }
        Ok(self.metrics.clone())
    }
}

fn padded_labels(g: &Graph, idx: &[u32], rows: usize) -> Vec<i32> {
    let mut y = vec![0i32; rows];
    for (i, &u) in idx.iter().enumerate() {
        y[i] = g.labels[u as usize] as i32;
    }
    y
}

fn train_mask(g: &Graph, idx: &[u32], rows: usize) -> Vec<f32> {
    let mut m = vec![0f32; rows];
    for (i, &u) in idx.iter().enumerate() {
        if g.split[u as usize] == 0 {
            m[i] = 1.0;
        }
    }
    m
}

#[derive(Default)]
struct EpochAgg {
    loss_w: f64,
    acc_w: f64,
    labeled: usize,
    peak_bytes: usize,
    dropped: usize,
}

impl EpochAgg {
    fn add(&mut self, s: &StepStats) {
        self.loss_w += s.loss_mean * s.labeled as f64;
        self.acc_w += s.train_acc * s.labeled as f64;
        self.labeled += s.labeled;
        self.peak_bytes = self.peak_bytes.max(s.active_bytes);
        self.dropped += s.dropped_halo;
    }

    fn finish(&self) -> StepStats {
        StepStats {
            loss_mean: self.loss_w / self.labeled.max(1) as f64,
            train_acc: self.acc_w / self.labeled.max(1) as f64,
            labeled: self.labeled,
            active_bytes: self.peak_bytes,
            dropped_halo: self.dropped,
        }
    }
}
