//! The training coordinator: paper Algorithm 1 as an event loop over a
//! pluggable [`Executor`] backend, with per-method policies for adjacency,
//! compensation scalars, and history write-back.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::exact::EvalResult;
use super::memory;
use super::methods::Method;
use super::metrics::{EpochRecord, RunMetrics};
use super::params::{sgd_step, Adam, AdamConfig, Params};
use crate::backend::{Executor, ModelSpec, StepInputs, StepWorkspace, TopStepInputs};
use crate::checkpoint;
use crate::compensation::{self, Compensation};
use crate::config::RunConfig;
use crate::graph::{load, Graph};
use crate::history::History;
use crate::partition::{partition, PartitionConfig};
use crate::runtime::Tensor;
use crate::sampler::{
    beta_vector, beta_vector_into, build_subgraph, Batcher, Buckets, HaloSampler, SubgraphBatch,
    SubgraphCache,
};
use crate::util::failpoint;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

pub struct Trainer {
    pub exec: Arc<dyn Executor>,
    pub cfg: RunConfig,
    pub graph: Arc<Graph>,
    pub clusters: Vec<Vec<u32>>,
    /// Resolved (profile, arch) the executor runs.
    pub model: ModelSpec,
    pub params: Params,
    pub opt: Adam,
    pub history: History,
    /// The method's compensation policy: per-step flags (what to gather,
    /// what to write back) plus any learned state (TOP transforms). The
    /// history *store* stays a trainer field — sharded workers exchange
    /// boundary rows through it — the policy decides how it is used.
    pub comp: Box<dyn Compensation>,
    pub batcher: Batcher,
    pub rng: Rng,
    pub n_train: usize,
    pub buckets: Buckets,
    pub metrics: RunMetrics,
    /// Reusable step scratch: every O(m · d) layer buffer of the native
    /// step comes from (and returns to) this pool, so steady-state steps
    /// allocate nothing. Behind a `Mutex` so it can be threaded through
    /// the shared-reference `StepInputs` without changing the `Executor`
    /// trait; the trainer is single-threaded, so the lock is uncontended.
    pub ws: Mutex<StepWorkspace>,
    /// Set false to restore allocate-per-step behaviour (baseline benches).
    pub reuse_workspace: bool,
    /// Fixed-mode subgraph blocks, built once and reused across epochs
    /// (enabled only when the schedule is deterministic; see
    /// [`SubgraphCache`] for the applicability matrix).
    pub sg_cache: SubgraphCache,
    /// The cluster-contiguous relabeling applied to the input graph:
    /// `orig_of[internal] = pre-permutation id`. The sharded coordinator
    /// composes this with its shard-local -> global map to route boundary
    /// history rows between workers.
    pub orig_of: Vec<u32>,
    /// SPIDER state (Appendix F): previous params + running estimator.
    spider_prev: Option<(Params, Vec<Tensor>)>,
    step_count: u64,
    /// Completed-epoch counter; [`Trainer::run`] continues after it, so a
    /// checkpoint-restored trainer resumes at the right epoch.
    epochs_done: usize,
}

/// One mini-batch step's host-visible results.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss_mean: f64,
    pub train_acc: f64,
    pub labeled: usize,
    pub active_bytes: usize,
    pub dropped_halo: usize,
}

impl Trainer {
    pub fn new(exec: Arc<dyn Executor>, cfg: RunConfig) -> Result<Trainer> {
        let raw = load(cfg.dataset, cfg.seed);
        Trainer::from_parent_graph(exec, cfg, raw)
    }

    /// Build a trainer over an explicitly-given graph — the reusable
    /// worker-state constructor. [`Trainer::new`] routes the loaded dataset
    /// through here; `coordinator::sharded` passes shard-local graphs, so a
    /// sharded worker is the *same* training core as the serial path rather
    /// than a fork of it (and `shards = 1` is bit-identical to `new`).
    pub fn from_parent_graph(
        exec: Arc<dyn Executor>,
        cfg: RunConfig,
        raw: Graph,
    ) -> Result<Trainer> {
        let profile = cfg.dataset.profile().to_string();
        let arch = exec.resolve_arch(&profile, &cfg.arch)?;
        let prof = exec.resolve_profile(&profile)?;
        // cross-check dataset dims vs the executor's model metadata
        if raw.d_x != prof.d_x || raw.n_class != prof.n_class {
            return Err(anyhow!(
                "dataset {} dims (d_x={}, c={}) do not match profile {} (d_x={}, c={})",
                cfg.dataset.name(),
                raw.d_x,
                raw.n_class,
                profile,
                prof.d_x,
                prof.n_class
            ));
        }

        // METIS-substitute partition, then relabel nodes cluster-contiguously
        let k = cfg.parts_or_default();
        let part = partition(&raw.csr, &PartitionConfig::new(k, cfg.seed ^ 0x9A27));
        let perm = part.contiguous_perm();
        let graph = Arc::new(raw.permute(&perm));
        // clusters in the permuted id space are contiguous ranges
        let mut clusters: Vec<Vec<u32>> = Vec::with_capacity(k);
        let mut base = 0u32;
        for c in part.clusters() {
            let len = c.len() as u32;
            clusters.push((base..base + len).collect());
            base += len;
        }
        clusters.retain(|c| !c.is_empty());

        let mut rng = Rng::new(cfg.seed ^ 0x7E57);
        let params = Params::init(&arch, &mut rng);
        let opt = Adam::new(
            &params,
            AdamConfig { lr: cfg.lr, weight_decay: cfg.weight_decay, ..Default::default() },
        );
        let hist_dims: Vec<usize> = arch.dims[1..arch.l].to_vec();
        let history = History::with_dtype(graph.n(), &hist_dims, cfg.history_dtype);
        let batcher = Batcher::new(
            clusters.clone(),
            cfg.clusters_per_batch,
            cfg.batcher_mode,
            cfg.seed ^ 0xBA7C,
        );
        let n_train = graph.split.iter().filter(|&&s| s == 0).count();
        let buckets = exec.buckets(&profile)?;
        let comp = compensation::for_training(&cfg, &arch)?;
        let model = ModelSpec { profile, arch_name: cfg.arch.clone(), arch };
        // Fixed groups + unbounded buckets => subgraph construction is a
        // deterministic function of the (identical-every-epoch) batch, so
        // blocks can be built once and reused (see SubgraphCache docs).
        let cache_ok = SubgraphCache::applicable(
            cfg.subgraph_cache,
            batcher.mode(),
            &buckets,
            &cfg.halo_sampler(),
        );
        Ok(Trainer {
            exec,
            cfg,
            graph,
            clusters,
            model,
            params,
            opt,
            history,
            comp,
            batcher,
            rng,
            n_train,
            buckets,
            metrics: RunMetrics::default(),
            ws: Mutex::new(StepWorkspace::new()),
            reuse_workspace: true,
            sg_cache: SubgraphCache::new(cache_ok),
            orig_of: perm,
            spider_prev: None,
            step_count: 0,
            epochs_done: 0,
        })
    }

    /// Rebuild a trainer from the latest checkpoint in `dir`, verifying
    /// the config fingerprint. The resumed run continues at
    /// `checkpoint epoch + 1`; with an f32 history it is bit-identical to
    /// the uninterrupted run (quantized stores round-trip their raw
    /// words, so they too resume from exactly the bits they saved).
    pub fn resume(
        exec: Arc<dyn Executor>,
        cfg: RunConfig,
        dir: &std::path::Path,
    ) -> Result<Trainer> {
        let mut t = Trainer::new(exec, cfg)?;
        let loaded = checkpoint::load(dir, &checkpoint::config_fingerprint(&t.cfg), 1)?;
        loaded.states[0].restore_into(&mut t)?;
        t.epochs_done = loaded.epoch;
        t.metrics = loaded.run.metrics;
        Ok(t)
    }

    pub fn arch_l(&self) -> usize {
        self.model.arch.l
    }

    /// Swap the training method — and with it the compensation policy —
    /// in place. The controlled-comparison hook for gradient-error
    /// measurement: same parameters, same histories, same batches, only
    /// the policy differs. Learned compensation state (TOP transforms)
    /// is freshly initialized, not carried over.
    pub fn set_method(&mut self, method: Method) -> Result<()> {
        self.cfg.method = method;
        self.comp = compensation::for_training(&self.cfg, &self.model.arch)?;
        Ok(())
    }

    /// Optimizer/SPIDER step counter (checkpointed).
    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    pub(crate) fn set_step_count(&mut self, c: u64) {
        self.step_count = c;
    }

    /// Completed epochs ([`Trainer::run`] continues after this count).
    pub fn epochs_done(&self) -> usize {
        self.epochs_done
    }

    pub(crate) fn set_epochs_done(&mut self, e: usize) {
        self.epochs_done = e;
    }

    pub(crate) fn spider_state(&self) -> Option<&(Params, Vec<Tensor>)> {
        self.spider_prev.as_ref()
    }

    pub(crate) fn set_spider_state(&mut self, s: Option<(Params, Vec<Tensor>)>) {
        self.spider_prev = s;
    }

    /// Replace caches and scratch that a checkpoint restore invalidates —
    /// or that a caught worker panic may have left poisoned (the
    /// workspace mutex) or half-filled (the subgraph cache). Both rebuild
    /// lazily and deterministically without consuming trainer RNG, so
    /// replacing them never changes results.
    pub(crate) fn reset_transient_state(&mut self) {
        self.ws = Mutex::new(StepWorkspace::new());
        let cache_ok = SubgraphCache::applicable(
            self.cfg.subgraph_cache,
            self.batcher.mode(),
            &self.buckets,
            &self.cfg.halo_sampler(),
        );
        self.sg_cache = SubgraphCache::new(cache_ok);
    }

    /// The configured halo subsampling policy (threaded into every
    /// [`build_subgraph`] call this trainer makes).
    pub fn halo_sampler(&self) -> HaloSampler {
        self.cfg.halo_sampler()
    }

    /// Run one mini-batch step end-to-end (sample -> execute -> write-back ->
    /// optimize). Returns stats and the raw gradients (for diagnostics).
    ///
    /// Standalone-step entry (benches, ad-hoc probes): applies the constant
    /// Eq. 14-15 factor b/c — outside an epoch loop there is no step index
    /// to derive the ragged-chunk correction from. The epoch loop goes
    /// through [`Trainer::step_on`] with [`Batcher::grad_scale_at`].
    pub fn step(&mut self, batch: &[u32]) -> Result<(StepStats, Vec<Tensor>)> {
        let sb = build_subgraph(
            &self.graph,
            batch,
            self.cfg.method.adjacency_policy(),
            &self.buckets,
            &self.cfg.halo_sampler(),
            &mut self.rng,
        )?;
        self.step_on(&sb, self.batcher.grad_scale())
    }

    /// Step on a pre-built subgraph: gradients at the given Eq. 14-15
    /// scale, then the method's optimizer update (Adam, or the SPIDER
    /// estimator for LMC-SPIDER).
    fn step_on(&mut self, sb: &SubgraphBatch, grad_scale: f32) -> Result<(StepStats, Vec<Tensor>)> {
        failpoint::fire("trainer.step")?;
        let (stats, grads) = self.grads_for_subgraph(sb, None, true, grad_scale)?;
        if self.cfg.method == Method::LmcSpider {
            self.spider_step(sb, &grads, grad_scale)?;
        } else {
            self.opt.step(&mut self.params, &grads);
        }
        self.step_count += 1;
        Ok((stats, grads))
    }

    /// Compute mini-batch gradients (optionally at explicitly-given params,
    /// for SPIDER), with or without history write-back, at the constant
    /// Eq. 14-15 scale. Step-indexed callers (the gradient-error probes)
    /// use [`Trainer::compute_minibatch_grads_at`].
    pub fn compute_minibatch_grads(
        &mut self,
        batch: &[u32],
        at_params: Option<&Params>,
        write_back: bool,
    ) -> Result<(StepStats, Vec<Tensor>)> {
        let gs = self.batcher.grad_scale();
        self.minibatch_grads_scaled(batch, at_params, write_back, gs)
    }

    /// [`Trainer::compute_minibatch_grads`] with the per-step Eq. 14-15
    /// factor for epoch step `step` — b/|chunk| instead of the constant
    /// b/c, correcting the ragged last stochastic chunk.
    pub fn compute_minibatch_grads_at(
        &mut self,
        step: usize,
        batch: &[u32],
        at_params: Option<&Params>,
        write_back: bool,
    ) -> Result<(StepStats, Vec<Tensor>)> {
        let gs = self.batcher.grad_scale_at(step);
        self.minibatch_grads_scaled(batch, at_params, write_back, gs)
    }

    fn minibatch_grads_scaled(
        &mut self,
        batch: &[u32],
        at_params: Option<&Params>,
        write_back: bool,
        grad_scale: f32,
    ) -> Result<(StepStats, Vec<Tensor>)> {
        let sb = build_subgraph(
            &self.graph,
            batch,
            self.cfg.method.adjacency_policy(),
            &self.buckets,
            &self.cfg.halo_sampler(),
            &mut self.rng,
        )?;
        self.grads_for_subgraph(&sb, at_params, write_back, grad_scale)
    }

    /// Execute the fused train step for a pre-built subgraph through the
    /// backend (the pipeline path builds subgraphs on a prefetch thread;
    /// history gathers stay on this thread at execute time, so results are
    /// identical to the serial path).
    pub fn grads_for_subgraph(
        &mut self,
        sb: &SubgraphBatch,
        at_params: Option<&Params>,
        write_back: bool,
        grad_scale: f32,
    ) -> Result<(StepStats, Vec<Tensor>)> {
        let spec = self.comp.spec();
        let l_total = self.model.arch.l;
        let dims = self.model.arch.dims.clone();

        // History/beta gather buffers: from the workspace pool (recycled
        // after write-back) on the reuse path, plain allocations otherwise.
        // Policies that skip history/beta get zero placeholder buffers.
        let (beta, hist_h, hist_v) = if self.reuse_workspace {
            let mut ws = self.ws.lock().unwrap();
            let mut beta = ws.grab(sb.bucket_h);
            if spec.uses_beta {
                beta_vector_into(sb, self.cfg.beta.alpha, self.cfg.beta.score, &mut beta);
            }
            let mut hist_h: Vec<Vec<f32>> = Vec::with_capacity(l_total.saturating_sub(1));
            for l in 1..l_total {
                let mut buf = ws.grab(sb.bucket_h * dims[l]);
                if spec.uses_history {
                    self.history.gather_h_into(l, &sb.halo, &mut buf);
                }
                hist_h.push(buf);
            }
            let mut hist_v: Vec<Vec<f32>> = Vec::with_capacity(l_total.saturating_sub(1));
            for l in 1..l_total {
                let mut buf = ws.grab(sb.bucket_h * dims[l]);
                if spec.stores_aux {
                    self.history.gather_v_into(l, &sb.halo, &mut buf);
                }
                hist_v.push(buf);
            }
            (beta, hist_h, hist_v)
        } else {
            let beta = if spec.uses_beta {
                beta_vector(sb, self.cfg.beta.alpha, self.cfg.beta.score)
            } else {
                vec![0f32; sb.bucket_h]
            };
            let hist_h: Vec<Vec<f32>> = (1..l_total)
                .map(|l| {
                    if spec.uses_history {
                        self.history.gather_h(l, &sb.halo, sb.bucket_h)
                    } else {
                        vec![0f32; sb.bucket_h * dims[l]]
                    }
                })
                .collect();
            let hist_v: Vec<Vec<f32>> = (1..l_total)
                .map(|l| {
                    if spec.stores_aux {
                        self.history.gather_v(l, &sb.halo, sb.bucket_h)
                    } else {
                        vec![0f32; sb.bucket_h * dims[l]]
                    }
                })
                .collect();
            (beta, hist_h, hist_v)
        };

        let inputs = StepInputs {
            graph: self.graph.as_ref(),
            sb,
            model: &self.model,
            params: at_params.unwrap_or(&self.params),
            hist_h,
            hist_v,
            beta,
            bwd_scale: if self.cfg.force_bwd_off { 0.0 } else { spec.bwd_scale },
            vscale: 1.0 / self.n_train.max(1) as f32,
            grad_scale,
            top: self
                .comp
                .transforms()
                .map(|(fwd, bwd)| TopStepInputs { fwd, bwd, fit: write_back }),
            ws: if self.reuse_workspace { Some(&self.ws) } else { None },
        };
        let mut outs = self.exec.forward_backward(&inputs)?;

        if write_back {
            if spec.uses_history {
                for l in 1..l_total {
                    self.history.scatter_h(l, &sb.batch, &outs.new_h[l - 1]);
                }
            }
            if spec.stores_aux {
                for l in 1..l_total {
                    self.history.scatter_v(l, &sb.batch, &outs.new_v[l - 1]);
                }
            }
            if let Some(m) = spec.halo_momentum {
                for l in 1..l_total {
                    self.history.momentum_h(l, &sb.halo, &outs.htilde[l - 1], m);
                }
            }
            if spec.uses_history {
                self.history.tick(&sb.batch);
            }
        }

        // Recycle the gather buffers and the escaped step-output buffers
        // back into the pool: the next step's grabs then hit warm buffers,
        // closing the zero-allocation loop.
        if self.reuse_workspace {
            let mut ws = self.ws.lock().unwrap();
            let StepInputs { hist_h, hist_v, beta, .. } = inputs;
            ws.put(beta);
            ws.put_all(hist_h);
            ws.put_all(hist_v);
            ws.put_all(outs.new_h.drain(..));
            ws.put_all(outs.new_v.drain(..));
            ws.put_all(outs.htilde.drain(..));
        }

        // TOP transform update (the step's fit gradients, applied with the
        // policy's own relaxation rate) — after the StepInputs borrow of
        // the transforms has ended.
        if write_back {
            if let Some(f) = outs.top_fit.take() {
                self.comp.fit(&f);
            }
        }

        let labeled = sb
            .batch
            .iter()
            .filter(|&&u| self.graph.split[u as usize] == 0)
            .count();
        let stats = StepStats {
            loss_mean: outs.loss_sum / labeled.max(1) as f64,
            train_acc: outs.correct / labeled.max(1) as f64,
            labeled,
            active_bytes: outs.active_bytes,
            dropped_halo: sb.dropped_halo,
        };
        Ok((stats, outs.grads))
    }

    /// SPIDER update (Appendix F): periodic anchors via the exact oracle;
    /// in between, v_k = g(W_k; B_k) - g(W_{k-1}; B_k) + v_{k-1}, evaluated
    /// on the *same* sampled subgraph B_k at both parameter points.
    fn spider_step(
        &mut self,
        sb: &SubgraphBatch,
        grads_now: &[Tensor],
        grad_scale: f32,
    ) -> Result<()> {
        let anchor_due = self.step_count % self.cfg.spider_period as u64 == 0;
        let estimator: Vec<Tensor> = if anchor_due || self.spider_prev.is_none() {
            self.exec.full_grad(self.graph.as_ref(), &self.params, &self.model)?.grads
        } else {
            let (prev_params, prev_est) = self.spider_prev.take().unwrap();
            // same subgraph, same scale as the step's own gradients — the
            // estimator's difference term must be computed at one weight
            let (_, grads_prev) =
                self.grads_for_subgraph(sb, Some(&prev_params), false, grad_scale)?;
            grads_now
                .iter()
                .zip(&grads_prev)
                .zip(&prev_est)
                .map(|((gn, gp), pe)| {
                    let data: Vec<f32> = gn
                        .data
                        .iter()
                        .zip(&gp.data)
                        .zip(&pe.data)
                        .map(|((a, b), c)| a - b + c)
                        .collect();
                    Tensor::from_vec(&gn.shape, data)
                })
                .collect()
        };
        let prev_params = self.params.clone();
        sgd_step(&mut self.params, &estimator, self.cfg.lr);
        self.spider_prev = Some((prev_params, estimator));
        Ok(())
    }

    /// One full training epoch; returns aggregate stats.
    ///
    /// With `cfg.pipeline`, subgraph construction for step i+1 overlaps the
    /// backend execution of step i on a prefetch thread (GAS §E.2-style
    /// concurrent mini-batch execution). Each batch draws from its own
    /// forked RNG stream — derived identically in both modes — so the
    /// pipelined and serial paths sample the same halo subsets and produce
    /// identical results; prefetch-thread panics surface as errors.
    ///
    /// In `Fixed` batcher mode with unbounded buckets the per-group blocks
    /// are deterministic and identical every epoch, so they are built once
    /// (on whichever path runs the first epoch), stored in `sg_cache`, and
    /// steady-state epochs skip subgraph construction — and the prefetch
    /// thread — entirely. History gathers stay per-step, so cached and
    /// rebuilt paths produce bit-identical results
    /// (`fixed_mode_subgraph_cache_matches_uncached`).
    pub fn train_epoch(&mut self) -> Result<StepStats> {
        if self.cfg.method == Method::Gd {
            return self.gd_epoch();
        }
        let batches = self.batcher.epoch_batches();
        let mut agg = EpochAgg::default();
        let policy = self.cfg.method.adjacency_policy();
        // per-batch deterministic rng streams, forked regardless of mode so
        // `pipeline = true/false` and cache on/off leave self.rng in the
        // same state (unbounded-bucket builds never consume from them)
        let mut rngs: Vec<Rng> =
            (0..batches.len()).map(|i| self.rng.fork(i as u64)).collect();
        if self.sg_cache.is_complete(batches.len()) {
            // steady-state Fixed mode: every group's blocks are cached
            for (i, b) in batches.iter().enumerate() {
                let sb = self
                    .sg_cache
                    .get(i, b)
                    .ok_or_else(|| anyhow!("subgraph cache invalidated mid-run (step {i})"))?;
                let gs = self.batcher.grad_scale_at(i);
                let (s, _) = self.step_on(sb.as_ref(), gs)?;
                agg.add(&s);
            }
            return Ok(agg.finish());
        }
        if self.cfg.pipeline && batches.len() > 1 {
            let graph = self.graph.clone();
            let buckets = self.buckets.clone();
            let sampler = self.cfg.halo_sampler();
            let batches_bg = batches.clone();
            let (tx, rx) = std::sync::mpsc::sync_channel::<Result<SubgraphBatch>>(2);
            let mut handle = Some(std::thread::spawn(move || {
                for (i, b) in batches_bg.iter().enumerate() {
                    let sb = build_subgraph(&graph, b, policy, &buckets, &sampler, &mut rngs[i]);
                    if tx.send(sb).is_err() {
                        break;
                    }
                }
            }));
            // construction of batches i+1, i+2 overlaps execution of batch i
            // (channel capacity 2 bounds prefetch memory)
            for i in 0..batches.len() {
                let sb = match rx.recv() {
                    Ok(built) => Arc::new(built?),
                    Err(_) => {
                        // channel closed early — surface the prefetch panic
                        join_prefetch(handle.take())?;
                        return Err(anyhow!(
                            "prefetch channel closed before all batches arrived"
                        ));
                    }
                };
                if self.sg_cache.enabled() {
                    self.sg_cache.insert(i, sb.clone());
                }
                let gs = self.batcher.grad_scale_at(i);
                let (s, _) = self.step_on(sb.as_ref(), gs)?;
                agg.add(&s);
            }
            join_prefetch(handle.take())?;
        } else {
            for (i, b) in batches.iter().enumerate() {
                let sb = match self.sg_cache.get(i, b) {
                    Some(cached) => cached,
                    None => {
                        let built = Arc::new(build_subgraph(
                            &self.graph,
                            b,
                            policy,
                            &self.buckets,
                            &self.cfg.halo_sampler(),
                            &mut rngs[i],
                        )?);
                        self.sg_cache.insert(i, built.clone());
                        built
                    }
                };
                let gs = self.batcher.grad_scale_at(i);
                let (s, _) = self.step_on(sb.as_ref(), gs)?;
                agg.add(&s);
            }
        }
        self.sg_cache.seal(batches.len());
        Ok(agg.finish())
    }

    fn gd_epoch(&mut self) -> Result<StepStats> {
        let oracle = self.exec.full_grad(self.graph.as_ref(), &self.params, &self.model)?;
        let bytes = memory::gd_active_bytes(
            self.graph.n(),
            &self.model.arch.dims,
            self.graph.d_x,
            self.graph.csr.neighbors.len(),
        );
        self.opt.step(&mut self.params, &oracle.grads);
        self.step_count += 1;
        Ok(StepStats {
            loss_mean: oracle.train_loss,
            train_acc: 0.0,
            labeled: self.n_train,
            active_bytes: bytes,
            dropped_halo: 0,
        })
    }

    pub fn evaluate(&self) -> Result<EvalResult> {
        self.exec.evaluate(self.graph.as_ref(), &self.params, &self.model)
    }

    /// Full training run with periodic evaluation; honors `target_acc` early
    /// stop (Table 2 protocol). Returns the metrics trace.
    ///
    /// Starts after [`Trainer::epochs_done`] (0 on a fresh trainer, the
    /// checkpoint epoch after [`Trainer::resume`]) and writes an
    /// epoch-boundary checkpoint whenever `checkpoint_dir` is set and the
    /// epoch lands on the `checkpoint_every` grid.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let sw = Stopwatch::start();
        for epoch in (self.epochs_done + 1)..=self.cfg.epochs {
            let es = Stopwatch::start();
            let stats = self.train_epoch()?;
            self.epochs_done = epoch;
            let epoch_secs = es.secs();
            let do_eval = epoch % self.cfg.eval_every.max(1) == 0 || epoch == self.cfg.epochs;
            let eval = if do_eval { Some(self.evaluate()?) } else { None };
            let staleness = self.history.mean_staleness();
            let obs = EpochObs {
                epoch,
                epoch_secs,
                stats: &stats,
                eval: eval.as_ref(),
                staleness,
                shards: None,
            };
            if record_epoch(&mut self.metrics, &self.cfg, &sw, obs) {
                break;
            }
            self.maybe_checkpoint(epoch)?;
        }
        Ok(self.metrics.clone())
    }

    /// Write an epoch-boundary checkpoint when one is due.
    fn maybe_checkpoint(&self, epoch: usize) -> Result<()> {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return Ok(());
        };
        if !checkpoint::due(epoch, self.cfg.checkpoint_every, self.cfg.epochs) {
            return Ok(());
        }
        let state = checkpoint::TrainerState::capture(self);
        let run = checkpoint::RunState { epochs_done: epoch, metrics: self.metrics.clone() };
        checkpoint::save(
            std::path::Path::new(dir),
            &checkpoint::config_fingerprint(&self.cfg),
            epoch,
            std::slice::from_ref(&state),
            &run,
        )
    }
}

/// One epoch's observations, shared by the serial and sharded run loops.
pub(crate) struct EpochObs<'a> {
    pub epoch: usize,
    pub epoch_secs: f64,
    pub stats: &'a StepStats,
    pub eval: Option<&'a EvalResult>,
    pub staleness: f64,
    /// `Some(worker count)` on the sharded path (annotates the verbose line).
    pub shards: Option<usize>,
}

/// Shared per-epoch bookkeeping for [`Trainer::run`] and
/// `ShardedTrainer::run`: assemble and push the [`EpochRecord`], emit the
/// verbose line, and apply the `target_acc` early-stop protocol. Returns
/// true when the target was reached (and `reached_target` recorded), so the
/// caller's epoch loop knows to stop — keeping the two run loops from
/// drifting apart.
pub(crate) fn record_epoch(
    metrics: &mut RunMetrics,
    cfg: &RunConfig,
    sw: &Stopwatch,
    obs: EpochObs,
) -> bool {
    let rec = EpochRecord {
        epoch: obs.epoch,
        wall_secs: sw.secs(),
        epoch_secs: obs.epoch_secs,
        train_loss: obs.stats.loss_mean,
        train_acc: obs.stats.train_acc,
        val_acc: obs.eval.map(|e| e.val_acc).unwrap_or(f64::NAN),
        test_acc: obs.eval.map(|e| e.test_acc).unwrap_or(f64::NAN),
        active_bytes: obs.stats.active_bytes,
        staleness: obs.staleness,
    };
    if cfg.verbose {
        let suffix = match obs.shards {
            Some(s) => format!(", {s} shards"),
            None => String::new(),
        };
        println!(
            "epoch {:>4}  loss {:.4}  val {:.4}  test {:.4}  ({:.2}s{})",
            rec.epoch, rec.train_loss, rec.val_acc, rec.test_acc, rec.wall_secs, suffix
        );
    }
    metrics.push(rec);
    if let (Some(target), Some(e)) = (cfg.target_acc, obs.eval) {
        if e.test_acc >= target {
            metrics.reached_target = Some((obs.epoch, sw.secs()));
            return true;
        }
    }
    false
}

/// Join the prefetch thread, converting a panic into a readable error
/// instead of swallowing it.
fn join_prefetch(handle: Option<std::thread::JoinHandle<()>>) -> Result<()> {
    let Some(h) = handle else {
        return Ok(());
    };
    match h.join() {
        Ok(()) => Ok(()),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(anyhow!("subgraph prefetch thread panicked: {msg}"))
        }
    }
}

#[derive(Default)]
struct EpochAgg {
    loss_w: f64,
    acc_w: f64,
    labeled: usize,
    peak_bytes: usize,
    dropped: usize,
}

impl EpochAgg {
    fn add(&mut self, s: &StepStats) {
        self.loss_w += s.loss_mean * s.labeled as f64;
        self.acc_w += s.train_acc * s.labeled as f64;
        self.labeled += s.labeled;
        self.peak_bytes = self.peak_bytes.max(s.active_bytes);
        self.dropped += s.dropped_halo;
    }

    fn finish(&self) -> StepStats {
        StepStats {
            loss_mean: self.loss_w / self.labeled.max(1) as f64,
            train_acc: self.acc_w / self.labeled.max(1) as f64,
            labeled: self.labeled,
            active_bytes: self.peak_bytes,
            dropped_halo: self.dropped,
        }
    }
}
