//! Per-run metrics trace: epoch records, curve export (Fig. 2/4/5 series),
//! epochs/runtime-to-target (Table 2 protocol).

use crate::util::table::Table;

#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub wall_secs: f64,
    pub epoch_secs: f64,
    pub train_loss: f64,
    pub train_acc: f64,
    pub val_acc: f64,  // NaN if not evaluated this epoch
    pub test_acc: f64, // NaN if not evaluated this epoch
    pub active_bytes: usize,
    pub staleness: f64,
}

#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub records: Vec<EpochRecord>,
    /// (epoch, wall seconds) at which target test accuracy was reached.
    pub reached_target: Option<(usize, f64)>,
}

impl RunMetrics {
    pub fn push(&mut self, r: EpochRecord) {
        self.records.push(r);
    }

    pub fn best_val_test(&self) -> Option<(f64, f64)> {
        // test accuracy at the best validation epoch (paper protocol)
        let mut best: Option<(f64, f64)> = None;
        for r in &self.records {
            if r.val_acc.is_nan() {
                continue;
            }
            if best.map(|(v, _)| r.val_acc > v).unwrap_or(true) {
                best = Some((r.val_acc, r.test_acc));
            }
        }
        best
    }

    pub fn final_test(&self) -> Option<f64> {
        self.records.iter().rev().find(|r| !r.test_acc.is_nan()).map(|r| r.test_acc)
    }

    pub fn peak_active_bytes(&self) -> usize {
        self.records.iter().map(|r| r.active_bytes).max().unwrap_or(0)
    }

    pub fn total_secs(&self) -> f64 {
        self.records.last().map(|r| r.wall_secs).unwrap_or(0.0)
    }

    pub fn mean_epoch_secs(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.epoch_secs).sum::<f64>() / self.records.len() as f64
    }

    /// Smoothed test-accuracy curve (sliding window, as in Fig. 2).
    pub fn smoothed_test(&self, window: usize) -> Vec<(f64, f64)> {
        let pts: Vec<(f64, f64)> = self
            .records
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| (r.wall_secs, r.test_acc))
            .collect();
        let w = window.max(1);
        (0..pts.len())
            .map(|i| {
                let s = i.saturating_sub(w - 1);
                let slice = &pts[s..=i];
                let mean = slice.iter().map(|&(_, a)| a).sum::<f64>() / slice.len() as f64;
                (pts[i].0, mean)
            })
            .collect()
    }

    pub fn curve_table(&self, label: &str) -> Table {
        let mut t = Table::new(
            &format!("curve: {label}"),
            &["epoch", "wall_secs", "train_loss", "val_acc", "test_acc", "staleness"],
        );
        for r in &self.records {
            t.row(vec![
                r.epoch.to_string(),
                format!("{:.3}", r.wall_secs),
                format!("{:.5}", r.train_loss),
                format!("{:.4}", r.val_acc),
                format!("{:.4}", r.test_acc),
                format!("{:.2}", r.staleness),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, val: f64, test: f64, secs: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            wall_secs: secs,
            epoch_secs: 1.0,
            train_loss: 1.0 / epoch as f64,
            train_acc: 0.5,
            val_acc: val,
            test_acc: test,
            active_bytes: 1000,
            staleness: 1.0,
        }
    }

    #[test]
    fn best_val_picks_test_at_best_val() {
        let mut m = RunMetrics::default();
        m.push(rec(1, 0.5, 0.48, 1.0));
        m.push(rec(2, 0.7, 0.66, 2.0));
        m.push(rec(3, 0.6, 0.72, 3.0));
        assert_eq!(m.best_val_test(), Some((0.7, 0.66)));
        assert_eq!(m.final_test(), Some(0.72));
    }

    #[test]
    fn smoothing_window() {
        let mut m = RunMetrics::default();
        for e in 1..=5 {
            m.push(rec(e, 0.5, e as f64 / 10.0, e as f64));
        }
        let sm = m.smoothed_test(3);
        assert_eq!(sm.len(), 5);
        // last point = mean of 0.3, 0.4, 0.5
        assert!((sm[4].1 - 0.4).abs() < 1e-9);
    }
}
