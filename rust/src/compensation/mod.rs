//! Pluggable message-compensation API (ROADMAP "Message-invariance
//! compensation (TOP)" / ISSUE 9).
//!
//! Subgraph-wise training discards messages from out-of-batch neighbors;
//! every method in this repo is a policy for *compensating* that loss.
//! Until now the policy was hard-wired through `backend/native.rs`
//! (Eq. 9 forward combine, Eq. 12 backward combine), `Method`'s boolean
//! knobs, and serve's `serve_beta` special case. The [`Compensation`]
//! trait pulls all of it behind one seam:
//!
//!   * [`LmcHistory`] — the paper's Eq. 9/12 path over the [`History`]
//!     store. Covers LMC (forward + backward compensation), GAS (forward
//!     history only, `beta = 0`), and FM (GAS + momentum push), which
//!     differ only in the [`CompensationSpec`] flags. Bit-identical to
//!     the pre-trait trainer (`tests/integration_compensation.rs`).
//!   * [`NoComp`] — CLUSTER / GD: no halo compensation, no state.
//!   * [`Top`] — message invariance ("Accurate and Scalable GNNs via
//!     Message Invariance", arXiv 2502.19693, the LMC authors'
//!     follow-up): a per-layer learned linear transform synthesizes
//!     out-of-batch contributions from *fresh in-batch* quantities
//!     instead of reading a stale history. Forward halo rows become
//!     `htilde @ T_l`; backward halo cotangents become
//!     `v_full @ S_l`. The transforms are fitted online, alongside the
//!     GNN parameters, by regressing the *incomplete* (A_bb-only)
//!     in-batch quantities onto the complete ones — pairs the batch
//!     itself provides, no extra supervision. No O(n) memory, no
//!     staleness; state is `2·(L-1)·d²` floats.
//!
//! The trainer owns a `Box<dyn Compensation>` next to its `History`
//! store: the trait carries the *policy* and any learned state, the
//! store stays where the sharded exchange / checkpoint / serve plumbing
//! already expects it. Compensation state is checkpointed as an opaque
//! section under `LMCCKPT1` ([`Compensation::encode_state`]).

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::params::Params;
use crate::history::History;
use crate::runtime::{ArchInfo, Tensor};
use crate::sampler::{beta_vector, BetaScore, SubgraphBatch};

/// Which compensation family a run uses (the `compensation` config knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompKind {
    /// History-based Eq. 9/12 (LMC / GAS / FM).
    Lmc,
    /// Learned message-invariance transforms (TOP).
    Top,
    /// No halo compensation (CLUSTER / GD; serve: pure history halo).
    None,
}

impl CompKind {
    pub fn parse(s: &str) -> Option<CompKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lmc" | "history" => CompKind::Lmc,
            "top" | "mi" | "message-invariance" => CompKind::Top,
            "none" | "off" => CompKind::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompKind::Lmc => "lmc",
            CompKind::Top => "top",
            CompKind::None => "none",
        }
    }
}

/// Flat description of a compensation policy — the knobs the step kernels
/// and the trainer's gather/write-back sequence key on. One method = one
/// spec ([`crate::coordinator::methods::Method::compensation`]), so the
/// old scattered predicates (`uses_beta`, `bwd_scale`, `uses_history`,
/// `stores_aux`, `halo_momentum`) live in a single table.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompensationSpec {
    pub kind: CompKind,
    /// Forward Eq. 9 combination on? (beta > 0 allowed)
    pub uses_beta: bool,
    /// Backward compensation strength (Eqs. 11-13); 0 disables C_b.
    pub bwd_scale: f32,
    /// Read historical embeddings for the halo?
    pub uses_history: bool,
    /// Store auxiliary-variable histories (Vbar)?
    pub stores_aux: bool,
    /// FM's momentum push of incomplete fresh halo values into history.
    pub halo_momentum: Option<f32>,
}

impl CompensationSpec {
    /// The full LMC policy (forward + backward history compensation).
    pub fn lmc() -> CompensationSpec {
        CompensationSpec {
            kind: CompKind::Lmc,
            uses_beta: true,
            bwd_scale: 1.0,
            uses_history: true,
            stores_aux: true,
            halo_momentum: None,
        }
    }

    /// GAS: historical halo values, beta = 0, no backward compensation.
    pub fn gas() -> CompensationSpec {
        CompensationSpec { uses_beta: false, bwd_scale: 0.0, stores_aux: false, ..Self::lmc() }
    }

    /// FM: GAS + momentum-0.3 push of fresh halo values into the store.
    pub fn fm() -> CompensationSpec {
        CompensationSpec { halo_momentum: Some(0.3), ..Self::gas() }
    }

    /// TOP: learned transforms, full backward compensation, no history.
    pub fn top() -> CompensationSpec {
        CompensationSpec {
            kind: CompKind::Top,
            uses_beta: false,
            bwd_scale: 1.0,
            uses_history: false,
            stores_aux: false,
            halo_momentum: None,
        }
    }

    /// CLUSTER / GD: nothing to compensate.
    pub fn none() -> CompensationSpec {
        CompensationSpec {
            kind: CompKind::None,
            uses_beta: false,
            bwd_scale: 0.0,
            uses_history: false,
            stores_aux: false,
            halo_momentum: None,
        }
    }
}

/// Per-step fitting gradients for TOP's transforms, computed by the
/// backend on the in-batch regression pairs (see `backend/native.rs`):
/// one `d_l × d_l` gradient per message-passing boundary `l = 1..L-1`,
/// already normalized so a unit learning rate is a full relaxation step
/// toward the per-batch least-squares transform.
#[derive(Clone, Debug, Default)]
pub struct TopFit {
    /// Gradients for the forward transforms `T_l`.
    pub fwd: Vec<Tensor>,
    /// Gradients for the backward transforms `S_l`.
    pub bwd: Vec<Tensor>,
}

/// A compensation policy plus its method-specific learned state.
///
/// `Send + Sync` because the serve engine shares itself across request
/// threads and sharded workers own one per worker.
pub trait Compensation: Send + Sync {
    /// The flat policy flags the step kernels and trainer key on.
    fn spec(&self) -> CompensationSpec;

    /// Serve-side Eq. 9 β vector for a cached tile (one entry per halo
    /// row). All-zero means halo rows are served purely from the warm
    /// history — the pre-trait `serve_beta = 0` default.
    fn serve_beta(&self, sb: &SubgraphBatch) -> Vec<f32>;

    /// TOP's learned per-layer transforms `(forward T, backward S)`;
    /// `None` for policies without learned state.
    fn transforms(&self) -> Option<(&[Tensor], &[Tensor])> {
        None
    }

    /// Apply one online fitting step from the backend's in-batch
    /// regression gradients. No-op for stateless policies.
    fn fit(&mut self, _fit: &TopFit) {}

    /// Resident bytes of compensation state for a trainer holding
    /// `hist` — the memory column of the grad-error shoot-out.
    fn state_bytes(&self, hist: &History) -> usize;

    /// Serialize learned state for the `LMCCKPT1` compensation section.
    /// Empty for stateless policies.
    fn encode_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state written by [`Compensation::encode_state`]. The
    /// checkpoint config fingerprint already guarantees the same method,
    /// so a payload mismatch is corruption, not a config change.
    fn decode_state(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.is_empty() {
            Ok(())
        } else {
            bail!(
                "checkpoint carries {} bytes of compensation state but this \
                 method keeps none",
                bytes.len()
            )
        }
    }
}

/// The paper's Eq. 9/12 history path (LMC / GAS / FM — the spec flags
/// select the sub-policy). The `History` store itself stays owned by the
/// trainer / serve engine; this type carries the β policy.
pub struct LmcHistory {
    spec: CompensationSpec,
    alpha: f32,
    score: BetaScore,
}

impl LmcHistory {
    pub fn new(spec: CompensationSpec, alpha: f32, score: BetaScore) -> LmcHistory {
        LmcHistory { spec, alpha, score }
    }
}

impl Compensation for LmcHistory {
    fn spec(&self) -> CompensationSpec {
        self.spec
    }

    fn serve_beta(&self, sb: &SubgraphBatch) -> Vec<f32> {
        if self.alpha > 0.0 {
            beta_vector(sb, self.alpha, self.score)
        } else {
            vec![0f32; sb.halo.len()]
        }
    }

    fn state_bytes(&self, hist: &History) -> usize {
        // Hbar always; Vbar only when the backward path stores aux rows.
        if self.spec.stores_aux {
            hist.bytes()
        } else {
            hist.bytes() / 2
        }
    }
}

/// No compensation (CLUSTER / GD). On the serve path this is the default
/// cached mode: halo rows come purely from the warm history (β ≡ 0).
pub struct NoComp;

impl Compensation for NoComp {
    fn spec(&self) -> CompensationSpec {
        CompensationSpec::none()
    }

    fn serve_beta(&self, sb: &SubgraphBatch) -> Vec<f32> {
        vec![0f32; sb.halo.len()]
    }

    fn state_bytes(&self, _hist: &History) -> usize {
        0
    }
}

/// TOP message invariance: per-boundary learned linear transforms.
///
/// `fwd[l-1]` (`T_l`, `d_l × d_l`) maps the incomplete fresh halo
/// activations `htilde` (Eq. 10) to synthesized complete ones; `bwd[l-2]`
/// (`S_{l-1}`, `d_{l-1} × d_{l-1}`) maps fresh incomplete halo cotangents
/// to synthesized complete ones. Identity-initialized, so step 0 equals
/// the pure `β = 1` fresh-value policy and fitting only improves on it.
pub struct Top {
    spec: CompensationSpec,
    fwd: Vec<Tensor>,
    bwd: Vec<Tensor>,
    lr: f32,
}

impl Top {
    /// `widths` are the hidden-layer dims `arch.dims[1..arch.l]` — the
    /// same per-boundary widths the history store uses.
    pub fn new(widths: &[usize], lr: f32) -> Top {
        let ident = |d: usize| {
            let mut t = Tensor::zeros(&[d, d]);
            for i in 0..d {
                t.data[i * d + i] = 1.0;
            }
            t
        };
        Top {
            spec: CompensationSpec::top(),
            fwd: widths.iter().map(|&d| ident(d)).collect(),
            bwd: widths.iter().map(|&d| ident(d)).collect(),
            lr,
        }
    }

    /// Transform state as a named `Params` set — reuses the bitwise
    /// `LMCPAR1` wire format (CRC-trailed) for checkpointing.
    fn as_params(&self) -> Params {
        let mut names = Vec::with_capacity(self.fwd.len() + self.bwd.len());
        let mut tensors = Vec::with_capacity(self.fwd.len() + self.bwd.len());
        for (i, t) in self.fwd.iter().enumerate() {
            names.push(format!("T{}", i + 1));
            tensors.push(t.clone());
        }
        for (i, s) in self.bwd.iter().enumerate() {
            names.push(format!("S{}", i + 1));
            tensors.push(s.clone());
        }
        Params { names, tensors }
    }
}

impl Compensation for Top {
    fn spec(&self) -> CompensationSpec {
        self.spec
    }

    fn serve_beta(&self, sb: &SubgraphBatch) -> Vec<f32> {
        // unreachable in practice: for_serve refuses TOP (transforms are
        // not persisted with --save-params); pure history is the safe
        // degenerate answer
        vec![0f32; sb.halo.len()]
    }

    fn transforms(&self) -> Option<(&[Tensor], &[Tensor])> {
        Some((&self.fwd, &self.bwd))
    }

    fn fit(&mut self, fit: &TopFit) {
        let lr = self.lr;
        for (t, g) in self.fwd.iter_mut().zip(&fit.fwd) {
            debug_assert_eq!(t.shape, g.shape);
            for (tv, &gv) in t.data.iter_mut().zip(&g.data) {
                *tv -= lr * gv;
            }
        }
        for (s, g) in self.bwd.iter_mut().zip(&fit.bwd) {
            debug_assert_eq!(s.shape, g.shape);
            for (sv, &gv) in s.data.iter_mut().zip(&g.data) {
                *sv -= lr * gv;
            }
        }
    }

    fn state_bytes(&self, _hist: &History) -> usize {
        let scalars: usize = self
            .fwd
            .iter()
            .chain(self.bwd.iter())
            .map(|t| t.data.len())
            .sum();
        scalars * std::mem::size_of::<f32>()
    }

    fn encode_state(&self) -> Vec<u8> {
        self.as_params().to_bytes()
    }

    fn decode_state(&mut self, bytes: &[u8]) -> Result<()> {
        let p = Params::from_bytes(bytes)?;
        let expect = self.as_params();
        if p.names != expect.names {
            bail!(
                "TOP compensation state mismatch: checkpoint has {:?}, \
                 this run expects {:?}",
                p.names,
                expect.names
            );
        }
        for (have, want) in p.tensors.iter().zip(&expect.tensors) {
            if have.shape != want.shape {
                bail!(
                    "TOP transform shape mismatch: checkpoint {:?} vs arch {:?}",
                    have.shape,
                    want.shape
                );
            }
        }
        let k = self.fwd.len();
        self.fwd = p.tensors[..k].to_vec();
        self.bwd = p.tensors[k..].to_vec();
        Ok(())
    }
}

/// Training-side constructor: the method determines the policy; the
/// `compensation` knob, when set, must agree (it exists so configs can be
/// explicit and so serve — which has no method — can select a policy).
pub fn for_training(cfg: &RunConfig, arch: &ArchInfo) -> Result<Box<dyn Compensation>> {
    let spec = cfg.method.compensation();
    if let Some(k) = cfg.compensation {
        if k != spec.kind {
            bail!(
                "compensation = \"{}\" conflicts with --method {} (which implies \
                 \"{}\"): pick the method that matches, e.g. --method {}",
                k.name(),
                cfg.method.name(),
                spec.kind.name(),
                match k {
                    CompKind::Lmc => "lmc",
                    CompKind::Top => "top",
                    CompKind::None => "cluster",
                }
            );
        }
    }
    match spec.kind {
        CompKind::Lmc => {
            Ok(Box::new(LmcHistory::new(spec, cfg.beta.alpha, cfg.beta.score)))
        }
        CompKind::None => Ok(Box::new(NoComp)),
        CompKind::Top => {
            if cfg.arch != "gcn" {
                bail!(
                    "--method top implements the message-invariance fit for \
                     --arch gcn only (got --arch {})",
                    cfg.arch
                );
            }
            Ok(Box::new(Top::new(&arch.dims[1..arch.l], cfg.top_lr)))
        }
    }
}

/// Serve-side constructor for the cached tile path. With the knob unset
/// this reproduces the pre-trait behavior bit-for-bit: `comp_beta > 0`
/// (the old `serve_beta`) serves the Eq. 9 combination, otherwise halo
/// rows come purely from the warm history.
pub fn for_serve(cfg: &RunConfig) -> Result<Box<dyn Compensation>> {
    let kind = match cfg.compensation {
        Some(k) => k,
        None => {
            if cfg.comp_beta > 0.0 {
                CompKind::Lmc
            } else {
                CompKind::None
            }
        }
    };
    match kind {
        CompKind::Lmc => Ok(Box::new(LmcHistory::new(
            CompensationSpec::lmc(),
            cfg.comp_beta,
            cfg.beta.score,
        ))),
        CompKind::None => Ok(Box::new(NoComp)),
        CompKind::Top => bail!(
            "serve supports compensation = lmc|none: TOP's learned transforms \
             are training state and are not persisted with --save-params"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comp_kind_parses_all_aliases() {
        for (alias, kind) in [
            ("lmc", CompKind::Lmc),
            ("history", CompKind::Lmc),
            ("top", CompKind::Top),
            ("MI", CompKind::Top),
            ("message-invariance", CompKind::Top),
            ("none", CompKind::None),
            ("off", CompKind::None),
        ] {
            assert_eq!(CompKind::parse(alias), Some(kind), "{alias}");
        }
        assert!(CompKind::parse("bogus").is_none());
        for k in [CompKind::Lmc, CompKind::Top, CompKind::None] {
            assert_eq!(CompKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn top_initializes_to_identity() {
        let top = Top::new(&[3, 5], 0.25);
        let (fwd, bwd) = top.transforms().unwrap();
        assert_eq!(fwd.len(), 2);
        assert_eq!(bwd.len(), 2);
        for t in fwd.iter().chain(bwd) {
            let d = t.shape[0];
            assert_eq!(t.shape, vec![d, d]);
            for i in 0..d {
                for j in 0..d {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert_eq!(t.data[i * d + j], want);
                }
            }
        }
    }

    #[test]
    fn top_fit_applies_scaled_gradient_step() {
        let mut top = Top::new(&[2], 0.5);
        let mut g = Tensor::zeros(&[2, 2]);
        g.data.copy_from_slice(&[1.0, -2.0, 0.0, 4.0]);
        let fit = TopFit { fwd: vec![g.clone()], bwd: vec![g] };
        top.fit(&fit);
        let (fwd, bwd) = top.transforms().unwrap();
        // identity - 0.5 * g
        assert_eq!(fwd[0].data, vec![0.5, 1.0, 0.0, -1.0]);
        assert_eq!(bwd[0].data, vec![0.5, 1.0, 0.0, -1.0]);
    }

    #[test]
    fn top_state_roundtrips_bitwise() {
        let mut top = Top::new(&[4, 3], 0.25);
        // perturb away from identity so the payload is non-trivial
        let mut g = Tensor::zeros(&[4, 4]);
        for (i, v) in g.data.iter_mut().enumerate() {
            *v = (i as f32 - 7.5) * 0.125;
        }
        let mut g2 = Tensor::zeros(&[3, 3]);
        for (i, v) in g2.data.iter_mut().enumerate() {
            *v = (i as f32).sin();
        }
        top.fit(&TopFit { fwd: vec![g.clone(), g2.clone()], bwd: vec![g, g2] });
        let bytes = top.encode_state();
        let mut fresh = Top::new(&[4, 3], 0.25);
        fresh.decode_state(&bytes).unwrap();
        assert_eq!(fresh.encode_state(), bytes);
        let (a_f, a_b) = top.transforms().unwrap();
        let (b_f, b_b) = fresh.transforms().unwrap();
        for (x, y) in a_f.iter().chain(a_b).zip(b_f.iter().chain(b_b)) {
            assert_eq!(x.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                       y.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn top_decode_rejects_wrong_shape_and_garbage() {
        let top = Top::new(&[4], 0.25);
        let bytes = top.encode_state();
        let mut wrong = Top::new(&[5], 0.25);
        assert!(wrong.decode_state(&bytes).is_err());
        let mut ok = Top::new(&[4], 0.25);
        assert!(ok.decode_state(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn stateless_policies_reject_nonempty_state() {
        let mut nc = NoComp;
        assert!(nc.decode_state(&[]).is_ok());
        assert!(nc.decode_state(&[1, 2, 3]).is_err());
        let mut lmc = LmcHistory::new(CompensationSpec::lmc(), 0.4, BetaScore::TwoXMinusXSquared);
        assert!(lmc.decode_state(&[]).is_ok());
        assert!(lmc.decode_state(&[9]).is_err());
        assert!(lmc.encode_state().is_empty());
    }
}
