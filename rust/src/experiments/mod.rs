//! Experiment harness: one runner per paper table/figure (DESIGN.md §4).
//!
//! Each runner regenerates the corresponding artifact as CSV + markdown in
//! `--out` (default `results/`). Absolute numbers differ from the paper
//! (simulated datasets, CPU PJRT substrate); the *shape* — method ordering,
//! approximate speedup factors, crossovers — is the reproduction target and
//! is asserted by `rust/tests/test_experiments.rs` on scaled-down settings.

mod ablation;
mod curves;
mod efficiency;
mod grad_error;
mod prediction;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::backend::{make_executor, Backend, Executor};
use crate::config::RunConfig;
use crate::coordinator::{RunMetrics, ShardedTrainer, Trainer};
use crate::util::cli::Args;

pub use ablation::{run_fig4, run_table8, run_table9};
pub use curves::{run_fig2, run_fig5};
pub use efficiency::{run_sharded, run_table2, run_table6, run_table7};
pub use grad_error::{run_fig3, run_grad_shootout, run_sampler_shootout};
pub use prediction::{run_table1, run_table3};

/// Shared experiment context.
pub struct Ctx {
    pub exec: Arc<dyn Executor>,
    pub backend: Backend,
    pub out: PathBuf,
    /// Global epoch scale: 1.0 = paper-shaped defaults; tests use ~0.1.
    pub epoch_scale: f64,
    pub seed: u64,
}

impl Ctx {
    pub fn new(
        backend: Backend,
        artifact_dir: &str,
        out: &str,
        epoch_scale: f64,
        seed: u64,
    ) -> Result<Ctx> {
        let cfg = RunConfig {
            backend,
            artifact_dir: artifact_dir.to_string(),
            ..RunConfig::default()
        };
        Ok(Ctx {
            exec: make_executor(&cfg)?,
            backend,
            out: PathBuf::from(out),
            epoch_scale,
            seed,
        })
    }

    pub fn epochs(&self, base: usize) -> usize {
        ((base as f64 * self.epoch_scale).round() as usize).max(2)
    }

    /// Build and run one training configuration; returns the metrics trace.
    pub fn run(&self, mut cfg: RunConfig) -> Result<(Trainer, RunMetrics)> {
        cfg.backend = self.backend; // executor already built; keep cfg honest
        let mut t = Trainer::new(self.exec.clone(), cfg)?;
        let m = t.run()?;
        Ok((t, m))
    }

    /// Build and run one partition-parallel sharded configuration
    /// (`cfg.shards` workers; see `coordinator::sharded`).
    pub fn run_sharded(&self, mut cfg: RunConfig) -> Result<(ShardedTrainer, RunMetrics)> {
        cfg.backend = self.backend;
        let mut t = ShardedTrainer::new(self.exec.clone(), cfg)?;
        let m = t.run()?;
        Ok((t, m))
    }

    pub fn base_cfg(&self, dataset: &str, arch: &str, method: &str) -> Result<RunConfig> {
        let mut cfg = RunConfig {
            seed: self.seed,
            backend: self.backend,
            ..RunConfig::default()
        };
        cfg.dataset = crate::graph::DatasetId::parse(dataset)
            .ok_or_else(|| anyhow!("dataset {dataset}"))?;
        cfg.arch = arch.to_string();
        cfg.method = crate::coordinator::Method::parse(method)
            .ok_or_else(|| anyhow!("method {method}"))?;
        Ok(cfg)
    }
}

pub fn dispatch(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("usage: lmc experiment <id> [--out DIR]"))?;
    let backend = Backend::parse(args.opt_or("backend", "native"))
        .ok_or_else(|| anyhow!("unknown backend"))?;
    let ctx = Ctx::new(
        backend,
        args.opt_or("artifacts", "artifacts"),
        args.opt_or("out", "results"),
        args.opt_f64("epoch-scale").unwrap_or(1.0),
        args.opt_usize("seed").unwrap_or(0) as u64,
    )?;
    std::fs::create_dir_all(&ctx.out)?;
    match id {
        "table1" => run_table1(&ctx).map(|_| ()),
        "table2" => run_table2(&ctx).map(|_| ()),
        "table3" => run_table3(&ctx).map(|_| ()),
        "table6" => run_table6(&ctx).map(|_| ()),
        "table7" => run_table7(&ctx).map(|_| ()),
        "table8" => run_table8(&ctx).map(|_| ()),
        "table9" => run_table9(&ctx).map(|_| ()),
        "sharded" => run_sharded(&ctx).map(|_| ()),
        "fig2" => run_fig2(&ctx).map(|_| ()),
        "fig3" => run_fig3(&ctx).map(|_| ()),
        "grad-error" => run_grad_shootout(&ctx).map(|_| ()),
        "samplers" => run_sampler_shootout(&ctx).map(|_| ()),
        "fig4" => run_fig4(&ctx).map(|_| ()),
        "fig5" => run_fig5(&ctx).map(|_| ()),
        "all" => {
            run_table1(&ctx)?;
            run_table2(&ctx)?;
            run_table3(&ctx)?;
            run_table6(&ctx)?;
            run_table7(&ctx)?;
            run_table8(&ctx)?;
            run_table9(&ctx)?;
            run_sharded(&ctx)?;
            run_fig2(&ctx)?;
            run_fig3(&ctx)?;
            run_grad_shootout(&ctx)?;
            run_sampler_shootout(&ctx)?;
            run_fig4(&ctx)?;
            run_fig5(&ctx)?;
            Ok(())
        }
        other => Err(anyhow!("unknown experiment '{other}'")),
    }
}
