//! Figure 3: average relative gradient-estimation error per MP layer for
//! CLUSTER / GAS / LMC during GCN training.

use anyhow::Result;

use super::Ctx;
use crate::coordinator::grad_check;
use crate::util::table::Table;

/// For each method, train on arxiv-sim (GCN) and record the per-layer
/// relative errors ‖g~ - ∇L‖/‖∇L‖ every epoch (paper protocol: average over
/// the epoch's mini-batches, deterministic forward).
pub fn run_fig3(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 3: relative gradient estimation error (arxiv-sim, GCN)",
        &["method", "epoch", "layer", "rel_err", "overall", "bias"],
    );
    let epochs = ctx.epochs(12);
    for method in ["cluster", "gas", "lmc"] {
        let cfg = {
            let mut c = ctx.base_cfg("arxiv-sim", "gcn", method)?;
            c.epochs = epochs;
            c.lr = 3e-3; // Theorem 2 regime: moderate staleness
            c
        };
        let mut trainer = crate::coordinator::Trainer::new(ctx.exec.clone(), cfg)?;
        for epoch in 1..=epochs {
            trainer.train_epoch()?;
            let rep = grad_check::measure(&mut trainer)?;
            let bias = grad_check::measure_bias(&mut trainer)?;
            for (l, e) in rep.per_layer.iter().enumerate() {
                t.row(vec![
                    method.to_uppercase(),
                    epoch.to_string(),
                    (l + 1).to_string(),
                    format!("{e:.5}"),
                    format!("{:.5}", rep.overall),
                    format!("{bias:.5}"),
                ]);
            }
            println!(
                "fig3: {method} epoch {epoch} rel err {:.4} bias {:.4}",
                rep.overall, bias
            );
        }
    }
    t.save(&ctx.out, "fig3")?;
    Ok(t)
}
