//! Figure 3: average relative gradient-estimation error per MP layer for
//! CLUSTER / GAS / LMC during GCN training.

use anyhow::Result;

use super::Ctx;
use crate::coordinator::grad_check;
use crate::sampler::HaloSamplerKind;
use crate::util::table::Table;

/// For each method, train on arxiv-sim (GCN) and record the per-layer
/// relative errors ‖g~ - ∇L‖/‖∇L‖ every epoch (paper protocol: average over
/// the epoch's mini-batches, deterministic forward).
pub fn run_fig3(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 3: relative gradient estimation error (arxiv-sim, GCN)",
        &["method", "epoch", "layer", "rel_err", "overall", "bias"],
    );
    let epochs = ctx.epochs(12);
    for method in ["cluster", "gas", "lmc"] {
        let cfg = {
            let mut c = ctx.base_cfg("arxiv-sim", "gcn", method)?;
            c.epochs = epochs;
            c.lr = 3e-3; // Theorem 2 regime: moderate staleness
            c
        };
        let mut trainer = crate::coordinator::Trainer::new(ctx.exec.clone(), cfg)?;
        for epoch in 1..=epochs {
            trainer.train_epoch()?;
            let rep = grad_check::measure(&mut trainer)?;
            let bias = grad_check::measure_bias(&mut trainer)?;
            for (l, e) in rep.per_layer.iter().enumerate() {
                t.row(vec![
                    method.to_uppercase(),
                    epoch.to_string(),
                    (l + 1).to_string(),
                    format!("{e:.5}"),
                    format!("{:.5}", rep.overall),
                    format!("{bias:.5}"),
                ]);
            }
            println!(
                "fig3: {method} epoch {epoch} rel err {:.4} bias {:.4}",
                rep.overall, bias
            );
        }
    }
    t.save(&ctx.out, "fig3")?;
    Ok(t)
}

/// `lmc experiment grad-error`: the compensation-method shoot-out.
/// Trains LMC, TOP, and GAS on the same arxiv-sim GCN task and reports
/// each method's overall gradient error against the exact oracle, mean
/// epoch wall time, and resident compensation-state bytes (history
/// stores for LMC/GAS, learned transforms for TOP). The expected shape:
/// TOP's error lands below GAS's (its synthesized halo messages track
/// the fresh values instead of stale history) at a compensation-state
/// footprint orders of magnitude below LMC's O(n · d) stores.
pub fn run_grad_shootout(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Gradient-error shoot-out: LMC vs TOP vs GAS (arxiv-sim, GCN)",
        &["method", "grad_err_overall", "epoch_secs", "comp_state_bytes"],
    );
    let warm = ctx.epochs(8);
    for method in ["lmc", "top", "gas"] {
        let cfg = {
            let mut c = ctx.base_cfg("arxiv-sim", "gcn", method)?;
            c.epochs = warm;
            c.lr = 3e-3; // same regime as fig3
            c
        };
        let mut trainer = crate::coordinator::Trainer::new(ctx.exec.clone(), cfg)?;
        let mut secs = 0f64;
        for _ in 0..warm {
            let t0 = std::time::Instant::now();
            trainer.train_epoch()?;
            secs += t0.elapsed().as_secs_f64();
        }
        let epoch_secs = secs / warm.max(1) as f64;
        let rep = grad_check::measure(&mut trainer)?;
        let bytes = trainer.comp.state_bytes(&trainer.history);
        t.row(vec![
            method.to_uppercase(),
            format!("{:.6}", rep.overall),
            format!("{epoch_secs:.4}"),
            bytes.to_string(),
        ]);
        println!(
            "grad-error: {method} rel err {:.4} epoch {epoch_secs:.3}s comp state {bytes} bytes",
            rep.overall
        );
    }
    t.save(&ctx.out, "grad_error")?;
    Ok(t)
}

/// `lmc experiment samplers`: the halo-sampler shoot-out. Each row trains
/// the same arxiv-sim GCN task under one halo subsampling policy (keep
/// fraction 0.5, plus the full-halo baseline) crossed with {LMC
/// compensation, none} — "none" is the GAS historical fallback, i.e. stale
/// history rows with no Eq. 9 correction — and reports the overall
/// gradient error against the exact oracle plus mean epoch wall time.
/// Expected shape: every rescaled policy stays close to the full-halo
/// error of its compensation row (the Horvitz–Thompson rescale keeps the
/// aggregation unbiased) while spending less time per epoch, and LMC rows
/// sit below their "none" twins at every sampler.
pub fn run_sampler_shootout(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Halo-sampler shoot-out: gradient error vs wall-clock (arxiv-sim, GCN, keep 0.5)",
        &["sampler", "compensation", "grad_err_overall", "epoch_secs", "dropped_halo"],
    );
    let warm = ctx.epochs(8);
    let samplers = [
        HaloSamplerKind::None,
        HaloSamplerKind::Uniform,
        HaloSamplerKind::Labor,
        HaloSamplerKind::Importance,
    ];
    for kind in samplers {
        for (comp_label, method) in [("lmc", "lmc"), ("none", "gas")] {
            let cfg = {
                let mut c = ctx.base_cfg("arxiv-sim", "gcn", method)?;
                c.epochs = warm;
                c.lr = 3e-3; // same regime as fig3 / grad-error
                c.halo_sampler = kind;
                c.halo_keep = 0.5;
                c
            };
            let mut trainer = crate::coordinator::Trainer::new(ctx.exec.clone(), cfg)?;
            let mut secs = 0f64;
            let mut dropped = 0usize;
            for _ in 0..warm {
                let t0 = std::time::Instant::now();
                let stats = trainer.train_epoch()?;
                secs += t0.elapsed().as_secs_f64();
                dropped = stats.dropped_halo;
            }
            let epoch_secs = secs / warm.max(1) as f64;
            let rep = grad_check::measure(&mut trainer)?;
            t.row(vec![
                kind.name().to_string(),
                comp_label.to_string(),
                format!("{:.6}", rep.overall),
                format!("{epoch_secs:.4}"),
                dropped.to_string(),
            ]);
            println!(
                "samplers: {} comp={comp_label} rel err {:.4} epoch {epoch_secs:.3}s dropped {dropped}",
                kind.name(),
                rep.overall
            );
        }
    }
    t.save(&ctx.out, "sampler_shootout")?;
    Ok(t)
}
