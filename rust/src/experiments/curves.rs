//! Figure 2 (test acc / train loss vs runtime, large datasets) and
//! Figure 5 (small Planetoid-style datasets incl. full-batch GD).

use anyhow::Result;

use super::Ctx;
use crate::util::table::Table;

fn curve_rows(t: &mut Table, label: &str, m: &crate::coordinator::RunMetrics, smooth: usize) {
    let smoothed = m.smoothed_test(smooth);
    let mut si = 0usize;
    for r in &m.records {
        let sm = if !r.test_acc.is_nan() && si < smoothed.len() {
            let v = smoothed[si].1;
            si += 1;
            v
        } else {
            f64::NAN
        };
        t.row(vec![
            label.to_string(),
            r.epoch.to_string(),
            format!("{:.3}", r.wall_secs),
            format!("{:.5}", r.train_loss),
            format!("{:.4}", r.test_acc),
            format!("{:.4}", sm),
        ]);
    }
}

/// Fig. 2: convergence curves for CLUSTER/GAS/FM/LMC on arxiv-sim and
/// reddit-sim (GCN) — runtime on the x axis, smoothed test accuracy and
/// train loss as series.
pub fn run_fig2(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 2: test accuracy & training loss vs runtime",
        &["series", "epoch", "wall_secs", "train_loss", "test_acc", "test_acc_smooth"],
    );
    for ds in ["arxiv-sim", "reddit-sim"] {
        for method in ["cluster", "gas", "fm", "lmc"] {
            let mut cfg = ctx.base_cfg(ds, "gcn", method)?;
            cfg.epochs = ctx.epochs(40);
            cfg.eval_every = 1;
            let (_, m) = ctx.run(cfg)?;
            curve_rows(&mut t, &format!("{ds}/{method}"), &m, 5);
            println!(
                "fig2: {ds}/{method} final test {:.4}",
                m.final_test().unwrap_or(f64::NAN)
            );
        }
    }
    t.save(&ctx.out, "fig2")?;
    Ok(t)
}

/// Fig. 5: GD vs GAS vs LMC on cora/citeseer/pubmed-sim (GCN).
pub fn run_fig5(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 5: small datasets — testing accuracy vs runtime",
        &["series", "epoch", "wall_secs", "train_loss", "test_acc", "test_acc_smooth"],
    );
    for ds in ["cora-sim", "citeseer-sim", "pubmed-sim"] {
        for method in ["gd", "gas", "lmc"] {
            let mut cfg = ctx.base_cfg(ds, "gcn", method)?;
            cfg.epochs = ctx.epochs(40);
            cfg.eval_every = 1;
            let (_, m) = ctx.run(cfg)?;
            curve_rows(&mut t, &format!("{ds}/{method}"), &m, 5);
            println!(
                "fig5: {ds}/{method} final test {:.4}",
                m.final_test().unwrap_or(f64::NAN)
            );
        }
    }
    t.save(&ctx.out, "fig5")?;
    Ok(t)
}
