//! Table 1 (prediction performance) and Table 3 (batch-size robustness).

use anyhow::Result;

use super::Ctx;
use crate::util::table::Table;

const T1_DATASETS: &[&str] = &["reddit-sim", "ppi-sim", "flickr-sim", "arxiv-sim"];
const T1_METHODS: &[&str] = &["cluster", "gas", "fm", "lmc"];

/// Table 1: test accuracy (at best validation epoch) per dataset x arch x
/// method, plus the full-batch GD reference row.
pub fn run_table1(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table 1: prediction performance (test acc % at best val)",
        &["method", "arch", "reddit-sim", "ppi-sim", "flickr-sim", "arxiv-sim"],
    );
    for arch in ["gcn", "gcnii"] {
        for method in std::iter::once(&"gd").chain(T1_METHODS) {
            let mut cells = vec![method.to_uppercase(), arch.to_string()];
            for ds in T1_DATASETS {
                let mut cfg = ctx.base_cfg(ds, arch, method)?;
                cfg.epochs = ctx.epochs(if *method == "gd" { 80 } else { 40 });
                cfg.eval_every = 2;
                let (_, m) = ctx.run(cfg)?;
                let acc = m.best_val_test().map(|(_, t)| t).unwrap_or(f64::NAN);
                cells.push(format!("{:.2}", 100.0 * acc));
                println!("table1: {method}/{arch}/{ds} -> {:.2}", 100.0 * acc);
            }
            t.row(cells);
        }
    }
    t.save(&ctx.out, "table1")?;
    println!("{}", t.to_markdown());
    Ok(t)
}

/// Table 3: accuracy under batch sizes (clusters per batch) 1/2/5/10 on
/// arxiv-sim, GAS vs LMC, GCN and GCNII.
pub fn run_table3(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table 3: performance under different batch sizes (arxiv-sim)",
        &["batch_size", "gcn GAS", "gcn LMC", "gcnii GAS", "gcnii LMC"],
    );
    for &bs in &[1usize, 2, 5, 10] {
        let mut cells = vec![bs.to_string()];
        for arch in ["gcn", "gcnii"] {
            for method in ["gas", "lmc"] {
                let mut cfg = ctx.base_cfg("arxiv-sim", arch, method)?;
                cfg.clusters_per_batch = bs;
                cfg.epochs = ctx.epochs(40);
                // paper: smaller lr works better at tiny batches
                if bs <= 2 {
                    cfg.lr = 5e-3;
                }
                let (_, m) = ctx.run(cfg)?;
                let acc = m.best_val_test().map(|(_, t)| t).unwrap_or(f64::NAN);
                cells.push(format!("{:.2}", 100.0 * acc));
                println!("table3: bs={bs} {method}/{arch} -> {:.2}", 100.0 * acc);
            }
        }
        // reorder: we generated gcn-gas, gcn-lmc, gcnii-gas, gcnii-lmc ✓
        t.row(cells);
    }
    t.save(&ctx.out, "table3")?;
    println!("{}", t.to_markdown());
    Ok(t)
}
