//! Table 2 (epochs / runtime to target accuracy + memory), Table 6
//! (training time per epoch), Table 7 (memory + reserved messages), and the
//! sharded-vs-serial throughput table (`experiment sharded`).

use anyhow::Result;

use super::Ctx;
use crate::coordinator::memory::{gd_active_bytes, reserved_messages};
use crate::coordinator::{Method, SyncMode};
use crate::graph::load;
use crate::util::table::Table;

const EFF_METHODS: &[&str] = &["cluster", "gas", "fm", "lmc"];

/// Table 2: epochs and wall seconds to reach the GD reference accuracy, and
/// the peak simulated-accelerator bytes, per dataset (GCN) + arxiv (GCNII).
pub fn run_table2(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table 2: efficiency of CLUSTER, GAS, FM, LMC",
        &["dataset&gnn", "method", "epochs-to-target", "runtime_s", "active_MB", "target_acc"],
    );
    let cases: &[(&str, &str)] = &[
        ("arxiv-sim", "gcn"),
        ("flickr-sim", "gcn"),
        ("reddit-sim", "gcn"),
        ("ppi-sim", "gcn"),
        ("arxiv-sim", "gcnii"),
    ];
    for &(ds, arch) in cases {
        // GD reference accuracy first (the "full-batch accuracy" target);
        // aim slightly below its best to keep runs bounded, as in the paper
        // ("runtime to reach the full-batch accuracy").
        let mut gd_cfg = ctx.base_cfg(ds, arch, "gd")?;
        gd_cfg.epochs = ctx.epochs(80);
        gd_cfg.eval_every = 4;
        let (_, gdm) = ctx.run(gd_cfg)?;
        let target = gdm.best_val_test().map(|(_, t)| t).unwrap_or(0.5) * 0.98;
        for method in EFF_METHODS {
            let mut cfg = ctx.base_cfg(ds, arch, method)?;
            cfg.epochs = ctx.epochs(80);
            cfg.target_acc = Some(target);
            cfg.eval_every = 1;
            let (_, m) = ctx.run(cfg)?;
            let (ep, secs) = m
                .reached_target
                .map(|(e, s)| (e as f64, s))
                .unwrap_or((f64::NAN, f64::NAN));
            t.row(vec![
                format!("{ds} & {arch}"),
                method.to_uppercase(),
                if ep.is_nan() { ">max".into() } else { format!("{ep:.0}") },
                if secs.is_nan() { "-".into() } else { format!("{secs:.1}") },
                format!("{:.1}", m.peak_active_bytes() as f64 / 1e6),
                format!("{:.3}", target),
            ]);
            println!("table2: {ds}/{arch}/{method} epochs={ep:.0} secs={secs:.1}");
        }
    }
    t.save(&ctx.out, "table2")?;
    println!("{}", t.to_markdown());
    Ok(t)
}

/// Table 6: training time per epoch (seconds), per dataset x method.
pub fn run_table6(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table 6: training time (s) per epoch",
        &["dataset&gnn", "CLUSTER", "GAS", "FM", "LMC"],
    );
    let cases: &[(&str, &str)] = &[
        ("arxiv-sim", "gcn"),
        ("flickr-sim", "gcn"),
        ("reddit-sim", "gcn"),
        ("ppi-sim", "gcn"),
        ("arxiv-sim", "gcnii"),
        ("flickr-sim", "gcnii"),
    ];
    for &(ds, arch) in cases {
        let mut cells = vec![format!("{ds} & {arch}")];
        for method in EFF_METHODS {
            let mut cfg = ctx.base_cfg(ds, arch, method)?;
            cfg.epochs = ctx.epochs(6).max(3);
            cfg.eval_every = usize::MAX; // pure training time
            let (_, m) = ctx.run(cfg)?;
            // skip the first (warmup/compile) epoch
            let times: Vec<f64> = m.records.iter().skip(1).map(|r| r.epoch_secs).collect();
            let mean = times.iter().sum::<f64>() / times.len().max(1) as f64;
            cells.push(format!("{mean:.2}"));
            println!("table6: {ds}/{arch}/{method} {mean:.2}s/epoch");
        }
        t.row(cells);
    }
    t.save(&ctx.out, "table6")?;
    println!("{}", t.to_markdown());
    Ok(t)
}

/// Sharded-vs-serial throughput: partition-parallel workers (one trainer
/// per shard, synchronized at epoch barriers) against the single-trainer
/// baseline — same dataset, arch, method, and epoch budget. The serial row
/// anchors the speedup column; the `hist` row adds the boundary
/// history-row exchange on top of parameter averaging.
pub fn run_sharded(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Sharded training: partition-parallel throughput vs serial",
        &["dataset&gnn", "shards", "sync_mode", "mean_epoch_s", "speedup", "final_train_loss"],
    );
    let (ds, arch, method) = ("arxiv-sim", "gcn", "lmc");
    let epochs = ctx.epochs(10);
    let mut serial_secs = f64::NAN;
    for &(shards, mode) in &[(1usize, "avg"), (2, "avg"), (4, "avg"), (4, "hist")] {
        let mut cfg = ctx.base_cfg(ds, arch, method)?;
        cfg.epochs = epochs;
        cfg.eval_every = usize::MAX;
        cfg.shards = shards;
        cfg.sync_mode = SyncMode::parse(mode).unwrap();
        let m = if shards == 1 {
            ctx.run(cfg)?.1
        } else {
            ctx.run_sharded(cfg)?.1
        };
        let mean = m.mean_epoch_secs();
        if shards == 1 {
            serial_secs = mean;
        }
        let final_loss = m.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
        t.row(vec![
            format!("{ds} & {arch}"),
            shards.to_string(),
            if shards == 1 { "serial".into() } else { mode.to_string() },
            format!("{mean:.3}"),
            format!("{:.2}x", serial_secs / mean),
            format!("{final_loss:.4}"),
        ]);
        println!("sharded: {shards} shards ({mode}) {mean:.3}s/epoch, final loss {final_loss:.4}");
    }
    t.save(&ctx.out, "sharded")?;
    println!("{}", t.to_markdown());
    Ok(t)
}

/// Table 7: active memory + proportion of reserved messages in forward and
/// backward passes, batch size 1 and the dataset default.
pub fn run_table7(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table 7: active memory (MB) / reserved messages fwd / bwd",
        &["batch_size", "method", "arxiv-sim", "flickr-sim", "reddit-sim", "ppi-sim"],
    );
    let datasets = ["arxiv-sim", "flickr-sim", "reddit-sim", "ppi-sim"];
    // Full-batch GD row
    {
        let mut cells = vec!["full".to_string(), "GD".to_string()];
        for ds in datasets {
            let id = crate::graph::DatasetId::parse(ds).unwrap();
            let g = load(id, ctx.seed);
            let arch = ctx.exec.resolve_arch(id.profile(), "gcn")?;
            let mb = gd_active_bytes(g.n(), &arch.dims, g.d_x, g.csr.neighbors.len()) as f64 / 1e6;
            cells.push(format!("{mb:.1} / 100% / 100%"));
        }
        t.row(cells);
    }
    for &(bs, label) in &[(1usize, "1"), (0usize, "default")] {
        for method_name in ["cluster", "gas", "lmc"] {
            let method = Method::parse(method_name).unwrap();
            let mut cells = vec![label.to_string(), method_name.to_uppercase()];
            for ds in datasets {
                let mut cfg = ctx.base_cfg(ds, "gcn", method_name)?;
                if bs > 0 {
                    cfg.clusters_per_batch = bs;
                }
                cfg.epochs = 1;
                cfg.eval_every = usize::MAX;
                let (mut trainer, m) = ctx.run(cfg)?;
                let batches = trainer.batcher.epoch_batches();
                let acct = reserved_messages(&trainer.graph, &batches, method);
                cells.push(format!(
                    "{:.1} / {:.0}% / {:.0}%",
                    m.peak_active_bytes() as f64 / 1e6,
                    100.0 * acct.fwd_frac,
                    100.0 * acct.bwd_frac
                ));
            }
            t.row(cells);
        }
    }
    t.save(&ctx.out, "table7")?;
    println!("{}", t.to_markdown());
    Ok(t)
}
