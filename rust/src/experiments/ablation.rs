//! Figure 4 (compensation ablation: GAS vs C_f vs C_f & C_b) and
//! Tables 8-9 (beta hyperparameter sweeps, paper §E.4).

use anyhow::Result;

use super::Ctx;
use crate::sampler::BetaScore;
use crate::util::table::Table;

/// Fig. 4: on arxiv-sim (GCN), small (1 cluster) and large (10 clusters)
/// batches: GAS vs LMC-with-only-C_f vs full LMC.
pub fn run_fig4(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 4: improvement of the compensations (arxiv-sim, GCN)",
        &["batch_size", "variant", "best_test_acc", "final_test_acc"],
    );
    for &bs in &[1usize, 10] {
        for (variant, method, bwd_off, beta_alpha) in [
            ("GAS", "gas", false, 0.0f32),
            ("Cf", "lmc", true, 1.0),
            ("Cf&Cb", "lmc", false, 1.0),
        ] {
            let mut cfg = ctx.base_cfg("arxiv-sim", "gcn", method)?;
            cfg.clusters_per_batch = bs;
            cfg.epochs = ctx.epochs(40);
            cfg.force_bwd_off = bwd_off;
            cfg.beta.alpha = beta_alpha;
            if bs == 1 {
                cfg.lr = 5e-3;
            }
            let (_, m) = ctx.run(cfg)?;
            let best = m.best_val_test().map(|(_, a)| a).unwrap_or(f64::NAN);
            let fin = m.final_test().unwrap_or(f64::NAN);
            t.row(vec![
                bs.to_string(),
                variant.to_string(),
                format!("{:.2}", 100.0 * best),
                format!("{:.2}", 100.0 * fin),
            ]);
            println!("fig4: bs={bs} {variant} best={:.2}", 100.0 * best);
        }
    }
    t.save(&ctx.out, "fig4")?;
    println!("{}", t.to_markdown());
    Ok(t)
}

/// Table 8: LMC accuracy vs alpha on arxiv-sim (GCN), batch sizes 1 and 10.
pub fn run_table8(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table 8: prediction performance under different alpha (arxiv-sim)",
        &["batch_size", "alpha=0.0", "0.2", "0.4", "0.6", "0.8", "1.0"],
    );
    for &(bs, lr) in &[(1usize, 5e-3), (10usize, 1e-2)] {
        let mut cells = vec![bs.to_string()];
        for &alpha in &[0.0f32, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let mut cfg = ctx.base_cfg("arxiv-sim", "gcn", "lmc")?;
            cfg.clusters_per_batch = bs;
            cfg.lr = lr;
            cfg.epochs = ctx.epochs(30);
            cfg.beta.alpha = alpha;
            cfg.beta.score = BetaScore::TwoXMinusXSquared;
            let (_, m) = ctx.run(cfg)?;
            let best = m.best_val_test().map(|(_, a)| a).unwrap_or(f64::NAN);
            cells.push(format!("{:.2}", 100.0 * best));
            println!("table8: bs={bs} alpha={alpha} -> {:.2}", 100.0 * best);
        }
        t.row(cells);
    }
    t.save(&ctx.out, "table8")?;
    println!("{}", t.to_markdown());
    Ok(t)
}

/// Table 9: LMC accuracy vs score function on arxiv-sim (GCN).
pub fn run_table9(ctx: &Ctx) -> Result<Table> {
    let scores = [
        BetaScore::TwoXMinusXSquared,
        BetaScore::One,
        BetaScore::XSquared,
        BetaScore::X,
        BetaScore::SinX,
    ];
    let mut header = vec!["batch_size".to_string()];
    header.extend(scores.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Table 9: prediction performance under different score (arxiv-sim)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &(bs, lr, alpha) in &[(1usize, 5e-3, 0.4f32), (10usize, 1e-2, 1.0)] {
        let mut cells = vec![bs.to_string()];
        for &score in &scores {
            let mut cfg = ctx.base_cfg("arxiv-sim", "gcn", "lmc")?;
            cfg.clusters_per_batch = bs;
            cfg.lr = lr;
            cfg.epochs = ctx.epochs(30);
            cfg.beta.alpha = alpha;
            cfg.beta.score = score;
            let (_, m) = ctx.run(cfg)?;
            let best = m.best_val_test().map(|(_, a)| a).unwrap_or(f64::NAN);
            cells.push(format!("{:.2}", 100.0 * best));
            println!("table9: bs={bs} score={} -> {:.2}", score.name(), 100.0 * best);
        }
        t.row(cells);
    }
    t.save(&ctx.out, "table9")?;
    println!("{}", t.to_markdown());
    Ok(t)
}
