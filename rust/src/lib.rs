//! LMC: Fast Training of GNNs via Subgraph-Wise Sampling with Provable
//! Convergence (Shi, Liang, Wang — ICLR 2023), reproduced as a layered
//! Rust (+ optional JAX/Pallas AOT) system.
//!
//! Layer map (see DESIGN.md and rust/README.md):
//!   - L3 (this crate): graph substrate, METIS-substitute partitioner,
//!     sparse subgraph sampler (CSR blocks), historical value store,
//!     training coordinator, experiment harness.
//!   - L2' (`backend`): pluggable execution — the default native Rust CPU
//!     backend (rayon row-wise SpMM over the sparse blocks, no artifacts)
//!     and the PJRT backend (`--features pjrt`) that executes AOT HLO.
//!   - L2 (`python/compile`): GCN/GCNII forward + explicit backward message
//!     passing with LMC compensation, AOT-lowered to HLO text.
//!   - L1 (`python/compile/kernels`): Pallas halo-aggregation and
//!     compensation kernels.

pub mod backend;
pub mod checkpoint;
pub mod compensation;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod graph;
pub mod history;
pub mod partition;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;
