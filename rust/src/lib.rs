//! LMC: Fast Training of GNNs via Subgraph-Wise Sampling with Provable
//! Convergence (Shi, Liang, Wang — ICLR 2023), reproduced as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer map (see DESIGN.md):
//!   - L3 (this crate): graph substrate, METIS-substitute partitioner,
//!     subgraph sampler, historical value store, PJRT runtime, training
//!     coordinator, experiment harness.
//!   - L2 (`python/compile`): GCN/GCNII forward + explicit backward message
//!     passing with LMC compensation, AOT-lowered to HLO text.
//!   - L1 (`python/compile/kernels`): Pallas halo-aggregation and
//!     compensation kernels.

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod graph;
pub mod history;
pub mod partition;
pub mod runtime;
pub mod sampler;
pub mod util;
