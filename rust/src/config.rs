//! Run configuration: defaults, TOML file loading, CLI overrides.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::backend::Backend;
use crate::compensation::CompKind;
use crate::coordinator::methods::{BetaConfig, Method};
use crate::coordinator::sharded::SyncMode;
use crate::graph::DatasetId;
use crate::history::HistDtype;
use crate::sampler::{BatcherMode, BetaScore, HaloSampler, HaloSamplerKind};
use crate::serve::ServeMode;
use crate::util::cli::Args;
use crate::util::toml::{parse as toml_parse, TomlDoc};

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetId,
    pub arch: String, // "gcn" | "gcnii"
    pub method: Method,
    /// Execution backend: "native" (pure-Rust CPU over sparse blocks, the
    /// default — no artifacts needed) or "pjrt" (AOT/HLO, `--features pjrt`).
    pub backend: Backend,
    pub seed: u64,
    /// Number of partition clusters (METIS parts).
    pub parts: usize,
    /// Clusters per mini-batch ("batch size" in the paper's Table 3 sense).
    pub clusters_per_batch: usize,
    pub epochs: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub beta: BetaConfig,
    pub batcher_mode: BatcherMode,
    /// Evaluate every this many epochs.
    pub eval_every: usize,
    /// Stop once test accuracy reaches this value (Table 2 protocol).
    pub target_acc: Option<f64>,
    pub artifact_dir: String,
    /// Overlap next-batch assembly with execution (std::thread pipeline).
    pub pipeline: bool,
    /// Reuse per-group subgraph blocks across epochs when the schedule is
    /// deterministic (`Fixed` batcher mode with unbounded buckets); no
    /// effect in `Stochastic` mode. On by default.
    pub subgraph_cache: bool,
    /// Partition-parallel shards (`coordinator::sharded`): 1 = plain serial
    /// trainer; > 1 = one worker trainer per shard, run concurrently and
    /// synchronized at epoch barriers.
    pub shards: usize,
    /// Epochs between parameter-averaging syncs (sharded runs only).
    pub sync_every: usize,
    /// How sharded workers synchronize: "avg" (synchronous parameter
    /// averaging) or "hist" (averaging + boundary history-row exchange).
    pub sync_mode: SyncMode,
    /// SPIDER anchor period (LMC-SPIDER only).
    pub spider_period: usize,
    /// Serve-path tile assembly: "cached" (1-hop core + history halo, the
    /// LMC-style default) or "exact" (L-hop closure, bit-identical to the
    /// full-graph oracle).
    pub serve_mode: ServeMode,
    /// Serve-path micro-batching: flush once this many node ids are
    /// queued; also the max core nodes per assembled tile.
    pub serve_max_batch: usize,
    /// Serve-path micro-batching: flush once the oldest queued request
    /// has waited this many milliseconds.
    pub serve_max_wait_ms: u64,
    /// Compensation family override (`compensation = "lmc" | "top" | "none"`).
    /// Training: must agree with the method (the method implies its
    /// compensation; the knob exists for explicit configs and clear errors).
    /// Serve: selects the cached-mode halo policy — unset defaults to the
    /// Eq. 9 combination when `comp_beta > 0` and pure history otherwise.
    pub compensation: Option<CompKind>,
    /// Eq. 9 β strength on the cached serve path (0 = pure history).
    /// `serve_beta` is the deprecated TOML/CLI alias for this knob.
    pub comp_beta: f32,
    /// TOP: learning rate for the online transform fit (normalized
    /// relaxation step; 1.0 ≈ jump to the per-batch least-squares fit).
    pub top_lr: f32,
    /// TCP listen address (`host:port`) for the networked serve
    /// front-end; `None` (default) keeps the stdin/stdout transport.
    pub serve_listen: Option<String>,
    /// Loadtest: target open-loop arrival rate, requests/second across
    /// all connections.
    pub loadtest_qps: f64,
    /// Loadtest: concurrent client connections.
    pub loadtest_conns: usize,
    /// Loadtest: duration of the arrival schedule, seconds.
    pub loadtest_secs: f64,
    /// Loadtest: request sizes (node ids per request), cycled across the
    /// schedule so batches mix small and large requests.
    pub loadtest_sizes: Vec<usize>,
    /// At-rest element type of the history store (`Hbar`/`Vbar` rows):
    /// "f32" (bit-identical default), "bf16" (half the bytes/node, ≤ 2⁻⁸
    /// relative quantization error), or "f16". Accumulation stays f32.
    pub history_dtype: HistDtype,
    /// Ablation (Fig. 4): run LMC with only the forward compensation C_f by
    /// forcing the backward compensation off.
    pub force_bwd_off: bool,
    pub verbose: bool,
    /// Directory for epoch-boundary `LMCCKPT1` checkpoints (and the
    /// `lmc train --resume` source). `None` (default) disables
    /// checkpointing entirely — the train loop stays untouched.
    pub checkpoint_dir: Option<String>,
    /// Epochs between checkpoints when `checkpoint_dir` is set (the final
    /// epoch is always checkpointed).
    pub checkpoint_every: usize,
    /// Sharded recovery: how many times a failed worker epoch may be
    /// rolled back to the sync-barrier snapshot and retried before the
    /// run errors out. 0 disables recovery.
    pub worker_retries: usize,
    /// Halo subsampling policy (`--halo-sampler`): "none" (full halo, the
    /// bit-identical default), "uniform" (rescaled uniform cap), "labor"
    /// (LABOR layer-dependent), or "importance" (FastGCN/LADIES). Every
    /// policy except "none" keeps halo nodes with explicit inclusion
    /// probabilities and rescales the surviving edges by 1/p, so the
    /// expected aggregation matches the full halo.
    pub halo_sampler: HaloSamplerKind,
    /// Target keep fraction of each batch's halo (`--halo-keep`); only
    /// active when `halo_sampler` is not "none". 1.0 is a passthrough.
    pub halo_keep: f32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: DatasetId::ArxivSim,
            arch: "gcn".into(),
            method: Method::Lmc,
            backend: Backend::Native,
            seed: 0,
            parts: 0, // 0 = dataset default
            clusters_per_batch: 2,
            epochs: 60,
            lr: 1e-2,
            weight_decay: 0.0,
            beta: BetaConfig::default(),
            batcher_mode: BatcherMode::Stochastic,
            eval_every: 2,
            target_acc: None,
            artifact_dir: "artifacts".into(),
            pipeline: false,
            subgraph_cache: true,
            shards: 1,
            sync_every: 1,
            sync_mode: SyncMode::Average,
            spider_period: 10,
            serve_mode: ServeMode::Cached,
            serve_max_batch: 256,
            serve_max_wait_ms: 4,
            compensation: None,
            comp_beta: 0.0,
            top_lr: 0.25,
            serve_listen: None,
            loadtest_qps: 500.0,
            loadtest_conns: 8,
            loadtest_secs: 5.0,
            loadtest_sizes: vec![1, 4, 16],
            history_dtype: HistDtype::F32,
            force_bwd_off: false,
            verbose: false,
            checkpoint_dir: None,
            checkpoint_every: 1,
            worker_retries: 2,
            halo_sampler: HaloSamplerKind::None,
            halo_keep: 0.5,
        }
    }
}

impl RunConfig {
    /// The halo subsampling policy these knobs select.
    pub fn halo_sampler(&self) -> HaloSampler {
        HaloSampler::new(self.halo_sampler, self.halo_keep)
    }

    pub fn parts_or_default(&self) -> usize {
        if self.parts > 0 {
            self.parts
        } else {
            self.dataset.default_parts()
        }
    }

    pub fn from_toml_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let doc = toml_parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let get = |k: &str| doc.get(k).or_else(|| doc.get(&format!("train.{k}")));
        if let Some(v) = get("dataset").and_then(|v| v.as_str()) {
            self.dataset = DatasetId::parse(v).ok_or_else(|| anyhow!("unknown dataset {v}"))?;
        }
        if let Some(v) = get("arch").and_then(|v| v.as_str()) {
            self.arch = v.to_string();
        }
        if let Some(v) = get("method").and_then(|v| v.as_str()) {
            self.method = Method::parse(v).ok_or_else(|| anyhow!("unknown method {v}"))?;
        }
        if let Some(v) = get("backend").and_then(|v| v.as_str()) {
            self.backend = Backend::parse(v).ok_or_else(|| anyhow!("unknown backend {v}"))?;
        }
        if let Some(v) = get("seed").and_then(|v| v.as_i64()) {
            self.seed = v as u64;
        }
        if let Some(v) = get("parts").and_then(|v| v.as_i64()) {
            self.parts = v as usize;
        }
        if let Some(v) = get("clusters_per_batch").and_then(|v| v.as_i64()) {
            self.clusters_per_batch = v as usize;
        }
        if let Some(v) = get("epochs").and_then(|v| v.as_i64()) {
            self.epochs = v as usize;
        }
        if let Some(v) = get("lr").and_then(|v| v.as_f64()) {
            self.lr = v;
        }
        if let Some(v) = get("weight_decay").and_then(|v| v.as_f64()) {
            self.weight_decay = v;
        }
        if let Some(v) = get("beta_alpha").and_then(|v| v.as_f64()) {
            self.beta.alpha = v as f32;
        }
        if let Some(v) = get("beta_score").and_then(|v| v.as_str()) {
            self.beta.score = BetaScore::parse(v).ok_or_else(|| anyhow!("unknown score {v}"))?;
        }
        if let Some(v) = get("fixed_batches").and_then(|v| v.as_bool()) {
            self.batcher_mode = if v { BatcherMode::Fixed } else { BatcherMode::Stochastic };
        }
        if let Some(v) = get("eval_every").and_then(|v| v.as_i64()) {
            self.eval_every = v as usize;
        }
        if let Some(v) = get("target_acc").and_then(|v| v.as_f64()) {
            self.target_acc = Some(v);
        }
        if let Some(v) = get("artifact_dir").and_then(|v| v.as_str()) {
            self.artifact_dir = v.to_string();
        }
        if let Some(v) = get("pipeline").and_then(|v| v.as_bool()) {
            self.pipeline = v;
        }
        if let Some(v) = get("subgraph_cache").and_then(|v| v.as_bool()) {
            self.subgraph_cache = v;
        }
        if let Some(v) = get("shards").and_then(|v| v.as_i64()) {
            // a negative value must not wrap to usize::MAX
            self.shards = v.max(0) as usize;
        }
        if let Some(v) = get("sync_every").and_then(|v| v.as_i64()) {
            self.sync_every = v.max(0) as usize;
        }
        if let Some(v) = get("sync_mode").and_then(|v| v.as_str()) {
            self.sync_mode =
                SyncMode::parse(v).ok_or_else(|| anyhow!("unknown sync_mode {v}"))?;
        }
        if let Some(v) = get("spider_period").and_then(|v| v.as_i64()) {
            self.spider_period = v as usize;
        }
        if let Some(v) = get("serve_mode").and_then(|v| v.as_str()) {
            self.serve_mode =
                ServeMode::parse(v).ok_or_else(|| anyhow!("unknown serve_mode {v}"))?;
        }
        if let Some(v) = get("serve_max_batch").and_then(|v| v.as_i64()) {
            self.serve_max_batch = v.max(0) as usize;
        }
        if let Some(v) = get("serve_max_wait_ms").and_then(|v| v.as_i64()) {
            self.serve_max_wait_ms = v.max(0) as u64;
        }
        if let Some(v) = get("compensation").and_then(|v| v.as_str()) {
            self.compensation =
                Some(CompKind::parse(v).ok_or_else(|| anyhow!("unknown compensation {v}"))?);
        }
        if let Some(v) = get("serve_beta").and_then(|v| v.as_f64()) {
            // deprecated alias for comp_beta (pre-Compensation-trait name);
            // applied first so an explicit comp_beta wins when both are set
            eprintln!(
                "warning: `serve_beta` is deprecated; use `comp_beta` (with \
                 `compensation = \"lmc\"` to be explicit)"
            );
            self.comp_beta = v as f32;
        }
        if let Some(v) = get("comp_beta").and_then(|v| v.as_f64()) {
            self.comp_beta = v as f32;
        }
        if let Some(v) = get("top_lr").and_then(|v| v.as_f64()) {
            self.top_lr = v as f32;
        }
        if let Some(v) = get("serve_listen").and_then(|v| v.as_str()) {
            self.serve_listen = Some(v.to_string());
        }
        if let Some(v) = get("loadtest_qps").and_then(|v| v.as_f64()) {
            self.loadtest_qps = v;
        }
        if let Some(v) = get("loadtest_conns").and_then(|v| v.as_i64()) {
            self.loadtest_conns = v.max(0) as usize;
        }
        if let Some(v) = get("loadtest_secs").and_then(|v| v.as_f64()) {
            self.loadtest_secs = v;
        }
        if let Some(v) = get("loadtest_sizes").and_then(|v| v.as_str()) {
            self.loadtest_sizes = parse_sizes(v)?;
        }
        if let Some(v) = get("history_dtype").and_then(|v| v.as_str()) {
            self.history_dtype = HistDtype::parse(v).map_err(|e| anyhow!(e))?;
        }
        if let Some(v) = get("checkpoint_dir").and_then(|v| v.as_str()) {
            self.checkpoint_dir = Some(v.to_string());
        }
        if let Some(v) = get("checkpoint_every").and_then(|v| v.as_i64()) {
            self.checkpoint_every = v.max(0) as usize;
        }
        if let Some(v) = get("worker_retries").and_then(|v| v.as_i64()) {
            self.worker_retries = v.max(0) as usize;
        }
        if let Some(v) = get("halo_sampler").and_then(|v| v.as_str()) {
            self.halo_sampler = HaloSamplerKind::parse(v).ok_or_else(|| {
                anyhow!("unknown halo_sampler {v} (none | uniform | labor | importance)")
            })?;
        }
        if let Some(v) = get("halo_keep").and_then(|v| v.as_f64()) {
            if !(0.0..=1.0).contains(&v) {
                return Err(anyhow!("halo_keep must be in [0, 1], got {v}"));
            }
            self.halo_keep = v as f32;
        }
        Ok(())
    }

    pub fn apply_cli(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.opt("config") {
            let text = std::fs::read_to_string(v)?;
            let doc = toml_parse(&text).map_err(|e| anyhow!("{v}: {e}"))?;
            self.apply_toml(&doc)?;
        }
        if let Some(v) = args.opt("dataset") {
            self.dataset = DatasetId::parse(v).ok_or_else(|| anyhow!("unknown dataset {v}"))?;
        }
        if let Some(v) = args.opt("arch") {
            self.arch = v.to_string();
        }
        if let Some(v) = args.opt("method") {
            self.method = Method::parse(v).ok_or_else(|| anyhow!("unknown method {v}"))?;
        }
        if let Some(v) = args.opt("backend") {
            self.backend = Backend::parse(v).ok_or_else(|| anyhow!("unknown backend {v}"))?;
        }
        if let Some(v) = args.opt_usize("seed") {
            self.seed = v as u64;
        }
        if let Some(v) = args.opt_usize("parts") {
            self.parts = v;
        }
        if let Some(v) = args.opt_usize("clusters-per-batch") {
            self.clusters_per_batch = v;
        }
        if let Some(v) = args.opt_usize("epochs") {
            self.epochs = v;
        }
        if let Some(v) = args.opt_f64("lr") {
            self.lr = v;
        }
        if let Some(v) = args.opt_f64("beta-alpha") {
            self.beta.alpha = v as f32;
        }
        if let Some(v) = args.opt("beta-score") {
            self.beta.score = BetaScore::parse(v).ok_or_else(|| anyhow!("unknown score {v}"))?;
        }
        if let Some(v) = args.opt_f64("target-acc") {
            self.target_acc = Some(v);
        }
        if let Some(v) = args.opt_usize("eval-every") {
            self.eval_every = v;
        }
        if let Some(v) = args.opt("artifacts") {
            self.artifact_dir = v.to_string();
        }
        if let Some(v) = args.opt_usize("shards") {
            self.shards = v;
        }
        if let Some(v) = args.opt_usize("sync-every") {
            self.sync_every = v;
        }
        if let Some(v) = args.opt("sync-mode") {
            self.sync_mode =
                SyncMode::parse(v).ok_or_else(|| anyhow!("unknown sync-mode {v}"))?;
        }
        if let Some(v) = args.opt("serve-mode") {
            self.serve_mode =
                ServeMode::parse(v).ok_or_else(|| anyhow!("unknown serve-mode {v}"))?;
        }
        if let Some(v) = args.opt_usize("serve-max-batch") {
            self.serve_max_batch = v;
        }
        if let Some(v) = args.opt_usize("serve-max-wait-ms") {
            self.serve_max_wait_ms = v as u64;
        }
        if let Some(v) = args.opt("compensation") {
            self.compensation =
                Some(CompKind::parse(v).ok_or_else(|| anyhow!("unknown compensation {v}"))?);
        }
        if let Some(v) = args.opt_f64("serve-beta") {
            // deprecated alias, applied before --comp-beta so the
            // canonical flag wins when both are given
            eprintln!("warning: `--serve-beta` is deprecated; use `--comp-beta`");
            self.comp_beta = v as f32;
        }
        if let Some(v) = args.opt_f64("comp-beta") {
            self.comp_beta = v as f32;
        }
        if let Some(v) = args.opt_f64("top-lr") {
            self.top_lr = v as f32;
        }
        if let Some(v) = args.opt("listen") {
            self.serve_listen = Some(v.to_string());
        }
        if let Some(v) = args.opt_f64("loadtest-qps") {
            self.loadtest_qps = v;
        }
        if let Some(v) = args.opt_usize("loadtest-conns") {
            self.loadtest_conns = v;
        }
        if let Some(v) = args.opt_f64("loadtest-secs") {
            self.loadtest_secs = v;
        }
        if let Some(v) = args.opt("loadtest-sizes") {
            self.loadtest_sizes = parse_sizes(v)?;
        }
        if let Some(v) = args.opt("history-dtype") {
            self.history_dtype = HistDtype::parse(v).map_err(|e| anyhow!(e))?;
        }
        if let Some(v) = args.opt("checkpoint-dir") {
            self.checkpoint_dir = Some(v.to_string());
        }
        if let Some(v) = args.opt_usize("checkpoint-every") {
            self.checkpoint_every = v;
        }
        if let Some(v) = args.opt_usize("worker-retries") {
            self.worker_retries = v;
        }
        if let Some(v) = args.opt("halo-sampler") {
            self.halo_sampler = HaloSamplerKind::parse(v).ok_or_else(|| {
                anyhow!("unknown halo-sampler {v} (none | uniform | labor | importance)")
            })?;
        }
        if let Some(v) = args.opt_f64("halo-keep") {
            if !(0.0..=1.0).contains(&v) {
                return Err(anyhow!("--halo-keep must be in [0, 1], got {v}"));
            }
            self.halo_keep = v as f32;
        }
        if args.has_flag("fixed-batches") {
            self.batcher_mode = BatcherMode::Fixed;
        }
        if args.has_flag("pipeline") {
            self.pipeline = true;
        }
        if args.has_flag("no-subgraph-cache") {
            self.subgraph_cache = false;
        }
        if args.has_flag("verbose") {
            self.verbose = true;
        }
        Ok(())
    }
}

/// Comma-separated request-size list (`"1,4,16"`) for the loadtest knob.
fn parse_sizes(s: &str) -> Result<Vec<usize>> {
    let v: Vec<usize> = s
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().map_err(|e| anyhow!("bad request size '{t}': {e}")))
        .collect::<Result<_>>()?;
    if v.is_empty() {
        return Err(anyhow!("loadtest_sizes needs at least one request size"));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_overrides() {
        let doc = toml_parse(
            "[train]\nmethod = \"gas\"\ndataset = \"reddit-sim\"\nlr = 0.005\nepochs = 7\nbeta_score = \"2x-x2\"\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.method, Method::Gas);
        assert_eq!(cfg.backend, Backend::Native); // default
        assert_eq!(cfg.dataset, DatasetId::RedditSim);
        assert_eq!(cfg.lr, 0.005);
        assert_eq!(cfg.epochs, 7);
        assert_eq!(cfg.beta.score, BetaScore::TwoXMinusXSquared);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["train", "--method", "cluster", "--epochs", "3", "--backend", "native", "--verbose"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut cfg = RunConfig::default();
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.method, Method::Cluster);
        assert_eq!(cfg.epochs, 3);
        assert_eq!(cfg.backend, Backend::Native);
        assert!(cfg.verbose);
    }

    #[test]
    fn sharding_knobs_parse() {
        let doc =
            toml_parse("shards = 4\nsync_every = 3\nsync_mode = \"hist\"\n").unwrap();
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.shards, 1); // serial by default
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.sync_every, 3);
        assert_eq!(cfg.sync_mode, SyncMode::HistoryExchange);
        let args = Args::parse(
            ["train", "--shards", "2", "--sync-every", "5", "--sync-mode", "avg"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.shards, 2);
        assert_eq!(cfg.sync_every, 5);
        assert_eq!(cfg.sync_mode, SyncMode::Average);
        assert!(SyncMode::parse("nope").is_none());
        assert_eq!(SyncMode::Average.name(), "avg");
        assert_eq!(SyncMode::HistoryExchange.name(), "hist");
    }

    #[test]
    fn serve_knobs_parse() {
        let doc = toml_parse(
            "serve_mode = \"exact\"\nserve_max_batch = 64\nserve_max_wait_ms = 9\ncomp_beta = 0.25\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        // these assert the *defaults*, before apply_toml runs — see the
        // explicit precedence test below for the layering itself
        assert_eq!(cfg.serve_mode, ServeMode::Cached);
        assert_eq!(cfg.comp_beta, 0.0);
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.serve_mode, ServeMode::Exact);
        assert_eq!(cfg.serve_max_batch, 64);
        assert_eq!(cfg.serve_max_wait_ms, 9);
        assert!((cfg.comp_beta - 0.25).abs() < 1e-9);
        let args = Args::parse(
            [
                "serve",
                "--serve-mode",
                "cached",
                "--serve-max-batch",
                "512",
                "--serve-max-wait-ms",
                "2",
                "--comp-beta",
                "0.1",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.serve_mode, ServeMode::Cached);
        assert_eq!(cfg.serve_max_batch, 512);
        assert_eq!(cfg.serve_max_wait_ms, 2);
        assert!((cfg.comp_beta - 0.1).abs() < 1e-6);
        assert!(ServeMode::parse("bogus").is_none());
    }

    /// Intended layering, pinned explicitly (ISSUE 9 satellite): defaults
    /// < TOML (including a `--config FILE` named on the command line,
    /// which `apply_cli` applies *first*) < explicit CLI flags. The old
    /// `serve_knobs_parse` asserted `serve_beta == 0.0` *before* calling
    /// `apply_toml` — that checks the default, not a precedence bug.
    #[test]
    fn serve_knob_precedence_is_defaults_then_toml_then_cli() {
        // defaults
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.comp_beta, 0.0);
        assert_eq!(cfg.serve_max_batch, 256);
        // TOML layer overrides defaults
        let doc = toml_parse("comp_beta = 0.25\nserve_max_batch = 64\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert!((cfg.comp_beta - 0.25).abs() < 1e-9);
        assert_eq!(cfg.serve_max_batch, 64);
        // --config file layer + explicit flags in one apply_cli call: the
        // file is applied first, so the explicit flag wins over it
        let dir = std::env::temp_dir().join(format!("lmc_cfg_prec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prec.toml");
        std::fs::write(&path, "comp_beta = 0.5\nserve_max_batch = 32\n").unwrap();
        let args = Args::parse(
            ["serve", "--config", path.to_str().unwrap(), "--comp-beta", "0.1"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert!((cfg.comp_beta - 0.1).abs() < 1e-6, "explicit flag beats --config file");
        assert_eq!(cfg.serve_max_batch, 32, "--config file beats earlier layers");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compensation_knobs_and_deprecated_serve_beta_alias() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.compensation, None); // method decides by default
        assert_eq!(cfg.top_lr, 0.25);
        let doc = toml_parse("compensation = \"top\"\ntop_lr = 0.05\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.compensation, Some(CompKind::Top));
        assert!((cfg.top_lr - 0.05).abs() < 1e-9);
        // deprecated TOML alias still lands on comp_beta
        let doc = toml_parse("serve_beta = 0.3\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert!((cfg.comp_beta - 0.3).abs() < 1e-9);
        // canonical key wins when both are present in one document
        let doc = toml_parse("serve_beta = 0.9\ncomp_beta = 0.2\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert!((cfg.comp_beta - 0.2).abs() < 1e-9);
        // CLI: alias maps, canonical flag wins over the alias
        let args = Args::parse(
            ["serve", "--serve-beta", "0.4"].iter().map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert!((cfg.comp_beta - 0.4).abs() < 1e-6);
        let args = Args::parse(
            ["serve", "--serve-beta", "0.4", "--comp-beta", "0.6", "--compensation", "lmc"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert!((cfg.comp_beta - 0.6).abs() < 1e-6);
        assert_eq!(cfg.compensation, Some(CompKind::Lmc));
        // bad names error instead of silently defaulting
        let doc = toml_parse("compensation = \"bogus\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
    }

    #[test]
    fn listen_and_loadtest_knobs_parse() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.serve_listen, None); // stdin transport by default
        assert_eq!(cfg.loadtest_conns, 8);
        assert_eq!(cfg.loadtest_sizes, vec![1, 4, 16]);
        let doc = toml_parse(
            "serve_listen = \"127.0.0.1:7070\"\nloadtest_qps = 250.0\nloadtest_conns = 4\n\
             loadtest_secs = 1.5\nloadtest_sizes = \"2,8\"\n",
        )
        .unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.serve_listen.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(cfg.loadtest_qps, 250.0);
        assert_eq!(cfg.loadtest_conns, 4);
        assert_eq!(cfg.loadtest_secs, 1.5);
        assert_eq!(cfg.loadtest_sizes, vec![2, 8]);
        let args = Args::parse(
            [
                "loadtest",
                "--listen",
                "0.0.0.0:9090",
                "--loadtest-qps",
                "1000",
                "--loadtest-conns",
                "16",
                "--loadtest-secs",
                "3",
                "--loadtest-sizes",
                "1, 32",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.serve_listen.as_deref(), Some("0.0.0.0:9090"));
        assert_eq!(cfg.loadtest_qps, 1000.0);
        assert_eq!(cfg.loadtest_conns, 16);
        assert_eq!(cfg.loadtest_secs, 3.0);
        assert_eq!(cfg.loadtest_sizes, vec![1, 32]);
        // malformed size lists error instead of silently defaulting
        assert!(parse_sizes("1,x").is_err());
        assert!(parse_sizes("").is_err());
    }

    #[test]
    fn history_dtype_knob_parses() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.history_dtype, HistDtype::F32); // bit-identical default
        let doc = toml_parse("history_dtype = \"bf16\"\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.history_dtype, HistDtype::Bf16);
        // train.-scoped key works like every other knob
        let doc = toml_parse("[train]\nhistory_dtype = \"f16\"\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.history_dtype, HistDtype::F16);
        let args = Args::parse(
            ["train", "--history-dtype", "f32"].iter().map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.history_dtype, HistDtype::F32);
        // bad names error instead of silently defaulting
        let doc = toml_parse("history_dtype = \"int8\"\n").unwrap();
        let err = cfg.apply_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("int8") && err.contains("bf16"), "{err}");
        assert!(HistDtype::parse("f64").is_err());
    }

    #[test]
    fn checkpoint_knobs_parse() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.checkpoint_dir, None); // checkpointing off by default
        assert_eq!(cfg.checkpoint_every, 1);
        assert_eq!(cfg.worker_retries, 2);
        let doc = toml_parse(
            "checkpoint_dir = \"ckpt\"\ncheckpoint_every = 5\nworker_retries = 3\n",
        )
        .unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.checkpoint_dir.as_deref(), Some("ckpt"));
        assert_eq!(cfg.checkpoint_every, 5);
        assert_eq!(cfg.worker_retries, 3);
        // train.-scoped keys work like every other knob
        let doc = toml_parse("[train]\ncheckpoint_every = 2\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.checkpoint_every, 2);
        let args = Args::parse(
            [
                "train",
                "--checkpoint-dir",
                "other",
                "--checkpoint-every",
                "7",
                "--worker-retries",
                "0",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.checkpoint_dir.as_deref(), Some("other"));
        assert_eq!(cfg.checkpoint_every, 7);
        assert_eq!(cfg.worker_retries, 0);
    }

    #[test]
    fn halo_sampler_knobs_parse() {
        let mut cfg = RunConfig::default();
        // bit-identical default: no subsampling policy
        assert_eq!(cfg.halo_sampler, HaloSamplerKind::None);
        assert!(!cfg.halo_sampler().is_subsampling());
        let doc = toml_parse("halo_sampler = \"labor\"\nhalo_keep = 0.25\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.halo_sampler, HaloSamplerKind::Labor);
        assert!((cfg.halo_keep - 0.25).abs() < 1e-6);
        assert!(cfg.halo_sampler().is_subsampling());
        // train.-scoped key works like every other knob
        let doc = toml_parse("[train]\nhalo_sampler = \"importance\"\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.halo_sampler, HaloSamplerKind::Importance);
        let args = Args::parse(
            ["train", "--halo-sampler", "uniform", "--halo-keep", "0.75"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.halo_sampler, HaloSamplerKind::Uniform);
        assert!((cfg.halo_keep - 0.75).abs() < 1e-6);
        // bad names and out-of-range fractions error instead of defaulting
        let doc = toml_parse("halo_sampler = \"bogus\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let doc = toml_parse("halo_keep = 1.5\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let args = Args::parse(
            ["train", "--halo-keep", "-0.1"].iter().map(|s| s.to_string()),
        );
        assert!(cfg.apply_cli(&args).is_err());
    }

    #[test]
    fn backend_parses_from_toml() {
        let doc = toml_parse("backend = \"pjrt\"\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.backend, Backend::Pjrt);
        assert!(Backend::parse("nope").is_none());
        assert_eq!(Backend::Native.name(), "native");
    }
}
