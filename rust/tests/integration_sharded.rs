//! Integration: partition-parallel sharded training (`coordinator::sharded`)
//! on the native backend — shards=1 equivalence with the plain trainer,
//! bit-determinism under worker scheduling, both sync modes, the sharded
//! convergence gap vs serial, and workspace stability under sharding.

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{Method, ShardedTrainer, SyncMode, Trainer};
use lmc::graph::DatasetId;
use lmc::sampler::BatcherMode;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new())
}

fn cfg(epochs: usize, shards: usize) -> RunConfig {
    RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method: Method::Lmc,
        epochs,
        eval_every: usize::MAX,
        seed: 1,
        shards,
        ..Default::default()
    }
}

#[test]
fn shards_one_is_bit_identical_to_plain_trainer() {
    // The sharded coordinator must degenerate to the serial trainer: one
    // shard covering the whole graph, worker 0 seeded like the plain
    // trainer, averaging a no-op. Parameters and per-epoch training
    // metrics are compared bit-for-bit.
    let c = cfg(3, 1);
    let mut serial = Trainer::new(exec(), c.clone()).unwrap();
    let sm = serial.run().unwrap();
    let mut sharded = ShardedTrainer::new(exec(), c).unwrap();
    let dm = sharded.run().unwrap();
    assert_eq!(sharded.num_workers(), 1);
    assert_eq!(sharded.boundary_rows(), 0, "single shard has no boundary");
    let wp = &sharded.workers[0].trainer.params;
    assert_eq!(serial.params.tensors.len(), wp.tensors.len());
    for (a, b) in serial.params.tensors.iter().zip(&wp.tensors) {
        assert_eq!(a.data, b.data, "sharded(1) params diverged from plain trainer");
    }
    assert_eq!(sm.records.len(), dm.records.len());
    for (a, b) in sm.records.iter().zip(&dm.records) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.staleness.to_bits(), b.staleness.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.active_bytes, b.active_bytes, "epoch {}", a.epoch);
    }
}

#[test]
fn sharded_runs_are_deterministic_under_scheduling() {
    // Workers run on the rayon pool in nondeterministic order, but every
    // synchronization happens on the coordinator thread in fixed shard
    // order — two identically-seeded runs must agree bit-for-bit.
    let run = || {
        let mut t = ShardedTrainer::new(exec(), cfg(3, 4)).unwrap();
        let m = t.run().unwrap();
        let params: Vec<Vec<Vec<f32>>> = t
            .workers
            .iter()
            .map(|w| w.trainer.params.tensors.iter().map(|x| x.data.clone()).collect())
            .collect();
        (m, params)
    };
    let (m1, p1) = run();
    let (m2, p2) = run();
    assert_eq!(p1, p2, "worker params differ across identical runs");
    assert_eq!(m1.records.len(), m2.records.len());
    for (a, b) in m1.records.iter().zip(&m2.records) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.val_acc.to_bits(), b.val_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.staleness.to_bits(), b.staleness.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.active_bytes, b.active_bytes, "epoch {}", a.epoch);
    }
}

#[test]
fn shards4_averaging_tracks_serial_final_loss() {
    // Acceptance: a shards=4 synchronous-averaging run reaches within 2%
    // of the single-trainer final loss in the same number of epochs, with
    // both losses measured by the *exact parent-graph* oracle (per-shard
    // training losses carry a constant boundary-truncation offset, so they
    // are not comparable across topologies). One cluster-group per step
    // (clusters_per_batch = parts) keeps local drift to a single Adam step
    // between averages, and the conservative lr keeps both trajectories in
    // the tracking regime where epoch-wise averaging follows the serial
    // path; the asymptotic boundary-truncation gap at large lr is exactly
    // what the hist sync mode is for (see rust/README.md).
    let epochs = 6;
    let mk = |shards: usize| {
        let mut c = cfg(epochs, shards);
        c.clusters_per_batch = 8; // = cora-sim default parts: one step/epoch
        c.lr = 1e-3;
        c
    };
    let mut serial = Trainer::new(exec(), mk(1)).unwrap();
    let init_loss = serial.evaluate().unwrap().train_loss;
    serial.run().unwrap();
    let s_final = serial.evaluate().unwrap().train_loss;
    let mut sharded = ShardedTrainer::new(exec(), mk(4)).unwrap();
    assert!(sharded.num_workers() > 1);
    sharded.run().unwrap();
    let d_final = sharded.evaluate().unwrap().train_loss;
    assert!(s_final < init_loss, "serial baseline failed to learn ({init_loss} -> {s_final})");
    assert!(d_final < init_loss, "sharded run failed to learn ({init_loss} -> {d_final})");
    let tol = 0.02 * s_final.abs().max(init_loss.abs());
    assert!(
        (d_final - s_final).abs() <= tol,
        "sharded final loss {d_final:.4} vs serial {s_final:.4} (tol {tol:.4}, init {init_loss:.4})"
    );
}

#[test]
fn history_exchange_syncs_boundary_rows() {
    // hist mode: boundary history rows are exchanged every epoch even when
    // parameter averaging runs less often. After the final epoch's
    // exchange every halo row must bitwise match the owner's core row.
    let mut c = cfg(3, 3);
    c.sync_mode = SyncMode::HistoryExchange;
    c.sync_every = 2;
    let mut t = ShardedTrainer::new(exec(), c).unwrap();
    assert!(t.boundary_rows() > 0, "3 shards of cora-sim must share boundaries");
    let m = t.run().unwrap();
    let first = m.records.first().unwrap().train_loss;
    let last = m.records.last().unwrap().train_loss;
    assert!(last < first, "hist mode failed to learn ({first} -> {last})");
    for l in 1..t.workers[0].trainer.arch_l() {
        assert!(t.boundary_in_sync(l), "layer {l} boundary rows out of sync after exchange");
    }

    // control: in avg mode halo rows keep their locally-computed values,
    // which differ from the owner's (different subgraph, different params)
    let mut t2 = ShardedTrainer::new(exec(), cfg(3, 3)).unwrap();
    t2.run().unwrap();
    assert!(
        !t2.boundary_in_sync(1),
        "avg mode should not have exchanged boundary history rows"
    );
}

#[test]
fn sharded_workspace_misses_stabilize() {
    // PR 2's zero-steady-state-allocation property must survive the
    // sharded path: after warmup epochs every worker's workspace pool
    // covers all per-layer grabs.
    let mut c = cfg(1, 3);
    c.batcher_mode = BatcherMode::Fixed;
    let mut t = ShardedTrainer::new(exec(), c).unwrap();
    t.train_epoch().unwrap();
    t.train_epoch().unwrap();
    let misses = |t: &ShardedTrainer| -> u64 {
        t.workers.iter().map(|w| w.trainer.ws.lock().unwrap().misses()).sum()
    };
    let grabs = |t: &ShardedTrainer| -> u64 {
        t.workers.iter().map(|w| w.trainer.ws.lock().unwrap().grabs()).sum()
    };
    let warm = misses(&t);
    t.train_epoch().unwrap();
    t.train_epoch().unwrap();
    assert_eq!(misses(&t), warm, "sharded steady-state epochs still allocate step buffers");
    assert!(grabs(&t) > warm, "sharded workspace not exercised");
}

#[test]
fn sharded_worker_graphs_tile_the_parent() {
    // Construction invariants: every parent node is a core node of exactly
    // one worker, the composed internal->global maps are consistent, and
    // no worker trains a halo node (its split is demoted).
    let t = ShardedTrainer::new(exec(), cfg(1, 4)).unwrap();
    let n = t.parent.n();
    let mut owner_count = vec![0usize; n];
    for (wid, w) in t.workers.iter().enumerate() {
        let nc = t.views[wid].n_core();
        assert_eq!(w.global_of.len(), w.trainer.graph.n());
        for (row, &g) in w.global_of.iter().enumerate() {
            let old = w.trainer.orig_of[row] as usize;
            assert_eq!(t.views[wid].global_of(old as u32), g);
            if old < nc {
                owner_count[g as usize] += 1;
                // core rows keep the parent split
                assert_eq!(w.trainer.graph.split[row], t.parent.split[g as usize]);
            } else {
                // halo rows are never trainable
                assert_ne!(w.trainer.graph.split[row], 0, "halo row in train split");
            }
        }
    }
    assert!(owner_count.iter().all(|&c| c == 1), "parent nodes not tiled exactly once");
    // labeled-train totals add up to the parent's
    let total: usize = t.workers.iter().map(|w| w.trainer.n_train).sum();
    assert_eq!(total, t.parent.num_labeled_train());
}
