//! Integration: the [`lmc::compensation::Compensation`] trait seam.
//!
//! The refactor's contract is twofold: (1) routing LMC through the trait
//! must be *bit-identical* to the pre-trait trainer — pinned here against
//! a frozen replica of the old hand-wired step sequence; (2) the new TOP
//! policy (message invariance, arXiv 2502.19693) must train, checkpoint
//! its learned transforms bitwise through `LMCCKPT1`, and land a gradient
//! error below GAS at a fraction of LMC's history memory (the shoot-out
//! acceptance criteria).

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor, StepInputs};
use lmc::checkpoint;
use lmc::compensation::CompKind;
use lmc::config::RunConfig;
use lmc::coordinator::{grad_check, Method, Trainer};
use lmc::graph::DatasetId;
use lmc::sampler::{beta_vector, build_subgraph, HaloSampler};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new())
}

fn cfg(method: Method, epochs: usize) -> RunConfig {
    RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method,
        epochs,
        eval_every: epochs,
        seed: 1,
        ..Default::default()
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The pinned bit-identity check: drive one trainer through the trait
/// (`compute_minibatch_grads`) and a twin through a frozen replica of the
/// pre-trait step sequence — explicit `beta_vector` / history gathers /
/// `StepInputs` with LMC's literal constants, then manual write-back —
/// and require bitwise-equal gradients, parameters, and history stores at
/// every step. Both twins also take the optimizer step so later rounds
/// exercise genuinely stale histories, not just the zero-initialized one.
#[test]
fn lmc_through_trait_is_bit_identical_to_frozen_reference() {
    let mut t = Trainer::new(exec(), cfg(Method::Lmc, 1)).unwrap();
    let mut r = Trainer::new(exec(), cfg(Method::Lmc, 1)).unwrap();
    let l_total = t.model.arch.l;
    let k = r.clusters.len();
    assert!(k >= 2, "cora-sim should partition into several clusters");
    let all: Vec<u32> = (0..t.graph.n() as u32).collect();

    for round in 0..2 * k {
        let batch = r.clusters[round % k].clone();

        // trait path
        let (_, grads) = t.compute_minibatch_grads(&batch, None, true).unwrap();
        t.opt.step(&mut t.params, &grads);

        // frozen reference: the pre-trait grads_for_subgraph, inlined
        let sb = build_subgraph(
            &r.graph,
            &batch,
            r.cfg.method.adjacency_policy(),
            &r.buckets,
            &HaloSampler::none(),
            &mut r.rng,
        )
        .unwrap();
        let hist_h: Vec<Vec<f32>> =
            (1..l_total).map(|l| r.history.gather_h(l, &sb.halo, sb.bucket_h)).collect();
        let hist_v: Vec<Vec<f32>> =
            (1..l_total).map(|l| r.history.gather_v(l, &sb.halo, sb.bucket_h)).collect();
        let beta = beta_vector(&sb, r.cfg.beta.alpha, r.cfg.beta.score);
        let inputs = StepInputs {
            graph: r.graph.as_ref(),
            sb: &sb,
            model: &r.model,
            params: &r.params,
            hist_h,
            hist_v,
            beta,
            bwd_scale: 1.0,
            vscale: 1.0 / r.n_train.max(1) as f32,
            grad_scale: r.batcher.grad_scale(),
            top: None,
            ws: None,
        };
        let outs = r.exec.forward_backward(&inputs).unwrap();
        for l in 1..l_total {
            r.history.scatter_h(l, &sb.batch, &outs.new_h[l - 1]);
            r.history.scatter_v(l, &sb.batch, &outs.new_v[l - 1]);
        }
        r.history.tick(&sb.batch);
        r.opt.step(&mut r.params, &outs.grads);

        assert_eq!(grads.len(), outs.grads.len());
        for (a, b) in grads.iter().zip(&outs.grads) {
            assert_eq!(bits(&a.data), bits(&b.data), "round {round}: gradients diverged");
        }
        for (a, b) in t.params.tensors.iter().zip(&r.params.tensors) {
            assert_eq!(bits(&a.data), bits(&b.data), "round {round}: params diverged");
        }
        for l in 1..l_total {
            assert_eq!(
                bits(&t.history.gather_h(l, &all, all.len())),
                bits(&r.history.gather_h(l, &all, all.len())),
                "round {round}: Hbar^{l} diverged"
            );
            assert_eq!(
                bits(&t.history.gather_v(l, &all, all.len())),
                bits(&r.history.gather_v(l, &all, all.len())),
                "round {round}: Vbar^{l} diverged"
            );
        }
    }
}

#[test]
fn top_trains_learns_and_moves_off_identity() {
    let mut t = Trainer::new(exec(), cfg(Method::Top, 6)).unwrap();
    let m = t.run().unwrap();
    let first = m.records.first().unwrap().train_loss;
    let last = m.records.last().unwrap().train_loss;
    assert!(last < first * 0.7, "TOP loss did not drop ({first} -> {last})");
    assert!(m.final_test().unwrap() > 0.4, "TOP test acc not above chance");
    // the online fit must actually have moved the transforms
    let (fwd, bwd) = t.comp.transforms().expect("TOP exposes transforms");
    let off_identity = fwd.iter().chain(bwd).any(|tr| {
        let d = tr.shape[0];
        tr.data
            .iter()
            .enumerate()
            .any(|(i, &v)| v != if i / d == i % d { 1.0 } else { 0.0 })
    });
    assert!(off_identity, "TOP transforms never updated from identity");
}

#[test]
fn top_training_is_deterministic() {
    let run = || {
        let mut c = cfg(Method::Top, 3);
        c.eval_every = usize::MAX;
        let mut t = Trainer::new(exec(), c).unwrap();
        for _ in 0..3 {
            t.train_epoch().unwrap();
        }
        let state = t.comp.encode_state();
        (t.params.tensors.clone(), state)
    };
    let (p1, s1) = run();
    let (p2, s2) = run();
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(bits(&a.data), bits(&b.data), "TOP params not deterministic");
    }
    assert_eq!(s1, s2, "TOP transform state not deterministic");
}

/// TOP's learned state must survive `LMCCKPT1` bitwise: capture → encode →
/// decode → re-encode is a fixed point, a restored trainer carries the
/// exact transform bytes, and resumed training replays bit-identically to
/// the uninterrupted run. Seed-looped so the payload is never one lucky
/// bit pattern.
#[test]
fn top_state_roundtrips_bitwise_through_lmcckpt1() {
    for seed in [1u64, 7, 23] {
        let mk = || {
            let mut c = cfg(Method::Top, 5);
            c.seed = seed;
            c.eval_every = usize::MAX;
            c
        };
        let mut t = Trainer::new(exec(), mk()).unwrap();
        for _ in 0..2 {
            t.train_epoch().unwrap();
        }
        let fp = checkpoint::config_fingerprint(&t.cfg);
        let state = checkpoint::TrainerState::capture(&t);
        let bytes = checkpoint::encode_state(&state, &fp);
        let decoded = checkpoint::decode_state(&bytes, &fp).unwrap();
        assert_eq!(
            checkpoint::encode_state(&decoded, &fp),
            bytes,
            "seed {seed}: encode/decode not a bitwise fixed point"
        );

        let mut resumed = Trainer::new(exec(), mk()).unwrap();
        decoded.restore_into(&mut resumed).unwrap();
        let comp_state = t.comp.encode_state();
        assert!(!comp_state.is_empty(), "TOP must persist transform state");
        assert_eq!(
            resumed.comp.encode_state(),
            comp_state,
            "seed {seed}: restored transforms differ"
        );

        // a resumed run must replay the original bit-for-bit
        t.train_epoch().unwrap();
        resumed.train_epoch().unwrap();
        for (a, b) in t.params.tensors.iter().zip(&resumed.params.tensors) {
            assert_eq!(bits(&a.data), bits(&b.data), "seed {seed}: resume diverged");
        }
        assert_eq!(resumed.comp.encode_state(), t.comp.encode_state());
    }
}

#[test]
fn top_rejects_mismatched_method_and_unsupported_arch() {
    // explicit knob conflicting with the method is a config error
    let mut c = cfg(Method::Top, 2);
    c.compensation = Some(CompKind::Lmc);
    assert!(Trainer::new(exec(), c).is_err());
    // agreeing knob is fine
    let mut c = cfg(Method::Top, 2);
    c.compensation = Some(CompKind::Top);
    assert!(Trainer::new(exec(), c).is_ok());
    // the message-invariance fit is wired for GCN only
    let mut c = cfg(Method::Top, 2);
    c.arch = "gcnii".into();
    assert!(Trainer::new(exec(), c).is_err());
}

/// The shoot-out acceptance criteria (`lmc experiment grad-error`): after
/// identical warmup on arxiv-sim, TOP's gradient error lands strictly
/// below GAS's (synthesized fresh-value halos beat stale history reads
/// without backward compensation) while its compensation state — two
/// `d × d` transforms per boundary — is a sliver of LMC's O(n · d)
/// history stores.
#[test]
fn top_beats_gas_error_at_a_fraction_of_lmc_memory() {
    let mut err = std::collections::HashMap::new();
    let mut state_bytes = std::collections::HashMap::new();
    for method in [Method::Lmc, Method::Top, Method::Gas] {
        let mut c = cfg(method, 3);
        c.dataset = DatasetId::ArxivSim;
        c.lr = 3e-3; // fig3's moderate-staleness regime
        c.eval_every = usize::MAX;
        let mut t = Trainer::new(exec(), c).unwrap();
        for _ in 0..3 {
            t.train_epoch().unwrap();
        }
        let rep = grad_check::measure(&mut t).unwrap();
        err.insert(method.name(), rep.overall);
        state_bytes.insert(method.name(), t.comp.state_bytes(&t.history));
    }
    let (top, gas) = (err["TOP"], err["GAS"]);
    assert!(top < gas, "TOP grad error {top} !< GAS {gas}");
    let (top_b, lmc_b) = (state_bytes["TOP"], state_bytes["LMC"]);
    assert!(
        top_b < lmc_b,
        "TOP comp state {top_b} B !< LMC history footprint {lmc_b} B"
    );
}
