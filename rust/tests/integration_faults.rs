//! Integration: crash safety. Kill-and-resume bit-identity for the serial
//! and sharded trainers (in-process via failpoints and out-of-process via
//! SIGKILL of a spawned `lmc train`), sharded worker rollback recovery,
//! retry-budget exhaustion, torn checkpoint writes leaving the previous
//! epoch resumable, and config-fingerprint refusal.

use std::fs;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use lmc::backend::{Executor, NativeExecutor};
use lmc::checkpoint;
use lmc::config::RunConfig;
use lmc::coordinator::{Method, Params, ShardedTrainer, Trainer};
use lmc::graph::DatasetId;
use lmc::util::failpoint;
use lmc::util::json::Json;

/// The failpoint rule table is process-global; every test that trains
/// in-process must hold this so an armed rule never leaks into a
/// neighbouring test's run.
static FP_LOCK: Mutex<()> = Mutex::new(());

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new())
}

/// cora-sim defaults: 8 parts, 2 clusters/batch — 4 `trainer.step` hits
/// per serial epoch, 16 per sharded epoch at shards=4 (4 per worker).
fn cfg(epochs: usize, shards: usize) -> RunConfig {
    RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method: Method::Lmc,
        epochs,
        eval_every: usize::MAX,
        seed: 1,
        shards,
        ..Default::default()
    }
}

fn temp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lmc_faults_{}_{}", name, std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn bits(p: &Params) -> Vec<Vec<u32>> {
    p.tensors.iter().map(|t| t.data.iter().map(|x| x.to_bits()).collect()).collect()
}

/// Kill a serial run at `trainer.step` hit `hit` via an injected io error,
/// resume from the last epoch checkpoint, and require the finished run to
/// be bit-identical to an uninterrupted control.
fn serial_kill_resume_at(hit: u64, name: &str) {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = temp_dir(name);

    let mut control = Trainer::new(exec(), cfg(5, 1)).unwrap();
    let control_metrics = control.run().unwrap();

    let mut c = cfg(5, 1);
    c.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    failpoint::set_for_test(&format!("trainer.step:{hit}:io-error"));
    let mut victim = Trainer::new(exec(), c.clone()).unwrap();
    let err = victim.run().unwrap_err();
    failpoint::set_for_test("");
    assert!(format!("{err:#}").contains("injected io error"), "unexpected error: {err:#}");
    drop(victim);

    let mut resumed = Trainer::resume(exec(), c, &dir).unwrap();
    let resumed_metrics = resumed.run().unwrap();

    assert_eq!(bits(&control.params), bits(&resumed.params), "params diverged after resume");
    assert_eq!(control_metrics.records.len(), resumed_metrics.records.len());
    for (a, b) in control_metrics.records.iter().zip(&resumed_metrics.records) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "epoch {} loss", a.epoch);
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn serial_kill_mid_epoch_resume_is_bit_identical() {
    // Hit 6 = epoch 2, step 2: dies mid-epoch, resumes from epoch 1.
    serial_kill_resume_at(6, "serial_mid");
}

#[test]
fn serial_kill_at_epoch_start_resume_is_bit_identical() {
    // Hit 9 = epoch 3, step 1: dies on the first step after a checkpoint.
    serial_kill_resume_at(9, "serial_start");
}

#[test]
fn sharded_interrupt_then_resume_is_bit_identical() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = temp_dir("sharded_resume");

    let mut control = ShardedTrainer::new(exec(), cfg(4, 4)).unwrap();
    control.run().unwrap();

    // retries=0 so the injected failure aborts the run instead of being
    // rolled back; hit 9 = the first worker body of epoch 3.
    let mut c = cfg(4, 4);
    c.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    c.worker_retries = 0;
    failpoint::set_for_test("sharded.worker:9:io-error");
    let mut victim = ShardedTrainer::new(exec(), c.clone()).unwrap();
    let err = victim.run().unwrap_err();
    failpoint::set_for_test("");
    assert!(format!("{err:#}").contains("worker"), "unexpected error: {err:#}");
    drop(victim);

    let mut resumed = ShardedTrainer::resume(exec(), c, &dir).unwrap();
    assert_eq!(resumed.epochs_done(), 2, "should resume from the epoch-2 barrier");
    resumed.run().unwrap();

    for w in 0..control.num_workers() {
        assert_eq!(
            bits(&control.workers[w].trainer.params),
            bits(&resumed.workers[w].trainer.params),
            "worker {w} params diverged after resume"
        );
    }
    assert_eq!(bits(&control.averaged_params()), bits(&resumed.averaged_params()));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn sharded_worker_panic_at_epoch_start_recovers_bit_identically() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());

    let mut control = ShardedTrainer::new(exec(), cfg(3, 4)).unwrap();
    control.run().unwrap();

    // Hit 6 = the second worker body of epoch 2 panics before training;
    // the default retry budget rebuilds it from the barrier snapshot.
    failpoint::set_for_test("sharded.worker:6:panic");
    let mut t = ShardedTrainer::new(exec(), cfg(3, 4)).unwrap();
    let r = t.run();
    failpoint::set_for_test("");
    r.unwrap();

    for w in 0..control.num_workers() {
        assert_eq!(
            bits(&control.workers[w].trainer.params),
            bits(&t.workers[w].trainer.params),
            "worker {w} params diverged after recovery"
        );
    }
    assert_eq!(bits(&control.averaged_params()), bits(&t.averaged_params()));
}

#[test]
fn sharded_worker_panic_mid_epoch_rolls_back_partial_state() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());

    let mut control = ShardedTrainer::new(exec(), cfg(3, 4)).unwrap();
    control.run().unwrap();

    // 16 trainer.step hits per sharded epoch: hit 20 panics some worker
    // partway through epoch 2, after it has already advanced params and
    // history. Recovery must discard that partial progress.
    failpoint::set_for_test("trainer.step:20:panic");
    let mut t = ShardedTrainer::new(exec(), cfg(3, 4)).unwrap();
    let r = t.run();
    failpoint::set_for_test("");
    r.unwrap();

    for w in 0..control.num_workers() {
        assert_eq!(
            bits(&control.workers[w].trainer.params),
            bits(&t.workers[w].trainer.params),
            "worker {w} params diverged after mid-epoch rollback"
        );
    }
}

#[test]
fn sharded_retry_budget_exhaustion_is_a_readable_error() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());

    failpoint::set_for_test("sharded.worker:1+:panic");
    let mut t = ShardedTrainer::new(exec(), cfg(2, 4)).unwrap();
    let err = t.run().unwrap_err();
    failpoint::set_for_test("");

    let msg = format!("{err:#}");
    assert!(msg.contains("--worker-retries"), "not actionable: {msg}");
    assert!(msg.contains("panicked"), "should carry the last worker error: {msg}");
}

#[test]
fn torn_shard_write_preserves_previous_checkpoint() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = temp_dir("torn_shard");

    // 3 ckpt.write hits per serial checkpoint (shard, run, manifest):
    // hit 4 tears the epoch-2 shard file mid-write.
    let mut c = cfg(3, 1);
    c.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    failpoint::set_for_test("ckpt.write:4:torn-write");
    let mut victim = Trainer::new(exec(), c.clone()).unwrap();
    let err = victim.run().unwrap_err();
    failpoint::set_for_test("");
    assert!(format!("{err:#}").contains("torn write"), "unexpected error: {err:#}");
    drop(victim);

    // The epoch-1 checkpoint is untouched and loadable.
    let loaded = checkpoint::load(&dir, &checkpoint::config_fingerprint(&c), 1).unwrap();
    assert_eq!(loaded.epoch, 1);

    let mut control = Trainer::new(exec(), cfg(3, 1)).unwrap();
    control.run().unwrap();
    let mut resumed = Trainer::resume(exec(), c, &dir).unwrap();
    resumed.run().unwrap();
    assert_eq!(bits(&control.params), bits(&resumed.params));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn torn_manifest_write_keeps_manifest_on_previous_epoch() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = temp_dir("torn_manifest");

    // Hit 6 tears the epoch-2 manifest: the epoch-2 state files land but
    // the commit point never moves, so epoch 1 stays the live checkpoint.
    let mut c = cfg(3, 1);
    c.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    failpoint::set_for_test("ckpt.write:6:torn-write");
    let mut victim = Trainer::new(exec(), c.clone()).unwrap();
    assert!(victim.run().is_err());
    failpoint::set_for_test("");
    drop(victim);

    let loaded = checkpoint::load(&dir, &checkpoint::config_fingerprint(&c), 1).unwrap();
    assert_eq!(loaded.epoch, 1, "manifest must still point at epoch 1");

    let mut resumed = Trainer::resume(exec(), c, &dir).unwrap();
    resumed.run().unwrap();
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_incompatible_config_and_missing_checkpoint() {
    let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = temp_dir("fp_mismatch");

    let mut c = cfg(2, 1);
    c.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(exec(), c.clone()).unwrap().run().unwrap();

    let mut c2 = c.clone();
    c2.seed = 2;
    let err = Trainer::resume(exec(), c2, &dir).unwrap_err();
    assert!(format!("{err:#}").contains("incompatible config"), "{err:#}");

    let missing = temp_dir("fp_missing");
    let err = Trainer::resume(exec(), c, &missing).unwrap_err();
    assert!(format!("{err:#}").contains("no resumable checkpoint"), "{err:#}");
    let _ = fs::remove_dir_all(&dir);
}

/// Out-of-process crash: spawn `lmc train`, SIGKILL it mid-epoch-3 while
/// a failpoint holds it asleep, resume in a fresh process, and require
/// the saved params file to be byte-identical to an uninterrupted run
/// (LMCPAR1 files are deterministic, so byte equality ⟺ param equality).
#[test]
fn external_sigkill_and_resume_matches_uninterrupted_run() {
    use std::process::{Command, Stdio};
    use std::time::{Duration, Instant};

    let base = temp_dir("extkill");
    fs::create_dir_all(&base).unwrap();
    let ckpt = base.join("ckpt");
    let ctrl = base.join("ctrl.bin");
    let res = base.join("res.bin");
    let bin = env!("CARGO_BIN_EXE_lmc");
    fn train_cmd(bin: &str) -> Command {
        let mut c = Command::new(bin);
        c.args(["train", "--dataset", "cora-sim", "--arch", "gcn"]);
        c.args(["--method", "lmc", "--epochs", "6", "--seed", "1"]);
        c
    }

    let status = train_cmd(bin)
        .arg("--save-params")
        .arg(&ctrl)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "control run failed");

    // Victim checkpoints epochs 1 and 2, then sleeps at epoch 3, step 2.
    let mut child = train_cmd(bin)
        .arg("--checkpoint-dir")
        .arg(&ckpt)
        .env("LMC_FAILPOINTS", "trainer.step:10:sleep")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();

    let manifest = ckpt.join("MANIFEST.json");
    let deadline = Instant::now() + Duration::from_secs(110);
    loop {
        let epoch = fs::read_to_string(&manifest)
            .ok()
            .and_then(|s| Json::parse(&s).ok())
            .and_then(|j| j.get("epoch").and_then(Json::as_usize));
        if epoch == Some(2) {
            break;
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            panic!("victim never committed the epoch-2 checkpoint");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    child.kill().unwrap(); // SIGKILL: no destructors, no flush
    let _ = child.wait();

    let status = train_cmd(bin)
        .arg("--resume")
        .arg(&ckpt)
        .arg("--save-params")
        .arg(&res)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "resumed run failed");

    assert_eq!(
        fs::read(&ctrl).unwrap(),
        fs::read(&res).unwrap(),
        "resumed params file differs from the uninterrupted run"
    );
    let _ = fs::remove_dir_all(&base);
}
