//! Integration: manifest + PJRT runtime + numeric cross-check of a compiled
//! layer program against a host-side reference. Requires `make artifacts`
//! and a `--features pjrt` build with the real xla bindings.
#![cfg(feature = "pjrt")]

use std::path::Path;

use lmc::runtime::{lit_f32, to_vec_f32, Runtime};
use lmc::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new(Path::new("artifacts")).expect("run `make artifacts` before `cargo test`")
}

#[test]
fn manifest_has_all_programs_per_profile() {
    let rt = runtime();
    for (pname, prof) in &rt.manifest.profiles {
        for arch in ["gcn", "gcnii"] {
            let info = rt.manifest.arch(pname, arch).unwrap();
            for (b, h) in &prof.step_buckets {
                rt.manifest.train_step(pname, arch, *b, *h).unwrap();
            }
            for l in 1..=info.l {
                rt.manifest.fwd_layer(pname, arch, l).unwrap();
                rt.manifest.bwd_layer(pname, arch, l).unwrap();
            }
            rt.manifest.loss_grad(pname, arch).unwrap();
            if arch == "gcnii" {
                rt.manifest.embed0(pname, arch).unwrap();
                rt.manifest.embed0_bwd(pname, arch).unwrap();
            }
            // canonical params exist with consistent dims
            assert_eq!(info.dims.len(), info.l + 1);
            assert!(!info.params.is_empty());
        }
    }
}

/// fwd_layer numerics: relu(Ahat @ H @ W + b) for layer 1 of planetoid GCN,
/// computed host-side, must match the compiled program (which routes the
/// aggregation through the Pallas kernel).
#[test]
fn fwd_layer_matches_host_reference() {
    let rt = runtime();
    let spec = rt.manifest.fwd_layer("planetoid", "gcn", 1).unwrap().clone();
    let (bt, ht) = (spec.b, spec.h);
    let arch = rt.manifest.arch("planetoid", "gcn").unwrap().clone();
    let d_x = 48;
    let d1 = arch.dims[1];

    let mut rng = Rng::new(9);
    let mut r = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32 * 0.3).collect() };
    // small active region inside the padded buffers
    let (nb, nh) = (13usize, 21usize);
    let mut abb = vec![0f32; bt * bt];
    let mut abh = vec![0f32; bt * ht];
    for i in 0..nb {
        for j in 0..nb {
            abb[i * bt + j] = if (i + j) % 3 == 0 { 0.2 } else { 0.0 };
        }
        for j in 0..nh {
            abh[i * ht + j] = if (i * 7 + j) % 5 == 0 { 0.1 } else { 0.0 };
        }
    }
    let hp_t = {
        let mut v = vec![0f32; bt * d_x];
        v[..nb * d_x].copy_from_slice(&r(nb * d_x));
        v
    };
    let hp_h = {
        let mut v = vec![0f32; ht * d_x];
        v[..nh * d_x].copy_from_slice(&r(nh * d_x));
        v
    };
    let w1 = r(d_x * d1);
    let b1 = r(d1);

    let inputs = vec![
        lit_f32(&abb, &[bt, bt]).unwrap(),
        lit_f32(&abh, &[bt, ht]).unwrap(),
        lit_f32(&hp_t, &[bt, d_x]).unwrap(),
        lit_f32(&hp_h, &[ht, d_x]).unwrap(),
        lit_f32(&vec![0f32; bt * d_x], &[bt, d_x]).unwrap(), // H0_t unused by GCN
        lit_f32(&w1, &[d_x, d1]).unwrap(),
        lit_f32(&b1, &[d1]).unwrap(),
    ];
    let out = rt.execute(&spec.name, &inputs).unwrap();
    let got = to_vec_f32(&out[0]).unwrap();

    // host reference
    let mut agg = vec![0f32; nb * d_x];
    for i in 0..nb {
        for j in 0..nb {
            let w = abb[i * bt + j];
            if w != 0.0 {
                for d in 0..d_x {
                    agg[i * d_x + d] += w * hp_t[j * d_x + d];
                }
            }
        }
        for j in 0..nh {
            let w = abh[i * ht + j];
            if w != 0.0 {
                for d in 0..d_x {
                    agg[i * d_x + d] += w * hp_h[j * d_x + d];
                }
            }
        }
    }
    for i in 0..nb {
        for o in 0..d1 {
            let mut z = b1[o];
            for d in 0..d_x {
                z += agg[i * d_x + d] * w1[d * d1 + o];
            }
            let want = z.max(0.0); // layer 1 of 3 -> relu
            let gotv = got[i * d1 + o];
            assert!(
                (want - gotv).abs() <= 1e-4 * (1.0 + want.abs()),
                "mismatch at ({i},{o}): want {want}, got {gotv}"
            );
        }
    }
}

#[test]
fn execute_validates_input_arity_and_shape() {
    let rt = runtime();
    let spec = rt.manifest.loss_grad("planetoid", "gcn").unwrap().clone();
    // wrong arity
    let err = match rt.execute(&spec.name, &[]) {
        Err(e) => e,
        Ok(_) => panic!("empty inputs accepted"),
    };
    assert!(err.to_string().contains("inputs"), "{err}");
    // wrong shape
    let bad: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|_| lit_f32(&[0.0], &[1]).unwrap())
        .collect();
    let err = match rt.execute(&spec.name, &bad) {
        Err(e) => e,
        Ok(_) => panic!("bad shapes accepted"),
    };
    assert!(err.to_string().contains("elements"), "{err}");
}

#[test]
fn executable_cache_reuses_compilation() {
    let rt = runtime();
    let name = &rt.manifest.loss_grad("planetoid", "gcn").unwrap().name.clone();
    let a = rt.executable(name).unwrap();
    let b = rt.executable(name).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}
