//! Integration: the experiment harness runs end-to-end at reduced scale and
//! reproduces the paper's qualitative shapes, on the native backend (no
//! artifacts needed). Heavier checks are behind `--ignored` (run via
//! `cargo test --release -- --ignored` or the `make experiments` harness).

use lmc::backend::Backend;
use lmc::experiments::Ctx;
use lmc::experiments::{run_fig4, run_table7};

fn ctx() -> Ctx {
    let out = std::env::temp_dir().join("lmc_test_results");
    Ctx::new(Backend::Native, "artifacts", out.to_str().unwrap(), 0.08, 3)
        .expect("native experiment context")
}

#[test]
fn table7_shapes_hold() {
    // Cheap (accounting only + 1 epoch per cell): GAS fwd 100%/bwd <100%,
    // LMC 100%/100%, CLUSTER symmetric and smallest.
    let t = run_table7(&ctx()).unwrap();
    let md = t.to_markdown();
    // every LMC row is 100% / 100%
    for row in t.rows.iter().filter(|r| r[1] == "LMC") {
        for cell in &row[2..] {
            assert!(cell.contains("100% / 100%"), "LMC row {cell} in\n{md}");
        }
    }
    for row in t.rows.iter().filter(|r| r[1] == "GAS") {
        for cell in &row[2..] {
            let parts: Vec<&str> = cell.split('/').collect();
            assert!(parts[1].trim().starts_with("100%"), "GAS fwd {cell}");
            let bwd: f64 = parts[2].trim().trim_end_matches('%').parse().unwrap();
            assert!(bwd < 100.0, "GAS bwd should discard messages: {cell}");
        }
    }
}

#[test]
#[ignore = "several minutes: trains 6 configurations"]
fn fig4_ablation_shape() {
    // C_f & C_b should not lose to GAS at small batch (paper Fig. 4a).
    let t = run_fig4(&ctx()).unwrap();
    let get = |bs: &str, variant: &str| -> f64 {
        t.rows
            .iter()
            .find(|r| r[0] == bs && r[1] == variant)
            .map(|r| r[2].parse().unwrap())
            .unwrap()
    };
    assert!(get("1", "Cf&Cb") + 1.5 >= get("1", "GAS"));
}
